"""Pallas attention kernels (L1) — the paper's compute hot-spot.

Two kernels:

* :func:`attention_decode` — single-token decode attention over a padded
  KV cache with grouped KV heads (GQA; MHA/MQA as special cases), using a
  one-pass online softmax so the ``[H, S]`` score matrix is never
  materialized in VMEM.
* :func:`attention_prefill` — causal flash-style prefill attention over
  M tokens, tiled ``(head, q-tile, k-tile)`` with block-level causal
  skipping.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
accelerator streams the shared/inner dimension through row/column FIFOs
into a 128x128 systolic array. On TPU the analogous schedule is the
``BlockSpec`` index map: the sequence axis is streamed HBM->VMEM in
``S_TILE`` blocks while per-head accumulators stay VMEM-resident — the
same "keep the reduction stationary, stream the long axis" insight.

Kernels MUST run ``interpret=True`` here: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. The interpret
path lowers to plain HLO, which is what ``aot.py`` ships to the Rust
runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["attention_decode", "attention_prefill", "NEG_INF"]

# Finite stand-in for -inf. exp(NEG_INF - NEG_INF) == 1 keeps the online
# softmax correction factor well-defined for fully-masked tiles (a true
# -inf would produce exp(-inf + inf) = nan).
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, acc_ref, m_ref, l_ref, *, scale):
    """One (head, seq-tile) grid step of online-softmax decode attention."""
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :]  # [Dh]
    k = k_ref[:, 0, :]  # [S_TILE, Dh]
    v = v_ref[:, 0, :]  # [S_TILE, Dh]
    scores = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale
    scores = scores + mask_ref[...]  # [S_TILE]

    m_prev = m_ref[0, 0]
    l_prev = l_ref[0, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(scores))
    corr = jnp.exp(m_prev - m_cur)
    p = jnp.exp(scores - m_cur)  # [S_TILE]
    l_ref[0, 0] = l_prev * corr + jnp.sum(p)
    m_ref[0, 0] = m_cur
    acc_ref[0, :] = acc_ref[0, :] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )


def attention_decode(
    q: jax.Array,  # [H, Dh]
    k: jax.Array,  # [S, Hkv, Dh]
    v: jax.Array,  # [S, Hkv, Dh]
    mask: jax.Array,  # [S] additive; 0 valid, NEG_INF padded
    *,
    s_tile: int = 128,
) -> jax.Array:  # [H, Dh]
    """Fused single-token GQA decode attention (online softmax).

    Query head ``h`` reads KV head ``h // (H // Hkv)`` directly through
    the BlockSpec index map — the grouped heads are never materialized
    (that is the GQA bandwidth saving the paper's Fig. 1 measures).
    """
    H, dh = q.shape
    S, hkv, _ = k.shape
    if H % hkv != 0:
        raise ValueError(f"H={H} must be divisible by Hkv={hkv}")
    if S % s_tile != 0:
        raise ValueError(f"S={S} must be divisible by s_tile={s_tile}")
    group = H // hkv
    grid = (H, S // s_tile)
    scale = 1.0 / (dh**0.5)

    kernel = functools.partial(_decode_kernel, scale=scale)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, dh), lambda h, s: (h, 0)),  # q: head-stationary
            pl.BlockSpec((s_tile, 1, dh), lambda h, s, g=group: (s, h // g, 0)),
            pl.BlockSpec((s_tile, 1, dh), lambda h, s, g=group: (s, h // g, 0)),
            pl.BlockSpec((s_tile,), lambda h, s: (s,)),
        ],
        out_specs=[
            pl.BlockSpec((1, dh), lambda h, s: (h, 0)),  # acc revisited over s
            pl.BlockSpec((1, 1), lambda h, s: (h, 0)),  # running max
            pl.BlockSpec((1, 1), lambda h, s: (h, 0)),  # running denom
        ],
        out_shape=[
            jax.ShapeDtypeStruct((H, dh), jnp.float32),
            jax.ShapeDtypeStruct((H, 1), jnp.float32),
            jax.ShapeDtypeStruct((H, 1), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, mask)
    del m  # running max only needed inside the online-softmax recurrence
    return acc / l


def _prefill_kernel(
    q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *, scale, q_tile, s_tile
):
    """One (head, q-tile, k-tile) grid step of causal flash attention."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level causality: a k-tile strictly above the diagonal of this
    # q-tile contributes nothing. (The grid still visits it; the paper's
    # scheduler similarly skips empty sub-operations — cf. subops tiling.)
    @pl.when(ki * s_tile < (qi + 1) * q_tile)
    def _body():
        q = q_ref[:, 0, :]  # [Q_TILE, Dh]
        k = k_ref[:, 0, :]  # [S_TILE, Dh]
        v = v_ref[:, 0, :]  # [S_TILE, Dh]
        scores = (
            jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        )  # [Q_TILE, S_TILE]
        q_pos = qi * q_tile + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
        k_pos = ki * s_tile + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        scores = jnp.where(k_pos <= q_pos, scores, NEG_INF)

        m_prev = m_ref[:, 0]  # [Q_TILE]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        corr = jnp.exp(m_prev - m_cur)
        p = jnp.exp(scores - m_cur[:, None])
        l_ref[:, 0] = l_prev * corr + jnp.sum(p, axis=-1)
        m_ref[:, 0] = m_cur
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )


def attention_prefill(
    q: jax.Array,  # [M, H, Dh]
    k: jax.Array,  # [M, Hkv, Dh]
    v: jax.Array,  # [M, Hkv, Dh]
    *,
    q_tile: int = 128,
    s_tile: int = 128,
) -> jax.Array:  # [M, Dh] — single-head (H must be 1); see multihead wrapper
    """Causal flash-style prefill attention, one head per call."""
    M, H, dh = q.shape
    hkv = k.shape[1]
    if H % hkv != 0:
        raise ValueError(f"H={H} must be divisible by Hkv={hkv}")
    if M % q_tile != 0 or M % s_tile != 0:
        raise ValueError(f"M={M} must be divisible by q_tile and s_tile")
    group = H // hkv
    grid = (H, M // q_tile, M // s_tile)
    scale = 1.0 / (dh**0.5)

    kernel = functools.partial(
        _prefill_kernel, scale=scale, q_tile=q_tile, s_tile=s_tile
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_tile, 1, dh), lambda h, qi, ki: (qi, h, 0)),
            pl.BlockSpec((s_tile, 1, dh), lambda h, qi, ki, g=group: (ki, h // g, 0)),
            pl.BlockSpec((s_tile, 1, dh), lambda h, qi, ki, g=group: (ki, h // g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((q_tile, dh), lambda h, qi, ki: (qi, 0)),
            pl.BlockSpec((q_tile, 1), lambda h, qi, ki: (qi, 0)),
            pl.BlockSpec((q_tile, 1), lambda h, qi, ki: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, dh), jnp.float32),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
    del m
    # Kernel computes one head per outermost grid index into the same
    # [M, Dh] accumulator; heads are therefore vmapped at the caller level
    # to keep VMEM residency bounded at one head's accumulator.
    return acc / l


# The prefill kernel above writes all heads into a single [M, Dh]
# accumulator (the out_specs ignore h), which is only correct for H == 1.
# attention_prefill_multihead vmaps over heads so each head gets a private
# accumulator while preserving the GQA head->group mapping.
def attention_prefill_multihead(
    q: jax.Array,  # [M, H, Dh]
    k: jax.Array,  # [M, Hkv, Dh]
    v: jax.Array,  # [M, Hkv, Dh]
    *,
    q_tile: int = 128,
    s_tile: int = 128,
) -> jax.Array:  # [M, H, Dh]
    M, H, dh = q.shape
    hkv = k.shape[1]
    group = H // hkv

    def one_head(h):
        qh = jax.lax.dynamic_slice_in_dim(q, h, 1, axis=1)  # [M, 1, Dh]
        g = h // group
        kg = jax.lax.dynamic_slice_in_dim(k, g, 1, axis=1)
        vg = jax.lax.dynamic_slice_in_dim(v, g, 1, axis=1)
        return attention_prefill(qh, kg, vg, q_tile=q_tile, s_tile=s_tile)

    heads = jax.lax.map(one_head, jnp.arange(H))  # [H, M, Dh]
    return jnp.transpose(heads, (1, 0, 2))
