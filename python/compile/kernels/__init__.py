"""Pallas kernels (L1) and their pure-jnp oracles.

Import surface used by the L2 model (`compile.model`) and the pytest
suite. All kernels run interpret=True (see attention.py module docs).
"""

from .attention import (  # noqa: F401
    NEG_INF,
    attention_decode,
    attention_prefill,
    attention_prefill_multihead,
)
from .matmul import quant_matmul, tiled_matmul  # noqa: F401
from . import ref  # noqa: F401
