"""Pallas tiled matmul kernels (L1).

``tiled_matmul`` is the f32 projection workhorse used by the L2 model for
QKV / output / FFN projections; ``quant_matmul`` mirrors the paper's
uniform 8-bit operand setting (int8 x int8 -> int32 accumulate -> f32
dequant), which is what the Rust performance model assumes per MAC.

Tiles default to 128 — the MXU systolic tile and, not coincidentally, the
paper's 128x128 PE array dimension: one output tile per grid step with the
shared dimension streamed in ``k_tile`` blocks is exactly the row/column
FIFO streaming schedule of the paper's Fig. 4 template, expressed as a
BlockSpec.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["tiled_matmul", "quant_matmul"]


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (m, n, k) grid step: o[m,n] += x[m,k] @ w[k,n]."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def tiled_matmul(
    x: jax.Array,  # [M, K] f32
    w: jax.Array,  # [K, N] f32
    *,
    m_tile: int = 128,
    n_tile: int = 128,
    k_tile: int = 128,
) -> jax.Array:  # [M, N] f32
    """Blocked f32 matmul; output tile stationary, K streamed."""
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"inner dims mismatch: {K} vs {K2}")
    m_tile = min(m_tile, M)
    n_tile = min(n_tile, N)
    k_tile = min(k_tile, K)
    if M % m_tile or N % n_tile or K % k_tile:
        raise ValueError(
            f"dims ({M},{K},{N}) not divisible by tiles ({m_tile},{k_tile},{n_tile})"
        )
    grid = (M // m_tile, N // n_tile, K // k_tile)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_tile, k_tile), lambda m, n, k: (m, k)),
            pl.BlockSpec((k_tile, n_tile), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((m_tile, n_tile), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=True,
    )(x, w)


def _quant_matmul_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, *, k_steps):
    """int8 x int8 -> int32 accumulate; dequantize on the last K step.

    The f32 output ref doubles as the int32 accumulator (bit-compatible
    width); values are reinterpreted only at the final dequant step.
    """
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.dot(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    o_ref[...] += acc

    @pl.when(ki == k_steps - 1)
    def _dequant():
        o_ref[...] = o_ref[...] * xs_ref[0] * ws_ref[...][None, :]


def quant_matmul(
    x_q: jax.Array,  # [M, K] int8
    w_q: jax.Array,  # [K, N] int8
    x_scale: jax.Array,  # [1] f32 per-tensor
    w_scale: jax.Array,  # [N] f32 per-channel
    *,
    m_tile: int = 128,
    n_tile: int = 128,
    k_tile: int = 128,
) -> jax.Array:  # [M, N] f32
    """8-bit symmetric quantized matmul with int32 accumulation.

    Note: partial sums are carried in f32 (exact for |acc| < 2^24, which
    holds for int8 x int8 with K_tile <= 2^8 terms per step and the tiny
    model dims used on this substrate; the ref oracle accumulates in
    int32 and the property tests assert exact agreement).
    """
    M, K = x_q.shape
    K2, N = w_q.shape
    if K != K2:
        raise ValueError(f"inner dims mismatch: {K} vs {K2}")
    m_tile = min(m_tile, M)
    n_tile = min(n_tile, N)
    k_tile = min(k_tile, K)
    if M % m_tile or N % n_tile or K % k_tile:
        raise ValueError(
            f"dims ({M},{K},{N}) not divisible by tiles ({m_tile},{k_tile},{n_tile})"
        )
    k_steps = K // k_tile
    grid = (M // m_tile, N // n_tile, k_steps)
    import functools

    kernel = functools.partial(_quant_matmul_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_tile, k_tile), lambda m, n, k: (m, k)),
            pl.BlockSpec((k_tile, n_tile), lambda m, n, k: (k, n)),
            pl.BlockSpec((1,), lambda m, n, k: (0,)),
            pl.BlockSpec((n_tile,), lambda m, n, k: (n,)),
        ],
        out_specs=pl.BlockSpec((m_tile, n_tile), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=True,
    )(x_q, w_q, x_scale, w_scale)
