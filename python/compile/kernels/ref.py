"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to float tolerance)
counterpart here. pytest (python/tests/) sweeps shapes/dtypes with
hypothesis and asserts allclose between the kernel (interpret=True) and
these references. The references are also what the L2 model's unit tests
compare against, so L1-vs-L2 disagreements are always attributable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "attention_decode_ref",
    "attention_prefill_ref",
    "matmul_ref",
    "quant_matmul_ref",
    "rmsnorm_ref",
    "layernorm_ref",
    "swiglu_ref",
    "softmax_ref",
]


def softmax_ref(x: jax.Array, axis: int = -1) -> jax.Array:
    """Numerically-stable softmax (subtract running max)."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def attention_decode_ref(
    q: jax.Array,  # [H, Dh]        query for the single decode token
    k: jax.Array,  # [S, Hkv, Dh]   key cache (padded to S)
    v: jax.Array,  # [S, Hkv, Dh]   value cache (padded to S)
    mask: jax.Array,  # [S]         additive mask: 0 for valid, -inf for pad
) -> jax.Array:  # [H, Dh]
    """Single-token decode attention with grouped KV heads (GQA).

    Query head h attends to KV head ``h // (H // Hkv)``. MHA is the
    Hkv == H special case; MQA is Hkv == 1.
    """
    H, dh = q.shape
    S, hkv, _ = k.shape
    assert H % hkv == 0, f"H={H} not divisible by Hkv={hkv}"
    group = H // hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    # Expand kv heads to query heads: [S, H, Dh]
    k_exp = jnp.repeat(k, group, axis=1)
    v_exp = jnp.repeat(v, group, axis=1)
    # scores[h, s] = q[h] . k[s, h]
    scores = jnp.einsum("hd,shd->hs", q, k_exp) * scale
    scores = scores + mask[None, :]
    p = softmax_ref(scores, axis=-1)
    return jnp.einsum("hs,shd->hd", p, v_exp)


def attention_prefill_ref(
    q: jax.Array,  # [M, H, Dh]
    k: jax.Array,  # [M, Hkv, Dh]
    v: jax.Array,  # [M, Hkv, Dh]
) -> jax.Array:  # [M, H, Dh]
    """Causal self-attention over a full M-token prefill."""
    M, H, dh = q.shape
    hkv = k.shape[1]
    group = H // hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    k_exp = jnp.repeat(k, group, axis=1)
    v_exp = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("mhd,nhd->hmn", q, k_exp) * scale
    causal = jnp.tril(jnp.ones((M, M), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, -jnp.inf)
    p = softmax_ref(scores, axis=-1)
    return jnp.einsum("hmn,nhd->mhd", p, v_exp)


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain f32 matmul oracle: [M, K] @ [K, N] -> [M, N]."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def quant_matmul_ref(
    x_q: jax.Array,  # [M, K] int8
    w_q: jax.Array,  # [K, N] int8
    x_scale: jax.Array,  # scalar f32
    w_scale: jax.Array,  # [N] f32 per-output-channel
) -> jax.Array:  # [M, N] f32
    """8-bit symmetric-quantized matmul with int32 accumulation.

    Mirrors the paper's uniform 8-bit operand setting: accumulate in
    int32, dequantize with per-tensor activation scale x per-channel
    weight scale.
    """
    acc = jnp.dot(
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * x_scale * w_scale[None, :]


def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last axis (DeepSeek/Qwen-style)."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma


def layernorm_ref(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5
) -> jax.Array:
    """LayerNorm over the last axis (GPT-2-style)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array) -> jax.Array:
    """SwiGLU gate: silu(x @ w_gate) * (x @ w_up)."""
    g = jnp.dot(x, w_gate, preferred_element_type=jnp.float32)
    u = jnp.dot(x, w_up, preferred_element_type=jnp.float32)
    return jax.nn.silu(g) * u
