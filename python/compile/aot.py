"""AOT lowering: JAX (L2+L1) -> HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README).

Every entry point is lowered with ``return_tuple=True``; the Rust side
unwraps with ``Literal::to_tuple``. A ``manifest.json`` records, for each
artifact, the positional input order / shapes / dtypes and output shapes,
so the Rust runtime never has to guess at pytree flattening order — the
entry functions here take *positional* args in the documented order.

Run via ``make artifacts`` (no-op when inputs are unchanged); Python never
runs on the request path.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import NEG_INF, attention_decode, tiled_matmul


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io(specs):
    return [
        {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}
        for name, s in specs
    ]


# ---------------------------------------------------------------------------
# Entry points. Positional-arg wrappers with fixed, manifest-recorded order.
# ---------------------------------------------------------------------------


def _decode_entry(cfg: M.ModelConfig):
    """decode(x, k_cache, v_cache, pos, *weights) -> (y, new_k, new_v)."""
    L, D, S = cfg.n_layers, cfg.d_model, cfg.max_seq
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    weight_names = ["wqkv", "wo", "w2", "ln1_g", "ln2_g"]
    if cfg.ffn == "swiglu":
        weight_names += ["wg", "wu"]
    else:
        weight_names += ["w1"]
    if cfg.norm == "layernorm":
        weight_names += ["ln1_b", "ln2_b"]

    def fn(x, kc, vc, pos, *weights):
        params = dict(zip(weight_names, weights))
        return M.decode_step(cfg, params, x, kc, vc, pos)

    shapes = {
        "wqkv": (L, D, cfg.qkv_out_dim),
        "wo": (L, cfg.n_heads * Dh, D),
        "w2": (L, cfg.d_ff, D),
        "ln1_g": (L, D),
        "ln2_g": (L, D),
        "wg": (L, D, cfg.d_ff),
        "wu": (L, D, cfg.d_ff),
        "w1": (L, D, cfg.d_ff),
        "ln1_b": (L, D),
        "ln2_b": (L, D),
    }
    inputs = [
        ("x", _spec((1, D))),
        ("k_cache", _spec((L, S, Hkv, Dh))),
        ("v_cache", _spec((L, S, Hkv, Dh))),
        ("pos", _spec((), jnp.int32)),
    ] + [(n, _spec(shapes[n])) for n in weight_names]
    outputs = [
        ("y", _spec((1, D))),
        ("new_k_cache", _spec((L, S, Hkv, Dh))),
        ("new_v_cache", _spec((L, S, Hkv, Dh))),
    ]
    return fn, inputs, outputs


def _prefill_entry(cfg: M.ModelConfig, m: int):
    """prefill(xs, *weights) -> (ys, k_cache, v_cache)."""
    L, D = cfg.n_layers, cfg.d_model
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    weight_names = ["wqkv", "wo", "w2", "ln1_g", "ln2_g"]
    if cfg.ffn == "swiglu":
        weight_names += ["wg", "wu"]
    else:
        weight_names += ["w1"]
    if cfg.norm == "layernorm":
        weight_names += ["ln1_b", "ln2_b"]

    def fn(xs, *weights):
        params = dict(zip(weight_names, weights))
        return M.prefill(cfg, params, xs)

    shapes = {
        "wqkv": (L, D, cfg.qkv_out_dim),
        "wo": (L, cfg.n_heads * Dh, D),
        "w2": (L, cfg.d_ff, D),
        "ln1_g": (L, D),
        "ln2_g": (L, D),
        "wg": (L, D, cfg.d_ff),
        "wu": (L, D, cfg.d_ff),
        "w1": (L, D, cfg.d_ff),
        "ln1_b": (L, D),
        "ln2_b": (L, D),
    }
    inputs = [("xs", _spec((m, D)))] + [(n, _spec(shapes[n])) for n in weight_names]
    outputs = [
        ("ys", _spec((m, D))),
        ("k_cache", _spec((L, m, Hkv, Dh))),
        ("v_cache", _spec((L, m, Hkv, Dh))),
    ]
    return fn, inputs, outputs


def _attention_entry(h: int, hkv: int, dh: int, s: int):
    def fn(q, k, v, mask):
        return (attention_decode(q, k, v, mask, s_tile=min(128, s)),)

    inputs = [
        ("q", _spec((h, dh))),
        ("k", _spec((s, hkv, dh))),
        ("v", _spec((s, hkv, dh))),
        ("mask", _spec((s,))),
    ]
    outputs = [("out", _spec((h, dh)))]
    return fn, inputs, outputs


def _matmul_entry(m: int, k: int, n: int):
    def fn(x, w):
        return (tiled_matmul(x, w),)

    inputs = [("x", _spec((m, k))), ("w", _spec((k, n)))]
    outputs = [("out", _spec((m, n)))]
    return fn, inputs, outputs


def entries():
    """All AOT entry points: name -> (fn, input specs, output specs, meta)."""
    out = {}
    for cfg in (M.TINY_MHA, M.TINY_GQA):
        tag = cfg.name.replace("-", "_")
        fn, ins, outs = _decode_entry(cfg)
        out[f"decode_{tag}"] = (fn, ins, outs, {"model": cfg.name, "kind": "decode"})
        fn, ins, outs = _prefill_entry(cfg, m=32)
        out[f"prefill_{tag}"] = (
            fn,
            ins,
            outs,
            {"model": cfg.name, "kind": "prefill", "m": 32},
        )
    fn, ins, outs = _attention_entry(h=4, hkv=2, dh=32, s=128)
    out["attn_decode_gqa"] = (fn, ins, outs, {"kind": "kernel"})
    fn, ins, outs = _matmul_entry(128, 128, 128)
    out["matmul_f32_128"] = (fn, ins, outs, {"kind": "kernel"})
    return out


def build(out_dir: pathlib.Path, only: str | None = None) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"format": "hlo-text", "neg_inf": NEG_INF, "entries": {}}
    manifest_path = out_dir / "manifest.json"
    if manifest_path.exists():
        manifest = json.loads(manifest_path.read_text())
    for name, (fn, ins, outs, meta) in entries().items():
        if only and name != only:
            continue
        path = out_dir / f"{name}.hlo.txt"
        lowered = jax.jit(fn).lower(*[s for _, s in ins])
        text = to_hlo_text(lowered)
        path.write_text(text)
        manifest["entries"][name] = {
            "file": path.name,
            "inputs": _io(ins),
            "outputs": _io(outs),
            "meta": meta,
        }
        print(f"wrote {path} ({len(text)} chars)")
    manifest_path.write_text(json.dumps(manifest, indent=2))
    print(f"wrote {manifest_path} ({len(manifest['entries'])} entries)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="lower a single entry by name")
    args = ap.parse_args()
    build(pathlib.Path(args.out), args.only)


if __name__ == "__main__":
    main()
