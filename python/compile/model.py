"""L2 — JAX transformer decoder (build-time only; never on the request path).

Defines the paper's two workload families as *functional* models:

* GPT-2-XL-style blocks: LayerNorm + MHA + GELU FFN
* DeepSeek-R1-Distill-Qwen-style blocks: RMSNorm + GQA + SwiGLU

The full-size configs (`GPT2_XL`, `DS_R1D_Q15B`) are used for parameter /
MAC accounting only (they cross-check the paper's Table I and the Rust
workload builder). The `TINY_*` configs are the ones actually lowered by
``aot.py`` and executed from the Rust runtime — same code path, smaller
dims, per DESIGN.md's substitution table.

All heavy compute goes through the L1 Pallas kernels
(``kernels.tiled_matmul``, ``kernels.attention_decode``,
``kernels.attention_prefill_multihead``) so the lowered HLO exercises the
kernel path end to end. Layers are folded with ``lax.scan`` over stacked
parameters (one trace per block, not per layer — §Perf L2).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from .kernels import (
    NEG_INF,
    attention_decode,
    attention_prefill_multihead,
    tiled_matmul,
)
from .kernels.ref import layernorm_ref, rmsnorm_ref

__all__ = [
    "ModelConfig",
    "GPT2_XL",
    "DS_R1D_Q15B",
    "TINY_MHA",
    "TINY_GQA",
    "init_params",
    "decode_step",
    "prefill",
    "param_count",
    "total_macs",
    "kv_cache_bytes",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Structural description of a decoder-only transformer.

    Mirrors the paper's Table I columns: L (layers), D (embedding dim),
    D_ff (FFN hidden dim), H (query heads), H_kv (shared KV heads), FFN
    type. ``max_seq`` is the padded KV-cache length S used by the decode
    path (the paper's M).
    """

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    ffn: Literal["gelu", "swiglu"]
    norm: Literal["layernorm", "rmsnorm"]
    max_seq: int

    def __post_init__(self):
        if self.n_heads % self.n_kv_heads:
            raise ValueError(
                f"{self.name}: H={self.n_heads} not divisible by "
                f"Hkv={self.n_kv_heads}"
            )

    @property
    def attention_kind(self) -> str:
        if self.n_kv_heads == self.n_heads:
            return "MHA"
        if self.n_kv_heads == 1:
            return "MQA"
        return "GQA"

    @property
    def qkv_out_dim(self) -> int:
        return (self.n_heads + 2 * self.n_kv_heads) * self.d_head


# ---------------------------------------------------------------------------
# Paper configurations (Table I) — accounting only, never lowered.
# ---------------------------------------------------------------------------

GPT2_XL = ModelConfig(
    name="gpt2-xl",
    n_layers=48,
    d_model=1600,
    n_heads=25,
    n_kv_heads=25,  # MHA
    d_head=64,
    d_ff=6400,
    ffn="gelu",
    norm="layernorm",
    max_seq=2048,
)

DS_R1D_Q15B = ModelConfig(
    name="ds-r1d-qwen-1.5b",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,  # GQA, group size 6
    d_head=128,
    d_ff=8960,
    ffn="swiglu",
    norm="rmsnorm",
    max_seq=2048,
)

# ---------------------------------------------------------------------------
# Tiny configs — the ones AOT-lowered and run from Rust (same code path).
# ---------------------------------------------------------------------------

TINY_MHA = ModelConfig(
    name="tiny-mha",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_head=32,
    d_ff=256,
    ffn="gelu",
    norm="layernorm",
    max_seq=128,
)

TINY_GQA = ModelConfig(
    name="tiny-gqa",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=256,
    ffn="swiglu",
    norm="rmsnorm",
    max_seq=128,
)


# ---------------------------------------------------------------------------
# Accounting (cross-checked against the paper's Table I by pytest and by
# the Rust workload builder).
# ---------------------------------------------------------------------------


def param_count(cfg: ModelConfig) -> int:
    """Non-embedding parameter count (the paper's P column)."""
    qkv = cfg.d_model * cfg.qkv_out_dim
    out = cfg.n_heads * cfg.d_head * cfg.d_model
    if cfg.ffn == "swiglu":
        ffn = 3 * cfg.d_model * cfg.d_ff
    else:
        ffn = 2 * cfg.d_model * cfg.d_ff
    norms = (2 if cfg.norm == "layernorm" else 1) * 2 * cfg.d_model
    return cfg.n_layers * (qkv + out + ffn + norms)


def total_macs(cfg: ModelConfig, seq_len: int | None = None) -> int:
    """Total MACs for a full causal pass over ``seq_len`` tokens.

    Projection MACs are seq_len * weight-matrix sizes; attention
    score/context MACs are 2 * H * S^2 * Dh per layer (full causal score
    matrix, matching the simulator's op graph and the paper's MACs column).
    """
    s = seq_len or cfg.max_seq
    qkv = cfg.d_model * cfg.qkv_out_dim
    out = cfg.n_heads * cfg.d_head * cfg.d_model
    ffn = (3 if cfg.ffn == "swiglu" else 2) * cfg.d_model * cfg.d_ff
    proj = s * (qkv + out + ffn)
    attn = 2 * cfg.n_heads * s * s * cfg.d_head
    return cfg.n_layers * (proj + attn)


def kv_cache_bytes(
    cfg: ModelConfig, seq_len: int | None = None, bytes_per_el: int = 1
) -> int:
    """KV-cache footprint at ``seq_len`` tokens (8-bit operands default)."""
    s = seq_len or cfg.max_seq
    return 2 * cfg.n_layers * s * cfg.n_kv_heads * cfg.d_head * bytes_per_el


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, jax.Array]:
    """Random (scaled-normal) parameters, stacked over layers.

    Layout (L = n_layers, D = d_model):
      wqkv [L, D, (H+2Hkv)*Dh]   wo [L, H*Dh, D]
      gelu:   w1 [L, D, Dff]  w2 [L, Dff, D]
      swiglu: wg [L, D, Dff]  wu [L, D, Dff]  w2 [L, Dff, D]
      norm scales [L, D] (+ biases for layernorm)
    """
    L, D = cfg.n_layers, cfg.d_model
    keys = jax.random.split(key, 8)

    def w(k, *shape):
        fan_in = shape[-2]
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(
            jnp.float32
        )

    params = {
        "wqkv": w(keys[0], L, D, cfg.qkv_out_dim),
        "wo": w(keys[1], L, cfg.n_heads * cfg.d_head, D),
        "w2": w(keys[2], L, cfg.d_ff, D),
        "ln1_g": jnp.ones((L, D), jnp.float32),
        "ln2_g": jnp.ones((L, D), jnp.float32),
    }
    if cfg.ffn == "swiglu":
        params["wg"] = w(keys[3], L, D, cfg.d_ff)
        params["wu"] = w(keys[4], L, D, cfg.d_ff)
    else:
        params["w1"] = w(keys[3], L, D, cfg.d_ff)
    if cfg.norm == "layernorm":
        params["ln1_b"] = jnp.zeros((L, D), jnp.float32)
        params["ln2_b"] = jnp.zeros((L, D), jnp.float32)
    return params


def _norm(cfg: ModelConfig, x, g, b):
    if cfg.norm == "layernorm":
        return layernorm_ref(x, g, b)
    return rmsnorm_ref(x, g)


def _ffn(cfg: ModelConfig, h, layer):
    if cfg.ffn == "swiglu":
        gate = tiled_matmul(h, layer["wg"])
        up = tiled_matmul(h, layer["wu"])
        act = jax.nn.silu(gate) * up
    else:
        act = jax.nn.gelu(tiled_matmul(h, layer["w1"]))
    return tiled_matmul(act, layer["w2"])


def _split_qkv(cfg: ModelConfig, qkv: jax.Array):
    """Split a [T, (H+2Hkv)*Dh] projection into q/k/v head tensors."""
    T = qkv.shape[0]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = qkv[:, : H * Dh].reshape(T, H, Dh)
    k = qkv[:, H * Dh : (H + Hkv) * Dh].reshape(T, Hkv, Dh)
    v = qkv[:, (H + Hkv) * Dh :].reshape(T, Hkv, Dh)
    return q, k, v


def decode_step(
    cfg: ModelConfig,
    params: dict[str, jax.Array],
    x: jax.Array,  # [1, D] hidden state of the current token
    k_cache: jax.Array,  # [L, S, Hkv, Dh]
    v_cache: jax.Array,  # [L, S, Hkv, Dh]
    pos: jax.Array,  # scalar int32: index of the current token
):
    """One auto-regressive decode step across all layers.

    Returns ``(y [1, D], new_k_cache, new_v_cache)``. The KV caches are
    functionally updated at ``pos``; the Rust runtime round-trips them
    between steps (they are the tensors whose growth the paper's Stage I
    traces).
    """
    S = cfg.max_seq
    mask = jnp.where(jnp.arange(S) <= pos, 0.0, NEG_INF).astype(jnp.float32)

    def body(x, layer):
        h = _norm(cfg, x, layer["ln1_g"], layer.get("ln1_b"))
        qkv = tiled_matmul(h, layer["wqkv"])  # [1, (H+2Hkv)*Dh]
        q, k_new, v_new = _split_qkv(cfg, qkv)
        kc = jax.lax.dynamic_update_slice(layer["k_cache"], k_new, (pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(layer["v_cache"], v_new, (pos, 0, 0))
        attn = attention_decode(q[0], kc, vc, mask, s_tile=min(128, S))
        x = x + tiled_matmul(attn.reshape(1, -1), layer["wo"])
        h2 = _norm(cfg, x, layer["ln2_g"], layer.get("ln2_b"))
        x = x + _ffn(cfg, h2, layer)
        return x, (kc, vc)

    layers = dict(params)
    layers["k_cache"] = k_cache
    layers["v_cache"] = v_cache
    y, (new_k, new_v) = jax.lax.scan(body, x, layers)
    return y, new_k, new_v


def prefill(
    cfg: ModelConfig,
    params: dict[str, jax.Array],
    xs: jax.Array,  # [M, D] hidden states of the prompt tokens
):
    """Causal forward pass over the whole prompt, producing the KV caches.

    Returns ``(ys [M, D], k_cache [L, M, Hkv, Dh], v_cache)``. This is
    the op graph Stage I simulates at M=2048 for the paper's workloads.
    """
    M = xs.shape[0]
    tile = min(128, M)

    def body(x, layer):
        h = _norm(cfg, x, layer["ln1_g"], layer.get("ln1_b"))
        qkv = tiled_matmul(h, layer["wqkv"])  # [M, (H+2Hkv)*Dh]
        q, k, v = _split_qkv(cfg, qkv)
        attn = attention_prefill_multihead(q, k, v, q_tile=tile, s_tile=tile)
        x = x + tiled_matmul(attn.reshape(M, -1), layer["wo"])
        h2 = _norm(cfg, x, layer["ln2_g"], layer.get("ln2_b"))
        x = x + _ffn(cfg, h2, layer)
        return x, (k, v)

    ys, (ks, vs) = jax.lax.scan(body, xs, params)
    return ys, ks, vs
