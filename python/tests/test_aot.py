"""AOT pipeline tests: every entry lowers to parseable HLO text and the
manifest faithfully records the positional interface the Rust runtime uses."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


@pytest.fixture(scope="module")
def entry_map():
    return aot.entries()


def test_entry_inventory(entry_map):
    names = set(entry_map)
    assert {
        "decode_tiny_mha",
        "decode_tiny_gqa",
        "prefill_tiny_mha",
        "prefill_tiny_gqa",
        "attn_decode_gqa",
        "matmul_f32_128",
    } <= names


def test_all_entries_lower_to_hlo_text(entry_map):
    for name, (fn, ins, outs, _meta) in entry_map.items():
        lowered = jax.jit(fn).lower(*[s for _, s in ins])
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        # return_tuple=True: root is a tuple of the declared outputs
        assert text.count("parameter(") >= len(ins), name


def test_lowered_outputs_match_declared_shapes(entry_map):
    for name, (fn, ins, outs, _meta) in entry_map.items():
        res = jax.eval_shape(fn, *[s for _, s in ins])
        flat = jax.tree.leaves(res)
        assert len(flat) == len(outs), name
        for got, (oname, want) in zip(flat, outs):
            assert tuple(got.shape) == tuple(want.shape), (name, oname)
            assert got.dtype == want.dtype, (name, oname)


def test_decode_entry_executes_positionally():
    """The positional wrapper == the dict-params model call."""
    cfg = M.TINY_GQA
    fn, ins, _outs, _meta = aot.entries()["decode_tiny_gqa"]
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.d_model), jnp.float32)
    kc = jnp.zeros(
        (cfg.n_layers, cfg.max_seq, cfg.n_kv_heads, cfg.d_head), jnp.float32
    )
    vc = jnp.zeros_like(kc)
    # manifest order: x, k_cache, v_cache, pos, then weights by name
    weight_names = [n for n, _ in ins[4:]]
    args = [x, kc, vc, jnp.int32(0)] + [params[n] for n in weight_names]
    y_pos, _, _ = fn(*args)
    y_ref, _, _ = M.decode_step(cfg, params, x, kc, vc, jnp.int32(0))
    np.testing.assert_allclose(y_pos, y_ref, atol=1e-6)


@pytest.mark.skipif(not ARTIFACTS.exists(), reason="run `make artifacts` first")
def test_manifest_consistent_with_entries():
    manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
    live = aot.entries()
    for name, ent in manifest["entries"].items():
        assert name in live, f"stale manifest entry {name}"
        _fn, ins, outs, _meta = live[name]
        assert [i["name"] for i in ent["inputs"]] == [n for n, _ in ins]
        for rec, (_n, spec) in zip(ent["inputs"], ins):
            assert tuple(rec["shape"]) == tuple(spec.shape)
            assert rec["dtype"] == str(spec.dtype)
        assert [o["name"] for o in ent["outputs"]] == [n for n, _ in outs]
        assert (ARTIFACTS / ent["file"]).exists()


@pytest.mark.skipif(not ARTIFACTS.exists(), reason="run `make artifacts` first")
def test_artifact_files_are_hlo_text():
    manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
    for name, ent in manifest["entries"].items():
        text = (ARTIFACTS / ent["file"]).read_text()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
