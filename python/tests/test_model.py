"""L2 model tests: Table I accounting, decode/prefill consistency, shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


# --- Table I cross-checks (paper's headline structural numbers) -----------


@pytest.mark.parametrize(
    "cfg,p_paper_b,macs_paper_t,kind",
    [
        (M.GPT2_XL, 1.48, 3.66, "MHA"),
        (M.DS_R1D_Q15B, 1.31, 3.04, "GQA"),
    ],
)
def test_table1_accounting(cfg, p_paper_b, macs_paper_t, kind):
    p = M.param_count(cfg) / 1e9
    macs = M.total_macs(cfg) / 1e12
    assert abs(p - p_paper_b) < 0.01 * p_paper_b + 0.01, (p, p_paper_b)
    assert abs(macs - macs_paper_t) < 0.01 * macs_paper_t + 0.01, (macs, macs_paper_t)
    assert cfg.attention_kind == kind


def test_kv_cache_ratio_mha_vs_gqa():
    """GQA slashes KV bytes: the structural root of the paper's Fig. 5."""
    kv_mha = M.kv_cache_bytes(M.GPT2_XL)
    kv_gqa = M.kv_cache_bytes(M.DS_R1D_Q15B)
    # GPT-2 XL: 2*48*2048*25*64 = 314.6 MB; DS: 2*28*2048*2*128 = 29.4 MB
    assert kv_mha == 2 * 48 * 2048 * 1600
    assert kv_gqa == 2 * 28 * 2048 * 256
    assert kv_mha / kv_gqa > 10


def test_attention_kind_classification():
    assert M.TINY_MHA.attention_kind == "MHA"
    assert M.TINY_GQA.attention_kind == "GQA"
    mqa = M.ModelConfig(
        name="mqa", n_layers=1, d_model=64, n_heads=4, n_kv_heads=1,
        d_head=16, d_ff=128, ffn="gelu", norm="layernorm", max_seq=64,
    )
    assert mqa.attention_kind == "MQA"


def test_bad_grouping_rejected():
    with pytest.raises(ValueError, match="divisible"):
        M.ModelConfig(
            name="bad", n_layers=1, d_model=64, n_heads=5, n_kv_heads=2,
            d_head=16, d_ff=128, ffn="gelu", norm="layernorm", max_seq=64,
        )


# --- functional consistency -------------------------------------------------


@pytest.fixture(scope="module", params=["tiny-mha", "tiny-gqa"])
def cfg(request):
    return {"tiny-mha": M.TINY_MHA, "tiny-gqa": M.TINY_GQA}[request.param]


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def test_param_shapes(cfg, params):
    L, D = cfg.n_layers, cfg.d_model
    assert params["wqkv"].shape == (L, D, cfg.qkv_out_dim)
    assert params["wo"].shape == (L, cfg.n_heads * cfg.d_head, D)
    assert params["w2"].shape == (L, cfg.d_ff, D)
    if cfg.ffn == "swiglu":
        assert params["wg"].shape == (L, D, cfg.d_ff)
        assert params["wu"].shape == (L, D, cfg.d_ff)
    else:
        assert params["w1"].shape == (L, D, cfg.d_ff)
    if cfg.norm == "layernorm":
        assert params["ln1_b"].shape == (L, D)


def test_decode_step_shapes_and_cache_update(cfg, params):
    L, S = cfg.n_layers, cfg.max_seq
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    x = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.d_model), jnp.float32)
    kc = jnp.zeros((L, S, Hkv, Dh), jnp.float32)
    vc = jnp.zeros_like(kc)
    y, nk, nv = M.decode_step(cfg, params, x, kc, vc, jnp.int32(3))
    assert y.shape == (1, cfg.d_model)
    assert nk.shape == kc.shape and nv.shape == vc.shape
    # only position 3 may change
    changed_k = jnp.any(nk != 0, axis=(2, 3))  # [L, S]
    assert bool(jnp.all(changed_k[:, 3]))
    assert not bool(jnp.any(changed_k[:, :3])) and not bool(
        jnp.any(changed_k[:, 4:])
    )
    assert bool(jnp.all(jnp.isfinite(y)))


def test_prefill_matches_decode_loop(cfg, params):
    """Prefill over m tokens == m sequential decode steps (same y, KV)."""
    m = 8
    xs = jax.random.normal(jax.random.PRNGKey(2), (m, cfg.d_model), jnp.float32)
    ys_pre, k_pre, v_pre = M.prefill(cfg, params, xs)

    L, S = cfg.n_layers, cfg.max_seq
    kc = jnp.zeros((L, S, cfg.n_kv_heads, cfg.d_head), jnp.float32)
    vc = jnp.zeros_like(kc)
    ys_dec = []
    for t in range(m):
        y, kc, vc = M.decode_step(cfg, params, xs[t : t + 1], kc, vc, jnp.int32(t))
        ys_dec.append(y[0])
    ys_dec = jnp.stack(ys_dec)
    np.testing.assert_allclose(ys_dec, ys_pre, atol=5e-4, rtol=1e-4)
    np.testing.assert_allclose(kc[:, :m], k_pre, atol=5e-5, rtol=1e-5)
    np.testing.assert_allclose(vc[:, :m], v_pre, atol=5e-5, rtol=1e-5)


def test_decode_is_causal_in_pos(cfg, params):
    """Garbage beyond pos in the caches must not affect the output."""
    L, S = cfg.n_layers, cfg.max_seq
    x = jax.random.normal(jax.random.PRNGKey(3), (1, cfg.d_model), jnp.float32)
    kc = jax.random.normal(
        jax.random.PRNGKey(4), (L, S, cfg.n_kv_heads, cfg.d_head), jnp.float32
    )
    vc = jax.random.normal(jax.random.PRNGKey(5), kc.shape, jnp.float32)
    pos = 5
    y1, _, _ = M.decode_step(cfg, params, x, kc, vc, jnp.int32(pos))
    kc2 = kc.at[:, pos + 1 :].set(1e3)
    vc2 = vc.at[:, pos + 1 :].set(-1e3)
    y2, _, _ = M.decode_step(cfg, params, x, kc2, vc2, jnp.int32(pos))
    np.testing.assert_allclose(y1, y2, atol=1e-5)


def test_decode_deterministic(cfg, params):
    x = jax.random.normal(jax.random.PRNGKey(6), (1, cfg.d_model), jnp.float32)
    L, S = cfg.n_layers, cfg.max_seq
    kc = jnp.zeros((L, S, cfg.n_kv_heads, cfg.d_head), jnp.float32)
    vc = jnp.zeros_like(kc)
    y1, _, _ = M.decode_step(cfg, params, x, kc, vc, jnp.int32(0))
    y2, _, _ = M.decode_step(cfg, params, x, kc, vc, jnp.int32(0))
    np.testing.assert_array_equal(y1, y2)
