"""Shared pytest fixtures/settings for the TRAPTI python suite."""

import jax
import pytest
from hypothesis import settings

jax.config.update("jax_platform_name", "cpu")

# Pallas interpret mode re-traces per shape; keep hypothesis deadlines off
# so compile time is never mistaken for flakiness.
settings.register_profile("trapti", deadline=None, max_examples=25)
settings.load_profile("trapti")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
