"""L1 matmul kernels vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import quant_matmul, tiled_matmul
from compile.kernels import ref


@st.composite
def mm_shapes(draw):
    t = draw(st.sampled_from([16, 32, 64]))
    m = t * draw(st.integers(1, 3))
    k = t * draw(st.integers(1, 3))
    n = t * draw(st.integers(1, 3))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, k, n, t, seed


@given(mm_shapes())
def test_tiled_matmul_matches_ref(shape):
    m, k, n, t, seed = shape
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    out = tiled_matmul(x, w, m_tile=t, n_tile=t, k_tile=t)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(out, want, atol=1e-4 * k**0.5, rtol=1e-5)


def test_tiled_matmul_m1_row_vector():
    """The decode path multiplies [1, K] x [K, N] — M smaller than tile."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
    out = tiled_matmul(x, w)
    np.testing.assert_allclose(out, ref.matmul_ref(x, w), atol=1e-4, rtol=1e-5)


def test_tiled_matmul_identity():
    x = jnp.eye(64, dtype=jnp.float32)
    w = jnp.asarray(np.random.default_rng(2).standard_normal((64, 64)), jnp.float32)
    np.testing.assert_allclose(
        tiled_matmul(x, w, m_tile=32, n_tile=32, k_tile=32), w, atol=1e-6
    )


def test_tiled_matmul_rejects_mismatched_inner():
    x = jnp.zeros((32, 64), jnp.float32)
    w = jnp.zeros((65, 32), jnp.float32)
    with pytest.raises(ValueError, match="inner dims"):
        tiled_matmul(x, w)


def test_tiled_matmul_rejects_indivisible():
    x = jnp.zeros((48, 64), jnp.float32)
    w = jnp.zeros((64, 64), jnp.float32)
    with pytest.raises(ValueError, match="divisible"):
        tiled_matmul(x, w, m_tile=32, n_tile=32, k_tile=32)


@st.composite
def qmm_shapes(draw):
    t = draw(st.sampled_from([16, 32, 64]))
    m = t * draw(st.integers(1, 2))
    k = t * draw(st.integers(1, 2))
    n = t * draw(st.integers(1, 2))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, k, n, t, seed


@settings(max_examples=15)
@given(qmm_shapes())
def test_quant_matmul_matches_ref_exactly(shape):
    """int8 x int8 with int32-exact f32 carries: bitwise-equal dequant."""
    m, k, n, t, seed = shape
    rng = np.random.default_rng(seed)
    xq = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    wq = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    xs = jnp.asarray([float(rng.random() * 0.1 + 1e-3)], jnp.float32)
    ws = jnp.asarray(rng.random(n) * 0.1 + 1e-3, jnp.float32)
    out = quant_matmul(xq, wq, xs, ws, m_tile=t, n_tile=t, k_tile=t)
    want = ref.quant_matmul_ref(xq, wq, xs[0], ws)
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_quant_matmul_zero_inputs():
    xq = jnp.zeros((32, 32), jnp.int8)
    wq = jnp.zeros((32, 32), jnp.int8)
    out = quant_matmul(
        xq, wq, jnp.asarray([0.5], jnp.float32), jnp.ones(32, jnp.float32)
    )
    np.testing.assert_array_equal(out, jnp.zeros((32, 32), jnp.float32))


def test_quant_matmul_extreme_values():
    """Saturated int8 operands stay exact through the f32 carry."""
    k = 64
    xq = jnp.full((16, k), -128, jnp.int8)
    wq = jnp.full((k, 16), 127, jnp.int8)
    out = quant_matmul(
        xq, wq, jnp.asarray([1.0], jnp.float32), jnp.ones(16, jnp.float32),
        m_tile=16, n_tile=16, k_tile=32,
    )
    np.testing.assert_array_equal(out, jnp.full((16, 16), -128 * 127 * k, jnp.float32))
