"""L1 attention kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps the GQA shape space (H, Hkv grouping, head dim,
sequence length, valid prefix length); fixed tests pin the MHA/MQA
corner cases and numerical-stability behaviors.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    NEG_INF,
    attention_decode,
    attention_prefill_multihead,
)
from compile.kernels import ref

ATOL = 2e-5


def _mk_qkv(rng, H, Hkv, Dh, S):
    q = jnp.asarray(rng.standard_normal((H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((S, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((S, Hkv, Dh)), jnp.float32)
    return q, k, v


def _mask(S, valid):
    return jnp.where(jnp.arange(S) < valid, 0.0, NEG_INF).astype(jnp.float32)


# --- decode ---------------------------------------------------------------


@st.composite
def decode_shapes(draw):
    hkv = draw(st.sampled_from([1, 2, 4]))
    group = draw(st.sampled_from([1, 2, 3, 4]))
    dh = draw(st.sampled_from([8, 16, 32, 64]))
    n_tiles = draw(st.integers(1, 4))
    s_tile = draw(st.sampled_from([32, 64, 128]))
    s = n_tiles * s_tile
    valid = draw(st.integers(1, s))
    seed = draw(st.integers(0, 2**31 - 1))
    return hkv * group, hkv, dh, s, s_tile, valid, seed


@given(decode_shapes())
def test_decode_matches_ref_hypothesis(shape):
    H, Hkv, Dh, S, s_tile, valid, seed = shape
    rng = np.random.default_rng(seed)
    q, k, v = _mk_qkv(rng, H, Hkv, Dh, S)
    mask = _mask(S, valid)
    out = attention_decode(q, k, v, mask, s_tile=s_tile)
    want = ref.attention_decode_ref(q, k, v, mask)
    np.testing.assert_allclose(out, want, atol=ATOL, rtol=1e-5)


@pytest.mark.parametrize(
    "H,Hkv,label",
    [(8, 8, "MHA"), (8, 2, "GQA"), (8, 1, "MQA")],
)
def test_decode_attention_variants(H, Hkv, label):
    """The kernel covers all three of the paper's Fig. 2 variants."""
    rng = np.random.default_rng(42)
    q, k, v = _mk_qkv(rng, H, Hkv, 32, 128)
    mask = _mask(128, 77)
    out = attention_decode(q, k, v, mask)
    want = ref.attention_decode_ref(q, k, v, mask)
    np.testing.assert_allclose(out, want, atol=ATOL, rtol=1e-5, err_msg=label)


def test_decode_single_valid_token():
    """valid=1 -> output is exactly v[0] for each head's group."""
    rng = np.random.default_rng(7)
    H, Hkv, Dh, S = 4, 2, 16, 64
    q, k, v = _mk_qkv(rng, H, Hkv, Dh, S)
    out = attention_decode(q, k, v, _mask(S, 1), s_tile=32)
    group = H // Hkv
    for h in range(H):
        np.testing.assert_allclose(out[h], v[0, h // group], atol=ATOL)


def test_decode_mask_invariance_to_padding_values():
    """Padded cache slots must not influence the result at all."""
    rng = np.random.default_rng(3)
    H, Hkv, Dh, S, valid = 4, 4, 32, 128, 50
    q, k, v = _mk_qkv(rng, H, Hkv, Dh, S)
    mask = _mask(S, valid)
    out1 = attention_decode(q, k, v, mask)
    k2 = k.at[valid:].set(1e6)  # garbage in padded region
    v2 = v.at[valid:].set(-1e6)
    out2 = attention_decode(q, k2, v2, mask)
    np.testing.assert_allclose(out1, out2, atol=ATOL)


def test_decode_large_score_stability():
    """Online softmax must survive large logits (no overflow)."""
    rng = np.random.default_rng(11)
    H, Hkv, Dh, S = 2, 2, 16, 64
    q = jnp.asarray(rng.standard_normal((H, Dh)) * 100, jnp.float32)
    k = jnp.asarray(rng.standard_normal((S, Hkv, Dh)) * 100, jnp.float32)
    v = jnp.asarray(rng.standard_normal((S, Hkv, Dh)), jnp.float32)
    out = attention_decode(q, k, v, _mask(S, S), s_tile=32)
    assert bool(jnp.all(jnp.isfinite(out)))
    want = ref.attention_decode_ref(q, k, v, _mask(S, S))
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)


def test_decode_rejects_bad_grouping():
    rng = np.random.default_rng(0)
    q, k, v = _mk_qkv(rng, 5, 2, 16, 64)
    with pytest.raises(ValueError, match="divisible"):
        attention_decode(q, k, v, _mask(64, 64), s_tile=32)


def test_decode_rejects_bad_tiling():
    rng = np.random.default_rng(0)
    q, k, v = _mk_qkv(rng, 4, 2, 16, 96)
    with pytest.raises(ValueError, match="divisible"):
        attention_decode(q, k, v, _mask(96, 96), s_tile=64)


# --- prefill ---------------------------------------------------------------


@st.composite
def prefill_shapes(draw):
    hkv = draw(st.sampled_from([1, 2]))
    group = draw(st.sampled_from([1, 2, 4]))
    dh = draw(st.sampled_from([8, 16, 32]))
    tile = draw(st.sampled_from([16, 32, 64]))
    n_tiles = draw(st.integers(1, 3))
    seed = draw(st.integers(0, 2**31 - 1))
    return hkv * group, hkv, dh, tile * n_tiles, tile, seed


@settings(max_examples=10)
@given(prefill_shapes())
def test_prefill_matches_ref_hypothesis(shape):
    H, Hkv, Dh, M, tile, seed = shape
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((M, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((M, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((M, Hkv, Dh)), jnp.float32)
    out = attention_prefill_multihead(q, k, v, q_tile=tile, s_tile=tile)
    want = ref.attention_prefill_ref(q, k, v)
    np.testing.assert_allclose(out, want, atol=ATOL, rtol=1e-5)


def test_prefill_causality():
    """Changing future tokens must not change past outputs."""
    rng = np.random.default_rng(5)
    M, H, Hkv, Dh = 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((M, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((M, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((M, Hkv, Dh)), jnp.float32)
    out1 = attention_prefill_multihead(q, k, v, q_tile=32, s_tile=32)
    k2 = k.at[40:].add(5.0)
    v2 = v.at[40:].add(-3.0)
    out2 = attention_prefill_multihead(q, k2, v2, q_tile=32, s_tile=32)
    np.testing.assert_allclose(out1[:40], out2[:40], atol=ATOL)
    assert float(jnp.max(jnp.abs(out1[41:] - out2[41:]))) > 1e-3


def test_prefill_first_token_is_v0():
    rng = np.random.default_rng(9)
    M, H, Hkv, Dh = 32, 2, 1, 16
    q = jnp.asarray(rng.standard_normal((M, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((M, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((M, Hkv, Dh)), jnp.float32)
    out = attention_prefill_multihead(q, k, v, q_tile=16, s_tile=16)
    for h in range(H):
        np.testing.assert_allclose(out[0, h], v[0, 0], atol=ATOL)


def test_prefill_equals_decode_composition():
    """Prefill row t == decode with a cache holding tokens 0..t."""
    rng = np.random.default_rng(13)
    M, H, Hkv, Dh = 32, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((M, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((M, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((M, Hkv, Dh)), jnp.float32)
    pre = attention_prefill_multihead(q, k, v, q_tile=16, s_tile=16)
    for t in (0, 7, 31):
        dec = attention_decode(q[t], k, v, _mask(M, t + 1), s_tile=16)
        np.testing.assert_allclose(pre[t], dec, atol=ATOL, rtol=1e-5)
