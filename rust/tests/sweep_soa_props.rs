//! Property suite for the SoA fused-sweep engine: on randomized traces
//! and randomized grids, the group-deduplicated structure-of-arrays path
//! (`banking::sweep`) must be **bit-identical** to the per-point naive
//! oracle (`banking::sweep_naive`) — every float compared via
//! `to_bits`, not a tolerance. The targeted generators pin the shapes
//! that stress the group layout specifically:
//!
//! - `usable_per_bank == 0` (alpha * C/B < 1): every positive demand
//!   saturates the ladder at B banks;
//! - zero-segment traces (finalized with no records, including end 0);
//! - B = 1-only grids (the reference organization *is* the whole grid);
//! - grids **without** bank 1 and **without** policy `None` — the
//!   engine synthesizes the unbanked/ungated reference out-of-grid, and
//!   that synthetic lane must not perturb the in-grid results;
//! - single- vs multi-policy grids (one vs many decider lanes per
//!   ladder group).
//!
//! Case count honors `PROPTEST_CASES` (CI sets 64).

use trapti::api::ApiContext;
use trapti::banking::{sweep, sweep_naive, GatingPolicy, SweepPoint, SweepSpec};
use trapti::trace::{AccessStats, OccupancyTrace};
use trapti::util::proptest::check;
use trapti::util::rng::Rng;

/// Honors `PROPTEST_CASES` (the CI knob) with a local default.
fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Strict comparator: every field of every point identical, floats by
/// `to_bits`. The SoA engine recomputes nothing per-candidate that the
/// naive oracle derives — it *shares* state — so the outputs are the
/// same float expressions evaluated in the same order, and anything
/// short of bit-identity is a real divergence.
fn assert_bit_identical(fused: &[SweepPoint], naive: &[SweepPoint]) {
    assert_eq!(fused.len(), naive.len(), "point count");
    for (f, n) in fused.iter().zip(naive) {
        let at = format!(
            "C={} B={} alpha={} {:?}",
            n.eval.capacity, n.eval.banks, n.eval.alpha, n.eval.policy
        );
        assert_eq!(f.eval.capacity, n.eval.capacity, "{at}");
        assert_eq!(f.eval.banks, n.eval.banks, "{at}");
        assert_eq!(f.eval.alpha.to_bits(), n.eval.alpha.to_bits(), "{at}");
        assert_eq!(f.eval.policy, n.eval.policy, "{at}");
        assert_eq!(f.eval.n_switch, n.eval.n_switch, "{at}");
        assert_eq!(f.eval.latency_cycles, n.eval.latency_cycles, "{at}");
        for (a, b, what) in [
            (f.eval.e_dyn_j, n.eval.e_dyn_j, "e_dyn_j"),
            (f.eval.e_leak_j, n.eval.e_leak_j, "e_leak_j"),
            (f.eval.e_sw_j, n.eval.e_sw_j, "e_sw_j"),
            (f.eval.avg_active_banks, n.eval.avg_active_banks, "avg_active"),
            (f.eval.gated_fraction, n.eval.gated_fraction, "gated_fraction"),
            (f.eval.area_mm2, n.eval.area_mm2, "area_mm2"),
            (f.base_e_j, n.base_e_j, "base_e_j"),
            (f.base_area_mm2, n.base_area_mm2, "base_area_mm2"),
        ] {
            assert_eq!(a.to_bits(), b.to_bits(), "{what}: {a} vs {b} at {at}");
        }
        assert_eq!(f.eval.characterization, n.eval.characterization, "{at}");
    }
}

/// Random monotone occupancy trace on one memory. `max_needed == 0`
/// produces a trace whose every sample needs zero bytes (peak 0).
fn random_trace(
    rng: &mut Rng,
    capacity: u64,
    max_needed: u64,
    max_segments: u64,
) -> OccupancyTrace {
    let mut tr = OccupancyTrace::new("mem", capacity);
    let mut t = 0u64;
    for _ in 0..rng.below(max_segments + 1) {
        t += rng.range(1, 10_000);
        let needed = if max_needed == 0 || rng.below(6) == 0 {
            0
        } else {
            rng.below(max_needed + 1)
        };
        tr.record(t, needed, 0);
    }
    tr.finalize(t + rng.range(1, 2_000));
    tr
}

fn random_stats(rng: &mut Rng) -> AccessStats {
    AccessStats {
        reads: rng.below(20_000_000),
        writes: rng.below(5_000_000),
        ..Default::default()
    }
}

const POLICY_POOL: [GatingPolicy; 4] = [
    GatingPolicy::None,
    GatingPolicy::Aggressive,
    GatingPolicy::Conservative { min_idle_factor: 4.0 },
    GatingPolicy::Drowsy { retention_factor: 0.25 },
];

/// Random subset (in pool order, possibly with None absent / present)
/// of the policy pool; never empty.
fn random_policies(rng: &mut Rng) -> Vec<GatingPolicy> {
    let mask = rng.range(1, 15); // inclusive: at least one of the four
    POLICY_POOL
        .iter()
        .enumerate()
        .filter(|(i, _)| mask & (1 << i) != 0)
        .map(|(_, p)| *p)
        .collect()
}

/// Random subset of the power-of-two bank pool; never empty.
fn random_banks(rng: &mut Rng, pool: &[u32]) -> Vec<u32> {
    let mask = rng.range(1, (1u64 << pool.len()) - 1);
    pool.iter()
        .enumerate()
        .filter(|(i, _)| mask & (1u64 << i) != 0)
        .map(|(_, b)| *b)
        .collect()
}

fn diff(ctx: &ApiContext, tr: &OccupancyTrace, stats: &AccessStats, grid: &SweepSpec, freq: f64) {
    let fused = sweep(&ctx.cacti, tr, stats, grid, freq).unwrap();
    let naive = sweep_naive(&ctx.cacti, tr, stats, grid, freq).unwrap();
    assert_bit_identical(&fused, &naive);
}

#[test]
fn prop_soa_matches_naive_on_random_grids_and_traces() {
    let ctx = ApiContext::new();
    check("soa-random-grid", cases(48), |rng: &mut Rng| {
        let cap = rng.range(1, 1 << 26);
        let tr = random_trace(rng, cap, cap, 60);
        let peak = tr.peak_needed();
        // Capacity axis straddles the peak so the infeasibility filter
        // drops some capacities on the fused side too.
        let grid = SweepSpec {
            capacities: vec![(peak / 2).max(1), peak.max(1), peak.max(1) * 2, cap.max(1) * 2],
            banks: random_banks(rng, &[1, 2, 4, 8, 16, 32, 64]),
            alphas: vec![0.05 + rng.f64() * 0.95, 1.0],
            policies: random_policies(rng),
        };
        diff(&ctx, &tr, &random_stats(rng), &grid, 0.5 + rng.f64() * 1.5);
    });
}

#[test]
fn prop_soa_handles_usable_per_bank_zero() {
    let ctx = ApiContext::new();
    check("soa-usable-zero", cases(32), |rng: &mut Rng| {
        // alpha * C / B < 1: floor() yields usable_per_bank == 0, so any
        // positive demand pins the ladder at B banks. Capacity stays at
        // or above the peak so the grid point is feasible.
        let banks = 32u32;
        let cap = rng.range(1, banks as u64); // alpha < 1 and C <= B => alpha*C/B < 1
        let tr = random_trace(rng, cap, cap, 40);
        let grid = SweepSpec {
            capacities: vec![cap.max(tr.peak_needed())],
            banks: vec![1, banks],
            alphas: vec![0.05 + rng.f64() * 0.9],
            policies: random_policies(rng),
        };
        diff(&ctx, &tr, &random_stats(rng), &grid, 1.0);
    });
}

#[test]
fn prop_soa_handles_zero_segment_traces() {
    let ctx = ApiContext::new();
    check("soa-zero-segments", cases(24), |rng: &mut Rng| {
        // A trace finalized with no recorded samples — including the
        // fully degenerate end == 0 case every other round.
        let mut tr = OccupancyTrace::new("mem", 1 << 20);
        let end = if rng.below(2) == 0 { 0 } else { rng.range(1, 50_000) };
        tr.finalize(end);
        let grid = SweepSpec {
            capacities: vec![1, 1 << 20],
            banks: random_banks(rng, &[1, 2, 8, 32]),
            alphas: vec![0.9],
            policies: random_policies(rng),
        };
        diff(&ctx, &tr, &random_stats(rng), &grid, 1.0);
    });
}

#[test]
fn prop_soa_handles_bank_one_only_grids() {
    let ctx = ApiContext::new();
    check("soa-b1-only", cases(24), |rng: &mut Rng| {
        let cap = rng.range(1, 1 << 24);
        let tr = random_trace(rng, cap, cap, 50);
        let grid = SweepSpec {
            capacities: vec![tr.peak_needed().max(1), cap.max(1) * 2],
            banks: vec![1],
            alphas: vec![0.5 + rng.f64() * 0.5],
            policies: random_policies(rng),
        };
        diff(&ctx, &tr, &random_stats(rng), &grid, 1.0);
    });
}

#[test]
fn prop_soa_synthesizes_reference_outside_grid() {
    let ctx = ApiContext::new();
    check("soa-synthetic-reference", cases(32), |rng: &mut Rng| {
        let cap = rng.range(1, 1 << 24);
        let tr = random_trace(rng, cap, cap, 50);
        // Neither bank 1 nor policy None appears in the grid: the B=1
        // ungated reference behind base_e_j/base_area_mm2 is synthetic.
        let grid = SweepSpec {
            capacities: vec![tr.peak_needed().max(1) * 2],
            banks: random_banks(rng, &[2, 4, 8, 16, 32]),
            alphas: vec![0.9, 1.0],
            policies: vec![
                GatingPolicy::Aggressive,
                GatingPolicy::Conservative { min_idle_factor: 2.0 + rng.f64() * 6.0 },
            ],
        };
        diff(&ctx, &tr, &random_stats(rng), &grid, 1.0);
    });
}

#[test]
fn prop_soa_single_policy_lane_matches_multi() {
    let ctx = ApiContext::new();
    check("soa-lane-count", cases(24), |rng: &mut Rng| {
        let cap = rng.range(1, 1 << 24);
        let tr = random_trace(rng, cap, cap, 50);
        let stats = random_stats(rng);
        let banks = random_banks(rng, &[1, 4, 16]);
        let caps = vec![tr.peak_needed().max(1), cap.max(1) * 2];
        // Multi-policy grid once...
        let multi = SweepSpec {
            capacities: caps.clone(),
            banks: banks.clone(),
            alphas: vec![0.9],
            policies: POLICY_POOL.to_vec(),
        };
        diff(&ctx, &tr, &stats, &multi, 1.0);
        let all = sweep(&ctx.cacti, &tr, &stats, &multi, 1.0).unwrap();
        // ...then each policy alone: a single-lane group must reproduce
        // the matching slice of the multi-lane run bit-for-bit (lane
        // fan-out is pure bookkeeping, not arithmetic).
        for policy in POLICY_POOL {
            let single = SweepSpec {
                capacities: caps.clone(),
                banks: banks.clone(),
                alphas: vec![0.9],
                policies: vec![policy],
            };
            diff(&ctx, &tr, &stats, &single, 1.0);
            let solo = sweep(&ctx.cacti, &tr, &stats, &single, 1.0).unwrap();
            let slice: Vec<&SweepPoint> =
                all.iter().filter(|p| p.eval.policy == policy).collect();
            assert_eq!(solo.len(), slice.len(), "{policy:?}");
            for (s, m) in solo.iter().zip(slice) {
                assert_eq!(
                    s.eval.e_total_j().to_bits(),
                    m.eval.e_total_j().to_bits(),
                    "{policy:?}: single-lane vs multi-lane"
                );
                assert_eq!(s.eval.n_switch, m.eval.n_switch, "{policy:?}");
            }
        }
    });
}
