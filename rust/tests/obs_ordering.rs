//! Property tests for the WAL's ordering guarantees — the executable
//! port of the event-log spec's invariants (docs/ARCHITECTURE.md
//! §Observability):
//!
//! 1. `RunStartFirst`  — the first record of every log is `RunStart`.
//! 2. `RunEndLast`     — `RunEnd` appears only as the final record.
//! 3. `StageBracketed` — every `StageEnd` is preceded by its stage's
//!    `StageStart`, each stage starts and ends at most once.
//! 4. `MonotoneStamps` — `seq` is dense from 0 (strictly monotone), the
//!    envelope `t` is non-decreasing.
//! 5. `PrefixStable`   — the log is append-only: after every write, the
//!    readable records extend (never rewrite) the previous read, across
//!    segment rotation.
//!
//! Checked two ways: over arbitrary synthetic schedules driven through
//! [`trapti::obs::WalSink`] with tiny rotation thresholds, and over the
//! real Stage-I engines (prefill, decode, multi-memory, serving) via
//! `materialize_logged` — whose WAL must additionally replay into
//! bit-identical occupancy traces (the replay/materialize equivalence
//! this whole subsystem rests on).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use trapti::api::{ApiContext, ExperimentSpec, MaterializedRun};
use trapti::config::{multilevel, tiny};
use trapti::obs::{replay_wal, EventLog, ObsEvent, WalSink};
use trapti::serving::ServingParams;
use trapti::trace::sink::{MemoryDesc, RunEvent, TraceSink};
use trapti::trace::{AccessStats, OccupancyTrace};
use trapti::util::proptest::check;
use trapti::util::rng::Rng;
use trapti::workload::TINY_GQA;

/// Honors `PROPTEST_CASES` (the CI knob) with a local default.
fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn tmp_dir(tag: &str) -> PathBuf {
    let n = DIR_SEQ.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!(
        "trapti-obs-ordering-{tag}-{}-{n}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Assert invariants 1–4 on a decoded log.
fn assert_ordering_invariants(log: &EventLog) {
    assert!(!log.records.is_empty(), "a written log is never empty");
    assert!(
        matches!(log.records[0].event, ObsEvent::RunStart { .. }),
        "RunStartFirst: first record is {:?}",
        log.records[0].event
    );
    let mut started: BTreeMap<u32, usize> = BTreeMap::new();
    let mut ended: BTreeMap<u32, usize> = BTreeMap::new();
    for (i, r) in log.records.iter().enumerate() {
        assert_eq!(r.seq, i as u64, "MonotoneStamps: seq dense from 0");
        if i > 0 {
            assert!(
                log.records[i - 1].t <= r.t,
                "MonotoneStamps: t regressed at seq {i}: {} -> {}",
                log.records[i - 1].t,
                r.t
            );
            assert!(
                !matches!(r.event, ObsEvent::RunStart { .. }),
                "RunStartFirst: duplicate RunStart at seq {i}"
            );
        }
        match r.event {
            ObsEvent::RunEnd { .. } => assert_eq!(
                i,
                log.records.len() - 1,
                "RunEndLast: RunEnd at seq {i} is not final"
            ),
            ObsEvent::StageStart { stage } => {
                assert!(
                    started.insert(stage, i).is_none(),
                    "StageBracketed: stage {stage} started twice"
                );
            }
            ObsEvent::StageEnd { stage } => {
                assert!(
                    started.contains_key(&stage),
                    "StageBracketed: stage {stage} ended before starting"
                );
                assert!(
                    ended.insert(stage, i).is_none(),
                    "StageBracketed: stage {stage} ended twice"
                );
            }
            _ => {}
        }
    }
    for (stage, end_ix) in &ended {
        assert!(
            started[stage] < *end_ix,
            "StageBracketed: stage {stage} end precedes start"
        );
    }
}

/// Drive one random-but-valid schedule through a `WalSink` (tiny
/// rotation threshold so multi-segment logs are the common case) and
/// return the directory for inspection.
fn random_schedule(rng: &mut Rng, dir: &PathBuf) -> usize {
    let run_id = rng.next_u64();
    let mut sink = WalSink::create(dir, run_id, 0)
        .unwrap()
        .with_rotate_bytes(32 + rng.below(256));
    let n_mems = 1 + rng.below(3) as usize;
    let mems: Vec<MemoryDesc> = (0..n_mems)
        .map(|i| MemoryDesc {
            name: format!("mem{i}"),
            capacity: 1 << 20,
        })
        .collect();
    sink.begin(&mems);

    let mut t = 0u64;
    let mut written = 1usize;
    let mut next_stage = 0u32;
    let mut open_stages: Vec<u32> = Vec::new();
    let mut next_req = 0u32;
    let mut in_flight: Vec<u32> = Vec::new();
    for _ in 0..rng.below(60) {
        t += rng.below(50); // sometimes zero: same-instant records
        match rng.below(6) {
            0 | 1 => {
                let mem = rng.below(n_mems as u64) as usize;
                sink.on_sample(mem, t, rng.below(1 << 20), rng.below(1 << 10));
            }
            2 => {
                sink.on_event(t, &RunEvent::StageStart { stage: next_stage });
                open_stages.push(next_stage);
                next_stage += 1;
            }
            3 if !open_stages.is_empty() => {
                let ix = rng.below(open_stages.len() as u64) as usize;
                let stage = open_stages.swap_remove(ix);
                sink.on_event(t, &RunEvent::StageEnd { stage });
            }
            4 => {
                sink.on_event(t, &RunEvent::Admit { request: next_req });
                in_flight.push(next_req);
                next_req += 1;
            }
            5 if !in_flight.is_empty() => {
                let ix = rng.below(in_flight.len() as u64) as usize;
                let request = in_flight.swap_remove(ix);
                sink.on_event(t, &RunEvent::Complete { request });
            }
            _ => continue, // guard not met: skip the slot
        }
        written += 1;
    }
    for stage in std::mem::take(&mut open_stages) {
        sink.on_event(t, &RunEvent::StageEnd { stage });
        written += 1;
    }
    let end = t + rng.below(100);
    sink.finish(end);
    // Retrospective Stage-III tail (events stamped at the end envelope).
    for bank in 0..rng.below(4) as u32 {
        sink.append_event(
            end,
            &RunEvent::BankSpan { bank, state: "gated", t0: 0, t1: end },
        );
        written += 1;
    }
    sink.close(None).unwrap();
    written + 1 // + RunEnd
}

#[test]
fn arbitrary_schedules_satisfy_the_ordering_invariants() {
    check("obs-ordering", cases(32), |rng| {
        let dir = tmp_dir("arb");
        let expected = random_schedule(rng, &dir);
        let log = EventLog::open(&dir).unwrap();
        assert!(!log.truncated);
        assert!(log.complete());
        assert_eq!(log.records.len(), expected);
        assert_ordering_invariants(&log);
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn log_reads_are_prefix_stable_across_rotation() {
    check("obs-prefix-stable", cases(16), |rng| {
        let dir = tmp_dir("prefix");
        let mut sink = WalSink::create(&dir, 9, 0)
            .unwrap()
            .with_rotate_bytes(48 + rng.below(64)); // rotate every 1-2 records
        sink.begin(&[MemoryDesc { name: "sram".into(), capacity: 1 << 20 }]);
        let mut prev = EventLog::open(&dir).unwrap().records;
        let mut t = 0;
        for _ in 0..12 {
            t += rng.below(20);
            sink.on_sample(0, t, rng.below(1 << 16), 0);
            let now = EventLog::open(&dir).unwrap();
            assert!(!now.truncated, "live log must read clean");
            assert!(
                now.records.starts_with(&prev),
                "PrefixStable: a later read rewrote earlier records"
            );
            assert_eq!(now.records.len(), prev.len() + 1);
            prev = now.records;
        }
        sink.finish(t);
        sink.close(None).unwrap();
        let closed = EventLog::open(&dir).unwrap();
        assert!(closed.records.starts_with(&prev), "close preserves the prefix");
        assert!(closed.complete());
        assert!(closed.segments > 1, "rotation must have happened");
        let _ = std::fs::remove_dir_all(&dir);
    });
}

// --- Real engines: invariants + replay/materialize bit-identity -------

/// The acceptance criterion: the WAL alone reconstructs the
/// materialized traces bit-identically (same samples, same `to_bits`
/// floats) and carries the exact run statistics.
fn assert_wal_mirrors_run(dir: &PathBuf, spec: &ExperimentSpec, run: &MaterializedRun) {
    let log = EventLog::open(dir).unwrap();
    assert!(log.complete() && !log.truncated);
    assert_eq!(log.run_id(), Some(spec.content_hash()));
    assert_ordering_invariants(&log);

    let replay = replay_wal(dir).unwrap();
    assert!(replay.complete);
    assert_eq!(replay.run_id, spec.content_hash());
    let materialized: Vec<&OccupancyTrace> = match run {
        MaterializedRun::Single(s) => s.result.traces.iter().collect(),
        MaterializedRun::Serving(r) => vec![r.trace()],
    };
    assert_eq!(replay.traces.len(), materialized.len());
    for (got, want) in replay.traces.iter().zip(&materialized) {
        assert_eq!(got.memory, want.memory);
        assert_eq!(got.capacity, want.capacity);
        assert_eq!(got.samples(), want.samples(), "bit-identical sample lists");
        assert_eq!(got.end_time(), want.end_time());
        assert_eq!(got.peak_needed(), want.peak_needed());
        assert_eq!(
            got.avg_needed().to_bits(),
            want.avg_needed().to_bits(),
            "bit-identical derived floats"
        );
    }
    let stats: &AccessStats = run.stats();
    assert_eq!(replay.stats.as_ref(), Some(stats));
}

fn logged_roundtrip(tag: &str, spec: ExperimentSpec) {
    let ctx = ApiContext::new();
    let dir = tmp_dir(tag);
    let run = spec.materialize_logged(&ctx, &dir, 0).unwrap();
    assert_wal_mirrors_run(&dir, &spec, &run);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prefill_run_log_is_ordered_and_replays_bit_identical() {
    logged_roundtrip(
        "prefill",
        ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .accel(tiny())
            .build()
            .unwrap(),
    );
}

#[test]
fn decode_run_log_is_ordered_and_replays_bit_identical() {
    logged_roundtrip(
        "decode",
        ExperimentSpec::builder()
            .model(TINY_GQA)
            .decode(32, 16)
            .accel(tiny())
            .build()
            .unwrap(),
    );
}

#[test]
fn multi_memory_run_logs_every_trace() {
    logged_roundtrip(
        "multilevel",
        ExperimentSpec::builder()
            .model(TINY_GQA)
            .prefill(64)
            .accel(multilevel())
            .build()
            .unwrap(),
    );
}

#[test]
fn serving_run_log_brackets_every_request() {
    let mut p = ServingParams::new(16, 4, 7);
    p.prompt_min = 4;
    p.prompt_max = 24;
    p.gen_min = 2;
    p.gen_max = 12;
    p.page_tokens = 8;
    p.mean_arrival_gap = 40_000;
    let spec = ExperimentSpec::builder()
        .model(TINY_GQA)
        .serving(p)
        .accel(tiny())
        .build()
        .unwrap();
    let ctx = ApiContext::new();
    let dir = tmp_dir("serving");
    let run = spec.materialize_logged(&ctx, &dir, 0).unwrap();
    assert_wal_mirrors_run(&dir, &spec, &run);

    // Serving-specific ordering: every request admits before it
    // completes, and all 16 requests appear in both roles.
    let log = EventLog::open(&dir).unwrap();
    let mut admitted: BTreeMap<u32, usize> = BTreeMap::new();
    let mut completed: BTreeMap<u32, usize> = BTreeMap::new();
    for (i, r) in log.records.iter().enumerate() {
        match r.event {
            ObsEvent::Admit { request } => {
                assert!(admitted.insert(request, i).is_none());
            }
            ObsEvent::Complete { request } => {
                assert!(completed.insert(request, i).is_none());
            }
            _ => {}
        }
    }
    assert_eq!(admitted.len(), 16);
    assert_eq!(completed.len(), 16);
    for (req, done_ix) in &completed {
        assert!(admitted[req] < *done_ix, "request {req} completed before admit");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
