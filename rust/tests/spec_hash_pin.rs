//! Golden pins for [`ExperimentSpec::content_hash`].
//!
//! The hash is the identity of every content-addressed lab artifact
//! (`result/<job-id>/`, job ids are FNV chains seeded by it) and the
//! `BatchRunner` memoization key. A silent change — reordered fields, a
//! different policy encoding, a new hashed field without a version
//! bump — would invalidate every stored artifact while looking like a
//! refactor. These exact values (independently recomputed from the
//! documented serialization, not captured from the code under test)
//! make that loud: if a pin moves, bump `trapti-spec-v1` /
//! `LAB_SCHEMA_VERSION` deliberately and regenerate stores.

use trapti::api::ExperimentSpec;
use trapti::banking::{GatingPolicy, SweepSpec};
use trapti::config::{baseline, tiny};
use trapti::serving::ServingParams;
use trapti::util::MIB;
use trapti::workload::{GPT2_XL, TINY_GQA, TINY_MHA};

#[test]
fn tiny_mha_prefill_pin() {
    let spec = ExperimentSpec::builder()
        .model(TINY_MHA)
        .prefill(64)
        .accel(tiny())
        .build()
        .unwrap();
    assert_eq!(spec.content_hash(), 0xf0956a9f84583979);
}

#[test]
fn tiny_gqa_decode_pin() {
    let spec = ExperimentSpec::builder()
        .model(TINY_GQA)
        .decode(16, 8)
        .accel(tiny())
        .build()
        .unwrap();
    assert_eq!(spec.content_hash(), 0xaf795202420f86a1);
}

#[test]
fn tiny_gqa_serving_pin() {
    let spec = ExperimentSpec::builder()
        .model(TINY_GQA)
        .serving(ServingParams::new(8, 2, 7))
        .accel(tiny())
        .build()
        .unwrap();
    assert_eq!(spec.content_hash(), 0x3c73ee6add37678a);
}

/// The scheduling-extension fields (bursty arrivals, heavy tails,
/// tiers, shared prefix, tenancy) hash under a version marker that is
/// only mixed in when at least one extension is enabled — so every
/// pre-extension serving spec (all defaults) keeps its exact pin above,
/// and no stored lab artifact is invalidated. Enabling any extension
/// must move the hash. This is the documented extension rule
/// (docs/ARCHITECTURE.md, "Spec identity"): new `ServingParams` fields
/// may only be hashed behind a default-off gate.
#[test]
fn serving_extensions_preserve_legacy_pin_and_are_semantic() {
    let legacy = ExperimentSpec::builder()
        .model(TINY_GQA)
        .serving(ServingParams::new(8, 2, 7))
        .accel(tiny())
        .build()
        .unwrap();
    assert_eq!(legacy.content_hash(), 0x3c73ee6add37678a);

    let bursty = ExperimentSpec::builder()
        .model(TINY_GQA)
        .serving(ServingParams::new(8, 2, 7).with_bursty_traffic())
        .accel(tiny())
        .build()
        .unwrap();
    assert_ne!(bursty.content_hash(), legacy.content_hash());

    let mut tiered_params = ServingParams::new(8, 2, 7);
    tiered_params.tiers = 2;
    let tiered = ExperimentSpec::builder()
        .model(TINY_GQA)
        .serving(tiered_params)
        .accel(tiny())
        .build()
        .unwrap();
    assert_ne!(tiered.content_hash(), legacy.content_hash());
    assert_ne!(tiered.content_hash(), bursty.content_hash());
}

#[test]
fn sweep_grid_is_part_of_the_identity() {
    let spec = ExperimentSpec::builder()
        .model(TINY_MHA)
        .prefill(64)
        .accel(tiny())
        .sweep(SweepSpec {
            capacities: vec![2 * MIB, 4 * MIB],
            banks: vec![1, 2, 4, 8],
            alphas: vec![0.9],
            policies: vec![
                GatingPolicy::None,
                GatingPolicy::Aggressive,
                GatingPolicy::conservative(),
                GatingPolicy::drowsy(),
            ],
        })
        .build()
        .unwrap();
    assert_eq!(spec.content_hash(), 0x2b9486fa16abff01);
}

#[test]
fn paper_scale_decode_pin() {
    let spec = ExperimentSpec::builder()
        .model(GPT2_XL)
        .decode(512, 128)
        .accel(baseline())
        .build()
        .unwrap();
    assert_eq!(spec.content_hash(), 0x028d7062579eccb1);
}
