//! Golden pins for [`ExperimentSpec::content_hash`].
//!
//! The hash is the identity of every content-addressed lab artifact
//! (`result/<job-id>/`, job ids are FNV chains seeded by it) and the
//! `BatchRunner` memoization key. A silent change — reordered fields, a
//! different policy encoding, a new hashed field without a version
//! bump — would invalidate every stored artifact while looking like a
//! refactor. These exact values (independently recomputed from the
//! documented serialization, not captured from the code under test)
//! make that loud: if a pin moves, bump `trapti-spec-v1` /
//! `LAB_SCHEMA_VERSION` deliberately and regenerate stores.

use trapti::api::ExperimentSpec;
use trapti::banking::{GatingPolicy, HierarchyConfig, SweepSpec};
use trapti::config::{baseline, tiny};
use trapti::serving::ServingParams;
use trapti::util::MIB;
use trapti::workload::{FIG1_MLA, FIG1_MQA, FIG1_SWA, GPT2_XL, TINY_GQA, TINY_MHA};

#[test]
fn tiny_mha_prefill_pin() {
    let spec = ExperimentSpec::builder()
        .model(TINY_MHA)
        .prefill(64)
        .accel(tiny())
        .build()
        .unwrap();
    assert_eq!(spec.content_hash(), 0xf0956a9f84583979);
}

#[test]
fn tiny_gqa_decode_pin() {
    let spec = ExperimentSpec::builder()
        .model(TINY_GQA)
        .decode(16, 8)
        .accel(tiny())
        .build()
        .unwrap();
    assert_eq!(spec.content_hash(), 0xaf795202420f86a1);
}

#[test]
fn tiny_gqa_serving_pin() {
    let spec = ExperimentSpec::builder()
        .model(TINY_GQA)
        .serving(ServingParams::new(8, 2, 7))
        .accel(tiny())
        .build()
        .unwrap();
    assert_eq!(spec.content_hash(), 0x3c73ee6add37678a);
}

/// The scheduling-extension fields (bursty arrivals, heavy tails,
/// tiers, shared prefix, tenancy) hash under a version marker that is
/// only mixed in when at least one extension is enabled — so every
/// pre-extension serving spec (all defaults) keeps its exact pin above,
/// and no stored lab artifact is invalidated. Enabling any extension
/// must move the hash. This is the documented extension rule
/// (docs/ARCHITECTURE.md, "Spec identity"): new `ServingParams` fields
/// may only be hashed behind a default-off gate.
#[test]
fn serving_extensions_preserve_legacy_pin_and_are_semantic() {
    let legacy = ExperimentSpec::builder()
        .model(TINY_GQA)
        .serving(ServingParams::new(8, 2, 7))
        .accel(tiny())
        .build()
        .unwrap();
    assert_eq!(legacy.content_hash(), 0x3c73ee6add37678a);

    let bursty = ExperimentSpec::builder()
        .model(TINY_GQA)
        .serving(ServingParams::new(8, 2, 7).with_bursty_traffic())
        .accel(tiny())
        .build()
        .unwrap();
    assert_ne!(bursty.content_hash(), legacy.content_hash());

    let mut tiered_params = ServingParams::new(8, 2, 7);
    tiered_params.tiers = 2;
    let tiered = ExperimentSpec::builder()
        .model(TINY_GQA)
        .serving(tiered_params)
        .accel(tiny())
        .build()
        .unwrap();
    assert_ne!(tiered.content_hash(), legacy.content_hash());
    assert_ne!(tiered.content_hash(), bursty.content_hash());
}

#[test]
fn sweep_grid_is_part_of_the_identity() {
    let spec = ExperimentSpec::builder()
        .model(TINY_MHA)
        .prefill(64)
        .accel(tiny())
        .sweep(SweepSpec {
            capacities: vec![2 * MIB, 4 * MIB],
            banks: vec![1, 2, 4, 8],
            alphas: vec![0.9],
            policies: vec![
                GatingPolicy::None,
                GatingPolicy::Aggressive,
                GatingPolicy::conservative(),
                GatingPolicy::drowsy(),
            ],
        })
        .build()
        .unwrap();
    assert_eq!(spec.content_hash(), 0x2b9486fa16abff01);
}

#[test]
fn paper_scale_decode_pin() {
    let spec = ExperimentSpec::builder()
        .model(GPT2_XL)
        .decode(512, 128)
        .accel(baseline())
        .build()
        .unwrap();
    assert_eq!(spec.content_hash(), 0x028d7062579eccb1);
}

/// New spectrum presets: MQA carries no attention extension, so it
/// hashes through the legacy serialization; MLA and SWA each trip the
/// attention gate (marker word + latent/window fields). All three
/// values are recomputed independently from the documented
/// serialization, like every pin in this file.
#[test]
fn spectrum_preset_pins() {
    let mqa = ExperimentSpec::builder()
        .model(FIG1_MQA)
        .decode(16, 8)
        .accel(tiny())
        .build()
        .unwrap();
    assert_eq!(mqa.content_hash(), 0x537965368b9f02f9);

    let mla = ExperimentSpec::builder()
        .model(FIG1_MLA)
        .decode(16, 8)
        .accel(tiny())
        .build()
        .unwrap();
    assert_eq!(mla.content_hash(), 0x6349fa8b559c981a);

    let swa = ExperimentSpec::builder()
        .model(FIG1_SWA)
        .prefill(64)
        .accel(tiny())
        .build()
        .unwrap();
    assert_eq!(swa.content_hash(), 0x457871cb024342c9);
}

/// The attention-extension gate mirrors the serving rule: fields only
/// hash when enabled. A preset with both knobs zeroed is
/// indistinguishable from one that predates the fields — the tiny-MHA
/// pin above proves that for the stock presets; here the same model
/// with a latent or a window must move away from its own pin, and the
/// two knobs must not collide with each other.
#[test]
fn attn_extensions_preserve_legacy_pin_and_are_semantic() {
    let build = |latent_dim: u32, window: u32| {
        let mut m = TINY_MHA.clone();
        m.latent_dim = latent_dim;
        m.window = window;
        ExperimentSpec::builder()
            .model(m)
            .prefill(64)
            .accel(tiny())
            .build()
            .unwrap()
            .content_hash()
    };
    let legacy = build(0, 0);
    assert_eq!(legacy, 0xf0956a9f84583979, "all-off must keep the pin");
    let latent = build(64, 0);
    let window = build(0, 64);
    assert_ne!(latent, legacy);
    assert_ne!(window, legacy);
    assert_ne!(latent, window);
}

/// The hierarchy gate follows the same extension rule: a flat spec
/// (`hierarchy` unset) keeps its pre-hierarchy pin bit-for-bit, and
/// enabling the L2 pool moves the hash to a pinned value of its own.
/// Both the capacity and the migration energy are part of the identity.
#[test]
fn hierarchy_preserves_legacy_pin_and_pins_its_own() {
    let flat = ExperimentSpec::builder()
        .model(TINY_MHA)
        .prefill(64)
        .accel(tiny())
        .build()
        .unwrap();
    assert_eq!(flat.content_hash(), 0xf0956a9f84583979);

    let hier = ExperimentSpec::builder()
        .model(TINY_MHA)
        .prefill(64)
        .accel(tiny())
        .hierarchy(HierarchyConfig::new(8 * MIB))
        .build()
        .unwrap();
    assert_eq!(hier.content_hash(), 0xfd70ecf44bad3719);

    let mut pricier = HierarchyConfig::new(8 * MIB);
    pricier.migrate_energy_per_byte_j = 4e-12;
    let repriced = ExperimentSpec::builder()
        .model(TINY_MHA)
        .prefill(64)
        .accel(tiny())
        .hierarchy(pricier)
        .build()
        .unwrap();
    assert_ne!(repriced.content_hash(), hier.content_hash());
    assert_ne!(repriced.content_hash(), flat.content_hash());
}
