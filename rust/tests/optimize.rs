//! Integration suite for the Stage-II Pareto/portfolio optimizer.
//!
//! The acceptance property: frontier points must be dominated-free
//! against the *naive oracle's* sweep output (`sweep_naive`) on
//! randomized traces — the optimizer can never emit a configuration that
//! some evaluated candidate beats on energy, activity, and area at once.
//! Plus: cross-workload portfolio consistency via brute force, and
//! byte-determinism of the `pareto_csv` artifact over the fused
//! serving/decode pipeline (what the CI `repro optimize` gate compares).

use trapti::api::{ApiContext, ExperimentSpec, PortfolioOptions};
use trapti::banking::{
    optimize, pareto_frontier, sweep_naive, Constraints, GatingPolicy, SweepPoint,
    SweepSpec, WorkloadSweep,
};
use trapti::cacti::CactiModel;
use trapti::report::tables::pareto_csv;
use trapti::serving::ServingParams;
use trapti::trace::{AccessStats, OccupancyTrace};
use trapti::util::proptest::check;
use trapti::util::rng::Rng;
use trapti::util::MIB;
use trapti::workload::{TINY_GQA, TINY_MHA};

fn objectives(p: &SweepPoint) -> [f64; 3] {
    [p.eval.e_total_j(), p.eval.avg_active_banks, p.eval.area_mm2]
}

fn dominates(a: &[f64; 3], b: &[f64; 3]) -> bool {
    a.iter().zip(b).all(|(x, y)| x <= y) && a.iter().zip(b).any(|(x, y)| x < y)
}

fn random_trace(rng: &mut Rng, cap: u64) -> OccupancyTrace {
    let mut tr = OccupancyTrace::new("m", cap);
    let mut t = 0u64;
    for _ in 0..rng.range(1, 100) {
        t += rng.range(1, 20_000);
        let needed = if rng.below(5) == 0 { 0 } else { rng.below(cap + 1) };
        tr.record(t, needed, 0);
    }
    tr.finalize(t + rng.range(1, 5_000));
    tr
}

fn rich_grid(peak: u64) -> SweepSpec {
    SweepSpec {
        capacities: vec![peak.max(1), peak.max(1) * 2, peak.max(1) * 4],
        banks: vec![1, 2, 4, 8, 16, 32],
        alphas: vec![0.9],
        policies: vec![
            GatingPolicy::None,
            GatingPolicy::Aggressive,
            GatingPolicy::conservative(),
            GatingPolicy::drowsy(),
        ],
    }
}

/// The ISSUE acceptance property: on randomized traces, every frontier
/// point of the optimizer is dominated-free against the *naive oracle's*
/// full sweep, and every non-frontier candidate is weakly dominated by
/// some frontier member (nothing good was dropped).
#[test]
fn prop_frontier_dominated_free_against_sweep_naive() {
    let cacti = CactiModel::default();
    check("optimize-frontier-vs-naive", 30, |rng: &mut Rng| {
        let tr = random_trace(rng, 48 * MIB);
        let stats = AccessStats {
            reads: rng.below(1 << 28),
            writes: rng.below(1 << 28),
            ..Default::default()
        };
        let points =
            sweep_naive(&cacti, &tr, &stats, &rich_grid(tr.peak_needed()), 1.0)
                .unwrap();
        assert!(!points.is_empty());
        let frontier = pareto_frontier(&points, 0.0);
        assert!(!frontier.is_empty());
        let obj: Vec<[f64; 3]> = points.iter().map(objectives).collect();
        for &i in &frontier {
            for (j, o) in obj.iter().enumerate() {
                assert!(
                    j == i || !dominates(o, &obj[i]),
                    "frontier point {i} is dominated by sweep point {j}"
                );
            }
        }
        for (j, o) in obj.iter().enumerate() {
            if frontier.contains(&j) {
                continue;
            }
            assert!(
                frontier
                    .iter()
                    .any(|&i| obj[i].iter().zip(o).all(|(x, y)| x <= y)),
                "candidate {j} neither on frontier nor covered by it"
            );
        }
    });
}

/// The full optimize() pass over the oracle output: the robust-best
/// portfolio pick must brute-force-minimize worst-case regret across
/// workloads, and regrets must be exact energy ratios.
#[test]
fn prop_portfolio_regret_matches_brute_force() {
    let cacti = CactiModel::default();
    check("optimize-portfolio-brute-force", 10, |rng: &mut Rng| {
        // Shared grid across two random workloads, anchored above both
        // peaks so the portfolio intersection is non-empty.
        let ta = random_trace(rng, 32 * MIB);
        let tb = random_trace(rng, 32 * MIB);
        let peak = ta.peak_needed().max(tb.peak_needed()).max(1);
        let grid = rich_grid(peak);
        let stats = AccessStats {
            reads: 1_000_000,
            writes: 500_000,
            ..Default::default()
        };
        let wa = WorkloadSweep {
            name: "wa".to_string(),
            end_cycles: ta.end_time().unwrap(),
            points: sweep_naive(&cacti, &ta, &stats, &grid, 1.0).unwrap(),
        };
        let wb = WorkloadSweep {
            name: "wb".to_string(),
            end_cycles: tb.end_time().unwrap(),
            points: sweep_naive(&cacti, &tb, &stats, &grid, 1.0).unwrap(),
        };
        let r = optimize(&[wa, wb], &Constraints::default(), 0.0, None).unwrap();
        let best = r.robust_best().unwrap();
        // Brute force: every portfolio entry's worst-case regret >= the
        // chosen one's.
        for e in &r.portfolio {
            assert!(best.worst_regret_pct <= e.worst_regret_pct + 1e-12);
        }
        // Regrets recompute exactly from the frontiers' best energies.
        for e in &r.portfolio {
            for ((reg, energy), f) in
                e.regret_pct.iter().zip(&e.energy_j).zip(&r.frontiers)
            {
                let want = if f.best_energy_j == 0.0 {
                    0.0
                } else {
                    (energy - f.best_energy_j) / f.best_energy_j * 100.0
                };
                assert!((reg - want).abs() < 1e-9, "{reg} vs {want}");
            }
        }
    });
}

/// End-to-end determinism of the CLI artifact: the fused decode+serving
/// portfolio pipeline produces byte-identical `pareto_csv` output across
/// runs (the CI gate's in-process equivalent).
#[test]
fn pareto_csv_is_byte_deterministic_over_fused_pipeline() {
    let ctx = ApiContext::new();
    let mut p = ServingParams::new(12, 3, 7);
    p.prompt_min = 4;
    p.prompt_max = 24;
    p.gen_min = 2;
    p.gen_max = 12;
    p.page_tokens = 8;
    p.mean_arrival_gap = 40_000;
    let specs = vec![
        ExperimentSpec::builder()
            .model(TINY_MHA)
            .decode(24, 12)
            .accel(trapti::config::tiny())
            .build()
            .unwrap(),
        ExperimentSpec::builder()
            .model(TINY_GQA)
            .serving(p)
            .accel(trapti::config::tiny())
            .build()
            .unwrap(),
    ];
    let opts = PortfolioOptions {
        grid: Some(SweepSpec {
            capacities: vec![2 * MIB, 4 * MIB, 8 * MIB],
            banks: vec![1, 2, 4, 8],
            alphas: vec![0.9],
            policies: vec![
                GatingPolicy::Aggressive,
                GatingPolicy::conservative(),
                GatingPolicy::drowsy(),
            ],
        }),
        ..Default::default()
    };
    let a = trapti::api::run_portfolio(&ctx, &specs, &opts).unwrap();
    let b = trapti::api::run_portfolio(&ctx, &specs, &opts).unwrap();
    let csv_a = pareto_csv(&a.result);
    let csv_b = pareto_csv(&b.result);
    assert!(!csv_a.is_empty());
    assert_eq!(csv_a, csv_b, "pareto CSV must be byte-identical");
    // Both workloads contribute frontier rows.
    assert!(csv_a.contains("tiny-mha-decode24+12"));
    assert!(csv_a.contains("tiny-gqa-serve-r12-c3-s7"));
    // And the robust-best is stable.
    assert_eq!(
        a.result.robust_best().unwrap().key,
        b.result.robust_best().unwrap().key
    );
}

/// Constraints thread through the full pipeline: a min-capacity floor
/// excludes small configs from frontier and portfolio alike.
#[test]
fn constraints_apply_across_portfolio() {
    let ctx = ApiContext::new();
    let spec = ExperimentSpec::builder()
        .model(TINY_GQA)
        .decode(24, 12)
        .accel(trapti::config::tiny())
        .build()
        .unwrap();
    let grid = SweepSpec {
        capacities: vec![2 * MIB, 4 * MIB, 8 * MIB],
        banks: vec![1, 4],
        alphas: vec![0.9],
        policies: vec![GatingPolicy::Aggressive],
    };
    let run = trapti::api::run_portfolio(
        &ctx,
        std::slice::from_ref(&spec),
        &PortfolioOptions {
            grid: Some(grid),
            constraints: Constraints {
                min_capacity: Some(4 * MIB),
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap();
    for f in &run.result.frontiers {
        for fp in &f.frontier {
            assert!(fp.point.eval.capacity >= 4 * MIB);
        }
    }
    for e in &run.result.portfolio {
        assert!(e.key.capacity >= 4 * MIB);
    }
}
