//! Event-engine integration suite: the differential harness against the
//! retained round-robin oracle (byte-identical materialized traces on
//! every workload the oracle covers), stream-order and page-lifecycle
//! invariants under preemption, bit-determinism of evict/restore, and
//! the typed rejection of degenerate specs.

use trapti::serving::{ServingParams, ServingParamsError};
use trapti::sim::serving::{round_robin, simulate_serving, simulate_serving_with, ServingSimOptions};
use trapti::trace::{MemoryDesc, RunEvent, TraceSink};
use trapti::util::proptest::check;
use trapti::util::rng::Rng;
use trapti::workload::TINY_GQA;

/// Random legacy-schedulable params (no tiers/prefix/tenancy — the
/// oracle's domain), optionally with bursty arrivals and a heavy tail,
/// which only reshape the request schedule and stay oracle-comparable.
fn random_oracle_params(rng: &mut Rng) -> ServingParams {
    let mut p = ServingParams::new(
        rng.range(1, 48) as u32,
        rng.range(1, 8) as u32,
        rng.next_u64(),
    );
    p.prompt_min = rng.range(1, 8) as u32;
    p.prompt_max = p.prompt_min + rng.range(0, 40) as u32;
    p.gen_min = rng.range(1, 6) as u32;
    p.gen_max = p.gen_min + rng.range(0, 24) as u32;
    p.page_tokens = rng.range(1, 32) as u32;
    p.mean_arrival_gap = rng.below(200_000);
    if rng.below(2) == 0 {
        p = p.with_bursty_traffic();
    }
    if rng.below(2) == 0 {
        p.len_tail_q8 = rng.range(1, 255) as u32;
    }
    p
}

/// The tentpole acceptance property: on every workload the round-robin
/// oracle can express, the event-driven engine materializes the exact
/// same trace — sample for sample — and the same stats and makespan.
#[test]
fn event_engine_matches_oracle_on_random_workloads() {
    let accel = trapti::config::tiny();
    check("event-vs-oracle", 16, |rng: &mut Rng| {
        let p = random_oracle_params(rng);
        let event = simulate_serving(&TINY_GQA, p, &accel).unwrap();
        let oracle =
            round_robin(&TINY_GQA, p, &accel, ServingSimOptions::default()).unwrap();
        assert_eq!(event.trace.samples(), oracle.trace.samples());
        assert_eq!(event.trace.end_time(), oracle.trace.end_time());
        assert_eq!(event.trace_hash(), oracle.trace_hash());
        assert_eq!(event.stats, oracle.stats);
        assert_eq!(event.total_cycles, oracle.total_cycles);
        assert_eq!(event.completed, oracle.completed);
        assert_eq!(event.peak_concurrent, oracle.peak_concurrent);
        assert_eq!(event.workload, oracle.workload);
        assert_eq!(event.evicted, 0);
        assert_eq!(event.restored, 0);
    });
}

/// Records the cycle stamp of everything the engine streams out, in
/// arrival order, to check the heap's total order from the outside.
#[derive(Default)]
struct StreamOrderRecorder {
    stamps: Vec<u64>,
    admits: u32,
    completes: u32,
    evicts: u32,
    restores: u32,
}

impl TraceSink for StreamOrderRecorder {
    fn begin(&mut self, _memories: &[MemoryDesc]) {}

    fn on_sample(&mut self, _mem: usize, t: u64, _needed: u64, _obsolete: u64) {
        self.stamps.push(t);
    }

    fn on_event(&mut self, t: u64, event: &RunEvent) {
        self.stamps.push(t);
        match event {
            RunEvent::Admit { .. } => self.admits += 1,
            RunEvent::Complete { .. } => self.completes += 1,
            RunEvent::Evict { .. } => self.evicts += 1,
            RunEvent::Restore { .. } => self.restores += 1,
            _ => {}
        }
    }
}

/// The event heap's (t, seq) total order is externally visible as a
/// non-decreasing stream of cycle stamps — samples and structural
/// events interleaved — even under preemption, where restores replay
/// evicted KV at later cycles.
#[test]
fn stream_timestamps_never_go_backwards() {
    let accel = trapti::config::tiny();
    check("stream-order", 10, |rng: &mut Rng| {
        let mut p = random_oracle_params(rng);
        p.tiers = rng.range(1, 4) as u32;
        let mut rec = StreamOrderRecorder::default();
        let r = simulate_serving_with(
            &TINY_GQA,
            p,
            &accel,
            ServingSimOptions {
                sink: Some(&mut rec),
                materialize: false,
            },
        )
        .unwrap();
        assert!(
            rec.stamps.windows(2).all(|w| w[0] <= w[1]),
            "stream must be time-ordered"
        );
        assert_eq!(*rec.stamps.last().unwrap(), r.total_cycles);
        assert_eq!(rec.admits, p.requests, "restores are not fresh admits");
        assert_eq!(rec.completes, p.requests);
        assert_eq!(rec.evicts, r.evicted);
        assert_eq!(rec.restores, r.restored);
    });
}

/// Page lifecycle under preemption: every evicted request is restored
/// exactly once, every request still completes, occupancy never exceeds
/// the sized arena capacity (a double-free would wrap the page
/// accounting and blow straight past it), and the arena drains to zero.
#[test]
fn preemption_never_double_frees_pages() {
    let accel = trapti::config::tiny();
    check("preemption-pages", 12, |rng: &mut Rng| {
        let mut p = ServingParams::new(
            rng.range(8, 48) as u32,
            rng.range(1, 4) as u32,
            rng.next_u64(),
        );
        p.prompt_min = 2;
        p.prompt_max = 2 + rng.range(0, 24) as u32;
        p.gen_min = 2;
        p.gen_max = 2 + rng.range(0, 16) as u32;
        p.page_tokens = rng.range(1, 16) as u32;
        // Tight arrivals + tiers: admissions pile up behind running
        // streams, so higher-priority waiters force evictions.
        p.mean_arrival_gap = rng.below(2_000);
        p.tiers = rng.range(2, 4) as u32;
        let r = simulate_serving(&TINY_GQA, p, &accel).unwrap();
        assert_eq!(r.completed, p.requests);
        assert_eq!(r.evicted, r.restored);
        let samples = r.trace.samples();
        assert!(samples
            .iter()
            .all(|s| s.needed + s.obsolete <= r.arena_capacity));
        let last = samples.last().unwrap();
        assert_eq!((last.needed, last.obsolete), (0, 0), "arena must drain");
    });
}

/// Preemption and restore are bit-deterministic: the same tiered spec
/// yields the same trace hash, eviction count, and makespan every run.
#[test]
fn preemption_is_bit_deterministic() {
    let accel = trapti::config::tiny();
    let mut p = ServingParams::new(40, 2, 11);
    p.prompt_min = 4;
    p.prompt_max = 32;
    p.gen_min = 2;
    p.gen_max = 16;
    p.page_tokens = 8;
    p.mean_arrival_gap = 500;
    p.tiers = 3;
    let a = simulate_serving(&TINY_GQA, p, &accel).unwrap();
    let b = simulate_serving(&TINY_GQA, p, &accel).unwrap();
    assert_eq!(a.trace_hash(), b.trace_hash());
    assert_eq!(a.trace.samples(), b.trace.samples());
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!((a.evicted, a.restored), (b.evicted, b.restored));
    assert_eq!(a.stats, b.stats);
}

/// Degenerate specs die in the typed validator, not deep inside the
/// engine: every `ServingParamsError` variant is reachable and precise.
#[test]
fn degenerate_specs_fail_with_typed_errors() {
    use ServingParamsError as E;
    let base = || ServingParams::new(8, 2, 7);
    let cases: Vec<(ServingParams, E)> = vec![
        (
            {
                let mut p = base();
                p.requests = 0;
                p
            },
            E::ZeroRequests,
        ),
        (
            {
                let mut p = base();
                p.concurrency = 0;
                p
            },
            E::ZeroConcurrency,
        ),
        (
            {
                let mut p = base();
                p.prompt_min = 9;
                p.prompt_max = 3;
                p
            },
            E::PromptRangeInverted { min: 9, max: 3 },
        ),
        (
            {
                let mut p = base();
                p.gen_min = 0;
                p
            },
            E::ZeroGenMin,
        ),
        (
            {
                let mut p = base();
                p.gen_min = 8;
                p.gen_max = 2;
                p
            },
            E::GenRangeInverted { min: 8, max: 2 },
        ),
        (
            {
                let mut p = base();
                p.page_tokens = 0;
                p
            },
            E::ZeroPageTokens,
        ),
        (
            {
                let mut p = base();
                p.burst_gap = 100;
                p
            },
            E::BurstDwellMissing,
        ),
        (
            {
                let mut p = base();
                p.burst_len = 8;
                p
            },
            E::BurstDwellWithoutGap,
        ),
        (
            {
                let mut p = base();
                p.len_tail_q8 = 256;
                p
            },
            E::TailOutOfRange { q8: 256 },
        ),
        (
            {
                let mut p = base();
                p.prompt_min = 0;
                p.len_tail_q8 = 128;
                p
            },
            E::TailNeedsPositivePromptMin,
        ),
        (
            {
                let mut p = base();
                p.tiers = 0;
                p
            },
            E::ZeroTiers,
        ),
        (
            {
                let mut p = base();
                p.tenants = 3;
                p
            },
            E::BadTenants { tenants: 3 },
        ),
    ];
    for (p, want) in cases {
        assert_eq!(p.validate(), Err(want), "params: {p:?}");
    }
    assert!(base().validate().is_ok());
    assert!(base().with_bursty_traffic().validate().is_ok());
}
