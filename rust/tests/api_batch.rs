//! Integration tests for the `trapti::api` pipeline: the acceptance
//! check that a `BatchRunner` executing several specs *concurrently*
//! produces byte-identical reports to sequential execution, plus
//! streaming-vs-materialized equivalence through the public API.
//! Tiny-model scale so it stays fast in every profile.

use std::sync::Arc;

use trapti::api::{ApiContext, BatchRunner, ExperimentSpec};
use trapti::banking::{GatingPolicy, SweepSpec};
use trapti::config::tiny;
use trapti::trace::{MaterializeSink, OnlineStatsSink, TeeSink};
use trapti::util::MIB;
use trapti::workload::{Workload, TINY_GQA, TINY_MHA};

fn grid() -> SweepSpec {
    SweepSpec {
        capacities: vec![2 * MIB, 4 * MIB],
        banks: vec![1, 2, 4, 8],
        alphas: vec![0.9],
        policies: vec![GatingPolicy::Aggressive],
    }
}

fn spec(model: trapti::workload::ModelPreset, wl: Workload) -> ExperimentSpec {
    ExperimentSpec::builder()
        .model(model)
        .workload(wl)
        .accel(tiny())
        .sweep(grid())
        .build()
        .unwrap()
}

/// The acceptance criterion: >= 2 specs executed concurrently must
/// produce byte-identical reports to sequential execution, with
/// duplicates deduplicated by content hash.
#[test]
fn concurrent_batch_matches_sequential_byte_for_byte() {
    let specs = vec![
        spec(TINY_GQA, Workload::Prefill { seq: 64 }),
        spec(TINY_MHA, Workload::Prefill { seq: 64 }),
        spec(TINY_GQA, Workload::Decode { prompt: 16, gen: 8 }),
        spec(TINY_GQA, Workload::Prefill { seq: 64 }), // duplicate of [0]
    ];
    let runner = BatchRunner::new().threads(4);

    let parallel = runner.run(&specs).unwrap();
    let sequential = runner.run_sequential(&specs).unwrap();
    assert_eq!(parallel.len(), 4);
    assert_eq!(sequential.len(), 4);

    for (p, s) in parallel.iter().zip(&sequential) {
        assert_eq!(p.hash, s.hash);
        assert_eq!(p.report(), s.report(), "spec {:016x}", p.hash);
        assert!(p.report().contains("stage2"), "sweep rendered");
    }

    // Memoization: the duplicate spec shares the first run's results.
    assert!(Arc::ptr_eq(&parallel[0].stage1, &parallel[3].stage1));
    assert!(Arc::ptr_eq(&parallel[0].sweep, &parallel[3].sweep));
    // Distinct specs do not.
    assert!(!Arc::ptr_eq(&parallel[0].stage1, &parallel[1].stage1));
    assert!(!Arc::ptr_eq(&parallel[0].stage1, &parallel[2].stage1));
    // The sequential reference never memoizes.
    assert!(!Arc::ptr_eq(&sequential[0].stage1, &sequential[3].stage1));
}

/// Streaming Stage I through the public API: online statistics and a
/// streamed materialization must match a conventional run exactly.
#[test]
fn streaming_matches_materialized_through_api() {
    let ctx = ApiContext::new();
    let spec = spec(TINY_GQA, Workload::Prefill { seq: 64 });
    let s1 = spec.run_stage1(&ctx).unwrap();

    let mut mat = MaterializeSink::new();
    let mut online = OnlineStatsSink::new();
    let summary = {
        let mut tee = TeeSink::new(vec![&mut mat, &mut online]);
        spec.stream_stage1(&ctx, &mut tee).unwrap()
    };

    assert_eq!(summary.total_cycles(), s1.result.total_cycles);
    assert_eq!(summary.stats(), &s1.result.stats);
    // Materialized stream == materialized run, sample for sample.
    assert_eq!(mat.traces().len(), s1.traces().len());
    for (a, b) in mat.traces().iter().zip(s1.traces()) {
        assert_eq!(a.samples(), b.samples(), "memory {}", b.memory);
    }
    // O(1) online stats agree with the materialized queries.
    let m = online.shared().unwrap();
    assert_eq!(m.peak_needed(), s1.result.peak_needed());
    assert!((m.avg_needed() - s1.trace().avg_needed()).abs() < 1e-9);
}

/// Typed-handle path equals the batch path for the same spec.
#[test]
fn batch_results_match_direct_stage_handles() {
    let ctx = ApiContext::new();
    let sp = spec(TINY_MHA, Workload::Prefill { seq: 48 });
    let direct_s1 = sp.run_stage1(&ctx).unwrap();
    let direct_pts = direct_s1.stage2(&ctx).unwrap();

    let batch = BatchRunner::with_context(ctx.clone())
        .threads(2)
        .run(std::slice::from_ref(&sp))
        .unwrap();
    assert_eq!(batch.len(), 1);
    let b = &batch[0];
    assert_eq!(b.stage1.result.total_cycles, direct_s1.result.total_cycles);
    assert_eq!(b.sweep.len(), 1, "shared-SRAM sweep group");
    let (mem, pts) = &b.sweep[0];
    assert_eq!(mem, "sram");
    assert_eq!(pts.len(), direct_pts.shared().len());
    for (a, d) in pts.iter().zip(direct_pts.shared()) {
        assert_eq!(a.eval.e_total_j().to_bits(), d.eval.e_total_j().to_bits());
    }
}
