//! Property suite for the attention-variant spectrum (the scenario
//! axis): KV-footprint monotonicity MHA → GQA → MQA → MLA at matched
//! shape, sliding-window decode occupancy plateauing at the window, and
//! qkv/paper-counterpart consistency for every preset in
//! `all_presets()`. The degenerate-config rule rides along: a window at
//! or beyond the final context must leave the decode run bit-identical
//! to the unwindowed model (while still moving the spec hash, per the
//! extension-gate rule).
//!
//! Case count honors `PROPTEST_CASES` (CI sets 64).

use trapti::api::{ApiContext, ExperimentSpec};
use trapti::util::proptest::check;
use trapti::util::rng::Rng;
use trapti::workload::{
    all_presets, paper_counterpart, preset, spectrum_presets, AttnKind, FfnKind,
    ModelPreset, NormKind, FIG1_MHA, FIG1_SWA, TINY_MHA,
};

/// Honors `PROPTEST_CASES` (the CI knob) with a local default.
fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// A structurally valid preset at an arbitrary attention shape. Only the
/// attention knobs vary across a matched chain; everything else is fixed
/// so KV-footprint comparisons isolate the attention family.
fn shape(
    layers: u16,
    heads: u32,
    kv_heads: u32,
    d_head: u32,
    latent_dim: u32,
    window: u32,
) -> ModelPreset {
    ModelPreset {
        name: "prop-shape",
        layers,
        d_model: heads * d_head,
        heads,
        kv_heads,
        d_head,
        d_ff: 4 * heads * d_head,
        ffn: FfnKind::Gelu,
        norm: NormKind::LayerNorm,
        latent_dim,
        window,
    }
}

#[test]
fn spectrum_presets_kv_monotone_non_increasing_at_matched_params() {
    let presets = spectrum_presets();
    assert_eq!(presets.len(), 5, "MHA, GQA, MQA, MLA, SWA");
    for m in &presets {
        assert_eq!(m.param_count(), FIG1_MHA.param_count(), "{}", m.name);
    }
    // The first four are the shrinking-KV chain at every horizon.
    for seq in [1u64, 64, 256, 2048, 1 << 16] {
        let kv: Vec<u64> = presets
            .iter()
            .take(4)
            .map(|m| m.kv_cache_bytes(seq))
            .collect();
        assert!(kv.windows(2).all(|w| w[0] >= w[1]), "seq={seq}: {kv:?}");
        assert!(kv[0] > kv[3], "MLA must undercut MHA at seq={seq}: {kv:?}");
    }
    // The SWA point plateaus rather than shrinks: equal to its MHA base
    // below the window, constant above it.
    assert_eq!(FIG1_SWA.kv_cache_bytes(128), FIG1_MHA.kv_cache_bytes(128));
    assert_eq!(
        FIG1_SWA.kv_cache_bytes(1 << 20),
        FIG1_SWA.kv_cache_bytes(FIG1_SWA.window as u64)
    );
}

#[test]
fn prop_kv_chain_monotone_on_random_matched_shapes() {
    check("kv-chain-monotone", cases(64), |rng: &mut Rng| {
        let layers = rng.range(1, 8) as u16;
        let d_head = 8u32 << rng.below(4);
        let heads_pool = [4u32, 6, 8, 12, 16, 24];
        let heads = heads_pool[rng.below(heads_pool.len() as u64) as usize];
        let divisors: Vec<u32> = (2..heads).filter(|d| heads % d == 0).collect();
        let kv_mid = divisors[rng.below(divisors.len() as u64) as usize];
        // MLA latent never wider than the MQA pair it undercuts.
        let latent = rng.range(1, 2 * d_head as u64) as u32;
        let chain = [
            shape(layers, heads, heads, d_head, 0, 0),
            shape(layers, heads, kv_mid, d_head, 0, 0),
            shape(layers, heads, 1, d_head, 0, 0),
            shape(layers, heads, heads, d_head, latent, 0),
        ];
        let kinds: Vec<AttnKind> = chain.iter().map(ModelPreset::attn_kind).collect();
        assert_eq!(
            kinds,
            [AttnKind::Mha, AttnKind::Gqa, AttnKind::Mqa, AttnKind::Mla]
        );
        for seq in [0u64, 1, rng.range(2, 1 << 14)] {
            let kv: Vec<u64> = chain.iter().map(|m| m.kv_cache_bytes(seq)).collect();
            assert!(
                kv.windows(2).all(|w| w[0] >= w[1]),
                "H={heads} Hkv={kv_mid} Dh={d_head} latent={latent} seq={seq}: {kv:?}"
            );
        }
        // Per-token accounting stays exact along the whole chain.
        for m in &chain {
            assert_eq!(m.k_token_bytes() + m.v_token_bytes(), m.kv_token_bytes());
        }
    });
}

#[test]
fn prop_windowed_kv_plateaus_and_collapses_when_off() {
    check("window-plateau", cases(64), |rng: &mut Rng| {
        let layers = rng.range(1, 6) as u16;
        let d_head = 8u32 << rng.below(3);
        let heads = 2u32 << rng.below(3);
        let window = rng.range(1, 4096) as u32;
        let base = shape(layers, heads, heads, d_head, 0, 0);
        let swa = shape(layers, heads, heads, d_head, 0, window);
        // At or below the window: byte-identical to the unwindowed base.
        let inside = rng.range(1, window as u64);
        assert_eq!(swa.kv_cache_bytes(inside), base.kv_cache_bytes(inside));
        assert_eq!(swa.total_macs(inside), base.total_macs(inside));
        // Beyond the window: pinned at the window's footprint, never
        // above the full-causal curve.
        let beyond = window as u64 + rng.range(1, 1 << 16);
        assert_eq!(
            swa.kv_cache_bytes(beyond),
            swa.kv_cache_bytes(window as u64)
        );
        assert!(swa.kv_cache_bytes(beyond) <= base.kv_cache_bytes(beyond));
        // The window is an occupancy knob only: parameters and the
        // attention-family classification are untouched.
        assert_eq!(swa.param_count(), base.param_count());
        assert_eq!(swa.attn_kind(), base.attn_kind());
    });
}

#[test]
fn windowed_decode_occupancy_plateaus_at_the_window() {
    let ctx = ApiContext::new();
    let mut swa = TINY_MHA.clone();
    swa.window = 8;
    let peak = |m: &ModelPreset, gen: u32| {
        let spec = ExperimentSpec::builder()
            .model(m.clone())
            .decode(32, gen)
            .accel(trapti::config::tiny())
            .build()
            .unwrap();
        spec.run_stage1(&ctx).unwrap().trace().peak_needed()
    };
    // The window saturates during the 32-token prompt, so windowed
    // decode peak occupancy is flat in the generation length...
    let p_short = peak(&swa, 8);
    let p_long = peak(&swa, 32);
    assert_eq!(p_short, p_long, "windowed decode peak must plateau");
    // ...while the full-horizon twin keeps growing with context...
    let f_short = peak(&TINY_MHA, 8);
    let f_long = peak(&TINY_MHA, 32);
    assert!(
        f_long > f_short,
        "full-causal decode peak must grow: {f_short} vs {f_long}"
    );
    // ...and the plateau sits strictly below the growing curve.
    assert!(p_long < f_long, "window must cap occupancy: {p_long} vs {f_long}");
}

#[test]
fn window_at_or_beyond_final_context_is_bit_identical_to_flat_decode() {
    let ctx = ApiContext::new();
    let mut wide = TINY_MHA.clone();
    wide.window = 64; // final context is 16 + 8 = 24 < 64: never binds
    let run = |m: &ModelPreset| {
        let spec = ExperimentSpec::builder()
            .model(m.clone())
            .decode(16, 8)
            .accel(trapti::config::tiny())
            .build()
            .unwrap();
        spec.run_stage1(&ctx).unwrap()
    };
    let flat = run(&TINY_MHA);
    let win = run(&wide);
    assert_eq!(flat.graph.total_macs(), win.graph.total_macs());
    assert_eq!(flat.graph.kv_bytes(), win.graph.kv_bytes());
    assert_eq!(flat.result.total_cycles, win.result.total_cycles);
    let (a, b) = (flat.trace().samples(), win.trace().samples());
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!((x.t, x.needed, x.obsolete), (y.t, y.needed, y.obsolete));
    }
    // The run is identical, but the spec hash is not: any enabled
    // attention extension joins the content hash (the extension-gate
    // rule), while the all-off form must hash like before the field
    // existed.
    assert_ne!(flat.spec.content_hash(), win.spec.content_hash());
}

#[test]
fn qkv_and_paper_counterpart_consistency_for_every_preset() {
    let presets = all_presets();
    assert_eq!(presets.len(), 9);
    for m in &presets {
        assert_eq!(
            m.qkv_out_dim(),
            (m.heads + 2 * m.kv_heads) * m.d_head,
            "{}",
            m.name
        );
        assert_eq!(
            m.k_token_bytes() + m.v_token_bytes(),
            m.kv_token_bytes(),
            "{}",
            m.name
        );
        assert!(
            m.kv_token_bytes() <= 2 * (m.kv_heads * m.d_head) as u64,
            "{}: a latent must compress, never inflate, the KV pair",
            m.name
        );
        assert_eq!(
            preset(m.name).as_ref(),
            Some(m),
            "{} must round-trip through preset()",
            m.name
        );
        match paper_counterpart(m.name) {
            Some(c) => {
                assert_ne!(c.name, m.name);
                assert_eq!(
                    paper_counterpart(c.name).as_ref(),
                    Some(m),
                    "{}: pairing must be symmetric",
                    m.name
                );
                // Each pair contrasts MHA against a shared-KV family.
                assert_ne!(
                    c.attn_kind() == AttnKind::Mha,
                    m.attn_kind() == AttnKind::Mha,
                    "{}",
                    m.name
                );
            }
            None => assert!(
                matches!(m.attn_kind(), AttnKind::Mqa | AttnKind::Mla)
                    || m.window > 0,
                "{} must have a co-residency counterpart",
                m.name
            ),
        }
    }
}
