//! Differential wall for the hierarchy-aware Stage II/III engine
//! (`banking::hierarchy`). The degenerate-config contract: with the
//! knob off — `config = None`, or an L1 capacity already covering the
//! peak — the hierarchy path must be `to_bits`-identical to the flat
//! `sweep_fused` / `replay_trace_with` engines. Below the peak, the
//! oracle is a trace clamped at the L1 capacity in the test itself: the
//! L1 side of every spilled point must equal the flat sweep of that
//! clamped trace bit-for-bit, and the L2 charge obeys closed-form
//! invariants (spilled peak, migration lower bound, residency bound,
//! collapse conservation).
//!
//! Case count honors `PROPTEST_CASES` (CI sets 64).

use trapti::api::ApiContext;
use trapti::banking::{
    replay_hierarchy, replay_trace_with, sweep_fused, sweep_hierarchy,
    GatingPolicy, HierarchyConfig, HierarchyPoint, OnlineConfig, OnlineError,
    OnlineReport, SweepPoint, SweepSpec,
};
use trapti::trace::{AccessStats, OccupancyTrace};
use trapti::util::proptest::check;
use trapti::util::rng::Rng;
use trapti::util::MIB;

/// Honors `PROPTEST_CASES` (the CI knob) with a local default.
fn cases(default: u64) -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Strict point comparator: every field identical, floats by `to_bits`.
fn assert_points_bit_identical(flat: &[SweepPoint], hier: &[HierarchyPoint]) {
    assert_eq!(flat.len(), hier.len(), "point count");
    for (f, h) in flat.iter().zip(hier) {
        let n = &h.point;
        let at = format!(
            "C={} B={} alpha={} {:?}",
            f.eval.capacity, f.eval.banks, f.eval.alpha, f.eval.policy
        );
        assert_eq!(f.eval.capacity, n.eval.capacity, "{at}");
        assert_eq!(f.eval.banks, n.eval.banks, "{at}");
        assert_eq!(f.eval.alpha.to_bits(), n.eval.alpha.to_bits(), "{at}");
        assert_eq!(f.eval.policy, n.eval.policy, "{at}");
        assert_eq!(f.eval.n_switch, n.eval.n_switch, "{at}");
        assert_eq!(f.eval.latency_cycles, n.eval.latency_cycles, "{at}");
        for (a, b, what) in [
            (f.eval.e_dyn_j, n.eval.e_dyn_j, "e_dyn_j"),
            (f.eval.e_leak_j, n.eval.e_leak_j, "e_leak_j"),
            (f.eval.e_sw_j, n.eval.e_sw_j, "e_sw_j"),
            (f.eval.avg_active_banks, n.eval.avg_active_banks, "avg_active"),
            (f.eval.gated_fraction, n.eval.gated_fraction, "gated_fraction"),
            (f.eval.area_mm2, n.eval.area_mm2, "area_mm2"),
            (f.base_e_j, n.base_e_j, "base_e_j"),
            (f.base_area_mm2, n.base_area_mm2, "base_area_mm2"),
        ] {
            assert_eq!(a.to_bits(), b.to_bits(), "{what}: {a} vs {b} at {at}");
        }
        assert_eq!(f.eval.characterization, n.eval.characterization, "{at}");
    }
}

/// Strict online-report comparator (timeline-free replays).
fn assert_reports_bit_identical(f: &OnlineReport, h: &OnlineReport) {
    assert_eq!(f.stall_cycles, h.stall_cycles);
    assert_eq!(f.wake_events, h.wake_events);
    assert_eq!(f.trace_cycles, h.trace_cycles);
    assert_eq!(f.eval.n_switch, h.eval.n_switch);
    for (a, b, what) in [
        (f.eval.e_dyn_j, h.eval.e_dyn_j, "e_dyn_j"),
        (f.eval.e_leak_j, h.eval.e_leak_j, "e_leak_j"),
        (f.eval.e_sw_j, h.eval.e_sw_j, "e_sw_j"),
        (f.eval.gated_fraction, h.eval.gated_fraction, "gated_fraction"),
    ] {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: {a} vs {b}");
    }
}

/// Random occupancy trace with nonzero obsolete bytes (the clamp must
/// respect the obsolete-fits-in-the-remainder rule too).
fn random_trace(rng: &mut Rng, capacity: u64, max_segments: u64) -> OccupancyTrace {
    let mut tr = OccupancyTrace::new("mem", capacity);
    let mut t = 0u64;
    for _ in 0..rng.below(max_segments + 1) {
        t += rng.range(1, 10_000);
        let needed = if rng.below(6) == 0 { 0 } else { rng.below(capacity + 1) };
        let obsolete = rng.below(capacity - needed + 1);
        tr.record(t, needed, obsolete);
    }
    tr.finalize(t + rng.range(1, 2_000));
    tr
}

fn random_stats(rng: &mut Rng) -> AccessStats {
    AccessStats {
        reads: rng.below(20_000_000),
        writes: rng.below(5_000_000),
        ..Default::default()
    }
}

const POLICY_POOL: [GatingPolicy; 4] = [
    GatingPolicy::None,
    GatingPolicy::Aggressive,
    GatingPolicy::Conservative { min_idle_factor: 4.0 },
    GatingPolicy::Drowsy { retention_factor: 0.25 },
];

/// Random subset of the policy pool; never empty.
fn random_policies(rng: &mut Rng) -> Vec<GatingPolicy> {
    let mask = rng.range(1, 15);
    POLICY_POOL
        .iter()
        .enumerate()
        .filter(|(i, _)| mask & (1 << i) != 0)
        .map(|(_, p)| *p)
        .collect()
}

/// Random subset of the power-of-two bank pool; never empty.
fn random_banks(rng: &mut Rng, pool: &[u32]) -> Vec<u32> {
    let mask = rng.range(1, (1u64 << pool.len()) - 1);
    pool.iter()
        .enumerate()
        .filter(|(i, _)| mask & (1u64 << i) != 0)
        .map(|(_, b)| *b)
        .collect()
}

/// The test's own clamp — the documented L1 view of a spilled run:
/// needed capped at the capacity, obsolete in whatever room remains.
fn clamp(tr: &OccupancyTrace, cap: u64) -> OccupancyTrace {
    let mut out = OccupancyTrace::new(&tr.memory, cap);
    for s in tr.samples() {
        let needed = s.needed.min(cap);
        out.record(s.t, needed, s.obsolete.min(cap - needed));
    }
    out.finalize(tr.end_time().expect("finalized input"));
    out
}

#[test]
fn prop_none_config_is_bitwise_flat_sweep() {
    let ctx = ApiContext::new();
    check("hier-none-flat", cases(48), |rng: &mut Rng| {
        let cap = rng.range(1, 1 << 26);
        let tr = random_trace(rng, cap, 50);
        let peak = tr.peak_needed();
        // Straddle the peak so the flat infeasibility filter fires too.
        let grid = SweepSpec {
            capacities: vec![(peak / 2).max(1), peak.max(1), cap.max(1) * 2],
            banks: random_banks(rng, &[1, 2, 4, 8, 16]),
            alphas: vec![0.05 + rng.f64() * 0.95],
            policies: random_policies(rng),
        };
        let stats = random_stats(rng);
        let freq = 0.5 + rng.f64() * 1.5;
        let flat = sweep_fused(&ctx.cacti, &tr, &stats, &grid, freq).unwrap();
        let hier = sweep_hierarchy(&ctx.cacti, &tr, &stats, &grid, freq, None).unwrap();
        assert!(hier.iter().all(|p| p.l2.is_none()));
        assert_points_bit_identical(&flat, &hier);
    });
}

#[test]
fn prop_l1_covering_peak_is_bitwise_flat_even_with_config() {
    let ctx = ApiContext::new();
    check("hier-above-peak-flat", cases(32), |rng: &mut Rng| {
        let cap = rng.range(1, 1 << 26);
        let tr = random_trace(rng, cap, 50);
        let peak = tr.peak_needed();
        // Every capacity covers the peak: the config must be inert.
        let grid = SweepSpec {
            capacities: vec![peak.max(1), peak.max(1) * 2, peak.max(1) * 4],
            banks: random_banks(rng, &[1, 4, 16, 64]),
            alphas: vec![0.9, 1.0],
            policies: random_policies(rng),
        };
        let cfg = HierarchyConfig::new(rng.range(1, 1 << 26));
        let stats = random_stats(rng);
        let flat = sweep_fused(&ctx.cacti, &tr, &stats, &grid, 1.0).unwrap();
        let hier =
            sweep_hierarchy(&ctx.cacti, &tr, &stats, &grid, 1.0, Some(&cfg)).unwrap();
        assert!(hier.iter().all(|p| p.l2.is_none()));
        assert_points_bit_identical(&flat, &hier);
    });
}

#[test]
fn prop_spilled_points_match_flat_sweep_of_clamped_trace() {
    let ctx = ApiContext::new();
    check("hier-spill-oracle", cases(32), |rng: &mut Rng| {
        let cap = rng.range(1 << 10, 1 << 26);
        let tr = random_trace(rng, cap, 50);
        let peak = tr.peak_needed();
        if peak < 2 {
            return; // no below-peak capacity exists
        }
        let l1 = rng.range(1, peak - 1);
        let cfg = HierarchyConfig::new(peak); // excess always fits
        let grid = SweepSpec {
            capacities: vec![l1],
            banks: random_banks(rng, &[1, 2, 8, 32]),
            alphas: vec![0.9],
            policies: random_policies(rng),
        };
        let stats = random_stats(rng);
        let hier =
            sweep_hierarchy(&ctx.cacti, &tr, &stats, &grid, 1.0, Some(&cfg)).unwrap();
        assert_eq!(hier.len(), grid.points(), "spill cap must be admitted");
        // Oracle: the L1 side is the flat sweep of the clamped trace.
        let flat = sweep_fused(&ctx.cacti, &clamp(&tr, l1), &stats, &grid, 1.0).unwrap();
        assert_points_bit_identical(&flat, &hier);
        let end = tr.end_time().unwrap();
        for p in &hier {
            let l2 = p.l2.as_ref().expect("below-peak point must carry L2");
            assert_eq!(l2.spilled_peak_bytes, peak - l1);
            // The spill level must at least rise from 0 to its own peak.
            assert!(l2.migrate_bytes >= l2.spilled_peak_bytes);
            assert_eq!(
                l2.e_migrate_j.to_bits(),
                (l2.migrate_bytes as f64 * cfg.migrate_energy_per_byte_j).to_bits()
            );
            assert!(l2.l2_resident_cycles <= end);
            assert!(l2.e_l2_leak_j >= 0.0);
            // Collapse conserves components exactly: migration joins
            // dynamic energy, L2 residence joins leakage.
            let before = p.point.eval.clone();
            let c = p.clone().collapse();
            assert_eq!(
                c.eval.e_dyn_j.to_bits(),
                (before.e_dyn_j + l2.e_migrate_j).to_bits()
            );
            assert_eq!(
                c.eval.e_leak_j.to_bits(),
                (before.e_leak_j + l2.e_l2_leak_j).to_bits()
            );
            assert_eq!(c.eval.e_sw_j.to_bits(), before.e_sw_j.to_bits());
        }
    });
}

#[test]
fn prop_replay_flat_when_feasible_and_clamped_oracle_when_spilled() {
    let ctx = ApiContext::new();
    check("hier-replay-diff", cases(32), |rng: &mut Rng| {
        let cap = rng.range(1 << 10, 1 << 26);
        let tr = random_trace(rng, cap, 40);
        let peak = tr.peak_needed();
        let stats = random_stats(rng);
        let policy = POLICY_POOL[rng.below(4) as usize];
        let banks = 1u32 << rng.below(5);
        // Feasible capacity: the config (present or not) must be inert.
        let config = OnlineConfig::new(peak.max(1), banks, 0.9, policy);
        let cfg = HierarchyConfig::new(rng.range(1, 1 << 20));
        let flat =
            replay_trace_with(&ctx.cacti, &tr, &stats, config, 1.0, false).unwrap();
        for hierarchy in [None, Some(&cfg)] {
            let hier = replay_hierarchy(
                &ctx.cacti, &tr, &stats, config, 1.0, false, hierarchy,
            )
            .unwrap();
            assert!(hier.l2.is_none());
            assert_reports_bit_identical(&flat, &hier.report);
            assert_eq!(flat.e_total_j().to_bits(), hier.e_total_j().to_bits());
        }
        if peak < 2 {
            return;
        }
        // Below the peak: the flat replay refuses outright...
        let l1 = rng.range(1, peak - 1);
        let sub = OnlineConfig::new(l1, banks, 0.9, policy);
        assert!(matches!(
            replay_trace_with(&ctx.cacti, &tr, &stats, sub, 1.0, false),
            Err(OnlineError::InfeasibleCapacity { .. })
        ));
        // ...the hierarchy admits it when the excess fits the pool, and
        // the L1 report is the flat replay of the clamped trace...
        let pool = HierarchyConfig::new(peak - l1);
        let rep = replay_hierarchy(&ctx.cacti, &tr, &stats, sub, 1.0, false, Some(&pool))
            .unwrap();
        let l2 = rep.l2.as_ref().expect("spilled replay must carry L2");
        assert_eq!(l2.spilled_peak_bytes, peak - l1);
        let flat_sub =
            replay_trace_with(&ctx.cacti, &clamp(&tr, l1), &stats, sub, 1.0, false)
                .unwrap();
        assert_reports_bit_identical(&flat_sub, &rep.report);
        // ...and overflow past the pool reports the combined capacity.
        if peak - l1 >= 2 {
            let small = HierarchyConfig::new(peak - l1 - 1);
            match replay_hierarchy(&ctx.cacti, &tr, &stats, sub, 1.0, false, Some(&small))
            {
                Err(OnlineError::InfeasibleCapacity { capacity, peak_needed }) => {
                    assert_eq!(capacity, l1 + small.l2_capacity);
                    assert_eq!(peak_needed, peak);
                }
                other => panic!("expected InfeasibleCapacity, got {other:?}"),
            }
        }
    });
}

/// Deterministic grid-shape check: capacity-major output order, the
/// skip rule for excess beyond the L2 pool, and flat bit-identity for
/// the at-or-above-peak columns of a mixed grid.
#[test]
fn mixed_grid_orders_capacities_and_skips_oversized_spill() {
    let ctx = ApiContext::new();
    let mut tr = OccupancyTrace::new("sram", 128 * MIB);
    let mut t = 0;
    while t < 4_000_000 {
        tr.record(t, 40 * MIB, 0);
        tr.record(t + 300_000, 8 * MIB, MIB);
        t += 600_000;
    }
    tr.finalize(4_000_000);
    let stats = AccessStats {
        reads: 2_000_000,
        writes: 500_000,
        ..Default::default()
    };
    // 4 MiB spills 36 MiB (> pool: skipped), 24 MiB spills 16 MiB
    // (admitted), 64 MiB covers the peak (flat).
    let grid = SweepSpec {
        capacities: vec![4 * MIB, 24 * MIB, 64 * MIB],
        banks: vec![1, 4],
        alphas: vec![0.9],
        policies: vec![GatingPolicy::None, GatingPolicy::Aggressive],
    };
    let cfg = HierarchyConfig::new(20 * MIB);
    let pts =
        sweep_hierarchy(&ctx.cacti, &tr, &stats, &grid, 1.0, Some(&cfg)).unwrap();
    let caps: Vec<u64> = pts.iter().map(|p| p.point.eval.capacity).collect();
    assert_eq!(pts.len(), 8, "two admitted capacities x 2 banks x 2 policies");
    assert!(caps[..4].iter().all(|&c| c == 24 * MIB), "{caps:?}");
    assert!(caps[4..].iter().all(|&c| c == 64 * MIB), "{caps:?}");
    assert!(pts[..4].iter().all(|p| p.l2.is_some()));
    assert!(pts[4..].iter().all(|p| p.l2.is_none()));
    // The flat column of the mixed grid is bit-identical to the whole
    // flat sweep (which drops both below-peak capacities itself).
    let flat = sweep_fused(&ctx.cacti, &tr, &stats, &grid, 1.0).unwrap();
    assert_points_bit_identical(&flat, &pts[4..]);
}
