//! Full-scale integration tests: the paper's headline claims must hold
//! on the real Table I workloads at M=2048, driven through the typed
//! `trapti::api` pipeline (run in release for speed:
//! `cargo test --release --test paper_experiments`; the test profile
//! builds with opt-level 2, so plain `cargo test` works too).

use trapti::api::{experiments as exp, ApiContext, ExperimentSpec};
use trapti::banking::{evaluate, GatingPolicy};
use trapti::util::MIB;

fn ctx() -> ApiContext {
    ApiContext::new()
}

#[test]
fn fig5_peak_utilization_gap() {
    let pair = exp::paired_prefill(&ctx()).unwrap();
    // Paper: 107.3 vs 39.1 MiB (2.72x). Calibrated reproduction: 95.5 vs
    // 41.5 (2.30x) — assert the shape with generous bands.
    let mha = pair.mha.result.peak_needed() as f64 / MIB as f64;
    let gqa = pair.gqa.result.peak_needed() as f64 / MIB as f64;
    assert!((80.0..=120.0).contains(&mha), "MHA peak {mha} MiB");
    assert!((30.0..=50.0).contains(&gqa), "GQA peak {gqa} MiB");
    assert!(pair.peak_ratio() > 2.0, "peak ratio {}", pair.peak_ratio());
    // Both fit the 128 MiB baseline without capacity write-backs.
    assert!(pair.mha.result.feasible());
    assert!(pair.gqa.result.feasible());
}

#[test]
fn fig5_time_gap() {
    let pair = exp::paired_prefill(&ctx()).unwrap();
    // Paper: 593.9 vs 313.6 ms (1.89x); ours: 320.6 vs 208.2 (1.54x).
    assert!(
        pair.time_ratio() > 1.3,
        "GQA must be substantially faster: {}",
        pair.time_ratio()
    );
    let mha_ms = pair.mha.result.seconds() * 1e3;
    let gqa_ms = pair.gqa.result.seconds() * 1e3;
    assert!((200.0..=700.0).contains(&mha_ms), "{mha_ms} ms");
    assert!((150.0..=400.0).contains(&gqa_ms), "{gqa_ms} ms");
}

#[test]
fn fig7_utilization_and_energy_order() {
    let pair = exp::paired_prefill(&ctx()).unwrap();
    // GQA runs closer to compute capability (paper 77% vs 38%).
    assert!(
        pair.gqa.result.active_utilization()
            > pair.mha.result.active_utilization()
    );
    // And consumes less on-chip energy (paper 40.52 vs 78.47 J).
    assert!(pair.gqa.energy.on_chip_j() < pair.mha.energy.on_chip_j());
    // Magnitudes in the paper's regime (tens of joules).
    let e = pair.mha.energy.on_chip_j();
    assert!((30.0..=120.0).contains(&e), "MHA on-chip {e} J");
}

#[test]
fn sizing_matches_paper_capacities() {
    let s = exp::sizing(&ctx()).unwrap();
    // Paper: GPT-2 XL -> 112 MiB, DS -> 48 MiB (16 MiB rounding).
    assert_eq!(s.gqa_required, 48 * MIB, "DS required capacity");
    assert!(
        s.mha_required >= 96 * MIB && s.mha_required <= 112 * MIB,
        "GPT-2 required {} MiB",
        s.mha_required / MIB
    );
    // DS at 64 MiB: negligible latency change (paper: -1.48 ms).
    assert!(s.gqa_64mib_delta_s.abs() < 0.01, "{}", s.gqa_64mib_delta_s);
}

#[test]
fn table2_banking_reduces_energy_with_sweet_spot() {
    let c = ctx();
    let pair = exp::paired_prefill(&c).unwrap();
    let t2 = exp::table2(&c, &pair).unwrap();
    // Best bank count lands in the interior (paper: B in {8,16}).
    for cap in [64 * MIB, 96 * MIB, 128 * MIB] {
        let best = exp::Table2::best_banks_at(&t2.gqa_points, cap).unwrap();
        assert!(
            (2..=16).contains(&best),
            "GQA best banks at {} MiB: {best}",
            cap / MIB
        );
    }
    // DS reductions grow with capacity headroom (paper: -30.4% .. -61.3%).
    let best_at = |cap: u64| {
        t2.gqa_points
            .iter()
            .filter(|p| p.eval.capacity == cap)
            .map(|p| p.delta_e_pct())
            .fold(f64::INFINITY, f64::min)
    };
    let d48 = best_at(48 * MIB);
    let d128 = best_at(128 * MIB);
    assert!(d128 < d48, "more headroom must help: {d48} vs {d128}");
    assert!(d128 < -45.0, "DS@128 best {d128}%");
    // GQA benefits more than MHA at matched capacity (paper's claim).
    let mha_d128 = t2
        .mha_points
        .iter()
        .filter(|p| p.eval.capacity == 128 * MIB)
        .map(|p| p.delta_e_pct())
        .fold(f64::INFINITY, f64::min);
    assert!(
        d128 <= mha_d128 + 1.0,
        "GQA {d128}% should beat MHA {mha_d128}%"
    );
}

#[test]
fn fig8_alpha_monotonicity_at_full_scale() {
    let c = ctx();
    let pair = exp::paired_prefill(&c).unwrap();
    let f8 = exp::fig8(&pair.gqa);
    let avgs: Vec<f64> = f8
        .timelines
        .iter()
        .map(|t| trapti::banking::avg_active(t))
        .collect();
    // alphas = [1.0, 0.9, 0.75, 0.5]: average active banks must not
    // decrease as alpha falls.
    for w in avgs.windows(2) {
        assert!(w[1] >= w[0] - 1e-9, "{avgs:?}");
    }
    // At B=4 / 64 MiB the DS trace must leave gate-eligible time.
    assert!(avgs[1] < 4.0, "some banks must be idle at alpha=0.9");
}

#[test]
fn table3_multilevel_headline() {
    let t3 = exp::table3(&ctx()).unwrap();
    // Paper: multi-level run is slower & hungrier than single-level
    // (550 ms, 73.4 J) with per-memory peaks near 34-38 MiB.
    let ms = t3.stage1.result.seconds() * 1e3;
    assert!((300.0..=700.0).contains(&ms), "{ms} ms");
    assert!(t3.stage1.result.feasible(), "64 MiB DMs must suffice");
    for tr in &t3.stage1.result.traces[1..] {
        let peak = tr.peak_needed() as f64 / MIB as f64;
        assert!((10.0..=60.0).contains(&peak), "{}: {peak} MiB", tr.memory);
    }
    // The headline: up to ~78% SRAM energy reduction (ours overshoots on
    // the staging-only shared SRAM; DMs land in the paper's band).
    assert!(t3.best_delta() < -70.0, "best dE {}", t3.best_delta());
}

#[test]
fn switching_overhead_negligible() {
    // Paper §IV-C: "switching overhead had a negligible impact".
    let c = ctx();
    let pair = exp::paired_prefill(&c).unwrap();
    let ev = evaluate(
        &c.cacti,
        pair.gqa.trace(),
        &pair.gqa.result.stats,
        128 * MIB,
        16,
        0.9,
        GatingPolicy::Aggressive,
        1.0,
    ).unwrap();
    assert!(
        ev.e_sw_j < 0.01 * ev.e_total_j(),
        "switching {} J vs total {} J",
        ev.e_sw_j,
        ev.e_total_j()
    );
}

#[test]
fn trace_reuse_equals_inline_stage2() {
    // The two-stage decoupling: Stage II over a saved+reloaded trace
    // must give identical numbers to the inline evaluation.
    let c = ctx();
    let s1 = ExperimentSpec::builder()
        .model(trapti::workload::DS_R1D_Q15B)
        .prefill(2048)
        .build()
        .unwrap()
        .run_stage1(&c)
        .unwrap();
    let dir = std::env::temp_dir().join("trapti-trace-roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ds.trace.json");
    trapti::trace::save_trace(s1.trace(), &path).unwrap();
    let reloaded = trapti::trace::load_trace(&path).unwrap();
    let spec = s1.paper_sweep();
    let inline = trapti::banking::sweep(
        &c.cacti, s1.trace(), &s1.result.stats, &spec, 1.0,
    )
    .unwrap();
    let from_file =
        trapti::banking::sweep(&c.cacti, &reloaded, &s1.result.stats, &spec, 1.0)
            .unwrap();
    assert_eq!(inline.len(), from_file.len());
    for (a, b) in inline.iter().zip(&from_file) {
        assert!((a.eval.e_total_j() - b.eval.e_total_j()).abs() < 1e-12);
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn aggregate_baseline_cannot_see_gating_opportunities() {
    // The gap-and-motivation claim, measured at full scale.
    let c = ctx();
    let pair = exp::paired_prefill(&c).unwrap();
    let s1 = &pair.gqa;
    let view = trapti::analytic::AggregateView::from_stats(
        s1.result.peak_needed(),
        s1.result.total_cycles,
        &s1.result.stats,
    );
    let agg = trapti::analytic::estimate(&c.cacti, &view, 128 * MIB, 16, 0.9, 1.0);
    let trapti_ev = evaluate(
        &c.cacti,
        s1.trace(),
        &s1.result.stats,
        128 * MIB,
        16,
        0.9,
        GatingPolicy::Aggressive,
        1.0,
    ).unwrap();
    assert!(
        trapti_ev.e_leak_j < agg.e_leak_j,
        "time resolution must beat peak-pinned leakage: {} vs {}",
        trapti_ev.e_leak_j,
        agg.e_leak_j
    );
}
