//! Differential suite for the fused Stage-II sweep engine: the fused
//! single-pass path (`banking::sweep`, `SweepSink`, `serve_fused`,
//! `stream_stage2`) must be indistinguishable from the per-point naive
//! oracle (`banking::sweep_naive`) on every workload type — prefill,
//! decode, and serving — plus a property check that the fused activity
//! integral equals `avg_active(bank_activity(...))` per candidate.

use trapti::api::{ApiContext, ExperimentSpec};
use trapti::banking::{
    avg_active, bank_activity, sweep, sweep_naive, GatingPolicy, OccupancyBasis,
    SweepPoint, SweepSpec,
};
use trapti::serving::ServingParams;
use trapti::trace::{AccessStats, OccupancyTrace};
use trapti::util::proptest::check;
use trapti::util::rng::Rng;
use trapti::util::MIB;
use trapti::workload::TINY_GQA;

/// Every `SweepPoint` field within 1e-12 (energies are bit-identical in
/// practice; the tolerance is the acceptance bound), with
/// `n_switch`/`gated_fraction` exact.
fn assert_points_match(fused: &[SweepPoint], naive: &[SweepPoint]) {
    assert_eq!(fused.len(), naive.len(), "point count");
    for (f, n) in fused.iter().zip(naive) {
        let at = format!(
            "C={} B={} alpha={} {:?}",
            n.eval.capacity, n.eval.banks, n.eval.alpha, n.eval.policy
        );
        assert_eq!(f.eval.capacity, n.eval.capacity, "{at}");
        assert_eq!(f.eval.banks, n.eval.banks, "{at}");
        assert_eq!(f.eval.alpha.to_bits(), n.eval.alpha.to_bits(), "{at}");
        assert_eq!(f.eval.policy, n.eval.policy, "{at}");
        // Exact integer / bookkeeping fields.
        assert_eq!(f.eval.n_switch, n.eval.n_switch, "{at}");
        assert_eq!(
            f.eval.gated_fraction.to_bits(),
            n.eval.gated_fraction.to_bits(),
            "{at}"
        );
        assert_eq!(f.eval.latency_cycles, n.eval.latency_cycles, "{at}");
        // Float fields within 1e-12 (absolute or relative).
        for (a, b, what) in [
            (f.eval.e_dyn_j, n.eval.e_dyn_j, "e_dyn"),
            (f.eval.e_leak_j, n.eval.e_leak_j, "e_leak"),
            (f.eval.e_sw_j, n.eval.e_sw_j, "e_sw"),
            (f.eval.avg_active_banks, n.eval.avg_active_banks, "avg_act"),
            (f.eval.area_mm2, n.eval.area_mm2, "area"),
            (f.base_e_j, n.base_e_j, "base_e"),
            (f.base_area_mm2, n.base_area_mm2, "base_area"),
            (f.delta_e_pct(), n.delta_e_pct(), "dE%"),
            (f.delta_a_pct(), n.delta_a_pct(), "dA%"),
        ] {
            let tol = 1e-12 * b.abs().max(1.0);
            assert!((a - b).abs() <= tol, "{what} {a} vs {b} at {at}");
        }
    }
}

fn rich_grid(capacities: Vec<u64>) -> SweepSpec {
    SweepSpec {
        capacities,
        banks: vec![1, 2, 4, 8, 16, 32],
        alphas: vec![0.9, 1.0],
        policies: vec![
            GatingPolicy::None,
            GatingPolicy::Aggressive,
            GatingPolicy::conservative(),
            GatingPolicy::drowsy(),
        ],
    }
}

#[test]
fn sweep_fused_matches_naive_on_prefill_trace() {
    let ctx = ApiContext::new();
    let s1 = ExperimentSpec::builder()
        .model(TINY_GQA)
        .prefill(96)
        .accel(trapti::config::tiny())
        .build()
        .unwrap()
        .run_stage1(&ctx)
        .unwrap();
    let grid = rich_grid(vec![2 * MIB, 4 * MIB, 8 * MIB]);
    let fused = sweep(&ctx.cacti, s1.trace(), &s1.result.stats, &grid, 1.0).unwrap();
    let naive = sweep_naive(&ctx.cacti, s1.trace(), &s1.result.stats, &grid, 1.0).unwrap();
    assert!(!fused.is_empty());
    assert_points_match(&fused, &naive);
}

#[test]
fn sweep_fused_matches_naive_on_decode_trace() {
    let ctx = ApiContext::new();
    let s1 = ExperimentSpec::builder()
        .model(TINY_GQA)
        .decode(48, 24)
        .accel(trapti::config::tiny())
        .build()
        .unwrap()
        .run_stage1(&ctx)
        .unwrap();
    let grid = rich_grid(vec![MIB, 2 * MIB, 4 * MIB]);
    let fused = sweep(&ctx.cacti, s1.trace(), &s1.result.stats, &grid, 1.0).unwrap();
    let naive = sweep_naive(&ctx.cacti, s1.trace(), &s1.result.stats, &grid, 1.0).unwrap();
    assert!(!fused.is_empty());
    assert_points_match(&fused, &naive);
}

#[test]
fn sweep_fused_matches_naive_on_serving_trace() {
    let ctx = ApiContext::new();
    let mut p = ServingParams::new(48, 6, 11);
    p.prompt_min = 4;
    p.prompt_max = 48;
    p.gen_min = 2;
    p.gen_max = 24;
    p.page_tokens = 8;
    p.mean_arrival_gap = 40_000;
    let spec = ExperimentSpec::builder()
        .model(TINY_GQA)
        .serving(p)
        .accel(trapti::config::tiny())
        .build()
        .unwrap();
    let run = spec.run_serving().unwrap();
    // Capacity axis straddles the peak so the infeasibility filter is
    // exercised on both sides.
    let peak = run.trace().peak_needed();
    let grid = rich_grid(vec![
        (peak / 2).max(1),
        peak.max(1),
        peak * 2,
        peak * 4,
    ]);
    let fused = sweep(&ctx.cacti, run.trace(), &run.result.stats, &grid, 1.0).unwrap();
    let naive = sweep_naive(&ctx.cacti, run.trace(), &run.result.stats, &grid, 1.0).unwrap();
    assert!(!fused.is_empty());
    assert_points_match(&fused, &naive);

    // And the end-to-end fused serving path (simulation streaming into
    // the sweep sink, no materialized trace) agrees with Stage II over
    // the materialized trace on the same grid.
    let sweep_grid = run.serving_grid();
    let reference = run.stage2_with(&ctx, &sweep_grid).unwrap();
    let (fused_run, fused_sweep) = spec.serve_fused_with(&ctx, &sweep_grid).unwrap();
    assert_eq!(fused_run.result.total_cycles, run.result.total_cycles);
    assert_points_match(&fused_sweep.points, &reference.points);
}

#[test]
fn stream_stage2_is_fused_stage1_plus_stage2() {
    let ctx = ApiContext::new();
    let grid = rich_grid(vec![2 * MIB, 4 * MIB]);
    let spec = ExperimentSpec::builder()
        .model(TINY_GQA)
        .prefill(64)
        .accel(trapti::config::tiny())
        .sweep(grid.clone())
        .build()
        .unwrap();
    let s1 = spec.run_stage1(&ctx).unwrap();
    let reference = s1.stage2_with(&ctx, &grid).unwrap();
    let (summary, points) = spec.stream_stage2(&ctx).unwrap();
    assert_eq!(summary.total_cycles(), s1.result.total_cycles);
    assert_points_match(&points, reference.shared());
}

/// Property: the fused engine's per-candidate activity integral equals
/// `avg_active(bank_activity(trace, ...))` for every candidate, on
/// randomized traces (the integral is reported as
/// `eval.avg_active_banks`).
#[test]
fn prop_fused_activity_integral_matches_bank_activity() {
    let ctx = ApiContext::new();
    check("fused-activity-integral", 60, |rng: &mut Rng| {
        let cap = rng.range(1, 48) * MIB;
        let mut tr = OccupancyTrace::new("m", cap);
        let mut t = 0u64;
        for _ in 0..rng.range(1, 80) {
            t += rng.range(1, 5_000);
            let needed = if rng.below(5) == 0 { 0 } else { rng.below(cap + 1) };
            tr.record(t, needed, 0);
        }
        tr.finalize(t + rng.range(1, 1_000));

        let grid = SweepSpec {
            capacities: vec![tr.peak_needed().max(1), tr.peak_needed().max(1) * 2],
            banks: vec![1, 4, 8, 32],
            alphas: vec![0.05 + rng.f64() * 0.95],
            policies: vec![GatingPolicy::Aggressive],
        };
        let stats = AccessStats::default();
        let pts = sweep(&ctx.cacti, &tr, &stats, &grid, 1.0).unwrap();
        assert_eq!(pts.len(), grid.points());
        for p in &pts {
            let timeline = bank_activity(
                &tr,
                p.eval.capacity,
                p.eval.banks,
                p.eval.alpha,
                OccupancyBasis::NeededOnly,
            );
            let want = avg_active(&timeline);
            assert_eq!(
                p.eval.avg_active_banks.to_bits(),
                want.to_bits(),
                "activity integral at C={} B={} alpha={}: {} vs {}",
                p.eval.capacity,
                p.eval.banks,
                p.eval.alpha,
                p.eval.avg_active_banks,
                want
            );
        }
    });
}
