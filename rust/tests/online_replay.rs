//! Integration suite for the Stage-III online gating co-simulation.
//!
//! The acceptance property: with wake latency forced to 0, the online
//! replay's energy is **bit-identical** to the offline
//! `banking::evaluate` of the same configuration — on prefill, decode,
//! AND serving traces. Plus: stall monotonicity in the replayed wake
//! latency, determinism of the streamed path (what the CI `repro
//! replay` gate compares), and timeline integrity.

use trapti::api::{ApiContext, ExperimentSpec, MaterializedRun};
use trapti::banking::{
    evaluate, replay_trace, BankState, GatingPolicy, OnlineConfig,
};
use trapti::serving::ServingParams;
use trapti::workload::{TINY_GQA, TINY_MHA};

fn ctx() -> ApiContext {
    ApiContext::new()
}

fn prefill_spec() -> ExperimentSpec {
    ExperimentSpec::builder()
        .model(TINY_MHA)
        .prefill(64)
        .accel(trapti::config::tiny())
        .build()
        .unwrap()
}

fn decode_spec() -> ExperimentSpec {
    ExperimentSpec::builder()
        .model(TINY_GQA)
        .decode(32, 16)
        .accel(trapti::config::tiny())
        .build()
        .unwrap()
}

fn serving_spec() -> ExperimentSpec {
    let mut p = ServingParams::new(16, 4, 7);
    p.prompt_min = 4;
    p.prompt_max = 32;
    p.gen_min = 2;
    p.gen_max = 16;
    p.page_tokens = 8;
    p.mean_arrival_gap = 50_000;
    ExperimentSpec::builder()
        .model(TINY_GQA)
        .serving(p)
        .accel(trapti::config::tiny())
        .build()
        .unwrap()
}

/// Materialize any workload kind via the shared api helper (the same
/// path the production validation pass uses).
fn materialize(spec: &ExperimentSpec) -> MaterializedRun {
    spec.materialize(&ctx()).unwrap()
}

fn policies() -> [GatingPolicy; 4] {
    [
        GatingPolicy::None,
        GatingPolicy::Aggressive,
        GatingPolicy::conservative(),
        GatingPolicy::drowsy(),
    ]
}

/// The ISSUE acceptance property: zero-wake reconciliation holds
/// bit-for-bit on prefill, decode, and serving traces, across every
/// policy and several bank counts.
#[test]
fn zero_wake_reconciles_on_prefill_decode_and_serving() {
    let ctx = ctx();
    for (label, spec) in [
        ("prefill", prefill_spec()),
        ("decode", decode_spec()),
        ("serving", serving_spec()),
    ] {
        let run = materialize(&spec);
        let freq = spec.freq_ghz();
        // Capacity covering the trace (its declared capacity always
        // covers the peak), so every config is feasible.
        let capacity = run.trace().capacity;
        for policy in policies() {
            for banks in [1u32, 8, 32] {
                let mut cfg = OnlineConfig::new(capacity, banks, 0.9, policy);
                cfg.wake_override = Some(0);
                let online =
                    replay_trace(&ctx.cacti, run.trace(), run.stats(), cfg, freq)
                        .unwrap();
                let offline = evaluate(
                    &ctx.cacti, run.trace(), run.stats(), capacity, banks, 0.9,
                    policy, freq,
                )
                .unwrap();
                assert_eq!(online.stall_cycles, 0, "{label}/{policy:?}/B{banks}");
                assert_eq!(
                    online.eval.e_total_j().to_bits(),
                    offline.e_total_j().to_bits(),
                    "{label}/{policy:?}/B{banks}: E_total must be bit-identical"
                );
                assert_eq!(
                    online.eval.e_leak_j.to_bits(),
                    offline.e_leak_j.to_bits(),
                    "{label}/{policy:?}/B{banks}"
                );
                assert_eq!(
                    online.eval.e_sw_j.to_bits(),
                    offline.e_sw_j.to_bits(),
                    "{label}/{policy:?}/B{banks}"
                );
                assert_eq!(online.eval.n_switch, offline.n_switch);
                assert_eq!(
                    online.eval.avg_active_banks.to_bits(),
                    offline.avg_active_banks.to_bits()
                );
                assert_eq!(
                    online.eval.gated_fraction.to_bits(),
                    offline.gated_fraction.to_bits()
                );
            }
        }
    }
}

/// Stall monotonicity: raising the replayed wake latency never reduces
/// the total stall (the gate schedule can only gate more as observed
/// idle runs stretch, and each wake costs more).
#[test]
fn stall_is_monotone_in_wake_latency_on_real_traces() {
    let ctx = ctx();
    for spec in [decode_spec(), serving_spec()] {
        let run = materialize(&spec);
        let freq = spec.freq_ghz();
        let capacity = run.trace().capacity;
        for policy in [GatingPolicy::Aggressive, GatingPolicy::drowsy()] {
            let mut prev = 0u64;
            for wake in [0u64, 1, 10, 100, 1_000, 10_000] {
                let mut cfg = OnlineConfig::new(capacity, 8, 0.9, policy);
                cfg.wake_override = Some(wake);
                let r = replay_trace(&ctx.cacti, run.trace(), run.stats(), cfg, freq)
                    .unwrap();
                assert_eq!(r.stall_cycles, r.wake_events * wake, "{policy:?}");
                assert!(
                    r.stall_cycles >= prev,
                    "{policy:?}: stall {} < {prev} at wake={wake}",
                    r.stall_cycles
                );
                assert_eq!(r.end_cycles(), r.trace_cycles + r.stall_cycles);
                prev = r.stall_cycles;
            }
        }
    }
}

/// Determinism (the CI `repro replay` gate's in-process equivalent):
/// two streamed replays produce byte-identical timeline CSVs and
/// bit-identical energies, and the streamed path agrees with the
/// materialized replay.
#[test]
fn streamed_replay_is_deterministic_and_matches_materialized() {
    let ctx = ctx();
    let spec = decode_spec();
    let run = materialize(&spec);
    let cfg = OnlineConfig::new(run.trace().capacity, 8, 0.9, GatingPolicy::Aggressive);

    let (_, a) = spec.stream_online(&ctx, cfg).unwrap();
    let (_, b) = spec.stream_online(&ctx, cfg).unwrap();
    assert_eq!(a.timeline_csv(), b.timeline_csv(), "replay must be deterministic");
    assert_eq!(a.eval.e_total_j().to_bits(), b.eval.e_total_j().to_bits());
    assert_eq!(a.stall_cycles, b.stall_cycles);

    let materialized =
        replay_trace(&ctx.cacti, run.trace(), run.stats(), cfg, spec.freq_ghz())
            .unwrap();
    assert_eq!(a.timeline_csv(), materialized.timeline_csv());
    assert_eq!(
        a.eval.e_total_j().to_bits(),
        materialized.eval.e_total_j().to_bits()
    );

    // Serving twin: serve_online is deterministic too.
    let sspec = serving_spec();
    let scfg = OnlineConfig::new(
        sspec.serving_arena_grid().unwrap().capacities[0],
        8,
        0.9,
        GatingPolicy::Aggressive,
    );
    let (_, sa) = sspec.serve_online(&ctx, scfg).unwrap();
    let (_, sb) = sspec.serve_online(&ctx, scfg).unwrap();
    assert_eq!(sa.timeline_csv(), sb.timeline_csv());
    assert_eq!(sa.eval.e_total_j().to_bits(), sb.eval.e_total_j().to_bits());
}

/// Timeline integrity on real traces: every bank's spans tile
/// `[0, end_cycles)` with no gaps or overlaps, waking time equals
/// `wake_events`-consistent stall accounting, and states respect the
/// policy (no Gated spans under drowsy, no Drowsy spans under
/// aggressive, neither under `None`).
#[test]
fn timelines_are_gapless_and_policy_consistent() {
    let ctx = ctx();
    let spec = decode_spec();
    let run = materialize(&spec);
    let capacity = run.trace().capacity;
    for policy in policies() {
        let cfg = OnlineConfig::new(capacity, 8, 0.9, policy);
        let r = replay_trace(&ctx.cacti, run.trace(), run.stats(), cfg, spec.freq_ghz())
            .unwrap();
        assert_eq!(r.timelines.len(), 8);
        for (b, spans) in r.timelines.iter().enumerate() {
            let mut t = 0u64;
            for s in spans {
                assert_eq!(s.t0, t, "{policy:?} bank {b}: gap before {s:?}");
                assert!(s.t1 > s.t0);
                match s.state {
                    BankState::Gated => assert!(
                        !matches!(policy, GatingPolicy::Drowsy { .. } | GatingPolicy::None),
                        "{policy:?} bank {b} gated"
                    ),
                    BankState::Drowsy => assert!(
                        matches!(policy, GatingPolicy::Drowsy { .. }),
                        "{policy:?} bank {b} drowsy"
                    ),
                    BankState::Waking => assert!(
                        !matches!(policy, GatingPolicy::None),
                        "{policy:?} bank {b} waking"
                    ),
                    _ => {}
                }
                t = s.t1;
            }
            assert_eq!(t, r.end_cycles(), "{policy:?} bank {b} must reach the end");
        }
    }
}

/// The portfolio validation pass reconciles with direct replays: each
/// row's observed energy equals a hand replay of the same config, and
/// the zero-wake invariant implies observed == predicted when the
/// frontier config never gates.
#[test]
fn online_validate_rows_match_direct_replays() {
    use trapti::api::PortfolioOptions;
    use trapti::banking::SweepSpec;
    use trapti::util::MIB;
    let ctx = ctx();
    let specs = vec![decode_spec(), serving_spec()];
    let grid = SweepSpec {
        capacities: vec![2 * MIB, 4 * MIB, 8 * MIB],
        banks: vec![1, 2, 4, 8],
        alphas: vec![0.9],
        policies: vec![GatingPolicy::Aggressive, GatingPolicy::drowsy()],
    };
    let run = trapti::api::run_portfolio(
        &ctx,
        &specs,
        &PortfolioOptions {
            grid: Some(grid),
            ..Default::default()
        },
    )
    .unwrap();
    let vals = trapti::api::online_validate(&ctx, &specs, &run).unwrap();
    assert!(!vals.is_empty());
    for (spec, frontier) in specs.iter().zip(&run.result.frontiers) {
        let mat = materialize(spec);
        for v in vals.iter().filter(|v| v.workload == frontier.workload) {
            let cfg = OnlineConfig::new(
                v.key.capacity,
                v.key.banks,
                v.key.alpha(),
                v.key.policy(),
            );
            let direct = trapti::banking::replay_trace_with(
                &ctx.cacti,
                mat.trace(),
                mat.stats(),
                cfg,
                spec.freq_ghz(),
                false,
            )
            .unwrap();
            assert_eq!(
                v.observed_e_j.to_bits(),
                direct.eval.e_total_j().to_bits(),
                "{}/{}",
                v.workload,
                v.key.label()
            );
            assert_eq!(v.stall_cycles, direct.stall_cycles);
            if v.wake_events == 0 {
                // Nothing gated -> no stalls -> online == offline exactly.
                assert_eq!(v.observed_e_j.to_bits(), v.predicted_e_j.to_bits());
            }
        }
    }
}
