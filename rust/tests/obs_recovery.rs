//! Crash-recovery and format-stability tests for the WAL.
//!
//! * Torn-tail sweep: truncate a known log at **every** byte offset and
//!   check the reader recovers exactly the longest valid record prefix
//!   (and that `replay_wal` still yields finalize-able traces from it).
//! * Golden byte pins: the 28-byte segment header and the Prometheus
//!   text exposition are on-disk/exported formats — external tooling
//!   (the CI determinism gate's `tail -c +29`, scrapers) depends on
//!   their exact bytes, so they are pinned literally here. If one of
//!   these tests fails, you are changing a serialization format: bump
//!   `WAL_VERSION` / update the consumers, then re-pin.

use std::fs;
use std::path::PathBuf;

use trapti::obs::wal::{ACTIVE_SEGMENT, WAL_HEADER_LEN, WAL_VERSION};
use trapti::obs::{
    replay_wal, EventLog, MetricsSnapshot, ObsError, WalHeader, WalSink,
};
use trapti::trace::sink::{MemoryDesc, RunEvent, TraceSink};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "trapti-obs-recovery-{tag}-{}",
        std::process::id()
    ));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// Write a small, fully known log (6 records) and return the bytes of
/// its single sealed segment.
fn known_log(dir: &PathBuf) -> Vec<u8> {
    let mut sink = WalSink::create(dir, 0xABCD, 0).unwrap();
    sink.begin(&[MemoryDesc { name: "sram".into(), capacity: 4096 }]);
    sink.on_event(0, &RunEvent::StageStart { stage: 0 });
    sink.on_sample(0, 4, 640, 0);
    sink.on_sample(0, 9, 512, 64);
    sink.on_event(11, &RunEvent::StageEnd { stage: 0 });
    sink.finish(16);
    sink.close(None).unwrap();
    fs::read(dir.join("000000.seg")).unwrap()
}

/// Byte offsets that end a complete frame (including `WAL_HEADER_LEN`,
/// the zero-record boundary), parsed straight from the framing.
fn frame_boundaries(bytes: &[u8]) -> Vec<usize> {
    let mut cuts = vec![WAL_HEADER_LEN];
    let mut off = WAL_HEADER_LEN;
    while off < bytes.len() {
        let len =
            u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        off += 4 + len + 8;
        cuts.push(off);
    }
    cuts
}

#[test]
fn every_truncation_point_recovers_the_longest_valid_prefix() {
    let src = tmp_dir("trunc-src");
    let bytes = known_log(&src);
    let full = EventLog::open(&src).unwrap();
    assert_eq!(full.records.len(), 6);
    assert!(full.complete());

    let boundaries = frame_boundaries(&bytes);
    assert_eq!(*boundaries.last().unwrap(), bytes.len(), "framing parses");

    let scratch = tmp_dir("trunc-scratch");
    fs::create_dir_all(&scratch).unwrap();
    let seg = scratch.join(ACTIVE_SEGMENT);
    for cut in 0..=bytes.len() {
        fs::write(&seg, &bytes[..cut]).unwrap();
        let log = EventLog::open(&scratch).unwrap();
        if cut < WAL_HEADER_LEN {
            // Not even a header survived: empty log, flagged torn.
            assert!(log.truncated, "cut {cut}");
            assert!(log.header.is_none(), "cut {cut}");
            assert!(log.records.is_empty(), "cut {cut}");
            continue;
        }
        let k = boundaries.iter().filter(|b| **b <= cut).count() - 1;
        assert_eq!(
            log.records,
            full.records[..k],
            "cut {cut}: longest valid prefix has {k} records"
        );
        assert_eq!(
            log.truncated,
            !boundaries.contains(&cut),
            "cut {cut}: torn iff mid-frame"
        );
        assert_eq!(log.header, full.header, "header survives any body cut");

        // Whatever survived must still replay into finalized traces.
        match replay_wal(&scratch) {
            Ok(replay) => {
                assert!(k >= 1, "replay needs RunStart (cut {cut})");
                assert_eq!(replay.run_id, 0xABCD);
                assert_eq!(replay.complete, k == full.records.len());
                assert_eq!(replay.traces.len(), 1);
                replay.traces[0].validate().unwrap();
                assert_eq!(replay.traces[0].end_time(), Some(replay.end));
            }
            Err(ObsError::Incomplete(_)) => {
                assert_eq!(k, 0, "only a RunStart-less log refuses replay");
            }
            Err(e) => panic!("cut {cut}: unexpected error {e}"),
        }
    }
    let _ = fs::remove_dir_all(&src);
    let _ = fs::remove_dir_all(&scratch);
}

#[test]
fn truncated_log_surfaces_in_metrics_flags() {
    let src = tmp_dir("trunc-metrics-src");
    let bytes = known_log(&src);
    let scratch = tmp_dir("trunc-metrics");
    fs::create_dir_all(&scratch).unwrap();
    // Cut mid-way through the final (RunEnd) frame.
    fs::write(scratch.join(ACTIVE_SEGMENT), &bytes[..bytes.len() - 3]).unwrap();

    let log = EventLog::open(&scratch).unwrap();
    let m = MetricsSnapshot::from_log(&log);
    assert!(!m.complete);
    assert!(m.truncated);
    let text = m.render();
    assert!(text.contains("trapti_run_complete 0"));
    assert!(text.contains("trapti_log_truncated 1"));
    let _ = fs::remove_dir_all(&src);
    let _ = fs::remove_dir_all(&scratch);
}

/// The 28-byte segment header, pinned byte for byte. The CI determinism
/// gate strips exactly this much (`tail -c +29`) before comparing runs;
/// changing any offset here breaks that contract.
#[test]
fn segment_header_bytes_are_pinned() {
    #[rustfmt::skip]
    const GOLDEN: [u8; 28] = [
        // magic "TWAL"
        0x54, 0x57, 0x41, 0x4C,
        // version = 1 (u32 LE)
        0x01, 0x00, 0x00, 0x00,
        // run id = 0x0123456789ABCDEF (u64 LE)
        0xEF, 0xCD, 0xAB, 0x89, 0x67, 0x45, 0x23, 0x01,
        // segment index = 0 (u32 LE)
        0x00, 0x00, 0x00, 0x00,
        // wall clock = 1000 unix ms (u64 LE)
        0xE8, 0x03, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
    ];
    assert_eq!(GOLDEN.len(), WAL_HEADER_LEN);

    let header = WalHeader {
        version: WAL_VERSION,
        run_id: 0x0123_4567_89AB_CDEF,
        segment: 0,
        wall_unix_ms: 1000,
    };
    assert_eq!(header.encode(), GOLDEN);
    assert_eq!(WalHeader::decode(&GOLDEN), Some(header));

    // And the writer puts exactly these bytes at the front of segment 0.
    let dir = tmp_dir("header-pin");
    let sink = WalSink::create(&dir, 0x0123_4567_89AB_CDEF, 1000).unwrap();
    let bytes = fs::read(dir.join(ACTIVE_SEGMENT)).unwrap();
    assert_eq!(&bytes[..WAL_HEADER_LEN], &GOLDEN);
    drop(sink);
    let _ = fs::remove_dir_all(&dir);
}

/// Two identical runs stamped with different wall clocks must differ in
/// nothing but the header — the exact assumption behind the CI gate's
/// `tail -c +29 | cmp`.
#[test]
fn wall_clock_only_ever_touches_the_header() {
    let write = |dir: &PathBuf, wall: u64| {
        let mut sink = WalSink::create(dir, 0x5EED, wall).unwrap();
        sink.begin(&[MemoryDesc { name: "sram".into(), capacity: 1 << 20 }]);
        sink.on_sample(0, 3, 999, 0);
        sink.on_event(5, &RunEvent::Admit { request: 0 });
        sink.finish(9);
        sink.close(None).unwrap();
        fs::read(dir.join("000000.seg")).unwrap()
    };
    let dir_a = tmp_dir("wall-a");
    let dir_b = tmp_dir("wall-b");
    let a = write(&dir_a, 0);
    let b = write(&dir_b, 1_700_000_000_000);
    assert_ne!(a[..WAL_HEADER_LEN], b[..WAL_HEADER_LEN]);
    assert_eq!(a[WAL_HEADER_LEN..], b[WAL_HEADER_LEN..], "bodies identical");
    let _ = fs::remove_dir_all(&dir_a);
    let _ = fs::remove_dir_all(&dir_b);
}

/// The Prometheus exposition, pinned literally: scrapers parse this
/// text, so metric names, label spelling, and ordering are a contract.
#[test]
fn prometheus_exposition_is_pinned() {
    let dir = tmp_dir("prom-pin");
    let mut sink = WalSink::create(&dir, 42, 0).unwrap();
    sink.begin(&[
        MemoryDesc { name: "sram".into(), capacity: 1024 },
        MemoryDesc { name: "kv".into(), capacity: 512 },
    ]);
    sink.on_event(0, &RunEvent::StageStart { stage: 0 });
    sink.on_sample(0, 5, 100, 28);
    sink.on_sample(1, 9, 64, 0);
    sink.on_event(12, &RunEvent::StageEnd { stage: 0 });
    sink.finish(20);
    sink.append_event(
        20,
        &RunEvent::BankSpan { bank: 0, state: "gated", t0: 8, t1: 20 },
    );
    sink.append_event(
        20,
        &RunEvent::WakeStall { bank: 0, at: 8, stall_cycles: 2 },
    );
    sink.close(None).unwrap();

    let log = EventLog::open(&dir).unwrap();
    let rendered = MetricsSnapshot::from_log(&log).render();
    const GOLDEN: &str = "\
# HELP trapti_run_id Run identifier from the WAL header.
# TYPE trapti_run_id gauge
trapti_run_id 42
# HELP trapti_events_total WAL records folded into this snapshot.
# TYPE trapti_events_total counter
trapti_events_total 8
# HELP trapti_cycles Highest simulation cycle observed.
# TYPE trapti_cycles gauge
trapti_cycles 20
# HELP trapti_samples_total Occupancy samples observed.
# TYPE trapti_samples_total counter
trapti_samples_total 2
# HELP trapti_occupancy_bytes Current occupancy (needed+obsolete) per memory.
# TYPE trapti_occupancy_bytes gauge
trapti_occupancy_bytes{memory=\"sram\"} 128
trapti_occupancy_bytes{memory=\"kv\"} 64
# HELP trapti_occupancy_peak_bytes Peak occupancy per memory.
# TYPE trapti_occupancy_peak_bytes gauge
trapti_occupancy_peak_bytes{memory=\"sram\"} 128
trapti_occupancy_peak_bytes{memory=\"kv\"} 64
# HELP trapti_stages_started_total Dataflow stages entered.
# TYPE trapti_stages_started_total counter
trapti_stages_started_total 1
# HELP trapti_stages_completed_total Dataflow stages completed.
# TYPE trapti_stages_completed_total counter
trapti_stages_completed_total 1
# HELP trapti_requests_admitted_total Serving requests admitted.
# TYPE trapti_requests_admitted_total counter
trapti_requests_admitted_total 0
# HELP trapti_requests_completed_total Serving requests completed.
# TYPE trapti_requests_completed_total counter
trapti_requests_completed_total 0
# HELP trapti_bank_state_spans_total Stage-III bank state spans by state.
# TYPE trapti_bank_state_spans_total counter
trapti_bank_state_spans_total{state=\"gated\"} 1
# HELP trapti_bank_state_cycles_total Stage-III cycles spent per bank state.
# TYPE trapti_bank_state_cycles_total counter
trapti_bank_state_cycles_total{state=\"gated\"} 12
# HELP trapti_wake_stalls_total Stage-III wake-up stalls.
# TYPE trapti_wake_stalls_total counter
trapti_wake_stalls_total 1
# HELP trapti_wake_stall_cycles_total Cycles lost to wake-up stalls.
# TYPE trapti_wake_stall_cycles_total counter
trapti_wake_stall_cycles_total 2
# HELP trapti_run_complete 1 once RunEnd was observed.
# TYPE trapti_run_complete gauge
trapti_run_complete 1
# HELP trapti_log_truncated 1 when a torn tail was discarded on read.
# TYPE trapti_log_truncated gauge
trapti_log_truncated 0
";
    assert_eq!(rendered, GOLDEN);
    let _ = fs::remove_dir_all(&dir);
}
