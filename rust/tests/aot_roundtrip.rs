//! Cross-layer integration: AOT artifacts (L2 JAX + L1 Pallas, lowered
//! to HLO text) executed through the L3 PJRT runtime. Skips gracefully
//! when `make artifacts` has not been run.

use trapti::runtime::{default_artifact_dir, DecodeSession, Manifest, Runtime, Value};
use trapti::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping AOT tests: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(Manifest::load(&dir).unwrap()).unwrap())
}

#[test]
fn manifest_covers_all_expected_entries() {
    let Some(rt) = runtime() else { return };
    for name in [
        "decode_tiny_mha",
        "decode_tiny_gqa",
        "prefill_tiny_mha",
        "prefill_tiny_gqa",
        "attn_decode_gqa",
        "matmul_f32_128",
    ] {
        assert!(rt.manifest().entry(name).is_ok(), "missing {name}");
    }
}

#[test]
fn matmul_against_host_reference() {
    // The L1 tiled-matmul kernel (interpret-mode Pallas inside the HLO)
    // against a plain host-side triple loop.
    let Some(mut rt) = runtime() else { return };
    let n = 128usize;
    let mut rng = Rng::new(99);
    let mut x = vec![0f32; n * n];
    let mut w = vec![0f32; n * n];
    rng.fill_normal_f32(&mut x, 0.5);
    rng.fill_normal_f32(&mut w, 0.5);
    let out = rt
        .execute("matmul_f32_128", &[Value::F32(x.clone()), Value::F32(w.clone())])
        .unwrap();
    let got = out[0].as_f32().unwrap();
    // Spot-check 64 random entries (full n^3 host matmul is slow in CI).
    for _ in 0..64 {
        let i = rng.below(n as u64) as usize;
        let j = rng.below(n as u64) as usize;
        let mut acc = 0f64;
        for k in 0..n {
            acc += x[i * n + k] as f64 * w[k * n + j] as f64;
        }
        let g = got[i * n + j] as f64;
        assert!(
            (g - acc).abs() < 1e-3,
            "mismatch at ({i},{j}): {g} vs {acc}"
        );
    }
}

#[test]
fn attention_kernel_uniform_value_property() {
    // If all valid V rows are identical, attention output equals that
    // row regardless of scores — a kernel-level invariant exercised
    // through the full AOT pipeline.
    let Some(mut rt) = runtime() else { return };
    let (h, hkv, dh, s) = (4usize, 2usize, 32usize, 128usize);
    let mut rng = Rng::new(5);
    let mut q = vec![0f32; h * dh];
    let mut k = vec![0f32; s * hkv * dh];
    rng.fill_normal_f32(&mut q, 1.0);
    rng.fill_normal_f32(&mut k, 1.0);
    let mut v = vec![0f32; s * hkv * dh];
    for t in 0..s {
        for g in 0..hkv {
            for d in 0..dh {
                v[(t * hkv + g) * dh + d] = (g * dh + d) as f32 * 0.01;
            }
        }
    }
    let valid = 57;
    let mask: Vec<f32> = (0..s)
        .map(|t| if t < valid { 0.0 } else { -1e30 })
        .collect();
    let out = rt
        .execute(
            "attn_decode_gqa",
            &[Value::F32(q), Value::F32(k), Value::F32(v), Value::F32(mask)],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();
    let group = h / hkv;
    for head in 0..h {
        let g = head / group;
        for d in 0..dh {
            let want = (g * dh + d) as f32 * 0.01;
            let x = got[head * dh + d];
            assert!((x - want).abs() < 1e-4, "h{head} d{d}: {x} vs {want}");
        }
    }
}

#[test]
fn mha_and_gqa_decode_models_diverge() {
    // Same seed, same input: the two attention mechanisms must produce
    // different functions (sanity that the artifacts aren't mixed up).
    let Some(mut rt) = runtime() else { return };
    let x: Vec<f32> = (0..128).map(|i| ((i % 13) as f32 - 6.0) * 0.2).collect();
    let mut mha = DecodeSession::new(&mut rt, "tiny-mha", 1).unwrap();
    let mut gqa = DecodeSession::new(&mut rt, "tiny-gqa", 1).unwrap();
    let ym = mha.step(&mut rt, &x).unwrap();
    let yg = gqa.step(&mut rt, &x).unwrap();
    assert_ne!(ym, yg);
}

#[test]
fn long_generation_stays_bounded() {
    // 120 steps (near the 128-token KV capacity) with tanh feedback:
    // activations must stay finite and bounded — the e2e example's
    // stability claim, asserted.
    let Some(mut rt) = runtime() else { return };
    let mut sess = DecodeSession::new(&mut rt, "tiny-gqa", 2024).unwrap();
    let mags = sess.generate(&mut rt, 120, 3).unwrap();
    assert_eq!(mags.len(), 120);
    for (i, m) in mags.iter().enumerate() {
        assert!(m.is_finite() && *m < 100.0, "step {i}: magnitude {m}");
    }
}
