//! Property suite for the Stage-II banking layer, driven by the in-tree
//! `util::proptest` harness over randomized occupancy traces.
//!
//! Four families of invariants:
//! 1. Eq. 1 (`banks_required`) is monotone in occupancy and clamped to
//!    `[0, B]`.
//! 2. `bank_activity` timelines exactly tile `[0, end)` — no gaps, no
//!    overlaps — with coalesced neighbors that actually differ.
//! 3. `idle_intervals(b)` are disjoint, maximal, and consistent with the
//!    activity timeline they came from.
//! 4. `sweep` emits every grid point under its *requested* policy — the
//!    B=1 cell included (it used to be silently replaced by the ungated
//!    reference) — with finite deltas against a reference that is always
//!    the (B=1, no-gating) evaluation, on any trace (including
//!    degenerate zero-length / zero-stats ones).

use trapti::banking::{
    bank_activity, banks_required, evaluate, idle_intervals, sweep,
    ActivitySegment, GatingPolicy, OccupancyBasis, SweepSpec,
};
use trapti::cacti::CactiModel;
use trapti::trace::{AccessStats, OccupancyTrace};
use trapti::util::proptest::check;
use trapti::util::rng::Rng;
use trapti::util::MIB;

/// A random finalized trace with occupancy below `cap`.
fn random_trace(rng: &mut Rng, cap: u64) -> OccupancyTrace {
    let mut tr = OccupancyTrace::new("m", cap);
    let mut t = 0u64;
    for _ in 0..rng.range(1, 60) {
        t += rng.range(1, 2_000);
        let needed = rng.below(cap + 1);
        let obsolete = rng.below(cap - needed + 1);
        tr.record(t, needed, obsolete);
    }
    tr.finalize(t + rng.range(1, 500));
    tr
}

/// Random power-of-two bank count in [1, 32].
fn random_banks(rng: &mut Rng) -> u32 {
    1u32 << rng.below(6)
}

/// Random alpha in (0, 1].
fn random_alpha(rng: &mut Rng) -> f64 {
    0.05 + rng.f64() * 0.95
}

#[test]
fn prop_banks_required_monotone_and_clamped() {
    check("banks-required-monotone-clamped", 300, |rng| {
        let cap = rng.range(1, 1 << 30);
        let banks = random_banks(rng);
        let alpha = random_alpha(rng);
        let mut a = rng.below(2 * cap + 1);
        let mut b = rng.below(2 * cap + 1);
        if a > b {
            std::mem::swap(&mut a, &mut b);
        }
        let ra = banks_required(a, cap, banks, alpha);
        let rb = banks_required(b, cap, banks, alpha);
        // Monotone in occupancy.
        assert!(ra <= rb, "occ {a} -> {ra} banks but occ {b} -> {rb}");
        // Clamped to [0, B], zero exactly at zero occupancy.
        assert!(ra <= banks && rb <= banks);
        assert_eq!(banks_required(0, cap, banks, alpha), 0);
        if a > 0 {
            assert!(ra >= 1, "nonzero occupancy must keep >= 1 bank on");
        }
    });
}

#[test]
fn prop_activity_segments_tile_run_exactly() {
    check("activity-tiles-run", 200, |rng| {
        let cap = rng.range(1, 64) * MIB;
        let tr = random_trace(rng, cap);
        let banks = random_banks(rng);
        let alpha = random_alpha(rng);
        let basis = if rng.bool() {
            OccupancyBasis::NeededOnly
        } else {
            OccupancyBasis::NeededPlusObsolete
        };
        let act = bank_activity(&tr, cap, banks, alpha, basis);
        let end = tr.end_time().unwrap();

        assert!(!act.is_empty(), "end > 0 must yield segments");
        assert_eq!(act.first().unwrap().t0, 0, "timeline must start at 0");
        assert_eq!(act.last().unwrap().t1, end, "timeline must reach end");
        for s in &act {
            assert!(s.t0 < s.t1, "empty segment {s:?}");
            assert!(s.active <= banks, "active beyond B in {s:?}");
        }
        for w in act.windows(2) {
            // No gap, no overlap between consecutive segments...
            assert_eq!(w[0].t1, w[1].t0, "gap/overlap between {w:?}");
            // ...and coalescing leaves no equal neighbors.
            assert_ne!(w[0].active, w[1].active, "uncoalesced neighbors {w:?}");
        }
        let total: u64 = act.iter().map(|s| s.dt()).sum();
        assert_eq!(total, end, "segment durations must sum to the run");
    });
}

#[test]
fn prop_idle_intervals_disjoint_maximal_consistent() {
    check("idle-intervals-consistent", 200, |rng| {
        let cap = rng.range(1, 64) * MIB;
        let tr = random_trace(rng, cap);
        let banks = random_banks(rng);
        let act = bank_activity(&tr, cap, banks, random_alpha(rng), OccupancyBasis::NeededOnly);

        for bank in 0..banks {
            let idles = idle_intervals(&act, bank);
            for &(t0, t1) in &idles {
                assert!(t0 < t1, "empty idle interval ({t0}, {t1})");
            }
            // Disjoint AND maximal: merged intervals cannot touch — a
            // shared endpoint would mean the interval wasn't maximal.
            for w in idles.windows(2) {
                assert!(
                    w[0].1 < w[1].0,
                    "bank {bank}: intervals {w:?} touch or overlap"
                );
            }
            // Consistency with the timeline, both directions: idle time
            // equals the time spent at activity <= bank, and no segment
            // with activity > bank intersects an idle interval.
            let idle_total: u64 = idles.iter().map(|&(t0, t1)| t1 - t0).sum();
            let timeline_idle: u64 = act
                .iter()
                .filter(|s| s.active <= bank)
                .map(ActivitySegment::dt)
                .sum();
            assert_eq!(idle_total, timeline_idle, "bank {bank} idle time");
            for s in act.iter().filter(|s| s.active > bank) {
                for &(t0, t1) in &idles {
                    assert!(
                        s.t1 <= t0 || t1 <= s.t0,
                        "bank {bank}: busy segment {s:?} inside idle ({t0}, {t1})"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_sweep_points_carry_requested_policy_vs_ungated_reference() {
    let cacti = CactiModel::default();
    check("sweep-policy-vs-reference", 60, |rng| {
        let cap = rng.range(1, 32) * MIB;
        let tr = random_trace(rng, cap);
        let stats = AccessStats {
            reads: rng.below(1 << 30),
            writes: rng.below(1 << 30),
            ..Default::default()
        };
        // Grid at and above the trace's peak so nothing is skipped.
        let base_cap = tr.peak_needed().max(MIB);
        let alpha = random_alpha(rng);
        let spec = SweepSpec {
            capacities: vec![base_cap, base_cap * 2],
            banks: vec![1, 2, 8],
            alphas: vec![alpha],
            policies: vec![
                GatingPolicy::None,
                GatingPolicy::Aggressive,
                GatingPolicy::drowsy(),
            ],
        };
        let pts = sweep(&cacti, &tr, &stats, &spec, 1.0).unwrap();
        assert_eq!(pts.len(), spec.points());
        for p in &pts {
            assert!(p.delta_e_pct().is_finite());
            assert!(p.delta_a_pct().is_finite());
            // Every point — B=1 included — reports the policy it was
            // requested under (the old sweep silently substituted the
            // ungated reference at B=1).
            assert!(
                spec.policies.contains(&p.eval.policy),
                "policy {:?} not in grid",
                p.eval.policy
            );
            // The ΔE/ΔA reference is always the (B=1, ungated) eval.
            let reference = evaluate(
                &cacti,
                &tr,
                &stats,
                p.eval.capacity,
                1,
                alpha,
                GatingPolicy::None,
                1.0,
            ).unwrap();
            assert_eq!(p.base_e_j.to_bits(), reference.e_total_j().to_bits());
            assert_eq!(p.base_area_mm2.to_bits(), reference.area_mm2.to_bits());
            // The point itself equals a direct evaluation under its own
            // policy (B=1 drowsy/aggressive really are modeled now).
            let direct = evaluate(
                &cacti,
                &tr,
                &stats,
                p.eval.capacity,
                p.eval.banks,
                alpha,
                p.eval.policy,
                1.0,
            ).unwrap();
            assert_eq!(p.eval.e_total_j().to_bits(), direct.e_total_j().to_bits());
            assert_eq!(p.eval.n_switch, direct.n_switch);
            // No-gating at B=1 is exactly the reference: zero deltas.
            if p.eval.banks == 1 && p.eval.policy == GatingPolicy::None {
                assert!(p.delta_e_pct().abs() < 1e-9);
                assert!(p.delta_a_pct().abs() < 1e-9);
            }
            // Break-even-filtered gating never loses energy vs. the
            // reference at B=1 (same organization, gating only helps).
            if p.eval.banks == 1 && p.eval.policy == GatingPolicy::Aggressive {
                assert!(
                    p.eval.e_total_j() <= p.base_e_j + 1e-12,
                    "B=1 aggressive worse than ungated: {} vs {}",
                    p.eval.e_total_j(),
                    p.base_e_j
                );
            }
        }
    });
}
