//! Lab semantics (ISSUE 6 satellite): resume is byte-identical, a
//! second run is a pure cache hit, and gc never deletes live artifacts.
//!
//! These mirror the CI determinism/resume gate but run in-process so
//! `cargo test` catches a regression without the workflow.

use std::collections::BTreeMap;
use std::path::Path;

use trapti::api::ApiContext;
use trapti::lab::{execute, ExecOptions, JobKind, LabManifest, Plan, Store};

const MANIFEST: &str = r#"
[lab]
name = "lab-test"
accel = "tiny"
workloads = ["tiny-mha:prefill:64", "tiny-gqa:decode:16:8", "tiny-gqa:serve:8:2:7"]
validate = true

[grid]
capacities = ["2MiB", "4MiB"]
banks = [1, 2, 4, 8]
alphas = [0.9]
policies = ["aggressive", "drowsy"]
"#;

fn tmp_store(tag: &str) -> Store {
    let root = std::env::temp_dir().join(format!(
        "trapti-lab-it-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    Store::new(root)
}

fn plan() -> Plan {
    Plan::of(LabManifest::parse(MANIFEST).unwrap())
}

/// Every file under `root` as relative-path -> bytes, so two store
/// trees compare exactly (the in-process `diff -r`).
fn tree(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(dir: &Path, root: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            if path.is_dir() {
                walk(&path, root, out);
            } else {
                let rel = path
                    .strip_prefix(root)
                    .unwrap()
                    .to_string_lossy()
                    .into_owned();
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out);
    out
}

fn assert_trees_equal(a: &BTreeMap<String, Vec<u8>>, b: &BTreeMap<String, Vec<u8>>) {
    let ka: Vec<&String> = a.keys().collect();
    let kb: Vec<&String> = b.keys().collect();
    assert_eq!(ka, kb, "store trees hold different files");
    for (name, bytes) in a {
        assert_eq!(bytes, &b[name], "{name} differs between trees");
    }
}

#[test]
fn parallel_run_matches_sequential_and_second_run_is_free() {
    let ctx = ApiContext::new();
    let p = plan();

    let seq = tmp_store("seq");
    let s = execute(&ctx, &seq, &p, &ExecOptions::default()).unwrap();
    assert!(s.ok(), "{:?}", s.failed);
    assert_eq!(s.executed.len(), p.jobs.len());

    let par = tmp_store("par");
    let opts = ExecOptions {
        jobs: 4,
        ..Default::default()
    };
    let r = execute(&ctx, &par, &p, &opts).unwrap();
    assert!(r.ok(), "{:?}", r.failed);
    assert_trees_equal(&tree(seq.root()), &tree(par.root()));

    // Second pass over a complete store executes nothing.
    let again = execute(&ctx, &par, &p, &opts).unwrap();
    assert!(again.executed.is_empty(), "second run must be pure cache hits");
    assert_eq!(again.skipped.len(), p.jobs.len());
    assert_trees_equal(&tree(seq.root()), &tree(par.root()));

    let _ = std::fs::remove_dir_all(seq.root());
    let _ = std::fs::remove_dir_all(par.root());
}

#[test]
fn interrupted_run_resumes_to_identical_bytes() {
    let ctx = ApiContext::new();
    let p = plan();
    let store = tmp_store("resume");
    let opts = ExecOptions {
        jobs: 2,
        ..Default::default()
    };
    assert!(execute(&ctx, &store, &p, &opts).unwrap().ok());
    let complete = tree(store.root());

    // Simulate a crash: one sweep job's artifacts vanish entirely, and
    // another job dies mid-write (COMPLETE marker missing).
    let killed_sweep = p.jobs.iter().find(|j| j.kind == JobKind::Sweep).unwrap();
    std::fs::remove_dir_all(store.job_dir(killed_sweep.id)).unwrap();
    let torn = p.jobs.iter().find(|j| j.kind == JobKind::Optimize).unwrap();
    std::fs::remove_file(store.job_dir(torn.id).join("COMPLETE")).unwrap();

    let resumed = execute(&ctx, &store, &p, &opts).unwrap();
    assert!(resumed.ok(), "{:?}", resumed.failed);
    // Exactly the two damaged jobs re-ran; everything else was skipped.
    let mut reran = resumed.executed.clone();
    reran.sort_unstable();
    let mut expected = vec![killed_sweep.id, torn.id];
    expected.sort_unstable();
    assert_eq!(reran, expected, "only unfinished jobs re-run on resume");
    assert_eq!(resumed.skipped.len(), p.jobs.len() - 2);

    // Regeneration is bit-deterministic: the resumed store equals the
    // uninterrupted one file for file.
    assert_trees_equal(&complete, &tree(store.root()));
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn gc_preserves_everything_a_live_manifest_reaches() {
    let ctx = ApiContext::new();
    let p = plan();
    let store = tmp_store("gc");
    assert!(execute(&ctx, &store, &p, &ExecOptions::default()).unwrap().ok());

    // A stale job from some older campaign.
    let stale = 0xdead_beef_dead_beef_u64;
    store.begin(stale).unwrap();
    store.write_artifact(stale, "sweep.json", b"{}").unwrap();

    let before = tree(store.root());
    let removed = store.gc(&p.live_ids()).unwrap();
    assert_eq!(removed, vec![stale], "only the unreachable job goes");
    for job in &p.jobs {
        assert!(store.is_complete(job.id), "{} survives gc", job.label);
    }
    // Live artifacts are byte-untouched.
    let after = tree(store.root());
    for (name, bytes) in &after {
        assert_eq!(bytes, &before[name], "{name} changed during gc");
    }

    // gc with nothing live clears the store.
    let removed = store.gc(&Default::default()).unwrap();
    assert_eq!(removed.len(), p.jobs.len());
    assert!(store.jobs().unwrap().is_empty());
    let _ = std::fs::remove_dir_all(store.root());
}

// --- Manifest negative paths (ISSUE 7 satellite) ----------------------
//
// A bad manifest must fail with a message that names the actual
// mistake — three different mistakes must produce three different
// messages, or the user is left grepping a lab file against a generic
// "invalid manifest".

#[test]
fn duplicate_grid_key_is_rejected_by_name_and_line() {
    let err = LabManifest::parse(
        "[lab]\nname = \"d\"\naccel = \"tiny\"\n\
         workloads = [\"tiny-mha:prefill:64\"]\n\
         [grid]\ncapacities = [\"2MiB\"]\ncapacities = [\"4MiB\"]\n",
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("duplicate key `grid.capacities`"), "{err}");
    assert!(err.contains("line 7"), "points at the offending line: {err}");
}

#[test]
fn empty_workload_list_is_rejected() {
    let err = LabManifest::parse(
        "[lab]\nname = \"d\"\naccel = \"tiny\"\nworkloads = []\n",
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("`lab.workloads` is empty"), "{err}");
}

#[test]
fn unknown_gating_policy_is_rejected_with_the_valid_set() {
    let err = LabManifest::parse(
        "[lab]\nname = \"d\"\naccel = \"tiny\"\n\
         workloads = [\"tiny-mha:prefill:64\"]\n\
         [grid]\ncapacities = [\"2MiB\"]\npolicies = [\"warp\"]\n",
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("unknown policy `warp`"), "{err}");
    assert!(
        err.contains("none|aggressive|conservative|drowsy"),
        "lists the valid policies: {err}"
    );
}

#[test]
fn distinct_manifest_mistakes_produce_distinct_messages() {
    let msgs: Vec<String> = [
        "[lab]\nname = \"d\"\naccel = \"tiny\"\n\
         workloads = [\"tiny-mha:prefill:64\"]\n\
         [grid]\ncapacities = [\"2MiB\"]\ncapacities = [\"4MiB\"]\n",
        "[lab]\nname = \"d\"\naccel = \"tiny\"\nworkloads = []\n",
        "[lab]\nname = \"d\"\naccel = \"tiny\"\n\
         workloads = [\"tiny-mha:prefill:64\"]\n\
         [grid]\ncapacities = [\"2MiB\"]\npolicies = [\"warp\"]\n",
    ]
    .iter()
    .map(|m| LabManifest::parse(m).unwrap_err().to_string())
    .collect();
    for i in 0..msgs.len() {
        for j in i + 1..msgs.len() {
            assert_ne!(msgs[i], msgs[j], "mistakes {i} and {j} are conflated");
        }
    }
}
