//! Serving integration tests: the ISSUE acceptance scenario (gpt2-xl,
//! 256 requests, concurrency 64, seed 7), bit-determinism, and the
//! materialized-vs-streamed differential harness.

use trapti::api::{ApiContext, ExperimentSpec};
use trapti::serving::ServingParams;
use trapti::sim::serving::{simulate_serving, simulate_serving_with, ServingSimOptions};
use trapti::trace::{
    stream_csv_to_traces, CsvStreamSink, MemoryDesc, OnlineStatsSink, TeeSink,
};
use trapti::util::proptest::check;
use trapti::util::rng::Rng;
use trapti::workload::{GPT2_XL, TINY_GQA};

fn acceptance_spec(concurrency: u32) -> ExperimentSpec {
    ExperimentSpec::builder()
        .model(GPT2_XL)
        .serving(ServingParams::new(256, concurrency, 7))
        .build()
        .expect("acceptance spec builds")
}

/// The ISSUE acceptance scenario end to end: Stage I (serving sim) +
/// Stage II (banking sweep on the serving trace), deterministic, with
/// 64-way peak occupancy strictly above the single-stream peak.
#[test]
fn acceptance_gpt2_xl_c64_r256_seed7() {
    let ctx = ApiContext::new();
    let run = acceptance_spec(64).run_serving().unwrap();
    assert_eq!(run.result.completed, 256, "every request must finish");
    assert_eq!(run.result.peak_concurrent, 64, "cap must be reached");

    // Stage II completes and reports a best banking point.
    let s2 = run.stage2(&ctx).unwrap();
    assert!(!s2.points.is_empty());
    let best = s2.best().unwrap();
    assert!(best.eval.banks >= 1);
    assert!(
        s2.best_delta_pct() < 0.0,
        "banked gating must beat the reference on a serving trace"
    );

    // Bit-determinism: same seed, same trace hash, sample for sample.
    let again = acceptance_spec(64).run_serving().unwrap();
    assert_eq!(run.result.trace_hash(), again.result.trace_hash());
    assert_eq!(run.trace().samples(), again.trace().samples());
    assert_eq!(run.result.total_cycles, again.result.total_cycles);

    // Serving-shaped occupancy: 64 concurrent streams stack strictly
    // higher than a single stream of the same population.
    let single = acceptance_spec(1).run_serving().unwrap();
    assert_eq!(single.result.completed, 256);
    assert!(
        run.trace().peak_needed() > single.trace().peak_needed(),
        "c=64 peak {} must exceed c=1 peak {}",
        run.trace().peak_needed(),
        single.trace().peak_needed()
    );
}

#[test]
fn different_seed_changes_the_trace() {
    let a = acceptance_spec(4);
    let mut p = a.serving_params().unwrap();
    p.seed = 8;
    p.requests = 32;
    let mut q = p;
    q.seed = 9;
    let spec_for = |params| {
        ExperimentSpec::builder()
            .model(GPT2_XL)
            .serving(params)
            .build()
            .unwrap()
    };
    let rb = spec_for(p).run_serving().unwrap();
    let rc = spec_for(q).run_serving().unwrap();
    assert_ne!(rb.result.trace_hash(), rc.result.trace_hash());
}

/// Differential harness: a randomized serving workload run twice — once
/// materialized, once streaming through `OnlineStatsSink` +
/// `CsvStreamSink` — must agree on peaks/averages, and the CSV must
/// parse back (via `trace::io`) to the exact materialized samples.
#[test]
fn differential_materialized_vs_streamed_random_workloads() {
    let accel = trapti::config::tiny();
    check("serving-differential", 12, |rng: &mut Rng| {
        let mut p = ServingParams::new(
            rng.range(1, 40) as u32,
            rng.range(1, 8) as u32,
            rng.next_u64(),
        );
        p.prompt_min = rng.range(0, 8) as u32;
        p.prompt_max = p.prompt_min + rng.range(0, 40) as u32;
        p.gen_min = rng.range(1, 6) as u32;
        p.gen_max = p.gen_min + rng.range(0, 24) as u32;
        p.page_tokens = rng.range(1, 32) as u32;
        p.mean_arrival_gap = rng.below(200_000);

        // Run 1: materialized reference.
        let reference = simulate_serving(&TINY_GQA, p, &accel).unwrap();
        assert_eq!(reference.completed, p.requests);

        // Run 2: streaming-only, O(1) trace memory.
        let mut online = OnlineStatsSink::new();
        let mut csv = CsvStreamSink::new(Vec::new());
        let streamed = {
            let mut tee = TeeSink::new(vec![&mut online, &mut csv]);
            simulate_serving_with(
                &TINY_GQA,
                p,
                &accel,
                ServingSimOptions {
                    sink: Some(&mut tee),
                    materialize: false,
                },
            )
            .unwrap()
        };
        assert_eq!(streamed.total_cycles, reference.total_cycles);
        assert_eq!(streamed.stats, reference.stats);
        assert_eq!(streamed.trace.samples().len(), 1, "must not materialize");

        // Identical peaks and time-weighted averages.
        let m = online.shared().unwrap();
        assert_eq!(m.peak_needed(), reference.trace.peak_needed());
        assert_eq!(m.peak_occupied(), reference.trace.peak_occupied());
        assert_eq!(m.end_time(), reference.trace.end_time());
        assert!((m.avg_needed() - reference.trace.avg_needed()).abs() < 1e-9);

        // The CSV stream parses back to the exact materialized samples.
        let text = String::from_utf8(csv.into_inner().unwrap()).unwrap();
        let mems = vec![MemoryDesc {
            name: "kv-arena".to_string(),
            capacity: reference.arena_capacity,
        }];
        let parsed =
            stream_csv_to_traces(&text, &mems, reference.total_cycles).unwrap();
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].samples(), reference.trace.samples());
        assert_eq!(parsed[0].end_time(), reference.trace.end_time());
    });
}
