//! Bench: regenerate Fig. 1 (MHA vs GQA decode energy/latency) and time
//! the end-to-end generation. Run: `cargo bench --bench fig1_mha_vs_gqa`.

use trapti::api::{experiments as exp, ApiContext};
use trapti::report::figures;
use trapti::util::bench::{bench, default_iters};

fn main() {
    let ctx = ApiContext::new();
    let (_stats, f1) = bench("fig1_mha_vs_gqa", default_iters(), || {
        exp::fig1(&ctx).expect("fig1")
    });
    print!("{}", figures::fig1(&f1));
    assert!(f1.attn_energy_ratio() > 1.5, "GQA must win on attention energy");
    assert!(f1.attn_latency_ratio() > 1.5, "GQA must win on attention latency");
}
