//! Bench: regenerate Table III (multi-level hierarchy per-memory banking
//! sweep). Run: `cargo bench --bench table3_multilevel`.

use trapti::api::{experiments as exp, ApiContext};
use trapti::report::tables;
use trapti::util::bench::{bench, default_iters};

fn main() {
    let ctx = ApiContext::new();
    let (_stats, t3) = bench("table3_multilevel", default_iters(), || {
        exp::table3(&ctx).expect("table3")
    });
    println!(
        "multi-level: e2e {:.1} ms (paper 550), util {:.0}% (paper 57), \
         on-chip {:.1} J (paper 73.4)",
        t3.stage1.result.seconds() * 1e3,
        t3.stage1.result.active_utilization() * 100.0,
        t3.stage1.energy.on_chip_j(),
    );
    for t in tables::table3(&t3) {
        print!("{}", t.render());
    }
    println!("best dE: {:.1}% (paper headline: -77.8%)", t3.best_delta());
    assert_eq!(t3.per_memory.len(), 3, "shared + DM1 + DM2");
    assert!(t3.best_delta() < -60.0, "multi-level gating must beat -60%");
    assert!(t3.stage1.result.feasible());
}
