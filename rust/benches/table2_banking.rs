//! Bench: regenerate Table II (banking energy/area sweep, both
//! workloads, alpha = 0.9). Run: `cargo bench --bench table2_banking`.

use trapti::api::{experiments as exp, ApiContext};
use trapti::report::tables;
use trapti::util::bench::{bench, default_iters};
use trapti::util::MIB;

fn main() {
    let ctx = ApiContext::new();
    let pair = exp::paired_prefill(&ctx).expect("stage1 pair");
    let (_stats, t2) = bench("table2_banking", default_iters(), || {
        exp::table2(&ctx, &pair).expect("stage2")
    });
    for t in tables::table2(&t2) {
        print!("{}", t.render());
    }
    println!("best dE anywhere: {:.1}% (paper: -61.3% at DS 128 MiB B=16)", t2.best_delta());
    // Paper claims: banking reduces energy across all DS capacities with
    // the optimum in the middle of the bank range, not at B=32.
    for cap in [64 * MIB, 128 * MIB] {
        let best = exp::Table2::best_banks_at(&t2.gqa_points, cap).unwrap();
        assert!((2..=16).contains(&best), "best banks at {cap}: {best}");
    }
    assert!(t2.best_delta() < -40.0, "banking must cut energy substantially");
}
