//! Bench: regenerate Fig. 6 (per-op-class latency breakdown).
//! Run: `cargo bench --bench fig6_latency_breakdown`.

use trapti::api::{experiments as exp, ApiContext};
use trapti::report::figures;
use trapti::util::bench::{bench, default_iters};
use trapti::workload::OpClass;

fn main() {
    let ctx = ApiContext::new();
    let (_stats, pair) = bench("fig6_latency_breakdown", default_iters(), || {
        exp::paired_prefill(&ctx).expect("stage1 pair")
    });
    print!("{}", figures::fig6(&pair));
    // The paper's observation: GPT-2 XL spends more non-compute time
    // per unit of useful work. Metric: memory+idle cycles per TMAC.
    let stalls_per_tmac = |r: &trapti::sim::SimResult| {
        let mut mem = 0u64;
        for c in OpClass::all() {
            if let Some(b) = r.op_breakdown.get(c) {
                mem += b.memory + b.idle;
            }
        }
        mem as f64 / (r.total_macs as f64 / 1e12)
    };
    let mha = stalls_per_tmac(&pair.mha.result);
    let gqa = stalls_per_tmac(&pair.gqa.result);
    println!(
        "memory+idle cycles per TMAC: MHA {:.2e} vs GQA {:.2e}",
        mha, gqa
    );
    assert!(mha > gqa, "MHA must stall more per useful MAC");
}
