//! Bench: regenerate Fig. 5 (time-resolved occupancy traces, both
//! workloads at 128 MiB). Run: `cargo bench --bench fig5_occupancy`.

use trapti::api::{experiments as exp, ApiContext};
use trapti::report::figures;
use trapti::util::bench::{bench, default_iters};
use trapti::util::MIB;

fn main() {
    let ctx = ApiContext::new();
    let (_stats, pair) = bench("fig5_occupancy", default_iters(), || {
        exp::paired_prefill(&ctx).expect("stage1 pair")
    });
    let (text, _, _) = figures::fig5(&pair);
    print!("{text}");
    println!(
        "peak ratio MHA/GQA = {:.2}x (paper 2.72x); \
         MHA {:.1} MiB (paper 107.3), GQA {:.1} MiB (paper 39.1)",
        pair.peak_ratio(),
        pair.mha.result.peak_needed() as f64 / MIB as f64,
        pair.gqa.result.peak_needed() as f64 / MIB as f64,
    );
    assert!(pair.peak_ratio() > 1.8, "MHA must need substantially more SRAM");
    assert!(pair.mha.result.feasible() && pair.gqa.result.feasible());
}
