//! Bench: regenerate Fig. 8 (bank activity under different alphas, DS at
//! 64 MiB / B=4). Run: `cargo bench --bench fig8_bank_activity`.

use trapti::api::{experiments as exp, ApiContext};
use trapti::banking::avg_active;
use trapti::report::figures;
use trapti::util::bench::{bench, default_iters};

fn main() {
    let ctx = ApiContext::new();
    let pair = exp::paired_prefill(&ctx).expect("stage1 pair");
    let (_stats, f8) = bench("fig8_bank_activity", default_iters(), || {
        exp::fig8(&pair.gqa)
    });
    print!("{}", figures::fig8(&f8));
    // Lower alpha -> more active banks on average (the figure's message).
    let avgs: Vec<f64> = f8.timelines.iter().map(|t| avg_active(t)).collect();
    for w in avgs.windows(2) {
        assert!(w[1] >= w[0] - 1e-9, "avg active must rise as alpha falls: {avgs:?}");
    }
}
