//! Bench: serving occupancy vs concurrency — multi-tenant decode
//! streams over the paged KV arena at concurrency ∈ {1, 4, 16, 64},
//! each merged trace swept through Stage II.
//! Run: `cargo bench --bench fig10_serving_occupancy`.

use trapti::api::{experiments as exp, ApiContext};
use trapti::util::bench::{bench, default_iters};
use trapti::util::MIB;
use trapti::workload::GPT2_XL;

fn main() {
    let ctx = ApiContext::new();
    let (_stats, points) = bench("fig10_serving_occupancy", default_iters(), || {
        exp::fig10_serving(&ctx, &GPT2_XL, 256, 7).expect("serving runs")
    });

    println!(
        "{:>6} {:>11} {:>11} {:>11} {:>8} {:>6} {:>13} {:>8}",
        "conc", "peak[MiB]", "occ[MiB]", "avg[MiB]", "ms", "bestB", "best policy", "dE%"
    );
    for p in &points {
        println!(
            "{:>6} {:>11.1} {:>11.1} {:>11.1} {:>8.1} {:>6} {:>13} {:>8.1}",
            p.concurrency,
            p.peak_needed as f64 / MIB as f64,
            p.peak_occupied as f64 / MIB as f64,
            p.avg_needed / MIB as f64,
            p.total_cycles as f64 / 1e6,
            p.best_banks,
            p.best_policy.label(),
            p.best_delta_pct,
        );
    }

    // Serving-shaped occupancy is the point of the figure: stacking
    // concurrent KV caches must push the peak strictly past the
    // single-stream case, and every run must serve the whole population.
    let single = &points[0];
    let heavy = points.last().expect("four concurrency levels");
    assert_eq!(single.concurrency, 1);
    assert_eq!(heavy.concurrency, 64);
    for p in &points {
        assert_eq!(p.completed, 256, "requests dropped at c={}", p.concurrency);
        assert!(p.best_delta_pct < 0.0, "banking must win at c={}", p.concurrency);
    }
    assert!(
        heavy.peak_needed > single.peak_needed,
        "64-way serving peak {} must exceed single-stream peak {}",
        heavy.peak_needed,
        single.peak_needed
    );
    assert!(
        heavy.peak_concurrent > single.peak_concurrent,
        "concurrency cap never exercised"
    );
}
