//! Bench: event-driven serving engine vs. the retained round-robin
//! oracle on a bursty, heavy-tailed trace at fig10-and-beyond scale
//! (tiny-gqa, one million requests, concurrency 64).
//! Run: `cargo bench --bench serving_engine`.
//!
//! Both engines run in throughput mode (no sink, no materialized
//! trace); the event engine must be differentially identical to the
//! oracle and at least 10x faster at full scale — the closed-form
//! fast-forward across quiescent gaps is what makes million-request
//! traces tractable, and this bench is the regression tripwire for it.
//!
//! `TRAPTI_BENCH_SMOKE=1` shrinks the trace to CI scale (the speedup
//! threshold is waived there — at a few thousand requests the ratio is
//! noise — but the differential identity always holds). Emits
//! `BENCH_serving_engine.json` (events/sec, speedup) either way.

use trapti::serving::{generate_requests, ServingParams};
use trapti::sim::serving::{round_robin, simulate_serving_with, ServingSimOptions};
use trapti::util::bench::{bench, default_iters, emit_json, smoke};
use trapti::util::json::Json;
use trapti::workload::TINY_GQA;

fn main() {
    let accel = trapti::config::tiny();
    let smoke = smoke();
    // Smoke scale keeps CI in seconds; full scale is the acceptance
    // trace: 1M bursty heavy-tailed requests through a 64-wide server.
    let (requests, concurrency) = if smoke { (2_000, 16) } else { (1_000_000, 64) };
    let params = ServingParams::new(requests, concurrency, 7).with_bursty_traffic();

    // Simulated event count: one arrival + one completion + one decode
    // step per generated token, per request (scheduling rounds excluded
    // — they are engine bookkeeping, not workload events).
    let events: u64 = generate_requests(&params)
        .iter()
        .map(|r| r.gen as u64 + 2)
        .sum();
    println!(
        "bursty trace: {requests} requests, {events} simulated events{}",
        if smoke { " [smoke]" } else { "" },
    );

    // One measured iteration at full scale: the oracle alone walks
    // ~1M requests round by round and dominates the wall clock.
    let iters = if smoke { default_iters() } else { 1 };
    let throughput = || ServingSimOptions { sink: None, materialize: false };
    let (oracle_stats, oracle) = bench("serving_round_robin", iters, || {
        round_robin(&TINY_GQA, params, &accel, throughput()).expect("oracle run")
    });
    let (event_stats, event) = bench("serving_engine", iters, || {
        simulate_serving_with(&TINY_GQA, params, &accel, throughput())
            .expect("event run")
    });

    // Differential identity: the event engine IS the production path.
    assert_eq!(event.total_cycles, oracle.total_cycles);
    assert_eq!(event.completed, oracle.completed);
    assert_eq!(event.peak_concurrent, oracle.peak_concurrent);
    assert_eq!(event.stats, oracle.stats);
    assert_eq!(event.workload, oracle.workload);
    assert_eq!(event.completed, requests);

    let speedup = oracle_stats.mean.as_secs_f64() / event_stats.mean.as_secs_f64();
    let events_per_sec = events as f64 / event_stats.mean.as_secs_f64();
    println!(
        "event engine speedup over round-robin: {speedup:.1}x \
         ({:?} -> {:?}, {events_per_sec:.0} events/s)",
        oracle_stats.mean, event_stats.mean
    );
    assert!(
        smoke || speedup >= 10.0,
        "event engine must be >= 10x faster than the round-robin oracle \
         on the 1M-request bursty trace (got {speedup:.2}x)"
    );

    let mut fields = event_stats.to_json();
    fields.extend([
        ("round_robin_wall_ms", Json::num(oracle_stats.mean.as_secs_f64() * 1e3)),
        ("speedup_vs_round_robin", Json::num(speedup)),
        ("events_per_sec", Json::num(events_per_sec)),
        ("requests", Json::num(requests as f64)),
        ("events", Json::num(events as f64)),
        ("smoke", Json::Bool(smoke)),
    ]);
    let path = emit_json("serving_engine", fields).expect("bench artifact");
    println!("wrote {}", path.display());
}
