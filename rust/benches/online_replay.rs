//! Bench: Stage-III online gating replay of the Table II grid winners ×
//! {GPT-2 XL, DeepSeek-R1-Distill-Qwen-1.5B} × {decode, serving}.
//! Run: `cargo bench --bench online_replay`.
//!
//! The four workloads stream through the fused Stage-II pipeline once to
//! find each workload's own energy-optimal configuration (its "Table II
//! winner"); the timed region is the pure Stage-III replay — the
//! cycle-level per-bank state machines with wake-stall feedback — which
//! must stay cheap next to simulation (it walks the trace once per
//! config with O(B) state). Also asserts the module's two structural
//! properties on full-scale traces: zero-wake bit-identical
//! reconciliation with the offline evaluator, and determinism.
//!
//! `TRAPTI_BENCH_SMOKE=1` shrinks the workloads to the CI optimizer
//! gate's scale (both structural assertions still run). Emits
//! `BENCH_online_replay.json` for the perf trajectory either way.

use trapti::api::{optimize as api_opt, ApiContext, ExperimentSpec, MaterializedRun};
use trapti::banking::{evaluate, replay_trace_with, OnlineConfig};
use trapti::util::bench::{bench, default_iters, emit_json, smoke};
use trapti::util::json::Json;
use trapti::workload::{DS_R1D_Q15B, GPT2_XL};

fn main() {
    let ctx = ApiContext::new();
    let smoke = smoke();
    // Smoke scale mirrors the CI optimizer-determinism gate's workloads.
    let (dp, dg) = if smoke { (64, 16) } else { (512, 128) };
    let (sreq, sconc) = if smoke { (16, 4) } else { (64, 8) };

    let serving = |model: trapti::workload::ModelPreset| {
        ExperimentSpec::builder()
            .model(model)
            .serving(trapti::serving::ServingParams::new(sreq, sconc, 7))
            .build()
            .expect("serving spec")
    };
    let decode = |model: trapti::workload::ModelPreset| {
        ExperimentSpec::builder()
            .model(model)
            .decode(dp, dg)
            .build()
            .expect("decode spec")
    };
    let specs = vec![
        decode(GPT2_XL),
        decode(DS_R1D_Q15B),
        serving(GPT2_XL),
        serving(DS_R1D_Q15B),
    ];

    // Stage I + II once (fused): the Table II-shaped covering grid gives
    // each workload its own energy-optimal winner.
    let grid = api_opt::covering_grid(&specs);
    let run = api_opt::run_portfolio(
        &ctx,
        &specs,
        &api_opt::PortfolioOptions {
            grid: Some(grid),
            ..Default::default()
        },
    )
    .expect("portfolio pipeline");

    // Materialize each workload's trace once (the shared api helper);
    // replays borrow it.
    let mut workloads: Vec<(String, MaterializedRun, f64, OnlineConfig)> = Vec::new();
    for (spec, frontier) in specs.iter().zip(&run.result.frontiers) {
        let mat = spec.materialize(&ctx).expect("stage 1");
        let winner = frontier
            .frontier
            .iter()
            .find(|fp| trapti::banking::ConfigKey::of(&fp.point) == frontier.best_key)
            .unwrap_or(&frontier.frontier[0]);
        workloads.push((
            frontier.workload.clone(),
            mat,
            spec.freq_ghz(),
            OnlineConfig::of_point(&winner.point),
        ));
    }

    // Timed region: one Stage-III replay per workload winner (totals
    // only — no timeline recording, the validation-pass configuration).
    let (stats, reports) = bench("online_replay", default_iters(), || {
        workloads
            .iter()
            .map(|(_, mat, freq, cfg)| {
                replay_trace_with(&ctx.cacti, mat.trace(), mat.stats(), *cfg, *freq, false)
                    .expect("replay")
            })
            .collect::<Vec<_>>()
    });

    println!(
        "{:>34} {:>28} {:>12} {:>10} {:>8} {:>9}",
        "workload", "winner", "trace[cyc]", "stall[cyc]", "stall%", "wakes"
    );
    for ((name, ..), r) in workloads.iter().zip(&reports) {
        println!(
            "{:>34} {:>28} {:>12} {:>10} {:>7.3}% {:>9}",
            name,
            r.config.label(),
            r.trace_cycles,
            r.stall_cycles,
            r.stall_pct(),
            r.wake_events,
        );
    }

    // Zero-wake reconciliation on full-scale traces: bit-identical to
    // the offline evaluator for every winner.
    for (name, mat, freq, cfg) in &workloads {
        let mut zero = *cfg;
        zero.wake_override = Some(0);
        let online =
            replay_trace_with(&ctx.cacti, mat.trace(), mat.stats(), zero, *freq, false)
                .expect("zero-wake replay");
        let offline = evaluate(
            &ctx.cacti,
            mat.trace(),
            mat.stats(),
            cfg.capacity,
            cfg.banks,
            cfg.alpha,
            cfg.policy,
            *freq,
        )
        .expect("offline evaluate");
        assert_eq!(
            online.eval.e_total_j().to_bits(),
            offline.e_total_j().to_bits(),
            "{name}: zero-wake replay must reconcile bit-for-bit"
        );
        assert_eq!(online.stall_cycles, 0, "{name}");
    }

    // Determinism: a second replay pass is bit-identical.
    for ((name, mat, freq, cfg), first) in workloads.iter().zip(&reports) {
        let again =
            replay_trace_with(&ctx.cacti, mat.trace(), mat.stats(), *cfg, *freq, false)
                .expect("replay again");
        assert_eq!(again.stall_cycles, first.stall_cycles, "{name}");
        assert_eq!(
            again.eval.e_total_j().to_bits(),
            first.eval.e_total_j().to_bits(),
            "{name}: replay must be deterministic"
        );
    }

    println!("replay pass mean: {:?}", stats.mean);

    let trace_cycles_total: u64 = reports.iter().map(|r| r.trace_cycles).sum();
    let mut fields = stats.to_json();
    fields.extend([
        ("workloads", Json::num(workloads.len() as f64)),
        ("trace_cycles_total", Json::num(trace_cycles_total as f64)),
        ("smoke", Json::Bool(smoke)),
    ]);
    let path = emit_json("online_replay", fields).expect("bench artifact");
    println!("wrote {}", path.display());
}
