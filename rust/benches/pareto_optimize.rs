//! Bench: Stage-II Pareto/portfolio optimizer over the Table II grid ×
//! {GPT-2 XL, DeepSeek-R1-Distill-Qwen-1.5B} × {decode, serving}.
//! Run: `cargo bench --bench pareto_optimize`.
//!
//! The four workload sweeps are collected once through the fused
//! pipeline (`api::optimize::run_portfolio` streams Stage I straight
//! into the sweep engine); the timed region is the pure offline
//! optimizer pass — constraint filtering, per-workload ε-frontiers, and
//! the cross-workload regret portfolio — which must stay a trivial cost
//! next to simulation (the whole point of choosing offline).
//!
//! `TRAPTI_BENCH_SMOKE=1` shrinks the workloads to the CI optimizer
//! gate's scale (the MHA-vs-GQA divergence assertion is waived there —
//! it is a claim about the full-scale occupancy gap). Emits
//! `BENCH_pareto_optimize.json` for the perf trajectory either way.

use trapti::api::{optimize as api_opt, ApiContext, ExperimentSpec};
use trapti::banking::{optimize, Constraints};
use trapti::serving::ServingParams;
use trapti::util::bench::{bench, default_iters, emit_json, smoke};
use trapti::util::json::Json;
use trapti::util::MIB;
use trapti::workload::{DS_R1D_Q15B, GPT2_XL};

fn main() {
    let ctx = ApiContext::new();
    let smoke = smoke();
    let (dp, dg) = if smoke { (64, 16) } else { (512, 128) };
    let (sreq, sconc) = if smoke { (16, 4) } else { (64, 8) };

    let serving = |model: trapti::workload::ModelPreset| {
        ExperimentSpec::builder()
            .model(model)
            .serving(ServingParams::new(sreq, sconc, 7))
            .build()
            .expect("serving spec")
    };
    let decode = |model: trapti::workload::ModelPreset| {
        ExperimentSpec::builder()
            .model(model)
            .decode(dp, dg)
            .build()
            .expect("decode spec")
    };
    let specs = vec![
        decode(GPT2_XL),
        decode(DS_R1D_Q15B),
        serving(GPT2_XL),
        serving(DS_R1D_Q15B),
    ];

    // Table II grid shape shared by all four workloads: 16 MiB steps up
    // to the largest closed-form capacity bound (the GPT-2 XL serving
    // arena), paper bank set, alpha = 0.9, all four policies — the same
    // covering grid `repro optimize` derives by default.
    let grid = api_opt::covering_grid(&specs);
    println!(
        "grid: {} points up to {} MiB; 4 workloads (decode + serving, MHA + GQA){}",
        grid.points(),
        grid.capacities.last().expect("grid non-empty") / MIB,
        if smoke { " [smoke]" } else { "" }
    );
    let grid_points = grid.points();

    // Collect the four sweeps once (fused streaming; not the timed part).
    let run = api_opt::run_portfolio(
        &ctx,
        &specs,
        &api_opt::PortfolioOptions {
            grid: Some(grid),
            ..Default::default()
        },
    )
    .expect("portfolio pipeline");
    let workloads = run.workloads.clone();

    // Timed region: the pure offline optimizer pass.
    let (stats, result) = bench("pareto_optimize", default_iters(), || {
        optimize(&workloads, &Constraints::default(), 0.0, None).expect("optimize")
    });

    println!(
        "{:>34} {:>9} {:>9} {:>28}",
        "workload", "feasible", "frontier", "own optimum"
    );
    for f in &result.frontiers {
        println!(
            "{:>34} {:>9} {:>9} {:>28}",
            f.workload,
            f.feasible,
            f.frontier.len(),
            f.best_key.label(),
        );
    }
    let best = result.robust_best().expect("portfolio non-empty");
    println!(
        "robust-best: {} (worst regret {:+.1}%, mean {:+.1}%) over {} shared configs",
        best.key.label(),
        best.worst_regret_pct,
        best.mean_regret_pct,
        result.portfolio.len(),
    );

    // The paper's headline structure: MHA and GQA decode land on
    // *different* own-optimal configurations (the 2.72x occupancy gap
    // made concrete — a full-scale claim), and the optimizer result is
    // deterministic at any scale.
    assert_eq!(result.frontiers.len(), 4);
    for f in &result.frontiers {
        assert!(!f.frontier.is_empty(), "{} frontier empty", f.workload);
    }
    assert!(
        smoke || result.frontiers[0].best_key != result.frontiers[1].best_key,
        "MHA and GQA decode should prefer different configurations"
    );
    let again = optimize(&workloads, &Constraints::default(), 0.0, None).unwrap();
    assert_eq!(again.portfolio.len(), result.portfolio.len());
    assert_eq!(again.robust_best().unwrap().key, best.key);
    for (a, b) in again.frontiers.iter().zip(&result.frontiers) {
        assert_eq!(a.frontier.len(), b.frontier.len());
    }
    // The optimizer is the cheap half of the offline flow.
    println!("optimizer pass mean: {:?}", stats.mean);

    let mut fields = stats.to_json();
    fields.extend([
        ("grid_points", Json::num(grid_points as f64)),
        ("workloads", Json::num(workloads.len() as f64)),
        ("portfolio_configs", Json::num(result.portfolio.len() as f64)),
        ("smoke", Json::Bool(smoke)),
    ]);
    let path = emit_json("pareto_optimize", fields).expect("bench artifact");
    println!("wrote {}", path.display());
}
