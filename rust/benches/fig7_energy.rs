//! Bench: regenerate Fig. 7 (on-chip energy breakdown + utilization).
//! Run: `cargo bench --bench fig7_energy`.

use trapti::api::{experiments as exp, ApiContext};
use trapti::report::figures;
use trapti::util::bench::{bench, default_iters};

fn main() {
    let ctx = ApiContext::new();
    let (_stats, pair) = bench("fig7_energy", default_iters(), || {
        exp::paired_prefill(&ctx).expect("stage1 pair")
    });
    print!("{}", figures::fig7(&pair));
    let e_mha = pair.mha.energy.on_chip_j();
    let e_gqa = pair.gqa.energy.on_chip_j();
    println!("on-chip energy: MHA {e_mha:.2} J (paper 78.47), GQA {e_gqa:.2} J (paper 40.52)");
    assert!(e_mha > e_gqa, "MHA must consume more on-chip energy");
    assert!(
        pair.gqa.result.active_utilization() > pair.mha.result.active_utilization(),
        "GQA must utilize the PEs better"
    );
}
