//! Bench: the attention-variant spectrum runner (`repro spectrum`) —
//! five matched presets (MHA → GQA → MQA → MLA → SWA), each through the
//! full Stage I decode → Stage II sweep pipeline plus the PIM-offload
//! closed form. Run: `cargo bench --bench attn_spectrum`.
//!
//! `TRAPTI_BENCH_SMOKE=1` shrinks the decode to CI scale. Either way the
//! run asserts the tentpole invariants — the peak-occupancy curve is
//! monotone across the shrinking-KV chain and a repeat run is
//! bit-identical — and emits `BENCH_attn_spectrum.json` for the perf
//! trajectory.

use trapti::api::experiments::spectrum;
use trapti::api::ApiContext;
use trapti::util::bench::{bench, default_iters, emit_json, smoke};
use trapti::util::json::Json;

fn main() {
    let ctx = ApiContext::new();
    let smoke = smoke();
    let (prompt, gen) = if smoke { (32u32, 4u32) } else { (256, 32) };
    println!(
        "spectrum decode {prompt}+{gen}{}",
        if smoke { " [smoke]" } else { "" }
    );

    let iters = default_iters();
    let (stats, s) = bench("attn_spectrum", iters, || {
        spectrum(&ctx, prompt, gen, None, false).expect("spectrum run")
    });

    assert_eq!(s.rows.len(), 5, "MHA, GQA, MQA, MLA, SWA");
    assert!(s.peak_is_monotone(), "peak curve must shrink with the KV");
    for r in &s.rows {
        println!(
            "  {:<14} peak {:>12} B  best dE {:+.1}%  E_pim {:.3e} J",
            r.name, r.peak_needed, r.best_delta_pct, r.pim_e_j
        );
        assert!(r.best_delta_pct <= 0.0, "{}: gating never hurts", r.name);
        assert!(r.pim_e_j > 0.0 && r.peak_needed > 0, "{}", r.name);
    }

    // Determinism: the report the CI gate diffs must be reproducible.
    let again = spectrum(&ctx, prompt, gen, None, false).expect("spectrum rerun");
    for (a, b) in s.rows.iter().zip(&again.rows) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.kv_bytes, b.kv_bytes);
        assert_eq!(a.peak_needed, b.peak_needed);
        assert_eq!(a.best_delta_pct.to_bits(), b.best_delta_pct.to_bits());
        assert_eq!(a.best_energy_j.to_bits(), b.best_energy_j.to_bits());
        assert_eq!(a.pim_e_j.to_bits(), b.pim_e_j.to_bits());
    }

    let spread = s.rows[0].peak_needed as f64 / s.rows[3].peak_needed.max(1) as f64;
    println!("MHA/MLA peak spread: {spread:.2}x");

    let mut fields = stats.to_json();
    fields.extend([
        ("variants", Json::num(s.rows.len() as f64)),
        ("prompt", Json::num(prompt as f64)),
        ("gen", Json::num(gen as f64)),
        ("peak_spread_mha_over_mla", Json::num(spread)),
        ("smoke", Json::Bool(smoke)),
    ]);
    let path = emit_json("attn_spectrum", fields).expect("bench artifact");
    println!("wrote {}", path.display());
}
