//! Bench: regenerate Fig. 9 (energy-area scatter over all (C, B)
//! candidates). Run: `cargo bench --bench fig9_tradeoff`.

use trapti::api::{experiments as exp, ApiContext};
use trapti::report::figures;
use trapti::util::bench::{bench, default_iters};

fn main() {
    let ctx = ApiContext::new();
    let pair = exp::paired_prefill(&ctx).expect("stage1 pair");
    let (_stats, t2) = bench("fig9_tradeoff", default_iters(), || {
        exp::table2(&ctx, &pair).expect("stage2")
    });
    print!("{}", figures::fig9(&t2));
    // DS-R1D must dominate: lower energy at comparable area (its reduced,
    // more variable memory demand gates more).
    let min_gqa = t2.gqa_points.iter().map(|p| p.eval.e_total_j()).fold(f64::MAX, f64::min);
    let min_mha = t2.mha_points.iter().map(|p| p.eval.e_total_j()).fold(f64::MAX, f64::min);
    println!("min energy: GQA {min_gqa:.2} J vs MHA {min_mha:.2} J");
    assert!(min_gqa < min_mha, "GQA candidates must reach lower energy");
}
