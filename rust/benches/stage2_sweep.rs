//! Bench: fused single-pass Stage-II sweep vs. the per-point naive
//! oracle on the paper's Table II grid over a fig10-style serving trace
//! (gpt2-xl, 256 requests, concurrency 64 — the CI acceptance scenario).
//! Run: `cargo bench --bench stage2_sweep`.
//!
//! The fused engine must be differentially identical to the oracle and
//! at least 5x faster on this grid: Stage II is supposed to be the cheap
//! offline pass of the two-stage flow, and the naive
//! O(grid × B × segments) walk broke that on serving-length traces.
//!
//! `TRAPTI_BENCH_SMOKE=1` shrinks the serving trace to CI scale (the
//! speedup-threshold assertion is waived there — spawn overhead and a
//! short trace make the ratio noise — but the differential identity
//! always holds). Emits `BENCH_stage2_sweep.json` for the perf
//! trajectory either way.

use trapti::api::ApiContext;
use trapti::banking::{sweep, sweep_naive, GatingPolicy, SweepSpec};
use trapti::serving::ServingParams;
use trapti::sim::serving::simulate_serving;
use trapti::util::bench::{bench, default_iters, emit_json, smoke};
use trapti::util::json::Json;
use trapti::util::MIB;
use trapti::workload::GPT2_XL;

fn main() {
    let ctx = ApiContext::new();
    let accel = trapti::config::baseline();
    let smoke = smoke();
    // Smoke scale matches the CI fused-determinism gate's known-good
    // serving scenario; full scale is the fig10 acceptance trace.
    let (requests, concurrency) = if smoke { (64, 8) } else { (256, 64) };
    let run = simulate_serving(
        &GPT2_XL,
        ServingParams::new(requests, concurrency, 7),
        &accel,
    )
    .expect("serving trace");
    let trace = &run.trace;
    let peak = trace.peak_needed();

    // Table II grid shape anchored at this trace's peak: six 16 MiB
    // capacity steps x the paper's bank set (36 points, alpha = 0.9).
    let start = peak.div_ceil(16 * MIB).max(1) * 16 * MIB;
    let grid = SweepSpec {
        capacities: (0u64..6).map(|i| start + i * 16 * MIB).collect(),
        banks: vec![1, 2, 4, 8, 16, 32],
        alphas: vec![0.9],
        policies: vec![GatingPolicy::Aggressive],
    };
    println!(
        "serving trace: {} samples, peak {:.1} MiB; grid: {} points{}",
        trace.samples().len(),
        peak as f64 / MIB as f64,
        grid.points(),
        if smoke { " [smoke]" } else { "" },
    );

    let iters = default_iters();
    let (naive_stats, naive_pts) = bench("stage2_sweep_naive", iters, || {
        sweep_naive(&ctx.cacti, trace, &run.stats, &grid, 1.0).expect("finalized trace")
    });
    let (fused_stats, fused_pts) = bench("stage2_sweep_fused", iters, || {
        sweep(&ctx.cacti, trace, &run.stats, &grid, 1.0).expect("finalized trace")
    });

    // Differential identity: the fused engine IS the production path.
    assert_eq!(fused_pts.len(), naive_pts.len());
    for (f, n) in fused_pts.iter().zip(&naive_pts) {
        assert_eq!(f.eval.e_total_j().to_bits(), n.eval.e_total_j().to_bits());
        assert_eq!(f.eval.n_switch, n.eval.n_switch);
        assert_eq!(
            f.eval.gated_fraction.to_bits(),
            n.eval.gated_fraction.to_bits()
        );
        assert_eq!(f.base_e_j.to_bits(), n.base_e_j.to_bits());
    }
    let best = fused_pts
        .iter()
        .map(|p| p.delta_e_pct())
        .fold(f64::INFINITY, f64::min);
    println!("best dE on the serving trace: {best:.1}%");
    assert!(best < 0.0, "banking must win on serving traffic");

    let speedup = naive_stats.mean.as_secs_f64() / fused_stats.mean.as_secs_f64();
    println!(
        "fused speedup over naive: {speedup:.1}x ({:?} -> {:?})",
        naive_stats.mean, fused_stats.mean
    );
    assert!(
        smoke || speedup >= 5.0,
        "fused Stage II must be >= 5x faster on the Table II grid \
         (got {speedup:.2}x)"
    );

    let mut fields = fused_stats.to_json();
    fields.extend([
        ("naive_wall_ms", Json::num(naive_stats.mean.as_secs_f64() * 1e3)),
        ("speedup_vs_naive", Json::num(speedup)),
        ("grid_points", Json::num(grid.points() as f64)),
        ("trace_samples", Json::num(trace.samples().len() as f64)),
        ("smoke", Json::Bool(smoke)),
    ]);
    let path = emit_json("stage2_sweep", fields).expect("bench artifact");
    println!("wrote {}", path.display());
}
