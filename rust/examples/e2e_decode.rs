//! End-to-end driver proving all three layers compose (the repository's
//! full-system validation, DESIGN.md):
//!
//! 1. **Functional path** — loads the AOT-compiled JAX+Pallas decode
//!    model (HLO text -> PJRT CPU) and auto-regressively generates real
//!    tokens with a host-side KV cache, logging the activation-magnitude
//!    curve (the serving analogue of a loss curve).
//! 2. **Performance path** — Stage-I-simulates the *same* decode
//!    workload shape on the paper's accelerator through `trapti::api`
//!    and reports latency/throughput.
//! 3. **Optimization path** — Stage II picks the best banked SRAM with
//!    power gating for that workload.
//!
//! Requires `make artifacts` (build-time Python; never on this path)
//! and a build with the real `xla` crate (offline builds link a stub —
//! see rust/src/runtime/xla_stub.rs).
//!
//! Run: `cargo run --release --example e2e_decode`

use trapti::api::{ApiContext, ExperimentSpec};
use trapti::banking::{GatingPolicy, SweepSpec};
use trapti::config::tiny;
use trapti::runtime::{default_artifact_dir, DecodeSession, Manifest, Runtime};
use trapti::util::MIB;
use trapti::workload::TINY_GQA;

fn main() -> anyhow::Result<()> {
    // ---- 1. functional decode through PJRT ---------------------------
    let manifest = Manifest::load(&default_artifact_dir())?;
    let mut rt = Runtime::new(manifest)?;
    println!("PJRT platform: {}", rt.platform());
    let mut sess = DecodeSession::new(&mut rt, "tiny-gqa", 42)?;
    let steps = 96;
    let t0 = std::time::Instant::now();
    let mags = sess.generate(&mut rt, steps, 7)?;
    let wall = t0.elapsed();
    println!(
        "functional: generated {steps} tokens in {:.1} ms \
         ({:.2} ms/token, all finite)",
        wall.as_secs_f64() * 1e3,
        wall.as_secs_f64() * 1e3 / steps as f64,
    );
    println!("activation magnitude curve (every 12th step):");
    for (i, m) in mags.iter().enumerate().step_by(12) {
        println!("  step {i:>3}: {m:.4} {}", "#".repeat((m * 20.0) as usize));
    }

    // ---- 2. performance model of the same workload shape -------------
    let ctx = ApiContext::new();
    let s1 = ExperimentSpec::builder()
        .model(TINY_GQA)
        .decode(32, steps as u32)
        .accel(tiny())
        .sweep(SweepSpec {
            capacities: vec![MIB, 2 * MIB, 4 * MIB],
            banks: vec![1, 2, 4, 8],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive],
        })
        .build()?
        .run_stage1(&ctx)?;
    println!(
        "\nperformance model: {} ops, {:.3} ms simulated \
         ({:.1} us/token), peak SRAM {:.2} MiB",
        s1.graph.ops.len(),
        s1.result.seconds() * 1e3,
        s1.result.seconds() * 1e6 / steps as f64,
        s1.result.peak_needed() as f64 / MIB as f64,
    );

    // ---- 3. Stage-II optimization for this workload -------------------
    let s2 = s1.stage2(&ctx)?;
    let best = s2.best().expect("sweep non-empty");
    println!(
        "stage II: best organization C={} MiB, B={} -> {:.1}% SRAM energy \
         vs unbanked ({} candidates evaluated)",
        best.eval.capacity / MIB,
        best.eval.banks,
        best.delta_e_pct(),
        s2.shared().len(),
    );
    println!("\nall three layers compose: OK");
    Ok(())
}
