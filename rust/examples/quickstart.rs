//! Quickstart: the complete TRAPTI two-stage flow through `trapti::api`
//! — spec builder, Stage-I run, typed Stage-II handle.
//!
//! Stage I simulates DeepSeek-R1-Distill-Qwen-1.5B prefill (M=2048) on
//! the paper's baseline accelerator and extracts the time-resolved SRAM
//! occupancy trace; Stage II sweeps banked organizations with power
//! gating and prints the energy/area candidates.
//!
//! Run: `cargo run --release --example quickstart`

use trapti::api::{ApiContext, ExperimentSpec};
use trapti::banking::{GatingPolicy, SweepSpec};
use trapti::util::MIB;
use trapti::workload::DS_R1D_Q15B;

fn main() -> anyhow::Result<()> {
    let ctx = ApiContext::new();

    // --- Spec: model x workload x accelerator x sweep grid -------------
    let spec = ExperimentSpec::builder()
        .model(DS_R1D_Q15B)
        .prefill(2048) // baseline accelerator is the default
        .sweep(SweepSpec {
            capacities: vec![48 * MIB, 64 * MIB, 128 * MIB],
            banks: vec![1, 4, 8, 16],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive],
        })
        .build()?;
    println!("spec {:016x}", spec.content_hash());

    // --- Stage I: cycle-level simulation + occupancy trace ------------
    let s1 = spec.run_stage1(&ctx)?;
    println!("{}", s1.graph.summary());
    println!(
        "Stage I: {:.1} ms simulated, peak needed {:.1} MiB, \
         {} SRAM reads, feasible={}",
        s1.result.seconds() * 1e3,
        s1.result.peak_needed() as f64 / MIB as f64,
        s1.result.stats.reads,
        s1.result.feasible(),
    );

    // --- Stage II: banking + power-gating exploration ------------------
    // (typed handle: only obtainable from a Stage-I run, reading the
    // occupancy trace through a borrowed view).
    let s2 = s1.stage2(&ctx)?;
    println!("\nStage II (alpha=0.9, aggressive gating):");
    println!(
        "{:>8} {:>6} {:>12} {:>8} {:>12}",
        "C[MiB]", "banks", "E_total[J]", "dE%", "area[mm2]"
    );
    for p in s2.shared() {
        println!(
            "{:>8} {:>6} {:>12.2} {:>8.1} {:>12.1}",
            p.eval.capacity / MIB,
            p.eval.banks,
            p.eval.e_total_j(),
            p.delta_e_pct(),
            p.eval.area_mm2,
        );
    }
    Ok(())
}
