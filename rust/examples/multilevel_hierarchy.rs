//! Multi-level on-chip hierarchy (paper §IV-D, Fig. 10, Table III):
//! shared SRAM + two dedicated memories attached to SA pairs, with the
//! non-optimized placement that produces cross-memory data hopping.
//! Runs through `trapti::api` (single-level reference + multi-level
//! Table III with defensive per-memory sweeps).
//!
//! Run: `cargo run --release --example multilevel_hierarchy`

use trapti::api::{experiments as exp, ApiContext, ExperimentSpec};
use trapti::config::baseline;
use trapti::report::tables;
use trapti::util::MIB;
use trapti::workload::DS_R1D_Q15B;

fn main() -> anyhow::Result<()> {
    let ctx = ApiContext::new();

    // Single-level reference.
    let single = ExperimentSpec::builder()
        .model(DS_R1D_Q15B)
        .prefill(2048)
        .accel(baseline())
        .build()?
        .run_stage1(&ctx)?;
    // Multi-level run.
    let t3 = exp::table3(&ctx)?;
    let multi = &t3.stage1;

    println!("DS-R1D Q-1.5B prefill, single vs multi-level hierarchy:");
    println!(
        "{:>24} {:>12} {:>12}",
        "", "single", "multi-level"
    );
    println!(
        "{:>24} {:>9.1} ms {:>9.1} ms   (paper: 313.6 -> 550 ms)",
        "end-to-end",
        single.result.seconds() * 1e3,
        multi.result.seconds() * 1e3,
    );
    println!(
        "{:>24} {:>11.0}% {:>11.0}%   (paper: 77% -> 57%)",
        "active PE utilization",
        single.result.active_utilization() * 100.0,
        multi.result.active_utilization() * 100.0,
    );
    println!(
        "{:>24} {:>10.1} J {:>10.1} J   (paper: 40.5 -> 73.4 J)",
        "on-chip energy",
        single.energy.on_chip_j(),
        multi.energy.on_chip_j(),
    );
    println!("\nper-memory peak needed bytes:");
    for tr in multi.traces() {
        println!(
            "  {:>6}: {:>6.1} MiB (paper: sram 34.1, dm1 35.5, dm2 37.7)",
            tr.memory,
            tr.peak_needed() as f64 / MIB as f64
        );
    }
    println!();
    for t in tables::table3(&t3) {
        print!("{}", t.render());
    }
    println!(
        "\nbest per-memory reduction: {:.1}% (paper: up to -77.8%)",
        t3.best_delta()
    );
    Ok(())
}
