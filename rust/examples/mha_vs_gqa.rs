//! MHA vs GQA comparison — the paper's central narrative (Figs. 1, 5-7):
//! same accelerator, two attention mechanisms, radically different
//! on-chip memory behavior. Both Stage-I runs execute as one parallel
//! batch through `trapti::api::experiments`.
//!
//! Run: `cargo run --release --example mha_vs_gqa`

use trapti::api::{experiments as exp, ApiContext};
use trapti::report::figures;
use trapti::util::MIB;

fn main() -> anyhow::Result<()> {
    let ctx = ApiContext::new();

    // Decode-phase motivation (Fig. 1): a parameter-matched pair.
    let f1 = exp::fig1(&ctx)?;
    print!("{}", figures::fig1(&f1));

    // Prefill at M=2048 on the 128 MiB baseline (Figs. 5-7).
    let pair = exp::paired_prefill(&ctx)?;
    println!(
        "\npeak needed: MHA {:.1} MiB vs GQA {:.1} MiB -> {:.2}x \
         (paper 107.3 vs 39.1 = 2.72x)",
        pair.mha.result.peak_needed() as f64 / MIB as f64,
        pair.gqa.result.peak_needed() as f64 / MIB as f64,
        pair.peak_ratio(),
    );
    println!(
        "end-to-end: MHA {:.1} ms vs GQA {:.1} ms -> {:.2}x (paper 1.89x)",
        pair.mha.result.seconds() * 1e3,
        pair.gqa.result.seconds() * 1e3,
        pair.time_ratio(),
    );
    let (fig5_text, _, _) = figures::fig5(&pair);
    print!("{fig5_text}");
    print!("{}", figures::fig6(&pair));
    print!("{}", figures::fig7(&pair));
    Ok(())
}
