//! Experiment-file loader: maps a `configs/*.toml` file onto a model
//! preset, an accelerator configuration (preset + overrides), and a
//! Stage-II sweep spec — the launcher-facing config surface.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::banking::{GatingPolicy, SweepSpec};
use crate::workload::{preset, ModelPreset};

use super::parse::{parse_bytes, Config, Value};
use super::{named, AccelConfig};

#[derive(Debug, Clone)]
pub struct Experiment {
    pub model: ModelPreset,
    pub accel: AccelConfig,
    pub sweep: SweepSpec,
}

pub fn load(path: &Path) -> Result<Experiment> {
    from_config(&Config::load(path)?)
}

pub fn from_config(cfg: &Config) -> Result<Experiment> {
    let model_name = cfg.str("workload")?;
    let model = preset(model_name)
        .ok_or_else(|| anyhow!("unknown workload preset `{model_name}`"))?;

    let accel_name = cfg.str_or("accelerator.preset", "baseline");
    let mut accel =
        named(accel_name).ok_or_else(|| anyhow!("unknown accel `{accel_name}`"))?;
    if let Ok(cap) = cfg.bytes("accelerator.sram_capacity") {
        accel.on_chip[0].capacity = cap;
    }
    if let Ok(p) = cfg.u64("accelerator.sram_ports") {
        accel.on_chip[0].ports = p as u32;
    }
    if let Ok(l) = cfg.u64("accelerator.sram_latency_ns") {
        accel.on_chip[0].latency_cycles = l;
    }
    if let Ok(cap) = cfg.bytes("accelerator.dram_capacity") {
        accel.dram.capacity = cap;
    }
    if let Ok(p) = cfg.u64("accelerator.dram_ports") {
        accel.dram.ports = p as u32;
    }
    if let Ok(l) = cfg.u64("accelerator.dram_latency_ns") {
        accel.dram.latency_cycles = l;
    }
    if let Ok(s) = cfg.u64("compute.subops") {
        accel.sched.subops = s as u32;
    }
    accel.validate()?;

    let banks = cfg
        .u64_array("stage2.banks")
        .unwrap_or_else(|_| vec![1, 2, 4, 8, 16, 32])
        .into_iter()
        .map(|b| b as u32)
        .collect();
    let capacities = match cfg.get("stage2.capacities") {
        Some(Value::Array(items)) => items
            .iter()
            .map(|v| {
                v.as_str()
                    .ok_or_else(|| anyhow!("capacities must be size strings"))
                    .and_then(parse_bytes)
            })
            .collect::<Result<Vec<_>>>()?,
        _ => vec![accel.on_chip[0].capacity],
    };
    let policy = match cfg.str_or("stage2.policy", "aggressive") {
        "aggressive" => GatingPolicy::Aggressive,
        "conservative" => GatingPolicy::conservative(),
        "none" => GatingPolicy::None,
        other => anyhow::bail!("unknown gating policy `{other}`"),
    };
    Ok(Experiment {
        model,
        accel,
        sweep: SweepSpec {
            capacities,
            banks,
            alphas: vec![cfg.f64_or("stage2.alpha", 0.9)],
            policies: vec![policy],
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    #[test]
    fn loads_repo_config_files() {
        for name in ["configs/baseline.toml", "configs/multilevel.toml"] {
            let path = Path::new(env!("CARGO_MANIFEST_DIR")).join(name);
            let e = load(&path).unwrap_or_else(|err| panic!("{name}: {err:#}"));
            assert!(!e.sweep.banks.is_empty());
            assert!(!e.sweep.capacities.is_empty());
            e.accel.validate().unwrap();
        }
    }

    #[test]
    fn overrides_apply() {
        let cfg = Config::parse(
            r#"
workload = "tiny-gqa"
[accelerator]
preset = "tiny"
sram_capacity = "8MiB"
[stage2]
alpha = 0.75
banks = [1, 2]
"#,
        )
        .unwrap();
        let e = from_config(&cfg).unwrap();
        assert_eq!(e.model.name, "tiny-gqa");
        assert_eq!(e.accel.on_chip[0].capacity, 8 * MIB);
        assert_eq!(e.sweep.alphas, vec![0.75]);
        assert_eq!(e.sweep.banks, vec![1, 2]);
    }

    #[test]
    fn unknown_model_rejected() {
        let cfg = Config::parse("workload = \"nope\"").unwrap();
        assert!(from_config(&cfg).is_err());
    }
}
