//! Accelerator / simulation configuration.
//!
//! Mirrors the paper's §IV-A experimental setup: four 128x128 systolic
//! arrays at 1 GHz (one 8-bit MAC per PE per cycle), per-array row/column
//! FIFOs (128 lanes x 256 entries), a shared on-chip SRAM (128 MiB,
//! 512-bit interface, 4 ports, 32 ns) and off-chip DRAM (2 GiB, 2 ports,
//! 80 ns). `subops = 4` decomposes large matmuls across the arrays.
//!
//! Configs load from a small TOML-subset file format (`parse` module) or
//! from the named presets here.

pub mod experiment;
pub mod parse;
pub mod presets;

pub use experiment::{load as load_experiment, Experiment};
pub use presets::{baseline, multilevel, named, tiny};

/// Systolic-array compute subsystem.
#[derive(Debug, Clone, PartialEq)]
pub struct SaConfig {
    /// PE rows per array (128 in the paper's template).
    pub rows: u32,
    /// PE columns per array.
    pub cols: u32,
    /// Number of identical arrays (4).
    pub count: u32,
    /// Clock in GHz (1.0); cycles below are in this clock domain.
    pub freq_ghz: f64,
}

impl SaConfig {
    /// Peak MAC throughput across all arrays, MAC/s.
    pub fn peak_macs_per_s(&self) -> f64 {
        self.rows as f64 * self.cols as f64 * self.count as f64 * self.freq_ghz * 1e9
    }

    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_ghz * 1e9)
    }
}

/// Row/column FIFO stacks feeding each array.
#[derive(Debug, Clone, PartialEq)]
pub struct FifoConfig {
    /// Lanes per FIFO (matches the array edge: 128).
    pub lanes: u32,
    /// Depth in elements per lane (256).
    pub depth: u32,
}

/// One memory component (shared SRAM, dedicated memory, or DRAM).
#[derive(Debug, Clone, PartialEq)]
pub struct MemConfig {
    pub name: String,
    pub capacity: u64,
    /// Physical ports; each serves one transfer at a time.
    pub ports: u32,
    /// Interface width in bytes per cycle per port (512-bit = 64 B).
    pub bytes_per_cycle: u32,
    /// Access latency in cycles (1 GHz: 1 cycle = 1 ns).
    pub latency_cycles: u64,
}

impl MemConfig {
    /// Aggregate bandwidth in bytes/cycle.
    pub fn bandwidth(&self) -> u64 {
        self.ports as u64 * self.bytes_per_cycle as u64
    }
}

/// Scheduler behavior (TransInferSim-style in-order issue).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedConfig {
    /// Sub-operation decomposition factor (paper: subops = 4).
    pub subops: u32,
    /// In-order issue window, in ops: an op may only be dispatched when
    /// fewer than `issue_window` graph-order predecessors are still
    /// incomplete. Bounds how far execution runs ahead (and therefore
    /// how many transient tensors pile up).
    pub issue_window: usize,
    /// Issue window in schedule *stages* (layers for prefill,
    /// token-layers for decode): an op may issue only while its stage is
    /// within `window_stages` of the earliest incomplete op's stage.
    /// This is TransInferSim's layer-synchronized plan semantics and the
    /// knob that bounds per-layer transient pile-up model-independently.
    pub window_stages: u32,
    /// Weight prefetch lookahead, in ops ahead of the issue watermark.
    pub weight_prefetch_ops: usize,
    /// Bandwidth of the memory-path engine executing softmax / norm /
    /// element-wise ops (bytes per cycle). These ops run on a dedicated
    /// near-memory unit rather than reserving the SRAM data ports, so
    /// their throughput (vs. matmul issue rate) sets how fast attention
    /// transients retire — the emergent mechanism behind the MHA/GQA
    /// occupancy gap (EXPERIMENTS.md §Calibration).
    pub mem_path_bytes_per_cycle: u32,
    /// When true, weights are fetched into the shared SRAM once and stay
    /// resident (models small enough to fit on chip — the Fig. 1 matched
    /// pair). When false (default), the weight-stationary arrays stream
    /// weights DRAM -> PE registers and SRAM never holds them.
    pub weight_resident: bool,
}

/// Which memory each of the `SaConfig::count` arrays streams from, for
/// multi-level hierarchies (Fig. 10). `mem_of_sa[i]` indexes
/// `AccelConfig::on_chip`; single-memory setups use all zeros.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub mem_of_sa: Vec<u8>,
}

/// Full accelerator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct AccelConfig {
    pub name: String,
    pub sa: SaConfig,
    pub fifo: FifoConfig,
    /// On-chip memories; index 0 is the shared SRAM (DRAM-facing).
    pub on_chip: Vec<MemConfig>,
    pub dram: MemConfig,
    pub sched: SchedConfig,
    pub topology: Topology,
}

impl AccelConfig {
    pub fn shared_sram(&self) -> &MemConfig {
        &self.on_chip[0]
    }

    /// Validate internal consistency (fail loudly before simulating).
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::ensure;
        ensure!(self.sa.count > 0 && self.sa.rows > 0 && self.sa.cols > 0);
        ensure!(!self.on_chip.is_empty(), "need at least the shared SRAM");
        ensure!(
            self.topology.mem_of_sa.len() == self.sa.count as usize,
            "topology must map every systolic array to a memory"
        );
        for &m in &self.topology.mem_of_sa {
            ensure!(
                (m as usize) < self.on_chip.len(),
                "SA mapped to unknown memory {m}"
            );
        }
        for m in self.on_chip.iter().chain(std::iter::once(&self.dram)) {
            ensure!(m.capacity > 0 && m.ports > 0 && m.bytes_per_cycle > 0);
        }
        ensure!(self.sched.subops >= 1);
        ensure!(self.sched.issue_window >= 1);
        Ok(())
    }

    /// Clone with a different shared-SRAM capacity (+latency), for the
    /// Stage-I sizing loop and the Stage-II capacity sweeps.
    pub fn with_sram_capacity(&self, capacity: u64, latency_cycles: u64) -> Self {
        let mut c = self.clone();
        c.on_chip[0].capacity = capacity;
        c.on_chip[0].latency_cycles = latency_cycles;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_matches_paper_setup() {
        let c = baseline();
        c.validate().unwrap();
        assert_eq!(c.sa.count, 4);
        assert_eq!(c.sa.rows, 128);
        // Peak 65.5 TMAC/s (paper §IV-A).
        assert!((c.sa.peak_macs_per_s() / 1e12 - 65.536).abs() < 0.01);
        assert_eq!(c.shared_sram().capacity, 128 * crate::util::MIB);
        assert_eq!(c.shared_sram().latency_cycles, 32);
        assert_eq!(c.shared_sram().ports, 4);
        assert_eq!(c.shared_sram().bytes_per_cycle, 64);
        assert_eq!(c.dram.capacity, 2 * crate::util::GIB);
        assert_eq!(c.dram.latency_cycles, 80);
        assert_eq!(c.sched.subops, 4);
    }

    #[test]
    fn multilevel_has_three_memories() {
        let c = multilevel();
        c.validate().unwrap();
        assert_eq!(c.on_chip.len(), 3);
        // Two SAs on DM1, two on DM2 (Fig. 10).
        assert_eq!(c.topology.mem_of_sa, vec![1, 1, 2, 2]);
        for m in &c.on_chip {
            assert_eq!(m.capacity, 64 * crate::util::MIB);
        }
    }

    #[test]
    fn validate_catches_bad_topology() {
        let mut c = baseline();
        c.topology.mem_of_sa = vec![0, 0, 9, 0];
        assert!(c.validate().is_err());
        c.topology.mem_of_sa = vec![0];
        assert!(c.validate().is_err());
    }

    #[test]
    fn with_sram_capacity_swaps_only_shared() {
        let c = baseline().with_sram_capacity(64 * crate::util::MIB, 22);
        assert_eq!(c.shared_sram().capacity, 64 * crate::util::MIB);
        assert_eq!(c.shared_sram().latency_cycles, 22);
        assert_eq!(c.dram, baseline().dram);
    }

    #[test]
    fn cycles_to_seconds() {
        let c = baseline();
        assert!((c.sa.cycles_to_seconds(1_000_000_000) - 1.0).abs() < 1e-12);
    }
}
