//! Named accelerator presets matching the paper's evaluated setups.

use crate::util::{GIB, MIB};

use super::{
    AccelConfig, FifoConfig, MemConfig, SaConfig, SchedConfig, Topology,
};

fn sa_default() -> SaConfig {
    SaConfig {
        rows: 128,
        cols: 128,
        count: 4,
        freq_ghz: 1.0,
    }
}

fn fifo_default() -> FifoConfig {
    FifoConfig {
        lanes: 128,
        depth: 256,
    }
}

fn sched_default() -> SchedConfig {
    SchedConfig {
        subops: 4,
        // Calibrated in EXPERIMENTS.md §Calibration: wide enough that a
        // full MHA attention stage (25 head chains, 3 ops each) can run
        // ahead, as the paper's GPT-2 XL trace implies.
        issue_window: 80,
        window_stages: 1,
        weight_prefetch_ops: 8,
        mem_path_bytes_per_cycle: 122,
        weight_resident: false,
    }
}

/// The paper's baseline: single shared 128 MiB SRAM (512-bit, 4 ports,
/// 32 ns), 2 GiB DRAM (2 ports, 80 ns), 4x 128x128 SAs at 1 GHz.
pub fn baseline() -> AccelConfig {
    AccelConfig {
        name: "baseline-128MiB".into(),
        sa: sa_default(),
        fifo: fifo_default(),
        on_chip: vec![MemConfig {
            name: "sram".into(),
            capacity: 128 * MIB,
            ports: 4,
            bytes_per_cycle: 64, // 512-bit interface
            latency_cycles: 32,  // 32 ns at 1 GHz
        }],
        dram: MemConfig {
            name: "dram".into(),
            capacity: 2 * GIB,
            ports: 2,
            bytes_per_cycle: 64,
            latency_cycles: 80,
        },
        sched: sched_default(),
        topology: Topology {
            mem_of_sa: vec![0, 0, 0, 0],
        },
    }
}

/// §IV-D multi-level hierarchy: shared SRAM + two dedicated memories
/// (each attached to a pair of SAs), all 64 MiB. The shared SRAM fetches
/// from DRAM and backs the dedicated memories (Fig. 10).
pub fn multilevel() -> AccelConfig {
    let mem = |name: &str| MemConfig {
        name: name.into(),
        capacity: 64 * MIB,
        ports: 4,
        bytes_per_cycle: 64,
        latency_cycles: 22, // CACTI latency at 64 MiB (paper §IV-B)
    };
    AccelConfig {
        name: "multilevel-3x64MiB".into(),
        sa: sa_default(),
        fifo: fifo_default(),
        on_chip: vec![mem("sram"), mem("dm1"), mem("dm2")],
        dram: baseline().dram,
        sched: sched_default(),
        topology: Topology {
            mem_of_sa: vec![1, 1, 2, 2],
        },
    }
}

/// Scaled-down template for unit/integration tests and the tiny
/// functional models: one 2x 32x32 SA accelerator with a 4 MiB SRAM.
pub fn tiny() -> AccelConfig {
    AccelConfig {
        name: "tiny-test".into(),
        sa: SaConfig {
            rows: 32,
            cols: 32,
            count: 2,
            freq_ghz: 1.0,
        },
        fifo: FifoConfig {
            lanes: 32,
            depth: 64,
        },
        on_chip: vec![MemConfig {
            name: "sram".into(),
            capacity: 4 * MIB,
            ports: 2,
            bytes_per_cycle: 32,
            latency_cycles: 8,
        }],
        dram: MemConfig {
            name: "dram".into(),
            capacity: GIB,
            ports: 2,
            bytes_per_cycle: 32,
            latency_cycles: 40,
        },
        sched: SchedConfig {
            subops: 2,
            issue_window: 48,
            window_stages: 1,
            weight_prefetch_ops: 4,
            mem_path_bytes_per_cycle: 122,
            weight_resident: false,
        },
        topology: Topology {
            mem_of_sa: vec![0, 0],
        },
    }
}

/// Preset lookup for the CLI / config files.
pub fn named(name: &str) -> Option<AccelConfig> {
    match name {
        "baseline" | "baseline-128MiB" => Some(baseline()),
        "multilevel" | "multilevel-3x64MiB" => Some(multilevel()),
        "tiny" | "tiny-test" => Some(tiny()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate() {
        for p in [baseline(), multilevel(), tiny()] {
            p.validate().unwrap();
        }
    }

    #[test]
    fn named_lookup() {
        assert!(named("baseline").is_some());
        assert!(named("multilevel").is_some());
        assert!(named("tiny").is_some());
        assert!(named("xyz").is_none());
    }
}
