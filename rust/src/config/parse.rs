//! Minimal TOML-subset parser for experiment config files (no `toml`
//! crate offline).
//!
//! Supported grammar (sufficient for `configs/*.toml`):
//!
//! ```toml
//! # comment
//! [section]            # or [section.sub]
//! key = 42             # integer
//! cap = "128MiB"       # sizes as quoted strings with units
//! ratio = 0.9          # float
//! name = "gpt2-xl"     # string
//! flag = true          # bool
//! banks = [1, 2, 4]    # arrays of ints/floats/strings
//! ```
//!
//! Values keep their section-qualified key (`section.key`). Lookup
//! helpers convert with descriptive errors.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::{GIB, KIB, MIB};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// A parsed config: flat map of `section.key` -> value.
#[derive(Debug, Default, Clone)]
pub struct Config {
    pub values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let parsed = parse_value(val.trim())
                .with_context(|| format!("line {}: bad value for `{key}`", lineno + 1))?;
            if values.insert(full_key.clone(), parsed).is_some() {
                bail!("line {}: duplicate key `{full_key}`", lineno + 1);
            }
        }
        Ok(Self { values })
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("config: missing string `{key}`"))
    }

    pub fn u64(&self, key: &str) -> Result<u64> {
        let v = self
            .get(key)
            .and_then(Value::as_i64)
            .ok_or_else(|| anyhow!("config: missing integer `{key}`"))?;
        u64::try_from(v).map_err(|_| anyhow!("config: `{key}` must be >= 0"))
    }

    pub fn f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow!("config: missing number `{key}`"))
    }

    /// Byte size: integer, or string with KiB/MiB/GiB suffix.
    pub fn bytes(&self, key: &str) -> Result<u64> {
        match self.get(key) {
            Some(Value::Int(v)) if *v >= 0 => Ok(*v as u64),
            Some(Value::Str(s)) => parse_bytes(s),
            _ => bail!("config: missing byte size `{key}`"),
        }
    }

    pub fn u64_array(&self, key: &str) -> Result<Vec<u64>> {
        match self.get(key) {
            Some(Value::Array(items)) => items
                .iter()
                .map(|v| {
                    v.as_i64()
                        .and_then(|i| u64::try_from(i).ok())
                        .ok_or_else(|| anyhow!("config: `{key}` must be unsigned ints"))
                })
                .collect(),
            _ => bail!("config: missing array `{key}`"),
        }
    }

    /// Keys with defaults.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.u64(key).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.f64(key).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str(key).unwrap_or(default)
    }
}

/// Parse sizes like "128MiB", "2 GiB", "512KiB", "64".
pub fn parse_bytes(s: &str) -> Result<u64> {
    let s = s.trim();
    let (num, mult) = if let Some(p) = s.strip_suffix("GiB") {
        (p, GIB)
    } else if let Some(p) = s.strip_suffix("MiB") {
        (p, MIB)
    } else if let Some(p) = s.strip_suffix("KiB") {
        (p, KIB)
    } else if let Some(p) = s.strip_suffix('B') {
        (p, 1)
    } else {
        (s, 1)
    };
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|e| anyhow!("bad byte size `{s}`: {e}"))?;
    if v < 0.0 {
        bail!("negative byte size `{s}`");
    }
    Ok((v * mult as f64).round() as u64)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings is preserved.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value `{s}`")
}

/// Split on commas not nested in strings (arrays of strings may contain
/// commas).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
workload = "gpt2-xl"       # model preset

[accelerator]
preset = "baseline"
sram_capacity = "128MiB"
ports = 4

[stage2]
alpha = 0.9
banks = [1, 2, 4, 8, 16, 32]
capacities = ["48MiB", "64MiB"]
gate = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("workload").unwrap(), "gpt2-xl");
        assert_eq!(c.str("accelerator.preset").unwrap(), "baseline");
        assert_eq!(c.bytes("accelerator.sram_capacity").unwrap(), 128 * MIB);
        assert_eq!(c.u64("accelerator.ports").unwrap(), 4);
        assert!((c.f64("stage2.alpha").unwrap() - 0.9).abs() < 1e-12);
        assert_eq!(
            c.u64_array("stage2.banks").unwrap(),
            vec![1, 2, 4, 8, 16, 32]
        );
        assert_eq!(c.get("stage2.gate").unwrap(), &Value::Bool(true));
    }

    #[test]
    fn byte_units() {
        assert_eq!(parse_bytes("64").unwrap(), 64);
        assert_eq!(parse_bytes("64B").unwrap(), 64);
        assert_eq!(parse_bytes("2KiB").unwrap(), 2048);
        assert_eq!(parse_bytes("1.5 MiB").unwrap(), 3 * MIB / 2);
        assert_eq!(parse_bytes("2GiB").unwrap(), 2 * GIB);
        assert!(parse_bytes("-2MiB").is_err());
        assert!(parse_bytes("xMiB").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("k = ").is_err());
        assert!(Config::parse("k = [1,").is_err());
        assert!(Config::parse("k = \"open").is_err());
        assert!(Config::parse("k = 1\nk = 2").is_err());
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let c = Config::parse("k = \"a#b\" # trailing").unwrap();
        assert_eq!(c.str("k").unwrap(), "a#b");
    }

    #[test]
    fn defaults() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.u64_or("missing", 7), 7);
        assert_eq!(c.str_or("missing", "x"), "x");
        assert!((c.f64_or("missing", 0.5) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn string_arrays() {
        let c = Config::parse("caps = [\"48MiB\", \"64MiB\"]").unwrap();
        if let Some(Value::Array(items)) = c.get("caps") {
            assert_eq!(items.len(), 2);
            assert_eq!(items[0].as_str().unwrap(), "48MiB");
        } else {
            panic!("expected array");
        }
    }
}
