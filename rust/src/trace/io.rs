//! Trace (de)serialization: JSON for tooling, CSV for plotting.
//!
//! Stage II can run entirely offline from a saved trace (`repro simulate
//! --save-trace` -> `repro bank --trace`), decoupling the expensive
//! simulation from the cheap exploration exactly as the paper's two-stage
//! flow prescribes.

use std::path::Path;

use anyhow::{anyhow, ensure, Context, Result};

use crate::util::json::{parse, Json};

use super::occupancy::OccupancyTrace;
use super::sink::MemoryDesc;

pub fn trace_to_json(tr: &OccupancyTrace) -> Json {
    Json::obj(vec![
        ("memory", Json::str(tr.memory.clone())),
        ("capacity", Json::num(tr.capacity as f64)),
        (
            "end_time",
            tr.end_time()
                .map(|t| Json::num(t as f64))
                .unwrap_or(Json::Null),
        ),
        (
            "samples",
            Json::arr(tr.samples().iter().map(|s| {
                Json::arr([
                    Json::num(s.t as f64),
                    Json::num(s.needed as f64),
                    Json::num(s.obsolete as f64),
                ])
            })),
        ),
    ])
}

pub fn trace_from_json(j: &Json) -> Result<OccupancyTrace> {
    let memory = j
        .expect("memory")?
        .as_str()
        .ok_or_else(|| anyhow!("memory must be a string"))?;
    let capacity = j
        .expect("capacity")?
        .as_u64()
        .ok_or_else(|| anyhow!("capacity must be u64"))?;
    let mut tr = OccupancyTrace::new(memory, capacity);
    let samples = j
        .expect("samples")?
        .as_arr()
        .ok_or_else(|| anyhow!("samples must be an array"))?;
    for s in samples {
        let trip = s.as_arr().ok_or_else(|| anyhow!("sample must be array"))?;
        if trip.len() != 3 {
            return Err(anyhow!("sample must have 3 fields"));
        }
        let get = |i: usize| -> Result<u64> {
            trip[i]
                .as_u64()
                .ok_or_else(|| anyhow!("sample field {i} must be u64"))
        };
        tr.record(get(0)?, get(1)?, get(2)?);
    }
    if let Some(end) = j.expect("end_time")?.as_u64() {
        tr.finalize(end);
    }
    tr.validate()?;
    Ok(tr)
}

pub fn save_trace(tr: &OccupancyTrace, path: &Path) -> Result<()> {
    std::fs::write(path, trace_to_json(tr).to_string_compact())
        .with_context(|| format!("writing trace to {}", path.display()))
}

pub fn load_trace(path: &Path) -> Result<OccupancyTrace> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace from {}", path.display()))?;
    trace_from_json(&parse(&text)?)
}

/// Header emitted by [`super::sink::CsvStreamSink`].
pub const STREAM_CSV_HEADER: &str = "memory,t_cycles,needed_bytes,obsolete_bytes";

/// Typed error for a stream-CSV row whose timestamp precedes an earlier
/// row of the same memory — the input violates
/// [`OccupancyTrace::record`]'s monotonicity contract, so the trace
/// cannot be reconstructed. Carried inside the `anyhow::Error` returned
/// by [`stream_csv_to_traces`]; recover it with
/// `err.downcast_ref::<StreamOrderError>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamOrderError {
    /// Memory column of the offending row.
    pub memory: String,
    /// 1-based CSV line number of the offending row (header = line 1).
    pub row: usize,
    /// Timestamp of the latest earlier row for this memory.
    pub prev_t: u64,
    /// The offending (earlier) timestamp.
    pub t: u64,
}

impl std::fmt::Display for StreamOrderError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "stream CSV row {}: time went backwards for `{}` ({} after {})",
            self.row, self.memory, self.t, self.prev_t
        )
    }
}

impl std::error::Error for StreamOrderError {}

/// Parse a [`super::sink::CsvStreamSink`] export back into one finalized
/// trace per memory.
///
/// The stream is raw — several rows may share one `(memory, t)`, in
/// which case only the last is observable — so reconstruction goes
/// through [`OccupancyTrace::record`], whose overwrite/coalesce
/// semantics are exactly the stream's. Capacities and the end time are
/// not part of the CSV; the caller supplies them (the same
/// [`MemoryDesc`] list the sink was `begin`-ed with, and the run's end).
/// Output order matches `memories`; a row naming an unknown memory is an
/// error.
pub fn stream_csv_to_traces(
    csv: &str,
    memories: &[MemoryDesc],
    end: u64,
) -> Result<Vec<OccupancyTrace>> {
    let mut lines = csv.lines();
    let header = lines.next().ok_or_else(|| anyhow!("empty stream CSV"))?;
    ensure!(
        header == STREAM_CSV_HEADER,
        "unexpected stream CSV header `{header}`"
    );
    let mut traces: Vec<OccupancyTrace> = memories
        .iter()
        .map(|m| OccupancyTrace::new(&m.name, m.capacity))
        .collect();
    // Last row time per memory, tracked independently of the trace's
    // sample list: `record` coalesces no-op rows away, so the samples
    // alone cannot detect a backwards-time row that follows one.
    let mut last_row_t = vec![0u64; memories.len()];
    for (n, line) in lines.enumerate() {
        if line.is_empty() {
            continue;
        }
        let mut f = line.split(',');
        let (Some(name), Some(t), Some(needed), Some(obsolete), None) =
            (f.next(), f.next(), f.next(), f.next(), f.next())
        else {
            return Err(anyhow!("stream CSV row {}: want 4 fields", n + 2));
        };
        let i = memories
            .iter()
            .position(|m| m.name == name)
            .ok_or_else(|| anyhow!("stream CSV row {}: unknown memory `{name}`", n + 2))?;
        let parse_u64 = |s: &str, what: &str| -> Result<u64> {
            s.parse()
                .with_context(|| format!("stream CSV row {}: bad {what} `{s}`", n + 2))
        };
        let t = parse_u64(t, "t_cycles")?;
        if t < last_row_t[i] {
            return Err(StreamOrderError {
                memory: name.to_string(),
                row: n + 2,
                prev_t: last_row_t[i],
                t,
            }
            .into());
        }
        last_row_t[i] = t;
        traces[i].record(
            t,
            parse_u64(needed, "needed_bytes")?,
            parse_u64(obsolete, "obsolete_bytes")?,
        );
    }
    for tr in &mut traces {
        let last_t = tr.samples().last().expect("trace never empty").t;
        ensure!(
            last_t <= end,
            "stream CSV: end {} precedes last sample of `{}`",
            end,
            tr.memory
        );
        tr.finalize(end);
        tr.validate()?;
    }
    Ok(traces)
}

/// CSV rows `t_cycles,needed,obsolete,free` (Fig. 5's stacked regions).
pub fn trace_to_csv(tr: &OccupancyTrace) -> String {
    let mut out = String::from("t_cycles,needed_bytes,obsolete_bytes,free_bytes\n");
    for s in tr.samples() {
        let free = tr.capacity.saturating_sub(s.needed + s.obsolete);
        out.push_str(&format!("{},{},{},{}\n", s.t, s.needed, s.obsolete, free));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> OccupancyTrace {
        let mut tr = OccupancyTrace::new("sram", 1 << 20);
        tr.record(10, 100, 0);
        tr.record(20, 500, 50);
        tr.record(30, 200, 350);
        tr.finalize(40);
        tr
    }

    #[test]
    fn json_roundtrip() {
        let tr = sample_trace();
        let j = trace_to_json(&tr);
        let back = trace_from_json(&j).unwrap();
        assert_eq!(back.memory, tr.memory);
        assert_eq!(back.capacity, tr.capacity);
        assert_eq!(back.samples(), tr.samples());
        assert_eq!(back.end_time(), tr.end_time());
        assert_eq!(back.peak_needed(), 500);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("trapti-test-trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        let tr = sample_trace();
        save_trace(&tr, &path).unwrap();
        let back = load_trace(&path).unwrap();
        assert_eq!(back.samples(), tr.samples());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_includes_free_column() {
        let csv = trace_to_csv(&sample_trace());
        let lines: Vec<_> = csv.lines().collect();
        assert_eq!(lines[0], "t_cycles,needed_bytes,obsolete_bytes,free_bytes");
        assert_eq!(lines.len(), 5); // header + t=0 + 3 samples
        assert!(lines[2].starts_with("10,100,0,"));
    }

    #[test]
    fn stream_csv_roundtrip_matches_samples() {
        use crate::trace::sink::{CsvStreamSink, TraceSink};
        let mems = vec![
            MemoryDesc { name: "sram".into(), capacity: 1 << 20 },
            MemoryDesc { name: "dm1".into(), capacity: 1 << 20 },
        ];
        let mut sink = CsvStreamSink::new(Vec::new());
        sink.begin(&mems);
        sink.on_sample(0, 5, 100, 0);
        sink.on_sample(1, 5, 7, 1);
        sink.on_sample(0, 5, 200, 0); // same-instant supersession
        sink.on_sample(0, 9, 0, 200);
        sink.finish(12);
        let csv = String::from_utf8(sink.into_inner().unwrap()).unwrap();

        let traces = stream_csv_to_traces(&csv, &mems, 12).unwrap();
        assert_eq!(traces.len(), 2);
        let mut want = OccupancyTrace::new("sram", 1 << 20);
        want.record(5, 200, 0); // last state at t=5 wins
        want.record(9, 0, 200);
        want.finalize(12);
        assert_eq!(traces[0].samples(), want.samples());
        assert_eq!(traces[0].end_time(), Some(12));
        assert_eq!(traces[1].samples().last().unwrap().needed, 7);
    }

    #[test]
    fn stream_csv_rejects_malformed_input() {
        let mems = vec![MemoryDesc { name: "sram".into(), capacity: 100 }];
        // Bad header.
        assert!(stream_csv_to_traces("nope\n", &mems, 10).is_err());
        // Unknown memory.
        let csv = format!("{STREAM_CSV_HEADER}\nother,1,2,3\n");
        assert!(stream_csv_to_traces(&csv, &mems, 10).is_err());
        // Wrong arity.
        let csv = format!("{STREAM_CSV_HEADER}\nsram,1,2\n");
        assert!(stream_csv_to_traces(&csv, &mems, 10).is_err());
        // Non-numeric field.
        let csv = format!("{STREAM_CSV_HEADER}\nsram,1,x,3\n");
        assert!(stream_csv_to_traces(&csv, &mems, 10).is_err());
        // End before last sample.
        let csv = format!("{STREAM_CSV_HEADER}\nsram,20,1,0\n");
        assert!(stream_csv_to_traces(&csv, &mems, 10).is_err());
        // Backwards time, even behind a no-op row that coalesces away.
        let csv = format!("{STREAM_CSV_HEADER}\nsram,9,0,0\nsram,5,1,0\n");
        assert!(stream_csv_to_traces(&csv, &mems, 10).is_err());
        // Over capacity.
        let csv = format!("{STREAM_CSV_HEADER}\nsram,1,90,20\n");
        assert!(stream_csv_to_traces(&csv, &mems, 10).is_err());
        // Empty body is fine: one all-zero sample per memory.
        let csv = format!("{STREAM_CSV_HEADER}\n");
        let traces = stream_csv_to_traces(&csv, &mems, 10).unwrap();
        assert_eq!(traces[0].samples().len(), 1);
    }

    #[test]
    fn backwards_time_is_a_typed_stream_order_error() {
        let mems = vec![
            MemoryDesc { name: "sram".into(), capacity: 100 },
            MemoryDesc { name: "dm1".into(), capacity: 100 },
        ];
        // The no-op row at t=9 coalesces away in the trace, so only the
        // independent per-memory row clock can catch the regression; the
        // interleaved dm1 row must not reset sram's clock.
        let csv = format!("{STREAM_CSV_HEADER}\nsram,9,0,0\ndm1,1,2,0\nsram,5,1,0\n");
        let err = stream_csv_to_traces(&csv, &mems, 10).unwrap_err();
        let typed = err
            .downcast_ref::<StreamOrderError>()
            .expect("out-of-order timestamps must surface the typed error");
        assert_eq!(
            typed,
            &StreamOrderError {
                memory: "sram".to_string(),
                row: 4,
                prev_t: 9,
                t: 5,
            }
        );
        assert!(err.to_string().contains("time went backwards"), "{err}");
    }

    #[test]
    fn rejects_corrupt_json() {
        assert!(trace_from_json(&parse("{}").unwrap()).is_err());
        let bad = parse(r#"{"memory":"m","capacity":10,"end_time":5,"samples":[[0,99,99]]}"#)
            .unwrap();
        assert!(trace_from_json(&bad).is_err(), "over-capacity must fail");
    }
}
