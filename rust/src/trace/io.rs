//! Trace (de)serialization: JSON for tooling, CSV for plotting.
//!
//! Stage II can run entirely offline from a saved trace (`repro simulate
//! --save-trace` -> `repro bank --trace`), decoupling the expensive
//! simulation from the cheap exploration exactly as the paper's two-stage
//! flow prescribes.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{parse, Json};

use super::occupancy::OccupancyTrace;

pub fn trace_to_json(tr: &OccupancyTrace) -> Json {
    Json::obj(vec![
        ("memory", Json::str(tr.memory.clone())),
        ("capacity", Json::num(tr.capacity as f64)),
        (
            "end_time",
            tr.end_time()
                .map(|t| Json::num(t as f64))
                .unwrap_or(Json::Null),
        ),
        (
            "samples",
            Json::arr(tr.samples().iter().map(|s| {
                Json::arr([
                    Json::num(s.t as f64),
                    Json::num(s.needed as f64),
                    Json::num(s.obsolete as f64),
                ])
            })),
        ),
    ])
}

pub fn trace_from_json(j: &Json) -> Result<OccupancyTrace> {
    let memory = j
        .expect("memory")?
        .as_str()
        .ok_or_else(|| anyhow!("memory must be a string"))?;
    let capacity = j
        .expect("capacity")?
        .as_u64()
        .ok_or_else(|| anyhow!("capacity must be u64"))?;
    let mut tr = OccupancyTrace::new(memory, capacity);
    let samples = j
        .expect("samples")?
        .as_arr()
        .ok_or_else(|| anyhow!("samples must be an array"))?;
    for s in samples {
        let trip = s.as_arr().ok_or_else(|| anyhow!("sample must be array"))?;
        if trip.len() != 3 {
            return Err(anyhow!("sample must have 3 fields"));
        }
        let get = |i: usize| -> Result<u64> {
            trip[i]
                .as_u64()
                .ok_or_else(|| anyhow!("sample field {i} must be u64"))
        };
        tr.record(get(0)?, get(1)?, get(2)?);
    }
    if let Some(end) = j.expect("end_time")?.as_u64() {
        tr.finalize(end);
    }
    tr.validate()?;
    Ok(tr)
}

pub fn save_trace(tr: &OccupancyTrace, path: &Path) -> Result<()> {
    std::fs::write(path, trace_to_json(tr).to_string_compact())
        .with_context(|| format!("writing trace to {}", path.display()))
}

pub fn load_trace(path: &Path) -> Result<OccupancyTrace> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace from {}", path.display()))?;
    trace_from_json(&parse(&text)?)
}

/// CSV rows `t_cycles,needed,obsolete,free` (Fig. 5's stacked regions).
pub fn trace_to_csv(tr: &OccupancyTrace) -> String {
    let mut out = String::from("t_cycles,needed_bytes,obsolete_bytes,free_bytes\n");
    for s in tr.samples() {
        let free = tr.capacity.saturating_sub(s.needed + s.obsolete);
        out.push_str(&format!("{},{},{},{}\n", s.t, s.needed, s.obsolete, free));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> OccupancyTrace {
        let mut tr = OccupancyTrace::new("sram", 1 << 20);
        tr.record(10, 100, 0);
        tr.record(20, 500, 50);
        tr.record(30, 200, 350);
        tr.finalize(40);
        tr
    }

    #[test]
    fn json_roundtrip() {
        let tr = sample_trace();
        let j = trace_to_json(&tr);
        let back = trace_from_json(&j).unwrap();
        assert_eq!(back.memory, tr.memory);
        assert_eq!(back.capacity, tr.capacity);
        assert_eq!(back.samples(), tr.samples());
        assert_eq!(back.end_time(), tr.end_time());
        assert_eq!(back.peak_needed(), 500);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("trapti-test-trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        let tr = sample_trace();
        save_trace(&tr, &path).unwrap();
        let back = load_trace(&path).unwrap();
        assert_eq!(back.samples(), tr.samples());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_includes_free_column() {
        let csv = trace_to_csv(&sample_trace());
        let lines: Vec<_> = csv.lines().collect();
        assert_eq!(lines[0], "t_cycles,needed_bytes,obsolete_bytes,free_bytes");
        assert_eq!(lines.len(), 5); // header + t=0 + 3 samples
        assert!(lines[2].starts_with("10,100,0,"));
    }

    #[test]
    fn rejects_corrupt_json() {
        assert!(trace_from_json(&parse("{}").unwrap()).is_err());
        let bad = parse(r#"{"memory":"m","capacity":10,"end_time":5,"samples":[[0,99,99]]}"#)
            .unwrap();
        assert!(trace_from_json(&bad).is_err(), "over-capacity must fail");
    }
}
