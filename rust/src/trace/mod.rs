//! Stage-I artifacts: time-resolved occupancy traces and memory access
//! statistics, with (de)serialization so Stage II can run fully offline.

pub mod access;
pub mod io;
pub mod occupancy;
pub mod sink;

pub use access::{AccessStats, KindStats};
pub use io::{
    load_trace, save_trace, stream_csv_to_traces, trace_from_json, trace_to_csv,
    trace_to_json, StreamOrderError, STREAM_CSV_HEADER,
};
pub use occupancy::{OccupancyTrace, Sample, Segment};
pub use sink::{
    CsvStreamSink, MaterializeSink, MemoryDesc, OnlineMemStats, OnlineStatsSink,
    RunEvent, TeeSink, TraceSink,
};
