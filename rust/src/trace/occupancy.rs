//! Time-resolved SRAM occupancy trace — the key Stage-I artifact.
//!
//! The trace is piecewise-constant: a sample `(t, needed, obsolete)`
//! holds from `t` until the next sample. Stage II consumes the segments
//! (Δt_k of the paper's Eq. 4) directly; peak queries back the paper's
//! Fig. 5 annotations and the sizing loop.

use anyhow::{ensure, Result};

/// One change-point of the occupancy state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sample {
    /// Time in cycles.
    pub t: u64,
    /// Bytes of resident tensors still required by future ops.
    pub needed: u64,
    /// Bytes of resident tensors with no remaining consumers (evictable
    /// without correctness impact).
    pub obsolete: u64,
}

impl Sample {
    pub fn occupied(&self) -> u64 {
        self.needed + self.obsolete
    }
}

/// A piecewise-constant segment `[t0, t1)` (the paper's Δt_k).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    pub t0: u64,
    pub t1: u64,
    pub needed: u64,
    pub obsolete: u64,
}

impl Segment {
    pub fn dt(&self) -> u64 {
        self.t1 - self.t0
    }

    pub fn occupied(&self) -> u64 {
        self.needed + self.obsolete
    }
}

/// Occupancy trace of one memory over a simulated run.
#[derive(Debug, Clone)]
pub struct OccupancyTrace {
    /// Memory name (e.g. "sram", "dm1").
    pub memory: String,
    /// Memory capacity in bytes (the Fig. 5 "free" region is
    /// `capacity - needed - obsolete`).
    pub capacity: u64,
    samples: Vec<Sample>,
    /// End-of-run time (set by `finalize`); last sample extends to here.
    end_time: Option<u64>,
}

impl OccupancyTrace {
    pub fn new(memory: &str, capacity: u64) -> Self {
        Self {
            memory: memory.to_string(),
            capacity,
            samples: vec![Sample {
                t: 0,
                needed: 0,
                obsolete: 0,
            }],
            end_time: None,
        }
    }

    /// Record state at time `t` (monotonic non-decreasing). Consecutive
    /// identical states coalesce; same-time updates overwrite (only the
    /// final state at an instant is observable).
    pub fn record(&mut self, t: u64, needed: u64, obsolete: u64) {
        let last = self.samples.last_mut().expect("never empty");
        debug_assert!(t >= last.t, "time went backwards: {t} < {}", last.t);
        if last.t == t {
            last.needed = needed;
            last.obsolete = obsolete;
            // Coalesce with predecessor if the overwrite undid the change.
            if self.samples.len() >= 2 {
                let prev = self.samples[self.samples.len() - 2];
                let cur = *self.samples.last().unwrap();
                if prev.needed == cur.needed && prev.obsolete == cur.obsolete {
                    self.samples.pop();
                }
            }
        } else if last.needed != needed || last.obsolete != obsolete {
            self.samples.push(Sample {
                t,
                needed,
                obsolete,
            });
        }
    }

    /// Close the trace at the run's end time.
    pub fn finalize(&mut self, end: u64) {
        let last_t = self.samples.last().unwrap().t;
        assert!(end >= last_t, "finalize before last sample");
        self.end_time = Some(end);
    }

    pub fn end_time(&self) -> Option<u64> {
        self.end_time
    }

    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    /// Iterate piecewise-constant segments. Requires `finalize`.
    pub fn segments(&self) -> impl Iterator<Item = Segment> + '_ {
        let end = self.end_time.expect("trace not finalized");
        self.samples.iter().enumerate().filter_map(move |(i, s)| {
            let t1 = self
                .samples
                .get(i + 1)
                .map(|n| n.t)
                .unwrap_or(end);
            (t1 > s.t).then_some(Segment {
                t0: s.t,
                t1,
                needed: s.needed,
                obsolete: s.obsolete,
            })
        })
    }

    /// Peak bytes of *needed* data — the paper's "peak required capacity".
    pub fn peak_needed(&self) -> u64 {
        self.samples.iter().map(|s| s.needed).max().unwrap_or(0)
    }

    /// Peak total occupancy (needed + obsolete).
    pub fn peak_occupied(&self) -> u64 {
        self.samples.iter().map(|s| s.occupied()).max().unwrap_or(0)
    }

    /// Time-weighted average needed bytes.
    pub fn avg_needed(&self) -> f64 {
        let end = self.end_time.expect("trace not finalized");
        if end == 0 {
            return 0.0;
        }
        let sum: u128 = self
            .segments()
            .map(|seg| seg.needed as u128 * seg.dt() as u128)
            .sum();
        sum as f64 / end as f64
    }

    /// Integral of occupancy over time, byte-cycles (for the analytic
    /// baseline comparison).
    pub fn needed_byte_cycles(&self) -> u128 {
        self.segments()
            .map(|seg| seg.needed as u128 * seg.dt() as u128)
            .sum()
    }

    /// Validate invariants: monotonic time, occupancy within capacity.
    pub fn validate(&self) -> Result<()> {
        for w in self.samples.windows(2) {
            ensure!(w[0].t < w[1].t, "non-monotonic samples");
            ensure!(
                w[0].needed != w[1].needed || w[0].obsolete != w[1].obsolete,
                "uncoalesced duplicate sample at t={}",
                w[1].t
            );
        }
        for s in &self.samples {
            ensure!(
                s.occupied() <= self.capacity,
                "occupancy {} exceeds capacity {} at t={}",
                s.occupied(),
                self.capacity,
                s.t
            );
        }
        Ok(())
    }

    /// Downsample to at most `n` evenly spaced points (plotting).
    pub fn downsample(&self, n: usize) -> Vec<Sample> {
        if self.samples.len() <= n || n < 2 {
            return self.samples.clone();
        }
        let end = self.end_time.unwrap_or(self.samples.last().unwrap().t);
        let mut out = Vec::with_capacity(n);
        let mut idx = 0;
        for i in 0..n {
            let t = end * i as u64 / (n as u64 - 1);
            while idx + 1 < self.samples.len() && self.samples[idx + 1].t <= t {
                idx += 1;
            }
            let s = self.samples[idx];
            out.push(Sample {
                t,
                needed: s.needed,
                obsolete: s.obsolete,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn mk(events: &[(u64, u64, u64)], end: u64) -> OccupancyTrace {
        let mut t = OccupancyTrace::new("sram", 1000);
        for &(ti, n, o) in events {
            t.record(ti, n, o);
        }
        t.finalize(end);
        t
    }

    #[test]
    fn coalesces_identical_states() {
        let t = mk(&[(5, 10, 0), (7, 10, 0), (9, 20, 0)], 10);
        assert_eq!(t.samples().len(), 3); // t=0, t=5, t=9
    }

    #[test]
    fn same_time_overwrites() {
        let t = mk(&[(5, 10, 0), (5, 30, 2)], 10);
        assert_eq!(t.samples().len(), 2);
        assert_eq!(t.samples()[1], Sample { t: 5, needed: 30, obsolete: 2 });
    }

    #[test]
    fn overwrite_back_to_previous_coalesces() {
        let t = mk(&[(5, 10, 0), (5, 0, 0)], 10);
        assert_eq!(t.samples().len(), 1, "no-op change must disappear");
        t.validate().unwrap();
    }

    #[test]
    fn segments_cover_run_exactly() {
        let t = mk(&[(5, 10, 0), (9, 20, 4)], 12);
        let segs: Vec<_> = t.segments().collect();
        assert_eq!(segs.len(), 3);
        assert_eq!(segs[0], Segment { t0: 0, t1: 5, needed: 0, obsolete: 0 });
        assert_eq!(segs[1], Segment { t0: 5, t1: 9, needed: 10, obsolete: 0 });
        assert_eq!(segs[2], Segment { t0: 9, t1: 12, needed: 20, obsolete: 4 });
        let total: u64 = segs.iter().map(|s| s.dt()).sum();
        assert_eq!(total, 12);
    }

    #[test]
    fn peaks_and_average() {
        let t = mk(&[(2, 100, 0), (4, 50, 60), (8, 0, 0)], 10);
        assert_eq!(t.peak_needed(), 100);
        assert_eq!(t.peak_occupied(), 110);
        // avg = (0*2 + 100*2 + 50*4 + 0*2)/10 = 40
        assert!((t.avg_needed() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn validate_catches_over_capacity() {
        let mut t = OccupancyTrace::new("sram", 100);
        t.record(1, 90, 20);
        t.finalize(2);
        assert!(t.validate().is_err());
    }

    #[test]
    fn downsample_preserves_endpoints() {
        let t = mk(&[(10, 5, 0), (20, 9, 1), (30, 2, 2)], 100);
        // 4 samples > n=3: actually downsampled.
        let d = t.downsample(3);
        assert_eq!(d.len(), 3);
        assert_eq!(d[0].t, 0);
        assert_eq!(d[2].t, 100);
        assert_eq!(d[2].needed, 2);
        // n >= samples: passthrough.
        assert_eq!(t.downsample(10).len(), t.samples().len());
    }

    #[test]
    fn prop_random_traces_consistent() {
        check("occupancy-invariants", 100, |rng: &mut Rng| {
            let mut tr = OccupancyTrace::new("m", u64::MAX);
            let mut t = 0;
            for _ in 0..rng.range(1, 200) {
                t += rng.range(0, 50);
                tr.record(t, rng.below(1 << 30), rng.below(1 << 30));
            }
            tr.finalize(t + rng.range(0, 10));
            tr.validate().unwrap();
            // Segment Δt sums to end time.
            let total: u64 = tr.segments().map(|s| s.dt()).sum();
            assert_eq!(total, tr.end_time().unwrap());
            // avg <= peak.
            assert!(tr.avg_needed() <= tr.peak_needed() as f64 + 1e-9);
        });
    }
}
