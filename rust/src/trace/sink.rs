//! Streaming occupancy consumers (the `TraceSink` trait).
//!
//! Stage I's key artifact is the time-resolved occupancy trace, but not
//! every consumer needs it materialized: online peak/average statistics,
//! CSV export, capacity planning — and the whole of Stage II — can all
//! run on the *event stream*. The simulation engine forwards every
//! occupancy change of every on-chip memory to a `TraceSink` (see
//! `sim::engine::SimOptions`), so consumers choose between O(samples)
//! memory (\[`MaterializeSink`\]) and O(1) memory
//! (\[`OnlineStatsSink`\], \[`CsvStreamSink`\], and
//! `banking::SweepSink` — the fused Stage-II sweep engine running
//! directly on the stream).
//!
//! Stream semantics mirror [`OccupancyTrace::record`]: samples arrive
//! with non-decreasing `t`; several samples may share one `t`, in which
//! case only the **last** state at that instant is observable (the
//! engine emits intra-instant transients in order; sinks that aggregate
//! must overwrite, exactly as the materialized trace does).
//!
//! Beyond the sinks in this module, two engine sinks consume the stream
//! directly: `banking::SweepSink` (the fused Stage-II sweep) and
//! `banking::OnlineGateSim` (the Stage-III online gating co-simulation
//! with wake-stall timing feedback).

use std::io::Write;

use super::occupancy::OccupancyTrace;

/// A memory visible to the sink, announced once via [`TraceSink::begin`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemoryDesc {
    pub name: String,
    pub capacity: u64,
}

/// A structural (non-occupancy) run event, forwarded to sinks through
/// [`TraceSink::on_event`]. Occupancy changes keep their dedicated
/// [`TraceSink::on_sample`] channel; these events annotate the stream
/// with schedule structure: dataflow stage boundaries (`sim::engine`),
/// serving-scheduler admissions/completions (`sim::serving`), and the
/// Stage-III per-bank outcomes (`banking::online::OnlineReport::events`,
/// emitted retrospectively once the co-simulation has closed its spans).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunEvent {
    /// First op of dataflow stage `stage` issued.
    StageStart { stage: u32 },
    /// Last op of dataflow stage `stage` completed.
    StageEnd { stage: u32 },
    /// Serving scheduler admitted request `request` into the batch.
    Admit { request: u32 },
    /// Serving request `request` completed and released its KV pages.
    Complete { request: u32 },
    /// Serving scheduler preempted request `request`: its live KV
    /// spilled to DRAM and its arena pages were freed.
    Evict { request: u32 },
    /// Serving scheduler re-admitted a preempted request `request`,
    /// streaming its KV back from DRAM into fresh arena pages.
    Restore { request: u32 },
    /// Retrospective: bank `bank` held `state` (a
    /// `banking::online::BankState::label`) over `[t0, t1)` in
    /// stall-adjusted cycles.
    BankSpan {
        bank: u32,
        state: &'static str,
        t0: u64,
        t1: u64,
    },
    /// Retrospective: a wake-up at adjusted cycle `at` stalled the
    /// machine for `stall_cycles` while bank `bank` powered up.
    WakeStall {
        bank: u32,
        at: u64,
        stall_cycles: u64,
    },
}

/// Receiver of streamed occupancy samples for every on-chip memory.
pub trait TraceSink {
    /// Called once before simulation with the on-chip memory layout
    /// (index in this slice == `mem` index in [`TraceSink::on_sample`]).
    fn begin(&mut self, memories: &[MemoryDesc]) {
        let _ = memories;
    }

    /// Occupancy state of memory `mem` changed at cycle `t`.
    fn on_sample(&mut self, mem: usize, t: u64, needed: u64, obsolete: u64);

    /// A structural run event occurred at cycle `t` (default no-op, so
    /// occupancy-only sinks are unaffected). Events arrive with
    /// non-decreasing `t`, interleaved with samples in stream order.
    fn on_event(&mut self, t: u64, event: &RunEvent) {
        let _ = (t, event);
    }

    /// Simulation finished at cycle `end`; the last state of each memory
    /// extends to here.
    fn finish(&mut self, end: u64) {
        let _ = end;
    }
}

/// Builds one [`OccupancyTrace`] per memory — the materializing sink.
/// `simulate` without a sink is equivalent to running with this one.
#[derive(Debug, Default)]
pub struct MaterializeSink {
    traces: Vec<OccupancyTrace>,
}

impl MaterializeSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn traces(&self) -> &[OccupancyTrace] {
        &self.traces
    }

    pub fn into_traces(self) -> Vec<OccupancyTrace> {
        self.traces
    }
}

impl TraceSink for MaterializeSink {
    fn begin(&mut self, memories: &[MemoryDesc]) {
        self.traces = memories
            .iter()
            .map(|m| OccupancyTrace::new(&m.name, m.capacity))
            .collect();
    }

    fn on_sample(&mut self, mem: usize, t: u64, needed: u64, obsolete: u64) {
        self.traces[mem].record(t, needed, obsolete);
    }

    fn finish(&mut self, end: u64) {
        for tr in &mut self.traces {
            tr.finalize(end);
        }
    }
}

/// O(1)-memory online statistics for one memory: peaks and time-weighted
/// averages computed without storing samples.
#[derive(Debug, Clone, Default)]
pub struct OnlineMemStats {
    pub name: String,
    pub capacity: u64,
    /// Current state `(t, needed, obsolete)`, holding from `t`.
    cur: (u64, u64, u64),
    needed_byte_cycles: u128,
    occupied_byte_cycles: u128,
    peak_needed: u64,
    peak_occupied: u64,
    /// Distinct committed states (≈ materialized sample count).
    committed: u64,
    end: Option<u64>,
}

impl OnlineMemStats {
    /// Commit the current state over `[cur.t, until)` and fold it into
    /// the peaks. Zero-duration states at `finish` still count toward
    /// peaks, matching `OccupancyTrace::peak_needed` over samples.
    fn commit(&mut self, until: u64) {
        let (t, needed, obsolete) = self.cur;
        debug_assert!(until >= t);
        let dt = (until - t) as u128;
        self.needed_byte_cycles += needed as u128 * dt;
        self.occupied_byte_cycles += (needed + obsolete) as u128 * dt;
        self.peak_needed = self.peak_needed.max(needed);
        self.peak_occupied = self.peak_occupied.max(needed + obsolete);
        self.committed += 1;
    }

    fn record(&mut self, t: u64, needed: u64, obsolete: u64) {
        debug_assert!(t >= self.cur.0, "stream time went backwards");
        if t > self.cur.0 {
            self.commit(t);
        }
        // Same-instant updates overwrite (only the final state at an
        // instant is observable — see module docs).
        self.cur = (t, needed, obsolete);
    }

    fn finalize(&mut self, end: u64) {
        self.commit(end);
        self.end = Some(end);
    }

    pub fn peak_needed(&self) -> u64 {
        self.peak_needed
    }

    pub fn peak_occupied(&self) -> u64 {
        self.peak_occupied
    }

    /// Time-weighted average needed bytes (requires the run to have
    /// finished). Matches `OccupancyTrace::avg_needed`.
    pub fn avg_needed(&self) -> f64 {
        match self.end {
            Some(end) if end > 0 => self.needed_byte_cycles as f64 / end as f64,
            _ => 0.0,
        }
    }

    pub fn avg_occupied(&self) -> f64 {
        match self.end {
            Some(end) if end > 0 => self.occupied_byte_cycles as f64 / end as f64,
            _ => 0.0,
        }
    }

    pub fn needed_byte_cycles(&self) -> u128 {
        self.needed_byte_cycles
    }

    /// Distinct committed states (≈ the materialized sample count).
    pub fn committed_states(&self) -> u64 {
        self.committed
    }

    pub fn end_time(&self) -> Option<u64> {
        self.end
    }
}

/// Streaming peak/average statistics for every on-chip memory.
#[derive(Debug, Clone, Default)]
pub struct OnlineStatsSink {
    mems: Vec<OnlineMemStats>,
}

impl OnlineStatsSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn memories(&self) -> &[OnlineMemStats] {
        &self.mems
    }

    /// Shared-SRAM statistics (memory 0), if the run announced any.
    pub fn shared(&self) -> Option<&OnlineMemStats> {
        self.mems.first()
    }
}

impl TraceSink for OnlineStatsSink {
    fn begin(&mut self, memories: &[MemoryDesc]) {
        self.mems = memories
            .iter()
            .map(|m| OnlineMemStats {
                name: m.name.clone(),
                capacity: m.capacity,
                ..Default::default()
            })
            .collect();
    }

    fn on_sample(&mut self, mem: usize, t: u64, needed: u64, obsolete: u64) {
        self.mems[mem].record(t, needed, obsolete);
    }

    fn finish(&mut self, end: u64) {
        for m in &mut self.mems {
            m.finalize(end);
        }
    }
}

/// Streams `memory,t_cycles,needed_bytes,obsolete_bytes` rows as they
/// happen. The stream is raw: rows at the same `t` supersede earlier
/// ones (last wins), so post-processing should keep the final row per
/// `(memory, t)` — or use `trace_to_csv` on a materialized trace for a
/// deduplicated export.
pub struct CsvStreamSink<W: Write> {
    writer: W,
    names: Vec<String>,
    error: Option<std::io::Error>,
}

impl<W: Write> CsvStreamSink<W> {
    pub fn new(writer: W) -> Self {
        Self {
            writer,
            names: Vec::new(),
            error: None,
        }
    }

    fn write_row(&mut self, line: String) {
        if self.error.is_some() {
            return;
        }
        if let Err(e) = self.writer.write_all(line.as_bytes()) {
            self.error = Some(e);
        }
    }

    /// Hand back the writer; `Err` if any row failed to write.
    pub fn into_inner(self) -> std::io::Result<W> {
        match self.error {
            Some(e) => Err(e),
            None => Ok(self.writer),
        }
    }
}

impl<W: Write> TraceSink for CsvStreamSink<W> {
    fn begin(&mut self, memories: &[MemoryDesc]) {
        self.names = memories.iter().map(|m| m.name.clone()).collect();
        self.write_row("memory,t_cycles,needed_bytes,obsolete_bytes\n".to_string());
    }

    fn on_sample(&mut self, mem: usize, t: u64, needed: u64, obsolete: u64) {
        let name = self
            .names
            .get(mem)
            .map(String::as_str)
            .unwrap_or("?");
        self.write_row(format!("{name},{t},{needed},{obsolete}\n"));
    }

    fn finish(&mut self, _end: u64) {
        if self.error.is_none() {
            if let Err(e) = self.writer.flush() {
                self.error = Some(e);
            }
        }
    }
}

/// Fans one stream out to several sinks (e.g. materialize + online
/// stats in a single simulation pass).
pub struct TeeSink<'a> {
    sinks: Vec<&'a mut dyn TraceSink>,
}

impl<'a> TeeSink<'a> {
    pub fn new(sinks: Vec<&'a mut dyn TraceSink>) -> Self {
        Self { sinks }
    }
}

impl TraceSink for TeeSink<'_> {
    fn begin(&mut self, memories: &[MemoryDesc]) {
        for s in &mut self.sinks {
            s.begin(memories);
        }
    }

    fn on_sample(&mut self, mem: usize, t: u64, needed: u64, obsolete: u64) {
        for s in &mut self.sinks {
            s.on_sample(mem, t, needed, obsolete);
        }
    }

    fn on_event(&mut self, t: u64, event: &RunEvent) {
        for s in &mut self.sinks {
            s.on_event(t, event);
        }
    }

    fn finish(&mut self, end: u64) {
        for s in &mut self.sinks {
            s.finish(end);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mems() -> Vec<MemoryDesc> {
        vec![MemoryDesc {
            name: "sram".to_string(),
            capacity: u64::MAX,
        }]
    }

    /// Drive a sink and a reference OccupancyTrace with the same stream.
    fn drive(events: &[(u64, u64, u64)], end: u64) -> (OccupancyTrace, OnlineStatsSink) {
        let mut reference = OccupancyTrace::new("sram", u64::MAX);
        let mut online = OnlineStatsSink::new();
        online.begin(&mems());
        for &(t, n, o) in events {
            reference.record(t, n, o);
            online.on_sample(0, t, n, o);
        }
        reference.finalize(end);
        online.finish(end);
        (reference, online)
    }

    #[test]
    fn online_stats_match_materialized_simple() {
        let (tr, online) = drive(&[(2, 100, 0), (4, 50, 60), (8, 0, 0)], 10);
        let m = online.shared().unwrap();
        assert_eq!(m.peak_needed(), tr.peak_needed());
        assert_eq!(m.peak_occupied(), tr.peak_occupied());
        assert!((m.avg_needed() - tr.avg_needed()).abs() < 1e-9);
        assert_eq!(m.needed_byte_cycles(), tr.needed_byte_cycles());
        assert_eq!(m.end_time(), tr.end_time());
    }

    #[test]
    fn online_stats_overwrite_same_instant() {
        // The transient 1000 at t=5 is overwritten at the same instant
        // and must not pollute the peak (matching OccupancyTrace).
        let (tr, online) = drive(&[(5, 1000, 0), (5, 10, 0)], 10);
        assert_eq!(tr.peak_needed(), 10);
        assert_eq!(online.shared().unwrap().peak_needed(), 10);
    }

    #[test]
    fn online_stats_zero_duration_final_state_counts() {
        let (tr, online) = drive(&[(10, 999, 1)], 10);
        assert_eq!(tr.peak_needed(), 999);
        assert_eq!(online.shared().unwrap().peak_needed(), 999);
        assert_eq!(online.shared().unwrap().peak_occupied(), 1000);
    }

    #[test]
    fn prop_online_equals_materialized_on_random_streams() {
        crate::util::proptest::check("sink-online-vs-materialized", 100, |rng: &mut Rng| {
            let mut events = Vec::new();
            let mut t = 0u64;
            for _ in 0..rng.range(1, 150) {
                t += rng.below(30); // may repeat an instant
                events.push((t, rng.below(1 << 28), rng.below(1 << 28)));
            }
            let end = t + rng.range(0, 10);
            let (tr, online) = drive(&events, end);
            let m = online.shared().unwrap();
            assert_eq!(m.peak_needed(), tr.peak_needed());
            assert_eq!(m.peak_occupied(), tr.peak_occupied());
            assert_eq!(m.needed_byte_cycles(), tr.needed_byte_cycles());
            assert!((m.avg_needed() - tr.avg_needed()).abs() < 1e-6);
        });
    }

    #[test]
    fn materialize_sink_builds_finalized_traces() {
        let mut sink = MaterializeSink::new();
        sink.begin(&mems());
        sink.on_sample(0, 3, 40, 0);
        sink.on_sample(0, 7, 10, 30);
        sink.finish(12);
        let traces = sink.into_traces();
        assert_eq!(traces.len(), 1);
        assert_eq!(traces[0].end_time(), Some(12));
        assert_eq!(traces[0].peak_needed(), 40);
        traces[0].validate().unwrap();
    }

    #[test]
    fn csv_sink_streams_rows() {
        let mut sink = CsvStreamSink::new(Vec::new());
        sink.begin(&mems());
        sink.on_sample(0, 5, 100, 0);
        sink.finish(10);
        let bytes = sink.into_inner().unwrap();
        let text = String::from_utf8(bytes).unwrap();
        assert!(text.starts_with("memory,t_cycles,needed_bytes,obsolete_bytes\n"));
        assert!(text.contains("sram,5,100,0\n"));
    }

    #[test]
    fn tee_fans_out() {
        let mut a = MaterializeSink::new();
        let mut b = OnlineStatsSink::new();
        {
            let mut tee = TeeSink::new(vec![&mut a, &mut b]);
            tee.begin(&mems());
            tee.on_sample(0, 4, 7, 0);
            tee.finish(8);
        }
        assert_eq!(a.traces()[0].peak_needed(), 7);
        assert_eq!(b.shared().unwrap().peak_needed(), 7);
    }

    #[test]
    fn tee_forwards_events_and_default_sinks_ignore_them() {
        struct Recorder(Vec<(u64, RunEvent)>);
        impl TraceSink for Recorder {
            fn on_sample(&mut self, _m: usize, _t: u64, _n: u64, _o: u64) {}
            fn on_event(&mut self, t: u64, event: &RunEvent) {
                self.0.push((t, *event));
            }
        }
        let mut mat = MaterializeSink::new(); // default on_event: no-op
        let mut rec = Recorder(Vec::new());
        {
            let mut tee = TeeSink::new(vec![&mut mat, &mut rec]);
            tee.begin(&mems());
            tee.on_event(0, &RunEvent::StageStart { stage: 0 });
            tee.on_sample(0, 4, 7, 0);
            tee.on_event(9, &RunEvent::StageEnd { stage: 0 });
            tee.finish(9);
        }
        assert_eq!(
            rec.0,
            vec![
                (0, RunEvent::StageStart { stage: 0 }),
                (9, RunEvent::StageEnd { stage: 0 }),
            ]
        );
        assert_eq!(mat.traces()[0].peak_needed(), 7);
    }
}
