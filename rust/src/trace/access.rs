//! Memory access statistics — the second Stage-I output (paper
//! Stage-I §A.4): read/write counts feeding Eq. 3's dynamic energy, plus
//! traffic/eviction accounting for the sizing loop.

use std::collections::BTreeMap;

/// Access-granularity note: the simulator issues whole-tensor transfers;
/// counts here are in *interface words* (one access = one
/// `bytes_per_cycle`-wide beat, 64 B on the 512-bit SRAM port), which is
/// what CACTI's per-access energy corresponds to.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AccessStats {
    /// SRAM read accesses (interface words).
    pub reads: u64,
    /// SRAM write accesses (interface words).
    pub writes: u64,
    pub read_bytes: u64,
    pub write_bytes: u64,
    /// Evictions of obsolete data (free, no traffic).
    pub evictions_obsolete: u64,
    /// Capacity-induced write-backs of *needed* data (the condition the
    /// Stage-I sizing loop eliminates).
    pub writebacks: u64,
    pub writeback_bytes: u64,
    /// Refetches of previously written-back tensors.
    pub refetches: u64,
    /// DRAM traffic.
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    /// Per tensor-kind byte traffic (reporting).
    pub by_kind: BTreeMap<&'static str, KindStats>,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KindStats {
    pub read_bytes: u64,
    pub write_bytes: u64,
}

impl AccessStats {
    /// Record an SRAM read of `bytes` with the interface width `word`.
    pub fn sram_read(&mut self, bytes: u64, word: u32, kind: &'static str) {
        self.reads += bytes.div_ceil(word as u64);
        self.read_bytes += bytes;
        self.by_kind.entry(kind).or_default().read_bytes += bytes;
    }

    pub fn sram_write(&mut self, bytes: u64, word: u32, kind: &'static str) {
        self.writes += bytes.div_ceil(word as u64);
        self.write_bytes += bytes;
        self.by_kind.entry(kind).or_default().write_bytes += bytes;
    }

    pub fn dram_read(&mut self, bytes: u64) {
        self.dram_read_bytes += bytes;
    }

    pub fn dram_write(&mut self, bytes: u64) {
        self.dram_write_bytes += bytes;
    }

    pub fn writeback(&mut self, bytes: u64) {
        self.writebacks += 1;
        self.writeback_bytes += bytes;
        self.dram_write_bytes += bytes;
    }

    /// True when the run needed no capacity-induced write-backs — the
    /// feasibility condition of the Stage-I sizing loop.
    pub fn capacity_feasible(&self) -> bool {
        self.writebacks == 0
    }

    pub fn merge(&mut self, other: &AccessStats) {
        self.reads += other.reads;
        self.writes += other.writes;
        self.read_bytes += other.read_bytes;
        self.write_bytes += other.write_bytes;
        self.evictions_obsolete += other.evictions_obsolete;
        self.writebacks += other.writebacks;
        self.writeback_bytes += other.writeback_bytes;
        self.refetches += other.refetches;
        self.dram_read_bytes += other.dram_read_bytes;
        self.dram_write_bytes += other.dram_write_bytes;
        for (k, v) in &other.by_kind {
            let e = self.by_kind.entry(k).or_default();
            e.read_bytes += v.read_bytes;
            e.write_bytes += v.write_bytes;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_rounding() {
        let mut s = AccessStats::default();
        s.sram_read(65, 64, "act"); // 65 bytes = 2 x 64B beats
        assert_eq!(s.reads, 2);
        assert_eq!(s.read_bytes, 65);
        s.sram_write(64, 64, "act");
        assert_eq!(s.writes, 1);
    }

    #[test]
    fn feasibility() {
        let mut s = AccessStats::default();
        assert!(s.capacity_feasible());
        s.writeback(100);
        assert!(!s.capacity_feasible());
        assert_eq!(s.dram_write_bytes, 100);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = AccessStats::default();
        a.sram_read(128, 64, "weight");
        let mut b = AccessStats::default();
        b.sram_read(64, 64, "weight");
        b.sram_write(64, 64, "kv");
        a.merge(&b);
        assert_eq!(a.reads, 3);
        assert_eq!(a.by_kind["weight"].read_bytes, 192);
        assert_eq!(a.by_kind["kv"].write_bytes, 64);
    }
}
