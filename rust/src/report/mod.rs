//! Report layer: renders experiment results as paper-style tables,
//! ASCII figures, and CSV series (written under `reports/` by the CLI).

pub mod figures;
pub mod tables;
