//! Paper-style figure generators: ASCII plots for terminals + CSV series
//! for external plotting. One function per figure of the paper.

use std::fmt::Write as _;

use crate::api::experiments::{Fig1, Fig8, PairedStage1};
use crate::api::Stage1Run;
use crate::sim::SimResult;
use crate::trace::trace_to_csv;
use crate::util::table::{AsciiPlot, Table};
use crate::util::MIB;
use crate::workload::OpClass;

/// Fig. 1 — normalized energy/latency bars (MHA vs GQA decode).
pub fn fig1(f: &Fig1) -> String {
    let mut t = Table::new(
        "Fig. 1 — MHA vs GQA at similar parameter count (decode)",
        &["Metric", "GPT-2 XL (MHA)", "DS-R1D (GQA)", "MHA/GQA", "paper"],
    );
    t.row(vec![
        "On-chip energy [J]".into(),
        format!("{:.2}", f.mha_energy_j),
        format!("{:.2}", f.gqa_energy_j),
        format!("{:.2}x", f.energy_ratio()),
        "-".into(),
    ]);
    t.row(vec![
        "Latency [ms]".into(),
        format!("{:.1}", f.mha_seconds * 1e3),
        format!("{:.1}", f.gqa_seconds * 1e3),
        format!("{:.2}x", f.latency_ratio()),
        "-".into(),
    ]);
    t.row(vec![
        "Attention energy [J]".into(),
        format!("{:.2}", f.mha_attn_energy_j),
        format!("{:.2}", f.gqa_attn_energy_j),
        format!("{:.2}x", f.attn_energy_ratio()),
        "2.89x".into(),
    ]);
    t.row(vec![
        "Attention latency [Mcyc]".into(),
        format!("{:.1}", f.mha_attn_cycles as f64 / 1e6),
        format!("{:.1}", f.gqa_attn_cycles as f64 / 1e6),
        format!("{:.2}x", f.attn_latency_ratio()),
        "3.14x".into(),
    ]);
    t.render()
}

/// Fig. 5 — time-resolved occupancy traces, plot + stats + CSV.
pub fn fig5(pair: &PairedStage1) -> (String, String, String) {
    let render = |s1: &Stage1Run, label: &str, paper_peak: f64, paper_ms: f64| {
        let tr = s1.result.sram_trace();
        let pts_needed: Vec<(f64, f64)> = tr
            .downsample(400)
            .iter()
            .map(|s| (s.t as f64 / 1e6, s.needed as f64 / MIB as f64))
            .collect();
        let pts_occ: Vec<(f64, f64)> = tr
            .downsample(400)
            .iter()
            .map(|s| (s.t as f64 / 1e6, (s.needed + s.obsolete) as f64 / MIB as f64))
            .collect();
        let plot = AsciiPlot::new(&format!(
            "Fig. 5 ({label}): peak needed {:.1} MiB (paper {paper_peak}), \
             end-to-end {:.1} ms (paper {paper_ms})",
            tr.peak_needed() as f64 / MIB as f64,
            s1.result.seconds() * 1e3,
        ))
        .series("needed", pts_needed)
        .series("needed+obsolete", pts_occ)
        .labels("t [Mcycles]", "MiB");
        plot.render()
    };
    let text = format!(
        "{}\n{}",
        render(&pair.mha, "GPT-2 XL / MHA", 107.3, 593.9),
        render(&pair.gqa, "DS-R1D / GQA", 39.1, 313.6),
    );
    (
        text,
        trace_to_csv(pair.mha.result.sram_trace()),
        trace_to_csv(pair.gqa.result.sram_trace()),
    )
}

/// Fig. 6 — per-operation latency breakdown table for one workload.
pub fn fig6_half(result: &SimResult, label: &str) -> Table {
    let mut t = Table::new(
        &format!("Fig. 6 ({label}) — per-op-class latency breakdown [Mcycles]"),
        &["Op class", "Compute", "Memory", "Idle", "Mem+Idle %", "Count"],
    );
    for class in OpClass::all() {
        let Some(b) = result.op_breakdown.get(class) else {
            continue;
        };
        let total = b.total().max(1);
        t.row(vec![
            class.label().into(),
            format!("{:.2}", b.compute as f64 / 1e6),
            format!("{:.2}", b.memory as f64 / 1e6),
            format!("{:.2}", b.idle as f64 / 1e6),
            format!("{:.0}%", (b.memory + b.idle) as f64 / total as f64 * 100.0),
            b.count.to_string(),
        ]);
    }
    t
}

pub fn fig6(pair: &PairedStage1) -> String {
    format!(
        "{}\n{}",
        fig6_half(&pair.mha.result, "GPT-2 XL / MHA").render(),
        fig6_half(&pair.gqa.result, "DS-R1D / GQA").render()
    )
}

/// Fig. 7 — on-chip energy breakdown + utilization.
pub fn fig7(pair: &PairedStage1) -> String {
    let mut t = Table::new(
        "Fig. 7 — on-chip energy breakdown (128 MiB shared SRAM)",
        &["Component [J]", "GPT-2 XL (MHA)", "DS-R1D (GQA)"],
    );
    let rows: Vec<(&str, fn(&Stage1Run) -> f64)> = vec![
        ("PE dynamic", |s| s.energy.pe_dynamic_j),
        ("PE static", |s| s.energy.pe_static_j),
        ("FIFO static", |s| s.energy.fifo_static_j),
        ("SRAM dynamic", |s| s.energy.sram_dynamic_j),
        ("SRAM leakage", |s| s.energy.sram_leakage_j),
    ];
    for (name, f) in rows {
        t.row(vec![
            name.into(),
            format!("{:.2}", f(&pair.mha)),
            format!("{:.2}", f(&pair.gqa)),
        ]);
    }
    t.row(vec![
        "Total on-chip".into(),
        format!("{:.2} (paper 78.47)", pair.mha.energy.on_chip_j()),
        format!("{:.2} (paper 40.52)", pair.gqa.energy.on_chip_j()),
    ]);
    t.row(vec![
        "Active PE utilization".into(),
        format!(
            "{:.0}% (paper 38%)",
            pair.mha.result.active_utilization() * 100.0
        ),
        format!(
            "{:.0}% (paper 77%)",
            pair.gqa.result.active_utilization() * 100.0
        ),
    ]);
    t.render()
}

/// Fig. 8 — bank-activity timelines under different alphas.
pub fn fig8(f: &Fig8) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "Fig. 8 — DS-R1D @ 64 MiB, B=4: active banks over time per alpha \
         (trace peak {:.1} MiB)",
        f.trace_peak as f64 / MIB as f64
    );
    for (alpha, tl) in f.alphas.iter().zip(&f.timelines) {
        let total: u64 = tl.iter().map(|s| s.dt()).sum();
        let avg = crate::banking::avg_active(tl);
        let gate_time: u64 = tl
            .iter()
            .map(|s| s.dt() * (4 - s.active.min(4)) as u64)
            .sum();
        let _ = writeln!(
            out,
            "  alpha={alpha:<4} avg active={avg:.2}/4  \
             idle bank-time={:.0}%  segments={}",
            gate_time as f64 / (total as f64 * 4.0) * 100.0,
            tl.len()
        );
        let pts: Vec<(f64, f64)> = tl
            .iter()
            .map(|s| (s.t0 as f64 / 1e6, s.active as f64))
            .collect();
        let plot = AsciiPlot::new(&format!("  activity timeline (alpha={alpha})"))
            .series("B_act", pts)
            .labels("t [Mcycles]", "banks");
        let mut p = plot;
        p.height = 6;
        out.push_str(&p.render());
    }
    out
}

/// Fig. 9 — energy/area scatter CSV (both workloads, all (C,B) points).
pub fn fig9_csv(t2: &crate::api::experiments::Table2) -> String {
    let mut out = String::from("workload,capacity_mib,banks,energy_j,area_mm2\n");
    for (label, pts) in [("gpt2-xl", &t2.mha_points), ("ds-r1d", &t2.gqa_points)] {
        for p in pts.iter() {
            let _ = writeln!(
                out,
                "{label},{},{},{:.3},{:.1}",
                p.eval.capacity / MIB,
                p.eval.banks,
                p.eval.e_total_j(),
                p.eval.area_mm2
            );
        }
    }
    out
}

/// Stage-III state-timeline figure: one character row per bank, sampling
/// each bank's state over `width` evenly spaced instants of the
/// stall-adjusted run. Legend: `#` active, `-` idle (powered), `.`
/// gated, `d` drowsy, `w` waking. Deterministic — same report, same
/// bytes (the `repro replay` artifact alongside the timeline CSV).
pub fn online_timeline(r: &crate::banking::online::OnlineReport, width: usize) -> String {
    use crate::banking::online::BankState;
    let width = width.max(8);
    let end = r.end_cycles();
    let mut out = String::new();
    let _ = writeln!(
        out,
        "Stage III — per-bank state timeline, {} ({} trace + {} stall cycles, \
         {} wake event(s))",
        r.config.label(),
        r.trace_cycles,
        r.stall_cycles,
        r.wake_events,
    );
    let _ = writeln!(out, "legend: '#' active  '-' idle  '.' gated  'd' drowsy  'w' waking");
    if end == 0 || r.timelines.is_empty() {
        let _ = writeln!(out, "(empty run or timeline recording disabled)");
        return out;
    }
    let glyph = |s: BankState| match s {
        BankState::Active => '#',
        BankState::Idle => '-',
        BankState::Gated => '.',
        BankState::Drowsy => 'd',
        BankState::Waking => 'w',
    };
    for (b, spans) in r.timelines.iter().enumerate() {
        let mut row = String::with_capacity(width);
        let mut idx = 0usize;
        for i in 0..width {
            // Sample the state holding at the bucket's start instant.
            let t = end * i as u64 / width as u64;
            while idx + 1 < spans.len() && spans[idx].t1 <= t {
                idx += 1;
            }
            row.push(spans.get(idx).map(|s| glyph(s.state)).unwrap_or(' '));
        }
        let _ = writeln!(out, "bank {b:>2} |{row}|");
    }
    let _ = writeln!(
        out,
        "t: 0 .. {} cycles ({} cols, {:.0} cycles/col)",
        end,
        width,
        end as f64 / width as f64
    );
    out
}

/// Fig. 9 — ASCII scatter.
pub fn fig9(t2: &crate::api::experiments::Table2) -> String {
    let series = |pts: &[crate::banking::SweepPoint]| -> Vec<(f64, f64)> {
        pts.iter()
            .map(|p| (p.eval.area_mm2, p.eval.e_total_j()))
            .collect()
    };
    AsciiPlot::new("Fig. 9 — energy vs area across (C, B) candidates (alpha=0.9)")
        .series("GPT-2 XL", series(&t2.mha_points))
        .series("DS-R1D", series(&t2.gqa_points))
        .labels("area [mm2]", "E [J]")
        .render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{ApiContext, ExperimentSpec};
    use crate::config::tiny;
    use crate::workload::{TINY_GQA, TINY_MHA};

    fn tiny_pair() -> PairedStage1 {
        let ctx = ApiContext::new();
        let accel = tiny();
        let run = |model| {
            ExperimentSpec::builder()
                .model(model)
                .prefill(64)
                .accel(accel.clone())
                .build()
                .unwrap()
                .run_stage1(&ctx)
                .unwrap()
        };
        let mha = run(TINY_MHA);
        let gqa = run(TINY_GQA);
        PairedStage1 { mha, gqa, accel }
    }

    #[test]
    fn fig5_renders_and_exports_csv() {
        let pair = tiny_pair();
        let (text, csv_mha, csv_gqa) = fig5(&pair);
        assert!(text.contains("Fig. 5"));
        assert!(text.contains("peak needed"));
        assert!(csv_mha.starts_with("t_cycles,"));
        assert!(csv_gqa.lines().count() > 2);
    }

    #[test]
    fn fig6_contains_all_present_classes() {
        let pair = tiny_pair();
        let s = fig6(&pair);
        for label in ["QKV proj", "Attn score", "Softmax", "FFN matmul"] {
            assert!(s.contains(label), "missing {label}");
        }
    }

    #[test]
    fn fig7_totals_are_sums() {
        let pair = tiny_pair();
        let s = fig7(&pair);
        assert!(s.contains("Total on-chip"));
        assert!(s.contains("paper 78.47"));
    }

    #[test]
    fn online_timeline_renders_states_deterministically() {
        use crate::banking::{replay_trace, GatingPolicy, OnlineConfig};
        use crate::cacti::CactiModel;
        use crate::trace::{AccessStats, OccupancyTrace};
        let mut tr = OccupancyTrace::new("m", 64 * MIB);
        let mut t = 0;
        while t < 10_000_000 {
            tr.record(t, 20 * MIB, 0);
            tr.record(t + 100_000, 0, 0);
            t += 1_000_000;
        }
        tr.finalize(10_000_000);
        let cfg = OnlineConfig::new(64 * MIB, 4, 0.9, GatingPolicy::Aggressive);
        let r = replay_trace(
            &CactiModel::default(),
            &tr,
            &AccessStats::default(),
            cfg,
            1.0,
        )
        .unwrap();
        let s = online_timeline(&r, 80);
        assert!(s.contains("bank  0"), "{s}");
        assert!(s.contains("bank  3"), "{s}");
        assert!(s.contains('#') && s.contains('.'), "needs active+gated: {s}");
        assert!(s.contains("legend"));
        assert_eq!(s, online_timeline(&r, 80), "figure must be deterministic");
    }

    /// Golden fig9 CSV over synthetic round-number points: the exact
    /// byte layout of the paper artifact is pinned, and a zero-base
    /// degenerate point can never print NaN/inf (the CSV carries raw
    /// energy/area only; deltas are guarded at the struct level).
    #[test]
    fn golden_fig9_csv() {
        use crate::api::experiments::Table2;
        use crate::banking::{BankingEval, GatingPolicy, SweepPoint};
        use crate::cacti::SramCharacterization;

        let point = |banks: u32, e_total: f64, area: f64| SweepPoint {
            eval: BankingEval {
                capacity: 64 * MIB,
                banks,
                alpha: 0.9,
                policy: GatingPolicy::Aggressive,
                e_dyn_j: e_total,
                e_leak_j: 0.0,
                e_sw_j: 0.0,
                n_switch: 0,
                avg_active_banks: 1.0,
                gated_fraction: 0.0,
                area_mm2: area,
                latency_cycles: 10,
                characterization: SramCharacterization {
                    capacity: 64 * MIB,
                    banks,
                    e_read_j: 1e-9,
                    e_write_j: 1.1e-9,
                    p_leak_bank_w: 0.5,
                    e_switch_j: 1e-6,
                    wake_cycles: 100,
                    area_mm2: area,
                    latency_cycles: 10,
                },
            },
            base_e_j: 0.0, // degenerate base: must not leak NaN anywhere
            base_area_mm2: 0.0,
        };
        let t2 = Table2 {
            mha_points: vec![point(1, 10.0, 100.0)],
            gqa_points: vec![point(8, 5.0, 110.0)],
        };
        let got = fig9_csv(&t2);
        let want = "workload,capacity_mib,banks,energy_j,area_mm2\n\
                    gpt2-xl,64,1,10.000,100.0\n\
                    ds-r1d,64,8,5.000,110.0\n";
        assert_eq!(got, want);
        assert!(!got.contains("NaN") && !got.contains("inf"));
        // The ASCII scatter over the same points is NaN-free too.
        let plot = fig9(&t2);
        assert!(!plot.contains("NaN"), "{plot}");
    }
}
