//! Paper-style table renderers (Tables I-III + sizing summary), the
//! Stage-II optimizer's frontier/portfolio tables, and the Stage-III
//! online-validation table — all with deterministic CSV twins.

use std::fmt::Write as _;

use crate::analytic::PimEstimate;
use crate::api::experiments::{Sizing, Spectrum, Table2, Table3};
use crate::api::OnlineValidation;
use crate::banking::online::{BankState, OnlineReport};
use crate::banking::optimize::{OptimizeResult, WorkloadFrontier, WorkloadSweep};
use crate::banking::SweepPoint;
use crate::util::table::{fmt_delta_pct, Table};
use crate::util::MIB;
use crate::workload::{all_presets, ModelPreset};

/// Table I — model configurations (computed from the presets, not
/// hardcoded, so a preset typo would show up here and in the tests).
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table I — Model configurations",
        &[
            "Model", "M", "L", "D", "Dff", "Attn", "H", "Hkv", "FFN",
            "P (B)", "MACs (T)",
        ],
    );
    for m in all_presets()
        .iter()
        .filter(|m| m.name.starts_with("gpt2") || m.name.starts_with("ds-"))
    {
        t.row(table1_row(m, 2048));
    }
    t
}

pub fn table1_row(m: &ModelPreset, seq: u64) -> Vec<String> {
    vec![
        m.name.to_string(),
        seq.to_string(),
        m.layers.to_string(),
        m.d_model.to_string(),
        m.d_ff.to_string(),
        format!("{:?}", m.attn_kind()).to_uppercase(),
        m.heads.to_string(),
        m.kv_heads.to_string(),
        format!("{:?}", m.ffn),
        format!("{:.2}", m.param_count() as f64 / 1e9),
        format!("{:.2}", m.total_macs(seq) as f64 / 1e12),
    ]
}

/// One workload's half of Table II (rows = capacity, columns = banks).
pub fn table2_half(title: &str, points: &[SweepPoint], banks: &[u32]) -> Table {
    let mut headers: Vec<String> = vec!["C [MiB]".into()];
    for &b in banks {
        headers.push(format!("E(B={b}) [J]"));
        headers.push(format!("A(B={b}) [mm2]"));
        if b != 1 {
            headers.push(format!("dE%({b})"));
            headers.push(format!("dA%({b})"));
        }
    }
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(title, &hdr_refs);

    let mut capacities: Vec<u64> = points.iter().map(|p| p.eval.capacity).collect();
    capacities.sort_unstable();
    capacities.dedup();
    for cap in capacities {
        let mut row = vec![format!("{}", cap / MIB)];
        for &b in banks {
            let Some(p) = points
                .iter()
                .find(|p| p.eval.capacity == cap && p.eval.banks == b)
            else {
                row.push("-".into());
                row.push("-".into());
                if b != 1 {
                    row.push("-".into());
                    row.push("-".into());
                }
                continue;
            };
            row.push(format!("{:.2}", p.eval.e_total_j()));
            row.push(format!("{:.1}", p.eval.area_mm2));
            if b != 1 {
                row.push(fmt_delta_pct(p.eval.e_total_j(), p.base_e_j));
                row.push(fmt_delta_pct(p.eval.area_mm2, p.base_area_mm2));
            }
        }
        t.row(row);
    }
    t
}

/// Table II — both workloads.
pub fn table2(t2: &Table2) -> Vec<Table> {
    let banks = [1u32, 2, 4, 8, 16, 32];
    vec![
        table2_half(
            "Table II (top) — DeepSeek-R1-Distill-Qwen-1.5B, alpha=0.9",
            &t2.gqa_points,
            &banks,
        ),
        table2_half(
            "Table II (bottom) — GPT-2 XL, alpha=0.9",
            &t2.mha_points,
            &banks,
        ),
    ]
}

/// Table III — multi-level hierarchy, one block per memory.
pub fn table3(t3: &Table3) -> Vec<Table> {
    let banks = [1u32, 4, 8, 16];
    t3.per_memory
        .iter()
        .map(|(mem, pts)| {
            table2_half(
                &format!("Table III — {} (multi-level, alpha=0.9)", mem),
                pts,
                &banks,
            )
        })
        .collect()
}

/// §IV-B sizing summary.
pub fn sizing_table(s: &Sizing) -> Table {
    let mut t = Table::new(
        "Memory sizing (Stage-I loop, 16 MiB steps)",
        &["Workload", "Peak needed", "Required capacity", "Note"],
    );
    t.row(vec![
        "GPT-2 XL".into(),
        format!("{:.1} MiB", s.mha_peak as f64 / MIB as f64),
        format!("{} MiB", s.mha_required / MIB),
        "paper: 107.3 -> 112 MiB".into(),
    ]);
    t.row(vec![
        "DS-R1D Q-1.5B".into(),
        format!("{:.1} MiB", s.gqa_peak as f64 / MIB as f64),
        format!("{} MiB", s.gqa_required / MIB),
        "paper: 39.1 -> 48 MiB".into(),
    ]);
    t.row(vec![
        "DS @ 64 MiB".into(),
        "-".into(),
        format!("{:+.2} ms vs 128 MiB", s.gqa_64mib_delta_s * 1e3),
        "paper: -1.48 ms (22 ns SRAM)".into(),
    ]);
    t
}

/// Full Stage-II sweep of one workload, one row per evaluated
/// (C, B, alpha, policy) cell — the human-readable twin of the lab
/// store's bit-exact `sweep.json` artifact. Deterministic field order
/// and float precision, like every renderer here.
pub fn sweep_table(w: &WorkloadSweep) -> Table {
    let mut t = Table::new(
        &format!(
            "Stage-II sweep — {} ({} points over {} cycles)",
            w.name,
            w.points.len(),
            w.end_cycles
        ),
        &[
            "C [MiB]", "B", "alpha", "policy", "E [J]", "dE%", "avgBact",
            "gated%", "A [mm2]", "dA%",
        ],
    );
    for p in &w.points {
        t.row(vec![
            (p.eval.capacity / MIB).to_string(),
            p.eval.banks.to_string(),
            format!("{:.2}", p.eval.alpha),
            p.eval.policy.label().to_string(),
            format!("{:.3}", p.eval.e_total_j()),
            fmt_delta_pct(p.eval.e_total_j(), p.base_e_j),
            format!("{:.2}", p.eval.avg_active_banks),
            format!("{:.1}", p.eval.gated_fraction * 100.0),
            format!("{:.1}", p.eval.area_mm2),
            fmt_delta_pct(p.eval.area_mm2, p.base_area_mm2),
        ]);
    }
    t
}

/// Attention-variant spectrum table (`repro spectrum`): one row per
/// preset of [`crate::workload::spectrum_presets`] after the full
/// Stage I → Stage II pipeline, with the PIM-offload comparison columns.
/// The title carries the paired-prefill peak ratio when it was computed
/// (the paper's 2.72x headline).
pub fn spectrum_table(s: &Spectrum) -> Table {
    let title = match s.paper_peak_ratio {
        Some(r) => format!(
            "Attention spectrum — decode {}+{} (paper paired-prefill peak \
             ratio {:.2}x)",
            s.prompt, s.gen, r
        ),
        None => format!("Attention spectrum — decode {}+{}", s.prompt, s.gen),
    };
    let mut t = Table::new(
        &title,
        &[
            "Preset", "Attn", "KV [MiB]", "Peak [MiB]", "best dE%",
            "E_best [J]", "E_pim [J]", "PIM peak [MiB]",
        ],
    );
    for r in &s.rows {
        t.row(vec![
            r.name.to_string(),
            format!("{:?}", r.attn).to_uppercase(),
            format!("{:.2}", r.kv_bytes as f64 / MIB as f64),
            format!("{:.2}", r.peak_needed as f64 / MIB as f64),
            format!("{:+.1}", r.best_delta_pct),
            format!("{:.3}", r.best_energy_j),
            format!("{:.3}", r.pim_e_j),
            format!("{:.2}", r.pim_relieved_peak as f64 / MIB as f64),
        ]);
    }
    t
}

/// Deterministic CSV twin of [`spectrum_table`] — the `repro spectrum
/// --csv` artifact and the CI spectrum determinism gate's comparison
/// subject. Byte counts (not MiB) and full float precision; the optional
/// paper ratio lands on a trailing `paper_peak_ratio` line so two runs
/// with the same flags are byte-identical.
pub fn spectrum_csv(s: &Spectrum) -> String {
    let mut out = String::from(
        "preset,attn,kv_bytes,peak_needed_bytes,best_delta_e_pct,\
         best_energy_j,pim_e_j,pim_relieved_peak_bytes\n",
    );
    for r in &s.rows {
        let _ = writeln!(
            out,
            "{},{},{},{},{:.4},{:.6},{:.6},{}",
            r.name,
            format!("{:?}", r.attn).to_uppercase(),
            r.kv_bytes,
            r.peak_needed,
            r.best_delta_pct,
            r.best_energy_j,
            r.pim_e_j,
            r.pim_relieved_peak,
        );
    }
    if let Some(ratio) = s.paper_peak_ratio {
        let _ = writeln!(out, "paper_peak_ratio,{ratio:.6}");
    }
    out
}

/// One workload's ε-Pareto frontier (from
/// [`crate::banking::optimize::optimize`]): the configurations that are
/// not (ε-)beaten on all of energy, activity, and area at once.
pub fn pareto_table(f: &WorkloadFrontier) -> Table {
    let mut t = Table::new(
        &format!(
            "Pareto frontier — {} ({} feasible -> {} on frontier)",
            f.workload,
            f.feasible,
            f.frontier.len()
        ),
        &[
            "C [MiB]", "B", "alpha", "policy", "E [J]", "dE%", "avgBact",
            "A [mm2]", "dA%", "wake%",
        ],
    );
    for fp in &f.frontier {
        let p = &fp.point;
        t.row(vec![
            (p.eval.capacity / MIB).to_string(),
            p.eval.banks.to_string(),
            format!("{:.2}", p.eval.alpha),
            p.eval.policy.label().to_string(),
            format!("{:.3}", p.eval.e_total_j()),
            fmt_delta_pct(p.eval.e_total_j(), p.base_e_j),
            format!("{:.2}", p.eval.avg_active_banks),
            format!("{:.1}", p.eval.area_mm2),
            fmt_delta_pct(p.eval.area_mm2, p.base_area_mm2),
            format!("{:.2}", fp.wake_exposure_pct),
        ]);
    }
    t
}

/// [`pareto_table`] with the PIM-offload comparison columns: the
/// closed-form PIM energy for the same (model, workload) and each
/// frontier configuration's energy as a multiple of it. Existing
/// callers keep the PIM-free renderer; this wrapper is additive so the
/// golden pins on [`pareto_table`] stay valid.
pub fn pareto_table_pim(f: &WorkloadFrontier, pim: &PimEstimate) -> Table {
    let mut t = Table::new(
        &format!(
            "Pareto frontier vs PIM offload — {} ({} feasible -> {} on \
             frontier; E_pim {:.3} J)",
            f.workload,
            f.feasible,
            f.frontier.len(),
            pim.e_pim_j
        ),
        &[
            "C [MiB]", "B", "alpha", "policy", "E [J]", "dE%", "avgBact",
            "A [mm2]", "dA%", "wake%", "E/Epim",
        ],
    );
    for fp in &f.frontier {
        let p = &fp.point;
        let ratio = if pim.e_pim_j == 0.0 {
            "-".to_string()
        } else {
            format!("{:.2}", p.eval.e_total_j() / pim.e_pim_j)
        };
        t.row(vec![
            (p.eval.capacity / MIB).to_string(),
            p.eval.banks.to_string(),
            format!("{:.2}", p.eval.alpha),
            p.eval.policy.label().to_string(),
            format!("{:.3}", p.eval.e_total_j()),
            fmt_delta_pct(p.eval.e_total_j(), p.base_e_j),
            format!("{:.2}", p.eval.avg_active_banks),
            format!("{:.1}", p.eval.area_mm2),
            fmt_delta_pct(p.eval.area_mm2, p.base_area_mm2),
            format!("{:.2}", fp.wake_exposure_pct),
            ratio,
        ]);
    }
    t
}

/// Cross-workload portfolio regret, best-first (the top row is the
/// robust-best configuration). `max_rows` bounds the rendered rows; the
/// full ranking lives in the [`OptimizeResult`].
pub fn portfolio_table(r: &OptimizeResult, max_rows: usize) -> Table {
    let shown = max_rows.min(r.portfolio.len());
    let mut headers: Vec<String> = vec!["Config".into()];
    for name in &r.workload_names {
        headers.push(format!("regret% {name}"));
    }
    headers.push("worst%".into());
    headers.push("mean%".into());
    let hdr: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(
        &format!(
            "Portfolio regret (top {shown} of {} shared configs; \
             row 1 = robust-best)",
            r.portfolio.len()
        ),
        &hdr,
    );
    for e in r.portfolio.iter().take(max_rows) {
        let mut row = vec![e.key.label()];
        for reg in &e.regret_pct {
            row.push(format!("{reg:+.1}"));
        }
        row.push(format!("{:+.1}", e.worst_regret_pct));
        row.push(format!("{:+.1}", e.mean_regret_pct));
        t.row(row);
    }
    t
}

/// [`portfolio_table`] with a PIM-offload comparison column per
/// workload: each shared configuration's energy on that workload as a
/// multiple of the closed-form PIM energy (`-` for workloads with no
/// closed form, e.g. serving). `pim_e_j` pairs with
/// `r.workload_names` by index.
pub fn portfolio_table_pim(
    r: &OptimizeResult,
    max_rows: usize,
    pim_e_j: &[Option<f64>],
) -> Table {
    let shown = max_rows.min(r.portfolio.len());
    let mut headers: Vec<String> = vec!["Config".into()];
    for name in &r.workload_names {
        headers.push(format!("regret% {name}"));
        headers.push(format!("xPIM {name}"));
    }
    headers.push("worst%".into());
    headers.push("mean%".into());
    let hdr: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(
        &format!(
            "Portfolio regret vs PIM offload (top {shown} of {} shared \
             configs; row 1 = robust-best)",
            r.portfolio.len()
        ),
        &hdr,
    );
    for e in r.portfolio.iter().take(max_rows) {
        let mut row = vec![e.key.label()];
        for (i, reg) in e.regret_pct.iter().enumerate() {
            row.push(format!("{reg:+.1}"));
            match pim_e_j.get(i).copied().flatten() {
                Some(p) if p > 0.0 => {
                    row.push(format!("{:.2}", e.energy_j[i] / p));
                }
                _ => row.push("-".into()),
            }
        }
        row.push(format!("{:+.1}", e.worst_regret_pct));
        row.push(format!("{:+.1}", e.mean_regret_pct));
        t.row(row);
    }
    t
}

/// Deterministic CSV of every frontier point of every workload — the
/// `repro optimize --pareto-csv` artifact and the CI determinism gate's
/// comparison subject. Fixed field order and float precision: equal
/// inputs produce byte-identical output.
pub fn pareto_csv(r: &OptimizeResult) -> String {
    let mut out = String::from(
        "workload,capacity_mib,banks,alpha,policy,energy_j,delta_e_pct,\
         avg_active_banks,area_mm2,delta_a_pct,wake_exposure_pct\n",
    );
    for f in &r.frontiers {
        for fp in &f.frontier {
            let p = &fp.point;
            let _ = writeln!(
                out,
                "{},{},{},{:.3},{},{:.6},{:.3},{:.4},{:.3},{:.3},{:.4}",
                f.workload,
                p.eval.capacity / MIB,
                p.eval.banks,
                p.eval.alpha,
                p.eval.policy.label(),
                p.eval.e_total_j(),
                p.delta_e_pct(),
                p.eval.avg_active_banks,
                p.eval.area_mm2,
                p.delta_a_pct(),
                fp.wake_exposure_pct,
            );
        }
    }
    out
}

/// Stage-III validation table: every replayed frontier configuration's
/// offline prediction vs its online (stall-adjusted) observation — the
/// `repro optimize --online-validate 1` artifact.
pub fn validation_table(vals: &[OnlineValidation]) -> Table {
    let mut t = Table::new(
        &format!(
            "Online validation — {} frontier config(s) replayed (Stage III)",
            vals.len()
        ),
        &[
            "Workload", "Config", "E_pred [J]", "E_obs [J]", "dE%",
            "wake_pred%", "stall_obs%", "stall [cyc]", "wakes",
        ],
    );
    for v in vals {
        t.row(vec![
            v.workload.clone(),
            v.key.label(),
            format!("{:.3}", v.predicted_e_j),
            format!("{:.3}", v.observed_e_j),
            format!("{:+.3}", v.energy_delta_pct),
            format!("{:.2}", v.predicted_wake_pct),
            format!("{:.2}", v.observed_stall_pct),
            v.stall_cycles.to_string(),
            v.wake_events.to_string(),
        ]);
    }
    t
}

/// Deterministic CSV of the Stage-III validation pass (fixed field order
/// and float precision — equal inputs are byte-identical; the golden
/// test pins the exact bytes).
pub fn validation_csv(vals: &[OnlineValidation]) -> String {
    let mut out = String::from(
        "workload,config,predicted_e_j,observed_e_j,energy_delta_pct,\
         predicted_wake_pct,observed_stall_pct,trace_cycles,stall_cycles,\
         wake_events\n",
    );
    for v in vals {
        let _ = writeln!(
            out,
            "{},{},{:.6},{:.6},{:.4},{:.4},{:.4},{},{},{}",
            v.workload,
            v.key.label(),
            v.predicted_e_j,
            v.observed_e_j,
            v.energy_delta_pct,
            v.predicted_wake_pct,
            v.observed_stall_pct,
            v.trace_cycles,
            v.stall_cycles,
            v.wake_events,
        );
    }
    out
}

/// Per-bank state occupancy of one Stage-III replay: how each bank's
/// (stall-adjusted) run splits across the five states. Shares are
/// percentages of the adjusted run length.
pub fn online_bank_table(r: &OnlineReport) -> Table {
    let mut t = Table::new(
        &format!(
            "Per-bank state occupancy — {} (wake {} cyc, {} stall cyc)",
            r.config.label(),
            r.wake_cycles,
            r.stall_cycles
        ),
        &[
            "Bank", "active%", "idle%", "gated%", "drowsy%", "waking%", "spans",
        ],
    );
    let end = r.end_cycles();
    let pct = |cycles: u64| -> String {
        if end == 0 {
            "0.0".to_string()
        } else {
            format!("{:.1}", cycles as f64 / end as f64 * 100.0)
        }
    };
    for (b, spans) in r.timelines.iter().enumerate() {
        t.row(vec![
            b.to_string(),
            pct(r.state_cycles(b, BankState::Active)),
            pct(r.state_cycles(b, BankState::Idle)),
            pct(r.state_cycles(b, BankState::Gated)),
            pct(r.state_cycles(b, BankState::Drowsy)),
            pct(r.state_cycles(b, BankState::Waking)),
            spans.len().to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_columns() {
        let t = table1();
        assert_eq!(t.rows.len(), 2);
        let flat = t.render();
        assert!(flat.contains("1.48") || flat.contains("1.47"));
        assert!(flat.contains("3.66"));
        assert!(flat.contains("1.31"));
        assert!(flat.contains("3.04"));
        assert!(flat.contains("MHA"));
        assert!(flat.contains("GQA"));
    }

    #[test]
    fn table2_half_renders_deltas() {
        use crate::banking::{evaluate, GatingPolicy};
        use crate::cacti::CactiModel;
        use crate::trace::{AccessStats, OccupancyTrace};

        let mut tr = OccupancyTrace::new("sram", 64 * MIB);
        tr.record(10, 20 * MIB, 0);
        tr.finalize(1_000_000);
        let stats = AccessStats {
            reads: 1000,
            writes: 100,
            ..Default::default()
        };
        let cacti = CactiModel::default();
        let base = evaluate(
            &cacti, &tr, &stats, 64 * MIB, 1, 0.9,
            GatingPolicy::None, 1.0,
        )
        .unwrap();
        let banked = evaluate(
            &cacti, &tr, &stats, 64 * MIB, 8, 0.9,
            GatingPolicy::Aggressive, 1.0,
        )
        .unwrap();
        let pts = vec![
            SweepPoint {
                base_e_j: base.e_total_j(),
                base_area_mm2: base.area_mm2,
                eval: base,
            },
            SweepPoint {
                base_e_j: banked.e_total_j(), // placeholder, fixed below
                base_area_mm2: 0.0,
                eval: banked,
            },
        ];
        let mut pts = pts;
        pts[1].base_e_j = pts[0].eval.e_total_j();
        pts[1].base_area_mm2 = pts[0].eval.area_mm2;
        let t = table2_half("test", &pts, &[1, 8]);
        let s = t.render();
        assert!(s.contains("64"));
        assert!(s.contains('-'), "banked delta must be negative: {s}");
    }

    // ---- golden-output suite -------------------------------------------
    //
    // Synthetic points with round numbers make the expected strings
    // hand-computable; any formatting/column regression fails here in CI
    // instead of silently corrupting paper artifacts.

    use crate::banking::optimize::{
        ConfigKey, Constraints, FrontierPoint, OptimizeResult, PortfolioEntry,
        WorkloadFrontier,
    };
    use crate::banking::{BankingEval, GatingPolicy};
    use crate::cacti::SramCharacterization;

    fn synth_ch(capacity: u64, banks: u32) -> SramCharacterization {
        SramCharacterization {
            capacity,
            banks,
            e_read_j: 1e-9,
            e_write_j: 1.1e-9,
            p_leak_bank_w: 0.5,
            e_switch_j: 1e-6,
            wake_cycles: 100,
            area_mm2: 0.0,
            latency_cycles: 10,
        }
    }

    fn synth_point(
        cap_mib: u64,
        banks: u32,
        e_total: f64,
        area: f64,
        base_e: f64,
        base_a: f64,
    ) -> SweepPoint {
        SweepPoint {
            eval: BankingEval {
                capacity: cap_mib * MIB,
                banks,
                alpha: 0.9,
                policy: GatingPolicy::Aggressive,
                e_dyn_j: e_total,
                e_leak_j: 0.0,
                e_sw_j: 0.0,
                n_switch: 4,
                avg_active_banks: 2.5,
                gated_fraction: 0.25,
                area_mm2: area,
                latency_cycles: 10,
                characterization: synth_ch(cap_mib * MIB, banks),
            },
            base_e_j: base_e,
            base_area_mm2: base_a,
        }
    }

    fn synth_frontier(workload: &str, point: SweepPoint) -> WorkloadFrontier {
        WorkloadFrontier {
            workload: workload.to_string(),
            end_cycles: 1_000,
            feasible: 2,
            best_energy_j: point.eval.e_total_j(),
            best_key: ConfigKey::of(&point),
            frontier: vec![FrontierPoint {
                wake_exposure_pct: 20.0,
                point,
            }],
        }
    }

    #[test]
    fn golden_table2_half_csv() {
        let pts = vec![
            synth_point(64, 1, 10.0, 100.0, 10.0, 100.0),
            synth_point(64, 8, 5.0, 110.0, 10.0, 100.0),
        ];
        let got = table2_half("golden", &pts, &[1, 8]).to_csv();
        let want = "C [MiB],E(B=1) [J],A(B=1) [mm2],E(B=8) [J],A(B=8) [mm2],dE%(8),dA%(8)\n\
                    64,10.00,100.0,5.00,110.0,-50.0,+10.0\n";
        assert_eq!(got, want);
    }

    #[test]
    fn golden_table2_zero_base_renders_dash_not_nan() {
        // Regression (fig9/table2 NaN audit): a zero-energy/area base —
        // a degenerate zero-length trace — must render the paper's dash.
        let pts = vec![synth_point(64, 8, 5.0, 110.0, 0.0, 0.0)];
        let t = table2_half("golden", &pts, &[8]);
        let csv = t.to_csv();
        assert_eq!(
            csv,
            "C [MiB],E(B=8) [J],A(B=8) [mm2],dE%(8),dA%(8)\n\
             64,5.00,110.0,–,–\n"
        );
        let rendered = t.render();
        assert!(!rendered.contains("NaN"), "{rendered}");
        assert!(!rendered.contains("inf"), "{rendered}");
        assert!(rendered.contains('–'), "{rendered}");
    }

    #[test]
    fn golden_pareto_table_csv() {
        let f = synth_frontier("wa", synth_point(64, 8, 5.0, 110.0, 10.0, 100.0));
        let got = pareto_table(&f).to_csv();
        let want = "C [MiB],B,alpha,policy,E [J],dE%,avgBact,A [mm2],dA%,wake%\n\
                    64,8,0.90,aggressive,5.000,-50.0,2.50,110.0,+10.0,20.00\n";
        assert_eq!(got, want);
        assert!(pareto_table(&f)
            .render()
            .contains("2 feasible -> 1 on frontier"));
    }

    #[test]
    fn golden_portfolio_table_csv() {
        let pa = synth_point(64, 8, 5.0, 110.0, 10.0, 100.0);
        let r = OptimizeResult {
            epsilon: 0.0,
            constraints: Constraints::default(),
            workload_names: vec!["wa".to_string(), "wb".to_string()],
            frontiers: vec![
                synth_frontier("wa", pa.clone()),
                synth_frontier("wb", pa.clone()),
            ],
            portfolio: vec![PortfolioEntry {
                key: ConfigKey::of(&pa),
                energy_j: vec![5.0, 11.0],
                regret_pct: vec![0.0, 10.0],
                worst_regret_pct: 10.0,
                mean_regret_pct: 5.0,
            }],
        };
        let got = portfolio_table(&r, 20).to_csv();
        let want = "Config,regret% wa,regret% wb,worst%,mean%\n\
                    64MiB/B8/a0.90/aggressive,+0.0,+10.0,+10.0,+5.0\n";
        assert_eq!(got, want);
    }

    #[test]
    fn golden_pareto_csv() {
        let r = OptimizeResult {
            epsilon: 0.0,
            constraints: Constraints::default(),
            workload_names: vec!["wa".to_string()],
            frontiers: vec![synth_frontier(
                "wa",
                synth_point(64, 8, 5.0, 110.0, 10.0, 100.0),
            )],
            portfolio: vec![],
        };
        let got = pareto_csv(&r);
        let want = "workload,capacity_mib,banks,alpha,policy,energy_j,delta_e_pct,\
                    avg_active_banks,area_mm2,delta_a_pct,wake_exposure_pct\n\
                    wa,64,8,0.900,aggressive,5.000000,-50.000,2.5000,110.000,10.000,20.0000\n";
        assert_eq!(got, want);
    }

    #[test]
    fn golden_validation_table_and_csv() {
        // Round numbers make every formatted field hand-computable; any
        // formatting/column regression fails here in CI instead of
        // silently corrupting the Stage-III artifacts (the PR-4 golden
        // pattern).
        let key = ConfigKey::of(&synth_point(64, 8, 5.0, 110.0, 10.0, 100.0));
        let vals = vec![crate::api::OnlineValidation {
            workload: "wa".to_string(),
            key,
            predicted_e_j: 5.0,
            observed_e_j: 5.25,
            energy_delta_pct: 5.0,
            predicted_wake_pct: 20.0,
            observed_stall_pct: 2.5,
            trace_cycles: 1_000,
            stall_cycles: 25,
            wake_events: 5,
        }];
        let got = validation_table(&vals).to_csv();
        let want = "Workload,Config,E_pred [J],E_obs [J],dE%,wake_pred%,\
                    stall_obs%,stall [cyc],wakes\n\
                    wa,64MiB/B8/a0.90/aggressive,5.000,5.250,+5.000,20.00,2.50,25,5\n";
        assert_eq!(got, want);
        let got_csv = validation_csv(&vals);
        let want_csv = "workload,config,predicted_e_j,observed_e_j,\
                        energy_delta_pct,predicted_wake_pct,observed_stall_pct,\
                        trace_cycles,stall_cycles,wake_events\n\
                        wa,64MiB/B8/a0.90/aggressive,5.000000,5.250000,5.0000,\
                        20.0000,2.5000,1000,25,5\n";
        assert_eq!(got_csv, want_csv);
        assert!(validation_table(&vals)
            .render()
            .contains("1 frontier config(s) replayed"));
    }

    fn synth_online_report() -> OnlineReport {
        use crate::banking::online::{OnlineConfig, StateSpan};
        use crate::banking::GatingPolicy;
        let point = synth_point(64, 2, 5.0, 110.0, 10.0, 100.0);
        OnlineReport {
            config: OnlineConfig::new(64 * MIB, 2, 0.9, GatingPolicy::Aggressive),
            eval: point.eval,
            trace_cycles: 900,
            stall_cycles: 100,
            wake_events: 1,
            wake_cycles: 100,
            timelines: vec![
                vec![StateSpan { t0: 0, t1: 1000, state: BankState::Active }],
                vec![
                    StateSpan { t0: 0, t1: 400, state: BankState::Gated },
                    StateSpan { t0: 400, t1: 500, state: BankState::Waking },
                    StateSpan { t0: 500, t1: 900, state: BankState::Active },
                    StateSpan { t0: 900, t1: 1000, state: BankState::Idle },
                ],
            ],
        }
    }

    #[test]
    fn golden_timeline_csv() {
        let got = synth_online_report().timeline_csv();
        let want = "bank,state,t0_cycles,t1_cycles\n\
                    0,active,0,1000\n\
                    1,gated,0,400\n\
                    1,waking,400,500\n\
                    1,active,500,900\n\
                    1,idle,900,1000\n";
        assert_eq!(got, want);
    }

    #[test]
    fn golden_online_bank_table_csv() {
        let got = online_bank_table(&synth_online_report()).to_csv();
        let want = "Bank,active%,idle%,gated%,drowsy%,waking%,spans\n\
                    0,100.0,0.0,0.0,0.0,0.0,1\n\
                    1,40.0,10.0,40.0,0.0,10.0,4\n";
        assert_eq!(got, want);
        assert!(online_bank_table(&synth_online_report())
            .render()
            .contains("64MiB/B2/a0.90/aggressive"));
    }

    #[test]
    fn golden_spectrum_table_and_csv() {
        use crate::api::experiments::SpectrumRow;
        use crate::workload::AttnKind;
        let s = Spectrum {
            prompt: 512,
            gen: 128,
            rows: vec![
                SpectrumRow {
                    name: "fig1-mha-124m",
                    attn: AttnKind::Mha,
                    kv_bytes: 2 * MIB,
                    peak_needed: 4 * MIB,
                    best_delta_pct: -25.0,
                    best_energy_j: 2.0,
                    pim_e_j: 0.5,
                    pim_relieved_peak: 2 * MIB,
                },
                SpectrumRow {
                    name: "fig1-mla-124m",
                    attn: AttnKind::Mla,
                    kv_bytes: MIB / 2,
                    peak_needed: 5 * MIB / 2,
                    best_delta_pct: -10.0,
                    best_energy_j: 1.5,
                    pim_e_j: 0.25,
                    pim_relieved_peak: 2 * MIB,
                },
            ],
            paper_peak_ratio: Some(2.72),
        };
        let got = spectrum_table(&s).to_csv();
        let want = "Preset,Attn,KV [MiB],Peak [MiB],best dE%,E_best [J],\
                    E_pim [J],PIM peak [MiB]\n\
                    fig1-mha-124m,MHA,2.00,4.00,-25.0,2.000,0.500,2.00\n\
                    fig1-mla-124m,MLA,0.50,2.50,-10.0,1.500,0.250,2.00\n";
        assert_eq!(got, want);
        assert!(spectrum_table(&s).render().contains("2.72x"));
        let got_csv = spectrum_csv(&s);
        let want_csv = "preset,attn,kv_bytes,peak_needed_bytes,\
                        best_delta_e_pct,best_energy_j,pim_e_j,\
                        pim_relieved_peak_bytes\n\
                        fig1-mha-124m,MHA,2097152,4194304,-25.0000,2.000000,\
                        0.500000,2097152\n\
                        fig1-mla-124m,MLA,524288,2621440,-10.0000,1.500000,\
                        0.250000,2097152\n\
                        paper_peak_ratio,2.720000\n";
        assert_eq!(got_csv, want_csv);
        // Without the paired-prefill run the footer line is absent, so
        // the CSV stays pure rows.
        let mut bare = s;
        bare.paper_peak_ratio = None;
        assert!(!spectrum_csv(&bare).contains("paper_peak_ratio"));
        assert!(!spectrum_table(&bare).render().contains("ratio"));
    }

    #[test]
    fn golden_pareto_table_pim_csv() {
        let f = synth_frontier("wa", synth_point(64, 8, 5.0, 110.0, 10.0, 100.0));
        let pim = PimEstimate {
            attn_macs: 1000,
            kv_write_bytes: 100,
            e_pim_j: 2.5,
            kv_cache_bytes: MIB,
        };
        let got = pareto_table_pim(&f, &pim).to_csv();
        let want = "C [MiB],B,alpha,policy,E [J],dE%,avgBact,A [mm2],dA%,\
                    wake%,E/Epim\n\
                    64,8,0.90,aggressive,5.000,-50.0,2.50,110.0,+10.0,20.00,2.00\n";
        assert_eq!(got, want);
        assert!(pareto_table_pim(&f, &pim).render().contains("E_pim 2.500 J"));
        // A zero PIM estimate renders a dash, never inf.
        let zero = PimEstimate {
            attn_macs: 0,
            kv_write_bytes: 0,
            e_pim_j: 0.0,
            kv_cache_bytes: 0,
        };
        let rendered = pareto_table_pim(&f, &zero).render();
        assert!(!rendered.contains("inf"), "{rendered}");
    }

    #[test]
    fn golden_portfolio_table_pim_csv() {
        let pa = synth_point(64, 8, 5.0, 110.0, 10.0, 100.0);
        let r = OptimizeResult {
            epsilon: 0.0,
            constraints: Constraints::default(),
            workload_names: vec!["wa".to_string(), "wb".to_string()],
            frontiers: vec![
                synth_frontier("wa", pa.clone()),
                synth_frontier("wb", pa.clone()),
            ],
            portfolio: vec![PortfolioEntry {
                key: ConfigKey::of(&pa),
                energy_j: vec![5.0, 11.0],
                regret_pct: vec![0.0, 10.0],
                worst_regret_pct: 10.0,
                mean_regret_pct: 5.0,
            }],
        };
        // wa has a closed-form PIM estimate; wb (say, serving) does not.
        let got = portfolio_table_pim(&r, 20, &[Some(2.5), None]).to_csv();
        let want = "Config,regret% wa,xPIM wa,regret% wb,xPIM wb,worst%,mean%\n\
                    64MiB/B8/a0.90/aggressive,+0.0,2.00,+10.0,-,+10.0,+5.0\n";
        assert_eq!(got, want);
    }

    #[test]
    fn pareto_csv_zero_base_is_finite() {
        // The CSV delta columns go through the struct-level guard: a
        // zero base yields 0.000, never NaN/inf.
        let r = OptimizeResult {
            epsilon: 0.0,
            constraints: Constraints::default(),
            workload_names: vec!["wa".to_string()],
            frontiers: vec![synth_frontier(
                "wa",
                synth_point(64, 8, 5.0, 110.0, 0.0, 0.0),
            )],
            portfolio: vec![],
        };
        let got = pareto_csv(&r);
        assert!(!got.contains("NaN") && !got.contains("inf"), "{got}");
        assert!(got.contains(",0.000,"), "{got}");
    }
}
