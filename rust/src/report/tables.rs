//! Paper-style table renderers (Tables I-III + sizing summary).

use crate::api::experiments::{Sizing, Table2, Table3};
use crate::banking::SweepPoint;
use crate::util::table::{fmt_delta_pct, Table};
use crate::util::MIB;
use crate::workload::{all_presets, ModelPreset};

/// Table I — model configurations (computed from the presets, not
/// hardcoded, so a preset typo would show up here and in the tests).
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table I — Model configurations",
        &[
            "Model", "M", "L", "D", "Dff", "Attn", "H", "Hkv", "FFN",
            "P (B)", "MACs (T)",
        ],
    );
    for m in all_presets()
        .iter()
        .filter(|m| m.name.starts_with("gpt2") || m.name.starts_with("ds-"))
    {
        t.row(table1_row(m, 2048));
    }
    t
}

pub fn table1_row(m: &ModelPreset, seq: u64) -> Vec<String> {
    vec![
        m.name.to_string(),
        seq.to_string(),
        m.layers.to_string(),
        m.d_model.to_string(),
        m.d_ff.to_string(),
        format!("{:?}", m.attn_kind()).to_uppercase(),
        m.heads.to_string(),
        m.kv_heads.to_string(),
        format!("{:?}", m.ffn),
        format!("{:.2}", m.param_count() as f64 / 1e9),
        format!("{:.2}", m.total_macs(seq) as f64 / 1e12),
    ]
}

/// One workload's half of Table II (rows = capacity, columns = banks).
pub fn table2_half(title: &str, points: &[SweepPoint], banks: &[u32]) -> Table {
    let mut headers: Vec<String> = vec!["C [MiB]".into()];
    for &b in banks {
        headers.push(format!("E(B={b}) [J]"));
        headers.push(format!("A(B={b}) [mm2]"));
        if b != 1 {
            headers.push(format!("dE%({b})"));
            headers.push(format!("dA%({b})"));
        }
    }
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(title, &hdr_refs);

    let mut capacities: Vec<u64> = points.iter().map(|p| p.eval.capacity).collect();
    capacities.sort_unstable();
    capacities.dedup();
    for cap in capacities {
        let mut row = vec![format!("{}", cap / MIB)];
        for &b in banks {
            let Some(p) = points
                .iter()
                .find(|p| p.eval.capacity == cap && p.eval.banks == b)
            else {
                row.push("-".into());
                row.push("-".into());
                if b != 1 {
                    row.push("-".into());
                    row.push("-".into());
                }
                continue;
            };
            row.push(format!("{:.2}", p.eval.e_total_j()));
            row.push(format!("{:.1}", p.eval.area_mm2));
            if b != 1 {
                row.push(fmt_delta_pct(p.eval.e_total_j(), p.base_e_j));
                row.push(fmt_delta_pct(p.eval.area_mm2, p.base_area_mm2));
            }
        }
        t.row(row);
    }
    t
}

/// Table II — both workloads.
pub fn table2(t2: &Table2) -> Vec<Table> {
    let banks = [1u32, 2, 4, 8, 16, 32];
    vec![
        table2_half(
            "Table II (top) — DeepSeek-R1-Distill-Qwen-1.5B, alpha=0.9",
            &t2.gqa_points,
            &banks,
        ),
        table2_half(
            "Table II (bottom) — GPT-2 XL, alpha=0.9",
            &t2.mha_points,
            &banks,
        ),
    ]
}

/// Table III — multi-level hierarchy, one block per memory.
pub fn table3(t3: &Table3) -> Vec<Table> {
    let banks = [1u32, 4, 8, 16];
    t3.per_memory
        .iter()
        .map(|(mem, pts)| {
            table2_half(
                &format!("Table III — {} (multi-level, alpha=0.9)", mem),
                pts,
                &banks,
            )
        })
        .collect()
}

/// §IV-B sizing summary.
pub fn sizing_table(s: &Sizing) -> Table {
    let mut t = Table::new(
        "Memory sizing (Stage-I loop, 16 MiB steps)",
        &["Workload", "Peak needed", "Required capacity", "Note"],
    );
    t.row(vec![
        "GPT-2 XL".into(),
        format!("{:.1} MiB", s.mha_peak as f64 / MIB as f64),
        format!("{} MiB", s.mha_required / MIB),
        "paper: 107.3 -> 112 MiB".into(),
    ]);
    t.row(vec![
        "DS-R1D Q-1.5B".into(),
        format!("{:.1} MiB", s.gqa_peak as f64 / MIB as f64),
        format!("{} MiB", s.gqa_required / MIB),
        "paper: 39.1 -> 48 MiB".into(),
    ]);
    t.row(vec![
        "DS @ 64 MiB".into(),
        "-".into(),
        format!("{:+.2} ms vs 128 MiB", s.gqa_64mib_delta_s * 1e3),
        "paper: -1.48 ms (22 ns SRAM)".into(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_columns() {
        let t = table1();
        assert_eq!(t.rows.len(), 2);
        let flat = t.render();
        assert!(flat.contains("1.48") || flat.contains("1.47"));
        assert!(flat.contains("3.66"));
        assert!(flat.contains("1.31"));
        assert!(flat.contains("3.04"));
        assert!(flat.contains("MHA"));
        assert!(flat.contains("GQA"));
    }

    #[test]
    fn table2_half_renders_deltas() {
        use crate::banking::{evaluate, GatingPolicy};
        use crate::cacti::CactiModel;
        use crate::trace::{AccessStats, OccupancyTrace};

        let mut tr = OccupancyTrace::new("sram", 64 * MIB);
        tr.record(10, 20 * MIB, 0);
        tr.finalize(1_000_000);
        let stats = AccessStats {
            reads: 1000,
            writes: 100,
            ..Default::default()
        };
        let cacti = CactiModel::default();
        let base = evaluate(
            &cacti, &tr, &stats, 64 * MIB, 1, 0.9,
            GatingPolicy::None, 1.0,
        );
        let banked = evaluate(
            &cacti, &tr, &stats, 64 * MIB, 8, 0.9,
            GatingPolicy::Aggressive, 1.0,
        );
        let pts = vec![
            SweepPoint {
                base_e_j: base.e_total_j(),
                base_area_mm2: base.area_mm2,
                eval: base,
            },
            SweepPoint {
                base_e_j: banked.e_total_j(), // placeholder, fixed below
                base_area_mm2: 0.0,
                eval: banked,
            },
        ];
        let mut pts = pts;
        pts[1].base_e_j = pts[0].eval.e_total_j();
        pts[1].base_area_mm2 = pts[0].eval.area_mm2;
        let t = table2_half("test", &pts, &[1, 8]);
        let s = t.render();
        assert!(s.contains("64"));
        assert!(s.contains('-'), "banked delta must be negative: {s}");
    }
}
