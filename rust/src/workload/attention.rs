//! Attention-block graph builders (MHA / GQA / MQA / MLA / sliding
//! window, prefill and decode).
//!
//! Tensor sizes are bytes at 1 byte/element (uniform 8-bit operands,
//! paper §IV-A). Positional-encoding ops are omitted per the paper
//! ("element-wise and do not materially affect the SRAM occupancy
//! trends"), consistently for both models.

use super::graph::GraphBuilder;
use super::models::ModelPreset;
use super::op::OpKind;
use super::tensor::{TensorId, TensorKind};

/// Per-layer tensors the attention block produces/uses.
pub struct AttnBlockOut {
    /// Residual-stream output of the attention sub-block.
    pub out: TensorId,
    /// Key cache tensor for this layer.
    pub k_cache: TensorId,
    /// Value cache tensor for this layer.
    pub v_cache: TensorId,
}

/// Build the prefill attention sub-block for `layer`:
/// norm -> qkv -> per-head (score -> softmax -> ctx) -> out-proj -> add.
///
/// Per-head score/prob matrices are MxM at 1 byte: the dominant transient
/// for MHA (25 heads x 4 MiB at M=2048 for GPT-2 XL). K/V are written
/// once per layer as whole-layer cache tensors (M x Hkv x Dh each).
pub fn build_prefill_attention(
    b: &mut GraphBuilder,
    m: &ModelPreset,
    layer: u16,
    seq: u32,
    x: TensorId,
) -> AttnBlockOut {
    let d = m.d_model;
    // Sliding-window attention caps the visible KV horizon; with the
    // knob off this is exactly `seq` and every expression below reduces
    // to the original full-causal form.
    let horizon = m.kv_horizon(seq as u64) as u32;
    // Attention scores/probabilities are kept at 16-bit internal
    // precision (int8 MAC outputs accumulate in int32 and softmax runs on
    // 16-bit fixed point before re-quantization — standard for 8-bit
    // accelerators; DESIGN.md §5). Hence 2 bytes per score element.
    let mm = 2 * seq as u64 * horizon as u64;

    // Pre-norm.
    let w_ln1 = b.tensor(
        format!("w.ln1.l{layer}"),
        2 * d as u64,
        TensorKind::Weight,
        layer,
    );
    let x_n = b.tensor(
        format!("xn1.l{layer}"),
        seq as u64 * d as u64,
        TensorKind::Activation,
        layer,
    );
    b.op(
        format!("norm:ln1.l{layer}"),
        layer,
        OpKind::Norm {
            elems: seq as u64 * d as u64,
        },
        vec![x, w_ln1],
        vec![x_n],
    );

    // Fused QKV projection writing q + per-layer K/V cache tensors.
    let w_qkv = b.tensor(
        format!("w.qkv.l{layer}"),
        d as u64 * m.qkv_out_dim() as u64,
        TensorKind::Weight,
        layer,
    );
    let q = b.tensor(
        format!("q.l{layer}"),
        seq as u64 * (m.heads * m.d_head) as u64,
        TensorKind::Activation,
        layer,
    );
    // Cache tensors hold only the visible horizon; MLA shrinks the
    // per-token footprint to the latent width (the k/v halves sum to
    // `kv_token_bytes` exactly, and equal `Hkv * Dh` each when off).
    let k_cache = b.tensor(
        format!("k.l{layer}"),
        horizon as u64 * m.k_token_bytes(),
        TensorKind::KvCache,
        layer,
    );
    let v_cache = b.tensor(
        format!("v.l{layer}"),
        horizon as u64 * m.v_token_bytes(),
        TensorKind::KvCache,
        layer,
    );
    b.op(
        format!("qkv:l{layer}"),
        layer,
        OpKind::MatMul {
            m: seq,
            k: d,
            n: m.qkv_out_dim(),
        },
        vec![x_n, w_qkv],
        vec![q, k_cache, v_cache],
    );

    // Per-head attention. Query head h reads KV head h / group.
    let mut ctx_heads = Vec::with_capacity(m.heads as usize);
    for h in 0..m.heads {
        let s = b.tensor(
            format!("s.l{layer}.h{h}"),
            mm,
            TensorKind::Score,
            layer,
        );
        b.op(
            format!("score:l{layer}.h{h}"),
            layer,
            OpKind::MatMul {
                m: seq,
                k: m.d_head,
                n: horizon,
            },
            vec![q, k_cache],
            vec![s],
        );
        // Softmax is fused in place: probabilities overwrite the score
        // matrix (read+write same tensor), so each head carries ONE MxM
        // transient from score production until context consumption.
        b.op(
            format!("softmax:l{layer}.h{h}"),
            layer,
            OpKind::Softmax {
                rows: seq,
                cols: horizon,
            },
            vec![s],
            vec![s],
        );
        let c = b.tensor(
            format!("c.l{layer}.h{h}"),
            seq as u64 * m.d_head as u64,
            TensorKind::Activation,
            layer,
        );
        b.op(
            format!("ctx:l{layer}.h{h}"),
            layer,
            OpKind::MatMul {
                m: seq,
                k: horizon,
                n: m.d_head,
            },
            vec![s, v_cache],
            vec![c],
        );
        ctx_heads.push(c);
    }

    // Output projection over the concatenated heads.
    let w_o = b.tensor(
        format!("w.o.l{layer}"),
        (m.heads * m.d_head) as u64 * d as u64,
        TensorKind::Weight,
        layer,
    );
    let attn_out = b.tensor(
        format!("attn.l{layer}"),
        seq as u64 * d as u64,
        TensorKind::Activation,
        layer,
    );
    let mut proj_reads = ctx_heads;
    proj_reads.push(w_o);
    b.op(
        format!("proj:l{layer}"),
        layer,
        OpKind::MatMul {
            m: seq,
            k: m.heads * m.d_head,
            n: d,
        },
        proj_reads,
        vec![attn_out],
    );

    // Residual add.
    let x1 = b.tensor(
        format!("x1.l{layer}"),
        seq as u64 * d as u64,
        TensorKind::Activation,
        layer,
    );
    b.op(
        format!("add:res1.l{layer}"),
        layer,
        OpKind::Elementwise {
            elems: seq as u64 * d as u64,
            inputs: 2,
        },
        vec![x, attn_out],
        vec![x1],
    );

    AttnBlockOut {
        out: x1,
        k_cache,
        v_cache,
    }
}

/// Build one decode-step attention sub-block (single token at position
/// `pos`, KV caches updated in place). Head-batched op granularity:
/// score is one `[H, Dh] x [Dh, ctx]` matmul per layer (TransInferSim
/// groups per-token per-layer work; per-head splitting at m=1 would only
/// add scheduling noise).
#[allow(clippy::too_many_arguments)]
pub fn build_decode_attention(
    b: &mut GraphBuilder,
    m: &ModelPreset,
    layer: u16,
    pos: u32,
    x: TensorId,
    w: &DecodeLayerWeights,
    k_cache: TensorId,
    v_cache: TensorId,
) -> TensorId {
    let d = m.d_model;
    // Visible context: pos + 1 cached tokens, capped at the sliding
    // window when enabled (decode occupancy then plateaus).
    let ctx = m.kv_horizon(pos as u64 + 1) as u32;

    let x_n = b.tensor(
        format!("xn1.l{layer}.t{pos}"),
        d as u64,
        TensorKind::Activation,
        layer,
    );
    b.op(
        format!("norm:ln1.l{layer}.t{pos}"),
        layer,
        OpKind::Norm { elems: d as u64 },
        vec![x, w.ln1],
        vec![x_n],
    );

    let qkv = b.tensor(
        format!("qkv.l{layer}.t{pos}"),
        m.qkv_out_dim() as u64,
        TensorKind::Activation,
        layer,
    );
    b.op(
        format!("qkv:l{layer}.t{pos}"),
        layer,
        OpKind::MatMul {
            m: 1,
            k: d,
            n: m.qkv_out_dim(),
        },
        vec![x_n, w.qkv],
        vec![qkv],
    );

    // KV append: in-place update of the persistent caches.
    b.op(
        format!("kvapp:l{layer}.t{pos}"),
        layer,
        OpKind::Elementwise {
            elems: m.kv_token_bytes(),
            inputs: 2,
        },
        vec![qkv, k_cache, v_cache],
        vec![k_cache, v_cache],
    );

    // Attention per KV-head group: each group's score op streams that
    // group's K slice (Dh x ctx) through the array, so total KV traffic
    // is Hkv * Dh * ctx — exactly what GQA divides by H/Hkv and the
    // source of the paper's Fig. 1 energy/latency gap.
    let group = m.heads / m.kv_heads;
    let mut ctx_heads = Vec::with_capacity(m.kv_heads as usize);
    for g in 0..m.kv_heads {
        let sg = b.tensor(
            format!("s.l{layer}.t{pos}.g{g}"),
            2 * group as u64 * ctx as u64, // 16-bit internals
            TensorKind::Score,
            layer,
        );
        b.op(
            format!("score:l{layer}.t{pos}.g{g}"),
            layer,
            OpKind::MatMul {
                m: group,
                k: m.d_head,
                n: ctx,
            },
            vec![qkv, k_cache],
            vec![sg],
        );
        b.op(
            format!("softmax:l{layer}.t{pos}.g{g}"),
            layer,
            OpKind::Softmax {
                rows: group,
                cols: ctx,
            },
            vec![sg],
            vec![sg],
        );
        let cg = b.tensor(
            format!("c.l{layer}.t{pos}.g{g}"),
            (group * m.d_head) as u64,
            TensorKind::Activation,
            layer,
        );
        b.op(
            format!("ctx:l{layer}.t{pos}.g{g}"),
            layer,
            OpKind::MatMul {
                m: group,
                k: ctx,
                n: m.d_head,
            },
            vec![sg, v_cache],
            vec![cg],
        );
        ctx_heads.push(cg);
    }

    let attn_out = b.tensor(
        format!("attn.l{layer}.t{pos}"),
        d as u64,
        TensorKind::Activation,
        layer,
    );
    let mut proj_reads = ctx_heads;
    proj_reads.push(w.out);
    b.op(
        format!("proj:l{layer}.t{pos}"),
        layer,
        OpKind::MatMul {
            m: 1,
            k: m.heads * m.d_head,
            n: d,
        },
        proj_reads,
        vec![attn_out],
    );

    let x1 = b.tensor(
        format!("x1.l{layer}.t{pos}"),
        d as u64,
        TensorKind::Activation,
        layer,
    );
    b.op(
        format!("add:res1.l{layer}.t{pos}"),
        layer,
        OpKind::Elementwise {
            elems: d as u64,
            inputs: 2,
        },
        vec![x, attn_out],
        vec![x1],
    );
    x1
}

/// Weight tensors shared across decode steps for one layer (fetched once,
/// reused every token — unlike prefill where each weight has one use).
pub struct DecodeLayerWeights {
    pub ln1: TensorId,
    pub qkv: TensorId,
    pub out: TensorId,
    pub ln2: TensorId,
    pub ffn: Vec<TensorId>,
}

impl DecodeLayerWeights {
    pub fn declare(b: &mut GraphBuilder, m: &ModelPreset, layer: u16) -> Self {
        let d = m.d_model as u64;
        let ln1 = b.tensor(format!("w.ln1.l{layer}"), 2 * d, TensorKind::Weight, layer);
        let qkv = b.tensor(
            format!("w.qkv.l{layer}"),
            d * m.qkv_out_dim() as u64,
            TensorKind::Weight,
            layer,
        );
        let out = b.tensor(
            format!("w.o.l{layer}"),
            (m.heads * m.d_head) as u64 * d,
            TensorKind::Weight,
            layer,
        );
        let ln2 = b.tensor(format!("w.ln2.l{layer}"), 2 * d, TensorKind::Weight, layer);
        let ffn = match m.ffn {
            super::models::FfnKind::Gelu => vec![
                b.tensor(
                    format!("w.ff1.l{layer}"),
                    d * m.d_ff as u64,
                    TensorKind::Weight,
                    layer,
                ),
                b.tensor(
                    format!("w.ff2.l{layer}"),
                    m.d_ff as u64 * d,
                    TensorKind::Weight,
                    layer,
                ),
            ],
            super::models::FfnKind::SwiGlu => vec![
                b.tensor(
                    format!("w.ffg.l{layer}"),
                    d * m.d_ff as u64,
                    TensorKind::Weight,
                    layer,
                ),
                b.tensor(
                    format!("w.ffu.l{layer}"),
                    d * m.d_ff as u64,
                    TensorKind::Weight,
                    layer,
                ),
                b.tensor(
                    format!("w.ff2.l{layer}"),
                    m.d_ff as u64 * d,
                    TensorKind::Weight,
                    layer,
                ),
            ],
        };
        Self {
            ln1,
            qkv,
            out,
            ln2,
            ffn,
        }
    }
}
