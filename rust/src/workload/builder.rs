//! Whole-model workload builders: prefill (the paper's Fig. 5-9 runs)
//! and decode (the Fig. 1 MHA-vs-GQA motivation).

use anyhow::{bail, Result};

use crate::serving::ServingParams;

use super::attention::{
    build_decode_attention, build_prefill_attention, DecodeLayerWeights,
};
use super::graph::{GraphBuilder, KvResidency, WorkloadGraph};
use super::models::{FfnKind, ModelPreset};
use super::op::OpKind;
use super::tensor::{TensorId, TensorKind};

/// Workload selector for `build_workload`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// Single causal forward pass over `seq` tokens (paper §IV: M=2048).
    Prefill { seq: u32 },
    /// Auto-regressive generation of `gen` tokens after a `prompt`-token
    /// prefix whose KV is already cached (DRAM-resident at start).
    Decode { prompt: u32, gen: u32 },
    /// Multi-tenant serving: many concurrent decode streams over a paged
    /// KV arena (see [`crate::serving`]). Has no single dataflow graph —
    /// it runs through `sim::serving` / `ExperimentSpec::run_serving`.
    Serving(ServingParams),
}

pub fn build_workload(m: &ModelPreset, w: Workload) -> Result<WorkloadGraph> {
    match w {
        Workload::Prefill { seq } => build_prefill(m, seq),
        Workload::Decode { prompt, gen } => build_decode(m, prompt, gen),
        Workload::Serving(_) => bail!(
            "serving workloads have no single dataflow graph; run them \
             via ExperimentSpec::run_serving (sim::serving)"
        ),
    }
}

/// FFN sub-block (prefill, seq tokens).
fn build_ffn(
    b: &mut GraphBuilder,
    m: &ModelPreset,
    layer: u16,
    seq: u32,
    x: TensorId,
) -> TensorId {
    let d = m.d_model as u64;
    let sd = seq as u64 * d;
    let sff = seq as u64 * m.d_ff as u64;

    let w_ln2 = b.tensor(format!("w.ln2.l{layer}"), 2 * d, TensorKind::Weight, layer);
    let x_n = b.tensor(format!("xn2.l{layer}"), sd, TensorKind::Activation, layer);
    b.op(
        format!("norm:ln2.l{layer}"),
        layer,
        OpKind::Norm { elems: sd },
        vec![x, w_ln2],
        vec![x_n],
    );

    let act = match m.ffn {
        FfnKind::Gelu => {
            let w1 = b.tensor(
                format!("w.ff1.l{layer}"),
                d * m.d_ff as u64,
                TensorKind::Weight,
                layer,
            );
            let a1 = b.tensor(format!("ff1.l{layer}"), sff, TensorKind::Activation, layer);
            b.op(
                format!("ffn:up.l{layer}"),
                layer,
                OpKind::MatMul {
                    m: seq,
                    k: m.d_model,
                    n: m.d_ff,
                },
                vec![x_n, w1],
                vec![a1],
            );
            // GELU applies in place (activation units rewrite the
            // buffer; no second FFN-width transient).
            b.op(
                format!("add:gelu.l{layer}"),
                layer,
                OpKind::Elementwise {
                    elems: sff,
                    inputs: 1,
                },
                vec![a1],
                vec![a1],
            );
            a1
        }
        FfnKind::SwiGlu => {
            let wg = b.tensor(
                format!("w.ffg.l{layer}"),
                d * m.d_ff as u64,
                TensorKind::Weight,
                layer,
            );
            let wu = b.tensor(
                format!("w.ffu.l{layer}"),
                d * m.d_ff as u64,
                TensorKind::Weight,
                layer,
            );
            let g = b.tensor(format!("ffg.l{layer}"), sff, TensorKind::Activation, layer);
            b.op(
                format!("ffn:gate.l{layer}"),
                layer,
                OpKind::MatMul {
                    m: seq,
                    k: m.d_model,
                    n: m.d_ff,
                },
                vec![x_n, wg],
                vec![g],
            );
            let u = b.tensor(format!("ffu.l{layer}"), sff, TensorKind::Activation, layer);
            b.op(
                format!("ffn:up.l{layer}"),
                layer,
                OpKind::MatMul {
                    m: seq,
                    k: m.d_model,
                    n: m.d_ff,
                },
                vec![x_n, wu],
                vec![u],
            );
            // SiLU-gate multiply writes in place over the gate buffer
            // (one FFN-width transient retires immediately).
            b.op(
                format!("add:swiglu.l{layer}"),
                layer,
                OpKind::Elementwise {
                    elems: sff,
                    inputs: 2,
                },
                vec![g, u],
                vec![g],
            );
            g
        }
    };

    let w2 = b.tensor(
        format!("w.ff2.l{layer}"),
        m.d_ff as u64 * d,
        TensorKind::Weight,
        layer,
    );
    let f_out = b.tensor(format!("ffo.l{layer}"), sd, TensorKind::Activation, layer);
    b.op(
        format!("ffn:down.l{layer}"),
        layer,
        OpKind::MatMul {
            m: seq,
            k: m.d_ff,
            n: m.d_model,
        },
        vec![act, w2],
        vec![f_out],
    );

    let x2 = b.tensor(format!("x2.l{layer}"), sd, TensorKind::Activation, layer);
    b.op(
        format!("add:res2.l{layer}"),
        layer,
        OpKind::Elementwise {
            elems: sd,
            inputs: 2,
        },
        vec![x, f_out],
        vec![x2],
    );
    x2
}

/// Full prefill workload: `layers` decoder blocks over `seq` tokens.
pub fn build_prefill(m: &ModelPreset, seq: u32) -> Result<WorkloadGraph> {
    let mut b = GraphBuilder::new(
        &format!("{}-prefill-{}", m.name, seq),
        KvResidency::PerLayer,
    );
    // Input embeddings start DRAM-resident (no producer).
    let mut x = b.tensor(
        "x.embed",
        seq as u64 * m.d_model as u64,
        TensorKind::Activation,
        0,
    );
    for layer in 0..m.layers {
        b.set_stage(layer as u32);
        let attn = build_prefill_attention(&mut b, m, layer, seq, x);
        x = build_ffn(&mut b, m, layer, seq, attn.out);
    }
    // Mark the final residual stream as model output (pinned until end).
    let out = b.tensor(
        "y.final",
        seq as u64 * m.d_model as u64,
        TensorKind::Output,
        m.layers - 1,
    );
    b.op(
        "add:output",
        m.layers - 1,
        OpKind::Elementwise {
            elems: seq as u64 * m.d_model as u64,
            inputs: 1,
        },
        vec![x],
        vec![out],
    );
    b.finish()
}

/// Decode workload: generate `gen` tokens after `prompt` cached tokens.
/// KV caches are input tensors (prompt KV computed earlier), persistent,
/// and updated in place each step — their byte size is the *final* size
/// (prompt + gen), conservatively representing the end-of-run footprint.
pub fn build_decode(m: &ModelPreset, prompt: u32, gen: u32) -> Result<WorkloadGraph> {
    let mut b = GraphBuilder::new(
        &format!("{}-decode-{}p{}g", m.name, prompt, gen),
        KvResidency::Persistent,
    );
    let final_ctx = (prompt + gen) as u64;

    // Per-layer persistent weights and KV caches (inputs).
    let mut weights = Vec::with_capacity(m.layers as usize);
    let mut kv = Vec::with_capacity(m.layers as usize);
    for layer in 0..m.layers {
        weights.push(DecodeLayerWeights::declare(&mut b, m, layer));
        let horizon = m.kv_horizon(final_ctx);
        let k_bytes = horizon * m.k_token_bytes();
        let v_bytes = horizon * m.v_token_bytes();
        let k = b.tensor(format!("k.l{layer}"), k_bytes, TensorKind::KvCache, layer);
        let v = b.tensor(format!("v.l{layer}"), v_bytes, TensorKind::KvCache, layer);
        kv.push((k, v));
    }

    let mut prev_token: Option<TensorId> = None;
    for t in 0..gen {
        let pos = prompt + t;
        let mut x = b.tensor(
            format!("x.t{pos}"),
            m.d_model as u64,
            TensorKind::Activation,
            0,
        );
        if let Some(prev) = prev_token {
            // Token feedback: embedding of step t depends on step t-1's
            // output (auto-regressive serialization).
            b.op(
                format!("add:embed.t{pos}"),
                0,
                OpKind::Elementwise {
                    elems: m.d_model as u64,
                    inputs: 1,
                },
                vec![prev],
                vec![x],
            );
        }
        for layer in 0..m.layers {
            b.set_stage(t * m.layers as u32 + layer as u32);
            let (k_c, v_c) = kv[layer as usize];
            let x1 = build_decode_attention(
                &mut b,
                m,
                layer,
                pos,
                x,
                &weights[layer as usize],
                k_c,
                v_c,
            );
            x = build_decode_ffn(&mut b, m, layer, pos, x1, &weights[layer as usize]);
        }
        prev_token = Some(x);
    }
    // Final token output pinned.
    let out = b.tensor(
        "y.final",
        m.d_model as u64,
        TensorKind::Output,
        m.layers - 1,
    );
    b.op(
        "add:output",
        m.layers - 1,
        OpKind::Elementwise {
            elems: m.d_model as u64,
            inputs: 1,
        },
        vec![prev_token.expect("gen >= 1")],
        vec![out],
    );
    b.finish()
}

fn build_decode_ffn(
    b: &mut GraphBuilder,
    m: &ModelPreset,
    layer: u16,
    pos: u32,
    x: TensorId,
    w: &DecodeLayerWeights,
) -> TensorId {
    let d = m.d_model as u64;
    let x_n = b.tensor(
        format!("xn2.l{layer}.t{pos}"),
        d,
        TensorKind::Activation,
        layer,
    );
    b.op(
        format!("norm:ln2.l{layer}.t{pos}"),
        layer,
        OpKind::Norm { elems: d },
        vec![x, w.ln2],
        vec![x_n],
    );
    let act = match m.ffn {
        FfnKind::Gelu => {
            let a1 = b.tensor(
                format!("ff1.l{layer}.t{pos}"),
                m.d_ff as u64,
                TensorKind::Activation,
                layer,
            );
            b.op(
                format!("ffn:up.l{layer}.t{pos}"),
                layer,
                OpKind::MatMul {
                    m: 1,
                    k: m.d_model,
                    n: m.d_ff,
                },
                vec![x_n, w.ffn[0]],
                vec![a1],
            );
            b.op(
                format!("add:gelu.l{layer}.t{pos}"),
                layer,
                OpKind::Elementwise {
                    elems: m.d_ff as u64,
                    inputs: 1,
                },
                vec![a1],
                vec![a1],
            );
            a1
        }
        FfnKind::SwiGlu => {
            let g = b.tensor(
                format!("ffg.l{layer}.t{pos}"),
                m.d_ff as u64,
                TensorKind::Activation,
                layer,
            );
            b.op(
                format!("ffn:gate.l{layer}.t{pos}"),
                layer,
                OpKind::MatMul {
                    m: 1,
                    k: m.d_model,
                    n: m.d_ff,
                },
                vec![x_n, w.ffn[0]],
                vec![g],
            );
            let u = b.tensor(
                format!("ffu.l{layer}.t{pos}"),
                m.d_ff as u64,
                TensorKind::Activation,
                layer,
            );
            b.op(
                format!("ffn:up.l{layer}.t{pos}"),
                layer,
                OpKind::MatMul {
                    m: 1,
                    k: m.d_model,
                    n: m.d_ff,
                },
                vec![x_n, w.ffn[1]],
                vec![u],
            );
            b.op(
                format!("add:swiglu.l{layer}.t{pos}"),
                layer,
                OpKind::Elementwise {
                    elems: m.d_ff as u64,
                    inputs: 2,
                },
                vec![g, u],
                vec![g],
            );
            g
        }
    };
    let f_out = b.tensor(
        format!("ffo.l{layer}.t{pos}"),
        d,
        TensorKind::Activation,
        layer,
    );
    b.op(
        format!("ffn:down.l{layer}.t{pos}"),
        layer,
        OpKind::MatMul {
            m: 1,
            k: m.d_ff,
            n: m.d_model,
        },
        vec![act, w.ffn.last().copied().expect("ffn weights")],
        vec![f_out],
    );
    let x2 = b.tensor(
        format!("x2.l{layer}.t{pos}"),
        d,
        TensorKind::Activation,
        layer,
    );
    b.op(
        format!("add:res2.l{layer}.t{pos}"),
        layer,
        OpKind::Elementwise {
            elems: d,
            inputs: 2,
        },
        vec![x, f_out],
        vec![x2],
    );
    x2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::{DS_R1D_Q15B, GPT2_XL, TINY_GQA, TINY_MHA};

    #[test]
    fn prefill_macs_match_preset_accounting() {
        for m in [&TINY_MHA, &TINY_GQA] {
            let g = build_prefill(m, 128).unwrap();
            assert_eq!(
                g.total_macs(),
                m.total_macs(128),
                "graph MACs must equal closed-form accounting for {}",
                m.name
            );
        }
    }

    #[test]
    fn prefill_weight_bytes_match_param_count() {
        let g = build_prefill(&TINY_GQA, 64).unwrap();
        // Norm weights: builder stores 2*D per norm for both norm kinds
        // (scale+bias slots); preset counts rmsnorm as 1*D scale. Allow
        // that delta only.
        let slack = 2 * TINY_GQA.layers as u64 * TINY_GQA.d_model as u64;
        let diff = g.weight_bytes() as i64 - TINY_GQA.param_count() as i64;
        assert!(
            (0..=slack as i64).contains(&diff),
            "weights {} vs params {}",
            g.weight_bytes(),
            TINY_GQA.param_count()
        );
    }

    #[test]
    fn prefill_full_models_validate() {
        // The real Table I workloads at the paper's M=2048.
        for m in [&GPT2_XL, &DS_R1D_Q15B] {
            let g = build_prefill(m, 2048).unwrap();
            let macs = g.total_macs() as f64 / 1e12;
            let want = m.total_macs(2048) as f64 / 1e12;
            assert!((macs - want).abs() < 1e-9, "{}: {macs} vs {want}", m.name);
        }
    }

    #[test]
    fn prefill_kv_bytes() {
        let g = build_prefill(&GPT2_XL, 2048).unwrap();
        assert_eq!(g.kv_bytes(), GPT2_XL.kv_cache_bytes(2048));
    }

    #[test]
    fn prefill_op_counts_scale_with_heads() {
        let g_mha = build_prefill(&TINY_MHA, 64).unwrap();
        let g2 = build_prefill(&TINY_GQA, 64).unwrap();
        // Same head count; SwiGLU adds one extra FFN matmul per layer.
        assert_eq!(
            g_mha.ops.len() + TINY_MHA.layers as usize,
            g2.ops.len()
        );
    }

    #[test]
    fn decode_graph_structure() {
        let g = build_decode(&TINY_GQA, 16, 4).unwrap();
        // Persistent KV: caches are inputs sized to the final context.
        let kv = g
            .tensors
            .iter()
            .filter(|t| t.kind == crate::workload::tensor::TensorKind::KvCache)
            .collect::<Vec<_>>();
        assert_eq!(kv.len(), 2 * TINY_GQA.layers as usize);
        for t in kv {
            assert_eq!(
                t.bytes,
                20 * (TINY_GQA.kv_heads * TINY_GQA.d_head) as u64
            );
            assert!(t.is_input(), "decode KV must start DRAM-resident");
        }
        assert_eq!(g.kv_residency, KvResidency::Persistent);
    }

    #[test]
    fn decode_steps_serialize_via_token_feedback() {
        let g = build_decode(&TINY_MHA, 8, 3).unwrap();
        // Each generated token's embed op reads the previous token's x2.
        let embeds: Vec<_> = g
            .ops
            .iter()
            .filter(|o| o.name.starts_with("add:embed"))
            .collect();
        assert_eq!(embeds.len(), 2); // gen=3 -> 2 feedback edges
    }

    #[test]
    fn decode_kv_tensors_follow_horizon_and_latent_dim() {
        use crate::workload::models::{FIG1_MLA, FIG1_SWA};
        // Sliding window: KV inputs sized to the window, not the final
        // context (decode occupancy plateaus).
        let g = build_decode(&FIG1_SWA, 512, 4).unwrap();
        assert_eq!(g.kv_bytes(), FIG1_SWA.kv_cache_bytes(516));
        assert!(g.kv_bytes() < FIG1_SWA.layers as u64 * 516 * FIG1_SWA.kv_token_bytes());
        // Latent KV: per-token bytes come from latent_dim, not heads.
        let g2 = build_decode(&FIG1_MLA, 16, 4).unwrap();
        assert_eq!(g2.kv_bytes(), FIG1_MLA.kv_cache_bytes(20));
    }

    #[test]
    fn decode_macs_grow_with_context() {
        let short = build_decode(&TINY_MHA, 4, 2).unwrap().total_macs();
        let long = build_decode(&TINY_MHA, 64, 2).unwrap().total_macs();
        assert!(long > short, "attention cost must grow with context");
    }
}
