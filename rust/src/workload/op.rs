//! Operation descriptors for the workload graph.

use super::tensor::{OpId, TensorId};

/// What an op computes. Dimensions determine systolic-array timing (for
/// matmuls) or streamed bytes (for memory-path ops); see `sim::systolic`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Dense matmul `[m, k] x [k, n]` executed on the systolic arrays.
    MatMul { m: u32, k: u32, n: u32 },
    /// Row-wise softmax over `[rows, cols]`; executed on the memory path
    /// (the accelerator template has no dedicated vector unit, so
    /// element-wise work streams SRAM<->SRAM through the ports).
    Softmax { rows: u32, cols: u32 },
    /// LayerNorm / RMSNorm over `elems` elements (memory path).
    Norm { elems: u64 },
    /// Generic element-wise op (residual add, GELU, SiLU-mul, KV append);
    /// memory path. `inputs` counts streamed operands.
    Elementwise { elems: u64, inputs: u8 },
}

impl OpKind {
    /// Multiply-accumulate count (the paper's MACs column counts matmul
    /// work only; element-wise ops contribute traffic, not MACs).
    pub fn macs(&self) -> u64 {
        match *self {
            OpKind::MatMul { m, k, n } => m as u64 * k as u64 * n as u64,
            _ => 0,
        }
    }

    /// True if this op occupies a systolic array (vs the memory path).
    pub fn uses_systolic_array(&self) -> bool {
        matches!(self, OpKind::MatMul { .. })
    }

    /// Bytes streamed through memory during execution (operands read +
    /// result written), at 1 byte/element. For matmuls this is the
    /// FIFO-fed streaming traffic assuming no inter-tile reuse beyond
    /// the FIFO capacity (see `sim::systolic` for the tile schedule).
    pub fn streamed_bytes(&self) -> u64 {
        match *self {
            OpKind::MatMul { m, k, n } => {
                // Per 128x128 output tile: k column-bytes + k row-bytes per
                // lane (x128 lanes each) streamed; output written once.
                let tiles_m = (m as u64).div_ceil(128);
                let tiles_n = (n as u64).div_ceil(128);
                let per_tile_stream = 2 * k as u64 * 128;
                tiles_m * tiles_n * per_tile_stream + m as u64 * n as u64
            }
            OpKind::Softmax { rows, cols } => {
                // Two passes (max+exp-sum, then normalize) read + one write.
                3 * rows as u64 * cols as u64
            }
            OpKind::Norm { elems } => 3 * elems,
            OpKind::Elementwise { elems, inputs } => (inputs as u64 + 1) * elems,
        }
    }
}

/// One operation in the workload graph.
#[derive(Debug, Clone)]
pub struct Op {
    pub id: OpId,
    pub name: String,
    pub layer: u16,
    /// Monotonic schedule stage (prefill: layer index; decode:
    /// token*layers + layer). The scheduler's in-order issue window is
    /// expressed in stages — TransInferSim's layer-synchronized
    /// execution-plan semantics.
    pub stage: u32,
    pub kind: OpKind,
    /// Tensors read (dataflow deps; duplicates not allowed).
    pub reads: Vec<TensorId>,
    /// Tensors written. Multi-write tensors (KV append) are modeled as
    /// read+write of the same id.
    pub writes: Vec<TensorId>,
}

impl Op {
    pub fn macs(&self) -> u64 {
        self.kind.macs()
    }
}

/// Coarse phase used in the Fig. 6 per-operation-type breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpClass {
    QkvProj,
    AttnScore,
    AttnSoftmax,
    AttnContext,
    OutProj,
    FfnMatMul,
    NormOp,
    ElementwiseOp,
    KvAppend,
}

impl OpClass {
    pub fn label(self) -> &'static str {
        match self {
            OpClass::QkvProj => "QKV proj",
            OpClass::AttnScore => "Attn score",
            OpClass::AttnSoftmax => "Softmax",
            OpClass::AttnContext => "Attn context",
            OpClass::OutProj => "Out proj",
            OpClass::FfnMatMul => "FFN matmul",
            OpClass::NormOp => "Norm",
            OpClass::ElementwiseOp => "Elementwise",
            OpClass::KvAppend => "KV append",
        }
    }

    pub fn all() -> &'static [OpClass] {
        &[
            OpClass::QkvProj,
            OpClass::AttnScore,
            OpClass::AttnSoftmax,
            OpClass::AttnContext,
            OpClass::OutProj,
            OpClass::FfnMatMul,
            OpClass::NormOp,
            OpClass::ElementwiseOp,
            OpClass::KvAppend,
        ]
    }

    /// Classify by op name prefix (builders name ops `class:detail`).
    pub fn of(op: &Op) -> OpClass {
        let prefix = op.name.split(':').next().unwrap_or("");
        match prefix {
            "qkv" => OpClass::QkvProj,
            "score" => OpClass::AttnScore,
            "softmax" => OpClass::AttnSoftmax,
            "ctx" => OpClass::AttnContext,
            "proj" => OpClass::OutProj,
            "ffn" => OpClass::FfnMatMul,
            "norm" => OpClass::NormOp,
            "kvapp" => OpClass::KvAppend,
            _ => OpClass::ElementwiseOp,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_macs() {
        let k = OpKind::MatMul { m: 4, k: 5, n: 6 };
        assert_eq!(k.macs(), 120);
        assert!(k.uses_systolic_array());
    }

    #[test]
    fn memory_ops_have_no_macs() {
        assert_eq!(OpKind::Softmax { rows: 10, cols: 10 }.macs(), 0);
        assert_eq!(OpKind::Norm { elems: 100 }.macs(), 0);
        assert_eq!(OpKind::Elementwise { elems: 10, inputs: 2 }.macs(), 0);
    }

    #[test]
    fn streamed_bytes_matmul_counts_tiles() {
        // 128x128x128: one tile, 2*128*128 streamed + 128*128 written.
        let k = OpKind::MatMul { m: 128, k: 128, n: 128 };
        assert_eq!(k.streamed_bytes(), 2 * 128 * 128 + 128 * 128);
        // Partial tiles round up.
        let k2 = OpKind::MatMul { m: 1, k: 128, n: 129 };
        assert_eq!(k2.streamed_bytes(), 2 * 2 * 128 * 128 + 129);
    }

    #[test]
    fn elementwise_streams_inputs_plus_output() {
        let k = OpKind::Elementwise { elems: 100, inputs: 2 };
        assert_eq!(k.streamed_bytes(), 300);
    }

    #[test]
    fn classify_by_name() {
        let op = Op {
            id: OpId(0),
            name: "score:l3.h7".into(),
            layer: 3,
            stage: 3,
            kind: OpKind::MatMul { m: 1, k: 1, n: 1 },
            reads: vec![],
            writes: vec![],
        };
        assert_eq!(OpClass::of(&op), OpClass::AttnScore);
    }
}
