//! Tensor descriptors for the workload graph.
//!
//! Stage I simulates *structure*, not values: a tensor is a name, a byte
//! size (8-bit operands throughout, per the paper's §IV-A), a kind (which
//! drives residency policy and reporting), and producer/consumer links
//! that define dataflow dependencies and liveness.

use std::fmt;

/// Index into `WorkloadGraph::tensors`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub u32);

impl fmt::Display for TensorId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t{}", self.0)
    }
}

/// Index into `WorkloadGraph::ops`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub u32);

impl fmt::Display for OpId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "op{}", self.0)
    }
}

/// Classification used for residency policy, eviction preference
/// reporting, and the Fig. 5 needed/obsolete decomposition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorKind {
    /// Model weights: initially DRAM-resident, fetched on demand,
    /// obsolete after their (single, in a forward pass) consumer.
    Weight,
    /// Intermediate activation: produced on-chip, obsolete after last
    /// consumer.
    Activation,
    /// Key/value cache entries. Residency depends on
    /// [`KvResidency`](crate::workload::graph::KvResidency): per-layer
    /// (prefill analysis) or persistent (decode-ready semantics).
    KvCache,
    /// Attention score matrix (pre-softmax). The dominant transient for
    /// MHA workloads (the paper's Fig. 5 left).
    Score,
    /// Post-softmax probabilities.
    Prob,
    /// Final model output; pinned needed until end of run.
    Output,
}

impl TensorKind {
    pub fn label(self) -> &'static str {
        match self {
            TensorKind::Weight => "weight",
            TensorKind::Activation => "act",
            TensorKind::KvCache => "kv",
            TensorKind::Score => "score",
            TensorKind::Prob => "prob",
            TensorKind::Output => "out",
        }
    }
}

/// One tensor in the workload graph.
#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub id: TensorId,
    pub name: String,
    /// Footprint in bytes (8-bit quantized operands => bytes == elements).
    pub bytes: u64,
    pub kind: TensorKind,
    /// Transformer layer index (u16::MAX for graph-global tensors).
    pub layer: u16,
    /// Producing op; `None` for graph inputs (weights, embeddings) that
    /// start DRAM-resident.
    pub producer: Option<OpId>,
    /// Ops that read this tensor (filled by the graph builder).
    pub consumers: Vec<OpId>,
    /// For multi-level hierarchies: preferred memory id (None = shared).
    pub affinity: Option<u8>,
}

impl TensorInfo {
    pub fn is_input(&self) -> bool {
        self.producer.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_forms() {
        assert_eq!(TensorId(3).to_string(), "t3");
        assert_eq!(OpId(7).to_string(), "op7");
    }

    #[test]
    fn kind_labels_unique() {
        use TensorKind::*;
        let labels: Vec<_> = [Weight, Activation, KvCache, Score, Prob, Output]
            .iter()
            .map(|k| k.label())
            .collect();
        let mut dedup = labels.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(labels.len(), dedup.len());
    }
}
