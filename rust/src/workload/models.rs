//! Model presets (the paper's Table I) and structural accounting.
//!
//! These mirror `python/compile/model.py::ModelConfig` exactly — the
//! pytest suite checks the Python side against Table I and
//! `rust/tests/` checks this side against the same numbers, so the
//! performance model (here) and the functional model (JAX) can never
//! silently diverge. The attention-spectrum extensions (`latent_dim`,
//! `window`) are performance-model-only occupancy shapes; both default
//! to 0 (= off), under which every formula reduces to the original.

/// FFN flavor (paper Table I "FFN Type").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FfnKind {
    /// Two matmuls with GELU (GPT-2 family).
    Gelu,
    /// Gate + up + down matmuls with SiLU gating (Qwen/Llama family).
    SwiGlu,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormKind {
    LayerNorm,
    RmsNorm,
}

/// Attention family (paper Fig. 2, extended with the latent-KV point of
/// the spectrum).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnKind {
    Mha,
    Gqa,
    Mqa,
    /// Multi-head latent attention: the KV cache stores a compressed
    /// latent per token (`ModelPreset::latent_dim`), à la DeepSeek-V2.
    Mla,
}

/// Structural description of a decoder-only transformer (Table I row).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelPreset {
    pub name: &'static str,
    pub layers: u16,
    pub d_model: u32,
    pub heads: u32,
    pub kv_heads: u32,
    pub d_head: u32,
    pub d_ff: u32,
    pub ffn: FfnKind,
    pub norm: NormKind,
    /// Latent-KV (MLA) compression: when > 0, the per-token per-layer
    /// KV-cache footprint is `latent_dim` bytes (one 8-bit latent
    /// vector) instead of `2 * kv_heads * d_head`. 0 = off.
    pub latent_dim: u32,
    /// Sliding-window attention: when > 0, the KV horizon is capped at
    /// `window` tokens, so decode occupancy plateaus instead of growing
    /// with context. 0 = off (full causal horizon).
    pub window: u32,
}

impl ModelPreset {
    /// Classify the attention family. Latent-KV wins outright; a single
    /// shared KV head is MQA *even when `heads == 1`* (all query heads
    /// share one KV head trivially), so the MQA arm must fire before the
    /// MHA arm.
    pub fn attn_kind(&self) -> AttnKind {
        if self.latent_dim > 0 {
            AttnKind::Mla
        } else if self.kv_heads == 1 {
            AttnKind::Mqa
        } else if self.kv_heads == self.heads {
            AttnKind::Mha
        } else {
            AttnKind::Gqa
        }
    }

    /// Output width of the fused QKV projection.
    pub fn qkv_out_dim(&self) -> u32 {
        (self.heads + 2 * self.kv_heads) * self.d_head
    }

    /// Non-embedding parameter count (Table I column P).
    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let qkv = d * self.qkv_out_dim() as u64;
        let out = (self.heads * self.d_head) as u64 * d;
        let ffn = match self.ffn {
            FfnKind::Gelu => 2 * d * self.d_ff as u64,
            FfnKind::SwiGlu => 3 * d * self.d_ff as u64,
        };
        let norms = match self.norm {
            NormKind::LayerNorm => 4 * d,
            NormKind::RmsNorm => 2 * d,
        };
        self.layers as u64 * (qkv + out + ffn + norms)
    }

    /// Total matmul MACs for a causal pass over `seq` tokens
    /// (Table I column MACs at seq = 2048). Sliding-window attention
    /// caps the score/context horizon at `window`.
    pub fn total_macs(&self, seq: u64) -> u64 {
        let d = self.d_model as u64;
        let qkv = d * self.qkv_out_dim() as u64;
        let out = (self.heads * self.d_head) as u64 * d;
        let ffn = match self.ffn {
            FfnKind::Gelu => 2 * d * self.d_ff as u64,
            FfnKind::SwiGlu => 3 * d * self.d_ff as u64,
        };
        let proj = seq * (qkv + out + ffn);
        let attn =
            2 * self.heads as u64 * seq * self.kv_horizon(seq) * self.d_head as u64;
        self.layers as u64 * (proj + attn)
    }

    /// Combined K+V cache bytes per token per layer (8-bit operands).
    /// MLA stores one `latent_dim`-byte compressed latent instead of the
    /// full `2 * kv_heads * d_head` K/V pair.
    pub fn kv_token_bytes(&self) -> u64 {
        if self.latent_dim > 0 {
            self.latent_dim as u64
        } else {
            2 * (self.kv_heads * self.d_head) as u64
        }
    }

    /// K-side share of [`ModelPreset::kv_token_bytes`] (the ceiling
    /// half, so `k + v` is exact even for odd latent widths).
    pub fn k_token_bytes(&self) -> u64 {
        self.kv_token_bytes().div_ceil(2)
    }

    /// V-side share of [`ModelPreset::kv_token_bytes`] (the floor half).
    pub fn v_token_bytes(&self) -> u64 {
        self.kv_token_bytes() / 2
    }

    /// Number of cached tokens visible at sequence position `seq`:
    /// `min(seq, window)` under sliding-window attention, `seq` with the
    /// full causal horizon.
    pub fn kv_horizon(&self, seq: u64) -> u64 {
        if self.window > 0 {
            seq.min(self.window as u64)
        } else {
            seq
        }
    }

    /// KV-cache bytes at `seq` tokens (8-bit operands). Reduces to the
    /// original `2 * layers * seq * kv_heads * d_head` when both
    /// attention extensions are off.
    pub fn kv_cache_bytes(&self, seq: u64) -> u64 {
        self.layers as u64 * self.kv_horizon(seq) * self.kv_token_bytes()
    }

    /// True when either attention-spectrum extension (latent-KV or
    /// sliding window) is enabled — the spec-hash extension gate.
    pub fn has_attn_extensions(&self) -> bool {
        self.latent_dim != 0 || self.window != 0
    }

    /// Per-layer weight bytes (8-bit).
    pub fn layer_weight_bytes(&self) -> u64 {
        self.param_count() / self.layers as u64
    }
}

/// GPT-2 XL (MHA): L=48, D=1600, Dff=6400, H=25 -> P=1.48 B, 3.66 T MACs.
pub const GPT2_XL: ModelPreset = ModelPreset {
    name: "gpt2-xl",
    layers: 48,
    d_model: 1600,
    heads: 25,
    kv_heads: 25,
    d_head: 64,
    d_ff: 6400,
    ffn: FfnKind::Gelu,
    norm: NormKind::LayerNorm,
    latent_dim: 0,
    window: 0,
};

/// DeepSeek-R1-Distill-Qwen-1.5B (GQA): L=28, D=1536, Dff=8960, H=12,
/// Hkv=2 -> P=1.31 B, 3.04 T MACs.
pub const DS_R1D_Q15B: ModelPreset = ModelPreset {
    name: "ds-r1d-qwen-1.5b",
    layers: 28,
    d_model: 1536,
    heads: 12,
    kv_heads: 2,
    d_head: 128,
    d_ff: 8960,
    ffn: FfnKind::SwiGlu,
    norm: NormKind::RmsNorm,
    latent_dim: 0,
    window: 0,
};

/// Tiny MHA config — matches `python/compile/model.py::TINY_MHA`; the
/// functional artifact `decode_tiny_mha.hlo.txt` implements this model.
pub const TINY_MHA: ModelPreset = ModelPreset {
    name: "tiny-mha",
    layers: 2,
    d_model: 128,
    heads: 4,
    kv_heads: 4,
    d_head: 32,
    d_ff: 256,
    ffn: FfnKind::Gelu,
    norm: NormKind::LayerNorm,
    latent_dim: 0,
    window: 0,
};

/// Tiny GQA config — matches `python/compile/model.py::TINY_GQA`.
pub const TINY_GQA: ModelPreset = ModelPreset {
    name: "tiny-gqa",
    layers: 2,
    d_model: 128,
    heads: 4,
    kv_heads: 2,
    d_head: 32,
    d_ff: 256,
    ffn: FfnKind::SwiGlu,
    norm: NormKind::RmsNorm,
    latent_dim: 0,
    window: 0,
};

/// Fig. 1 matched pair: GPT-2-small-scale models with identical
/// parameter count and computational complexity, differing only in the
/// attention mechanism (MHA vs GQA). Small enough that weights stay
/// SRAM-resident (`SchedConfig::weight_resident`), so decode traffic is
/// dominated by the KV cache — the regime the paper's Fig. 1 compares.
pub const FIG1_MHA: ModelPreset = ModelPreset {
    name: "fig1-mha-124m",
    layers: 12,
    d_model: 768,
    heads: 12,
    kv_heads: 12,
    d_head: 64,
    d_ff: 3072,
    ffn: FfnKind::Gelu,
    norm: NormKind::LayerNorm,
    latent_dim: 0,
    window: 0,
};

/// GQA twin: Hkv = 2; Dff enlarged by 640 so the parameter count matches
/// FIG1_MHA exactly (the saved 2*(H-Hkv)*Dh*D of KV projection equals
/// the added 2*D*640 of FFN width).
pub const FIG1_GQA: ModelPreset = ModelPreset {
    name: "fig1-gqa-124m",
    layers: 12,
    d_model: 768,
    heads: 12,
    kv_heads: 2,
    d_head: 64,
    d_ff: 3712,
    ffn: FfnKind::Gelu,
    norm: NormKind::LayerNorm,
    latent_dim: 0,
    window: 0,
};

/// MQA twin: Hkv = 1; Dff enlarged by 704 so the parameter count matches
/// FIG1_MHA exactly (same construction as [`FIG1_GQA`]: the saved
/// 2*(H-1)*Dh*D of KV projection equals the added 2*D*704 of FFN width).
pub const FIG1_MQA: ModelPreset = ModelPreset {
    name: "fig1-mqa-124m",
    layers: 12,
    d_model: 768,
    heads: 12,
    kv_heads: 1,
    d_head: 64,
    d_ff: 3776,
    ffn: FfnKind::Gelu,
    norm: NormKind::LayerNorm,
    latent_dim: 0,
    window: 0,
};

/// MLA twin: FIG1_MHA's exact projection shape, but the KV cache holds a
/// 64-byte compressed latent per token per layer (DeepSeek-V2-style
/// latent-KV; the up/down latent projections are modeled as reusing the
/// KV-projection budget, so parameters stay matched). 24x smaller KV
/// footprint than FIG1_MHA at any horizon.
pub const FIG1_MLA: ModelPreset = ModelPreset {
    name: "fig1-mla-124m",
    layers: 12,
    d_model: 768,
    heads: 12,
    kv_heads: 12,
    d_head: 64,
    d_ff: 3072,
    ffn: FfnKind::Gelu,
    norm: NormKind::LayerNorm,
    latent_dim: 64,
    window: 0,
};

/// Sliding-window twin: FIG1_MHA with a 256-token KV horizon — decode
/// occupancy grows like MHA up to 256 cached tokens, then plateaus
/// (Mistral-style SWA). Parameters are untouched.
pub const FIG1_SWA: ModelPreset = ModelPreset {
    name: "fig1-swa-124m",
    layers: 12,
    d_model: 768,
    heads: 12,
    kv_heads: 12,
    d_head: 64,
    d_ff: 3072,
    ffn: FfnKind::Gelu,
    norm: NormKind::LayerNorm,
    latent_dim: 0,
    window: 256,
};

/// Look up a preset by name (CLI / config files).
pub fn preset(name: &str) -> Option<ModelPreset> {
    match name {
        "gpt2-xl" => Some(GPT2_XL),
        "ds-r1d-qwen-1.5b" | "ds-r1d" | "deepseek" => Some(DS_R1D_Q15B),
        "tiny-mha" => Some(TINY_MHA),
        "tiny-gqa" => Some(TINY_GQA),
        "fig1-mha" | "fig1-mha-124m" => Some(FIG1_MHA),
        "fig1-gqa" | "fig1-gqa-124m" => Some(FIG1_GQA),
        "fig1-mqa" | "fig1-mqa-124m" => Some(FIG1_MQA),
        "fig1-mla" | "fig1-mla-124m" => Some(FIG1_MLA),
        "fig1-swa" | "fig1-swa-124m" => Some(FIG1_SWA),
        _ => None,
    }
}

pub fn all_presets() -> Vec<ModelPreset> {
    vec![
        GPT2_XL, DS_R1D_Q15B, TINY_MHA, TINY_GQA, FIG1_MHA, FIG1_GQA, FIG1_MQA,
        FIG1_MLA, FIG1_SWA,
    ]
}

/// The parameter-matched attention-variant spectrum (`repro spectrum`),
/// in decreasing-KV-footprint order: MHA → GQA → MQA → MLA, plus the
/// sliding-window point whose footprint plateaus rather than shrinks.
pub fn spectrum_presets() -> Vec<ModelPreset> {
    vec![FIG1_MHA, FIG1_GQA, FIG1_MQA, FIG1_MLA, FIG1_SWA]
}

/// The paper's MHA↔GQA co-residency pairing: the preset that shares a
/// serving arena with `name` under multi-model tenancy
/// (`ServingParams::tenants == 2`). Each matched pair contrasts the two
/// attention families at comparable scale, so co-residency turns the
/// paper's MHA-vs-GQA comparison into one experiment.
pub fn paper_counterpart(name: &str) -> Option<ModelPreset> {
    match name {
        "gpt2-xl" => Some(DS_R1D_Q15B),
        "ds-r1d-qwen-1.5b" => Some(GPT2_XL),
        "tiny-mha" => Some(TINY_GQA),
        "tiny-gqa" => Some(TINY_MHA),
        "fig1-mha-124m" => Some(FIG1_GQA),
        "fig1-gqa-124m" => Some(FIG1_MHA),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_gpt2_xl() {
        let p = GPT2_XL.param_count() as f64 / 1e9;
        let macs = GPT2_XL.total_macs(2048) as f64 / 1e12;
        assert!((p - 1.48).abs() < 0.01, "P={p}");
        assert!((macs - 3.66).abs() < 0.01, "MACs={macs}");
        assert_eq!(GPT2_XL.attn_kind(), AttnKind::Mha);
    }

    #[test]
    fn table1_ds_r1d() {
        let p = DS_R1D_Q15B.param_count() as f64 / 1e9;
        let macs = DS_R1D_Q15B.total_macs(2048) as f64 / 1e12;
        assert!((p - 1.31).abs() < 0.01, "P={p}");
        assert!((macs - 3.04).abs() < 0.01, "MACs={macs}");
        assert_eq!(DS_R1D_Q15B.attn_kind(), AttnKind::Gqa);
    }

    #[test]
    fn kv_cache_mha_vs_gqa() {
        // GPT-2 XL: 2*48*2048*1600 B = 300 MiB; DS: 2*28*2048*256 = 28 MiB.
        assert_eq!(GPT2_XL.kv_cache_bytes(2048), 2 * 48 * 2048 * 1600);
        assert_eq!(DS_R1D_Q15B.kv_cache_bytes(2048), 2 * 28 * 2048 * 256);
        let ratio = GPT2_XL.kv_cache_bytes(2048) as f64
            / DS_R1D_Q15B.kv_cache_bytes(2048) as f64;
        assert!(ratio > 10.0, "GQA must slash KV footprint, got {ratio}");
    }

    #[test]
    fn tiny_presets_match_python() {
        // Shapes mirrored in python/compile/model.py; keep in sync.
        assert_eq!(TINY_MHA.qkv_out_dim(), (4 + 8) * 32);
        assert_eq!(TINY_GQA.qkv_out_dim(), (4 + 4) * 32);
        assert_eq!(TINY_GQA.attn_kind(), AttnKind::Gqa);
        assert_eq!(TINY_MHA.attn_kind(), AttnKind::Mha);
    }

    #[test]
    fn fig1_pair_is_parameter_matched() {
        // "similar parameter count and computational complexity" —
        // exact match by construction.
        assert_eq!(FIG1_MHA.param_count(), FIG1_GQA.param_count());
        let m = FIG1_MHA.total_macs(2048) as f64;
        let g = FIG1_GQA.total_macs(2048) as f64;
        assert!((m / g - 1.0).abs() < 0.01, "MACs {m} vs {g}");
        // And the KV footprint differs by H/Hkv = 6x.
        assert_eq!(
            FIG1_MHA.kv_cache_bytes(2048),
            6 * FIG1_GQA.kv_cache_bytes(2048)
        );
    }

    #[test]
    fn spectrum_is_parameter_matched_and_kv_monotone() {
        let base = FIG1_MHA.param_count();
        for m in spectrum_presets() {
            assert_eq!(m.param_count(), base, "{}", m.name);
        }
        // KV footprint strictly decreases MHA -> GQA -> MQA -> MLA.
        let kv: Vec<u64> = [FIG1_MHA, FIG1_GQA, FIG1_MQA, FIG1_MLA]
            .iter()
            .map(|m| m.kv_cache_bytes(2048))
            .collect();
        assert!(kv.windows(2).all(|w| w[0] > w[1]), "{kv:?}");
    }

    #[test]
    fn windowed_kv_plateaus_at_the_window() {
        assert_eq!(FIG1_SWA.kv_horizon(64), 64);
        assert_eq!(FIG1_SWA.kv_horizon(256), 256);
        assert_eq!(FIG1_SWA.kv_horizon(4096), 256);
        assert_eq!(
            FIG1_SWA.kv_cache_bytes(4096),
            FIG1_SWA.kv_cache_bytes(256)
        );
        // Below the window, SWA is byte-identical to its MHA base.
        assert_eq!(
            FIG1_SWA.kv_cache_bytes(128),
            FIG1_MHA.kv_cache_bytes(128)
        );
    }

    #[test]
    fn latent_kv_overrides_the_cache_footprint() {
        assert_eq!(FIG1_MLA.kv_token_bytes(), 64);
        assert_eq!(FIG1_MLA.kv_cache_bytes(2048), 12 * 2048 * 64);
        assert_eq!(
            FIG1_MLA.k_token_bytes() + FIG1_MLA.v_token_bytes(),
            FIG1_MLA.kv_token_bytes()
        );
        // With the knob off, the split halves reproduce the original
        // 2 * kv_heads * d_head exactly.
        assert_eq!(
            FIG1_MHA.k_token_bytes() + FIG1_MHA.v_token_bytes(),
            2 * (12 * 64) as u64
        );
    }

    #[test]
    fn preset_lookup() {
        assert_eq!(preset("gpt2-xl").unwrap(), GPT2_XL);
        assert_eq!(preset("deepseek").unwrap(), DS_R1D_Q15B);
        assert_eq!(preset("fig1-mqa").unwrap(), FIG1_MQA);
        assert_eq!(preset("fig1-mla-124m").unwrap(), FIG1_MLA);
        assert_eq!(preset("fig1-swa").unwrap(), FIG1_SWA);
        assert!(preset("nope").is_none());
        assert_eq!(all_presets().len(), 9);
    }

    #[test]
    fn paper_counterpart_is_a_symmetric_mha_gqa_pairing() {
        for m in [GPT2_XL, DS_R1D_Q15B, TINY_MHA, TINY_GQA, FIG1_MHA, FIG1_GQA] {
            let c = paper_counterpart(m.name).unwrap();
            assert_ne!(c.name, m.name);
            assert_eq!(paper_counterpart(c.name).unwrap(), m, "not symmetric");
            assert_ne!(c.attn_kind() == AttnKind::Mha, m.attn_kind() == AttnKind::Mha);
        }
        assert!(paper_counterpart("nope").is_none());
    }

    #[test]
    fn mqa_classification() {
        let mut m = TINY_MHA.clone();
        m.kv_heads = 1;
        assert_eq!(m.attn_kind(), AttnKind::Mqa);
        assert_eq!(FIG1_MQA.attn_kind(), AttnKind::Mqa);
    }

    /// Regression: a single-head model (`heads == kv_heads == 1`) used to
    /// hit the MHA arm first; the one shared KV head makes it MQA.
    #[test]
    fn single_head_model_classifies_as_mqa() {
        let mut m = TINY_MHA.clone();
        m.heads = 1;
        m.kv_heads = 1;
        assert_eq!(m.attn_kind(), AttnKind::Mqa);
    }

    #[test]
    fn mla_classification_wins_over_head_count() {
        assert_eq!(FIG1_MLA.attn_kind(), AttnKind::Mla);
        let mut m = FIG1_MLA.clone();
        m.kv_heads = 1;
        assert_eq!(m.attn_kind(), AttnKind::Mla, "latent beats MQA");
    }

    #[test]
    fn windowed_macs_plateau_per_token() {
        // Per-position attention work stops growing past the window.
        let grow = FIG1_MHA.total_macs(1024) - FIG1_MHA.total_macs(1023);
        let capped = FIG1_SWA.total_macs(1024) - FIG1_SWA.total_macs(1023);
        assert!(capped < grow, "SWA marginal MACs must be capped");
        // And with the knob off the formula is bit-for-bit the original.
        assert_eq!(FIG1_SWA.total_macs(128), FIG1_MHA.total_macs(128));
    }
}
