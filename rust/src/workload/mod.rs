//! Workload layer: structural transformer descriptions (ops, tensor
//! dimensions, dependencies) consumed by the Stage-I simulator.
//!
//! The paper provides workloads to TransInferSim as structural graphs;
//! this module is that substrate: model presets (Table I), attention
//! block builders (MHA/GQA/MQA, Fig. 2), and whole-model prefill/decode
//! graph construction.

pub mod attention;
pub mod builder;
pub mod graph;
pub mod models;
pub mod op;
pub mod tensor;

pub use builder::{build_decode, build_prefill, build_workload, Workload};
pub use graph::{GraphBuilder, KvResidency, WorkloadGraph};
pub use models::{
    all_presets, paper_counterpart, preset, spectrum_presets, AttnKind, FfnKind,
    ModelPreset, NormKind, DS_R1D_Q15B, FIG1_GQA, FIG1_MHA, FIG1_MLA, FIG1_MQA,
    FIG1_SWA, GPT2_XL, TINY_GQA, TINY_MHA,
};
pub use op::{Op, OpClass, OpKind};
pub use tensor::{OpId, TensorId, TensorInfo, TensorKind};
