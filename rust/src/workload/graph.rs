//! Workload graph: the structural description TransInferSim-style
//! simulation consumes (operation types, tensor dimensions, dependencies).

use std::collections::HashMap;

use anyhow::{bail, ensure, Result};

use super::op::{Op, OpKind};
use super::tensor::{OpId, TensorId, TensorInfo, TensorKind};

/// How KV-cache tensors' liveness is treated (DESIGN.md §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvResidency {
    /// KV of layer i is obsolete once layer i's attention consumed it
    /// (single forward pass analysis — the paper's Fig. 5 setting).
    PerLayer,
    /// KV stays needed until the end of the run (decode-ready semantics).
    Persistent,
}

/// A complete workload: tensors + ops in (construction = program) order.
/// Ops are issued by the scheduler in graph order subject to dataflow
/// readiness, mirroring TransInferSim's execution-plan construction.
#[derive(Debug, Clone)]
pub struct WorkloadGraph {
    pub name: String,
    pub tensors: Vec<TensorInfo>,
    pub ops: Vec<Op>,
    pub kv_residency: KvResidency,
}

impl WorkloadGraph {
    pub fn tensor(&self, id: TensorId) -> &TensorInfo {
        &self.tensors[id.0 as usize]
    }

    pub fn op(&self, id: OpId) -> &Op {
        &self.ops[id.0 as usize]
    }

    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.macs()).sum()
    }

    /// Non-embedding parameter bytes (Table I's P at 1 byte/param).
    pub fn weight_bytes(&self) -> u64 {
        self.tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Weight)
            .map(|t| t.bytes)
            .sum()
    }

    pub fn kv_bytes(&self) -> u64 {
        self.tensors
            .iter()
            .filter(|t| t.kind == TensorKind::KvCache)
            .map(|t| t.bytes)
            .sum()
    }

    /// Validate structural invariants. Called by builders' tests and by
    /// the simulator before execution (corrupt graphs fail loudly).
    pub fn validate(&self) -> Result<()> {
        for (i, t) in self.tensors.iter().enumerate() {
            ensure!(t.id.0 as usize == i, "tensor id/index mismatch at {i}");
            ensure!(t.bytes > 0, "zero-size tensor {}", t.name);
        }
        for (i, op) in self.ops.iter().enumerate() {
            ensure!(op.id.0 as usize == i, "op id/index mismatch at {i}");
            ensure!(!op.writes.is_empty(), "op {} writes nothing", op.name);
            for &tid in op.reads.iter().chain(&op.writes) {
                ensure!(
                    (tid.0 as usize) < self.tensors.len(),
                    "op {} references unknown tensor {tid}",
                    op.name
                );
            }
        }
        // Producer precedes consumers (graph order == valid topo order);
        // in-place updates (read+write same id) are allowed and keep the
        // original producer.
        for t in &self.tensors {
            if let Some(p) = t.producer {
                for &c in &t.consumers {
                    let in_place_update = self.ops[c.0 as usize]
                        .writes
                        .contains(&t.id);
                    if c.0 < p.0 && !in_place_update {
                        bail!(
                            "tensor {} consumed by {c} before produced by {p}",
                            t.name
                        );
                    }
                }
            }
        }
        // Consumer back-links match op reads.
        let mut counts: HashMap<TensorId, usize> = HashMap::new();
        for op in &self.ops {
            for &r in &op.reads {
                *counts.entry(r).or_default() += 1;
            }
        }
        for t in &self.tensors {
            let expect = counts.get(&t.id).copied().unwrap_or(0);
            ensure!(
                t.consumers.len() == expect,
                "tensor {} consumer backlinks {} != reads {}",
                t.name,
                t.consumers.len(),
                expect
            );
        }
        Ok(())
    }

    /// Summary line used by the CLI.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} ops, {} tensors, {:.2} T MACs, {:.1} MiB weights, \
             {:.1} MiB KV",
            self.name,
            self.ops.len(),
            self.tensors.len(),
            self.total_macs() as f64 / 1e12,
            self.weight_bytes() as f64 / (1 << 20) as f64,
            self.kv_bytes() as f64 / (1 << 20) as f64,
        )
    }
}

/// Incremental builder keeping producer/consumer links consistent.
#[derive(Debug)]
pub struct GraphBuilder {
    name: String,
    tensors: Vec<TensorInfo>,
    ops: Vec<Op>,
    kv_residency: KvResidency,
    stage: u32,
}

impl GraphBuilder {
    pub fn new(name: &str, kv_residency: KvResidency) -> Self {
        Self {
            name: name.to_string(),
            tensors: Vec::new(),
            ops: Vec::new(),
            kv_residency,
            stage: 0,
        }
    }

    /// Set the schedule stage for subsequently added ops (monotonic;
    /// builders bump it at layer / token boundaries).
    pub fn set_stage(&mut self, stage: u32) {
        debug_assert!(stage >= self.stage, "stages must be monotonic");
        self.stage = stage;
    }

    /// Declare a tensor; producer is attached when an op writes it.
    pub fn tensor(
        &mut self,
        name: impl Into<String>,
        bytes: u64,
        kind: TensorKind,
        layer: u16,
    ) -> TensorId {
        let id = TensorId(self.tensors.len() as u32);
        self.tensors.push(TensorInfo {
            id,
            name: name.into(),
            bytes,
            kind,
            layer,
            producer: None,
            consumers: Vec::new(),
            affinity: None,
        });
        id
    }

    /// Set memory affinity (multi-level hierarchies, Fig. 10).
    pub fn set_affinity(&mut self, t: TensorId, mem: u8) {
        self.tensors[t.0 as usize].affinity = Some(mem);
    }

    /// Append an op; wires producer/consumer links.
    pub fn op(
        &mut self,
        name: impl Into<String>,
        layer: u16,
        kind: OpKind,
        reads: Vec<TensorId>,
        writes: Vec<TensorId>,
    ) -> OpId {
        let id = OpId(self.ops.len() as u32);
        // Unknown ids are tolerated here and rejected by finish()'s
        // validate() with a proper error (builders never panic).
        for &r in &reads {
            if let Some(t) = self.tensors.get_mut(r.0 as usize) {
                t.consumers.push(id);
            }
        }
        for &w in &writes {
            if let Some(t) = self.tensors.get_mut(w.0 as usize) {
                // First writer is the producer; later writers are in-place
                // updates (KV append) and must also read the tensor.
                if t.producer.is_none() && !reads.contains(&w) {
                    t.producer = Some(id);
                }
            }
        }
        self.ops.push(Op {
            id,
            name: name.into(),
            layer,
            stage: self.stage,
            kind,
            reads,
            writes,
        });
        id
    }

    pub fn finish(self) -> Result<WorkloadGraph> {
        let g = WorkloadGraph {
            name: self.name,
            tensors: self.tensors,
            ops: self.ops,
            kv_residency: self.kv_residency,
        };
        g.validate()?;
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn tiny_graph() -> WorkloadGraph {
        let mut b = GraphBuilder::new("tiny", KvResidency::PerLayer);
        let x = b.tensor("x", 64, TensorKind::Activation, 0);
        let w = b.tensor("w", 128, TensorKind::Weight, 0);
        let y = b.tensor("y", 64, TensorKind::Activation, 0);
        b.op(
            "ffn:mm",
            0,
            OpKind::MatMul { m: 8, k: 8, n: 8 },
            vec![x, w],
            vec![y],
        );
        b.finish().unwrap()
    }

    #[test]
    fn builder_wires_links() {
        let g = tiny_graph();
        assert_eq!(g.tensor(TensorId(2)).producer, Some(OpId(0)));
        assert_eq!(g.tensor(TensorId(0)).consumers, vec![OpId(0)]);
        assert!(g.tensor(TensorId(0)).is_input());
        assert_eq!(g.total_macs(), 512);
        assert_eq!(g.weight_bytes(), 128);
    }

    #[test]
    fn validate_rejects_unknown_tensor() {
        let mut b = GraphBuilder::new("bad", KvResidency::PerLayer);
        let x = b.tensor("x", 8, TensorKind::Activation, 0);
        b.op(
            "e",
            0,
            OpKind::Elementwise { elems: 8, inputs: 1 },
            vec![x],
            vec![TensorId(99)],
        );
        assert!(b.finish().is_err());
    }

    #[test]
    fn validate_rejects_writeless_op() {
        let mut b = GraphBuilder::new("bad", KvResidency::PerLayer);
        let x = b.tensor("x", 8, TensorKind::Activation, 0);
        b.op(
            "e",
            0,
            OpKind::Elementwise { elems: 8, inputs: 1 },
            vec![x],
            vec![],
        );
        assert!(b.finish().is_err());
    }

    #[test]
    fn in_place_update_keeps_first_producer() {
        let mut b = GraphBuilder::new("kv", KvResidency::Persistent);
        let q = b.tensor("q", 8, TensorKind::Activation, 0);
        let kv = b.tensor("kv", 64, TensorKind::KvCache, 0);
        let o1 = b.op(
            "kvapp:0",
            0,
            OpKind::Elementwise { elems: 8, inputs: 1 },
            vec![q],
            vec![kv],
        );
        let q2 = b.tensor("q2", 8, TensorKind::Activation, 0);
        b.op(
            "kvapp:1",
            0,
            OpKind::Elementwise { elems: 8, inputs: 2 },
            vec![q2, kv],
            vec![kv],
        );
        let g = b.finish().unwrap();
        assert_eq!(g.tensor(kv).producer, Some(o1));
        assert_eq!(g.kv_bytes(), 64);
    }

    #[test]
    fn random_chain_graphs_validate() {
        check("random-chains-validate", 50, |rng| {
            let mut b = GraphBuilder::new("chain", KvResidency::PerLayer);
            let n = rng.range(1, 20) as usize;
            let mut prev = b.tensor("in", rng.range(1, 4096), TensorKind::Activation, 0);
            for i in 0..n {
                let w = b.tensor(
                    format!("w{i}"),
                    rng.range(1, 4096),
                    TensorKind::Weight,
                    i as u16,
                );
                let out = b.tensor(
                    format!("a{i}"),
                    rng.range(1, 4096),
                    TensorKind::Activation,
                    i as u16,
                );
                b.op(
                    format!("ffn:mm{i}"),
                    i as u16,
                    OpKind::MatMul {
                        m: rng.range(1, 256) as u32,
                        k: rng.range(1, 256) as u32,
                        n: rng.range(1, 256) as u32,
                    },
                    vec![prev, w],
                    vec![out],
                );
                prev = out;
            }
            let g = b.finish().unwrap();
            assert_eq!(g.ops.len(), n);
            g.validate().unwrap();
        });
    }
}
