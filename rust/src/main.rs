//! `repro` — TRAPTI command-line launcher.
//!
//! Every paper experiment is a subcommand; reports print to stdout and
//! are mirrored as text/CSV under `reports/`. No external CLI crate is
//! available offline, so argument parsing is a small in-tree affair.
//! All subcommands run through `trapti::api` (see docs/API.md for the
//! full flag reference).
//!
//! ```text
//! repro report <exp>      # table1|fig1|fig5|fig6|fig7|fig8|fig9|
//!                         # table2|table3|sizing|headline|all
//! repro simulate [--model gpt2-xl] [--accel baseline] [--seq 2048]
//!                [--decode PROMPT:GEN] [--save-trace FILE]
//! repro batch [--models gpt2-xl,ds-r1d] [--seq 2048] [--threads N]
//! repro bank --trace FILE [--alpha 0.9] [--banks 1,2,4,8,16,32]
//!            [--capacities 48,64,... (MiB)]
//! repro e2e [--model tiny-gqa] [--steps 64]    # functional PJRT decode
//! repro baseline-compare                        # vs aggregate-DSE flow
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use trapti::analytic;
use trapti::api::{
    experiments as exp, ApiContext, BatchRunner, ExperimentSpec, ServingEngine,
};
use trapti::banking::{
    evaluate, Constraints, GatingPolicy, OnlineConfig, OnlineGateSim, OnlineReport,
    SweepSpec,
};
use trapti::config::{named, parse::parse_bytes, AccelConfig};
use trapti::obs::{EventLog, MetricsSnapshot, WalSink, WatchView};
use trapti::report::{figures, tables};
use trapti::runtime::{default_artifact_dir, DecodeSession, Manifest, Runtime};
use trapti::trace::{load_trace, save_trace, trace_to_csv, TeeSink, TraceSink};
use trapti::util::MIB;
use trapti::workload::{preset, Workload};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny flag parser: positionals + `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(args: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = it
                    .next()
                    .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Self { positional, flags })
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn flag_or(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }

    /// Boolean-valued flag: `--key 1|true|yes|on` (the parser requires
    /// every flag to carry a value; `--key 0` really means off).
    fn bool_flag(&self, key: &str) -> Result<bool> {
        match self.flag(key) {
            None => Ok(false),
            Some(v) => match v.to_ascii_lowercase().as_str() {
                "1" | "true" | "yes" | "on" => Ok(true),
                "0" | "false" | "no" | "off" => Ok(false),
                other => bail!("--{key} wants 0/1 (got `{other}`)"),
            },
        }
    }
}

/// Wall clock for WAL segment headers (milliseconds since the Unix
/// epoch). Lands only in the 28-byte header, never in record payloads,
/// so two same-spec runs still compare equal after stripping headers.
fn wall_unix_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

fn reports_dir() -> PathBuf {
    let dir = PathBuf::from("reports");
    std::fs::create_dir_all(&dir).ok();
    dir
}

fn emit(name: &str, text: &str) -> Result<()> {
    println!("{text}");
    let path = reports_dir().join(format!("{name}.txt"));
    std::fs::write(&path, text).with_context(|| format!("writing {}", path.display()))?;
    eprintln!("[saved {}]", path.display());
    Ok(())
}

fn emit_csv(name: &str, csv: &str) -> Result<()> {
    let path = reports_dir().join(format!("{name}.csv"));
    std::fs::write(&path, csv)?;
    eprintln!("[saved {}]", path.display());
    Ok(())
}

fn run(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw)?;
    let cmd = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    match cmd {
        "report" => report(&args),
        "spectrum" => spectrum_cmd(&args),
        "simulate" => simulate_cmd(&args),
        "batch" => batch_cmd(&args),
        "serve" => serve_cmd(&args),
        "bank" => bank_cmd(&args),
        "optimize" => optimize_cmd(&args),
        "replay" => replay_cmd(&args),
        "watch" => watch_cmd(&args),
        "lab" => lab_cmd(&args),
        "bench" => bench_cmd(&args),
        "e2e" => e2e_cmd(&args),
        "baseline-compare" => baseline_compare(),
        "ablate" => ablate(),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `repro help`)"),
    }
}

const HELP: &str = "\
TRAPTI reproduction CLI — see README.md and docs/API.md.

  repro report <exp>       regenerate a paper table/figure
                           (table1 fig1 fig5 fig6 fig7 fig8 fig9
                            table2 table3 sizing headline all)
  repro spectrum           attention-variant spectrum: run the full
                           Stage I -> II pipeline for every preset of
                           the matched-parameter MHA->GQA->MQA->MLA->
                           windowed ladder and print the peak-occupancy /
                           gated-energy curve with the PIM-offload
                           comparison columns
                           (--prompt N [default 512] --gen N [default
                            128] --hierarchy MiB [L2 spill capacity;
                            hierarchy-aware Stage II] --migrate-epb J
                            [L1<->L2 migration energy per byte]
                            --paper 1 [also run the paired-prefill pair
                            and report the 2.72x peak-ratio headline]
                            --csv-out FILE [deterministic CSV; the CI
                            spectrum determinism gate compares bytes])
  repro simulate           Stage-I run (--model, --accel, --seq,
                           --decode P:G, --save-trace FILE, --config F,
                           --wal-out DIR [append-only event log of the
                           run; tail it live with `repro watch`],
                           --metrics-out FILE [Prometheus text metrics
                           folded from the WAL; needs --wal-out])
  repro batch              run several scenarios as one parallel,
                           memoized batch (--models A,B,.. --seq
                           --accel --threads N --decode P:G)
  repro serve              multi-tenant serving: concurrent decode
                           streams over a paged KV arena (event-driven
                           engine), then a Stage-II sweep on the merged
                           trace
                           (--model --accel --concurrency --requests
                            --seed --prompt MIN:MAX --gen MIN:MAX
                            --page-tokens N --arrival CYCLES
                            --burst-gap CYCLES --burst-len N --calm-len N
                            [two-state MMPP bursty arrivals]
                            --tail-q8 0..255 [heavy-tailed lengths]
                            --tiers N [priority preemption w/ KV
                            evict/restore] --prefix-tokens N [shared
                            system-prompt pages] --tenants 1|2 [co-
                            resident paper-pair models]
                            --engine event|round-robin [round-robin =
                            the legacy differential oracle]
                            --trace-csv FILE --save-trace FILE
                            --fused 1 [stream Stage I straight into the
                            fused Stage-II engine; no materialized trace]
                            --capacities MiB,.. --banks 1,2,..
                            --alpha A [explicit Stage-II grid]
                            --sweep-out FILE [write the Stage-II table]
                            --wal-out DIR [event log; with --fused the
                            stream tees into the WAL])
  repro bank               Stage-II sweep over a saved trace
                           (--trace FILE --alpha --banks --capacities)
  repro optimize           Stage-II Pareto optimizer + cross-workload
                           robust (portfolio) selection over several
                           workloads at once, streamed through the fused
                           sweep engine
                           (--workloads MODEL:prefill:SEQ|
                            MODEL:decode:PROMPT:GEN|
                            MODEL:serve:REQS:CONC:SEED,..
                            --accel NAME
                            --capacities MiB,.. --banks 1,2,.. --alpha A
                            --epsilon E [frontier thinning, default 0]
                            --max-area-pct X --max-wake-pct X
                            --min-capacity MiB [constraints]
                            --pareto-csv FILE [deterministic frontier CSV]
                            --report-out FILE [full text report]
                            --online-validate 1 [Stage-III replay of every
                            frontier config; appends the predicted-vs-
                            observed validation table]
                            --hierarchy MiB [banked L1 + L2 spill: sub-
                            peak capacities stay feasible, migration +
                            L2 leakage charged through the energy model;
                            single-sequence workloads only]
                            --migrate-epb J [per-byte migration energy]
                            --pim 1 [append the PIM-offload comparison
                            column to the pareto/portfolio tables])
  repro replay             Stage-III online power-gating co-simulation:
                           replay ONE (C,B,alpha,policy) configuration
                           cycle-by-cycle against the live Stage-I
                           stream with wake-latency stalls fed back into
                           timing (per-bank Active/Idle/Drowsy/Gated/
                           Waking state machines)
                           (--workload MODEL:prefill:SEQ|
                            MODEL:decode:PROMPT:GEN|
                            MODEL:serve:REQS:CONC:SEED
                            --accel NAME
                            --capacity MiB --banks B --alpha A
                            --policy none|aggressive|conservative|drowsy
                            --wake N [override wake latency, cycles]
                            --hierarchy MiB [L1+L2 replay: spill the
                            over-capacity excess to L2 and charge
                            migration + L2 leakage; single-sequence]
                            --migrate-epb J [per-byte migration energy]
                            --timeline-csv FILE [per-bank state spans]
                            --report-out FILE [deterministic report]
                            --wal-out DIR [event log incl. per-bank
                            spans and wake-stall events])
  repro watch              tail a WAL directory and render live run
                           progress; exits when the run completes
                           (--wal DIR --once 1 [render once and exit]
                            --interval-ms N [poll period, default 500]
                            --metrics-out FILE [refresh Prometheus
                            metrics on every poll])
  repro lab                content-addressed experiment lab: expand a
                           TOML manifest (models x workloads x grid x
                           constraints) into a Stage I/II/III job DAG
                           and execute it in parallel into a resumable
                           artifact store (complete jobs are skipped;
                           a killed run resumes where it stopped)
                             lab run --manifest FILE|@paper|
                                     @paired-prefill|@tiny
                                     --lab DIR [store root, default
                                     ./result] --jobs N [default: all
                                     cores] --continue-on-failure 1
                             lab list [--manifest F]   job/store status
                             lab gc --manifest F[,F..] remove jobs no
                                     listed manifest can reach
                             lab trace-params JOB_ID   print a job's
                                     provenance manifest
  repro bench              perf-trajectory tooling for the BENCH_*.json
                           artifacts the bench targets emit
                             bench check --baseline FILE ARTIFACT.json..
                                     compare each artifact against its
                                     committed baseline entry (keyed by
                                     `name`; rules are max_<field>/
                                     min_<field> numeric bounds) and
                                     fail on any violation
  repro e2e                functional PJRT decode (--model, --steps)
  repro baseline-compare   TRAPTI vs aggregate-statistics DSE
  repro ablate             gating-policy sensitivity study (the paper's
                           future-work item: none / aggressive /
                           conservative / drowsy x alpha)
";

fn report(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .ok_or_else(|| anyhow!("report needs an experiment name"))?;
    let ctx = ApiContext::new();
    let all = which == "all";

    if which == "table1" || all {
        emit("table1", &tables::table1().render())?;
    }
    if which == "fig1" || all {
        let f = exp::fig1(&ctx)?;
        emit("fig1", &figures::fig1(&f))?;
    }
    // The prefill pair backs fig5/6/7/8/9 + table2: run once, reuse.
    if ["fig5", "fig6", "fig7", "fig8", "fig9", "table2", "headline"]
        .contains(&which)
        || all
    {
        let pair = exp::paired_prefill(&ctx)?;
        if which == "fig5" || all {
            let (text, csv_m, csv_g) = figures::fig5(&pair);
            emit("fig5", &text)?;
            emit_csv("fig5_gpt2_xl_trace", &csv_m)?;
            emit_csv("fig5_ds_r1d_trace", &csv_g)?;
        }
        if which == "fig6" || all {
            emit("fig6", &figures::fig6(&pair))?;
        }
        if which == "fig7" || all {
            emit("fig7", &figures::fig7(&pair))?;
        }
        if which == "fig8" || all {
            let f8 = exp::fig8(&pair.gqa);
            emit("fig8", &figures::fig8(&f8))?;
        }
        if ["fig9", "table2", "headline"].contains(&which) || all {
            let t2 = exp::table2(&ctx, &pair)?;
            if which == "table2" || all {
                let text = tables::table2(&t2)
                    .iter()
                    .map(|t| t.render())
                    .collect::<Vec<_>>()
                    .join("\n");
                emit("table2", &text)?;
            }
            if which == "fig9" || all {
                emit("fig9", &figures::fig9(&t2))?;
                emit_csv("fig9_points", &figures::fig9_csv(&t2))?;
            }
            if which == "headline" || all {
                let t3 = exp::table3(&ctx)?;
                let h = exp::headline(&ctx)?;
                let text = format!(
                    "TRAPTI headline numbers (paper in parentheses)\n\
                     peak SRAM utilization ratio MHA/GQA: {:.2}x (2.72x)\n\
                     end-to-end time ratio MHA/GQA:       {:.2}x (1.89x)\n\
                     best Table II  dE: {:.1}% (-61.3%)\n\
                     best Table III dE: {:.1}% (-77.8%, the 78% claim)\n\
                     GQA extra banking benefit vs MHA: {:.1} pp (~20)\n",
                    h.peak_ratio,
                    h.time_ratio,
                    h.table2_best_delta,
                    t3.best_delta(),
                    h.gqa_extra_benefit_pct,
                );
                emit("headline", &text)?;
            }
        }
    }
    if which == "table3" || all {
        let t3 = exp::table3(&ctx)?;
        let mut text = format!(
            "Multi-level run: e2e {:.1} ms (paper 550 ms), util {:.0}% \
             (paper 57%), on-chip {:.1} J (paper 73.4 J)\n\n",
            t3.stage1.result.seconds() * 1e3,
            t3.stage1.result.active_utilization() * 100.0,
            t3.stage1.energy.on_chip_j(),
        );
        for t in tables::table3(&t3) {
            text.push_str(&t.render());
            text.push('\n');
        }
        emit("table3", &text)?;
    }
    if which == "sizing" || all {
        let s = exp::sizing(&ctx)?;
        emit("sizing", &tables::sizing_table(&s).render())?;
    }
    if !all
        && ![
            "table1", "fig1", "fig5", "fig6", "fig7", "fig8", "fig9", "table2",
            "table3", "sizing", "headline",
        ]
        .contains(&which)
    {
        bail!("unknown experiment `{which}`");
    }
    Ok(())
}

/// Optional L1+L2 hierarchy from `--hierarchy MiB` (+ `--migrate-epb`).
/// Absent flags mean the flat, bit-identical historical behavior.
fn hierarchy_flags(args: &Args) -> Result<Option<trapti::banking::HierarchyConfig>> {
    let Some(l2) = args.flag("hierarchy") else {
        if args.flag("migrate-epb").is_some() {
            bail!("--migrate-epb needs --hierarchy MiB (the L2 spill capacity)");
        }
        return Ok(None);
    };
    let l2_capacity = parse_bytes(&format!("{}MiB", l2.trim()))?;
    let mut hc = trapti::banking::HierarchyConfig::new(l2_capacity);
    if let Some(e) = args.flag("migrate-epb") {
        hc.migrate_energy_per_byte_j = e.parse()?;
    }
    Ok(Some(hc))
}

/// `repro spectrum` — the attention-variant spectrum report: every
/// preset of the matched-parameter MHA→GQA→MQA→MLA→windowed ladder runs
/// the full Stage I → Stage II pipeline (optionally hierarchy-aware)
/// and lands as one row of the peak-occupancy / gated-energy curve with
/// the closed-form PIM-offload comparison columns.
fn spectrum_cmd(args: &Args) -> Result<()> {
    let prompt: u32 = args.flag_or("prompt", "512").parse()?;
    let gen: u32 = args.flag_or("gen", "128").parse()?;
    let hierarchy = hierarchy_flags(args)?;
    let with_paper = args.bool_flag("paper")?;
    let ctx = ApiContext::new();
    let s = exp::spectrum(&ctx, prompt, gen, hierarchy, with_paper)?;
    emit("spectrum", &tables::spectrum_table(&s).render())?;
    if !s.peak_is_monotone() {
        eprintln!(
            "warning: MHA->GQA->MQA->MLA peak-occupancy curve is not \
             monotone non-increasing"
        );
    }
    if let Some(r) = s.paper_peak_ratio {
        println!(
            "paired-prefill peak SRAM ratio GPT-2 XL / DS-R1D: {r:.2}x \
             (paper 2.72x)"
        );
    }
    let csv = tables::spectrum_csv(&s);
    emit_csv("spectrum", &csv)?;
    if let Some(path) = args.flag("csv-out") {
        std::fs::write(path, &csv).with_context(|| format!("writing {path}"))?;
        println!("spectrum CSV saved to {path}");
    }
    Ok(())
}

fn parse_workload(args: &Args) -> Result<Workload> {
    if let Some(d) = args.flag("decode") {
        let (p, g) = d
            .split_once(':')
            .ok_or_else(|| anyhow!("--decode wants PROMPT:GEN"))?;
        Ok(Workload::Decode {
            prompt: p.parse()?,
            gen: g.parse()?,
        })
    } else {
        Ok(Workload::Prefill {
            seq: args.flag_or("seq", "2048").parse()?,
        })
    }
}

fn simulate_cmd(args: &Args) -> Result<()> {
    // --config FILE loads model + accelerator (+ sweep) from TOML;
    // individual flags override nothing in that case for clarity.
    let wl = parse_workload(args)?;
    let spec = if let Some(path) = args.flag("config") {
        let e = trapti::config::load_experiment(Path::new(path))?;
        ExperimentSpec::builder()
            .model(e.model)
            .workload(wl)
            .accel(e.accel)
            .sweep(e.sweep)
            .build()?
    } else {
        let model_name = args.flag_or("model", "gpt2-xl");
        let model = preset(&model_name)
            .ok_or_else(|| anyhow!("unknown model `{model_name}`"))?;
        let accel_name = args.flag_or("accel", "baseline");
        let accel = named(&accel_name)
            .ok_or_else(|| anyhow!("unknown accel `{accel_name}`"))?;
        ExperimentSpec::builder()
            .model(model)
            .workload(wl)
            .accel(accel)
            .build()?
    };
    let ctx = ApiContext::new();
    // --wal-out: identical run, but every occupancy sample and stage
    // event also lands in an append-only on-disk log (`repro watch`
    // tails it; `trapti::obs::replay_wal` reconstructs the trace).
    let s1 = match args.flag("wal-out") {
        Some(dir) => {
            let run = spec.materialize_logged(&ctx, Path::new(dir), wall_unix_ms())?;
            match run {
                trapti::api::MaterializedRun::Single(s1) => {
                    println!("WAL written to {dir}/");
                    s1
                }
                trapti::api::MaterializedRun::Serving(_) => {
                    unreachable!("simulate builds single-sequence workloads")
                }
            }
        }
        None => spec.run_stage1(&ctx)?,
    };
    println!("{}", s1.graph.summary());
    println!("spec hash: {:016x}", s1.spec.content_hash());
    println!(
        "cycles={} ({:.1} ms)  peak needed={:.1} MiB  occupied peak={:.1} MiB",
        s1.result.total_cycles,
        s1.result.seconds() * 1e3,
        s1.result.peak_needed() as f64 / MIB as f64,
        s1.trace().peak_occupied() as f64 / MIB as f64,
    );
    println!(
        "active PE util={:.1}%  e2e util={:.1}%  feasible={}  on-chip E={:.2} J",
        s1.result.active_utilization() * 100.0,
        s1.result.e2e_utilization() * 100.0,
        s1.result.feasible(),
        s1.energy.on_chip_j(),
    );
    println!(
        "SRAM reads={} writes={}  DRAM rd={:.2} GB wr={:.2} GB  writebacks={}",
        s1.result.stats.reads,
        s1.result.stats.writes,
        s1.result.stats.dram_read_bytes as f64 / 1e9,
        s1.result.stats.dram_write_bytes as f64 / 1e9,
        s1.result.stats.writebacks,
    );
    if let Some(path) = args.flag("save-trace") {
        save_trace(s1.trace(), Path::new(path))?;
        println!("trace saved to {path}");
    }
    if args.flag("csv").is_some() {
        emit_csv("trace", &trace_to_csv(s1.trace()))?;
    }
    if let Some(path) = args.flag("metrics-out") {
        let dir = args
            .flag("wal-out")
            .ok_or_else(|| anyhow!("--metrics-out folds the WAL; add --wal-out DIR"))?;
        let log = EventLog::open(Path::new(dir))?;
        MetricsSnapshot::from_log(&log).write_atomic(Path::new(path))?;
        println!("metrics written to {path}");
    }
    Ok(())
}

/// Run several scenarios as one parallel batch (BatchRunner): every
/// model in `--models` on the same workload/accelerator, memoized by
/// spec hash, then a Stage-II paper-grid summary per scenario.
fn batch_cmd(args: &Args) -> Result<()> {
    let wl = parse_workload(args)?;
    let accel_name = args.flag_or("accel", "baseline");
    let accel = named(&accel_name)
        .ok_or_else(|| anyhow!("unknown accel `{accel_name}`"))?;
    let models = args.flag_or("models", "gpt2-xl,ds-r1d");
    let mut specs = Vec::new();
    for name in models.split(',') {
        let name = name.trim();
        let model = preset(name).ok_or_else(|| anyhow!("unknown model `{name}`"))?;
        specs.push(
            ExperimentSpec::builder()
                .model(model)
                .workload(wl)
                .accel(accel.clone())
                .build()?,
        );
    }
    // derive_sweep keeps Stage II inside the batch's parallelism and
    // memoization (paper grid derived from each run's Stage-I peak).
    let mut runner = BatchRunner::new().derive_sweep(true);
    if let Some(t) = args.flag("threads") {
        runner = runner.threads(t.parse()?);
    }
    let t0 = std::time::Instant::now();
    let results = runner.run(&specs)?;
    println!(
        "batch: {} scenario(s) in {:.1} s wall",
        results.len(),
        t0.elapsed().as_secs_f64()
    );
    println!(
        "{:>18} {:>18} {:>12} {:>9} {:>11} {:>9} {:>9}",
        "model", "spec", "cycles", "ms", "peak[MiB]", "E[J]", "best dE%"
    );
    for r in &results {
        let best = r
            .sweep
            .iter()
            .flat_map(|(_, pts)| pts.iter())
            .map(|p| p.delta_e_pct())
            .fold(f64::INFINITY, f64::min);
        println!(
            "{:>18} {:>18} {:>12} {:>9.1} {:>11.1} {:>9.2} {:>9.1}",
            r.spec.model.name,
            format!("{:016x}", r.hash),
            r.stage1.result.total_cycles,
            r.stage1.result.seconds() * 1e3,
            r.stage1.result.peak_needed() as f64 / MIB as f64,
            r.stage1.energy.on_chip_j(),
            best,
        );
    }
    // --lab DIR: persist every result into the content-addressed lab
    // store, so batch output survives the process and later `repro lab
    // list` / `trace-params` can inspect it.
    if let Some(dir) = args.flag("lab") {
        let store = trapti::lab::Store::new(dir);
        let ids = trapti::lab::store::persist_batch(&store, &results)?;
        println!(
            "persisted {} new result(s) under {}/",
            ids.len(),
            store.root().display()
        );
    }
    Ok(())
}

/// Parse a `MIN:MAX` token range.
fn parse_range(s: &str, flag: &str) -> Result<(u32, u32)> {
    let (lo, hi) = s
        .split_once(':')
        .ok_or_else(|| anyhow!("--{flag} wants MIN:MAX"))?;
    Ok((lo.parse()?, hi.parse()?))
}

/// Optional explicit Stage-II grid from `--capacities` (MiB list),
/// `--banks` and `--alpha`; policies are the serving trio. Passing the
/// same grid to a materialized and a `--fused` run makes their sweep
/// tables byte-comparable (the CI determinism gate).
fn serving_grid_flags(args: &Args) -> Result<Option<SweepSpec>> {
    let Some(list) = args.flag("capacities") else {
        // --banks/--alpha only shape an *explicit* grid; without a
        // capacity axis they would be silently dropped, so reject them.
        if args.flag("banks").is_some() || args.flag("alpha").is_some() {
            bail!(
                "--banks/--alpha need --capacities MiB,.. (they customize an \
                 explicit Stage-II grid; without one the grid is derived \
                 from the trace peak / arena bound)"
            );
        }
        return Ok(None);
    };
    let capacities: Vec<u64> = list
        .split(',')
        .map(|s| parse_bytes(&format!("{}MiB", s.trim())))
        .collect::<Result<_>>()?;
    let banks: Vec<u32> = args
        .flag_or("banks", "1,2,4,8,16,32")
        .split(',')
        .map(|s| s.trim().parse::<u32>().map_err(anyhow::Error::from))
        .collect::<Result<_>>()?;
    let alpha: f64 = args.flag_or("alpha", "0.9").parse()?;
    Ok(Some(SweepSpec {
        capacities,
        banks,
        alphas: vec![alpha],
        policies: vec![
            GatingPolicy::Aggressive,
            GatingPolicy::conservative(),
            GatingPolicy::drowsy(),
        ],
    }))
}

/// Deterministic Stage-II report for a serving sweep (stable field order
/// and float formatting), shared by stdout and `--sweep-out` so the
/// materialized and fused paths are byte-comparable.
fn serving_sweep_report(s2: &trapti::api::ServingSweep) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "Stage II on the serving trace ({} candidates):",
        s2.points.len()
    );
    let _ = writeln!(
        out,
        "{:>9} {:>5} {:>13} {:>12} {:>8} {:>9} {:>10}",
        "C[MiB]", "B", "policy", "E_total[J]", "dE%", "avgBact", "gated%"
    );
    for p in &s2.points {
        let _ = writeln!(
            out,
            "{:>9} {:>5} {:>13} {:>12.3} {:>8.1} {:>9.2} {:>9.1}",
            p.eval.capacity / MIB,
            p.eval.banks,
            p.eval.policy.label(),
            p.eval.e_total_j(),
            p.delta_e_pct(),
            p.eval.avg_active_banks,
            p.eval.gated_fraction * 100.0,
        );
    }
    if let Some(best) = s2.best() {
        let _ = writeln!(
            out,
            "best: C={} MiB B={} policy={} (dE {:.1}%)",
            best.eval.capacity / MIB,
            best.eval.banks,
            best.eval.policy.label(),
            best.delta_e_pct(),
        );
    }
    out
}

/// Multi-tenant serving scenario: Stage-I serving simulation (merged
/// KV-arena occupancy) + Stage-II banking sweep on the serving trace.
/// With `--fused`, Stage I streams straight into the fused Stage-II
/// engine and no trace is materialized.
fn serve_cmd(args: &Args) -> Result<()> {
    let model_name = args.flag_or("model", "gpt2-xl");
    let model = preset(&model_name)
        .ok_or_else(|| anyhow!("unknown model `{model_name}`"))?;
    let accel_name = args.flag_or("accel", "baseline");
    let accel = named(&accel_name)
        .ok_or_else(|| anyhow!("unknown accel `{accel_name}`"))?;

    let mut params = trapti::serving::ServingParams::new(
        args.flag_or("requests", "256").parse()?,
        args.flag_or("concurrency", "64").parse()?,
        args.flag_or("seed", "7").parse()?,
    );
    if let Some(p) = args.flag("prompt") {
        (params.prompt_min, params.prompt_max) = parse_range(p, "prompt")?;
    }
    if let Some(g) = args.flag("gen") {
        (params.gen_min, params.gen_max) = parse_range(g, "gen")?;
    }
    if let Some(pt) = args.flag("page-tokens") {
        params.page_tokens = pt.parse()?;
    }
    if let Some(a) = args.flag("arrival") {
        params.mean_arrival_gap = a.parse()?;
    }
    if let Some(b) = args.flag("burst-gap") {
        params.burst_gap = b.parse()?;
        if params.burst_gap > 0 {
            // Dwell defaults so `--burst-gap N` alone is a valid bursty
            // spec; override with --burst-len / --calm-len.
            params.burst_len = 8;
            params.calm_len = 32;
        }
    }
    if let Some(b) = args.flag("burst-len") {
        params.burst_len = b.parse()?;
    }
    if let Some(c) = args.flag("calm-len") {
        params.calm_len = c.parse()?;
    }
    if let Some(q) = args.flag("tail-q8") {
        params.len_tail_q8 = q.parse()?;
    }
    if let Some(t) = args.flag("tiers") {
        params.tiers = t.parse()?;
    }
    if let Some(p) = args.flag("prefix-tokens") {
        params.prefix_tokens = p.parse()?;
    }
    if let Some(t) = args.flag("tenants") {
        params.tenants = t.parse()?;
    }
    let engine = match args.flag_or("engine", "event").as_str() {
        "event" => ServingEngine::Event,
        "round-robin" => ServingEngine::RoundRobin,
        other => bail!("unknown --engine `{other}` (event|round-robin)"),
    };
    let fused = args.bool_flag("fused")?;

    let mut builder = ExperimentSpec::builder()
        .model(model)
        .serving(params)
        .accel(accel);
    if let Some(grid) = serving_grid_flags(args)? {
        builder = builder.sweep(grid);
    }
    let spec = builder.build()?;
    let ctx = ApiContext::new();

    let (run, s2) = if fused {
        if engine == ServingEngine::RoundRobin {
            bail!("--engine round-robin is the materialized differential oracle; drop --fused");
        }
        match args.flag("wal-out") {
            Some(dir) => {
                // The fused stream tees into the WAL alongside the
                // single-pass sweep engine — same results, plus the log.
                let out =
                    spec.serve_fused_logged(&ctx, Path::new(dir), wall_unix_ms())?;
                println!("WAL written to {dir}/");
                out
            }
            None => spec.serve_fused(&ctx)?,
        }
    } else {
        let run = match args.flag("wal-out") {
            Some(dir) => {
                if engine == ServingEngine::RoundRobin {
                    bail!("--wal-out logging runs the event engine; drop --engine round-robin");
                }
                let run = spec.materialize_logged(&ctx, Path::new(dir), wall_unix_ms())?;
                match run {
                    trapti::api::MaterializedRun::Serving(run) => {
                        println!("WAL written to {dir}/");
                        run
                    }
                    trapti::api::MaterializedRun::Single(_) => {
                        unreachable!("serve builds serving workloads")
                    }
                }
            }
            None => spec.run_serving_with_engine(engine)?,
        };
        let s2 = run.stage2(&ctx)?;
        (run, s2)
    };
    let r = &run.result;
    println!("{} on {} [spec {:016x}]", r.workload, r.accel, spec.content_hash());
    println!(
        "completed {}/{} requests in {:.1} ms ({} cycles), peak {} concurrent",
        r.completed,
        params.requests,
        r.seconds() * 1e3,
        r.total_cycles,
        r.peak_concurrent,
    );
    if r.evicted > 0 {
        println!(
            "preemption: {} evictions, {} restores",
            r.evicted, r.restored
        );
    }
    if fused {
        println!(
            "arena: {:.1} MiB capacity, {:.1} KiB pages  trace: streamed \
             (fused Stage I+II, nothing materialized)",
            r.arena_capacity as f64 / MIB as f64,
            r.page_bytes as f64 / 1024.0,
        );
    } else {
        println!(
            "arena: {:.1} MiB capacity, {:.1} KiB pages  trace: {} samples, hash {:016x}",
            r.arena_capacity as f64 / MIB as f64,
            r.page_bytes as f64 / 1024.0,
            r.trace.samples().len(),
            r.trace_hash(),
        );
        println!(
            "occupancy: peak needed {:.1} MiB, peak occupied {:.1} MiB, avg needed {:.1} MiB",
            r.peak_needed() as f64 / MIB as f64,
            r.peak_occupied() as f64 / MIB as f64,
            r.trace.avg_needed() / MIB as f64,
        );
    }

    let table = serving_sweep_report(&s2);
    print!("\n{table}");
    if let Some(path) = args.flag("sweep-out") {
        std::fs::write(path, &table).with_context(|| format!("writing {path}"))?;
        println!("sweep table saved to {path}");
        eprintln!("note: --sweep-out is superseded by `repro lab run` (sweep.txt per job)");
    }

    if let Some(path) = args.flag("trace-csv") {
        if fused {
            bail!("--trace-csv needs a materialized trace; drop --fused");
        }
        std::fs::write(path, trace_to_csv(run.trace()))
            .with_context(|| format!("writing {path}"))?;
        println!("trace CSV saved to {path}");
    }
    if let Some(path) = args.flag("save-trace") {
        if fused {
            bail!("--save-trace needs a materialized trace; drop --fused");
        }
        save_trace(run.trace(), Path::new(path))?;
        println!("trace saved to {path}");
    }
    Ok(())
}

fn bank_cmd(args: &Args) -> Result<()> {
    let trace_path = args
        .flag("trace")
        .ok_or_else(|| anyhow!("bank needs --trace FILE (from simulate --save-trace)"))?;
    let trace = load_trace(Path::new(trace_path))?;
    let alpha: f64 = args.flag_or("alpha", "0.9").parse()?;
    let banks: Vec<u32> = args
        .flag_or("banks", "1,2,4,8,16,32")
        .split(',')
        .map(|s| s.trim().parse::<u32>().map_err(anyhow::Error::from))
        .collect::<Result<_>>()?;
    let capacities: Vec<u64> = match args.flag("capacities") {
        Some(list) => list
            .split(',')
            .map(|s| parse_bytes(&format!("{}MiB", s.trim())))
            .collect::<Result<_>>()?,
        None => vec![trace.capacity],
    };
    let ctx = ApiContext::new();
    // Reads/writes are not stored in the trace file; accept flags.
    let stats = trapti::trace::AccessStats {
        reads: args.flag_or("reads", "0").parse()?,
        writes: args.flag_or("writes", "0").parse()?,
        ..Default::default()
    };
    println!(
        "{:>9} {:>5} {:>12} {:>10} {:>8} {:>9} {:>10}",
        "C[MiB]", "B", "E_total[J]", "dE%", "avgBact", "gated%", "area[mm2]"
    );
    for &cap in &capacities {
        // ΔE reference: unbanked and ungated. Every row — B=1 included —
        // is evaluated under the gating policy (a lone bank still gates
        // its idle gaps).
        let base = evaluate(
            &ctx.cacti, &trace, &stats, cap, 1, alpha,
            GatingPolicy::None, 1.0,
        )?;
        for &b in &banks {
            let ev = evaluate(
                &ctx.cacti, &trace, &stats, cap, b, alpha,
                GatingPolicy::Aggressive, 1.0,
            )?;
            println!(
                "{:>9} {:>5} {:>12.3} {:>10.1} {:>8.2} {:>9.1} {:>10.1}",
                cap / MIB,
                b,
                ev.e_total_j(),
                ev.delta_pct(&base),
                ev.avg_active_banks,
                ev.gated_fraction * 100.0,
                ev.area_mm2,
            );
        }
    }
    Ok(())
}

/// Parse one `MODEL:prefill:SEQ` / `MODEL:decode:PROMPT:GEN` /
/// `MODEL:serve:REQUESTS:CONCURRENCY:SEED` workload descriptor — the
/// grammar lives in `trapti::lab::manifest` so the CLI and lab
/// manifests can never fork.
fn parse_workload_descriptor(desc: &str, accel: &AccelConfig) -> Result<ExperimentSpec> {
    trapti::lab::manifest::parse_descriptor(desc, accel)
}

/// Explicit optimizer grid from `--capacities`/`--banks`/`--alpha`
/// (all four gating policies), or `None` to derive a covering default.
fn optimize_grid_flags(args: &Args) -> Result<Option<SweepSpec>> {
    let Some(list) = args.flag("capacities") else {
        if args.flag("banks").is_some() || args.flag("alpha").is_some() {
            bail!(
                "--banks/--alpha need --capacities MiB,.. (without them \
                 `repro optimize` derives a grid covering every \
                 workload's capacity bound)"
            );
        }
        return Ok(None);
    };
    let capacities: Vec<u64> = list
        .split(',')
        .map(|s| parse_bytes(&format!("{}MiB", s.trim())))
        .collect::<Result<_>>()?;
    let banks: Vec<u32> = args
        .flag_or("banks", "1,2,4,8,16,32")
        .split(',')
        .map(|s| s.trim().parse::<u32>().map_err(anyhow::Error::from))
        .collect::<Result<_>>()?;
    let alpha: f64 = args.flag_or("alpha", "0.9").parse()?;
    Ok(Some(SweepSpec {
        capacities,
        banks,
        alphas: vec![alpha],
        // Same policy axis as the derived covering grid — the two flag
        // modes must explore the same policy set.
        policies: trapti::api::optimize::full_policy_axis(),
    }))
}

/// Stage-II Pareto + portfolio optimization over several workloads at
/// once — the offline flow that *chooses* a banked configuration. Each
/// workload runs fused (Stage I streams into the sweep engine; nothing
/// materialized), then `banking::optimize` filters, builds per-workload
/// ε-Pareto frontiers, and ranks shared configurations by worst-case
/// energy regret. Output is deterministic: same specs + seed produce
/// byte-identical reports and `--pareto-csv` files (the CI gate).
fn optimize_cmd(args: &Args) -> Result<()> {
    use std::fmt::Write as _;

    let accel_name = args.flag_or("accel", "baseline");
    let accel = named(&accel_name)
        .ok_or_else(|| anyhow!("unknown accel `{accel_name}`"))?;
    let descriptors = args.flag_or(
        "workloads",
        "gpt2-xl:decode:512:128,ds-r1d:decode:512:128,gpt2-xl:serve:64:8:7",
    );
    let mut specs = Vec::new();
    for d in descriptors.split(',') {
        specs.push(parse_workload_descriptor(d.trim(), &accel)?);
    }
    // --hierarchy lifts every workload's Stage II from flat SRAM to
    // banked L1 + L2 spill (sub-peak capacities become feasible, with
    // migration + L2 leakage charged); the spec validator rejects
    // serving workloads, which have no materializable single trace.
    if let Some(hc) = hierarchy_flags(args)? {
        for spec in &mut specs {
            spec.hierarchy = Some(hc);
            spec.validate()?;
        }
    }
    let grid = match optimize_grid_flags(args)? {
        Some(g) => g,
        // Shared covering grid derived from closed-form capacity bounds
        // (api::optimize::covering_grid — also what the bench uses).
        None => trapti::api::optimize::covering_grid(&specs),
    };
    let constraints = Constraints {
        max_area_overhead_pct: match args.flag("max-area-pct") {
            Some(v) => Some(v.parse()?),
            None => None,
        },
        max_wake_exposure_pct: match args.flag("max-wake-pct") {
            Some(v) => Some(v.parse()?),
            None => None,
        },
        min_capacity: match args.flag("min-capacity") {
            Some(v) => Some(parse_bytes(&format!("{}MiB", v.trim()))?),
            None => None,
        },
    };
    let epsilon: f64 = args.flag_or("epsilon", "0").parse()?;

    let ctx = ApiContext::new();
    let opts = trapti::api::PortfolioOptions {
        grid: Some(grid.clone()),
        constraints,
        epsilon,
        weights: None,
    };
    let run = trapti::api::run_portfolio(&ctx, &specs, &opts)?;
    let r = &run.result;

    // --pim 1: closed-form PIM-offload comparison column per workload
    // (None for serving, which has no closed form — rendered as `-`).
    let pim: Option<Vec<Option<trapti::analytic::PimEstimate>>> =
        if args.bool_flag("pim")? {
            Some(
                specs
                    .iter()
                    .map(|s| analytic::estimate_pim(&s.model, &s.workload))
                    .collect(),
            )
        } else {
            None
        };

    let mut report = String::new();
    let _ = writeln!(
        report,
        "Stage-II Pareto/portfolio optimization: {} workload(s), grid {} \
         points, epsilon={:.3}",
        r.workload_names.len(),
        grid.points(),
        r.epsilon,
    );
    for (i, f) in r.frontiers.iter().enumerate() {
        let _ = writeln!(
            report,
            "\n{}: own optimum {} (E={:.3} J over {} cycles)",
            f.workload,
            f.best_key.label(),
            f.best_energy_j,
            f.end_cycles,
        );
        match pim.as_ref().and_then(|ests| ests.get(i)?.as_ref()) {
            Some(est) => report.push_str(&tables::pareto_table_pim(f, est).render()),
            None => report.push_str(&tables::pareto_table(f).render()),
        }
    }
    report.push('\n');
    match &pim {
        Some(ests) => {
            let pim_e: Vec<Option<f64>> =
                ests.iter().map(|o| o.map(|p| p.e_pim_j)).collect();
            report.push_str(&tables::portfolio_table_pim(r, 15, &pim_e).render());
        }
        None => report.push_str(&tables::portfolio_table(r, 15).render()),
    }
    if let Some(best) = r.robust_best() {
        let _ = writeln!(
            report,
            "robust-best across all workloads: {}  (worst regret \
             {:+.1}%, mean {:+.1}%)",
            best.key.label(),
            best.worst_regret_pct,
            best.mean_regret_pct,
        );
    }
    // Stage-III pass: replay every frontier configuration online and
    // append the predicted-vs-observed validation table.
    if args.bool_flag("online-validate")? {
        let vals = trapti::api::online_validate(&ctx, &specs, &run)?;
        report.push('\n');
        report.push_str(&tables::validation_table(&vals).render());
    }
    print!("{report}");

    // Deprecated in favour of the lab store: `repro lab run` persists
    // the same portfolio.txt / pareto.csv content-addressed and
    // resumable. Kept for one-off runs.
    if let Some(path) = args.flag("report-out") {
        std::fs::write(path, &report).with_context(|| format!("writing {path}"))?;
        println!("report saved to {path}");
        eprintln!("note: --report-out is superseded by `repro lab run` (portfolio.txt)");
    }
    if let Some(path) = args.flag("pareto-csv") {
        std::fs::write(path, tables::pareto_csv(r))
            .with_context(|| format!("writing {path}"))?;
        println!("Pareto CSV saved to {path}");
        eprintln!("note: --pareto-csv is superseded by `repro lab run` (pareto.csv)");
    }
    Ok(())
}

/// `repro lab run|list|gc|trace-params` — the content-addressed
/// experiment lab (`trapti::lab`). A manifest argument is either a
/// TOML path or a built-in `@name` (see `api::experiments::lab_manifest`).
fn lab_cmd(args: &Args) -> Result<()> {
    use trapti::lab::store::{hex, parse_hex};
    use trapti::lab::{execute, ExecOptions, JobKind, LabManifest, Plan, Store};

    let sub = args.positional.get(1).map(String::as_str).unwrap_or("run");
    let store = Store::new(args.flag_or("lab", "result"));
    // `--manifest` accepts a comma-separated list for `gc`, so liveness
    // can span several campaigns sharing one store.
    let plans = |required: bool| -> Result<Vec<Plan>> {
        match args.flag("manifest") {
            None if required => bail!("lab {sub} needs --manifest FILE|@name"),
            None => Ok(Vec::new()),
            Some(list) => list
                .split(',')
                .map(|s| Ok(Plan::of(LabManifest::resolve(s.trim())?)))
                .collect(),
        }
    };
    match sub {
        "run" => {
            let plan = Plan::of(LabManifest::resolve(&args.flag_or("manifest", "@tiny"))?);
            let jobs = match args.flag("jobs") {
                Some(v) => v.parse::<usize>().context("--jobs")?,
                None => std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1),
            };
            let continue_on_failure = match args.flag_or("continue-on-failure", "0").as_str() {
                "1" | "true" | "yes" | "on" => true,
                "0" | "false" | "no" | "off" => false,
                other => bail!("--continue-on-failure wants 0/1 (got `{other}`)"),
            };
            let opts = ExecOptions {
                jobs,
                continue_on_failure,
                progress: true,
            };
            let ctx = ApiContext::new();
            let t0 = std::time::Instant::now();
            let summary = execute(&ctx, &store, &plan, &opts)?;
            println!(
                "lab `{}`: executed {}, skipped {} (cache hits), failed {} \
                 in {:.1} s wall",
                plan.manifest.name,
                summary.executed.len(),
                summary.skipped.len(),
                summary.failed.len(),
                t0.elapsed().as_secs_f64()
            );
            for (id, why) in &summary.failed {
                let label = plan.job(*id).map(|j| j.label.as_str()).unwrap_or("?");
                eprintln!("  FAILED {label} ({}): {why}", hex(*id));
            }
            if !summary.ok() {
                bail!("lab run finished with {} failed job(s)", summary.failed.len());
            }
            if let Some(opt) = plan.jobs.iter().find(|j| j.kind == JobKind::Optimize) {
                let bytes = store.read_artifact(opt.id, "portfolio.txt")?;
                print!("\n{}", String::from_utf8_lossy(&bytes));
            }
            println!("artifacts under {}/", store.root().display());
            Ok(())
        }
        "list" => {
            let planned = plans(false)?;
            if planned.is_empty() {
                // No manifest: list whatever the store holds.
                let ids = store.jobs()?;
                if ids.is_empty() {
                    println!("no jobs under {}/", store.root().display());
                    return Ok(());
                }
                println!("{:<16} {:>10} {}", "job", "kind", "label [lab]");
                for id in ids {
                    match store.manifest(id) {
                        Ok(m) => {
                            let s = |key: &str| -> String {
                                m.expect(key)
                                    .ok()
                                    .and_then(|v| v.as_str())
                                    .unwrap_or("?")
                                    .to_string()
                            };
                            println!(
                                "{} {:>10} {} [{}]",
                                hex(id),
                                s("kind"),
                                s("label"),
                                s("lab")
                            );
                        }
                        Err(_) => println!("{} {:>10} (incomplete)", hex(id), "-"),
                    }
                }
                return Ok(());
            }
            for plan in &planned {
                println!(
                    "lab `{}`: {} job(s) against {}/",
                    plan.manifest.name,
                    plan.jobs.len(),
                    store.root().display()
                );
                println!("{:<16} {:>8} {}", "job", "status", "label");
                for j in &plan.jobs {
                    let status = if store.is_complete(j.id) { "done" } else { "pending" };
                    println!("{} {:>8} {}", hex(j.id), status, j.label);
                }
            }
            Ok(())
        }
        "gc" => {
            let planned = plans(true)?;
            let mut live = std::collections::BTreeSet::new();
            for plan in &planned {
                live.extend(plan.live_ids());
            }
            let removed = store.gc(&live)?;
            println!(
                "gc: removed {} job(s), kept {} live under {}/",
                removed.len(),
                live.len(),
                store.root().display()
            );
            for id in removed {
                println!("  removed {}", hex(id));
            }
            Ok(())
        }
        "trace-params" => {
            let id = args
                .positional
                .get(2)
                .ok_or_else(|| anyhow!("lab trace-params needs a 16-hex JOB_ID"))?;
            let id = parse_hex(id)?;
            println!("{}", store.manifest(id)?.to_string_pretty());
            Ok(())
        }
        other => bail!("unknown lab subcommand `{other}` (run|list|gc|trace-params)"),
    }
}

fn parse_policy(name: &str) -> Result<GatingPolicy> {
    trapti::lab::manifest::parse_policy_name(name)
}

/// Deterministic Stage-III replay report (stable field order and float
/// formatting), shared by stdout and `--report-out` so two same-seed
/// runs are byte-comparable (the CI replay determinism gate).
fn online_replay_report(
    workload: &str,
    report: &OnlineReport,
    zero_wake: &OnlineReport,
) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "Stage III online replay: {workload} @ {}",
        report.config.label()
    );
    let _ = writeln!(
        out,
        "trace {} cycles; stalls +{} cycles ({:.4}%) over {} wake event(s) \
         (wake latency {} cyc)",
        report.trace_cycles,
        report.stall_cycles,
        report.stall_pct(),
        report.wake_events,
        report.wake_cycles,
    );
    let _ = writeln!(
        out,
        "energy online {:.6} J (dyn {:.6} + leak {:.6} + sw {:.6})",
        report.e_total_j(),
        report.eval.e_dyn_j,
        report.eval.e_leak_j,
        report.eval.e_sw_j,
    );
    let _ = writeln!(
        out,
        "offline Stage-II prediction {:.6} J (online delta {:+.4}%; the \
         offline model cannot see stall-extended leakage)",
        zero_wake.e_total_j(),
        report.eval.delta_pct(&zero_wake.eval),
    );
    out.push_str(&tables::online_bank_table(report).render());
    out.push('\n');
    out.push_str(&figures::online_timeline(report, 96));
    out
}

/// Stage III: online power-gating co-simulation of one configuration —
/// the Stage-I simulation streams occupancy straight into the
/// cycle-level gating replay (`banking::online::OnlineGateSim`), which
/// feeds wake-latency stalls back into execution timing. A second
/// zero-wake replay of the same stream supplies the offline-equivalent
/// prediction (bit-identical to `banking::evaluate`), so the report
/// quantifies exactly what the offline model missed.
fn replay_cmd(args: &Args) -> Result<()> {
    let accel_name = args.flag_or("accel", "baseline");
    let accel = named(&accel_name)
        .ok_or_else(|| anyhow!("unknown accel `{accel_name}`"))?;
    let descriptor = args.flag_or("workload", "gpt2-xl:decode:512:128");
    let spec = parse_workload_descriptor(descriptor.trim(), &accel)?;

    let capacity = match args.flag("capacity") {
        Some(v) => parse_bytes(&format!("{}MiB", v.trim()))?,
        // Default: the provisioned capacity the trace lives in — the
        // accelerator's shared SRAM for single-sequence runs, the
        // closed-form arena bound for serving (occupancy can never
        // exceed either, so the replay is always feasible).
        None => match spec.workload {
            Workload::Serving(_) => {
                trapti::api::optimize::covering_capacity_bound(&spec)
            }
            _ => spec.accel.on_chip[0].capacity,
        },
    };
    let banks: u32 = args.flag_or("banks", "8").parse()?;
    let alpha: f64 = args.flag_or("alpha", "0.9").parse()?;
    let policy = parse_policy(&args.flag_or("policy", "aggressive"))?;
    let mut cfg = OnlineConfig::new(capacity, banks, alpha, policy);
    if let Some(w) = args.flag("wake") {
        cfg.wake_override = Some(w.parse()?);
    }
    // --hierarchy: replay through the L1+L2 spill co-simulator instead
    // of the flat streaming path (needs a materialized trace).
    if let Some(hc) = hierarchy_flags(args)? {
        return replay_hierarchy_cmd(args, &spec, cfg, hc);
    }
    let mut zero_cfg = cfg;
    zero_cfg.wake_override = Some(0);

    // One Stage-I pass feeds BOTH co-simulators through a TeeSink: the
    // real replay and its zero-wake offline-equivalent prediction come
    // out of a single simulation, nothing materialized.
    let ctx = ApiContext::new();
    let mut sim = OnlineGateSim::new(&ctx.cacti, cfg, spec.freq_ghz())?;
    let mut zero_sim = OnlineGateSim::new(&ctx.cacti, zero_cfg, spec.freq_ghz())?;
    // --wal-out: tee the Stage-I stream into an on-disk event log too;
    // per-bank spans and wake stalls are appended after the replay (they
    // only exist once the report is final).
    let wal_dir = args.flag("wal-out").map(str::to_string);
    let mut wal = match &wal_dir {
        Some(dir) => Some(
            WalSink::create(Path::new(dir), spec.content_hash(), wall_unix_ms())
                .with_context(|| format!("creating WAL at {dir}"))?,
        ),
        None => None,
    };
    let (label, report, zero_wake, stats) = match spec.workload {
        Workload::Serving(_) => {
            let run = {
                let mut sinks: Vec<&mut dyn TraceSink> = vec![&mut sim, &mut zero_sim];
                if let Some(w) = wal.as_mut() {
                    sinks.push(w);
                }
                let mut tee = TeeSink::new(sinks);
                spec.stream_serving(&mut tee)?
            };
            let rep = sim.into_report(&run.result.stats)?;
            let zero = zero_sim.into_report(&run.result.stats)?;
            let stats = run.result.stats.clone();
            (run.result.workload.clone(), rep, zero, stats)
        }
        _ => {
            let summary = {
                let mut sinks: Vec<&mut dyn TraceSink> = vec![&mut sim, &mut zero_sim];
                if let Some(w) = wal.as_mut() {
                    sinks.push(w);
                }
                let mut tee = TeeSink::new(sinks);
                spec.stream_stage1(&ctx, &mut tee)?
            };
            let rep = sim.into_report(summary.stats())?;
            let zero = zero_sim.into_report(summary.stats())?;
            let stats = summary.stats().clone();
            (trapti::api::optimize::workload_label(&spec), rep, zero, stats)
        }
    };
    if let Some(mut w) = wal.take() {
        for (t, ev) in report.events() {
            w.append_event(t, &ev);
        }
        w.close(Some(&stats))?;
        if let Some(dir) = &wal_dir {
            println!("WAL written to {dir}/");
        }
    }

    let text = online_replay_report(&label, &report, &zero_wake);
    print!("{text}");
    if let Some(path) = args.flag("report-out") {
        std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
        println!("replay report saved to {path}");
    }
    if let Some(path) = args.flag("timeline-csv") {
        std::fs::write(path, report.timeline_csv())
            .with_context(|| format!("writing {path}"))?;
        println!("timeline CSV saved to {path}");
    }
    Ok(())
}

/// `repro replay --hierarchy MiB` — Stage-III replay of one
/// configuration through the L1+L2 spill co-simulator
/// ([`trapti::banking::replay_hierarchy`]): the over-L1 excess lives in
/// L2, with migration traffic and L2 leakage charged on top of the
/// online SRAM energy. Capacities at or above the trace peak fall back
/// to the flat replay bit-identically.
fn replay_hierarchy_cmd(
    args: &Args,
    spec: &ExperimentSpec,
    cfg: OnlineConfig,
    hc: trapti::banking::HierarchyConfig,
) -> Result<()> {
    use std::fmt::Write as _;
    if matches!(spec.workload, Workload::Serving(_)) {
        bail!(
            "--hierarchy needs a materializable single-sequence trace; \
             serving workloads are not supported"
        );
    }
    let ctx = ApiContext::new();
    let run = spec.materialize(&ctx)?;
    let replay = trapti::banking::replay_hierarchy(
        &ctx.cacti,
        run.trace(),
        run.stats(),
        cfg,
        spec.freq_ghz(),
        true,
        Some(&hc),
    )?;
    let report = &replay.report;
    let label = trapti::api::optimize::workload_label(spec);
    let mut text = String::new();
    let _ = writeln!(
        text,
        "Stage III online replay (L1 + {} MiB L2 spill): {label} @ {}",
        hc.l2_capacity / MIB,
        report.config.label(),
    );
    match &replay.l2 {
        Some(l2) => {
            let _ = writeln!(
                text,
                "spill: peak excess {:.2} MiB, migrated {:.2} MiB \
                 (E_migrate {:.6} J @ {:.3e} J/B), L2 resident {} cycles \
                 (E_l2_leak {:.6} J)",
                l2.spilled_peak_bytes as f64 / MIB as f64,
                l2.migrate_bytes as f64 / MIB as f64,
                l2.e_migrate_j,
                hc.migrate_energy_per_byte_j,
                l2.l2_resident_cycles,
                l2.e_l2_leak_j,
            );
        }
        None => {
            let _ = writeln!(
                text,
                "no spill: L1 capacity covers the trace peak (flat \
                 replay, bit-identical to the non-hierarchy path)"
            );
        }
    }
    let _ = writeln!(
        text,
        "trace {} cycles; stalls +{} cycles ({:.4}%) over {} wake event(s)",
        report.trace_cycles,
        report.stall_cycles,
        report.stall_pct(),
        report.wake_events,
    );
    let l2_e = replay.l2.as_ref().map(|l| l.e_total_j()).unwrap_or(0.0);
    let _ = writeln!(
        text,
        "energy online {:.6} J total (SRAM {:.6} + L2 charge {:.6})",
        replay.e_total_j(),
        report.e_total_j(),
        l2_e,
    );
    text.push_str(&tables::online_bank_table(report).render());
    print!("{text}");
    if let Some(path) = args.flag("report-out") {
        std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
        println!("replay report saved to {path}");
    }
    if let Some(path) = args.flag("timeline-csv") {
        std::fs::write(path, report.timeline_csv())
            .with_context(|| format!("writing {path}"))?;
        println!("timeline CSV saved to {path}");
    }
    Ok(())
}

/// `repro watch` — tail a WAL directory (written by `simulate`/`serve`/
/// `replay --wal-out`, or the lab executor's `.wal/` tree) and render
/// live run progress. Because the log is append-only with a
/// torn-tail-tolerant reader, every poll is a consistent snapshot that
/// refines the previous one; the watcher exits once the `RunEnd` record
/// lands. Can be started before the run: a missing directory renders as
/// a waiting line, not an error.
fn watch_cmd(args: &Args) -> Result<()> {
    let dir = args
        .flag("wal")
        .ok_or_else(|| anyhow!("watch needs --wal DIR (from --wal-out)"))?;
    let dir = Path::new(dir);
    let once = args.bool_flag("once")?;
    let interval: u64 = args.flag_or("interval-ms", "500").parse()?;
    loop {
        let view = WatchView::load(dir)?;
        print!("{}", view.render());
        if let (Some(path), Some(snap)) = (args.flag("metrics-out"), &view.snapshot) {
            snap.write_atomic(Path::new(path))?;
        }
        if once || view.complete() {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(interval.max(1)));
        println!();
    }
}

/// `repro bench check --baseline FILE ARTIFACT.json..` — compare each
/// `BENCH_*.json` artifact (emitted by the bench targets) against the
/// committed baseline's entry of the same `name`. Rules are generous
/// `max_<field>` / `min_<field>` numeric bounds
/// ([`trapti::util::bench::baseline_violations`]); an artifact whose
/// name has no baseline entry is a failure too, so new benches must be
/// enrolled in the trajectory.
fn bench_cmd(args: &Args) -> Result<()> {
    use trapti::util::bench::baseline_violations;
    use trapti::util::json;

    let sub = args
        .positional
        .get(1)
        .map(String::as_str)
        .ok_or_else(|| anyhow!("bench needs a subcommand (try `bench check`)"))?;
    if sub != "check" {
        bail!("unknown bench subcommand `{sub}` (try `bench check`)");
    }
    let baseline_path = args
        .flag("baseline")
        .ok_or_else(|| anyhow!("bench check needs --baseline FILE"))?;
    let artifacts = &args.positional[2..];
    if artifacts.is_empty() {
        bail!("bench check needs at least one BENCH_*.json artifact path");
    }
    let baseline = json::parse(
        &std::fs::read_to_string(baseline_path)
            .with_context(|| format!("reading baseline {baseline_path}"))?,
    )
    .with_context(|| format!("parsing baseline {baseline_path}"))?;

    let mut failures = 0usize;
    for path in artifacts {
        let artifact = json::parse(
            &std::fs::read_to_string(path)
                .with_context(|| format!("reading artifact {path}"))?,
        )
        .with_context(|| format!("parsing artifact {path}"))?;
        let name = artifact
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("{path}: artifact has no `name` field"))?;
        let violations = match baseline.get(name) {
            None => vec![format!("no baseline entry for `{name}`")],
            Some(rules) => baseline_violations(&artifact, rules),
        };
        if violations.is_empty() {
            println!("OK   {name} ({path})");
        } else {
            failures += 1;
            println!("FAIL {name} ({path})");
            for v in &violations {
                println!("     {v}");
            }
        }
    }
    if failures > 0 {
        bail!("bench check: {failures} artifact(s) violate the baseline");
    }
    Ok(())
}

fn e2e_cmd(args: &Args) -> Result<()> {
    let model = args.flag_or("model", "tiny-gqa");
    let steps: usize = args.flag_or("steps", "64").parse()?;
    let manifest = Manifest::load(&default_artifact_dir())?;
    let mut rt = Runtime::new(manifest)?;
    println!("PJRT platform: {}", rt.platform());
    let mut sess = DecodeSession::new(&mut rt, &model, 42)?;
    let t0 = std::time::Instant::now();
    let mags = sess.generate(&mut rt, steps, 7)?;
    let dt = t0.elapsed();
    println!(
        "{model}: generated {steps} tokens in {:.1} ms ({:.2} ms/token)",
        dt.as_secs_f64() * 1e3,
        dt.as_secs_f64() * 1e3 / steps as f64
    );
    println!(
        "activation magnitude curve: first={:.3} mid={:.3} last={:.3}",
        mags[0],
        mags[steps / 2],
        mags[steps - 1]
    );
    Ok(())
}

/// Policy-sensitivity ablation (paper future work): compare gating
/// policies and alphas on both workloads' traces at 128 MiB / B=8.
fn ablate() -> Result<()> {
    let ctx = ApiContext::new();
    let pair = exp::paired_prefill(&ctx)?;
    let policies = [
        GatingPolicy::None,
        GatingPolicy::Aggressive,
        GatingPolicy::conservative(),
        GatingPolicy::drowsy(),
    ];
    println!(
        "{:>10} {:>13} {:>6} {:>11} {:>10} {:>10} {:>9} {:>9}",
        "workload", "policy", "alpha", "E_total[J]", "E_leak[J]", "E_sw[mJ]",
        "gated%", "switches"
    );
    for (label, s1) in [("gpt2-xl", &pair.mha), ("ds-r1d", &pair.gqa)] {
        for policy in policies {
            for alpha in [1.0, 0.9, 0.75] {
                let ev = evaluate(
                    &ctx.cacti,
                    s1.trace(),
                    &s1.result.stats,
                    128 * MIB,
                    8,
                    alpha,
                    policy,
                    1.0,
                )?;
                println!(
                    "{label:>10} {:>13} {alpha:>6} {:>11.2} {:>10.2} {:>10.3} {:>8.1}% {:>9}",
                    policy.label(),
                    ev.e_total_j(),
                    ev.e_leak_j,
                    ev.e_sw_j * 1e3,
                    ev.gated_fraction * 100.0,
                    ev.n_switch,
                );
            }
        }
    }
    println!(
        "
Full power gating wins when idle intervals clear break-even;
         drowsy retention recovers most of the saving with single-cycle
         wake-up (latency-critical designs); conservative trades a small
         energy give-back for fewer transitions."
    );
    Ok(())
}

fn baseline_compare() -> Result<()> {
    let ctx = ApiContext::new();
    let pair = exp::paired_prefill(&ctx)?;
    println!(
        "{:>10} {:>8} {:>5} {:>14} {:>14} {:>8}",
        "workload", "C[MiB]", "B", "TRAPTI E_lk[J]", "aggreg E_lk[J]", "saving"
    );
    for (label, s1) in [("gpt2-xl", &pair.mha), ("ds-r1d", &pair.gqa)] {
        let trace = s1.trace();
        let cap = 128 * MIB;
        for b in [4u32, 8, 16] {
            let trapti_ev = evaluate(
                &ctx.cacti, trace, &s1.result.stats, cap, b, 0.9,
                GatingPolicy::Aggressive, 1.0,
            )?;
            let view = analytic::AggregateView::from_stats(
                trace.peak_needed(),
                s1.result.total_cycles,
                &s1.result.stats,
            );
            let agg = analytic::estimate(&ctx.cacti, &view, cap, b, 0.9, 1.0);
            println!(
                "{label:>10} {:>8} {b:>5} {:>14.2} {:>14.2} {:>7.0}%",
                cap / MIB,
                trapti_ev.e_leak_j,
                agg.e_leak_j,
                (1.0 - trapti_ev.e_leak_j / agg.e_leak_j) * 100.0
            );
        }
    }
    println!(
        "\nThe aggregate (Timeloop/MAESTRO-class) flow sees only peak capacity\n\
         and total access counts, so it must keep peak-occupancy banks on for\n\
         the whole run; TRAPTI's time-resolved trace licenses gating the\n\
         idle intervals — the saving column is the paper's core motivation."
    );
    Ok(())
}
