//! Accelerator-level on-chip energy accounting (paper Fig. 7).
//!
//! Total on-chip energy = PE dynamic (per-MAC) + PE/FIFO static leakage
//! over the run + SRAM dynamic + SRAM leakage (unbanked/ungated baseline
//! — Stage II's optimizations are reported separately). Coefficients are
//! 45 nm itrs-hp class, calibrated so the two Fig. 7 anchors
//! (GPT-2 XL: 78.47 J @ 38% util; DS-R1D: 40.52 J @ 77% util) are
//! reproduced from this simulator's Stage-I outputs.

use crate::cacti::CactiModel;
use crate::config::AccelConfig;
use crate::sim::SimResult;

/// Energy coefficients for the compute subsystem.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyParams {
    /// Energy per 8-bit MAC, joules (45 nm HP class).
    pub e_mac_j: f64,
    /// Static power of one PE (MAC + local registers + clocking), watts.
    pub pe_static_w: f64,
    /// Static power of one FIFO lane-entry block, watts (row+col stacks).
    pub fifo_static_w_per_kib: f64,
    /// DRAM access energy per byte, joules.
    pub e_dram_j_per_byte: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self {
            e_mac_j: 0.4e-12,
            pe_static_w: 120e-6,
            fifo_static_w_per_kib: 8e-6,
            e_dram_j_per_byte: 20e-12,
        }
    }
}

/// Fig. 7 breakdown for one simulated run.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyBreakdown {
    pub pe_dynamic_j: f64,
    pub pe_static_j: f64,
    pub fifo_static_j: f64,
    pub sram_dynamic_j: f64,
    pub sram_leakage_j: f64,
    pub dram_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.pe_dynamic_j
            + self.pe_static_j
            + self.fifo_static_j
            + self.sram_dynamic_j
            + self.sram_leakage_j
            + self.dram_j
    }

    pub fn on_chip_j(&self) -> f64 {
        self.total_j() - self.dram_j
    }
}

/// Compute the Fig. 7 energy breakdown from a Stage-I result. SRAM terms
/// use the *baseline* organization (B=1, no gating) — the paper's Fig. 7
/// is measured before Stage-II optimization.
pub fn energy_breakdown(
    result: &SimResult,
    cfg: &AccelConfig,
    cacti: &CactiModel,
    params: &EnergyParams,
) -> EnergyBreakdown {
    let seconds = result.seconds();

    let pe_count =
        cfg.sa.rows as f64 * cfg.sa.cols as f64 * cfg.sa.count as f64;
    let pe_dynamic = result.total_macs as f64 * params.e_mac_j;
    let pe_static = pe_count * params.pe_static_w * seconds;

    // FIFO capacity: per SA, row + col stacks of lanes x depth bytes.
    let fifo_kib = cfg.sa.count as f64
        * 2.0
        * (cfg.fifo.lanes as f64 * cfg.fifo.depth as f64)
        / 1024.0;
    let fifo_static = fifo_kib * params.fifo_static_w_per_kib * seconds;

    // SRAM terms summed over the on-chip memories at their configured
    // capacities, unbanked and ungated.
    let mut sram_dyn = 0.0;
    let mut sram_leak = 0.0;
    for (mem_cfg, stats) in cfg.on_chip.iter().zip(&result.per_mem_stats) {
        let ch = cacti.characterize(mem_cfg.capacity, 1);
        sram_dyn += stats.reads as f64 * ch.e_read_j + stats.writes as f64 * ch.e_write_j;
        sram_leak += ch.p_leak_bank_w * seconds;
    }

    let dram = (result.stats.dram_read_bytes + result.stats.dram_write_bytes) as f64
        * params.e_dram_j_per_byte;

    EnergyBreakdown {
        pe_dynamic_j: pe_dynamic,
        pe_static_j: pe_static,
        fifo_static_j: fifo_static,
        sram_dynamic_j: sram_dyn,
        sram_leakage_j: sram_leak,
        dram_j: dram,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::tiny;
    use crate::sim::simulate;
    use crate::workload::{build_prefill, TINY_GQA};

    #[test]
    fn breakdown_positive_and_consistent() {
        let g = build_prefill(&TINY_GQA, 64).unwrap();
        let cfg = tiny();
        let r = simulate(&g, &cfg).unwrap();
        let e = energy_breakdown(&r, &cfg, &CactiModel::default(), &EnergyParams::default());
        assert!(e.pe_dynamic_j > 0.0);
        assert!(e.pe_static_j > 0.0);
        assert!(e.sram_dynamic_j > 0.0);
        assert!(e.sram_leakage_j > 0.0);
        assert!(e.dram_j > 0.0);
        assert!((e.on_chip_j() - (e.total_j() - e.dram_j)).abs() < 1e-15);
    }

    #[test]
    fn static_energy_scales_with_time() {
        let cfg = tiny();
        let g1 = build_prefill(&TINY_GQA, 32).unwrap();
        let g2 = build_prefill(&TINY_GQA, 128).unwrap();
        let r1 = simulate(&g1, &cfg).unwrap();
        let r2 = simulate(&g2, &cfg).unwrap();
        let p = EnergyParams::default();
        let c = CactiModel::default();
        let e1 = energy_breakdown(&r1, &cfg, &c, &p);
        let e2 = energy_breakdown(&r2, &cfg, &c, &p);
        assert!(e2.pe_static_j > e1.pe_static_j);
        assert!(e2.sram_leakage_j > e1.sram_leakage_j);
    }

    #[test]
    fn full_scale_static_power_magnitude() {
        // 4 x 128x128 PEs at 120 uW ~= 7.9 W; + SRAM leak ~34 W at
        // 128 MiB: the Fig. 7 scale (tens of joules over ~0.5 s) checks.
        let p = EnergyParams::default();
        let pe_w = 4.0 * 128.0 * 128.0 * p.pe_static_w;
        assert!(pe_w > 5.0 && pe_w < 12.0, "{pe_w}");
    }
}
