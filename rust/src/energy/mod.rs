//! Accelerator-level energy accounting (Fig. 7 breakdowns).

pub mod accel;

pub use accel::{energy_breakdown, EnergyBreakdown, EnergyParams};
