//! Paged KV-cache arena: fixed-size pages per stream, allocated as
//! contexts grow and freed wholesale at completion.
//!
//! The arena is what turns many interleaved KV caches into the paper's
//! needed/obsolete occupancy split (see the [`super`] module docs):
//! *needed* is the live KV bytes of every active stream, *obsolete* is
//! page-internal fragmentation — bytes the allocator holds but no stream
//! needs, evictable for free exactly like the single-sequence trace's
//! obsolete tensors.

use anyhow::{bail, ensure, Result};

/// Per-stream allocation state.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct StreamAlloc {
    pages: u64,
    live_bytes: u64,
}

/// Fixed-page KV allocator shared by all active streams.
#[derive(Debug, Clone)]
pub struct PagedKvArena {
    page_bytes: u64,
    capacity_pages: u64,
    allocated_pages: u64,
    needed_bytes: u64,
    /// Pages pinned by [`PagedKvArena::reserve_shared`] for run-lifetime
    /// state (shared system-prompt prefix KV); counted in
    /// `allocated_pages` but owned by no stream and never freed.
    shared_pages: u64,
    shared_bytes: u64,
    /// `(stream id, alloc)` — sorted by id; streams are few (≤
    /// concurrency cap), so linear search beats hashing and stays
    /// deterministic.
    streams: Vec<(u32, StreamAlloc)>,
}

impl PagedKvArena {
    /// `capacity_bytes` rounds *down* to whole pages.
    pub fn new(page_bytes: u64, capacity_bytes: u64) -> Self {
        assert!(page_bytes > 0, "page_bytes must be > 0");
        Self {
            page_bytes,
            capacity_pages: capacity_bytes / page_bytes,
            allocated_pages: 0,
            needed_bytes: 0,
            shared_pages: 0,
            shared_bytes: 0,
            streams: Vec::new(),
        }
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    /// Whole-page capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_pages * self.page_bytes
    }

    /// Register a new stream with no pages yet.
    pub fn admit(&mut self, id: u32) -> Result<()> {
        ensure!(
            self.index_of(id).is_none(),
            "stream {id} already resident in the arena"
        );
        let at = self.streams.partition_point(|&(sid, _)| sid < id);
        self.streams.insert(at, (id, StreamAlloc::default()));
        Ok(())
    }

    /// Grow a stream's live KV by `bytes`, allocating pages on demand.
    /// Fails (leaving state unchanged) when the arena is out of pages.
    pub fn grow(&mut self, id: u32, bytes: u64) -> Result<()> {
        let page = self.page_bytes;
        let free = self.capacity_pages - self.allocated_pages;
        let Some(i) = self.index_of(id) else {
            bail!("stream {id} not resident in the arena");
        };
        let s = &mut self.streams[i].1;
        let new_live = s.live_bytes + bytes;
        let need_pages = new_live.div_ceil(page);
        let extra = need_pages.saturating_sub(s.pages);
        ensure!(
            extra <= free,
            "arena exhausted: stream {id} needs {extra} page(s), {free} free"
        );
        s.live_bytes = new_live;
        s.pages = need_pages;
        self.allocated_pages += extra;
        self.needed_bytes += bytes;
        Ok(())
    }

    /// Free every page of a completed stream.
    pub fn release(&mut self, id: u32) -> Result<()> {
        self.evict(id).map(|_| ())
    }

    /// Preempt a resident stream: free all of its pages and return its
    /// live byte count so the scheduler can spill the KV to DRAM and
    /// later [`PagedKvArena::restore`] it. A stream is either resident
    /// or gone — evicting a non-resident id fails, so pages cannot
    /// double-free across an evict/restore cycle.
    pub fn evict(&mut self, id: u32) -> Result<u64> {
        let Some(i) = self.index_of(id) else {
            bail!("stream {id} not resident in the arena");
        };
        let (_, s) = self.streams.remove(i);
        self.allocated_pages -= s.pages;
        self.needed_bytes -= s.live_bytes;
        Ok(s.live_bytes)
    }

    /// Re-admit an evicted stream and re-materialize `live_bytes` of KV
    /// in one step (the DRAM→SRAM restore). Atomic: when the arena lacks
    /// pages, the stream is left non-resident and state is unchanged.
    pub fn restore(&mut self, id: u32, live_bytes: u64) -> Result<()> {
        self.admit(id)?;
        if let Err(e) = self.grow(id, live_bytes) {
            let i = self.index_of(id).expect("just admitted");
            self.streams.remove(i);
            return Err(e);
        }
        Ok(())
    }

    /// Pin pages for run-lifetime shared state (the system-prompt prefix
    /// KV): allocated and needed like a stream's pages, but owned by the
    /// run itself and never freed — the occupancy floor every sample
    /// sits on.
    pub fn reserve_shared(&mut self, bytes: u64) -> Result<()> {
        let pages = bytes.div_ceil(self.page_bytes);
        let free = self.capacity_pages - self.allocated_pages;
        ensure!(
            pages <= free,
            "arena exhausted: shared reservation needs {pages} page(s), {free} free"
        );
        self.allocated_pages += pages;
        self.needed_bytes += bytes;
        self.shared_pages += pages;
        self.shared_bytes += bytes;
        Ok(())
    }

    fn index_of(&self, id: u32) -> Option<usize> {
        self.streams
            .binary_search_by_key(&id, |&(sid, _)| sid)
            .ok()
    }

    /// Live KV bytes across all streams (the trace's *needed*).
    pub fn needed_bytes(&self) -> u64 {
        self.needed_bytes
    }

    /// Bytes held in allocated pages.
    pub fn allocated_bytes(&self) -> u64 {
        self.allocated_pages * self.page_bytes
    }

    /// Page-internal fragmentation (the trace's *obsolete*).
    pub fn obsolete_bytes(&self) -> u64 {
        self.allocated_bytes() - self.needed_bytes
    }

    pub fn active_streams(&self) -> usize {
        self.streams.len()
    }

    /// Bytes pinned by [`PagedKvArena::reserve_shared`] (included in
    /// [`PagedKvArena::needed_bytes`]).
    pub fn shared_bytes(&self) -> u64 {
        self.shared_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_grows_by_whole_pages() {
        let mut a = PagedKvArena::new(100, 1000);
        a.admit(0).unwrap();
        a.grow(0, 30).unwrap();
        assert_eq!(a.needed_bytes(), 30);
        assert_eq!(a.allocated_bytes(), 100);
        assert_eq!(a.obsolete_bytes(), 70);
        // Still inside page 1.
        a.grow(0, 70).unwrap();
        assert_eq!(a.allocated_bytes(), 100);
        assert_eq!(a.obsolete_bytes(), 0);
        // Crosses into page 2.
        a.grow(0, 1).unwrap();
        assert_eq!(a.allocated_bytes(), 200);
        assert_eq!(a.obsolete_bytes(), 99);
    }

    #[test]
    fn release_frees_everything() {
        let mut a = PagedKvArena::new(100, 1000);
        a.admit(3).unwrap();
        a.admit(7).unwrap();
        a.grow(3, 150).unwrap();
        a.grow(7, 250).unwrap();
        assert_eq!(a.active_streams(), 2);
        assert_eq!(a.allocated_bytes(), 500);
        a.release(3).unwrap();
        assert_eq!(a.active_streams(), 1);
        assert_eq!(a.allocated_bytes(), 300);
        assert_eq!(a.needed_bytes(), 250);
        a.release(7).unwrap();
        assert_eq!(a.allocated_bytes(), 0);
        assert_eq!(a.needed_bytes(), 0);
    }

    #[test]
    fn capacity_enforced_and_failure_is_atomic() {
        let mut a = PagedKvArena::new(100, 250); // 2 whole pages
        a.admit(0).unwrap();
        a.grow(0, 200).unwrap();
        let before = a.clone();
        assert!(a.grow(0, 1).is_err());
        assert_eq!(a.needed_bytes(), before.needed_bytes());
        assert_eq!(a.allocated_bytes(), before.allocated_bytes());
    }

    #[test]
    fn duplicate_admit_and_unknown_stream_rejected() {
        let mut a = PagedKvArena::new(100, 1000);
        a.admit(1).unwrap();
        assert!(a.admit(1).is_err());
        assert!(a.grow(2, 10).is_err());
        assert!(a.release(2).is_err());
    }

    #[test]
    fn evict_returns_live_bytes_and_restore_round_trips() {
        let mut a = PagedKvArena::new(100, 1000);
        a.admit(5).unwrap();
        a.grow(5, 230).unwrap();
        let live = a.evict(5).unwrap();
        assert_eq!(live, 230);
        assert_eq!(a.allocated_bytes(), 0);
        assert_eq!(a.needed_bytes(), 0);
        // Double eviction is an error, not a silent double-free.
        assert!(a.evict(5).is_err());
        a.restore(5, live).unwrap();
        assert_eq!(a.allocated_bytes(), 300);
        assert_eq!(a.needed_bytes(), 230);
        assert_eq!(a.active_streams(), 1);
    }

    #[test]
    fn restore_failure_is_atomic() {
        let mut a = PagedKvArena::new(100, 300);
        a.admit(0).unwrap();
        a.grow(0, 250).unwrap();
        // 0 pages free: a 100-byte restore cannot fit.
        assert!(a.restore(9, 100).is_err());
        assert_eq!(a.active_streams(), 1);
        assert!(a.grow(9, 1).is_err(), "failed restore must not leave 9 resident");
        assert_eq!(a.needed_bytes(), 250);
    }

    #[test]
    fn shared_reservation_sets_the_occupancy_floor() {
        let mut a = PagedKvArena::new(100, 1000);
        a.reserve_shared(150).unwrap();
        assert_eq!(a.shared_bytes(), 150);
        assert_eq!(a.allocated_bytes(), 200);
        assert_eq!(a.needed_bytes(), 150);
        assert_eq!(a.obsolete_bytes(), 50);
        // Streams allocate on top of the floor and release back to it.
        a.admit(0).unwrap();
        a.grow(0, 100).unwrap();
        assert_eq!(a.allocated_bytes(), 300);
        a.release(0).unwrap();
        assert_eq!(a.allocated_bytes(), 200);
        assert_eq!(a.needed_bytes(), 150);
        // The reservation is capacity-checked like everything else.
        assert!(a.reserve_shared(10_000).is_err());
    }
}
