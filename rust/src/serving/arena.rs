//! Paged KV-cache arena: fixed-size pages per stream, allocated as
//! contexts grow and freed wholesale at completion.
//!
//! The arena is what turns many interleaved KV caches into the paper's
//! needed/obsolete occupancy split (see the [`super`] module docs):
//! *needed* is the live KV bytes of every active stream, *obsolete* is
//! page-internal fragmentation — bytes the allocator holds but no stream
//! needs, evictable for free exactly like the single-sequence trace's
//! obsolete tensors.

use anyhow::{bail, ensure, Result};

/// Per-stream allocation state.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct StreamAlloc {
    pages: u64,
    live_bytes: u64,
}

/// Fixed-page KV allocator shared by all active streams.
#[derive(Debug, Clone)]
pub struct PagedKvArena {
    page_bytes: u64,
    capacity_pages: u64,
    allocated_pages: u64,
    needed_bytes: u64,
    /// `(stream id, alloc)` — sorted by id; streams are few (≤
    /// concurrency cap), so linear search beats hashing and stays
    /// deterministic.
    streams: Vec<(u32, StreamAlloc)>,
}

impl PagedKvArena {
    /// `capacity_bytes` rounds *down* to whole pages.
    pub fn new(page_bytes: u64, capacity_bytes: u64) -> Self {
        assert!(page_bytes > 0, "page_bytes must be > 0");
        Self {
            page_bytes,
            capacity_pages: capacity_bytes / page_bytes,
            allocated_pages: 0,
            needed_bytes: 0,
            streams: Vec::new(),
        }
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    /// Whole-page capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_pages * self.page_bytes
    }

    /// Register a new stream with no pages yet.
    pub fn admit(&mut self, id: u32) -> Result<()> {
        ensure!(
            self.index_of(id).is_none(),
            "stream {id} already resident in the arena"
        );
        let at = self.streams.partition_point(|&(sid, _)| sid < id);
        self.streams.insert(at, (id, StreamAlloc::default()));
        Ok(())
    }

    /// Grow a stream's live KV by `bytes`, allocating pages on demand.
    /// Fails (leaving state unchanged) when the arena is out of pages.
    pub fn grow(&mut self, id: u32, bytes: u64) -> Result<()> {
        let page = self.page_bytes;
        let free = self.capacity_pages - self.allocated_pages;
        let Some(i) = self.index_of(id) else {
            bail!("stream {id} not resident in the arena");
        };
        let s = &mut self.streams[i].1;
        let new_live = s.live_bytes + bytes;
        let need_pages = new_live.div_ceil(page);
        let extra = need_pages.saturating_sub(s.pages);
        ensure!(
            extra <= free,
            "arena exhausted: stream {id} needs {extra} page(s), {free} free"
        );
        s.live_bytes = new_live;
        s.pages = need_pages;
        self.allocated_pages += extra;
        self.needed_bytes += bytes;
        Ok(())
    }

    /// Free every page of a completed stream.
    pub fn release(&mut self, id: u32) -> Result<()> {
        let Some(i) = self.index_of(id) else {
            bail!("stream {id} not resident in the arena");
        };
        let (_, s) = self.streams.remove(i);
        self.allocated_pages -= s.pages;
        self.needed_bytes -= s.live_bytes;
        Ok(())
    }

    fn index_of(&self, id: u32) -> Option<usize> {
        self.streams
            .binary_search_by_key(&id, |&(sid, _)| sid)
            .ok()
    }

    /// Live KV bytes across all streams (the trace's *needed*).
    pub fn needed_bytes(&self) -> u64 {
        self.needed_bytes
    }

    /// Bytes held in allocated pages.
    pub fn allocated_bytes(&self) -> u64 {
        self.allocated_pages * self.page_bytes
    }

    /// Page-internal fragmentation (the trace's *obsolete*).
    pub fn obsolete_bytes(&self) -> u64 {
        self.allocated_bytes() - self.needed_bytes
    }

    pub fn active_streams(&self) -> usize {
        self.streams.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_grows_by_whole_pages() {
        let mut a = PagedKvArena::new(100, 1000);
        a.admit(0).unwrap();
        a.grow(0, 30).unwrap();
        assert_eq!(a.needed_bytes(), 30);
        assert_eq!(a.allocated_bytes(), 100);
        assert_eq!(a.obsolete_bytes(), 70);
        // Still inside page 1.
        a.grow(0, 70).unwrap();
        assert_eq!(a.allocated_bytes(), 100);
        assert_eq!(a.obsolete_bytes(), 0);
        // Crosses into page 2.
        a.grow(0, 1).unwrap();
        assert_eq!(a.allocated_bytes(), 200);
        assert_eq!(a.obsolete_bytes(), 99);
    }

    #[test]
    fn release_frees_everything() {
        let mut a = PagedKvArena::new(100, 1000);
        a.admit(3).unwrap();
        a.admit(7).unwrap();
        a.grow(3, 150).unwrap();
        a.grow(7, 250).unwrap();
        assert_eq!(a.active_streams(), 2);
        assert_eq!(a.allocated_bytes(), 500);
        a.release(3).unwrap();
        assert_eq!(a.active_streams(), 1);
        assert_eq!(a.allocated_bytes(), 300);
        assert_eq!(a.needed_bytes(), 250);
        a.release(7).unwrap();
        assert_eq!(a.allocated_bytes(), 0);
        assert_eq!(a.needed_bytes(), 0);
    }

    #[test]
    fn capacity_enforced_and_failure_is_atomic() {
        let mut a = PagedKvArena::new(100, 250); // 2 whole pages
        a.admit(0).unwrap();
        a.grow(0, 200).unwrap();
        let before = a.clone();
        assert!(a.grow(0, 1).is_err());
        assert_eq!(a.needed_bytes(), before.needed_bytes());
        assert_eq!(a.allocated_bytes(), before.allocated_bytes());
    }

    #[test]
    fn duplicate_admit_and_unknown_stream_rejected() {
        let mut a = PagedKvArena::new(100, 1000);
        a.admit(1).unwrap();
        assert!(a.admit(1).is_err());
        assert!(a.grow(2, 10).is_err());
        assert!(a.release(2).is_err());
    }
}
