//! Multi-tenant serving scenarios: deterministic request workloads over
//! a paged KV arena.
//!
//! The paper's Stage-I traces ramp one sequence at a time; a serving
//! system instead holds **many interleaved KV caches** with staggered
//! arrivals, steady-state plateaus at the concurrency cap, and churn as
//! completed requests free their memory. That is exactly the regime
//! where banked power gating behaves differently from single-sequence
//! ramps, and this module produces the occupancy timelines that let
//! Stage II answer the banking question for it.
//!
//! ## How paged-arena occupancy maps onto needed/obsolete
//!
//! The single-sequence trace splits resident bytes into *needed* (data
//! future ops still read) and *obsolete* (resident but dead — evictable
//! for free). The serving scenario reproduces that split from the
//! allocator's point of view:
//!
//! * **needed** = Σ over active streams of their live KV bytes. Every
//!   byte of a live context is read again on the stream's next decode
//!   step, so it pins SRAM banks on exactly like needed tensor data.
//! * **obsolete** = allocated-page bytes − needed bytes, i.e. the
//!   page-internal fragmentation of the paged allocator (tail pages are
//!   only partially filled until the context grows into them). Those
//!   bytes occupy banked capacity but carry no data anyone will read, so
//!   — like obsolete tensors — dropping them is free and they do not
//!   keep banks powered under the paper's `NeededOnly` gating basis.
//! * Completion frees a stream's pages wholesale: both components drop
//!   at once, producing the churn edges that give serving traces their
//!   characteristic sawtooth around the concurrency plateau.
//!
//! The stream of `(t, needed, obsolete)` changes feeds the exact same
//! [`crate::trace::OccupancyTrace::record`] /
//! [`crate::trace::TraceSink`] machinery as the cycle-level simulator,
//! so every Stage-II consumer (sweeps, policies, figure renderers) works
//! on serving traces unchanged.
//!
//! Entry points: [`ServingParams`] (pure data, embedded in
//! [`crate::workload::Workload::Serving`] and hashed/validated by
//! [`crate::api::ExperimentSpec`]), [`generate_requests`], and the
//! scheduler in [`crate::sim::serving`].
//!
//! ```
//! use trapti::api::{ApiContext, ExperimentSpec};
//! use trapti::serving::ServingParams;
//! use trapti::workload::TINY_GQA;
//!
//! // 8 requests over a paged KV arena, concurrency 4, seed 7 — then a
//! // Stage-II sweep on the merged occupancy trace.
//! let mut p = ServingParams::new(8, 4, 7);
//! p.prompt_min = 4;
//! p.prompt_max = 16;
//! p.gen_min = 2;
//! p.gen_max = 8;
//! p.page_tokens = 8;
//! p.mean_arrival_gap = 50_000;
//! let spec = ExperimentSpec::builder()
//!     .model(TINY_GQA)
//!     .serving(p)
//!     .accel(trapti::config::tiny())
//!     .build()
//!     .unwrap();
//! let run = spec.run_serving().unwrap();
//! assert_eq!(run.result.completed, 8);
//! let s2 = run.stage2(&ApiContext::new()).unwrap();
//! assert!(!s2.points.is_empty());
//! ```

pub mod arena;
pub mod workload;

pub use arena::PagedKvArena;
pub use workload::{generate_requests, Request, ServingParams, ServingParamsError};
