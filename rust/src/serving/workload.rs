//! Deterministic request-arrival workload generation for the serving
//! simulator.
//!
//! A [`ServingParams`] is pure data — all-integer so it stays `Copy`/`Eq`
//! and hashes stably into [`crate::api::ExperimentSpec::content_hash`].
//! [`generate_requests`] expands it into a concrete arrival schedule with
//! the crate's seeded PRNG: same params, same requests, bit-for-bit.

use anyhow::{ensure, Result};

use crate::util::rng::Rng;

/// Parameters of one multi-tenant serving scenario.
///
/// Inter-arrival gaps are uniform in `[0, 2 * mean_arrival_gap]` cycles
/// (mean `mean_arrival_gap`); prompt and generation lengths are uniform
/// in their inclusive ranges. `page_tokens` sets the KV page granularity
/// of the paged arena (see [`super::arena::PagedKvArena`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServingParams {
    /// Total requests in the workload.
    pub requests: u32,
    /// Continuous-batching concurrency cap (max simultaneous streams).
    pub concurrency: u32,
    /// Arrival/length RNG seed.
    pub seed: u64,
    /// Mean inter-arrival gap in cycles.
    pub mean_arrival_gap: u64,
    /// Prompt length range (tokens, inclusive).
    pub prompt_min: u32,
    pub prompt_max: u32,
    /// Generation length range (tokens, inclusive).
    pub gen_min: u32,
    pub gen_max: u32,
    /// KV page granularity in tokens.
    pub page_tokens: u32,
}

impl ServingParams {
    /// Defaults for the paper-shaped serving scenario: prompts 64–512,
    /// generations 16–128, 16-token pages, 1M-cycle mean arrival gap.
    pub fn new(requests: u32, concurrency: u32, seed: u64) -> Self {
        Self {
            requests,
            concurrency,
            seed,
            mean_arrival_gap: 1_000_000,
            prompt_min: 64,
            prompt_max: 512,
            gen_min: 16,
            gen_max: 128,
            page_tokens: 16,
        }
    }

    /// Longest possible per-stream context (prompt + generated tokens).
    pub fn max_stream_tokens(&self) -> u32 {
        self.prompt_max + self.gen_max
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.requests >= 1, "serving needs requests >= 1");
        ensure!(self.concurrency >= 1, "serving needs concurrency >= 1");
        ensure!(
            self.prompt_min <= self.prompt_max,
            "serving prompt range inverted: {}..{}",
            self.prompt_min,
            self.prompt_max
        );
        ensure!(
            self.gen_min >= 1,
            "serving needs gen_min >= 1 (got {})",
            self.gen_min
        );
        ensure!(
            self.gen_min <= self.gen_max,
            "serving gen range inverted: {}..{}",
            self.gen_min,
            self.gen_max
        );
        ensure!(self.page_tokens >= 1, "serving needs page_tokens >= 1");
        Ok(())
    }
}

/// One generated request of the serving workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub id: u32,
    /// Arrival time in cycles (non-decreasing across the schedule).
    pub arrival: u64,
    /// Prompt tokens whose KV materializes at admission.
    pub prompt: u32,
    /// Tokens to generate before the request completes.
    pub gen: u32,
}

/// Expand params into the concrete, deterministic arrival schedule.
pub fn generate_requests(p: &ServingParams) -> Vec<Request> {
    let mut rng = Rng::new(p.seed);
    let mut t = 0u64;
    (0..p.requests)
        .map(|id| {
            t += rng.below(2 * p.mean_arrival_gap + 1);
            Request {
                id,
                arrival: t,
                prompt: rng.range(p.prompt_min as u64, p.prompt_max as u64) as u32,
                gen: rng.range(p.gen_min as u64, p.gen_max as u64) as u32,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let p = ServingParams::new(32, 4, 7);
        assert_eq!(generate_requests(&p), generate_requests(&p));
        let mut p2 = p;
        p2.seed = 8;
        assert_ne!(generate_requests(&p), generate_requests(&p2));
    }

    #[test]
    fn arrivals_monotone_and_lengths_in_range() {
        let p = ServingParams::new(200, 8, 3);
        let reqs = generate_requests(&p);
        assert_eq!(reqs.len(), 200);
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for r in &reqs {
            assert!((p.prompt_min..=p.prompt_max).contains(&r.prompt));
            assert!((p.gen_min..=p.gen_max).contains(&r.gen));
        }
    }

    #[test]
    fn zero_gap_means_simultaneous_arrivals() {
        let mut p = ServingParams::new(8, 2, 1);
        p.mean_arrival_gap = 0;
        for r in generate_requests(&p) {
            assert_eq!(r.arrival, 0);
        }
    }

    #[test]
    fn validate_rejects_bad_params() {
        assert!(ServingParams::new(1, 1, 0).validate().is_ok());
        let mut p = ServingParams::new(0, 1, 0);
        assert!(p.validate().is_err());
        p = ServingParams::new(1, 0, 0);
        assert!(p.validate().is_err());
        p = ServingParams::new(1, 1, 0);
        p.gen_min = 0;
        assert!(p.validate().is_err());
        p = ServingParams::new(1, 1, 0);
        p.prompt_min = 10;
        p.prompt_max = 5;
        assert!(p.validate().is_err());
        p = ServingParams::new(1, 1, 0);
        p.page_tokens = 0;
        assert!(p.validate().is_err());
    }
}
