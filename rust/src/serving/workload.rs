//! Deterministic request-arrival workload generation for the serving
//! simulator.
//!
//! A [`ServingParams`] is pure data — all-integer so it stays `Copy`/`Eq`
//! and hashes stably into [`crate::api::ExperimentSpec::content_hash`].
//! [`generate_requests`] expands it into a concrete arrival schedule with
//! the crate's seeded PRNG: same params, same requests, bit-for-bit.
//!
//! ## Traffic model
//!
//! The base model is Poisson-ish: inter-arrival gaps uniform in
//! `[0, 2 * mean_arrival_gap]`, prompt/generation lengths uniform in
//! their inclusive ranges. Four orthogonal extensions widen it toward
//! production-shaped traffic, each **off by default** so legacy specs
//! keep their request schedules (and spec hashes) bit-for-bit:
//!
//! * **Bursty arrivals** (`burst_gap` > 0): a two-state MMPP-style
//!   process. The schedule alternates between a *calm* state using
//!   `mean_arrival_gap` and a *burst* state using the (much tighter)
//!   `burst_gap`; after each request the state flips with probability
//!   `1/dwell`, giving geometric dwell times of mean `burst_len` /
//!   `calm_len` requests.
//! * **Heavy-tailed lengths** (`len_tail_q8` > 0): bounded-Pareto prompt
//!   and generation lengths via octave-geometric integer sampling — from
//!   the range floor, each doubling of the length scale survives with
//!   probability `len_tail_q8/256`, then the length is uniform within
//!   the chosen octave. The tail index is `alpha = -log2(q8/256)`
//!   (`128` gives `alpha = 1`). All-integer: no `powf`, no libm,
//!   platform-stable.
//! * **Priority tiers** (`tiers` > 1): each request draws a uniform tier
//!   in `0..tiers` (lower = higher priority). The event engine preempts
//!   resident low-priority streams for waiting high-priority ones.
//! * **Multi-model tenancy** (`tenants` == 2): each request draws a
//!   uniform lane; lane 0 is the spec's model, lane 1 its paper
//!   counterpart ([`crate::workload::paper_counterpart`]), co-resident
//!   in one arena.
//!
//! `prefix_tokens` (shared system-prompt KV) does not alter generation;
//! it reserves arena pages for the whole run (see [`crate::sim::serving`]).
//!
//! RNG draw order per request is part of the deterministic contract:
//! gap, optional burst-dwell flip, prompt, gen, optional tier, optional
//! lane. Disabled extensions draw nothing, which is what keeps legacy
//! schedules unchanged.

use std::fmt;

use crate::util::rng::Rng;

/// Parameters of one multi-tenant serving scenario.
///
/// Inter-arrival gaps are uniform in `[0, 2 * mean_arrival_gap]` cycles
/// (mean `mean_arrival_gap`); prompt and generation lengths are uniform
/// in their inclusive ranges unless the heavy-tail knob is set (see the
/// [module docs](self)). `page_tokens` sets the KV page granularity
/// of the paged arena (see [`super::arena::PagedKvArena`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServingParams {
    /// Total requests in the workload.
    pub requests: u32,
    /// Continuous-batching concurrency cap (max simultaneous streams).
    pub concurrency: u32,
    /// Arrival/length RNG seed.
    pub seed: u64,
    /// Mean inter-arrival gap in cycles (the *calm* state's gap when
    /// bursts are enabled).
    pub mean_arrival_gap: u64,
    /// Prompt length range (tokens, inclusive).
    pub prompt_min: u32,
    pub prompt_max: u32,
    /// Generation length range (tokens, inclusive).
    pub gen_min: u32,
    pub gen_max: u32,
    /// KV page granularity in tokens.
    pub page_tokens: u32,
    /// Mean inter-arrival gap in cycles during a burst; 0 disables the
    /// two-state burst process entirely.
    pub burst_gap: u64,
    /// Mean burst dwell in requests (geometric); required >= 1 when
    /// `burst_gap` > 0, must be 0 otherwise.
    pub burst_len: u32,
    /// Mean calm dwell in requests (geometric); same rules as
    /// `burst_len`.
    pub calm_len: u32,
    /// Heavy-tail knob: per-octave survival probability in Q8 fixed
    /// point (`q8/256`); 0 disables (uniform lengths), 255 max.
    pub len_tail_q8: u32,
    /// Priority tiers, lower = higher priority; 1 = no priorities.
    pub tiers: u32,
    /// Shared system-prompt prefix tokens, resident in the arena for the
    /// whole run; 0 disables.
    pub prefix_tokens: u32,
    /// Co-resident models sharing the arena: 1 = single-tenant, 2 adds
    /// the spec model's paper counterpart as lane 1.
    pub tenants: u32,
}

impl ServingParams {
    /// Defaults for the paper-shaped serving scenario: prompts 64–512,
    /// generations 16–128, 16-token pages, 1M-cycle mean arrival gap.
    /// Every traffic extension starts disabled, so defaulted params
    /// describe exactly the pre-extension workload.
    pub fn new(requests: u32, concurrency: u32, seed: u64) -> Self {
        Self {
            requests,
            concurrency,
            seed,
            mean_arrival_gap: 1_000_000,
            prompt_min: 64,
            prompt_max: 512,
            gen_min: 16,
            gen_max: 128,
            page_tokens: 16,
            burst_gap: 0,
            burst_len: 0,
            calm_len: 0,
            len_tail_q8: 0,
            tiers: 1,
            prefix_tokens: 0,
            tenants: 1,
        }
    }

    /// The `:bursty` traffic preset (lab descriptors, `repro serve`):
    /// heavy-tailed lengths riding a two-state burst process whose burst
    /// gaps are 20× tighter than the calm gap.
    pub fn with_bursty_traffic(mut self) -> Self {
        self.burst_gap = (self.mean_arrival_gap / 20).max(1);
        self.burst_len = 8;
        self.calm_len = 32;
        self.len_tail_q8 = 128;
        self
    }

    /// Longest possible per-stream context (prompt + generated tokens).
    pub fn max_stream_tokens(&self) -> u32 {
        self.prompt_max + self.gen_max
    }

    /// True when any post-v1 traffic field departs from its default.
    /// Gates the conditional spec-hash extension block
    /// ([`crate::api::ExperimentSpec::content_hash`]): defaulted params
    /// hash exactly like pre-extension specs.
    pub fn has_extensions(&self) -> bool {
        self.burst_gap != 0
            || self.burst_len != 0
            || self.calm_len != 0
            || self.len_tail_q8 != 0
            || self.tiers != 1
            || self.prefix_tokens != 0
            || self.tenants != 1
    }

    pub fn validate(&self) -> Result<(), ServingParamsError> {
        use ServingParamsError as E;
        if self.requests < 1 {
            return Err(E::ZeroRequests);
        }
        if self.concurrency < 1 {
            return Err(E::ZeroConcurrency);
        }
        if self.prompt_min > self.prompt_max {
            return Err(E::PromptRangeInverted {
                min: self.prompt_min,
                max: self.prompt_max,
            });
        }
        if self.gen_min < 1 {
            return Err(E::ZeroGenMin);
        }
        if self.gen_min > self.gen_max {
            return Err(E::GenRangeInverted {
                min: self.gen_min,
                max: self.gen_max,
            });
        }
        if self.page_tokens < 1 {
            return Err(E::ZeroPageTokens);
        }
        if self.burst_gap > 0 {
            if self.burst_len < 1 || self.calm_len < 1 {
                return Err(E::BurstDwellMissing);
            }
        } else if self.burst_len != 0 || self.calm_len != 0 {
            // One canonical encoding of "bursts off" keeps the spec hash
            // unambiguous.
            return Err(E::BurstDwellWithoutGap);
        }
        if self.len_tail_q8 > 255 {
            return Err(E::TailOutOfRange { q8: self.len_tail_q8 });
        }
        if self.len_tail_q8 > 0 && self.prompt_min < 1 {
            // The octave sampler needs a positive range floor.
            return Err(E::TailNeedsPositivePromptMin);
        }
        if self.tiers < 1 {
            return Err(E::ZeroTiers);
        }
        if !(1..=2).contains(&self.tenants) {
            return Err(E::BadTenants { tenants: self.tenants });
        }
        Ok(())
    }
}

/// Typed validation error for [`ServingParams`] — callers that build
/// degenerate specs (zero requests, zero concurrency, …) get a
/// matchable error from the builder instead of a downstream panic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServingParamsError {
    ZeroRequests,
    ZeroConcurrency,
    PromptRangeInverted { min: u32, max: u32 },
    ZeroGenMin,
    GenRangeInverted { min: u32, max: u32 },
    ZeroPageTokens,
    /// `burst_gap` > 0 without both dwell means.
    BurstDwellMissing,
    /// Dwell means set while `burst_gap` == 0.
    BurstDwellWithoutGap,
    TailOutOfRange { q8: u32 },
    TailNeedsPositivePromptMin,
    ZeroTiers,
    BadTenants { tenants: u32 },
}

impl fmt::Display for ServingParamsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use ServingParamsError as E;
        match *self {
            E::ZeroRequests => write!(f, "serving needs requests >= 1"),
            E::ZeroConcurrency => write!(f, "serving needs concurrency >= 1"),
            E::PromptRangeInverted { min, max } => {
                write!(f, "serving prompt range inverted: {min}..{max}")
            }
            E::ZeroGenMin => write!(f, "serving needs gen_min >= 1 (got 0)"),
            E::GenRangeInverted { min, max } => {
                write!(f, "serving gen range inverted: {min}..{max}")
            }
            E::ZeroPageTokens => write!(f, "serving needs page_tokens >= 1"),
            E::BurstDwellMissing => write!(
                f,
                "burst_gap > 0 needs burst_len >= 1 and calm_len >= 1"
            ),
            E::BurstDwellWithoutGap => write!(
                f,
                "burst_len/calm_len set while burst_gap == 0 (bursts off \
                 must leave the dwells 0)"
            ),
            E::TailOutOfRange { q8 } => {
                write!(f, "len_tail_q8 {q8} out of range (0..=255)")
            }
            E::TailNeedsPositivePromptMin => write!(
                f,
                "len_tail_q8 > 0 needs prompt_min >= 1 (octave sampler floor)"
            ),
            E::ZeroTiers => write!(f, "serving needs tiers >= 1"),
            E::BadTenants { tenants } => write!(
                f,
                "serving tenants must be 1 or 2 (got {tenants})"
            ),
        }
    }
}

impl std::error::Error for ServingParamsError {}

/// One generated request of the serving workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub id: u32,
    /// Arrival time in cycles (non-decreasing across the schedule).
    pub arrival: u64,
    /// Prompt tokens whose KV materializes at admission.
    pub prompt: u32,
    /// Tokens to generate before the request completes.
    pub gen: u32,
    /// Priority tier, `0..tiers` (lower = higher priority; always 0
    /// when tiers are disabled).
    pub tier: u32,
    /// Model lane, `0..tenants` (always 0 when single-tenant).
    pub lane: u32,
}

/// Bounded-Pareto length via octave-geometric escalation (see the
/// [module docs](self)). With `tail_q8 == 0` this is *exactly* the
/// legacy uniform draw — one `range` call, nothing else — so disabled
/// tails leave the RNG stream untouched.
fn sample_len(rng: &mut Rng, min: u32, max: u32, tail_q8: u32) -> u32 {
    if tail_q8 == 0 {
        return rng.range(min as u64, max as u64) as u32;
    }
    let hi = max as u64;
    let mut o_lo = min as u64; // validate(): >= 1 when tails are on
    if hi <= o_lo {
        return max;
    }
    loop {
        let next = o_lo * 2;
        if next > hi || rng.below(256) >= tail_q8 as u64 {
            break;
        }
        o_lo = next;
    }
    let o_hi = (o_lo * 2 - 1).min(hi);
    rng.range(o_lo, o_hi) as u32
}

/// Expand params into the concrete, deterministic arrival schedule.
pub fn generate_requests(p: &ServingParams) -> Vec<Request> {
    let mut rng = Rng::new(p.seed);
    let mut t = 0u64;
    let mut in_burst = false;
    (0..p.requests)
        .map(|id| {
            let gap = if p.burst_gap > 0 && in_burst {
                p.burst_gap
            } else {
                p.mean_arrival_gap
            };
            t += rng.below(2 * gap + 1);
            if p.burst_gap > 0 {
                // Geometric dwell: flip states with probability 1/dwell.
                let dwell = if in_burst { p.burst_len } else { p.calm_len };
                if rng.below(dwell as u64) == 0 {
                    in_burst = !in_burst;
                }
            }
            let prompt =
                sample_len(&mut rng, p.prompt_min, p.prompt_max, p.len_tail_q8);
            let gen = sample_len(&mut rng, p.gen_min, p.gen_max, p.len_tail_q8);
            let tier = if p.tiers > 1 { rng.below(p.tiers as u64) as u32 } else { 0 };
            let lane =
                if p.tenants > 1 { rng.below(p.tenants as u64) as u32 } else { 0 };
            Request { id, arrival: t, prompt, gen, tier, lane }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let p = ServingParams::new(32, 4, 7);
        assert_eq!(generate_requests(&p), generate_requests(&p));
        let mut p2 = p;
        p2.seed = 8;
        assert_ne!(generate_requests(&p), generate_requests(&p2));
    }

    #[test]
    fn arrivals_monotone_and_lengths_in_range() {
        let p = ServingParams::new(200, 8, 3);
        let reqs = generate_requests(&p);
        assert_eq!(reqs.len(), 200);
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for r in &reqs {
            assert!((p.prompt_min..=p.prompt_max).contains(&r.prompt));
            assert!((p.gen_min..=p.gen_max).contains(&r.gen));
            assert_eq!(r.tier, 0);
            assert_eq!(r.lane, 0);
        }
    }

    #[test]
    fn zero_gap_means_simultaneous_arrivals() {
        let mut p = ServingParams::new(8, 2, 1);
        p.mean_arrival_gap = 0;
        for r in generate_requests(&p) {
            assert_eq!(r.arrival, 0);
        }
    }

    #[test]
    fn disabled_extensions_leave_the_legacy_schedule_untouched() {
        // Explicitly-defaulted extension fields draw nothing from the
        // RNG: the schedule is bit-identical to a params value that
        // never heard of them.
        let p = ServingParams::new(64, 8, 11);
        let mut q = p;
        q.burst_gap = 0;
        q.len_tail_q8 = 0;
        q.tiers = 1;
        q.tenants = 1;
        assert_eq!(generate_requests(&p), generate_requests(&q));
        assert!(!p.has_extensions());
    }

    #[test]
    fn bursty_arrivals_tighten_gaps_and_stay_monotone() {
        let base = ServingParams::new(400, 8, 5);
        let bursty = base.with_bursty_traffic();
        assert!(bursty.has_extensions());
        bursty.validate().unwrap();
        let calm_reqs = generate_requests(&base);
        let burst_reqs = generate_requests(&bursty);
        for w in burst_reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        // Bursts compress the schedule: same request count arrives in
        // (much) less total time.
        assert!(
            burst_reqs.last().unwrap().arrival < calm_reqs.last().unwrap().arrival
        );
    }

    #[test]
    fn heavy_tail_lengths_stay_bounded_and_skew_low() {
        let mut p = ServingParams::new(2000, 8, 9);
        p.len_tail_q8 = 128; // alpha = 1
        let reqs = generate_requests(&p);
        let mut below_midpoint = 0usize;
        for r in &reqs {
            assert!((p.prompt_min..=p.prompt_max).contains(&r.prompt));
            assert!((p.gen_min..=p.gen_max).contains(&r.gen));
            if r.prompt < p.prompt_min.midpoint(p.prompt_max) {
                below_midpoint += 1;
            }
        }
        // Heavy tail = most mass near the floor, a long upper tail.
        assert!(
            below_midpoint * 3 > reqs.len() * 2,
            "expected >2/3 of prompts below the midpoint, got {below_midpoint}/{}",
            reqs.len()
        );
        assert!(reqs.iter().any(|r| r.prompt > p.prompt_max / 2), "no tail");
    }

    #[test]
    fn tiers_and_lanes_draw_in_range() {
        let mut p = ServingParams::new(300, 8, 2);
        p.tiers = 3;
        p.tenants = 2;
        let reqs = generate_requests(&p);
        assert!(reqs.iter().all(|r| r.tier < 3 && r.lane < 2));
        // All values actually occur.
        for tier in 0..3 {
            assert!(reqs.iter().any(|r| r.tier == tier), "tier {tier} never drawn");
        }
        for lane in 0..2 {
            assert!(reqs.iter().any(|r| r.lane == lane), "lane {lane} never drawn");
        }
    }

    #[test]
    fn validate_rejects_bad_params() {
        assert!(ServingParams::new(1, 1, 0).validate().is_ok());
        let mut p = ServingParams::new(0, 1, 0);
        assert_eq!(p.validate(), Err(ServingParamsError::ZeroRequests));
        p = ServingParams::new(1, 0, 0);
        assert_eq!(p.validate(), Err(ServingParamsError::ZeroConcurrency));
        p = ServingParams::new(1, 1, 0);
        p.gen_min = 0;
        assert!(p.validate().is_err());
        p = ServingParams::new(1, 1, 0);
        p.prompt_min = 10;
        p.prompt_max = 5;
        assert!(p.validate().is_err());
        p = ServingParams::new(1, 1, 0);
        p.page_tokens = 0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_extensions() {
        let mut p = ServingParams::new(4, 2, 0);
        p.burst_gap = 100;
        assert_eq!(p.validate(), Err(ServingParamsError::BurstDwellMissing));
        p.burst_len = 4;
        p.calm_len = 8;
        assert!(p.validate().is_ok());

        let mut p = ServingParams::new(4, 2, 0);
        p.burst_len = 4; // dwell without a gap: ambiguous encoding
        assert_eq!(p.validate(), Err(ServingParamsError::BurstDwellWithoutGap));

        let mut p = ServingParams::new(4, 2, 0);
        p.len_tail_q8 = 256;
        assert!(matches!(
            p.validate(),
            Err(ServingParamsError::TailOutOfRange { q8: 256 })
        ));
        p.len_tail_q8 = 128;
        p.prompt_min = 0;
        assert_eq!(
            p.validate(),
            Err(ServingParamsError::TailNeedsPositivePromptMin)
        );

        let mut p = ServingParams::new(4, 2, 0);
        p.tiers = 0;
        assert_eq!(p.validate(), Err(ServingParamsError::ZeroTiers));

        let mut p = ServingParams::new(4, 2, 0);
        p.tenants = 3;
        assert_eq!(
            p.validate(),
            Err(ServingParamsError::BadTenants { tenants: 3 })
        );
    }
}
