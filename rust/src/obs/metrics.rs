//! Prometheus-text-format export folded from an event log.
//!
//! [`MetricsSnapshot::from_log`] is a pure fold over [`EventLog`]
//! records — no live counters, no sampling window — so a metrics file
//! is always consistent with *some* prefix of the run, and two
//! identical runs render byte-identical text (fixed metric order,
//! memories in announcement order, integer values). The file is
//! written atomically (tmp + rename) so a scraper never sees a torn
//! exposition.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use super::event::ObsEvent;
use super::wal::EventLog;
use super::ObsError;

/// Per-memory occupancy counters (announcement order).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MemoryMetrics {
    pub name: String,
    pub capacity: u64,
    /// Occupancy (needed + obsolete) at the last observed sample.
    pub current_occupied: u64,
    pub current_needed: u64,
    /// Peak observed occupancy so far.
    pub peak_occupied: u64,
    pub peak_needed: u64,
    pub samples: u64,
}

/// All counters derivable from one log read. Construct with
/// [`MetricsSnapshot::from_log`], render with
/// [`MetricsSnapshot::render`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub run_id: u64,
    pub events_total: u64,
    /// Highest simulation time observed (envelope stamps / `RunEnd`).
    pub cycles: u64,
    pub complete: bool,
    pub truncated: bool,
    pub memories: Vec<MemoryMetrics>,
    pub stages_started: u64,
    pub stages_completed: u64,
    pub requests_admitted: u64,
    pub requests_completed: u64,
    pub requests_evicted: u64,
    pub requests_restored: u64,
    /// `(state label, span count, cycles)` per bank state, sorted by
    /// label for deterministic rendering.
    pub bank_states: Vec<(&'static str, u64, u64)>,
    pub wake_stalls: u64,
    pub wake_stall_cycles: u64,
}

impl MetricsSnapshot {
    /// Fold a log into counters.
    pub fn from_log(log: &EventLog) -> MetricsSnapshot {
        let mut m = MetricsSnapshot {
            run_id: log.run_id().unwrap_or(0),
            events_total: log.records.len() as u64,
            truncated: log.truncated,
            ..Default::default()
        };
        let mut bank_states: Vec<(&'static str, u64, u64)> = Vec::new();
        for rec in &log.records {
            m.cycles = m.cycles.max(rec.t);
            match rec.event {
                ObsEvent::RunStart { ref memories, .. } => {
                    m.memories = memories
                        .iter()
                        .map(|d| MemoryMetrics {
                            name: d.name.clone(),
                            capacity: d.capacity,
                            ..Default::default()
                        })
                        .collect();
                }
                ObsEvent::Sample { mem, needed, obsolete } => {
                    if let Some(mm) = m.memories.get_mut(mem as usize) {
                        mm.current_needed = needed;
                        mm.current_occupied = needed + obsolete;
                        mm.peak_needed = mm.peak_needed.max(needed);
                        mm.peak_occupied = mm.peak_occupied.max(needed + obsolete);
                        mm.samples += 1;
                    }
                }
                ObsEvent::StageStart { .. } => m.stages_started += 1,
                ObsEvent::StageEnd { .. } => m.stages_completed += 1,
                ObsEvent::Admit { .. } => m.requests_admitted += 1,
                ObsEvent::Complete { .. } => m.requests_completed += 1,
                ObsEvent::Evict { .. } => m.requests_evicted += 1,
                ObsEvent::Restore { .. } => m.requests_restored += 1,
                ObsEvent::BankSpan { state, t0, t1, .. } => {
                    match bank_states.iter_mut().find(|(s, _, _)| *s == state) {
                        Some(entry) => {
                            entry.1 += 1;
                            entry.2 += t1 - t0;
                        }
                        None => bank_states.push((state, 1, t1 - t0)),
                    }
                }
                ObsEvent::WakeStall { stall_cycles, .. } => {
                    m.wake_stalls += 1;
                    m.wake_stall_cycles += stall_cycles;
                }
                ObsEvent::RunEnd { end, .. } => {
                    m.cycles = m.cycles.max(end);
                    m.complete = true;
                }
            }
        }
        bank_states.sort_by_key(|(s, _, _)| *s);
        m.bank_states = bank_states;
        m
    }

    /// Total samples across memories.
    pub fn samples_total(&self) -> u64 {
        self.memories.iter().map(|m| m.samples).sum()
    }

    /// Wake-stall share of the run, percent (0 when no cycles yet).
    pub fn stall_pct(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            100.0 * self.wake_stall_cycles as f64 / self.cycles as f64
        }
    }

    /// Render the Prometheus text exposition. Deterministic: fixed
    /// metric order, memory labels in announcement order, bank states
    /// sorted by label.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(1024);
        let head = |out: &mut String, name: &str, help: &str, kind: &str| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {kind}");
        };

        head(&mut out, "trapti_run_id", "Run identifier from the WAL header.", "gauge");
        let _ = writeln!(out, "trapti_run_id {}", self.run_id);

        head(&mut out, "trapti_events_total", "WAL records folded into this snapshot.", "counter");
        let _ = writeln!(out, "trapti_events_total {}", self.events_total);

        head(&mut out, "trapti_cycles", "Highest simulation cycle observed.", "gauge");
        let _ = writeln!(out, "trapti_cycles {}", self.cycles);

        head(&mut out, "trapti_samples_total", "Occupancy samples observed.", "counter");
        let _ = writeln!(out, "trapti_samples_total {}", self.samples_total());

        head(&mut out, "trapti_occupancy_bytes", "Current occupancy (needed+obsolete) per memory.", "gauge");
        for m in &self.memories {
            let _ = writeln!(out, "trapti_occupancy_bytes{{memory=\"{}\"}} {}", m.name, m.current_occupied);
        }
        head(&mut out, "trapti_occupancy_peak_bytes", "Peak occupancy per memory.", "gauge");
        for m in &self.memories {
            let _ = writeln!(out, "trapti_occupancy_peak_bytes{{memory=\"{}\"}} {}", m.name, m.peak_occupied);
        }

        head(&mut out, "trapti_stages_started_total", "Dataflow stages entered.", "counter");
        let _ = writeln!(out, "trapti_stages_started_total {}", self.stages_started);
        head(&mut out, "trapti_stages_completed_total", "Dataflow stages completed.", "counter");
        let _ = writeln!(out, "trapti_stages_completed_total {}", self.stages_completed);

        head(&mut out, "trapti_requests_admitted_total", "Serving requests admitted.", "counter");
        let _ = writeln!(out, "trapti_requests_admitted_total {}", self.requests_admitted);
        head(&mut out, "trapti_requests_completed_total", "Serving requests completed.", "counter");
        let _ = writeln!(out, "trapti_requests_completed_total {}", self.requests_completed);
        head(&mut out, "trapti_requests_evicted_total", "Serving requests preempted (KV spilled to DRAM).", "counter");
        let _ = writeln!(out, "trapti_requests_evicted_total {}", self.requests_evicted);
        head(&mut out, "trapti_requests_restored_total", "Preempted serving requests re-admitted.", "counter");
        let _ = writeln!(out, "trapti_requests_restored_total {}", self.requests_restored);

        head(&mut out, "trapti_bank_state_spans_total", "Stage-III bank state spans by state.", "counter");
        for (state, count, _) in &self.bank_states {
            let _ = writeln!(out, "trapti_bank_state_spans_total{{state=\"{state}\"}} {count}");
        }
        head(&mut out, "trapti_bank_state_cycles_total", "Stage-III cycles spent per bank state.", "counter");
        for (state, _, cycles) in &self.bank_states {
            let _ = writeln!(out, "trapti_bank_state_cycles_total{{state=\"{state}\"}} {cycles}");
        }

        head(&mut out, "trapti_wake_stalls_total", "Stage-III wake-up stalls.", "counter");
        let _ = writeln!(out, "trapti_wake_stalls_total {}", self.wake_stalls);
        head(&mut out, "trapti_wake_stall_cycles_total", "Cycles lost to wake-up stalls.", "counter");
        let _ = writeln!(out, "trapti_wake_stall_cycles_total {}", self.wake_stall_cycles);

        head(&mut out, "trapti_run_complete", "1 once RunEnd was observed.", "gauge");
        let _ = writeln!(out, "trapti_run_complete {}", u8::from(self.complete));
        head(&mut out, "trapti_log_truncated", "1 when a torn tail was discarded on read.", "gauge");
        let _ = writeln!(out, "trapti_log_truncated {}", u8::from(self.truncated));
        out
    }

    /// Atomically write the rendered exposition to `path` (tmp +
    /// rename in the same directory, so scrapers never see a torn
    /// file).
    pub fn write_atomic(&self, path: &Path) -> Result<(), ObsError> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent)?;
            }
        }
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp);
        fs::write(&tmp, self.render())?;
        fs::rename(&tmp, path)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;

    use crate::trace::sink::{MemoryDesc, RunEvent, TraceSink};

    use super::super::sink::WalSink;
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "trapti-metrics-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_log(dir: &Path) -> EventLog {
        let mut wal = WalSink::create(dir, 0x77, 0).unwrap();
        wal.begin(&[
            MemoryDesc { name: "sram".into(), capacity: 1000 },
            MemoryDesc { name: "kv".into(), capacity: 500 },
        ]);
        wal.on_event(0, &RunEvent::StageStart { stage: 0 });
        wal.on_sample(0, 2, 100, 20);
        wal.on_sample(0, 6, 40, 0);
        wal.on_sample(1, 6, 30, 0);
        wal.on_event(7, &RunEvent::Admit { request: 0 });
        wal.on_event(8, &RunEvent::Evict { request: 0 });
        wal.on_event(8, &RunEvent::Restore { request: 0 });
        wal.on_event(9, &RunEvent::StageEnd { stage: 0 });
        wal.on_event(9, &RunEvent::Complete { request: 0 });
        wal.finish(10);
        wal.append_event(10, &RunEvent::BankSpan { bank: 0, state: "gated", t0: 4, t1: 10 });
        wal.append_event(10, &RunEvent::BankSpan { bank: 0, state: "active", t0: 0, t1: 4 });
        wal.append_event(10, &RunEvent::WakeStall { bank: 0, at: 4, stall_cycles: 3 });
        wal.close(None).unwrap();
        EventLog::open(dir).unwrap()
    }

    #[test]
    fn fold_counts_everything_once() {
        let dir = tmp_dir("fold");
        let log = sample_log(&dir);
        let m = MetricsSnapshot::from_log(&log);
        assert_eq!(m.run_id, 0x77);
        assert_eq!(m.cycles, 10);
        assert!(m.complete);
        assert_eq!(m.samples_total(), 3);
        assert_eq!(m.memories[0].peak_occupied, 120);
        assert_eq!(m.memories[0].current_occupied, 40);
        assert_eq!(m.memories[1].current_occupied, 30);
        assert_eq!(m.stages_started, 1);
        assert_eq!(m.stages_completed, 1);
        assert_eq!(m.requests_admitted, 1);
        assert_eq!(m.requests_completed, 1);
        assert_eq!(m.requests_evicted, 1);
        assert_eq!(m.requests_restored, 1);
        // Sorted by state label: active before gated.
        assert_eq!(m.bank_states, vec![("active", 1, 4), ("gated", 1, 6)]);
        assert_eq!(m.wake_stall_cycles, 3);
        assert!((m.stall_pct() - 30.0).abs() < 1e-9);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn render_is_deterministic_and_atomic_write_lands() {
        let dir = tmp_dir("render");
        let log = sample_log(&dir);
        let m = MetricsSnapshot::from_log(&log);
        let text = m.render();
        assert_eq!(text, MetricsSnapshot::from_log(&log).render());
        assert!(text.contains("trapti_occupancy_peak_bytes{memory=\"sram\"} 120"));
        assert!(text.contains("trapti_run_complete 1"));

        let out = dir.join("metrics.prom");
        m.write_atomic(&out).unwrap();
        assert_eq!(std::fs::read_to_string(&out).unwrap(), text);
        assert!(!out.with_extension("prom.tmp").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
