//! `trapti::obs` — WAL-backed observability for Stage I/III runs.
//!
//! Long runs (a million-cycle serving simulation, a thousand-cell lab
//! campaign) are opaque until they finish. This module gives every run
//! an **append-only, ordered, crash-recoverable event log**:
//!
//! * [`wal`] — the on-disk write-ahead log: [`WalWriter`] frames each
//!   record as `len | payload | fnv64(payload)` inside headered
//!   segments, sealing segments via tmp+rename rotation; the reader
//!   ([`EventLog::open`]) recovers the longest valid prefix of a torn
//!   log instead of failing.
//! * [`event`] — the typed record set ([`ObsEvent`]): run start/end,
//!   dataflow stage boundaries, occupancy samples, serving scheduler
//!   admissions/completions, and Stage-III per-bank spans and
//!   wake-stall events, each stamped with a strictly monotone sequence
//!   number and a non-decreasing timestamp.
//! * [`sink`] — [`WalSink`], a [`crate::trace::TraceSink`] that feeds
//!   the log from a live simulation (tee it next to any other sink).
//! * [`replay`] — [`replay_wal`]: reconstruct a bit-identical
//!   [`crate::trace::OccupancyTrace`] (and the run's
//!   [`crate::trace::AccessStats`]) from the log, so an interrupted
//!   Stage-I run resumes from the WAL instead of recomputing — the
//!   lab's validate jobs use exactly this.
//! * [`metrics`] — fold the log into Prometheus-text-format counters
//!   ([`MetricsSnapshot`]), written atomically to a `--metrics-out`
//!   file.
//! * [`watch`] — the `repro watch` live view: tail a WAL directory and
//!   render cycles simulated, current/peak occupancy, serving progress,
//!   bank gating, and stall share.
//!
//! ## Ordering guarantees
//!
//! Every log this module writes satisfies the invariants ported from
//! dashflow's ObservabilityOrdering TLA spec (property-tested over
//! generated schedules in `rust/tests/obs_ordering.rs`):
//!
//! 1. **RunStartFirst** — the first record is the only `RunStart`.
//! 2. **RunEndLast** — `RunEnd`, when present, is the unique last
//!    record (a log without it is a torn/in-flight run).
//! 3. **StageStartBeforeEnd** — each stage's `StageStart` precedes its
//!    `StageEnd`, one of each per stage.
//! 4. **Monotone stamps** — sequence numbers are strictly increasing
//!    (dense from 0) and timestamps are non-decreasing.
//! 5. **Append-only rotation** — a log read at any instant is a prefix
//!    of every later read, across segment rotation.

pub mod event;
pub mod metrics;
pub mod replay;
pub mod sink;
pub mod wal;
pub mod watch;

pub use event::{EventRecord, ObsEvent};
pub use metrics::MetricsSnapshot;
pub use replay::{replay_wal, WalReplay};
pub use sink::WalSink;
pub use wal::{EventLog, WalHeader, WalWriter};
pub use watch::WatchView;

use std::fmt;

/// Typed observability error: I/O, framing corruption that is not a
/// recoverable torn tail, or a record that decodes to nothing we know.
#[derive(Debug)]
pub enum ObsError {
    Io(std::io::Error),
    /// A checksummed record carries a payload we cannot decode — this is
    /// a version/foreign-writer problem, not a torn write, so the reader
    /// refuses instead of truncating.
    Decode(String),
    /// The log is structurally unusable for the requested operation
    /// (e.g. replay of a WAL with no `RunStart`).
    Incomplete(String),
}

impl fmt::Display for ObsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ObsError::Io(e) => write!(f, "WAL I/O error: {e}"),
            ObsError::Decode(why) => write!(f, "WAL record decode error: {why}"),
            ObsError::Incomplete(why) => write!(f, "WAL incomplete: {why}"),
        }
    }
}

impl std::error::Error for ObsError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ObsError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ObsError {
    fn from(e: std::io::Error) -> Self {
        ObsError::Io(e)
    }
}
