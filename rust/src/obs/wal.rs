//! The on-disk write-ahead log: headered segments of length-prefixed,
//! checksummed records.
//!
//! ## Layout
//!
//! A WAL is a directory. The writer appends to `active.seg`; when the
//! segment body exceeds the rotation threshold the file is **sealed by
//! rename** to its final numbered name (`000000.seg`, `000001.seg`, …)
//! — the tmp+rename idiom, so a numbered segment is always complete up
//! to at most one torn tail record. A cleanly closed log contains only
//! numbered segments; a surviving `active.seg` marks an in-flight or
//! crashed run.
//!
//! Each segment opens with a fixed 28-byte header:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  b"TWAL"
//!      4     4  format version (u32 LE)
//!      8     8  run id (u64 LE)
//!     16     4  segment index (u32 LE)
//!     20     8  wall-clock unix ms at creation (u64 LE)
//! ```
//!
//! The wall clock lives **only** here — record payloads carry
//! simulation time — so two identical runs differ in at most the first
//! 28 bytes of each segment (`tail -c +29 | cmp` is the CI determinism
//! gate), and deterministic producers (the lab) pass `wall_unix_ms = 0`
//! for fully identical bytes.
//!
//! Records are framed `len (u32 LE) | payload | fnv64(payload) (u64
//! LE)`; payload encoding lives in [`super::event`]. The reader
//! ([`EventLog::open`]) recovers the **longest valid prefix**: a short
//! frame, an implausible length, or a checksum mismatch truncates the
//! log there (`truncated = true`) instead of failing — but a record
//! that checksums correctly and still does not decode is a real
//! [`ObsError::Decode`], because silently dropping well-formed foreign
//! data would hide version skew.

use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::fnv::Fnv64;

use super::event::{decode, EventRecord, ObsEvent};
use super::ObsError;

pub const WAL_MAGIC: [u8; 4] = *b"TWAL";
pub const WAL_VERSION: u32 = 1;
/// Fixed segment header length in bytes (strip with `tail -c +29`).
pub const WAL_HEADER_LEN: usize = 28;
/// The in-flight segment name; sealed segments are `{index:06}.seg`.
pub const ACTIVE_SEGMENT: &str = "active.seg";
/// Default segment rotation threshold (body bytes, excluding header).
pub const DEFAULT_ROTATE_BYTES: u64 = 4 * 1024 * 1024;
/// Frames claiming more than this are treated as tail corruption.
const MAX_RECORD_LEN: u32 = 1 << 24;

/// Decoded segment header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalHeader {
    pub version: u32,
    pub run_id: u64,
    pub segment: u32,
    pub wall_unix_ms: u64,
}

impl WalHeader {
    pub fn encode(&self) -> [u8; WAL_HEADER_LEN] {
        let mut out = [0u8; WAL_HEADER_LEN];
        out[0..4].copy_from_slice(&WAL_MAGIC);
        out[4..8].copy_from_slice(&self.version.to_le_bytes());
        out[8..16].copy_from_slice(&self.run_id.to_le_bytes());
        out[16..20].copy_from_slice(&self.segment.to_le_bytes());
        out[20..28].copy_from_slice(&self.wall_unix_ms.to_le_bytes());
        out
    }

    /// `None` for a short or foreign header (torn tail, not our file).
    pub fn decode(bytes: &[u8]) -> Option<WalHeader> {
        if bytes.len() < WAL_HEADER_LEN || bytes[0..4] != WAL_MAGIC {
            return None;
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != WAL_VERSION {
            return None;
        }
        Some(WalHeader {
            version,
            run_id: u64::from_le_bytes(bytes[8..16].try_into().unwrap()),
            segment: u32::from_le_bytes(bytes[16..20].try_into().unwrap()),
            wall_unix_ms: u64::from_le_bytes(bytes[20..28].try_into().unwrap()),
        })
    }
}

fn segment_name(index: u32) -> String {
    format!("{index:06}.seg")
}

/// Append-only segment writer. Create one per run; frame payloads with
/// [`WalWriter::append`]; [`WalWriter::close`] seals the final segment.
/// Dropping without `close` leaves `active.seg` behind — exactly the
/// crashed-run shape the reader recovers from.
pub struct WalWriter {
    dir: PathBuf,
    file: File,
    run_id: u64,
    wall_unix_ms: u64,
    segment: u32,
    body_bytes: u64,
    rotate_bytes: u64,
}

impl WalWriter {
    /// Create (or reset) the log directory and open segment 0. Any
    /// `*.seg` files from a previous run of the same directory are
    /// removed first — a WAL is rewritten whole, never appended across
    /// runs. Pass `wall_unix_ms = 0` for byte-deterministic logs.
    pub fn create(dir: &Path, run_id: u64, wall_unix_ms: u64) -> std::io::Result<Self> {
        fs::create_dir_all(dir)?;
        for entry in fs::read_dir(dir)? {
            let path = entry?.path();
            if path.extension().is_some_and(|e| e == "seg") {
                fs::remove_file(&path)?;
            }
        }
        let mut w = WalWriter {
            dir: dir.to_path_buf(),
            // Placeholder; open_active replaces it immediately.
            file: File::create(dir.join(ACTIVE_SEGMENT))?,
            run_id,
            wall_unix_ms,
            segment: 0,
            body_bytes: 0,
            rotate_bytes: DEFAULT_ROTATE_BYTES,
        };
        w.write_header()?;
        Ok(w)
    }

    /// Override the rotation threshold (body bytes per segment). Small
    /// values force rotation early — the tests use this to exercise the
    /// append-only-across-rotation property.
    pub fn with_rotate_bytes(mut self, bytes: u64) -> Self {
        self.rotate_bytes = bytes.max(1);
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn run_id(&self) -> u64 {
        self.run_id
    }

    /// Index of the currently active segment.
    pub fn segment_index(&self) -> u32 {
        self.segment
    }

    fn write_header(&mut self) -> std::io::Result<()> {
        let header = WalHeader {
            version: WAL_VERSION,
            run_id: self.run_id,
            segment: self.segment,
            wall_unix_ms: self.wall_unix_ms,
        };
        self.file.write_all(&header.encode())
    }

    /// Frame and append one record payload; rotates the segment once
    /// the body crosses the threshold (a record never spans segments).
    pub fn append(&mut self, payload: &[u8]) -> std::io::Result<()> {
        let mut frame = Vec::with_capacity(payload.len() + 12);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(payload);
        let mut h = Fnv64::new();
        h.bytes(payload);
        frame.extend_from_slice(&h.finish().to_le_bytes());
        self.file.write_all(&frame)?;
        self.body_bytes += frame.len() as u64;
        if self.body_bytes >= self.rotate_bytes {
            self.rotate()?;
        }
        Ok(())
    }

    /// Seal `active.seg` under its final numbered name (atomic rename).
    fn seal_active(&mut self) -> std::io::Result<()> {
        self.file.flush()?;
        self.file.sync_all()?;
        fs::rename(
            self.dir.join(ACTIVE_SEGMENT),
            self.dir.join(segment_name(self.segment)),
        )
    }

    fn rotate(&mut self) -> std::io::Result<()> {
        self.seal_active()?;
        self.segment += 1;
        self.body_bytes = 0;
        self.file = File::create(self.dir.join(ACTIVE_SEGMENT))?;
        self.write_header()
    }

    /// Seal the final segment. After a clean close the directory holds
    /// only numbered segments.
    pub fn close(mut self) -> std::io::Result<()> {
        self.seal_active()
    }
}

/// A decoded log: every recoverable record of every segment, in order.
#[derive(Debug, Clone)]
pub struct EventLog {
    /// Header of the first segment (`None` for an empty/headerless log).
    pub header: Option<WalHeader>,
    pub records: Vec<EventRecord>,
    /// True when a torn tail (short frame / bad checksum / short
    /// header) was discarded — the records are the longest valid
    /// prefix.
    pub truncated: bool,
    /// Segment files the reader consumed (including a torn one).
    pub segments: u32,
}

impl EventLog {
    /// Read a WAL directory: numbered segments in index order, then
    /// `active.seg` if present. Stops at the first torn point.
    pub fn open(dir: &Path) -> Result<EventLog, ObsError> {
        let mut numbered: Vec<(u32, PathBuf)> = Vec::new();
        let mut active: Option<PathBuf> = None;
        for entry in fs::read_dir(dir)? {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            if name == ACTIVE_SEGMENT {
                active = Some(path);
            } else if let Some(stem) = name.strip_suffix(".seg") {
                if stem.len() == 6 {
                    if let Ok(ix) = stem.parse::<u32>() {
                        numbered.push((ix, path));
                    }
                }
            }
        }
        numbered.sort_by_key(|(ix, _)| *ix);
        let paths: Vec<PathBuf> = numbered
            .into_iter()
            .map(|(_, p)| p)
            .chain(active)
            .collect();

        let mut log = EventLog {
            header: None,
            records: Vec::new(),
            truncated: false,
            segments: 0,
        };
        for path in paths {
            let bytes = fs::read(&path)?;
            log.segments += 1;
            let Some(header) = WalHeader::decode(&bytes) else {
                // Short or foreign header: torn tail at a segment
                // boundary. Everything before it is the valid prefix.
                log.truncated = true;
                return Ok(log);
            };
            if let Some(first) = log.header {
                if header.run_id != first.run_id {
                    return Err(ObsError::Decode(format!(
                        "segment {} carries run id {:016x}, expected {:016x}",
                        path.display(),
                        header.run_id,
                        first.run_id
                    )));
                }
            } else {
                log.header = Some(header);
            }
            if !read_segment_body(&bytes[WAL_HEADER_LEN..], &mut log.records)? {
                log.truncated = true;
                return Ok(log);
            }
        }
        Ok(log)
    }

    /// Run id from the first segment header.
    pub fn run_id(&self) -> Option<u64> {
        self.header.map(|h| h.run_id)
    }

    /// True when the log ends with the `RunEnd` record — a cleanly
    /// closed run.
    pub fn complete(&self) -> bool {
        matches!(
            self.records.last(),
            Some(EventRecord {
                event: ObsEvent::RunEnd { .. },
                ..
            })
        )
    }
}

/// Parse one segment body; push decoded records. Returns `false` when a
/// torn tail was hit (caller stops reading further segments).
fn read_segment_body(
    mut body: &[u8],
    out: &mut Vec<EventRecord>,
) -> Result<bool, ObsError> {
    while !body.is_empty() {
        if body.len() < 4 {
            return Ok(false);
        }
        let len = u32::from_le_bytes(body[0..4].try_into().unwrap());
        if len > MAX_RECORD_LEN {
            return Ok(false);
        }
        let frame_len = 4 + len as usize + 8;
        if body.len() < frame_len {
            return Ok(false);
        }
        let payload = &body[4..4 + len as usize];
        let sum = u64::from_le_bytes(body[4 + len as usize..frame_len].try_into().unwrap());
        let mut h = Fnv64::new();
        h.bytes(payload);
        if h.finish() != sum {
            return Ok(false);
        }
        out.push(decode(payload)?);
        body = &body[frame_len..];
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::super::event::encode;
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "trapti-wal-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn rec(seq: u64, t: u64) -> EventRecord {
        EventRecord {
            seq,
            t,
            event: ObsEvent::Sample { mem: 0, needed: seq * 10, obsolete: 0 },
        }
    }

    #[test]
    fn write_read_roundtrip_and_clean_close_leaves_no_active_segment() {
        let dir = tmp_dir("roundtrip");
        let mut w = WalWriter::create(&dir, 0xabcd, 0).unwrap();
        let recs: Vec<EventRecord> = (0..10).map(|i| rec(i, i * 7)).collect();
        for r in &recs {
            w.append(&encode(r)).unwrap();
        }
        w.close().unwrap();
        assert!(!dir.join(ACTIVE_SEGMENT).exists());
        assert!(dir.join("000000.seg").exists());

        let log = EventLog::open(&dir).unwrap();
        assert_eq!(log.run_id(), Some(0xabcd));
        assert_eq!(log.records, recs);
        assert!(!log.truncated);
        assert_eq!(log.segments, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rotation_seals_numbered_segments_in_order() {
        let dir = tmp_dir("rotate");
        // ~33-byte frames, rotate every 64 body bytes: 2 records/segment.
        let mut w = WalWriter::create(&dir, 7, 0).unwrap().with_rotate_bytes(64);
        let recs: Vec<EventRecord> = (0..9).map(|i| rec(i, i)).collect();
        for r in &recs {
            w.append(&encode(r)).unwrap();
        }
        assert!(w.segment_index() >= 3, "rotation must have happened");
        w.close().unwrap();
        assert!(!dir.join(ACTIVE_SEGMENT).exists());
        assert!(dir.join("000000.seg").exists());
        assert!(dir.join("000001.seg").exists());

        let log = EventLog::open(&dir).unwrap();
        assert_eq!(log.records, recs, "order survives rotation");
        assert!(log.segments >= 4);
        // Every segment header agrees on the run id and counts up.
        for ix in 0..log.segments {
            let bytes = fs::read(dir.join(segment_name(ix))).unwrap();
            let h = WalHeader::decode(&bytes).unwrap();
            assert_eq!(h.run_id, 7);
            assert_eq!(h.segment, ix);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn uncleanly_dropped_writer_is_still_readable() {
        let dir = tmp_dir("crash");
        let mut w = WalWriter::create(&dir, 1, 0).unwrap();
        w.append(&encode(&rec(0, 0))).unwrap();
        w.append(&encode(&rec(1, 5))).unwrap();
        drop(w); // no close: active.seg remains
        assert!(dir.join(ACTIVE_SEGMENT).exists());
        let log = EventLog::open(&dir).unwrap();
        assert_eq!(log.records.len(), 2);
        assert!(!log.complete());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let dir = tmp_dir("torn");
        let mut w = WalWriter::create(&dir, 1, 0).unwrap();
        for i in 0..3 {
            w.append(&encode(&rec(i, i))).unwrap();
        }
        w.close().unwrap();
        let seg = dir.join("000000.seg");
        let mut bytes = fs::read(&seg).unwrap();
        let cut = bytes.len() - 5; // mid-checksum of the last record
        bytes.truncate(cut);
        fs::write(&seg, &bytes).unwrap();

        let log = EventLog::open(&dir).unwrap();
        assert!(log.truncated);
        assert_eq!(log.records.len(), 2, "longest valid prefix");
        assert_eq!(log.records, vec![rec(0, 0), rec(1, 1)]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn create_resets_a_previous_log() {
        let dir = tmp_dir("reset");
        let mut w = WalWriter::create(&dir, 1, 0).unwrap().with_rotate_bytes(1);
        w.append(&encode(&rec(0, 0))).unwrap(); // rotates: 000000.seg
        w.append(&encode(&rec(1, 1))).unwrap();
        w.close().unwrap();
        assert!(dir.join("000001.seg").exists());

        let mut w = WalWriter::create(&dir, 2, 0).unwrap();
        w.append(&encode(&rec(0, 0))).unwrap();
        w.close().unwrap();
        let log = EventLog::open(&dir).unwrap();
        assert_eq!(log.run_id(), Some(2));
        assert_eq!(log.records.len(), 1, "old segments are gone");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatched_run_ids_across_segments_are_rejected() {
        let dir = tmp_dir("mismatch");
        let mut w = WalWriter::create(&dir, 1, 0).unwrap().with_rotate_bytes(1);
        w.append(&encode(&rec(0, 0))).unwrap();
        w.close().unwrap(); // 000000.seg + 000001.seg (empty body)
        // Forge the second segment's run id.
        let seg = dir.join("000001.seg");
        let mut bytes = fs::read(&seg).unwrap();
        bytes[8..16].copy_from_slice(&99u64.to_le_bytes());
        fs::write(&seg, &bytes).unwrap();
        let err = EventLog::open(&dir).unwrap_err();
        assert!(matches!(err, ObsError::Decode(_)), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }
}
