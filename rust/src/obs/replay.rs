//! Reconstruct Stage-I artifacts from a WAL.
//!
//! The log records every occupancy sample the engine streamed, so
//! replaying it through [`OccupancyTrace::record`] rebuilds exactly the
//! traces a `MaterializeSink` would have built in the live run — the
//! same samples, the same coalescing, the same `end_time`, bit for bit.
//! That is how the lab resumes an interrupted validate job: if the job
//! directory is gone but its WAL survived, [`replay_wal`] recovers the
//! trace (and, for a cleanly closed run, the [`AccessStats`]) without
//! re-simulating.

use std::path::Path;

use crate::trace::{AccessStats, OccupancyTrace};

use super::event::ObsEvent;
use super::wal::EventLog;
use super::ObsError;

/// Everything a WAL can give back about its run.
#[derive(Debug, Clone)]
pub struct WalReplay {
    /// Run id from `RunStart` (equals the segment-header id).
    pub run_id: u64,
    /// One finalized trace per memory, in announcement order —
    /// bit-identical to the live run's materialized traces.
    pub traces: Vec<OccupancyTrace>,
    /// Access statistics, present only when the run closed cleanly with
    /// stats attached.
    pub stats: Option<AccessStats>,
    /// True when the log ends with `RunEnd` — a cleanly closed run. A
    /// false here means the replay covers a valid prefix of a crashed or
    /// still-running simulation (traces are finalized at the last
    /// observed instant).
    pub complete: bool,
    /// End time the traces were finalized at.
    pub end: u64,
}

/// Replay a WAL directory into materialized, finalized traces.
///
/// Errors: [`ObsError::Incomplete`] when the log has no `RunStart` (too
/// little survived to reconstruct anything); [`ObsError::Decode`] when
/// the log is structurally impossible for our writer (sample for an
/// unannounced memory, duplicate `RunStart`, records after `RunEnd`).
/// A torn tail is *not* an error — the longest valid prefix replays.
pub fn replay_wal(dir: &Path) -> Result<WalReplay, ObsError> {
    let log = EventLog::open(dir)?;
    replay_log(&log)
}

/// Replay an already-opened log (see [`replay_wal`]).
pub fn replay_log(log: &EventLog) -> Result<WalReplay, ObsError> {
    let mut records = log.records.iter();
    let Some(first) = records.next() else {
        return Err(ObsError::Incomplete(
            "log has no records (no RunStart survived)".to_string(),
        ));
    };
    let ObsEvent::RunStart { run_id, ref memories } = first.event else {
        return Err(ObsError::Incomplete(format!(
            "first record is {}, expected run_start",
            first.event.kind_label()
        )));
    };

    let mut traces: Vec<OccupancyTrace> = memories
        .iter()
        .map(|m| OccupancyTrace::new(&m.name, m.capacity))
        .collect();
    let mut stats: Option<AccessStats> = None;
    let mut complete = false;
    let mut last_t = first.t;

    for rec in records {
        if complete {
            return Err(ObsError::Decode(format!(
                "record seq {} follows RunEnd",
                rec.seq
            )));
        }
        last_t = last_t.max(rec.t);
        match rec.event {
            ObsEvent::RunStart { .. } => {
                return Err(ObsError::Decode(format!(
                    "duplicate RunStart at seq {}",
                    rec.seq
                )));
            }
            ObsEvent::Sample { mem, needed, obsolete } => {
                let Some(trace) = traces.get_mut(mem as usize) else {
                    return Err(ObsError::Decode(format!(
                        "sample for unannounced memory index {mem}"
                    )));
                };
                trace.record(rec.t, needed, obsolete);
            }
            ObsEvent::RunEnd { end, stats: ref s } => {
                last_t = last_t.max(end);
                stats = s.clone();
                complete = true;
            }
            // Structural events don't change occupancy (evict/restore
            // page movement arrives via its own Sample records).
            ObsEvent::StageStart { .. }
            | ObsEvent::StageEnd { .. }
            | ObsEvent::Admit { .. }
            | ObsEvent::Complete { .. }
            | ObsEvent::Evict { .. }
            | ObsEvent::Restore { .. }
            | ObsEvent::BankSpan { .. }
            | ObsEvent::WakeStall { .. } => {}
        }
    }

    for trace in &mut traces {
        trace.finalize(last_t);
    }
    Ok(WalReplay {
        run_id,
        traces,
        stats,
        complete,
        end: last_t,
    })
}

#[cfg(test)]
mod tests {
    use std::fs;
    use std::path::PathBuf;

    use crate::trace::sink::{MaterializeSink, MemoryDesc, TraceSink};
    use crate::trace::TeeSink;
    use crate::util::rng::Rng;

    use super::super::sink::WalSink;
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "trapti-replay-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn mems() -> Vec<MemoryDesc> {
        vec![
            MemoryDesc { name: "sram".into(), capacity: 1 << 20 },
            MemoryDesc { name: "kv".into(), capacity: 1 << 18 },
        ]
    }

    fn assert_bit_identical(a: &OccupancyTrace, b: &OccupancyTrace) {
        assert_eq!(a.memory, b.memory);
        assert_eq!(a.capacity, b.capacity);
        assert_eq!(a.samples(), b.samples());
        assert_eq!(a.end_time(), b.end_time());
        assert_eq!(a.avg_needed().to_bits(), b.avg_needed().to_bits());
    }

    #[test]
    fn replay_matches_materialize_on_random_streams() {
        crate::util::proptest::check("replay-vs-materialize", 25, |rng: &mut Rng| {
            let dir = tmp_dir(&format!("prop-{}", rng.below(u32::MAX as u64)));
            let mut wal = WalSink::create(&dir, 0xfeed, 0)
                .unwrap()
                .with_rotate_bytes(256); // force rotation mid-run
            let mut mat = MaterializeSink::new();
            {
                let mut tee = TeeSink::new(vec![&mut mat, &mut wal]);
                tee.begin(&mems());
                let mut t = 0u64;
                for _ in 0..rng.range(1, 120) {
                    t += rng.below(40);
                    let mem = rng.below(2) as usize;
                    tee.on_sample(mem, t, rng.below(1 << 16), rng.below(1 << 10));
                }
                tee.finish(t + rng.range(0, 20));
            }
            wal.close(None).unwrap();

            let replay = replay_wal(&dir).unwrap();
            assert!(replay.complete);
            assert_eq!(replay.run_id, 0xfeed);
            let live = mat.into_traces();
            assert_eq!(replay.traces.len(), live.len());
            for (r, l) in replay.traces.iter().zip(&live) {
                assert_bit_identical(r, l);
            }
            let _ = fs::remove_dir_all(&dir);
        });
    }

    #[test]
    fn incomplete_log_replays_its_prefix() {
        let dir = tmp_dir("prefix");
        let mut wal = WalSink::create(&dir, 9, 0).unwrap();
        wal.begin(&mems());
        wal.on_sample(0, 3, 77, 0);
        wal.on_sample(1, 8, 11, 2);
        drop(wal); // crash: no finish, no close

        let replay = replay_wal(&dir).unwrap();
        assert!(!replay.complete);
        assert_eq!(replay.end, 8, "finalized at the last observed instant");
        assert_eq!(replay.traces[0].samples().last().unwrap().needed, 77);
        replay.traces[0].validate().unwrap();
        replay.traces[1].validate().unwrap();
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_log_is_incomplete_error() {
        let dir = tmp_dir("empty");
        let wal = WalSink::create(&dir, 1, 0).unwrap();
        drop(wal); // header only, zero records
        let err = replay_wal(&dir).unwrap_err();
        assert!(matches!(err, ObsError::Incomplete(_)), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }
}
