//! The typed WAL record set and its byte codec.
//!
//! Every record payload is `seq u64 | t u64 | kind u8 | fields`, all
//! little-endian, strings length-prefixed (u32) — fully self-describing
//! and platform-stable, so two identical runs produce byte-identical
//! payloads (the CI WAL determinism gate `cmp`s them after stripping
//! the wall-clocked segment headers).

use std::collections::BTreeMap;

use crate::trace::sink::{MemoryDesc, RunEvent};
use crate::trace::{AccessStats, KindStats};

use super::ObsError;

/// One decoded WAL record: the monotone envelope stamps plus the event.
#[derive(Debug, Clone, PartialEq)]
pub struct EventRecord {
    /// Strictly monotone sequence number, dense from 0.
    pub seq: u64,
    /// Simulation-time stamp in cycles, non-decreasing across the log.
    pub t: u64,
    pub event: ObsEvent,
}

/// The observability event vocabulary. A superset of
/// [`crate::trace::RunEvent`]: the WAL additionally records the run
/// envelope (`RunStart`/`RunEnd`) and the occupancy samples themselves,
/// so the log alone reconstructs the trace.
#[derive(Debug, Clone, PartialEq)]
pub enum ObsEvent {
    /// First record of every log: the run identity and memory layout.
    RunStart {
        run_id: u64,
        memories: Vec<MemoryDesc>,
    },
    StageStart {
        stage: u32,
    },
    StageEnd {
        stage: u32,
    },
    /// Occupancy change of memory `mem` (same semantics as
    /// [`crate::trace::TraceSink::on_sample`]: last record at an instant
    /// wins).
    Sample {
        mem: u32,
        needed: u64,
        obsolete: u64,
    },
    Admit {
        request: u32,
    },
    Complete {
        request: u32,
    },
    /// Serving scheduler preempted request `request` (KV spilled to
    /// DRAM, arena pages freed).
    Evict {
        request: u32,
    },
    /// Serving scheduler re-admitted preempted request `request` (KV
    /// streamed back from DRAM).
    Restore {
        request: u32,
    },
    /// Stage-III retrospective: bank `bank` held `state` over `[t0, t1)`
    /// adjusted cycles.
    BankSpan {
        bank: u32,
        state: &'static str,
        t0: u64,
        t1: u64,
    },
    /// Stage-III retrospective: the wake-up at adjusted cycle `at`
    /// stalled the machine for `stall_cycles`.
    WakeStall {
        bank: u32,
        at: u64,
        stall_cycles: u64,
    },
    /// Last record of a cleanly closed run: the end time and, when the
    /// writer had them, the run's access statistics. A log without this
    /// record is an in-flight or crashed run.
    RunEnd {
        end: u64,
        stats: Option<AccessStats>,
    },
}

impl ObsEvent {
    /// Lift a live stream event into the WAL vocabulary.
    pub fn of_run_event(ev: &RunEvent) -> ObsEvent {
        match *ev {
            RunEvent::StageStart { stage } => ObsEvent::StageStart { stage },
            RunEvent::StageEnd { stage } => ObsEvent::StageEnd { stage },
            RunEvent::Admit { request } => ObsEvent::Admit { request },
            RunEvent::Complete { request } => ObsEvent::Complete { request },
            RunEvent::Evict { request } => ObsEvent::Evict { request },
            RunEvent::Restore { request } => ObsEvent::Restore { request },
            RunEvent::BankSpan { bank, state, t0, t1 } => {
                ObsEvent::BankSpan { bank, state, t0, t1 }
            }
            RunEvent::WakeStall { bank, at, stall_cycles } => {
                ObsEvent::WakeStall { bank, at, stall_cycles }
            }
        }
    }

    /// Short deterministic kind label (metrics/watch rendering).
    pub fn kind_label(&self) -> &'static str {
        match self {
            ObsEvent::RunStart { .. } => "run_start",
            ObsEvent::StageStart { .. } => "stage_start",
            ObsEvent::StageEnd { .. } => "stage_end",
            ObsEvent::Sample { .. } => "sample",
            ObsEvent::Admit { .. } => "admit",
            ObsEvent::Complete { .. } => "complete",
            ObsEvent::Evict { .. } => "evict",
            ObsEvent::Restore { .. } => "restore",
            ObsEvent::BankSpan { .. } => "bank_span",
            ObsEvent::WakeStall { .. } => "wake_stall",
            ObsEvent::RunEnd { .. } => "run_end",
        }
    }
}

const KIND_RUN_START: u8 = 0;
const KIND_STAGE_START: u8 = 1;
const KIND_STAGE_END: u8 = 2;
const KIND_SAMPLE: u8 = 3;
const KIND_ADMIT: u8 = 4;
const KIND_COMPLETE: u8 = 5;
const KIND_BANK_SPAN: u8 = 6;
const KIND_WAKE_STALL: u8 = 7;
const KIND_RUN_END: u8 = 8;
const KIND_EVICT: u8 = 9;
const KIND_RESTORE: u8 = 10;

/// Map a decoded bank-state label back onto the `'static` vocabulary of
/// `banking::online::BankState::label`. Unknown labels are a decode
/// error, not a torn write.
fn bank_state_static(name: &str) -> Option<&'static str> {
    match name {
        "active" => Some("active"),
        "idle" => Some("idle"),
        "drowsy" => Some("drowsy"),
        "gated" => Some("gated"),
        "waking" => Some("waking"),
        _ => None,
    }
}

/// Map a decoded tensor-kind name back onto the `'static` keys used by
/// `AccessStats::by_kind` (see `sim::engine`'s `sram_read` call sites).
fn tensor_kind_static(name: &str) -> Option<&'static str> {
    match name {
        "act" => Some("act"),
        "kv" => Some("kv"),
        "weight" => Some("weight"),
        _ => None,
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Encode one record payload (the WAL frames it with length + checksum).
pub fn encode(rec: &EventRecord) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    put_u64(&mut out, rec.seq);
    put_u64(&mut out, rec.t);
    match &rec.event {
        ObsEvent::RunStart { run_id, memories } => {
            out.push(KIND_RUN_START);
            put_u64(&mut out, *run_id);
            put_u32(&mut out, memories.len() as u32);
            for m in memories {
                put_str(&mut out, &m.name);
                put_u64(&mut out, m.capacity);
            }
        }
        ObsEvent::StageStart { stage } => {
            out.push(KIND_STAGE_START);
            put_u32(&mut out, *stage);
        }
        ObsEvent::StageEnd { stage } => {
            out.push(KIND_STAGE_END);
            put_u32(&mut out, *stage);
        }
        ObsEvent::Sample { mem, needed, obsolete } => {
            out.push(KIND_SAMPLE);
            put_u32(&mut out, *mem);
            put_u64(&mut out, *needed);
            put_u64(&mut out, *obsolete);
        }
        ObsEvent::Admit { request } => {
            out.push(KIND_ADMIT);
            put_u32(&mut out, *request);
        }
        ObsEvent::Complete { request } => {
            out.push(KIND_COMPLETE);
            put_u32(&mut out, *request);
        }
        ObsEvent::Evict { request } => {
            out.push(KIND_EVICT);
            put_u32(&mut out, *request);
        }
        ObsEvent::Restore { request } => {
            out.push(KIND_RESTORE);
            put_u32(&mut out, *request);
        }
        ObsEvent::BankSpan { bank, state, t0, t1 } => {
            out.push(KIND_BANK_SPAN);
            put_u32(&mut out, *bank);
            put_str(&mut out, state);
            put_u64(&mut out, *t0);
            put_u64(&mut out, *t1);
        }
        ObsEvent::WakeStall { bank, at, stall_cycles } => {
            out.push(KIND_WAKE_STALL);
            put_u32(&mut out, *bank);
            put_u64(&mut out, *at);
            put_u64(&mut out, *stall_cycles);
        }
        ObsEvent::RunEnd { end, stats } => {
            out.push(KIND_RUN_END);
            put_u64(&mut out, *end);
            match stats {
                None => out.push(0),
                Some(s) => {
                    out.push(1);
                    for v in [
                        s.reads,
                        s.writes,
                        s.read_bytes,
                        s.write_bytes,
                        s.evictions_obsolete,
                        s.writebacks,
                        s.writeback_bytes,
                        s.refetches,
                        s.dram_read_bytes,
                        s.dram_write_bytes,
                    ] {
                        put_u64(&mut out, v);
                    }
                    put_u32(&mut out, s.by_kind.len() as u32);
                    // BTreeMap iteration order is the key order:
                    // deterministic bytes.
                    for (kind, ks) in &s.by_kind {
                        put_str(&mut out, kind);
                        put_u64(&mut out, ks.read_bytes);
                        put_u64(&mut out, ks.write_bytes);
                    }
                }
            }
        }
    }
    out
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ObsError> {
        if self.pos + n > self.buf.len() {
            return Err(ObsError::Decode(format!(
                "payload truncated: want {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ObsError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ObsError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ObsError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String, ObsError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| ObsError::Decode("string field is not UTF-8".to_string()))
    }

    fn done(&self) -> Result<(), ObsError> {
        if self.pos != self.buf.len() {
            return Err(ObsError::Decode(format!(
                "{} trailing payload bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Decode one checksummed payload back into an [`EventRecord`].
pub fn decode(payload: &[u8]) -> Result<EventRecord, ObsError> {
    let mut c = Cursor { buf: payload, pos: 0 };
    let seq = c.u64()?;
    let t = c.u64()?;
    let kind = c.u8()?;
    let event = match kind {
        KIND_RUN_START => {
            let run_id = c.u64()?;
            let n = c.u32()? as usize;
            let mut memories = Vec::with_capacity(n);
            for _ in 0..n {
                let name = c.str()?;
                let capacity = c.u64()?;
                memories.push(MemoryDesc { name, capacity });
            }
            ObsEvent::RunStart { run_id, memories }
        }
        KIND_STAGE_START => ObsEvent::StageStart { stage: c.u32()? },
        KIND_STAGE_END => ObsEvent::StageEnd { stage: c.u32()? },
        KIND_SAMPLE => ObsEvent::Sample {
            mem: c.u32()?,
            needed: c.u64()?,
            obsolete: c.u64()?,
        },
        KIND_ADMIT => ObsEvent::Admit { request: c.u32()? },
        KIND_COMPLETE => ObsEvent::Complete { request: c.u32()? },
        KIND_EVICT => ObsEvent::Evict { request: c.u32()? },
        KIND_RESTORE => ObsEvent::Restore { request: c.u32()? },
        KIND_BANK_SPAN => {
            let bank = c.u32()?;
            let state_name = c.str()?;
            let state = bank_state_static(&state_name).ok_or_else(|| {
                ObsError::Decode(format!("unknown bank state `{state_name}`"))
            })?;
            ObsEvent::BankSpan {
                bank,
                state,
                t0: c.u64()?,
                t1: c.u64()?,
            }
        }
        KIND_WAKE_STALL => ObsEvent::WakeStall {
            bank: c.u32()?,
            at: c.u64()?,
            stall_cycles: c.u64()?,
        },
        KIND_RUN_END => {
            let end = c.u64()?;
            let stats = match c.u8()? {
                0 => None,
                1 => {
                    let mut s = AccessStats {
                        reads: c.u64()?,
                        writes: c.u64()?,
                        read_bytes: c.u64()?,
                        write_bytes: c.u64()?,
                        evictions_obsolete: c.u64()?,
                        writebacks: c.u64()?,
                        writeback_bytes: c.u64()?,
                        refetches: c.u64()?,
                        dram_read_bytes: c.u64()?,
                        dram_write_bytes: c.u64()?,
                        by_kind: BTreeMap::new(),
                    };
                    let n = c.u32()? as usize;
                    for _ in 0..n {
                        let name = c.str()?;
                        let kind = tensor_kind_static(&name).ok_or_else(|| {
                            ObsError::Decode(format!("unknown tensor kind `{name}`"))
                        })?;
                        let ks = KindStats {
                            read_bytes: c.u64()?,
                            write_bytes: c.u64()?,
                        };
                        s.by_kind.insert(kind, ks);
                    }
                    Some(s)
                }
                other => {
                    return Err(ObsError::Decode(format!(
                        "bad stats flag {other} in RunEnd"
                    )))
                }
            };
            ObsEvent::RunEnd { end, stats }
        }
        other => return Err(ObsError::Decode(format!("unknown record kind {other}"))),
    };
    c.done()?;
    Ok(EventRecord { seq, t, event })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(rec: EventRecord) {
        let bytes = encode(&rec);
        let back = decode(&bytes).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn every_kind_roundtrips() {
        let mut stats = AccessStats {
            reads: 10,
            writes: 5,
            read_bytes: 640,
            write_bytes: 320,
            evictions_obsolete: 1,
            writebacks: 2,
            writeback_bytes: 128,
            refetches: 3,
            dram_read_bytes: 4096,
            dram_write_bytes: 2048,
            by_kind: BTreeMap::new(),
        };
        stats.by_kind.insert("act", KindStats { read_bytes: 1, write_bytes: 2 });
        stats.by_kind.insert("kv", KindStats { read_bytes: 3, write_bytes: 4 });
        stats.by_kind.insert("weight", KindStats { read_bytes: 5, write_bytes: 6 });

        let events = vec![
            ObsEvent::RunStart {
                run_id: 0xdead_beef,
                memories: vec![
                    MemoryDesc { name: "sram".into(), capacity: 1 << 27 },
                    MemoryDesc { name: "kv-arena".into(), capacity: 1 << 24 },
                ],
            },
            ObsEvent::StageStart { stage: 0 },
            ObsEvent::StageEnd { stage: 0 },
            ObsEvent::Sample { mem: 1, needed: 123, obsolete: 45 },
            ObsEvent::Admit { request: 7 },
            ObsEvent::Complete { request: 7 },
            ObsEvent::Evict { request: 9 },
            ObsEvent::Restore { request: 9 },
            ObsEvent::BankSpan { bank: 3, state: "gated", t0: 10, t1: 99 },
            ObsEvent::WakeStall { bank: 3, at: 99, stall_cycles: 40 },
            ObsEvent::RunEnd { end: 1000, stats: Some(stats) },
            ObsEvent::RunEnd { end: 1000, stats: None },
        ];
        for (i, event) in events.into_iter().enumerate() {
            roundtrip(EventRecord { seq: i as u64, t: i as u64 * 10, event });
        }
    }

    #[test]
    fn encoding_is_deterministic() {
        let rec = EventRecord {
            seq: 42,
            t: 99,
            event: ObsEvent::Sample { mem: 0, needed: 1, obsolete: 2 },
        };
        assert_eq!(encode(&rec), encode(&rec.clone()));
    }

    #[test]
    fn unknown_kind_and_trailing_bytes_are_decode_errors() {
        let mut bytes = encode(&EventRecord {
            seq: 0,
            t: 0,
            event: ObsEvent::Admit { request: 1 },
        });
        let kind_off = 16; // seq + t
        bytes[kind_off] = 200;
        assert!(matches!(decode(&bytes).unwrap_err(), ObsError::Decode(_)));

        let mut ok = encode(&EventRecord {
            seq: 0,
            t: 0,
            event: ObsEvent::Admit { request: 1 },
        });
        ok.push(0);
        assert!(matches!(decode(&ok).unwrap_err(), ObsError::Decode(_)));
    }

    #[test]
    fn unknown_bank_state_is_a_decode_error() {
        // Hand-assemble a BankSpan with a foreign state label.
        let mut out = Vec::new();
        out.extend_from_slice(&0u64.to_le_bytes());
        out.extend_from_slice(&0u64.to_le_bytes());
        out.push(6); // KIND_BANK_SPAN
        out.extend_from_slice(&0u32.to_le_bytes());
        out.extend_from_slice(&(5u32).to_le_bytes());
        out.extend_from_slice(b"astra");
        out.extend_from_slice(&0u64.to_le_bytes());
        out.extend_from_slice(&1u64.to_le_bytes());
        let err = decode(&out).unwrap_err();
        assert!(err.to_string().contains("unknown bank state"), "{err}");
    }
}
