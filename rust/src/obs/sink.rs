//! [`WalSink`]: the [`TraceSink`] that feeds a write-ahead log from a
//! live simulation.
//!
//! `TraceSink` methods cannot return errors (the engines treat sinks as
//! infallible observers), so the sink is **sticky-failing**: the first
//! I/O error is stored and every later call becomes a no-op; the error
//! surfaces from [`WalSink::close`]. This keeps a broken disk from
//! aborting a simulation whose materialized results are still good.
//!
//! ## Protocol
//!
//! * [`TraceSink::begin`] writes the `RunStart` record (seq 0, t 0).
//! * Samples and live [`RunEvent`]s append in arrival order with a
//!   strictly monotone sequence number; envelope timestamps are clamped
//!   non-decreasing so every written log satisfies the ordering
//!   invariants of [`super`] by construction.
//! * [`TraceSink::finish`] only **remembers** the end time — it does
//!   not write `RunEnd`, because retrospective Stage-III events (bank
//!   spans, wake stalls) arrive after the trace stream ends, via
//!   [`WalSink::append_event`].
//! * [`WalSink::close`] writes the terminal `RunEnd` (with the run's
//!   [`AccessStats`] when the caller has them) and seals the final
//!   segment. A log missing `RunEnd` is, by definition, a crashed or
//!   in-flight run.

use std::path::Path;

use crate::trace::sink::{MemoryDesc, RunEvent, TraceSink};
use crate::trace::AccessStats;

use super::event::{encode, EventRecord, ObsEvent};
use super::wal::WalWriter;
use super::ObsError;

/// Append-only WAL producer implementing [`TraceSink`]. Tee it next to
/// a `MaterializeSink` (or any other sink) to observe a run without
/// changing its results.
pub struct WalSink {
    writer: WalWriter,
    seq: u64,
    last_t: u64,
    end: Option<u64>,
    error: Option<ObsError>,
}

impl WalSink {
    /// Create the log directory and segment 0. `run_id` stamps the
    /// header and the `RunStart` record; pass `wall_unix_ms = 0` for
    /// byte-deterministic logs (the lab does).
    pub fn create(dir: &Path, run_id: u64, wall_unix_ms: u64) -> std::io::Result<WalSink> {
        Ok(WalSink {
            writer: WalWriter::create(dir, run_id, wall_unix_ms)?,
            seq: 0,
            last_t: 0,
            end: None,
            error: None,
        })
    }

    /// Override the segment rotation threshold (see
    /// [`WalWriter::with_rotate_bytes`]).
    pub fn with_rotate_bytes(mut self, bytes: u64) -> WalSink {
        self.writer = self.writer.with_rotate_bytes(bytes);
        self
    }

    pub fn run_id(&self) -> u64 {
        self.writer.run_id()
    }

    /// The first I/O error hit so far, if any (the sink is a no-op once
    /// this is set; [`WalSink::close`] returns it).
    pub fn error(&self) -> Option<&ObsError> {
        self.error.as_ref()
    }

    fn write(&mut self, t: u64, event: ObsEvent) {
        if self.error.is_some() {
            return;
        }
        // Clamp: retrospective events carry their true times in the
        // payload; the envelope stamp must never go backwards.
        let t = t.max(self.last_t);
        let rec = EventRecord { seq: self.seq, t, event };
        if let Err(e) = self.writer.append(&encode(&rec)) {
            self.error = Some(ObsError::Io(e));
            return;
        }
        self.seq += 1;
        self.last_t = t;
    }

    /// Append a post-stream event (Stage-III bank spans / wake stalls
    /// arrive after `finish`). `t` is the envelope stamp and is clamped
    /// non-decreasing like every other record.
    pub fn append_event(&mut self, t: u64, event: &RunEvent) {
        self.write(t, ObsEvent::of_run_event(event));
    }

    /// Write the terminal `RunEnd` record and seal the log. The end
    /// time is the one `finish` reported (falling back to the last
    /// envelope stamp for runs that never finished a trace stream).
    pub fn close(mut self, stats: Option<&AccessStats>) -> Result<(), ObsError> {
        let end = self.end.unwrap_or(self.last_t);
        self.write(end, ObsEvent::RunEnd { end, stats: stats.cloned() });
        if let Some(e) = self.error {
            return Err(e);
        }
        self.writer.close()?;
        Ok(())
    }
}

impl TraceSink for WalSink {
    fn begin(&mut self, memories: &[MemoryDesc]) {
        let run_id = self.writer.run_id();
        self.write(0, ObsEvent::RunStart { run_id, memories: memories.to_vec() });
    }

    fn on_sample(&mut self, mem: usize, t: u64, needed: u64, obsolete: u64) {
        self.write(t, ObsEvent::Sample { mem: mem as u32, needed, obsolete });
    }

    fn on_event(&mut self, t: u64, event: &RunEvent) {
        self.write(t, ObsEvent::of_run_event(event));
    }

    fn finish(&mut self, end: u64) {
        self.end = Some(end);
    }
}

#[cfg(test)]
mod tests {
    use std::fs;
    use std::path::PathBuf;

    use super::super::wal::EventLog;
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "trapti-walsink-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn mems() -> Vec<MemoryDesc> {
        vec![
            MemoryDesc { name: "sram".into(), capacity: 1024 },
            MemoryDesc { name: "kv".into(), capacity: 512 },
        ]
    }

    #[test]
    fn full_protocol_produces_an_ordered_complete_log() {
        let dir = tmp_dir("protocol");
        let mut sink = WalSink::create(&dir, 0x51, 0).unwrap();
        sink.begin(&mems());
        sink.on_event(0, &RunEvent::StageStart { stage: 0 });
        sink.on_sample(0, 0, 100, 0);
        sink.on_sample(1, 5, 40, 8);
        sink.on_event(9, &RunEvent::StageEnd { stage: 0 });
        sink.finish(12);
        sink.append_event(
            12,
            &RunEvent::BankSpan { bank: 0, state: "gated", t0: 3, t1: 12 },
        );
        sink.close(None).unwrap();

        let log = EventLog::open(&dir).unwrap();
        assert!(log.complete());
        assert!(!log.truncated);
        assert_eq!(log.records.len(), 7);
        assert!(matches!(log.records[0].event, ObsEvent::RunStart { .. }));
        assert!(matches!(
            log.records.last().unwrap().event,
            ObsEvent::RunEnd { end: 12, .. }
        ));
        // Envelope stamps: seq dense from 0, t non-decreasing.
        for (i, r) in log.records.iter().enumerate() {
            assert_eq!(r.seq, i as u64);
        }
        for w in log.records.windows(2) {
            assert!(w[0].t <= w[1].t);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn unclosed_sink_leaves_an_incomplete_log() {
        let dir = tmp_dir("unclosed");
        let mut sink = WalSink::create(&dir, 1, 0).unwrap();
        sink.begin(&mems());
        sink.on_sample(0, 4, 10, 0);
        drop(sink);
        let log = EventLog::open(&dir).unwrap();
        assert_eq!(log.records.len(), 2);
        assert!(!log.complete());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn envelope_time_never_goes_backwards() {
        let dir = tmp_dir("clamp");
        let mut sink = WalSink::create(&dir, 1, 0).unwrap();
        sink.begin(&mems());
        sink.on_sample(0, 50, 1, 0);
        // Retrospective event stamped "earlier" than the stream head.
        sink.append_event(
            10,
            &RunEvent::WakeStall { bank: 0, at: 10, stall_cycles: 4 },
        );
        sink.close(None).unwrap();
        let log = EventLog::open(&dir).unwrap();
        for w in log.records.windows(2) {
            assert!(w[0].t <= w[1].t, "clamped envelope must be monotone");
        }
        // ...while the payload keeps the true time.
        assert!(matches!(
            log.records[2].event,
            ObsEvent::WakeStall { at: 10, .. }
        ));
        let _ = fs::remove_dir_all(&dir);
    }
}
