//! The `repro watch` live view: tail a WAL directory and render the
//! run's progress as a small text panel.
//!
//! A view is a pure function of one log read ([`WatchView::load`] →
//! [`WatchView::render`]), so watching is just re-reading the directory
//! on an interval — the WAL's append-only prefix property guarantees
//! each render is a refinement of the previous one. A directory that
//! does not exist yet (run not started) renders as a waiting line
//! rather than an error, so `repro watch` can be started before the
//! run it observes.

use std::fmt::Write as _;
use std::path::Path;

use crate::util::table::fmt_mib;

use super::metrics::MetricsSnapshot;
use super::wal::EventLog;
use super::ObsError;

/// One rendered observation of a WAL directory.
#[derive(Debug, Clone)]
pub struct WatchView {
    /// `None` while the WAL directory does not exist yet.
    pub snapshot: Option<MetricsSnapshot>,
}

impl WatchView {
    /// Read the log and fold it. A missing directory yields the
    /// "waiting" view; anything else propagates.
    pub fn load(dir: &Path) -> Result<WatchView, ObsError> {
        match EventLog::open(dir) {
            Ok(log) => Ok(WatchView {
                snapshot: Some(MetricsSnapshot::from_log(&log)),
            }),
            Err(ObsError::Io(e)) if e.kind() == std::io::ErrorKind::NotFound => {
                Ok(WatchView { snapshot: None })
            }
            Err(e) => Err(e),
        }
    }

    /// True once the observed run has written its `RunEnd` record —
    /// the watcher's stop condition.
    pub fn complete(&self) -> bool {
        self.snapshot.as_ref().is_some_and(|s| s.complete)
    }

    /// Render the panel. Deterministic for a given log state.
    pub fn render(&self) -> String {
        let Some(s) = &self.snapshot else {
            return "watch: waiting for WAL directory to appear\n".to_string();
        };
        let mut out = String::with_capacity(512);
        let status = if s.complete {
            "complete"
        } else if s.truncated {
            "torn tail"
        } else {
            "in flight"
        };
        let _ = writeln!(out, "run {:016x}  [{status}]", s.run_id);
        let _ = writeln!(out, "  cycles   {}", s.cycles);
        let _ = writeln!(
            out,
            "  events   {}  ({} samples)",
            s.events_total,
            s.samples_total()
        );
        let _ = writeln!(
            out,
            "  stages   {} started / {} completed",
            s.stages_started, s.stages_completed
        );
        if s.requests_admitted > 0 || s.requests_completed > 0 {
            let _ = writeln!(
                out,
                "  serving  {} admitted / {} completed",
                s.requests_admitted, s.requests_completed
            );
        }
        for m in &s.memories {
            let _ = writeln!(
                out,
                "  mem {:<10} cur {:>10}  peak {:>10}  cap {:>10}",
                m.name,
                fmt_mib(m.current_occupied),
                fmt_mib(m.peak_occupied),
                fmt_mib(m.capacity)
            );
        }
        if !s.bank_states.is_empty() {
            let states = s
                .bank_states
                .iter()
                .map(|(state, count, cycles)| format!("{state} {count}x/{cycles}cy"))
                .collect::<Vec<_>>()
                .join("  ");
            let _ = writeln!(out, "  banks    {states}");
        }
        if s.wake_stalls > 0 {
            let _ = writeln!(
                out,
                "  stalls   {} wakes, {} cycles ({:.2}%)",
                s.wake_stalls,
                s.wake_stall_cycles,
                s.stall_pct()
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;

    use crate::trace::sink::{MemoryDesc, RunEvent, TraceSink};

    use super::super::sink::WalSink;
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "trapti-watch-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn missing_directory_renders_waiting() {
        let dir = tmp_dir("waiting");
        let view = WatchView::load(&dir).unwrap();
        assert!(view.snapshot.is_none());
        assert!(!view.complete());
        assert!(view.render().contains("waiting"));
    }

    #[test]
    fn in_flight_then_complete() {
        let dir = tmp_dir("flight");
        let mut wal = WalSink::create(&dir, 0xab, 0).unwrap();
        wal.begin(&[MemoryDesc { name: "sram".into(), capacity: 1 << 20 }]);
        wal.on_sample(0, 5, 4096, 0);

        // Note: the live segment is readable mid-run.
        let view = WatchView::load(&dir).unwrap();
        assert!(!view.complete());
        let text = view.render();
        assert!(text.contains("[in flight]"), "{text}");
        assert!(text.contains("cycles   5"), "{text}");
        assert!(text.contains("mem sram"), "{text}");

        wal.finish(10);
        wal.append_event(10, &RunEvent::WakeStall { bank: 0, at: 7, stall_cycles: 2 });
        wal.close(None).unwrap();
        let view = WatchView::load(&dir).unwrap();
        assert!(view.complete());
        let text = view.render();
        assert!(text.contains("[complete]"), "{text}");
        assert!(text.contains("stalls   1 wakes"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
