//! # TRAPTI — Time-Resolved Analysis for SRAM Banking and Power Gating
//!
//! Reproduction of *"TRAPTI: Time-Resolved Analysis for SRAM Banking and
//! Power Gating Optimization in Embedded Transformer Inference"* as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! ## The two-stage flow
//!
//! * **Stage I** ([`sim`], [`memory`], [`trace`], [`workload`]): a
//!   TransInferSim-equivalent discrete-event, cycle-level simulator of
//!   Transformer inference on a systolic-array accelerator, producing
//!   time-resolved SRAM occupancy traces and access statistics. Traces
//!   can be materialized ([`trace::OccupancyTrace`]) or streamed to
//!   O(1)-memory consumers via [`trace::TraceSink`].
//! * **Stage II** ([`cacti`], [`banking`]): offline exploration of banked
//!   SRAM organizations and power-gating policies driven by the Stage-I
//!   trace (Eqs. 1–5 of the paper), plus the Pareto/portfolio optimizer
//!   ([`banking::optimize`](mod@banking::optimize)) that chooses among
//!   the evaluated candidates.
//! * **Stage III** ([`banking::online`]): execution-driven online
//!   gating co-simulation — one chosen configuration replays cycle by
//!   cycle against the live Stage-I stream with per-bank state machines
//!   and wake-latency stalls fed back into timing. Bit-identical to the
//!   offline evaluator at zero wake latency (the reconciliation
//!   property), it measures the stall-adjusted end-to-end cycles the
//!   trace-driven model can only bound (`repro replay`,
//!   [`api::online_validate`]).
//! * **Serving** ([`serving`], [`sim::serving`]): multi-tenant request
//!   workloads — concurrent decode streams over a paged KV arena with
//!   continuous-batching admission — producing merged occupancy traces
//!   through the same [`trace`] machinery, so Stage II answers the
//!   banking question for serving-shaped traffic too
//!   (`api::ExperimentSpec::run_serving`, `repro serve`).
//! * **Functional layer** ([`runtime`]): AOT-compiled JAX/Pallas decode
//!   models (HLO text in `artifacts/`) executed through PJRT — Python is
//!   never on the request path. Offline builds link an API-compatible
//!   stub (`runtime::xla_stub`).
//!
//! ## Entry points
//!
//! **[`api`] is the programmatic surface**: build an
//! [`api::ExperimentSpec`], run it into an [`api::Stage1Run`], derive an
//! [`api::Stage2Run`] over borrowed trace views, or execute a whole grid
//! of specs concurrently with [`api::BatchRunner`] (memoized by spec
//! content hash). The paper's figures/tables are one call away in
//! [`api::experiments`].
//!
//! ```no_run
//! use trapti::api::{ApiContext, BatchRunner, ExperimentSpec};
//! use trapti::workload::{DS_R1D_Q15B, GPT2_XL};
//!
//! let ctx = ApiContext::new();
//! // One scenario, two typed stages.
//! let s1 = ExperimentSpec::builder()
//!     .model(DS_R1D_Q15B)
//!     .prefill(2048)
//!     .build()
//!     .unwrap()
//!     .run_stage1(&ctx)
//!     .unwrap();
//! println!("peak needed: {} bytes", s1.result.peak_needed());
//! let s2 = s1.stage2(&ctx).unwrap();
//! println!("best dE: {:.1}%", s2.best_delta_pct());
//!
//! // Or a whole grid of scenarios as one parallel, memoized batch.
//! let specs = vec![
//!     ExperimentSpec::builder().model(GPT2_XL).prefill(2048).build().unwrap(),
//!     ExperimentSpec::builder().model(DS_R1D_Q15B).prefill(2048).build().unwrap(),
//! ];
//! for r in BatchRunner::new().run(&specs).unwrap() {
//!     print!("{}", r.report());
//! }
//! ```
//!
//! For many specs across many grids, [`lab`] is the experiment
//! manager: a declarative TOML manifest expands into a DAG of
//! Stage I/II/III jobs executed in parallel into a content-addressed,
//! crash-resumable artifact store (`repro lab run|list|gc|trace-params`).
//!
//! Other entry points: the `repro` binary (CLI — see `docs/API.md`),
//! `examples/` (`cargo run --release --example quickstart`), and the
//! paper benches (`cargo bench`). [`coordinator::Coordinator`] remains
//! as a thin deprecated shim over [`api`] for older call sites.

pub mod analytic;
pub mod api;
pub mod banking;
pub mod cacti;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod lab;
pub mod memory;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod trace;
pub mod util;
pub mod workload;
