//! # TRAPTI — Time-Resolved Analysis for SRAM Banking and Power Gating
//!
//! Reproduction of *"TRAPTI: Time-Resolved Analysis for SRAM Banking and
//! Power Gating Optimization in Embedded Transformer Inference"* as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Stage I** ([`sim`], [`memory`], [`trace`], [`workload`]): a
//!   TransInferSim-equivalent discrete-event, cycle-level simulator of
//!   Transformer inference on a systolic-array accelerator, producing
//!   time-resolved SRAM occupancy traces and access statistics.
//! * **Stage II** ([`cacti`], [`banking`]): offline exploration of banked
//!   SRAM organizations and power-gating policies driven by the Stage-I
//!   trace (Eqs. 1-5 of the paper).
//! * **Functional layer** ([`runtime`]): AOT-compiled JAX/Pallas decode
//!   models (HLO text in `artifacts/`) executed through PJRT — Python is
//!   never on the request path.
//!
//! Entry points: the `repro` binary (CLI), [`coordinator::Coordinator`]
//! (programmatic), and `examples/`.

pub mod analytic;
pub mod banking;
pub mod cacti;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod memory;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod util;
pub mod workload;
