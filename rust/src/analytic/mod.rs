//! Aggregate-statistics (Timeloop/MAESTRO-class) baseline estimator —
//! the prior-work comparator that lacks time-resolved occupancy.

pub mod baseline;

pub use baseline::{estimate, AggregateEstimate, AggregateView};
