//! Analytic comparators: the aggregate-statistics
//! (Timeloop/MAESTRO-class) baseline that lacks time-resolved
//! occupancy, and the PIM-offload baseline where attention never
//! touches SRAM.

pub mod baseline;
pub mod pim;

pub use baseline::{estimate, AggregateEstimate, AggregateView};
pub use pim::{estimate_pim, PimEstimate, E_PIM_MAC_J, E_PIM_WRITE_J_PER_BYTE};
