//! Aggregate-statistics baseline estimator (the prior-work comparator).
//!
//! Timeloop/MAESTRO-class DSE flows see only aggregate quantities — peak
//! capacity and total access counts — not execution-aligned occupancy
//! traces (paper §I, §II-C "Gap and motivation"). This module implements
//! that estimator faithfully so the benefit of time-resolved analysis can
//! be *measured*: the aggregate view must keep every bank on whenever the
//! workload might need it, because without Δt_k segments it cannot prove
//! any idle interval exceeds break-even.

use crate::cacti::CactiModel;
use crate::trace::AccessStats;

/// What an aggregate-only flow knows about a workload.
#[derive(Debug, Clone, Copy)]
pub struct AggregateView {
    /// Peak bytes ever needed (reported by capacity planning).
    pub peak_bytes: u64,
    /// Total run time, cycles.
    pub total_cycles: u64,
    /// Total access counts.
    pub reads: u64,
    pub writes: u64,
}

impl AggregateView {
    /// Collapse a full Stage-I result into the aggregate view (throwing
    /// away exactly the information TRAPTI keeps).
    pub fn from_stats(peak_bytes: u64, total_cycles: u64, stats: &AccessStats) -> Self {
        Self {
            peak_bytes,
            total_cycles,
            reads: stats.reads,
            writes: stats.writes,
        }
    }
}

/// Aggregate-only energy estimate for a (C, B) candidate.
///
/// Dynamic energy is identical to Eq. 3 (access counts are aggregate
/// data). Leakage, however, must assume the *static worst case*: all
/// banks that could ever hold needed data stay on for the whole run —
/// the peak-occupancy bank count, held for `total_cycles`. With no
/// temporal information there is no sound basis to gate below the peak.
#[derive(Debug, Clone, Copy)]
pub struct AggregateEstimate {
    pub e_dyn_j: f64,
    pub e_leak_j: f64,
    /// Banks the aggregate flow keeps powered (peak-based).
    pub static_active_banks: u32,
}

impl AggregateEstimate {
    pub fn e_total_j(&self) -> f64 {
        self.e_dyn_j + self.e_leak_j
    }
}

pub fn estimate(
    cacti: &CactiModel,
    view: &AggregateView,
    capacity: u64,
    banks: u32,
    alpha: f64,
    freq_ghz: f64,
) -> AggregateEstimate {
    let ch = cacti.characterize(capacity, banks);
    let e_dyn = view.reads as f64 * ch.e_read_j + view.writes as f64 * ch.e_write_j;
    let active = crate::banking::banks_required(view.peak_bytes, capacity, banks, alpha);
    // Peak-driven static decision: `active` banks on for the whole run.
    let seconds = view.total_cycles as f64 / (freq_ghz * 1e9);
    let e_leak = ch.p_leak_bank_w * active as f64 * seconds;
    AggregateEstimate {
        e_dyn_j: e_dyn,
        e_leak_j: e_leak,
        static_active_banks: active,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::banking::{evaluate, GatingPolicy};
    use crate::trace::OccupancyTrace;
    use crate::util::MIB;

    /// Bursty trace: needed occupancy is at peak only 10% of the time.
    fn bursty_trace(cycles: u64) -> (OccupancyTrace, AccessStats) {
        let mut tr = OccupancyTrace::new("sram", 128 * MIB);
        let mut t = 0;
        while t < cycles {
            tr.record(t, 100 * MIB, 0); // short burst at peak
            tr.record(t + 100_000, 10 * MIB, 0); // long low phase
            t += 1_000_000;
        }
        tr.finalize(cycles);
        let stats = AccessStats {
            reads: 1_000_000,
            writes: 500_000,
            ..Default::default()
        };
        (tr, stats)
    }

    #[test]
    fn aggregate_cannot_gate_below_peak() {
        let (tr, stats) = bursty_trace(100_000_000);
        let cacti = CactiModel::default();
        let view = AggregateView::from_stats(tr.peak_needed(), 100_000_000, &stats);
        let agg = estimate(&cacti, &view, 128 * MIB, 8, 0.9, 1.0);
        // Peak 100 MiB at 8 banks of 16 MiB, alpha 0.9 -> 7 banks pinned.
        assert_eq!(agg.static_active_banks, 7);

        // TRAPTI's trace-driven evaluation gates the low phases.
        let trapti = evaluate(
            &cacti, &tr, &stats, 128 * MIB, 8, 0.9,
            GatingPolicy::Aggressive, 1.0,
        )
        .unwrap();
        assert!(
            trapti.e_leak_j < agg.e_leak_j * 0.55,
            "time-resolved {} vs aggregate {} J",
            trapti.e_leak_j,
            agg.e_leak_j
        );
    }

    #[test]
    fn dynamic_energy_identical_to_eq3() {
        // Aggregate flows do get Eq. 3 right — only leakage differs.
        let (tr, stats) = bursty_trace(50_000_000);
        let cacti = CactiModel::default();
        let view = AggregateView::from_stats(tr.peak_needed(), 50_000_000, &stats);
        let agg = estimate(&cacti, &view, 128 * MIB, 4, 0.9, 1.0);
        let trapti = evaluate(
            &cacti, &tr, &stats, 128 * MIB, 4, 0.9,
            GatingPolicy::Aggressive, 1.0,
        )
        .unwrap();
        assert!((agg.e_dyn_j - trapti.e_dyn_j).abs() < 1e-12);
    }

    #[test]
    fn flat_workload_closes_the_gap() {
        // When occupancy is constant at peak, time resolution buys
        // nothing — both estimators agree (sanity against over-claiming).
        let mut tr = OccupancyTrace::new("sram", 128 * MIB);
        tr.record(0, 100 * MIB, 0);
        tr.finalize(10_000_000);
        let stats = AccessStats { reads: 1000, writes: 1000, ..Default::default() };
        let cacti = CactiModel::default();
        let view = AggregateView::from_stats(tr.peak_needed(), 10_000_000, &stats);
        let agg = estimate(&cacti, &view, 128 * MIB, 8, 0.9, 1.0);
        let trapti = evaluate(
            &cacti, &tr, &stats, 128 * MIB, 8, 0.9,
            GatingPolicy::Aggressive, 1.0,
        )
        .unwrap();
        // TRAPTI still gates the never-needed top bank(s); the pinned
        // ones match the aggregate count.
        let ratio = trapti.e_leak_j / agg.e_leak_j;
        assert!(ratio > 0.95 && ratio <= 1.3, "ratio={ratio}");
    }
}
