//! PIM-offload baseline: attention computed in memory, KV never
//! SRAM-resident (the X-Former-class comparator from PAPERS.md).
//!
//! Processing-in-memory accelerators hold the KV cache inside the
//! compute arrays and evaluate the score/context matmuls there, so the
//! on-chip SRAM only ever sees weights and activations. As a
//! comparison column this answers: how much of TRAPTI's banking +
//! gating headroom would an architectural change (offload) capture
//! instead? The estimate is closed-form over the model/workload shape —
//! deliberately trace-free, like the aggregate baseline next door — and
//! charges the PIM side per MAC and per KV byte written into the
//! arrays.

use crate::workload::{ModelPreset, Workload};

/// Energy per in-memory MAC, joules (~0.4 pJ — ReRAM crossbar figure,
/// X-Former §V).
pub const E_PIM_MAC_J: f64 = 0.4e-12;

/// Energy per KV byte written into the PIM arrays, joules (~10 pJ —
/// NVM writes dominate the offload's dynamic cost).
pub const E_PIM_WRITE_J_PER_BYTE: f64 = 10e-12;

/// Closed-form PIM-offload estimate for one (model, workload) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PimEstimate {
    /// Attention MACs moved into the arrays (score + context).
    pub attn_macs: u64,
    /// KV bytes written into the arrays (every token's KV, once).
    pub kv_write_bytes: u64,
    /// PIM-side energy: `attn_macs * E_PIM_MAC_J + kv_write_bytes *
    /// E_PIM_WRITE_J_PER_BYTE`.
    pub e_pim_j: f64,
    /// KV footprint that no longer competes for SRAM (window/latent
    /// aware — this is `ModelPreset::kv_cache_bytes` at the final
    /// context).
    pub kv_cache_bytes: u64,
}

impl PimEstimate {
    /// SRAM peak with the KV evicted to the arrays. Conservative: the
    /// KV may not all be resident at the observed peak instant, so the
    /// true relieved peak is at least this.
    pub fn relieved_peak(&self, peak_needed: u64) -> u64 {
        peak_needed.saturating_sub(self.kv_cache_bytes)
    }
}

/// Estimate the PIM offload for `workload` on `model`. Serving has no
/// single closed form (per-request contexts vary) — returns `None`.
pub fn estimate_pim(model: &ModelPreset, workload: &Workload) -> Option<PimEstimate> {
    let (attn_macs, final_ctx) = match *workload {
        Workload::Prefill { seq } => {
            let macs = model.layers as u64
                * 2
                * model.heads as u64
                * seq as u64
                * model.kv_horizon(seq as u64)
                * model.d_head as u64;
            (macs, seq as u64)
        }
        Workload::Decode { prompt, gen } => {
            // One query token per step; context grows (window-capped).
            let mut per_layer = 0u64;
            for t in 0..gen as u64 {
                let ctx = model.kv_horizon(prompt as u64 + t + 1);
                per_layer += 2 * model.heads as u64 * ctx * model.d_head as u64;
            }
            (
                model.layers as u64 * per_layer,
                prompt as u64 + gen as u64,
            )
        }
        Workload::Serving(_) => return None,
    };
    // Every token's KV enters the arrays exactly once; a sliding window
    // saves *capacity* (old entries overwritten), not write traffic.
    let kv_write_bytes = model.layers as u64 * final_ctx * model.kv_token_bytes();
    Some(PimEstimate {
        attn_macs,
        kv_write_bytes,
        e_pim_j: attn_macs as f64 * E_PIM_MAC_J
            + kv_write_bytes as f64 * E_PIM_WRITE_J_PER_BYTE,
        kv_cache_bytes: model.kv_cache_bytes(final_ctx),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::ServingParams;
    use crate::workload::{FIG1_MHA, FIG1_MLA, FIG1_MQA, FIG1_SWA, TINY_GQA};

    #[test]
    fn prefill_macs_match_closed_form() {
        let est = estimate_pim(&TINY_GQA, &Workload::Prefill { seq: 64 }).unwrap();
        let m = &TINY_GQA;
        assert_eq!(
            est.attn_macs,
            m.layers as u64 * 2 * m.heads as u64 * 64 * 64 * m.d_head as u64
        );
        assert_eq!(
            est.kv_write_bytes,
            m.layers as u64 * 64 * m.kv_token_bytes()
        );
        assert_eq!(est.kv_cache_bytes, m.kv_cache_bytes(64));
        assert!(est.e_pim_j > 0.0);
    }

    #[test]
    fn window_caps_macs_but_not_write_traffic() {
        let wl = Workload::Decode { prompt: 512, gen: 8 };
        let full = estimate_pim(&FIG1_MHA, &wl).unwrap();
        let swa = estimate_pim(&FIG1_SWA, &wl).unwrap();
        assert!(swa.attn_macs < full.attn_macs, "window must cap context MACs");
        assert_eq!(swa.kv_write_bytes, full.kv_write_bytes);
        assert!(swa.kv_cache_bytes < full.kv_cache_bytes);
    }

    #[test]
    fn latent_kv_shrinks_array_writes() {
        let wl = Workload::Prefill { seq: 256 };
        let mha = estimate_pim(&FIG1_MHA, &wl).unwrap();
        let mqa = estimate_pim(&FIG1_MQA, &wl).unwrap();
        let mla = estimate_pim(&FIG1_MLA, &wl).unwrap();
        assert!(mqa.kv_write_bytes < mha.kv_write_bytes);
        assert!(mla.kv_write_bytes < mqa.kv_write_bytes);
    }

    #[test]
    fn serving_has_no_closed_form() {
        let wl = Workload::Serving(ServingParams::new(8, 2, 7));
        assert!(estimate_pim(&TINY_GQA, &wl).is_none());
    }

    #[test]
    fn relieved_peak_saturates() {
        let est = estimate_pim(&TINY_GQA, &Workload::Prefill { seq: 64 }).unwrap();
        assert_eq!(est.relieved_peak(est.kv_cache_bytes / 2), 0);
        assert_eq!(
            est.relieved_peak(est.kv_cache_bytes + 10),
            10
        );
    }
}
