//! Discrete-event simulation engine (Stage I).
//!
//! Execution model (DESIGN.md §5):
//!
//! 1. Ops *issue* in graph order within a bounded in-order window
//!    (`SchedConfig::issue_window`) once their dataflow deps complete —
//!    TransInferSim-style execution-plan semantics. Weight tensors of ops
//!    slightly ahead of the watermark prefetch opportunistically.
//! 2. Issue triggers input fetches: tensors not resident in the op's
//!    memory arrive via DRAM/sibling-memory transfers (timed on ports).
//! 3. Matmuls split into `subops` sub-operations dispatched across free
//!    systolic arrays; each subop's duration is
//!    `max(systolic cycles, operand-stream reservation)` — streaming
//!    reserves SRAM port bandwidth, so concurrent arrays contend and the
//!    run becomes memory-bound exactly when demand exceeds the 4x64 B/cy
//!    interface (the paper's Fig. 6 stalls). Softmax/norm/element-wise
//!    ops execute on the memory path (port-reserved streaming).
//! 4. Completion decrements consumer counts; tensors with no remaining
//!    readers become *obsolete* (except persistent KV / outputs), feeding
//!    the needed/obsolete occupancy trace.

use std::cmp::Reverse;
use std::collections::BTreeMap;
use std::collections::BinaryHeap;

use anyhow::{Context, Result};

use crate::config::AccelConfig;
use crate::memory::MemorySystem;
use crate::trace::sink::{MemoryDesc, RunEvent, TraceSink};
use crate::workload::{
    KvResidency, OpClass, OpId, OpKind, TensorKind, WorkloadGraph,
};

use super::stats::{new_result, OpBreakdown, SimResult};
use super::systolic::{matmul_timing, split_subops};

const T_UNSET: u64 = u64::MAX;

/// Simulation knobs beyond the accelerator config.
///
/// * `sink` — optional streaming consumer of occupancy changes; the
///   engine forwards every state change of every on-chip memory as it
///   happens (same piecewise-constant semantics as the materialized
///   trace — see `trace::sink` module docs).
/// * `materialize` — when false, on-chip memories skip building their
///   `OccupancyTrace` (the `SimResult` traces stay empty), so a
///   sink-only run holds O(1) trace memory. Leave true whenever Stage II
///   will consume `SimResult::traces`.
pub struct SimOptions<'s> {
    pub sink: Option<&'s mut dyn TraceSink>,
    pub materialize: bool,
}

impl Default for SimOptions<'_> {
    fn default() -> Self {
        Self {
            sink: None,
            materialize: true,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Event {
    /// All input fetches for an op have landed.
    FetchDone(OpId),
    /// One systolic subop finished.
    SubopDone(OpId),
    /// A memory-path op finished.
    MemOpDone(OpId),
}

#[derive(Debug, Clone, Copy)]
struct OpRuntime {
    issued: bool,
    done: bool,
    subops_remaining: u32,
    t_deps_ready: u64,
    t_issue: u64,
    t_fetch_done: u64,
    /// Sum of pure-compute cycles over subops.
    compute_cycles: u64,
    /// Stream-bandwidth stall beyond compute.
    stream_stall: u64,
    /// Memory index this op executes against.
    mem: u8,
    /// Matmul outputs allocate lazily at first subop dispatch.
    outputs_allocated: bool,
}

impl Default for OpRuntime {
    fn default() -> Self {
        Self {
            issued: false,
            done: false,
            subops_remaining: 0,
            t_deps_ready: T_UNSET,
            t_issue: 0,
            t_fetch_done: 0,
            compute_cycles: 0,
            stream_stall: 0,
            mem: 0,
            outputs_allocated: false,
        }
    }
}

pub struct Simulator<'g> {
    graph: &'g WorkloadGraph,
    cfg: AccelConfig,
    mem: MemorySystem,
    ops: Vec<OpRuntime>,
    consumers_remaining: Vec<u32>,
    /// Unfinished producer-op count per op (0 == dataflow-ready);
    /// maintained incrementally via `dependents` (EXPERIMENTS.md §Perf
    /// L3-2) instead of rescanning reads on every event.
    deps_remaining: Vec<u32>,
    /// Ops unblocked by each op's completion (deduplicated).
    dependents: Vec<Vec<u32>>,
    /// Earliest incomplete op index (issue-window base).
    watermark: usize,
    events: BinaryHeap<Reverse<(u64, u64)>>,
    event_payload: Vec<Event>,
    /// Free-at times per systolic array.
    sa_free: Vec<u64>,
    sa_busy: u64,
    /// FIFO subop queue: (op, m, k, n) awaiting a free array.
    sa_queue: std::collections::VecDeque<(OpId, u32, u32, u32)>,
    now: u64,
    /// Dedicated memory-path (softmax/norm/elementwise) unit free-at.
    mem_unit_free: u64,
    /// Distinct on-chip memories with arrays attached.
    mem_groups: Vec<u8>,
    /// Last (needed, obsolete) forwarded to the sink, per memory
    /// (suppresses no-change emissions between events).
    last_emitted: Vec<(u64, u64)>,
    /// Ops per dataflow stage (for StageStart/StageEnd events).
    stage_total: BTreeMap<u32, u32>,
    stage_issued: BTreeMap<u32, u32>,
    stage_done: BTreeMap<u32, u32>,
    /// Structural events raised during the current event batch, all at
    /// `now`; flushed to the sink at batch boundaries beside the
    /// occupancy emission (dropped when no sink is attached).
    pending_events: Vec<RunEvent>,
}

impl<'g> Simulator<'g> {
    pub fn new(graph: &'g WorkloadGraph, cfg: &AccelConfig) -> Result<Self> {
        cfg.validate()?;
        graph.validate()?;
        let consumers = graph
            .tensors
            .iter()
            .map(|t| t.consumers.len() as u32)
            .collect();
        // Dependency graph at op granularity (distinct producers only).
        let mut deps_remaining = vec![0u32; graph.ops.len()];
        let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); graph.ops.len()];
        let mut scratch: Vec<u32> = Vec::new();
        for (i, op) in graph.ops.iter().enumerate() {
            scratch.clear();
            for &r in &op.reads {
                if let Some(pr) = graph.tensor(r).producer {
                    if pr.0 as usize != i && !scratch.contains(&pr.0) {
                        scratch.push(pr.0);
                    }
                }
            }
            deps_remaining[i] = scratch.len() as u32;
            for &pr in &scratch {
                dependents[pr as usize].push(i as u32);
            }
        }
        let mut mem_groups: Vec<u8> = cfg.topology.mem_of_sa.clone();
        mem_groups.sort_unstable();
        mem_groups.dedup();
        let mut stage_total: BTreeMap<u32, u32> = BTreeMap::new();
        for op in &graph.ops {
            *stage_total.entry(op.stage).or_insert(0) += 1;
        }
        Ok(Self {
            graph,
            cfg: cfg.clone(),
            mem: MemorySystem::new(cfg),
            ops: vec![OpRuntime::default(); graph.ops.len()],
            consumers_remaining: consumers,
            deps_remaining,
            dependents,
            watermark: 0,
            events: BinaryHeap::new(),
            event_payload: Vec::new(),
            sa_free: vec![0; cfg.sa.count as usize],
            sa_busy: 0,
            sa_queue: std::collections::VecDeque::new(),
            now: 0,
            mem_unit_free: 0,
            mem_groups,
            last_emitted: vec![(0, 0); cfg.on_chip.len()],
            stage_total,
            stage_issued: BTreeMap::new(),
            stage_done: BTreeMap::new(),
            pending_events: Vec::new(),
        })
    }

    fn push_event(&mut self, t: u64, ev: Event) {
        let seq = self.event_payload.len() as u64;
        self.event_payload.push(ev);
        self.events.push(Reverse((t, seq)));
    }

    /// Dataflow readiness from the maintained counter.
    #[inline]
    fn is_ready(&self, i: usize) -> bool {
        self.deps_remaining[i] == 0
    }

    /// Run to completion; returns the Stage-I result bundle.
    pub fn run(mut self) -> Result<SimResult> {
        self.run_inner(&mut SimOptions::default())
    }

    /// Run with explicit options (streaming sink / no materialization).
    pub fn run_with(mut self, mut opts: SimOptions<'_>) -> Result<SimResult> {
        self.run_inner(&mut opts)
    }

    /// Forward occupancy changes since the last emission to the sink.
    /// All mutations within one event batch happen at `self.now`, so
    /// emitting at batch boundaries observes exactly the states the
    /// materialized trace retains (same-instant transients coalesce).
    fn emit_occupancy(&mut self, sink: &mut dyn TraceSink) {
        for (i, m) in self.mem.on_chip.iter().enumerate() {
            let cur = (m.needed_bytes(), m.obsolete_bytes());
            if self.last_emitted[i] != cur {
                self.last_emitted[i] = cur;
                sink.on_sample(i, self.now, cur.0, cur.1);
            }
        }
    }

    /// Forward structural events raised during this event batch (stage
    /// boundaries), stamped at the batch time. Emitted after the
    /// occupancy samples so an event never precedes the state it
    /// annotates at the same instant.
    fn flush_run_events(&mut self, sink: &mut dyn TraceSink) {
        for ev in self.pending_events.drain(..) {
            sink.on_event(self.now, &ev);
        }
    }

    fn run_inner(&mut self, opts: &mut SimOptions<'_>) -> Result<SimResult> {
        if !opts.materialize {
            self.mem.set_sample_recording(false);
        }
        if let Some(sink) = opts.sink.as_deref_mut() {
            let descs: Vec<MemoryDesc> = self
                .mem
                .on_chip
                .iter()
                .map(|m| MemoryDesc {
                    name: m.cfg.name.clone(),
                    capacity: m.cfg.capacity,
                })
                .collect();
            sink.begin(&descs);
        }
        self.try_issue()?;
        self.dispatch_sa();
        if let Some(sink) = opts.sink.as_deref_mut() {
            self.emit_occupancy(sink);
            self.flush_run_events(sink);
        } else {
            self.pending_events.clear();
        }

        while let Some(Reverse((t, seq))) = self.events.pop() {
            debug_assert!(t >= self.now, "event time went backwards");
            self.now = t;
            match self.event_payload[seq as usize] {
                Event::FetchDone(op) => self.on_fetch_done(op)?,
                Event::SubopDone(op) => self.on_subop_done(op)?,
                Event::MemOpDone(op) => self.complete_op(op)?,
            }
            self.try_issue()?;
            self.dispatch_sa();
            if let Some(sink) = opts.sink.as_deref_mut() {
                self.emit_occupancy(sink);
                self.flush_run_events(sink);
            } else {
                self.pending_events.clear();
            }
        }

        if let Some(stuck) = self.ops.iter().position(|o| !o.done) {
            anyhow::bail!(
                "deadlock: op {} ({}) never completed",
                stuck,
                self.graph.ops[stuck].name
            );
        }

        let end = self.now;
        self.mem.finalize(end);
        if let Some(sink) = opts.sink.as_deref_mut() {
            self.flush_run_events(sink);
            sink.finish(end);
        }
        let traces: Vec<_> = self.mem.on_chip.iter().map(|m| m.trace.clone()).collect();
        for tr in &traces {
            tr.validate().context("occupancy trace invariant")?;
        }
        let per_mem: Vec<_> = self.mem.on_chip.iter().map(|m| m.stats.clone()).collect();
        let stats = self.mem.total_stats();

        // Fig. 6 breakdown.
        let mut breakdown: BTreeMap<OpClass, OpBreakdown> = BTreeMap::new();
        for (i, rt) in self.ops.iter().enumerate() {
            let class = OpClass::of(&self.graph.ops[i]);
            let b = breakdown.entry(class).or_default();
            b.compute += rt.compute_cycles / self.cfg.sched.subops.max(1) as u64;
            b.memory += (rt.t_fetch_done - rt.t_issue) + rt.stream_stall;
            let ready = if rt.t_deps_ready == T_UNSET { rt.t_issue } else { rt.t_deps_ready };
            b.idle += rt.t_issue.saturating_sub(ready);
            b.count += 1;
        }

        Ok(new_result(
            &self.graph.name,
            &self.cfg,
            end,
            traces,
            stats,
            per_mem,
            breakdown,
            self.graph.total_macs(),
            self.sa_busy,
        ))
    }

    /// Advance the watermark, record readiness, and issue ready ops
    /// within the in-order window.
    fn try_issue(&mut self) -> Result<()> {
        while self.watermark < self.ops.len() && self.ops[self.watermark].done {
            self.watermark += 1;
        }
        let limit = (self.watermark + self.cfg.sched.issue_window).min(self.ops.len());
        let stage_limit = if self.watermark < self.ops.len() {
            self.graph.ops[self.watermark]
                .stage
                .saturating_add(self.cfg.sched.window_stages)
        } else {
            u32::MAX
        };
        for i in self.watermark..limit {
            if self.ops[i].issued {
                continue;
            }
            if self.graph.ops[i].stage > stage_limit {
                break; // stages are monotonic in graph order
            }
            if !self.is_ready(i) {
                continue;
            }
            if self.ops[i].t_deps_ready == T_UNSET {
                self.ops[i].t_deps_ready = self.now;
            }
            self.issue_op(OpId(i as u32))?;
        }
        Ok(())
    }

    /// Memory group for an op: single-memory -> 0; multi-level ->
    /// layers alternate between the dedicated memories (the paper's
    /// *non-optimized* placement, §IV-D: each layer's tensors live near
    /// one SA pair, so the residual stream hops dm -> shared -> dm'
    /// at every layer boundary — the measured coordination overhead).
    fn assign_mem(&mut self, stage: u32) -> u8 {
        self.mem_groups[stage as usize % self.mem_groups.len()]
    }

    fn issue_op(&mut self, op_id: OpId) -> Result<()> {
        let i = op_id.0 as usize;
        let stage = self.graph.ops[i].stage;
        let mem = self.assign_mem(stage);
        self.ops[i].issued = true;
        self.ops[i].t_issue = self.now;
        self.ops[i].mem = mem;
        let issued = self.stage_issued.entry(stage).or_insert(0);
        *issued += 1;
        if *issued == 1 {
            self.pending_events.push(RunEvent::StageStart { stage });
        }

        let mut ready = self.now;
        let reads = self.graph.ops[i].reads.clone();
        for r in reads {
            let info = self.graph.tensor(r).clone();
            let out = self
                .mem
                .ensure_resident(self.now, &info, mem as usize)
                .with_context(|| {
                    format!("fetching {} for {}", info.name, self.graph.ops[i].name)
                })?;
            ready = ready.max(out.ready_at);
        }
        self.push_event(ready, Event::FetchDone(op_id));
        Ok(())
    }

    /// Weight bytes this op streams from DRAM (weight-stationary arrays
    /// load weights directly into PE registers; see hierarchy.rs).
    fn weight_bytes(&self, op_id: OpId) -> u64 {
        self.graph.ops[op_id.0 as usize]
            .reads
            .iter()
            .map(|&r| {
                let t = self.graph.tensor(r);
                if t.kind == TensorKind::Weight {
                    t.bytes
                } else {
                    0
                }
            })
            .sum()
    }

    fn on_fetch_done(&mut self, op_id: OpId) -> Result<()> {
        let i = op_id.0 as usize;
        self.ops[i].t_fetch_done = self.now;
        let mem = self.ops[i].mem as usize;

        match self.graph.ops[i].kind {
            OpKind::MatMul { m, k, n } => {
                // Outputs allocate lazily at first subop dispatch so that
                // occupancy tracks execution, not issue runahead.
                let parts = split_subops(m, k, n, self.cfg.sched.subops);
                self.ops[i].subops_remaining = parts.len() as u32;
                for (pm, pk, pn) in parts {
                    self.sa_queue.push_back((op_id, pm, pk, pn));
                }
            }
            _ => {
                // Memory-path op on the dedicated near-memory unit
                // (serialized; does not occupy the SRAM data ports).
                self.allocate_outputs(op_id)?;
                let bytes = self.graph.ops[i].kind.streamed_bytes();
                let word = self.mem.on_chip[mem].cfg.bytes_per_cycle;
                let bpc = self.cfg.sched.mem_path_bytes_per_cycle as u64;
                let dur = self.mem.on_chip[mem].cfg.latency_cycles
                    + bytes.div_ceil(bpc);
                let start = self.now.max(self.mem_unit_free);
                let end = start + dur;
                self.mem_unit_free = end;
                let rd = bytes * 2 / 3;
                self.mem.on_chip[mem].stats.sram_read(rd, word, "act");
                self.mem.on_chip[mem].stats.sram_write(bytes - rd, word, "act");
                self.ops[i].compute_cycles +=
                    dur * self.cfg.sched.subops.max(1) as u64;
                self.ops[i].stream_stall += start - self.now;
                self.push_event(end, Event::MemOpDone(op_id));
            }
        }
        Ok(())
    }

    fn allocate_outputs(&mut self, op_id: OpId) -> Result<()> {
        let i = op_id.0 as usize;
        if self.ops[i].outputs_allocated {
            return Ok(());
        }
        self.ops[i].outputs_allocated = true;
        let mem = self.ops[i].mem as usize;
        let writes = self.graph.ops[i].writes.clone();
        for w in writes {
            let info = self.graph.tensor(w).clone();
            self.mem
                .allocate_output(self.now, &info, mem)
                .with_context(|| {
                    format!("allocating {} for {}", info.name, self.graph.ops[i].name)
                })?;
        }
        Ok(())
    }

    /// Dispatch queued subops onto arrays that are free *now*. No future
    /// booking: dispatch decisions are made event-by-event so that a
    /// consumer op becoming ready can claim the next free array ahead of
    /// queued later producers (min-op-id priority) — this is what lets
    /// attention transients retire as fast as bandwidth allows instead of
    /// piling up behind a pre-booked schedule.
    fn dispatch_sa(&mut self) {
        loop {
            if self.sa_queue.is_empty() {
                return;
            }
            let mut dispatched = false;
            for sa_idx in 0..self.sa_free.len() {
                if self.sa_free[sa_idx] > self.now || self.sa_queue.is_empty() {
                    continue;
                }
                let sa_mem = self.mem.mem_for_sa(sa_idx);
                // Min-op-id priority among this array's memory group.
                let pos = self
                    .sa_queue
                    .iter()
                    .enumerate()
                    .filter(|(_, (op, ..))| {
                        self.ops[op.0 as usize].mem as usize == sa_mem
                    })
                    .min_by_key(|(_, (op, ..))| op.0)
                    .map(|(i, _)| i);
                let Some(pos) = pos else { continue };
                let (op_id, m, k, n) = self.sa_queue.remove(pos).expect("indexed");
                self.dispatch_one(sa_idx, op_id, m, k, n);
                dispatched = true;
            }
            if !dispatched {
                return;
            }
        }
    }

    fn dispatch_one(&mut self, sa_idx: usize, op_id: OpId, m: u32, k: u32, n: u32) {
        // First dispatch of the op allocates its outputs (occupancy
        // follows execution, not issue).
        self.allocate_outputs(op_id)
            .expect("output allocation failed at dispatch");
        let i = op_id.0 as usize;
        let mem = self.ops[i].mem as usize;
        let start = self.now.max(self.sa_free[sa_idx]);

        let lat = self.mem.on_chip[mem].cfg.latency_cycles;
        let timing = matmul_timing(&self.cfg.sa, m, k, n, lat);
        let compute_end = start + timing.total_cycles;

        // Reserve operand streaming on the feeding memory's ports.
        let word = self.mem.on_chip[mem].cfg.bytes_per_cycle;
        let stream_bytes = OpKind::MatMul { m, k, n }.streamed_bytes();
        let tr = self.mem.on_chip[mem].ports.transfer(start, stream_bytes);
        let out_bytes = m as u64 * n as u64;
        self.mem.on_chip[mem]
            .stats
            .sram_read(stream_bytes - out_bytes, word, "act");
        self.mem.on_chip[mem].stats.sram_write(out_bytes, word, "act");

        // Weight operands stream from DRAM into the array (per subop
        // share), overlapped with compute but bounded by DRAM bandwidth.
        // SRAM-resident weights (Fig. 1 small models) skip this: their
        // reads ride the regular SRAM streaming reservation.
        let n_subops = self.ops[i].subops_remaining.max(1) as u64;
        let wb = if self.cfg.sched.weight_resident {
            0
        } else {
            self.weight_bytes(op_id) / n_subops
        };
        let dram_end = if wb > 0 {
            let dtr = self.mem.dram.transfer(start, wb);
            self.mem.dram_stats.dram_read(wb);
            dtr.end
        } else {
            start
        };

        let end = compute_end.max(tr.end).max(dram_end);
        self.sa_free[sa_idx] = end;
        self.sa_busy += end - start;
        self.ops[i].compute_cycles += timing.total_cycles;
        self.ops[i].stream_stall += end - compute_end;
        self.push_event(end, Event::SubopDone(op_id));
    }

    fn on_subop_done(&mut self, op_id: OpId) -> Result<()> {
        let i = op_id.0 as usize;
        self.ops[i].subops_remaining -= 1;
        if self.ops[i].subops_remaining == 0 {
            self.complete_op(op_id)?;
        }
        Ok(())
    }

    fn complete_op(&mut self, op_id: OpId) -> Result<()> {
        let i = op_id.0 as usize;
        self.ops[i].done = true;
        let stage = self.graph.ops[i].stage;
        let done = self.stage_done.entry(stage).or_insert(0);
        *done += 1;
        if *done == self.stage_total[&stage] {
            self.pending_events.push(RunEvent::StageEnd { stage });
        }
        // Unblock dependents.
        for d in std::mem::take(&mut self.dependents[i]) {
            debug_assert!(self.deps_remaining[d as usize] > 0);
            self.deps_remaining[d as usize] -= 1;
        }

        // Liveness: decrement read tensors; obsolete at zero consumers.
        let reads = self.graph.ops[i].reads.clone();
        for r in reads {
            let c = &mut self.consumers_remaining[r.0 as usize];
            debug_assert!(*c > 0, "consumer underflow on {r}");
            *c -= 1;
            if *c == 0 {
                let info = self.graph.tensor(r);
                let persistent = matches!(info.kind, TensorKind::Output)
                    || (info.kind == TensorKind::KvCache
                        && self.graph.kv_residency == KvResidency::Persistent);
                if !persistent {
                    self.mem.mark_obsolete(self.now, r);
                }
            }
        }
        Ok(())
    }
}

impl<'g> Simulator<'g> {
    /// Run and also return the shared SRAM's needed-by-kind composition
    /// at its peak (calibration diagnostics).
    pub fn run_keeping_memory(
        self,
    ) -> Result<(SimResult, Vec<(&'static str, u64)>)> {
        // run() consumes self; replicate with composition capture.
        let mut sim = self;
        let result = {
            // Identical body to run(), but we need the memory afterwards;
            // easiest is to run and snatch composition before drop. We
            // restructure run() to populate the composition into the
            // result via the trace; instead we re-run the core loop here.
            sim.run_inner(&mut SimOptions::default())?
        };
        let comp = sim.mem.on_chip[0].peak_composition.clone();
        Ok((result, comp))
    }
}

/// Convenience: build + run (materialized traces, no sink).
pub fn simulate(graph: &WorkloadGraph, cfg: &AccelConfig) -> Result<SimResult> {
    let mut sim = Simulator::new(graph, cfg)?;
    sim.run_inner(&mut SimOptions::default())
}

/// Build + run with explicit [`SimOptions`] (streaming sink and/or
/// trace materialization control).
pub fn simulate_with(
    graph: &WorkloadGraph,
    cfg: &AccelConfig,
    mut opts: SimOptions<'_>,
) -> Result<SimResult> {
    let mut sim = Simulator::new(graph, cfg)?;
    sim.run_inner(&mut opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{baseline, tiny};
    use crate::workload::{build_prefill, TINY_GQA, TINY_MHA};

    #[test]
    fn tiny_prefill_completes() {
        let g = build_prefill(&TINY_GQA, 64).unwrap();
        let r = simulate(&g, &tiny()).unwrap();
        assert!(r.total_cycles > 0);
        assert!(r.feasible(), "4 MiB must fit the tiny model");
        assert_eq!(r.total_macs, TINY_GQA.total_macs(64));
        assert!(r.sram_trace().peak_needed() > 0);
        assert!(r.active_utilization() > 0.0 && r.active_utilization() <= 1.0);
    }

    #[test]
    fn deterministic() {
        let g = build_prefill(&TINY_MHA, 64).unwrap();
        let a = simulate(&g, &tiny()).unwrap();
        let b = simulate(&g, &tiny()).unwrap();
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.sram_trace().samples(), b.sram_trace().samples());
    }

    #[test]
    fn more_compute_takes_longer() {
        let g32 = build_prefill(&TINY_GQA, 32).unwrap();
        let g128 = build_prefill(&TINY_GQA, 128).unwrap();
        let r32 = simulate(&g32, &tiny()).unwrap();
        let r128 = simulate(&g128, &tiny()).unwrap();
        assert!(r128.total_cycles > r32.total_cycles);
        assert!(r128.peak_needed() > r32.peak_needed());
    }

    #[test]
    fn breakdown_covers_all_classes_present() {
        let g = build_prefill(&TINY_MHA, 64).unwrap();
        let r = simulate(&g, &tiny()).unwrap();
        use crate::workload::OpClass;
        for class in [
            OpClass::QkvProj,
            OpClass::AttnScore,
            OpClass::AttnSoftmax,
            OpClass::AttnContext,
            OpClass::OutProj,
            OpClass::FfnMatMul,
            OpClass::NormOp,
        ] {
            let b = r.op_breakdown.get(&class);
            assert!(b.is_some(), "missing class {class:?}");
            assert!(b.unwrap().count > 0);
        }
    }

    #[test]
    fn trace_conservation_needed_plus_obsolete_bounded() {
        let g = build_prefill(&TINY_GQA, 64).unwrap();
        let r = simulate(&g, &tiny()).unwrap();
        let cap = tiny().shared_sram().capacity;
        for s in r.sram_trace().samples() {
            assert!(s.needed + s.obsolete <= cap);
        }
    }

    #[test]
    fn sink_stream_matches_materialized_trace() {
        use crate::trace::sink::{MaterializeSink, OnlineStatsSink, TeeSink};
        let g = build_prefill(&TINY_GQA, 64).unwrap();
        let reference = simulate(&g, &tiny()).unwrap();

        let mut mat = MaterializeSink::new();
        let mut online = OnlineStatsSink::new();
        let streamed = {
            let mut tee = TeeSink::new(vec![&mut mat, &mut online]);
            simulate_with(
                &g,
                &tiny(),
                SimOptions {
                    sink: Some(&mut tee),
                    materialize: false,
                },
            )
            .unwrap()
        };
        // Timing/stats identical; internal traces stayed empty.
        assert_eq!(streamed.total_cycles, reference.total_cycles);
        assert_eq!(streamed.stats, reference.stats);
        assert_eq!(streamed.sram_trace().samples().len(), 1);

        // The streamed materialization reproduces the reference trace
        // sample-for-sample.
        assert_eq!(mat.traces().len(), reference.traces.len());
        for (a, b) in mat.traces().iter().zip(&reference.traces) {
            assert_eq!(a.samples(), b.samples(), "memory {}", b.memory);
            assert_eq!(a.end_time(), b.end_time());
        }
        // And the O(1) online stats agree with the materialized queries.
        let m = online.shared().unwrap();
        assert_eq!(m.peak_needed(), reference.peak_needed());
        assert_eq!(m.peak_occupied(), reference.sram_trace().peak_occupied());
        assert!(
            (m.avg_needed() - reference.sram_trace().avg_needed()).abs() < 1e-9
        );
    }

    #[test]
    fn stage_events_bracket_every_stage_exactly_once() {
        struct Recorder(Vec<(u64, RunEvent)>);
        impl TraceSink for Recorder {
            fn on_sample(&mut self, _m: usize, _t: u64, _n: u64, _o: u64) {}
            fn on_event(&mut self, t: u64, event: &RunEvent) {
                self.0.push((t, *event));
            }
        }
        let g = build_prefill(&TINY_GQA, 64).unwrap();
        let mut rec = Recorder(Vec::new());
        simulate_with(
            &g,
            &tiny(),
            SimOptions { sink: Some(&mut rec), materialize: false },
        )
        .unwrap();

        let stages: std::collections::BTreeSet<u32> =
            g.ops.iter().map(|o| o.stage).collect();
        for &stage in &stages {
            let start = rec
                .0
                .iter()
                .position(|(_, e)| *e == RunEvent::StageStart { stage });
            let end = rec
                .0
                .iter()
                .position(|(_, e)| *e == RunEvent::StageEnd { stage });
            let (Some(start), Some(end)) = (start, end) else {
                panic!("stage {stage} missing start/end event");
            };
            assert!(start < end, "stage {stage} start must precede end");
        }
        // Exactly one start + one end per stage, nothing else.
        assert_eq!(rec.0.len(), 2 * stages.len());
        // Event timestamps never go backwards.
        for w in rec.0.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
    }

    #[test]
    fn baseline_accepts_tiny_model_fast() {
        // Full-size accelerator, tiny model: must not be memory-bound.
        let g = build_prefill(&TINY_GQA, 128).unwrap();
        let r = simulate(&g, &baseline()).unwrap();
        assert!(r.feasible());
        assert!(r.seconds() < 0.01, "tiny model should finish in <10ms sim time");
    }
}
