//! Stage-I cycle-level discrete-event simulator (TransInferSim
//! equivalent): systolic-array timing, in-order windowed scheduling with
//! subop decomposition, port-contended memory streaming, and occupancy
//! trace extraction.

pub mod engine;
pub mod serving;
pub mod stats;
pub mod systolic;

pub use engine::{simulate, simulate_with, SimOptions, Simulator};
pub use serving::{
    arena_capacity, round_robin, simulate_serving, simulate_serving_with,
    ServingResult, ServingSimOptions,
};
pub use stats::{OpBreakdown, SimResult};
pub use systolic::{matmul_efficiency, matmul_timing, split_subops, MatmulTiming};
