//! Systolic-array timing model.
//!
//! A `rows x cols` weight-stationary array computes a matmul as a grid of
//! `rows x cols` output tiles. Each tile streams the shared dimension `k`
//! through the row/column FIFOs: `k` beats of useful work plus pipeline
//! fill (`rows`) and drain (`cols`) plus an inter-tile FIFO refill bubble
//! bounded by the feeding memory's access latency.
//!
//! This closed-form per-tile cost is what makes attention score ops
//! (small k = head dim) intrinsically inefficient on a 128x128 array —
//! the mechanism behind GPT-2 XL's low PE utilization in the paper's
//! Fig. 7 (Dh=64 fills half the array pipeline) versus DeepSeek's Dh=128.

use crate::config::SaConfig;

/// Timing of one matmul on ONE systolic array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MatmulTiming {
    /// Output tiles in the m and n directions.
    pub tiles_m: u64,
    pub tiles_n: u64,
    /// Cycles per output tile (fill + k + drain + refill bubble).
    pub cycles_per_tile: u64,
    /// Total cycles if executed on a single array.
    pub total_cycles: u64,
}

impl MatmulTiming {
    pub fn tiles(&self) -> u64 {
        self.tiles_m * self.tiles_n
    }
}

/// Cycle cost of `[m,k] x [k,n]` on one array of `sa`, fed by a memory
/// with `mem_latency` cycles access time (the inter-tile refill bubble).
pub fn matmul_timing(sa: &SaConfig, m: u32, k: u32, n: u32, mem_latency: u64) -> MatmulTiming {
    let tiles_m = (m as u64).div_ceil(sa.rows as u64);
    let tiles_n = (n as u64).div_ceil(sa.cols as u64);
    // Fill/drain span the full array even for partial tiles (the pipeline
    // must still traverse all PEs).
    let cycles_per_tile = k as u64 + sa.rows as u64 + sa.cols as u64 + mem_latency;
    MatmulTiming {
        tiles_m,
        tiles_n,
        cycles_per_tile,
        total_cycles: tiles_m * tiles_n * cycles_per_tile,
    }
}

/// MAC efficiency on one array: useful MACs / (cycles * PEs). This is
/// the quantity the §Perf L1 analysis reports as MXU utilization.
pub fn matmul_efficiency(sa: &SaConfig, m: u32, k: u32, n: u32, mem_latency: u64) -> f64 {
    let t = matmul_timing(sa, m, k, n, mem_latency);
    let macs = m as f64 * k as f64 * n as f64;
    let pe = (sa.rows * sa.cols) as f64;
    macs / (t.total_cycles as f64 * pe)
}

/// Split a matmul into `subops` sub-operations along its widest output
/// dimension (the paper's `subops=4` decomposition across the four SAs).
/// Returns per-subop (m, k, n) chunks; fewer than `subops` when the op is
/// too small to split.
pub fn split_subops(m: u32, k: u32, n: u32, subops: u32) -> Vec<(u32, u32, u32)> {
    let split_dim = |dim: u32, parts: u32| -> Vec<u32> {
        let parts = parts.min(dim).max(1);
        let base = dim / parts;
        let rem = dim % parts;
        (0..parts)
            .map(|i| base + u32::from(i < rem))
            .filter(|&c| c > 0)
            .collect()
    };
    if m >= n {
        split_dim(m, subops).into_iter().map(|c| (c, k, n)).collect()
    } else {
        split_dim(n, subops).into_iter().map(|c| (m, k, c)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn sa() -> SaConfig {
        SaConfig {
            rows: 128,
            cols: 128,
            count: 4,
            freq_ghz: 1.0,
        }
    }

    #[test]
    fn single_tile_cost() {
        let t = matmul_timing(&sa(), 128, 128, 128, 32);
        assert_eq!(t.tiles(), 1);
        assert_eq!(t.cycles_per_tile, 128 + 128 + 128 + 32);
        assert_eq!(t.total_cycles, 416);
    }

    #[test]
    fn partial_tiles_round_up() {
        let t = matmul_timing(&sa(), 1, 1600, 6400, 32);
        assert_eq!(t.tiles_m, 1);
        assert_eq!(t.tiles_n, 50);
    }

    #[test]
    fn small_k_is_inefficient() {
        // GPT-2 XL attention scores: k = Dh = 64 -> low efficiency;
        // DeepSeek's Dh = 128 does better per tile.
        let e64 = matmul_efficiency(&sa(), 2048, 64, 2048, 32);
        let e128 = matmul_efficiency(&sa(), 2048, 128, 2048, 32);
        let e_proj = matmul_efficiency(&sa(), 2048, 1600, 1600, 32);
        assert!(e64 < 0.25, "e64={e64}");
        assert!(e128 > e64);
        assert!(e_proj > 0.8, "projections should run near peak: {e_proj}");
    }

    #[test]
    fn split_along_widest() {
        let s = split_subops(2048, 64, 512, 4);
        assert_eq!(s, vec![(512, 64, 512); 4]);
        let s = split_subops(128, 64, 2048, 4);
        assert_eq!(s, vec![(128, 64, 512); 4]);
    }

    #[test]
    fn split_tiny_ops_degenerate() {
        let s = split_subops(1, 64, 2, 4);
        assert_eq!(s.len(), 2); // n=2 can only split two ways
        let s = split_subops(1, 64, 1, 4);
        assert_eq!(s, vec![(1, 64, 1)]);
    }

    #[test]
    fn prop_split_preserves_work() {
        check("subop-split-preserves-macs", 200, |rng| {
            let (m, k, n) = (
                rng.range(1, 4096) as u32,
                rng.range(1, 4096) as u32,
                rng.range(1, 4096) as u32,
            );
            let subops = rng.range(1, 8) as u32;
            let parts = split_subops(m, k, n, subops);
            let macs: u64 = parts
                .iter()
                .map(|&(pm, pk, pn)| pm as u64 * pk as u64 * pn as u64)
                .sum();
            assert_eq!(macs, m as u64 * k as u64 * n as u64);
            assert!(parts.len() <= subops as usize);
        });
    }

    #[test]
    fn prop_efficiency_bounded() {
        check("sa-efficiency-in-unit-interval", 100, |rng| {
            let e = matmul_efficiency(
                &sa(),
                rng.range(1, 8192) as u32,
                rng.range(1, 8192) as u32,
                rng.range(1, 8192) as u32,
                rng.range(0, 100),
            );
            assert!(e > 0.0 && e <= 1.0, "e={e}");
        });
    }
}
