//! Simulation results: end-to-end timing, per-op-class breakdowns
//! (Fig. 6), utilization (Fig. 7), traces and access statistics.

use std::collections::BTreeMap;

use crate::config::AccelConfig;
use crate::trace::{AccessStats, OccupancyTrace};
use crate::workload::OpClass;

/// Per-op-class latency decomposition (the paper's Fig. 6 bars).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpBreakdown {
    /// Pure compute cycles (systolic tile schedule / stream beats),
    /// normalized per parallel subop (elapsed-equivalent).
    pub compute: u64,
    /// Cycles waiting on memory: input fetches + streaming-bandwidth
    /// stalls beyond pure compute.
    pub memory: u64,
    /// Cycles between dependency readiness and dispatch (queueing for a
    /// systolic array / issue window).
    pub idle: u64,
    pub count: u64,
}

impl OpBreakdown {
    pub fn total(&self) -> u64 {
        self.compute + self.memory + self.idle
    }
}

/// Complete Stage-I output for one run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub workload: String,
    pub accel: String,
    /// End-to-end cycles (= ns at 1 GHz).
    pub total_cycles: u64,
    /// One occupancy trace per on-chip memory (index 0 = shared SRAM).
    pub traces: Vec<OccupancyTrace>,
    /// Aggregated access statistics (all on-chip memories + DRAM).
    pub stats: AccessStats,
    /// Per-memory statistics.
    pub per_mem_stats: Vec<AccessStats>,
    pub op_breakdown: BTreeMap<OpClass, OpBreakdown>,
    pub total_macs: u64,
    /// Sum of busy cycles across all systolic arrays.
    pub sa_busy_cycles: u64,
    /// PEs per array x arrays (for utilization math).
    pub peak_macs_per_cycle: u64,
    pub freq_ghz: f64,
    /// Number of systolic arrays (busy cycles are counted per array).
    pub arrays: u64,
}

impl SimResult {
    pub fn seconds(&self) -> f64 {
        self.total_cycles as f64 / (self.freq_ghz * 1e9)
    }

    /// Shared-SRAM trace (the paper's single-memory analyses).
    pub fn sram_trace(&self) -> &OccupancyTrace {
        &self.traces[0]
    }

    pub fn peak_needed(&self) -> u64 {
        self.sram_trace().peak_needed()
    }

    /// Average PE utilization while arrays are busy — the "compute
    /// efficiency" sense of the paper's Fig. 7 (38% vs 77%).
    pub fn active_utilization(&self) -> f64 {
        if self.sa_busy_cycles == 0 {
            return 0.0;
        }
        // peak_macs_per_cycle covers all arrays; sa_busy_cycles sums per
        // array, so normalize by arrays via the per-array peak.
        self.total_macs as f64 / (self.sa_busy_cycles as f64 * self.per_sa_peak())
    }

    /// End-to-end utilization: MACs / (elapsed x full peak).
    pub fn e2e_utilization(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.total_macs as f64
            / (self.total_cycles as f64 * self.peak_macs_per_cycle as f64)
    }

    fn per_sa_peak(&self) -> f64 {
        // peak_macs_per_cycle = rows*cols*count; busy cycles are counted
        // per array, so one busy cycle can retire rows*cols MACs.
        self.peak_macs_per_cycle as f64 / self.num_arrays() as f64
    }

    fn num_arrays(&self) -> u64 {
        self.arrays
    }

    pub fn feasible(&self) -> bool {
        self.stats.capacity_feasible()
    }
}

/// Builder-side helper so the engine fills `SimResult` coherently.
pub fn new_result(
    workload: &str,
    cfg: &AccelConfig,
    total_cycles: u64,
    traces: Vec<OccupancyTrace>,
    stats: AccessStats,
    per_mem_stats: Vec<AccessStats>,
    op_breakdown: BTreeMap<OpClass, OpBreakdown>,
    total_macs: u64,
    sa_busy_cycles: u64,
) -> SimResult {
    SimResult {
        workload: workload.to_string(),
        accel: cfg.name.clone(),
        total_cycles,
        traces,
        stats,
        per_mem_stats,
        op_breakdown,
        total_macs,
        sa_busy_cycles,
        peak_macs_per_cycle: cfg.sa.rows as u64 * cfg.sa.cols as u64 * cfg.sa.count as u64,
        freq_ghz: cfg.sa.freq_ghz,
        arrays: cfg.sa.count as u64,
    }
}
