//! Serving-level simulator: an event-driven continuous-batching engine
//! over a paged KV arena, with a retained round-robin oracle.
//!
//! Where `sim::engine` resolves one sequence at op granularity, this
//! scheduler resolves a whole request population at *decode-step*
//! granularity — the right resolution for serving-shaped occupancy,
//! where the interesting dynamics (staggered arrivals, concurrency
//! plateaus, completion churn, paged fragmentation, preemption) span
//! billions of cycles. Per-step costs come from a closed-form model of
//! the same accelerator config the cycle-level engine uses:
//!
//! * one **round** advances every active stream by one token; each
//!   lane's weights stream from DRAM once per round (the batching win),
//! * each stream then pays its projection MACs plus the larger of its
//!   attention MACs and its KV streaming time (context-proportional,
//!   including any shared prefix),
//! * **admission** (continuous batching) happens between rounds: arrived
//!   requests join while the concurrency cap has room, paying a prefill
//!   lump and materializing their prompt KV in the arena.
//!
//! ## Event taxonomy
//!
//! [`simulate_serving_with`] drives everything off one binary heap of
//! `(t, seq)`-ordered events (`seq` is a global push counter, so ties
//! break deterministically and pops are totally ordered):
//!
//! * **Arrival(i)** — wake-up at request *i*'s arrival time; moves it
//!   into the waiting set. At most one arrival event is armed at a time
//!   (each pop arms the next), so the heap stays O(batch) regardless of
//!   trace length. Arrivals that the admission scan already ingested
//!   pop as no-ops.
//! * **Step** — one stream's decode step completes (observed runs
//!   only): its KV page growth, access traffic, and possible completion
//!   land at the step's exact cycle, interleaved in time order with
//!   arrivals, so sinks see the same merged stream the round loop
//!   produced. The last step of a round schedules the next Round.
//! * **Round** — a scheduler boundary: ingest arrivals, admit/restore
//!   waiters in priority order, preempt if a strictly-higher-priority
//!   request is starved, then launch the next round of steps.
//!
//! **Fast-forward rule:** when the engine goes quiescent (no active
//! streams, nothing waiting) it schedules the next Round directly at the
//! next arrival's timestamp — a closed-form jump across the gap with no
//! intermediate events. Throughput runs (no sink, `materialize =
//! false`) go further: nothing can observe intra-round instants, so
//! Step events collapse into inline round execution with raw counter
//! accumulation — same schedule, same totals, million-request traces in
//! seconds.
//!
//! ## Oracle relationship
//!
//! [`round_robin`] is the retained round-by-round scheduler, kept as the
//! differential oracle exactly like `banking::sweep_naive` is for the
//! fused sweep: on every legacy workload (no tiers, no prefix, single
//! tenant — bursty arrivals and heavy-tailed lengths included, since
//! those live in `generate_requests`) the event engine is **bit
//! identical** to it — same merged trace, same stats, same cycle count
//! (`tests/serving_engine.rs`, plus a CI `cmp` gate on the trace CSV).
//! The scheduling extensions (priority preemption with KV evict/restore,
//! shared-prefix floors, multi-model tenancy) exist only in the event
//! engine.
//!
//! Every arena state change is forwarded through the existing
//! [`TraceSink`] machinery with the same piecewise-constant semantics as
//! the cycle-level engine, so serving traces drop into Stage II (and
//! every sink consumer) unchanged. All arithmetic is integer and the
//! workload is seeded, so runs are bit-deterministic.

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, VecDeque};

use anyhow::{ensure, Context, Result};

use crate::config::AccelConfig;
use crate::serving::{generate_requests, PagedKvArena, Request, ServingParams};
use crate::trace::sink::{MemoryDesc, RunEvent, TraceSink};
use crate::trace::{AccessStats, OccupancyTrace};
use crate::util::ceil_div;
use crate::util::fnv::Fnv64;
use crate::workload::{paper_counterpart, ModelPreset};

/// Serving-simulation knobs, mirroring [`super::SimOptions`].
pub struct ServingSimOptions<'s> {
    /// Optional streaming consumer of arena occupancy changes
    /// (memory 0 = the KV arena).
    pub sink: Option<&'s mut dyn TraceSink>,
    /// When false, the result's `trace` stays empty (sink-only run with
    /// O(1) trace memory). With no sink either, the engine switches to
    /// its throughput mode (see the [module docs](self)).
    pub materialize: bool,
}

impl Default for ServingSimOptions<'_> {
    fn default() -> Self {
        Self {
            sink: None,
            materialize: true,
        }
    }
}

/// Output of one serving run.
#[derive(Debug, Clone)]
pub struct ServingResult {
    /// Workload label, e.g. `gpt2-xl-serve-r256-c64-s7` (extension
    /// fields append suffixes; legacy specs keep the exact old label).
    pub workload: String,
    pub accel: String,
    /// Merged KV-arena occupancy trace (empty when the run streamed to a
    /// sink with `materialize = false`).
    pub trace: OccupancyTrace,
    /// KV-traffic access statistics (Eq. 3 inputs for Stage II).
    pub stats: AccessStats,
    /// Makespan in cycles (arrival of first request to last completion).
    pub total_cycles: u64,
    /// Requests that ran to completion (equals the workload size).
    pub completed: u32,
    /// Highest number of simultaneously active streams observed.
    pub peak_concurrent: u32,
    /// Preemptions: streams evicted to DRAM for a higher-priority
    /// waiter (0 on single-tier workloads).
    pub evicted: u32,
    /// Evicted streams re-admitted (every eviction restores eventually,
    /// so this equals `evicted` on a completed run).
    pub restored: u32,
    pub page_bytes: u64,
    pub arena_capacity: u64,
    pub freq_ghz: f64,
}

impl ServingResult {
    pub fn seconds(&self) -> f64 {
        self.total_cycles as f64 / (self.freq_ghz * 1e9)
    }

    pub fn peak_needed(&self) -> u64 {
        self.trace.peak_needed()
    }

    pub fn peak_occupied(&self) -> u64 {
        self.trace.peak_occupied()
    }

    /// Stable FNV-1a fingerprint of the materialized trace (samples +
    /// end time) — the CLI's determinism check. Meaningless on
    /// sink-only runs, whose trace is empty.
    pub fn trace_hash(&self) -> u64 {
        let mut h = Fnv64::new();
        h.str(&self.trace.memory);
        h.u64(self.trace.capacity);
        for s in self.trace.samples() {
            h.u64(s.t);
            h.u64(s.needed);
            h.u64(s.obsolete);
        }
        h.u64(self.trace.end_time().unwrap_or(0));
        h.finish()
    }
}

/// Closed-form per-step cost model derived from model + accelerator.
struct CostModel {
    macs_per_cycle: u64,
    /// Shared-SRAM aggregate bandwidth, bytes/cycle.
    sram_bw: u64,
    /// SRAM interface word for access-count accounting.
    word: u32,
    /// DRAM bandwidth, bytes/cycle (prefill floor, KV spill/restore).
    dram_bw: u64,
    /// Weight bytes streamed from DRAM per round (0 if resident).
    weight_bytes: u64,
    /// Cycles of that weight stream.
    weight_cycles: u64,
    /// KV bytes appended per generated token (all layers, K + V).
    kv_token_bytes: u64,
    /// Per-token projection + FFN MACs (whole model).
    proj_macs: u64,
    /// Attention MACs per context token per generated token.
    attn_macs_per_ctx: u64,
}

impl CostModel {
    fn new(m: &ModelPreset, cfg: &AccelConfig) -> Self {
        let macs_per_cycle =
            (cfg.sa.rows as u64 * cfg.sa.cols as u64 * cfg.sa.count as u64).max(1);
        let sram = cfg.shared_sram();
        let sram_bw = sram.bandwidth().max(1);
        let dram_bw = cfg.dram.bandwidth().max(1);
        let weight_bytes = if cfg.sched.weight_resident {
            0
        } else {
            m.param_count()
        };
        Self {
            macs_per_cycle,
            sram_bw,
            word: sram.bytes_per_cycle,
            dram_bw,
            weight_bytes,
            weight_cycles: ceil_div(weight_bytes, dram_bw),
            kv_token_bytes: m.kv_cache_bytes(1),
            proj_macs: m.total_macs(1),
            attn_macs_per_ctx: 2 * m.layers as u64 * m.heads as u64 * m.d_head as u64,
        }
    }

    /// Cycles one stream adds to a round when decoding at context `ctx`.
    fn decode_step_cycles(&self, ctx: u32) -> u64 {
        let attn = ceil_div(self.attn_macs_per_ctx * ctx as u64, self.macs_per_cycle);
        let kv_stream = ceil_div(self.kv_token_bytes * ctx as u64, self.sram_bw);
        let proj = ceil_div(self.proj_macs, self.macs_per_cycle);
        (proj + attn.max(kv_stream)).max(1)
    }

    /// Admission lump: compute-bound prefill, floored by one weight pass.
    fn prefill_cycles(&self, m: &ModelPreset, prompt: u32) -> u64 {
        let compute = ceil_div(m.total_macs(prompt as u64), self.macs_per_cycle);
        compute.max(self.weight_cycles)
    }
}

/// One active decode stream.
#[derive(Debug, Clone, Copy)]
struct Stream {
    id: u32,
    /// Tokens currently in the stream's KV cache (prompt + generated so
    /// far, excluding any shared prefix).
    ctx: u32,
    /// Tokens still to generate.
    remaining: u32,
    /// Priority tier (lower wins); 0 on single-tier workloads.
    tier: u32,
    /// Model lane (index into the co-resident lane list).
    lane: u32,
    /// Admission-order stamp: preemption evicts the most recently
    /// admitted stream among the lowest-priority ones.
    admitted_seq: u64,
}

/// Waiting-set classes: evicted streams restore ahead of fresh arrivals
/// of the same tier.
const CLASS_RESTORE: u8 = 0;
const CLASS_FRESH: u8 = 1;

/// Waiting-set entry, ordered by `(tier, class, order)` — priority
/// first, restores before fresh arrivals within a tier, FIFO within
/// each (tier, class). With tiers disabled this degenerates to pure
/// FIFO, which is what keeps the engine bit-identical to the oracle.
#[derive(Debug, Clone, Copy)]
struct WaitEntry {
    tier: u32,
    class: u8,
    /// Monotone ingestion stamp (FIFO tie-break).
    order: u64,
    s: Stream,
}

impl WaitEntry {
    fn key(&self) -> (u32, u8, u64) {
        (self.tier, self.class, self.order)
    }
}

impl PartialEq for WaitEntry {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl Eq for WaitEntry {}
impl PartialOrd for WaitEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for WaitEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key().cmp(&other.key())
    }
}

/// Event-queue payload (see the module docs for the taxonomy).
#[derive(Debug, Clone, Copy)]
enum EvKind {
    Arrival(u32),
    Step { s: Stream },
    Round,
}

/// Heap item: ordered by `(t, seq)` only — `seq` is unique, so the
/// order is total and deterministic regardless of payload.
#[derive(Debug, Clone, Copy)]
struct Ev {
    t: u64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        (self.t, self.seq) == (other.t, other.seq)
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        (self.t, self.seq).cmp(&(other.t, other.seq))
    }
}

/// Forward the arena's occupancy to the trace/sink iff it changed since
/// the last emission (same piecewise-constant semantics as the engine).
fn emit_change(
    t: u64,
    arena: &PagedKvArena,
    materialize: bool,
    trace: &mut OccupancyTrace,
    sink: &mut Option<&mut dyn TraceSink>,
    last: &mut (u64, u64),
) {
    let cur = (arena.needed_bytes(), arena.obsolete_bytes());
    if *last == cur {
        return;
    }
    *last = cur;
    if materialize {
        trace.record(t, cur.0, cur.1);
    }
    if let Some(s) = sink.as_deref_mut() {
        s.on_sample(0, t, cur.0, cur.1);
    }
}

/// The model presets co-resident in the arena: lane 0 is the spec's
/// model; `tenants == 2` adds its paper counterpart when one exists
/// (spec validation rejects tenancy for unpaired models, so a missing
/// counterpart here only shortens the list for capacity bounding).
fn lane_presets(model: &ModelPreset, params: &ServingParams) -> Vec<ModelPreset> {
    let mut lanes = vec![model.clone()];
    if params.tenants > 1 {
        if let Some(c) = paper_counterpart(model.name) {
            lanes.push(c);
        }
    }
    lanes
}

/// KV-arena capacity the serving simulator provisions for `(model,
/// params)`: every stream can grow to its maximum context (at the
/// byte-hungriest co-resident lane), plus each lane's shared-prefix
/// pages — so the concurrency cap, not page exhaustion, is the
/// admission limit, and preemption is never space-forced. The shared
/// helper behind `ExperimentSpec::serve_fused` grids and
/// `optimize::covering_capacity_bound`; a pure function of its inputs,
/// usable *before* the simulation runs. Reduces exactly to the pre-
/// extension formula when every extension is off.
pub fn arena_capacity(model: &ModelPreset, params: &ServingParams) -> u64 {
    let kv0 = model.kv_cache_bytes(1);
    let page_bytes = params.page_tokens as u64 * kv0;
    let lanes = lane_presets(model, params);
    let max_kv = lanes.iter().map(|m| m.kv_cache_bytes(1)).max().unwrap_or(kv0);
    let pages_per_stream =
        ceil_div(params.max_stream_tokens() as u64 * max_kv, page_bytes);
    let prefix_pages: u64 = lanes
        .iter()
        .map(|m| ceil_div(params.prefix_tokens as u64 * m.kv_cache_bytes(1), page_bytes))
        .sum();
    (params.concurrency as u64 * pages_per_stream + prefix_pages) * page_bytes
}

/// Workload label: legacy specs keep the exact pre-extension format;
/// non-default traffic fields append suffixes so distinct workloads stay
/// distinguishable in reports and lab stores.
fn workload_label(model: &ModelPreset, p: &ServingParams) -> String {
    let mut label = format!(
        "{}-serve-r{}-c{}-s{}",
        model.name, p.requests, p.concurrency, p.seed
    );
    if p.burst_gap > 0 {
        label.push_str(&format!("-b{}x{}v{}", p.burst_gap, p.burst_len, p.calm_len));
    }
    if p.len_tail_q8 > 0 {
        label.push_str(&format!("-q{}", p.len_tail_q8));
    }
    if p.tiers > 1 {
        label.push_str(&format!("-t{}", p.tiers));
    }
    if p.prefix_tokens > 0 {
        label.push_str(&format!("-p{}", p.prefix_tokens));
    }
    if p.tenants > 1 {
        label.push_str(&format!("-m{}", p.tenants));
    }
    label
}

/// Run a serving scenario with default options (materialized trace).
pub fn simulate_serving(
    model: &ModelPreset,
    params: ServingParams,
    cfg: &AccelConfig,
) -> Result<ServingResult> {
    simulate_serving_with(model, params, cfg, ServingSimOptions::default())
}

/// Raw access counters for the throughput fast path. Accumulated with
/// the same per-call `div_ceil` the [`AccessStats`] helpers use, then
/// flushed as plain u64 sums — bit-identical totals, none of the
/// per-step `BTreeMap` bookkeeping.
#[derive(Default)]
struct RawKv {
    rd_bytes: u64,
    rd_beats: u64,
    wr_bytes: u64,
    wr_beats: u64,
    dram_rd: u64,
    dram_wr: u64,
}

impl RawKv {
    fn flush_into(self, stats: &mut AccessStats) {
        stats.reads += self.rd_beats;
        stats.read_bytes += self.rd_bytes;
        stats.writes += self.wr_beats;
        stats.write_bytes += self.wr_bytes;
        let e = stats.by_kind.entry("kv").or_default();
        e.read_bytes += self.rd_bytes;
        e.write_bytes += self.wr_bytes;
        stats.dram_read_bytes += self.dram_rd;
        stats.dram_write_bytes += self.dram_wr;
    }
}

/// The event-driven engine's mutable state (see the module docs).
struct Engine<'s> {
    params: ServingParams,
    lanes: Vec<ModelPreset>,
    costs: Vec<CostModel>,
    reqs: Vec<Request>,
    word: u32,
    fast: bool,
    materialize: bool,

    arena: PagedKvArena,
    trace: OccupancyTrace,
    stats: AccessStats,
    raw: RawKv,
    sink: Option<&'s mut dyn TraceSink>,
    last_emitted: (u64, u64),

    heap: BinaryHeap<Reverse<Ev>>,
    seq: u64,
    waiting: BinaryHeap<Reverse<WaitEntry>>,
    active: VecDeque<Stream>,
    /// Next request index not yet moved into the waiting set.
    cursor: usize,
    wait_order: u64,
    admit_stamp: u64,
    /// Step events scheduled but not yet resolved (observed mode).
    in_flight: u32,

    now: u64,
    completed: u32,
    peak_concurrent: u32,
    evicted: u32,
    restored: u32,
}

impl Engine<'_> {
    fn push(&mut self, t: u64, kind: EvKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Ev { t, seq, kind }));
    }

    fn emit(&mut self, t: u64) {
        emit_change(
            t,
            &self.arena,
            self.materialize,
            &mut self.trace,
            &mut self.sink,
            &mut self.last_emitted,
        );
    }

    fn event(&mut self, t: u64, ev: &RunEvent) {
        if let Some(s) = self.sink.as_deref_mut() {
            s.on_event(t, ev);
        }
    }

    fn kv_read(&mut self, bytes: u64) {
        if self.fast {
            self.raw.rd_beats += bytes.div_ceil(self.word as u64);
            self.raw.rd_bytes += bytes;
        } else {
            self.stats.sram_read(bytes, self.word, "kv");
        }
    }

    fn kv_write(&mut self, bytes: u64) {
        if self.fast {
            self.raw.wr_beats += bytes.div_ceil(self.word as u64);
            self.raw.wr_bytes += bytes;
        } else {
            self.stats.sram_write(bytes, self.word, "kv");
        }
    }

    fn dram_read_traffic(&mut self, bytes: u64) {
        if self.fast {
            self.raw.dram_rd += bytes;
        } else {
            self.stats.dram_read(bytes);
        }
    }

    fn dram_write_traffic(&mut self, bytes: u64) {
        if self.fast {
            self.raw.dram_wr += bytes;
        } else {
            self.stats.dram_write(bytes);
        }
    }

    /// Move every request that has arrived by `now` into the waiting
    /// set (the cursor is the single source of truth, so arrival events
    /// the scan outruns pop later as no-ops).
    fn ingest_arrivals(&mut self) {
        while self.cursor < self.reqs.len() && self.reqs[self.cursor].arrival <= self.now {
            let r = self.reqs[self.cursor];
            self.cursor += 1;
            self.enqueue_request(r);
        }
    }

    fn enqueue_request(&mut self, r: Request) {
        let order = self.wait_order;
        self.wait_order += 1;
        self.waiting.push(Reverse(WaitEntry {
            tier: r.tier,
            class: CLASS_FRESH,
            order,
            s: Stream {
                id: r.id,
                ctx: r.prompt,
                remaining: r.gen,
                tier: r.tier,
                lane: r.lane,
                admitted_seq: 0,
            },
        }));
    }

    /// A scheduler boundary's admission pass: admit/restore waiters in
    /// priority order while the batch has room, re-ingesting arrivals
    /// as prefill/restore time advances the clock; once full, preempt
    /// as long as a strictly-higher-priority waiter is starved.
    fn admission_scan(&mut self) -> Result<()> {
        let cap = self.params.concurrency as usize;
        loop {
            self.ingest_arrivals();
            if self.active.len() < cap {
                let Some(Reverse(w)) = self.waiting.pop() else { break };
                self.admit(w)?;
                continue;
            }
            if self.params.tiers <= 1 {
                break;
            }
            let Some(best_tier) = self.waiting.peek().map(|Reverse(w)| w.tier) else {
                break;
            };
            // Victim: lowest priority, then most recently admitted.
            let (vi, vtier) = self
                .active
                .iter()
                .enumerate()
                .max_by_key(|(_, s)| (s.tier, s.admitted_seq))
                .map(|(i, s)| (i, s.tier))
                .expect("batch is full, hence non-empty");
            if best_tier >= vtier {
                break;
            }
            self.preempt(vi)?;
        }
        Ok(())
    }

    fn admit(&mut self, w: WaitEntry) -> Result<()> {
        let mut s = w.s;
        let li = s.lane as usize;
        let kvb = self.costs[li].kv_token_bytes;
        let live = s.ctx as u64 * kvb;
        if w.class == CLASS_RESTORE {
            // Restore pays the DRAM→SRAM stream of the spilled KV.
            let restore_cycles = ceil_div(live, self.costs[li].dram_bw);
            self.now += restore_cycles;
            if !self.fast {
                self.arena
                    .restore(s.id, live)
                    .with_context(|| format!("restoring request {}", s.id))?;
            }
            self.dram_read_traffic(live);
            self.kv_write(live);
            self.restored += 1;
        } else {
            let prefill = self.costs[li].prefill_cycles(&self.lanes[li], s.ctx);
            let weight_bytes = self.costs[li].weight_bytes;
            self.now += prefill;
            if !self.fast {
                self.arena
                    .admit(s.id)
                    .and_then(|()| self.arena.grow(s.id, live))
                    .with_context(|| format!("admitting request {}", s.id))?;
            }
            self.dram_read_traffic(weight_bytes);
            self.kv_write(live);
        }
        s.admitted_seq = self.admit_stamp;
        self.admit_stamp += 1;
        self.active.push_back(s);
        self.peak_concurrent = self.peak_concurrent.max(self.active.len() as u32);
        let t = self.now;
        self.emit(t);
        let ev = if w.class == CLASS_RESTORE {
            RunEvent::Restore { request: s.id }
        } else {
            RunEvent::Admit { request: s.id }
        };
        self.event(t, &ev);
        Ok(())
    }

    /// Evict `active[vi]`: spill its live KV to DRAM (off the critical
    /// path — no cycles charged; the restore pays the read back), free
    /// its pages, and park it in the waiting set's restore class.
    fn preempt(&mut self, vi: usize) -> Result<()> {
        let s = self.active.remove(vi).expect("victim index in range");
        let kvb = self.costs[s.lane as usize].kv_token_bytes;
        let live = s.ctx as u64 * kvb;
        if !self.fast {
            self.arena
                .evict(s.id)
                .with_context(|| format!("evicting request {}", s.id))?;
        }
        self.dram_write_traffic(live);
        self.evicted += 1;
        let t = self.now;
        self.emit(t);
        self.event(t, &RunEvent::Evict { request: s.id });
        let order = self.wait_order;
        self.wait_order += 1;
        self.waiting.push(Reverse(WaitEntry {
            tier: s.tier,
            class: CLASS_RESTORE,
            order,
            s,
        }));
        Ok(())
    }

    /// Each lane with at least one active stream pays its per-round
    /// weight pass.
    fn stream_weights(&mut self) {
        for li in 0..self.costs.len() {
            let (wc, wb) = (self.costs[li].weight_cycles, self.costs[li].weight_bytes);
            if wc > 0 && self.active.iter().any(|s| s.lane as usize == li) {
                self.now += wc;
                self.dram_read_traffic(wb);
            }
        }
    }

    /// Observed mode: serialize the round's steps as future Step events
    /// at their exact completion cycles; the last one re-arms Round.
    fn schedule_round_steps(&mut self) {
        self.stream_weights();
        let prefix = self.params.prefix_tokens;
        for _ in 0..self.active.len() {
            let mut s = self.active.pop_front().expect("active non-empty");
            s.ctx += 1;
            s.remaining -= 1;
            let step = self.costs[s.lane as usize].decode_step_cycles(prefix + s.ctx);
            self.now += step;
            let t = self.now;
            self.push(t, EvKind::Step { s });
            self.in_flight += 1;
        }
    }

    /// Throughput mode: the same round arithmetic executed inline.
    fn run_round_fast(&mut self) {
        self.stream_weights();
        let prefix = self.params.prefix_tokens;
        for _ in 0..self.active.len() {
            let mut s = self.active.pop_front().expect("active non-empty");
            s.ctx += 1;
            s.remaining -= 1;
            let li = s.lane as usize;
            let step = self.costs[li].decode_step_cycles(prefix + s.ctx);
            let kvb = self.costs[li].kv_token_bytes;
            self.now += step;
            self.kv_read((prefix as u64 + s.ctx as u64) * kvb);
            self.kv_write(kvb);
            if s.remaining == 0 {
                self.completed += 1;
            } else {
                self.active.push_back(s);
            }
        }
    }

    fn on_step(&mut self, t: u64, s: Stream) -> Result<()> {
        let kvb = self.costs[s.lane as usize].kv_token_bytes;
        self.arena
            .grow(s.id, kvb)
            .with_context(|| format!("decode step of request {}", s.id))?;
        self.kv_read((self.params.prefix_tokens as u64 + s.ctx as u64) * kvb);
        self.kv_write(kvb);
        let finished = s.remaining == 0;
        if finished {
            self.arena
                .release(s.id)
                .with_context(|| format!("completing request {}", s.id))?;
            self.completed += 1;
        } else {
            self.active.push_back(s);
        }
        self.emit(t);
        if finished {
            self.event(t, &RunEvent::Complete { request: s.id });
        }
        self.in_flight -= 1;
        if self.in_flight == 0 {
            let next = self.now;
            self.push(next, EvKind::Round);
        }
        Ok(())
    }

    fn run(&mut self) -> Result<()> {
        while let Some(Reverse(ev)) = self.heap.pop() {
            match ev.kind {
                EvKind::Arrival(i) => {
                    let i = i as usize;
                    if i == self.cursor {
                        let r = self.reqs[i];
                        self.cursor = i + 1;
                        self.enqueue_request(r);
                    }
                    // Keep exactly one arrival armed: the next unseen
                    // request (its arrival is >= this event's t, so the
                    // pop order stays time-monotone).
                    if self.cursor < self.reqs.len() {
                        let t = self.reqs[self.cursor].arrival;
                        self.push(t, EvKind::Arrival(self.cursor as u32));
                    }
                }
                EvKind::Step { s } => self.on_step(ev.t, s)?,
                EvKind::Round => {
                    self.now = self.now.max(ev.t);
                    self.admission_scan()?;
                    if self.active.is_empty() {
                        // Quiescent: closed-form fast-forward straight
                        // to the next arrival (or done).
                        if self.cursor < self.reqs.len() {
                            let t = self.reqs[self.cursor].arrival;
                            self.push(t, EvKind::Round);
                        }
                    } else if self.fast {
                        'rounds: loop {
                            self.run_round_fast();
                            self.admission_scan()?;
                            while self.active.is_empty() {
                                if self.cursor >= self.reqs.len() {
                                    break 'rounds;
                                }
                                self.now = self.now.max(self.reqs[self.cursor].arrival);
                                self.admission_scan()?;
                            }
                        }
                    } else {
                        self.schedule_round_steps();
                    }
                }
            }
        }
        Ok(())
    }
}

/// Run a serving scenario on the event-driven engine with explicit
/// sink/materialization options (see the [module docs](self)).
pub fn simulate_serving_with(
    model: &ModelPreset,
    params: ServingParams,
    cfg: &AccelConfig,
    mut opts: ServingSimOptions<'_>,
) -> Result<ServingResult> {
    params.validate()?;
    cfg.validate()?;
    let lanes = lane_presets(model, &params);
    ensure!(
        lanes.len() == params.tenants as usize,
        "model `{}` has no paper counterpart for multi-model tenancy (tenants={})",
        model.name,
        params.tenants
    );
    let costs: Vec<CostModel> = lanes.iter().map(|m| CostModel::new(m, cfg)).collect();
    let reqs = generate_requests(&params);

    // Pages are sized by lane 0 (the spec's model); capacity covers the
    // worst-case lane so preemption is never space-forced.
    let page_bytes = params.page_tokens as u64 * costs[0].kv_token_bytes;
    let capacity = arena_capacity(model, &params);

    if let Some(sink) = opts.sink.as_deref_mut() {
        sink.begin(&[MemoryDesc {
            name: "kv-arena".to_string(),
            capacity,
        }]);
    }

    let fast = opts.sink.is_none() && !opts.materialize;
    let word = costs[0].word;
    let first_arrival = reqs[0].arrival;
    let mut eng = Engine {
        params,
        lanes,
        costs,
        reqs,
        word,
        fast,
        materialize: opts.materialize,
        arena: PagedKvArena::new(page_bytes, capacity),
        trace: OccupancyTrace::new("kv-arena", capacity),
        stats: AccessStats::default(),
        raw: RawKv::default(),
        sink: opts.sink,
        last_emitted: (0, 0),
        heap: BinaryHeap::new(),
        seq: 0,
        waiting: BinaryHeap::new(),
        active: VecDeque::new(),
        cursor: 0,
        wait_order: 0,
        admit_stamp: 0,
        in_flight: 0,
        now: 0,
        completed: 0,
        peak_concurrent: 0,
        evicted: 0,
        restored: 0,
    };

    // Shared-prefix pages pin at t = 0, before any request arrives —
    // the occupancy floor every gating policy sees. Each lane writes
    // its own prefix KV once at startup.
    if eng.params.prefix_tokens > 0 {
        let prefix_bytes: Vec<u64> = eng
            .costs
            .iter()
            .map(|c| eng.params.prefix_tokens as u64 * c.kv_token_bytes)
            .collect();
        for bytes in prefix_bytes {
            if !eng.fast {
                eng.arena
                    .reserve_shared(bytes)
                    .context("reserving shared prefix pages")?;
            }
            eng.kv_write(bytes);
        }
        eng.emit(0);
    }

    // Kick-off: arm the first arrival and the first scheduler boundary.
    eng.push(first_arrival, EvKind::Arrival(0));
    eng.push(first_arrival, EvKind::Round);
    eng.run()?;

    let Engine {
        mut trace,
        mut stats,
        raw,
        sink,
        now,
        completed,
        peak_concurrent,
        evicted,
        restored,
        ..
    } = eng;
    if fast {
        raw.flush_into(&mut stats);
    }
    trace.finalize(now);
    if let Some(s) = sink {
        s.finish(now);
    }
    if opts.materialize {
        trace.validate().context("serving trace invariant")?;
    }

    Ok(ServingResult {
        workload: workload_label(model, &params),
        accel: cfg.name.clone(),
        trace,
        stats,
        total_cycles: now,
        completed,
        peak_concurrent,
        evicted,
        restored,
        page_bytes,
        arena_capacity: capacity,
        freq_ghz: cfg.sa.freq_ghz,
    })
}

/// The retained round-by-round scheduler — the event engine's
/// differential oracle, mirroring the `sweep_naive` pattern. Handles
/// the full arrival/length model (bursts and heavy tails live in
/// [`generate_requests`]) but only legacy scheduling: no priority
/// tiers, no shared prefix, no tenancy.
pub fn round_robin(
    model: &ModelPreset,
    params: ServingParams,
    cfg: &AccelConfig,
    mut opts: ServingSimOptions<'_>,
) -> Result<ServingResult> {
    params.validate()?;
    cfg.validate()?;
    ensure!(
        params.tiers <= 1 && params.prefix_tokens == 0 && params.tenants <= 1,
        "round_robin oracle supports only the legacy scheduling model \
         (tiers <= 1, prefix_tokens == 0, tenants <= 1); got tiers={} \
         prefix_tokens={} tenants={}",
        params.tiers,
        params.prefix_tokens,
        params.tenants
    );
    let cost = CostModel::new(model, cfg);
    let reqs = generate_requests(&params);

    // Arena sized so the concurrency cap — not page exhaustion — is the
    // admission limit (see `arena_capacity`).
    let page_bytes = params.page_tokens as u64 * cost.kv_token_bytes;
    let capacity = arena_capacity(model, &params);

    let mut arena = PagedKvArena::new(page_bytes, capacity);
    let mut trace = OccupancyTrace::new("kv-arena", capacity);
    let mut stats = AccessStats::default();
    if let Some(sink) = opts.sink.as_deref_mut() {
        sink.begin(&[MemoryDesc {
            name: "kv-arena".to_string(),
            capacity,
        }]);
    }

    let mut last_emitted = (0u64, 0u64);
    let materialize = opts.materialize;
    let mut active: VecDeque<Stream> = VecDeque::new();
    let mut next = 0usize;
    let mut now = 0u64;
    let mut completed = 0u32;
    let mut peak_concurrent = 0u32;

    loop {
        // Continuous-batching admission: arrived requests join while the
        // concurrency cap has room.
        while next < reqs.len()
            && active.len() < params.concurrency as usize
            && reqs[next].arrival <= now
        {
            let r = reqs[next];
            next += 1;
            now += cost.prefill_cycles(model, r.prompt);
            arena
                .admit(r.id)
                .and_then(|()| arena.grow(r.id, r.prompt as u64 * cost.kv_token_bytes))
                .with_context(|| format!("admitting request {}", r.id))?;
            stats.dram_read(cost.weight_bytes);
            stats.sram_write(r.prompt as u64 * cost.kv_token_bytes, cost.word, "kv");
            active.push_back(Stream {
                id: r.id,
                ctx: r.prompt,
                remaining: r.gen,
                tier: r.tier,
                lane: r.lane,
                admitted_seq: 0,
            });
            peak_concurrent = peak_concurrent.max(active.len() as u32);
            emit_change(
                now,
                &arena,
                materialize,
                &mut trace,
                &mut opts.sink,
                &mut last_emitted,
            );
            if let Some(s) = opts.sink.as_deref_mut() {
                s.on_event(now, &RunEvent::Admit { request: r.id });
            }
        }

        if active.is_empty() {
            // Idle: jump to the next arrival, or finish.
            let Some(r) = reqs.get(next) else { break };
            now = now.max(r.arrival);
            continue;
        }

        // One round: weights stream once for the whole batch...
        if cost.weight_cycles > 0 {
            now += cost.weight_cycles;
            stats.dram_read(cost.weight_bytes);
        }
        // ...then each active stream decodes one token, round-robin.
        for _ in 0..active.len() {
            let mut s = active.pop_front().expect("active non-empty");
            s.ctx += 1;
            s.remaining -= 1;
            now += cost.decode_step_cycles(s.ctx);
            arena
                .grow(s.id, cost.kv_token_bytes)
                .with_context(|| format!("decode step of request {}", s.id))?;
            stats.sram_read(s.ctx as u64 * cost.kv_token_bytes, cost.word, "kv");
            stats.sram_write(cost.kv_token_bytes, cost.word, "kv");
            let finished = s.remaining == 0;
            if finished {
                arena
                    .release(s.id)
                    .with_context(|| format!("completing request {}", s.id))?;
                completed += 1;
            } else {
                active.push_back(s);
            }
            emit_change(
                now,
                &arena,
                materialize,
                &mut trace,
                &mut opts.sink,
                &mut last_emitted,
            );
            if finished {
                if let Some(snk) = opts.sink.as_deref_mut() {
                    snk.on_event(now, &RunEvent::Complete { request: s.id });
                }
            }
        }
    }

    trace.finalize(now);
    if let Some(sink) = opts.sink.as_deref_mut() {
        sink.finish(now);
    }
    if opts.materialize {
        trace.validate().context("serving trace invariant")?;
    }

    Ok(ServingResult {
        workload: workload_label(model, &params),
        accel: cfg.name.clone(),
        trace,
        stats,
        total_cycles: now,
        completed,
        peak_concurrent,
        evicted: 0,
        restored: 0,
        page_bytes,
        arena_capacity: capacity,
        freq_ghz: cfg.sa.freq_ghz,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::tiny;
    use crate::trace::{MaterializeSink, OnlineStatsSink, TeeSink};
    use crate::workload::TINY_GQA;

    fn params(requests: u32, concurrency: u32, seed: u64) -> ServingParams {
        let mut p = ServingParams::new(requests, concurrency, seed);
        // Small lengths keep the unit tests fast.
        p.prompt_min = 4;
        p.prompt_max = 32;
        p.gen_min = 2;
        p.gen_max = 16;
        p.page_tokens = 8;
        p.mean_arrival_gap = 50_000;
        p
    }

    #[test]
    fn all_requests_complete_and_arena_drains() {
        let r = simulate_serving(&TINY_GQA, params(40, 4, 9), &tiny()).unwrap();
        assert_eq!(r.completed, 40);
        assert!(r.peak_concurrent >= 1 && r.peak_concurrent <= 4);
        assert!(r.total_cycles > 0);
        // The arena drains at the end: final state is empty.
        let last = r.trace.samples().last().unwrap();
        assert_eq!(last.needed, 0);
        assert_eq!(last.obsolete, 0);
        r.trace.validate().unwrap();
    }

    #[test]
    fn deterministic_across_runs() {
        let a = simulate_serving(&TINY_GQA, params(30, 4, 7), &tiny()).unwrap();
        let b = simulate_serving(&TINY_GQA, params(30, 4, 7), &tiny()).unwrap();
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.trace.samples(), b.trace.samples());
        assert_eq!(a.trace_hash(), b.trace_hash());
        assert_eq!(a.stats, b.stats);
        let c = simulate_serving(&TINY_GQA, params(30, 4, 8), &tiny()).unwrap();
        assert_ne!(a.trace_hash(), c.trace_hash());
    }

    #[test]
    fn concurrency_raises_peak_occupancy() {
        let p1 = simulate_serving(&TINY_GQA, params(40, 1, 5), &tiny()).unwrap();
        let p8 = simulate_serving(&TINY_GQA, params(40, 8, 5), &tiny()).unwrap();
        assert!(p8.peak_concurrent > p1.peak_concurrent);
        assert!(
            p8.peak_needed() > p1.peak_needed(),
            "8-way serving peak {} must exceed 1-way {}",
            p8.peak_needed(),
            p1.peak_needed()
        );
    }

    #[test]
    fn fragmentation_shows_up_as_obsolete() {
        let r = simulate_serving(&TINY_GQA, params(20, 4, 3), &tiny()).unwrap();
        // Paged allocation with 8-token pages and arbitrary prompt/gen
        // lengths must leave partially-filled tail pages at some point.
        assert!(
            r.trace.samples().iter().any(|s| s.obsolete > 0),
            "paged arena never fragmented"
        );
        // And fragmentation is bounded by one page per active stream.
        for s in r.trace.samples() {
            assert!(s.obsolete < r.page_bytes * (r.peak_concurrent as u64 + 1));
        }
    }

    #[test]
    fn arena_capacity_matches_simulated_arena() {
        let p = params(10, 4, 2);
        let r = simulate_serving(&TINY_GQA, p, &tiny()).unwrap();
        assert_eq!(r.arena_capacity, arena_capacity(&TINY_GQA, &p));
        // The provisioned bound always covers the observed occupancy.
        assert!(r.peak_occupied() <= r.arena_capacity);
        // Legacy identity: with no extensions the bound is exactly the
        // pre-extension formula.
        let kvb = TINY_GQA.kv_cache_bytes(1);
        let legacy = p.concurrency as u64
            * ceil_div(p.max_stream_tokens() as u64, p.page_tokens as u64)
            * (p.page_tokens as u64 * kvb);
        assert_eq!(arena_capacity(&TINY_GQA, &p), legacy);
    }

    #[test]
    fn every_request_is_admitted_then_completed() {
        struct Recorder(Vec<(u64, RunEvent)>);
        impl TraceSink for Recorder {
            fn on_sample(&mut self, _m: usize, _t: u64, _n: u64, _o: u64) {}
            fn on_event(&mut self, t: u64, event: &RunEvent) {
                self.0.push((t, *event));
            }
        }
        let p = params(20, 4, 13);
        let mut rec = Recorder(Vec::new());
        let r = simulate_serving_with(
            &TINY_GQA,
            p,
            &tiny(),
            ServingSimOptions { sink: Some(&mut rec), materialize: false },
        )
        .unwrap();
        assert_eq!(r.completed, 20);
        for id in 0..20u32 {
            let admit = rec
                .0
                .iter()
                .position(|(_, e)| *e == RunEvent::Admit { request: id });
            let done = rec
                .0
                .iter()
                .position(|(_, e)| *e == RunEvent::Complete { request: id });
            let (Some(admit), Some(done)) = (admit, done) else {
                panic!("request {id} missing admit/complete event");
            };
            assert!(admit < done, "request {id} admitted after completing");
        }
        assert_eq!(rec.0.len(), 40, "one admit + one complete per request");
        for w in rec.0.windows(2) {
            assert!(w[0].0 <= w[1].0, "event time went backwards");
        }
    }

    #[test]
    fn sink_stream_matches_materialized_trace() {
        let p = params(25, 4, 11);
        let reference = simulate_serving(&TINY_GQA, p, &tiny()).unwrap();

        let mut mat = MaterializeSink::new();
        let mut online = OnlineStatsSink::new();
        let streamed = {
            let mut tee = TeeSink::new(vec![&mut mat, &mut online]);
            simulate_serving_with(
                &TINY_GQA,
                p,
                &tiny(),
                ServingSimOptions {
                    sink: Some(&mut tee),
                    materialize: false,
                },
            )
            .unwrap()
        };
        assert_eq!(streamed.total_cycles, reference.total_cycles);
        assert_eq!(streamed.stats, reference.stats);
        // The internal trace stayed empty...
        assert_eq!(streamed.trace.samples().len(), 1);
        // ...while the sink materialization reproduces it exactly.
        assert_eq!(mat.traces().len(), 1);
        assert_eq!(mat.traces()[0].samples(), reference.trace.samples());
        assert_eq!(mat.traces()[0].end_time(), reference.trace.end_time());
        let m = online.shared().unwrap();
        assert_eq!(m.peak_needed(), reference.peak_needed());
        assert_eq!(m.peak_occupied(), reference.peak_occupied());
        assert!((m.avg_needed() - reference.trace.avg_needed()).abs() < 1e-9);
    }

    #[test]
    fn event_engine_matches_round_robin_oracle() {
        // Bit-identity on legacy scheduling, across seeds, shapes, and
        // the arrival/length extensions (which live in workload gen,
        // not the scheduler).
        for seed in [1, 5, 9] {
            for (requests, concurrency) in [(30, 4), (12, 1), (50, 8)] {
                let mut variants = vec![params(requests, concurrency, seed)];
                variants.push(params(requests, concurrency, seed).with_bursty_traffic());
                let mut tail = params(requests, concurrency, seed);
                tail.len_tail_q8 = 192;
                variants.push(tail);
                for p in variants {
                    let oracle =
                        round_robin(&TINY_GQA, p, &tiny(), ServingSimOptions::default())
                            .unwrap();
                    let engine = simulate_serving(&TINY_GQA, p, &tiny()).unwrap();
                    assert_eq!(engine.trace.samples(), oracle.trace.samples());
                    assert_eq!(engine.trace.end_time(), oracle.trace.end_time());
                    assert_eq!(engine.trace_hash(), oracle.trace_hash());
                    assert_eq!(engine.stats, oracle.stats);
                    assert_eq!(engine.total_cycles, oracle.total_cycles);
                    assert_eq!(engine.completed, oracle.completed);
                    assert_eq!(engine.peak_concurrent, oracle.peak_concurrent);
                    assert_eq!(engine.workload, oracle.workload);
                }
            }
        }
    }

    #[test]
    fn oracle_rejects_extended_scheduling() {
        let mut p = params(8, 2, 1);
        p.tiers = 2;
        assert!(round_robin(&TINY_GQA, p, &tiny(), ServingSimOptions::default())
            .is_err());
        let mut p = params(8, 2, 1);
        p.prefix_tokens = 8;
        assert!(round_robin(&TINY_GQA, p, &tiny(), ServingSimOptions::default())
            .is_err());
    }

    #[test]
    fn throughput_mode_matches_materialized_totals() {
        let mut specs = vec![params(30, 4, 7), params(30, 4, 7).with_bursty_traffic()];
        let mut tiered = params(40, 2, 3);
        tiered.tiers = 3;
        tiered.mean_arrival_gap = 500;
        specs.push(tiered);
        let mut fancy = params(24, 3, 5);
        fancy.prefix_tokens = 16;
        fancy.tenants = 2;
        specs.push(fancy);
        for p in specs {
            let slow = simulate_serving(&TINY_GQA, p, &tiny()).unwrap();
            let fast = simulate_serving_with(
                &TINY_GQA,
                p,
                &tiny(),
                ServingSimOptions { sink: None, materialize: false },
            )
            .unwrap();
            assert_eq!(fast.total_cycles, slow.total_cycles);
            assert_eq!(fast.stats, slow.stats);
            assert_eq!(fast.completed, slow.completed);
            assert_eq!(fast.peak_concurrent, slow.peak_concurrent);
            assert_eq!(fast.evicted, slow.evicted);
            assert_eq!(fast.restored, slow.restored);
        }
    }

    #[test]
    fn preemption_evicts_and_restores_deterministically() {
        let mut any_evicted = false;
        for seed in [1, 2, 3] {
            let mut p = params(40, 2, seed);
            p.tiers = 3;
            p.mean_arrival_gap = 500;
            let a = simulate_serving(&TINY_GQA, p, &tiny()).unwrap();
            let b = simulate_serving(&TINY_GQA, p, &tiny()).unwrap();
            assert_eq!(a.trace_hash(), b.trace_hash());
            assert_eq!(a.stats, b.stats);
            assert_eq!(a.completed, 40);
            // Every evicted stream is restored before it can finish.
            assert_eq!(a.evicted, a.restored);
            // Preemption spills show up as DRAM write traffic.
            if a.evicted > 0 {
                any_evicted = true;
                assert!(a.stats.dram_write_bytes > 0);
            }
            // Arena still drains completely.
            let last = a.trace.samples().last().unwrap();
            assert_eq!((last.needed, last.obsolete), (0, 0));
            a.trace.validate().unwrap();
            assert!(a.workload.ends_with("-t3"), "{}", a.workload);
        }
        assert!(
            any_evicted,
            "tight tiered arrivals never preempted across 3 seeds"
        );
    }

    #[test]
    fn shared_prefix_sets_occupancy_floor() {
        let mut p = params(20, 4, 9);
        p.prefix_tokens = 16;
        let r = simulate_serving(&TINY_GQA, p, &tiny()).unwrap();
        let floor = 16 * TINY_GQA.kv_cache_bytes(1);
        assert_eq!(r.completed, 20);
        // The floor pins from t = 0 and never drains.
        let first = r.trace.samples().first().unwrap();
        assert_eq!((first.t, first.needed), (0, floor));
        for s in r.trace.samples() {
            assert!(s.needed >= floor, "needed {} under floor {floor}", s.needed);
        }
        assert_eq!(r.trace.samples().last().unwrap().needed, floor);
        assert!(r.peak_occupied() <= r.arena_capacity);
        assert!(r.workload.ends_with("-p16"), "{}", r.workload);
        // The same workload without the prefix drains to zero.
        let base = simulate_serving(&TINY_GQA, params(20, 4, 9), &tiny()).unwrap();
        assert_eq!(base.trace.samples().last().unwrap().needed, 0);
    }

    #[test]
    fn co_resident_tenancy_completes_and_is_covered() {
        let mut p = params(30, 4, 7);
        p.tenants = 2;
        let r = simulate_serving(&TINY_GQA, p, &tiny()).unwrap();
        assert_eq!(r.completed, 30);
        assert!(r.workload.ends_with("-m2"), "{}", r.workload);
        // Pages are sized by lane 0; capacity covers the hungrier
        // counterpart lane (TINY_MHA has 2x the KV bytes per token).
        assert_eq!(r.page_bytes, p.page_tokens as u64 * TINY_GQA.kv_cache_bytes(1));
        assert!(r.arena_capacity > arena_capacity(&TINY_GQA, &params(30, 4, 7)));
        assert!(r.peak_occupied() <= r.arena_capacity);
        r.trace.validate().unwrap();
        // Determinism holds with two cost models in play.
        let again = simulate_serving(&TINY_GQA, p, &tiny()).unwrap();
        assert_eq!(r.trace_hash(), again.trace_hash());
    }

    #[test]
    fn tenancy_requires_a_paper_counterpart() {
        let mut unknown = TINY_GQA.clone();
        unknown.name = "mystery-model";
        let mut p = params(8, 2, 1);
        p.tenants = 2;
        let err = simulate_serving(&unknown, p, &tiny()).unwrap_err();
        assert!(err.to_string().contains("no paper counterpart"), "{err}");
    }
}
