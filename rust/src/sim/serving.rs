//! Serving-level simulator: round-robin continuous batching of many
//! decode streams over a paged KV arena.
//!
//! Where `sim::engine` resolves one sequence at op granularity, this
//! scheduler resolves a whole request population at *decode-step*
//! granularity — the right resolution for serving-shaped occupancy,
//! where the interesting dynamics (staggered arrivals, concurrency
//! plateaus, completion churn, paged fragmentation) span billions of
//! cycles. Per-step costs come from a closed-form model of the same
//! accelerator config the cycle-level engine uses:
//!
//! * one **round** advances every active stream by one token; the
//!   model's weights stream from DRAM once per round (the batching win),
//! * each stream then pays its projection MACs plus the larger of its
//!   attention MACs and its KV streaming time (context-proportional),
//! * **admission** (continuous batching) happens between rounds: arrived
//!   requests join while the concurrency cap has room, paying a prefill
//!   lump and materializing their prompt KV in the arena.
//!
//! Every arena state change is forwarded through the existing
//! [`TraceSink`] machinery with the same piecewise-constant semantics as
//! the cycle-level engine, so serving traces drop into Stage II (and
//! every sink consumer) unchanged. All arithmetic is integer and the
//! workload is seeded, so runs are bit-deterministic.

use std::collections::VecDeque;

use anyhow::{Context, Result};

use crate::config::AccelConfig;
use crate::serving::{generate_requests, PagedKvArena, ServingParams};
use crate::trace::sink::{MemoryDesc, RunEvent, TraceSink};
use crate::trace::{AccessStats, OccupancyTrace};
use crate::util::ceil_div;
use crate::util::fnv::Fnv64;
use crate::workload::ModelPreset;

/// Serving-simulation knobs, mirroring [`super::SimOptions`].
pub struct ServingSimOptions<'s> {
    /// Optional streaming consumer of arena occupancy changes
    /// (memory 0 = the KV arena).
    pub sink: Option<&'s mut dyn TraceSink>,
    /// When false, the result's `trace` stays empty (sink-only run with
    /// O(1) trace memory).
    pub materialize: bool,
}

impl Default for ServingSimOptions<'_> {
    fn default() -> Self {
        Self {
            sink: None,
            materialize: true,
        }
    }
}

/// Output of one serving run.
#[derive(Debug, Clone)]
pub struct ServingResult {
    /// Workload label, e.g. `gpt2-xl-serve-r256-c64-s7`.
    pub workload: String,
    pub accel: String,
    /// Merged KV-arena occupancy trace (empty when the run streamed to a
    /// sink with `materialize = false`).
    pub trace: OccupancyTrace,
    /// KV-traffic access statistics (Eq. 3 inputs for Stage II).
    pub stats: AccessStats,
    /// Makespan in cycles (arrival of first request to last completion).
    pub total_cycles: u64,
    /// Requests that ran to completion (equals the workload size).
    pub completed: u32,
    /// Highest number of simultaneously active streams observed.
    pub peak_concurrent: u32,
    pub page_bytes: u64,
    pub arena_capacity: u64,
    pub freq_ghz: f64,
}

impl ServingResult {
    pub fn seconds(&self) -> f64 {
        self.total_cycles as f64 / (self.freq_ghz * 1e9)
    }

    pub fn peak_needed(&self) -> u64 {
        self.trace.peak_needed()
    }

    pub fn peak_occupied(&self) -> u64 {
        self.trace.peak_occupied()
    }

    /// Stable FNV-1a fingerprint of the materialized trace (samples +
    /// end time) — the CLI's determinism check. Meaningless on
    /// sink-only runs, whose trace is empty.
    pub fn trace_hash(&self) -> u64 {
        let mut h = Fnv64::new();
        h.str(&self.trace.memory);
        h.u64(self.trace.capacity);
        for s in self.trace.samples() {
            h.u64(s.t);
            h.u64(s.needed);
            h.u64(s.obsolete);
        }
        h.u64(self.trace.end_time().unwrap_or(0));
        h.finish()
    }
}

/// Closed-form per-step cost model derived from model + accelerator.
struct CostModel {
    macs_per_cycle: u64,
    /// Shared-SRAM aggregate bandwidth, bytes/cycle.
    sram_bw: u64,
    /// SRAM interface word for access-count accounting.
    word: u32,
    /// Weight bytes streamed from DRAM per round (0 if resident).
    weight_bytes: u64,
    /// Cycles of that weight stream.
    weight_cycles: u64,
    /// KV bytes appended per generated token (all layers, K + V).
    kv_token_bytes: u64,
    /// Per-token projection + FFN MACs (whole model).
    proj_macs: u64,
    /// Attention MACs per context token per generated token.
    attn_macs_per_ctx: u64,
}

impl CostModel {
    fn new(m: &ModelPreset, cfg: &AccelConfig) -> Self {
        let macs_per_cycle =
            (cfg.sa.rows as u64 * cfg.sa.cols as u64 * cfg.sa.count as u64).max(1);
        let sram = cfg.shared_sram();
        let sram_bw = sram.bandwidth().max(1);
        let dram_bw = cfg.dram.bandwidth().max(1);
        let weight_bytes = if cfg.sched.weight_resident {
            0
        } else {
            m.param_count()
        };
        Self {
            macs_per_cycle,
            sram_bw,
            word: sram.bytes_per_cycle,
            weight_bytes,
            weight_cycles: ceil_div(weight_bytes, dram_bw),
            kv_token_bytes: m.kv_cache_bytes(1),
            proj_macs: m.total_macs(1),
            attn_macs_per_ctx: 2 * m.layers as u64 * m.heads as u64 * m.d_head as u64,
        }
    }

    /// Cycles one stream adds to a round when decoding at context `ctx`.
    fn decode_step_cycles(&self, ctx: u32) -> u64 {
        let attn = ceil_div(self.attn_macs_per_ctx * ctx as u64, self.macs_per_cycle);
        let kv_stream = ceil_div(self.kv_token_bytes * ctx as u64, self.sram_bw);
        let proj = ceil_div(self.proj_macs, self.macs_per_cycle);
        (proj + attn.max(kv_stream)).max(1)
    }

    /// Admission lump: compute-bound prefill, floored by one weight pass.
    fn prefill_cycles(&self, m: &ModelPreset, prompt: u32) -> u64 {
        let compute = ceil_div(m.total_macs(prompt as u64), self.macs_per_cycle);
        compute.max(self.weight_cycles)
    }
}

/// One active decode stream.
#[derive(Debug, Clone, Copy)]
struct Stream {
    id: u32,
    /// Tokens currently in the stream's KV cache.
    ctx: u32,
    /// Tokens still to generate.
    remaining: u32,
}

/// Forward the arena's occupancy to the trace/sink iff it changed since
/// the last emission (same piecewise-constant semantics as the engine).
fn emit_change(
    t: u64,
    arena: &PagedKvArena,
    materialize: bool,
    trace: &mut OccupancyTrace,
    sink: &mut Option<&mut dyn TraceSink>,
    last: &mut (u64, u64),
) {
    let cur = (arena.needed_bytes(), arena.obsolete_bytes());
    if *last == cur {
        return;
    }
    *last = cur;
    if materialize {
        trace.record(t, cur.0, cur.1);
    }
    if let Some(s) = sink.as_deref_mut() {
        s.on_sample(0, t, cur.0, cur.1);
    }
}

/// KV-arena capacity the serving simulator provisions for `(model,
/// params)`: every stream can grow to its maximum context, so the
/// concurrency cap — not page exhaustion — is the admission limit. A
/// pure function of its inputs, exposed so fused Stage-II grids
/// (`ExperimentSpec::serve_fused`) can bound candidate capacities
/// *before* the simulation runs.
pub fn arena_capacity(model: &ModelPreset, params: &ServingParams) -> u64 {
    let kv_token_bytes = model.kv_cache_bytes(1);
    let page_bytes = params.page_tokens as u64 * kv_token_bytes;
    let pages_per_stream =
        ceil_div(params.max_stream_tokens() as u64, params.page_tokens as u64);
    params.concurrency as u64 * pages_per_stream * page_bytes
}

/// Run a serving scenario with default options (materialized trace).
pub fn simulate_serving(
    model: &ModelPreset,
    params: ServingParams,
    cfg: &AccelConfig,
) -> Result<ServingResult> {
    simulate_serving_with(model, params, cfg, ServingSimOptions::default())
}

/// Run a serving scenario with explicit sink/materialization options.
pub fn simulate_serving_with(
    model: &ModelPreset,
    params: ServingParams,
    cfg: &AccelConfig,
    mut opts: ServingSimOptions<'_>,
) -> Result<ServingResult> {
    params.validate()?;
    cfg.validate()?;
    let cost = CostModel::new(model, cfg);
    let reqs = generate_requests(&params);

    // Arena sized so the concurrency cap — not page exhaustion — is the
    // admission limit (see `arena_capacity`).
    let page_bytes = params.page_tokens as u64 * cost.kv_token_bytes;
    let capacity = arena_capacity(model, &params);

    let mut arena = PagedKvArena::new(page_bytes, capacity);
    let mut trace = OccupancyTrace::new("kv-arena", capacity);
    let mut stats = AccessStats::default();
    if let Some(sink) = opts.sink.as_deref_mut() {
        sink.begin(&[MemoryDesc {
            name: "kv-arena".to_string(),
            capacity,
        }]);
    }

    let mut last_emitted = (0u64, 0u64);
    let materialize = opts.materialize;
    let mut active: VecDeque<Stream> = VecDeque::new();
    let mut next = 0usize;
    let mut now = 0u64;
    let mut completed = 0u32;
    let mut peak_concurrent = 0u32;

    loop {
        // Continuous-batching admission: arrived requests join while the
        // concurrency cap has room.
        while next < reqs.len()
            && active.len() < params.concurrency as usize
            && reqs[next].arrival <= now
        {
            let r = reqs[next];
            next += 1;
            now += cost.prefill_cycles(model, r.prompt);
            arena
                .admit(r.id)
                .and_then(|()| arena.grow(r.id, r.prompt as u64 * cost.kv_token_bytes))
                .with_context(|| format!("admitting request {}", r.id))?;
            stats.dram_read(cost.weight_bytes);
            stats.sram_write(r.prompt as u64 * cost.kv_token_bytes, cost.word, "kv");
            active.push_back(Stream {
                id: r.id,
                ctx: r.prompt,
                remaining: r.gen,
            });
            peak_concurrent = peak_concurrent.max(active.len() as u32);
            emit_change(
                now,
                &arena,
                materialize,
                &mut trace,
                &mut opts.sink,
                &mut last_emitted,
            );
            if let Some(s) = opts.sink.as_deref_mut() {
                s.on_event(now, &RunEvent::Admit { request: r.id });
            }
        }

        if active.is_empty() {
            // Idle: jump to the next arrival, or finish.
            let Some(r) = reqs.get(next) else { break };
            now = now.max(r.arrival);
            continue;
        }

        // One round: weights stream once for the whole batch...
        if cost.weight_cycles > 0 {
            now += cost.weight_cycles;
            stats.dram_read(cost.weight_bytes);
        }
        // ...then each active stream decodes one token, round-robin.
        for _ in 0..active.len() {
            let mut s = active.pop_front().expect("active non-empty");
            s.ctx += 1;
            s.remaining -= 1;
            now += cost.decode_step_cycles(s.ctx);
            arena
                .grow(s.id, cost.kv_token_bytes)
                .with_context(|| format!("decode step of request {}", s.id))?;
            stats.sram_read(s.ctx as u64 * cost.kv_token_bytes, cost.word, "kv");
            stats.sram_write(cost.kv_token_bytes, cost.word, "kv");
            let finished = s.remaining == 0;
            if finished {
                arena
                    .release(s.id)
                    .with_context(|| format!("completing request {}", s.id))?;
                completed += 1;
            } else {
                active.push_back(s);
            }
            emit_change(
                now,
                &arena,
                materialize,
                &mut trace,
                &mut opts.sink,
                &mut last_emitted,
            );
            if finished {
                if let Some(snk) = opts.sink.as_deref_mut() {
                    snk.on_event(now, &RunEvent::Complete { request: s.id });
                }
            }
        }
    }

    trace.finalize(now);
    if let Some(sink) = opts.sink.as_deref_mut() {
        sink.finish(now);
    }
    if opts.materialize {
        trace.validate().context("serving trace invariant")?;
    }

    Ok(ServingResult {
        workload: format!(
            "{}-serve-r{}-c{}-s{}",
            model.name, params.requests, params.concurrency, params.seed
        ),
        accel: cfg.name.clone(),
        trace,
        stats,
        total_cycles: now,
        completed,
        peak_concurrent,
        page_bytes,
        arena_capacity: capacity,
        freq_ghz: cfg.sa.freq_ghz,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::tiny;
    use crate::trace::{MaterializeSink, OnlineStatsSink, TeeSink};
    use crate::workload::TINY_GQA;

    fn params(requests: u32, concurrency: u32, seed: u64) -> ServingParams {
        let mut p = ServingParams::new(requests, concurrency, seed);
        // Small lengths keep the unit tests fast.
        p.prompt_min = 4;
        p.prompt_max = 32;
        p.gen_min = 2;
        p.gen_max = 16;
        p.page_tokens = 8;
        p.mean_arrival_gap = 50_000;
        p
    }

    #[test]
    fn all_requests_complete_and_arena_drains() {
        let r = simulate_serving(&TINY_GQA, params(40, 4, 9), &tiny()).unwrap();
        assert_eq!(r.completed, 40);
        assert!(r.peak_concurrent >= 1 && r.peak_concurrent <= 4);
        assert!(r.total_cycles > 0);
        // The arena drains at the end: final state is empty.
        let last = r.trace.samples().last().unwrap();
        assert_eq!(last.needed, 0);
        assert_eq!(last.obsolete, 0);
        r.trace.validate().unwrap();
    }

    #[test]
    fn deterministic_across_runs() {
        let a = simulate_serving(&TINY_GQA, params(30, 4, 7), &tiny()).unwrap();
        let b = simulate_serving(&TINY_GQA, params(30, 4, 7), &tiny()).unwrap();
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.trace.samples(), b.trace.samples());
        assert_eq!(a.trace_hash(), b.trace_hash());
        assert_eq!(a.stats, b.stats);
        let c = simulate_serving(&TINY_GQA, params(30, 4, 8), &tiny()).unwrap();
        assert_ne!(a.trace_hash(), c.trace_hash());
    }

    #[test]
    fn concurrency_raises_peak_occupancy() {
        let p1 = simulate_serving(&TINY_GQA, params(40, 1, 5), &tiny()).unwrap();
        let p8 = simulate_serving(&TINY_GQA, params(40, 8, 5), &tiny()).unwrap();
        assert!(p8.peak_concurrent > p1.peak_concurrent);
        assert!(
            p8.peak_needed() > p1.peak_needed(),
            "8-way serving peak {} must exceed 1-way {}",
            p8.peak_needed(),
            p1.peak_needed()
        );
    }

    #[test]
    fn fragmentation_shows_up_as_obsolete() {
        let r = simulate_serving(&TINY_GQA, params(20, 4, 3), &tiny()).unwrap();
        // Paged allocation with 8-token pages and arbitrary prompt/gen
        // lengths must leave partially-filled tail pages at some point.
        assert!(
            r.trace.samples().iter().any(|s| s.obsolete > 0),
            "paged arena never fragmented"
        );
        // And fragmentation is bounded by one page per active stream.
        for s in r.trace.samples() {
            assert!(s.obsolete < r.page_bytes * (r.peak_concurrent as u64 + 1));
        }
    }

    #[test]
    fn arena_capacity_matches_simulated_arena() {
        let p = params(10, 4, 2);
        let r = simulate_serving(&TINY_GQA, p, &tiny()).unwrap();
        assert_eq!(r.arena_capacity, arena_capacity(&TINY_GQA, &p));
        // The provisioned bound always covers the observed occupancy.
        assert!(r.peak_occupied() <= r.arena_capacity);
    }

    #[test]
    fn every_request_is_admitted_then_completed() {
        struct Recorder(Vec<(u64, RunEvent)>);
        impl TraceSink for Recorder {
            fn on_sample(&mut self, _m: usize, _t: u64, _n: u64, _o: u64) {}
            fn on_event(&mut self, t: u64, event: &RunEvent) {
                self.0.push((t, *event));
            }
        }
        let p = params(20, 4, 13);
        let mut rec = Recorder(Vec::new());
        let r = simulate_serving_with(
            &TINY_GQA,
            p,
            &tiny(),
            ServingSimOptions { sink: Some(&mut rec), materialize: false },
        )
        .unwrap();
        assert_eq!(r.completed, 20);
        for id in 0..20u32 {
            let admit = rec
                .0
                .iter()
                .position(|(_, e)| *e == RunEvent::Admit { request: id });
            let done = rec
                .0
                .iter()
                .position(|(_, e)| *e == RunEvent::Complete { request: id });
            let (Some(admit), Some(done)) = (admit, done) else {
                panic!("request {id} missing admit/complete event");
            };
            assert!(admit < done, "request {id} admitted after completing");
        }
        assert_eq!(rec.0.len(), 40, "one admit + one complete per request");
        for w in rec.0.windows(2) {
            assert!(w[0].0 <= w[1].0, "event time went backwards");
        }
    }

    #[test]
    fn sink_stream_matches_materialized_trace() {
        let p = params(25, 4, 11);
        let reference = simulate_serving(&TINY_GQA, p, &tiny()).unwrap();

        let mut mat = MaterializeSink::new();
        let mut online = OnlineStatsSink::new();
        let streamed = {
            let mut tee = TeeSink::new(vec![&mut mat, &mut online]);
            simulate_serving_with(
                &TINY_GQA,
                p,
                &tiny(),
                ServingSimOptions {
                    sink: Some(&mut tee),
                    materialize: false,
                },
            )
            .unwrap()
        };
        assert_eq!(streamed.total_cycles, reference.total_cycles);
        assert_eq!(streamed.stats, reference.stats);
        // The internal trace stayed empty...
        assert_eq!(streamed.trace.samples().len(), 1);
        // ...while the sink materialization reproduces it exactly.
        assert_eq!(mat.traces().len(), 1);
        assert_eq!(mat.traces()[0].samples(), reference.trace.samples());
        assert_eq!(mat.traces()[0].end_time(), reference.trace.end_time());
        let m = online.shared().unwrap();
        assert_eq!(m.peak_needed(), reference.peak_needed());
        assert_eq!(m.peak_occupied(), reference.peak_occupied());
        assert!((m.avg_needed() - reference.trace.avg_needed()).abs() < 1e-9);
    }
}
