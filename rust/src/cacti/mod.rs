//! CACTI-style analytical SRAM characterization (45 nm itrs-hp),
//! calibrated against the paper's CACTI 7 outputs. Supplies per-access
//! energies, per-bank leakage, transition costs, area, and latency to
//! Stage II and the Stage-I latency model.

pub mod model;
pub mod tech;

pub use model::{CactiModel, SramCharacterization};
pub use tech::TechParams;
