//! 45 nm itrs-hp technology constants for the analytical SRAM model.
//!
//! The paper characterizes SRAM candidates with CACTI 7 at 45 nm using
//! the `itrs-hp` (high-performance, high-leakage) device model. CACTI
//! itself is not available in this environment, so `model.rs` implements
//! a CACTI-shaped analytical model whose coefficients are *calibrated
//! against the paper's own Table II / Table III outputs* (which are
//! CACTI numbers) — see DESIGN.md's substitution table and
//! EXPERIMENTS.md §Calibration for the fit.

/// Calibratable coefficient set. Defaults reproduce the paper's Table II
/// trends under the Stage-I access counts of this repository's simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct TechParams {
    // --- dynamic access energy: E_acc(C,B) = e0 + kc*(C/B) + kb*sqrt(B) [nJ]
    /// Base energy per (64 B word) access, nJ.
    pub e0_nj: f64,
    /// Wordline/bitline scaling with per-bank capacity, nJ per MiB.
    pub kc_nj_per_mib: f64,
    /// Inter-bank H-tree routing overhead, nJ per sqrt(bank).
    pub kb_nj: f64,

    // --- leakage: P_bank(C,B) = pm*(C/B) + pb [W]
    /// Cell-array leakage per MiB (itrs-hp is leakage-dominated).
    pub pm_w_per_mib: f64,
    /// Per-bank peripheral leakage, W.
    pub pb_w: f64,

    // --- power gating
    /// Sleep-transistor transition energy per bank, nJ per MiB of bank.
    pub esw_nj_per_mib: f64,
    /// Wake-up latency per transition, cycles (ns at 1 GHz).
    pub wake_cycles: u64,

    // --- area: A(C,B) = a0 + am*C + ab*C*log2(B) [mm^2]
    pub a0_mm2: f64,
    pub am_mm2_per_mib: f64,
    /// Banking area overhead per MiB per log2(bank) (H-tree + periphery).
    pub ab_mm2: f64,

    // --- access latency: L(C,B) = max(1, l0 + l1*sqrt(C/B) + lb*sqrt(B)) [cycles]
    pub l0_cycles: f64,
    pub l1_cycles_per_sqrt_mib: f64,
    pub lb_cycles: f64,
}

impl Default for TechParams {
    fn default() -> Self {
        Self::itrs_hp_45nm()
    }
}

impl TechParams {
    /// Calibrated 45 nm itrs-hp parameters (EXPERIMENTS.md §Calibration).
    pub fn itrs_hp_45nm() -> Self {
        // Fitted against the paper's Table II (CACTI 7, 45 nm itrs-hp)
        // using this simulator's Stage-I access counts and run times —
        // derivation in EXPERIMENTS.md §Calibration. The DS-R1D B=1
        // column reproduces to <1%:
        //   E(C) = N_eff*(e0 + kc*C) + pm*C*T
        //   DS: 913e6 accesses, T=0.208 s -> e0=2.7 nJ, kc=0.054 nJ/MiB,
        //   pm=0.792 W/MiB (leakage-dominated, as itrs-hp must be).
        Self {
            e0_nj: 2.7,
            kc_nj_per_mib: 0.054,
            kb_nj: 1.65,
            pm_w_per_mib: 0.792,
            pb_w: 0.05,
            esw_nj_per_mib: 200.0,
            wake_cycles: 100,
            a0_mm2: 49.06,
            am_mm2_per_mib: 16.78,
            ab_mm2: 0.5, // area overhead: +ab * C_MiB * log2(B)
            l0_cycles: -2.14,
            l1_cycles_per_sqrt_mib: 3.018,
            lb_cycles: 0.4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_itrs_hp() {
        assert_eq!(TechParams::default(), TechParams::itrs_hp_45nm());
    }

    #[test]
    fn leakage_dominates_as_itrs_hp_should() {
        // At 128 MiB the leakage power must be tens of watts (HP devices)
        // — this is what makes power gating worth 50-80% (Table II).
        let p = TechParams::itrs_hp_45nm();
        let total_leak = p.pm_w_per_mib * 128.0 + p.pb_w;
        assert!(total_leak > 20.0 && total_leak < 150.0, "{total_leak} W");
    }
}
