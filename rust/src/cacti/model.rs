//! CACTI-shaped analytical SRAM characterization.
//!
//! For a banked SRAM of total capacity `C` split into `B` equal banks,
//! produces the quantities Stage II consumes (paper §III-B.1): per-access
//! read/write energy, per-bank leakage power, bank sleep-transition
//! energy, total area, and access latency. Functional forms follow
//! CACTI's structure (bitline energy grows with per-bank capacity,
//! H-tree routing with bank count, leakage with total cells); the
//! coefficients are calibrated against the paper's CACTI 7 numbers.

use crate::util::MIB;

use super::tech::TechParams;

/// Characterization of one (capacity, banks) SRAM organization.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SramCharacterization {
    pub capacity: u64,
    pub banks: u32,
    /// Energy per read access (one interface word), joules.
    pub e_read_j: f64,
    /// Energy per write access, joules.
    pub e_write_j: f64,
    /// Leakage power of ONE bank, watts.
    pub p_leak_bank_w: f64,
    /// Energy of one on<->off bank transition, joules.
    pub e_switch_j: f64,
    /// Wake-up latency, cycles.
    pub wake_cycles: u64,
    /// Total area, mm^2.
    pub area_mm2: f64,
    /// Access latency, cycles.
    pub latency_cycles: u64,
}

impl SramCharacterization {
    /// Leakage power with all banks on, watts.
    pub fn p_leak_total_w(&self) -> f64 {
        self.p_leak_bank_w * self.banks as f64
    }
}

#[derive(Debug, Clone, Default)]
pub struct CactiModel {
    pub tech: TechParams,
}

impl CactiModel {
    pub fn new(tech: TechParams) -> Self {
        Self { tech }
    }

    /// Characterize a (C, B) organization. `banks` must be a power of two
    /// >= 1 (CACTI's constraint, and the paper's sweep set).
    pub fn characterize(&self, capacity: u64, banks: u32) -> SramCharacterization {
        assert!(banks >= 1 && banks.is_power_of_two(), "banks={banks}");
        assert!(capacity > 0);
        let t = &self.tech;
        let c_mib = capacity as f64 / MIB as f64;
        let bank_mib = c_mib / banks as f64;

        let e_read_nj =
            t.e0_nj + t.kc_nj_per_mib * bank_mib + t.kb_nj * (banks as f64).sqrt();
        // CACTI writes cost slightly more than reads (full bitline swing).
        let e_write_nj = e_read_nj * 1.1;

        let p_leak_bank = t.pm_w_per_mib * bank_mib + t.pb_w;
        let e_switch_nj = t.esw_nj_per_mib * bank_mib;

        let area = t.a0_mm2
            + t.am_mm2_per_mib * c_mib
            + t.ab_mm2 * c_mib * (banks as f64).log2();

        let latency = (t.l0_cycles
            + t.l1_cycles_per_sqrt_mib * bank_mib.sqrt()
            + t.lb_cycles * (banks as f64).sqrt())
        .max(1.0)
        .round() as u64;

        SramCharacterization {
            capacity,
            banks,
            e_read_j: e_read_nj * 1e-9,
            e_write_j: e_write_nj * 1e-9,
            p_leak_bank_w: p_leak_bank,
            e_switch_j: e_switch_nj * 1e-9,
            wake_cycles: t.wake_cycles,
            area_mm2: area,
            latency_cycles: latency,
        }
    }

    /// Unbanked access latency at a capacity — the Stage-I memory
    /// latency model (paper: 32 ns @ 128 MiB, 22 ns @ 64 MiB).
    pub fn latency_cycles(&self, capacity: u64) -> u64 {
        self.characterize(capacity, 1).latency_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn model() -> CactiModel {
        CactiModel::default()
    }

    #[test]
    fn paper_latencies() {
        // §IV-A: 32 ns @ 128 MiB; §IV-B: 22 ns @ 64 MiB.
        let m = model();
        assert_eq!(m.latency_cycles(128 * MIB), 32);
        assert_eq!(m.latency_cycles(64 * MIB), 22);
    }

    #[test]
    fn smaller_banks_cheaper_access() {
        let m = model();
        let b1 = m.characterize(128 * MIB, 1);
        let b8 = m.characterize(128 * MIB, 8);
        assert!(b8.e_read_j < b1.e_read_j);
        // But routing overhead eventually pushes cost back up.
        let b256 = m.characterize(128 * MIB, 256);
        assert!(b256.e_read_j > b8.e_read_j);
    }

    #[test]
    fn total_leakage_grows_mildly_with_banks() {
        let m = model();
        let b1 = m.characterize(128 * MIB, 1);
        let b16 = m.characterize(128 * MIB, 16);
        // All-on leakage: banking adds peripheral overhead only.
        assert!(b16.p_leak_total_w() > b1.p_leak_total_w());
        assert!(b16.p_leak_total_w() < b1.p_leak_total_w() * 1.15);
        // One bank of 16 leaks about 1/16th of the array.
        assert!(b16.p_leak_bank_w < b1.p_leak_bank_w / 8.0);
    }

    #[test]
    fn area_grows_with_capacity_and_banks() {
        let m = model();
        let a48 = m.characterize(48 * MIB, 1).area_mm2;
        let a128 = m.characterize(128 * MIB, 1).area_mm2;
        assert!(a128 > a48 * 2.0);
        let a128b32 = m.characterize(128 * MIB, 32).area_mm2;
        assert!(a128b32 > a128);
        // Paper Table II: B=32 adds ~16% over B=1 at 128 MiB.
        let overhead = a128b32 / a128;
        assert!(overhead > 1.05 && overhead < 1.35, "overhead={overhead}");
    }

    #[test]
    fn writes_cost_more_than_reads() {
        let c = model().characterize(64 * MIB, 4);
        assert!(c.e_write_j > c.e_read_j);
    }

    #[test]
    #[should_panic(expected = "banks=3")]
    fn non_power_of_two_rejected() {
        model().characterize(64 * MIB, 3);
    }

    #[test]
    fn prop_characterization_positive_and_monotone() {
        check("cacti-positive", 100, |rng| {
            let m = model();
            let c = rng.range(1, 256) * MIB;
            let b = 1u32 << rng.below(7);
            let ch = m.characterize(c, b);
            assert!(ch.e_read_j > 0.0);
            assert!(ch.p_leak_bank_w > 0.0);
            assert!(ch.area_mm2 > 0.0);
            assert!(ch.latency_cycles >= 1);
            assert!(ch.e_switch_j >= 0.0);
            // Doubling capacity at fixed banks increases area & leakage.
            let ch2 = m.characterize(2 * c, b);
            assert!(ch2.area_mm2 > ch.area_mm2);
            assert!(ch2.p_leak_bank_w > ch.p_leak_bank_w);
        });
    }
}
