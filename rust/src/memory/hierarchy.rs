//! Multi-memory system: shared SRAM (+ optional dedicated memories) over
//! DRAM, with transfer routing and timing.
//!
//! Single-memory setups route everything through `on_chip[0]`. The
//! Fig. 10 multi-level hierarchy attaches SAs to dedicated memories; data
//! produced near one SA pair and consumed by the other hops
//! `dm -> shared -> dm'`, which is exactly the coordination overhead the
//! paper's §IV-D measures.

use anyhow::Result;

use crate::config::AccelConfig;
use crate::trace::AccessStats;
use crate::workload::{TensorId, TensorInfo, TensorKind};

use super::port::PortTimer;
use super::sram::SramModel;

fn kind_label(k: TensorKind) -> &'static str {
    k.label()
}

#[derive(Debug, Clone)]
pub struct MemorySystem {
    pub on_chip: Vec<SramModel>,
    pub dram: PortTimer,
    pub dram_stats: AccessStats,
    mem_of_sa: Vec<u8>,
    /// See `SchedConfig::weight_resident`.
    weight_resident: bool,
}

/// Outcome of ensuring a tensor is readable from a memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FetchOutcome {
    /// Cycle at which the data is available in the destination memory.
    pub ready_at: u64,
    /// True if any off-chip (DRAM) transfer was involved.
    pub from_dram: bool,
    /// Bytes moved (0 if already resident).
    pub moved_bytes: u64,
}

impl MemorySystem {
    pub fn new(cfg: &AccelConfig) -> Self {
        Self {
            on_chip: cfg.on_chip.iter().map(SramModel::new).collect(),
            dram: PortTimer::new(&cfg.dram),
            dram_stats: AccessStats::default(),
            mem_of_sa: cfg.topology.mem_of_sa.clone(),
            weight_resident: cfg.sched.weight_resident,
        }
    }

    /// Memory index the given systolic array streams from.
    pub fn mem_for_sa(&self, sa: usize) -> usize {
        self.mem_of_sa[sa] as usize
    }

    pub fn shared(&self) -> &SramModel {
        &self.on_chip[0]
    }

    pub fn shared_mut(&mut self) -> &mut SramModel {
        &mut self.on_chip[0]
    }

    /// Where is this tensor currently resident (first hit)?
    fn find_resident(&self, t: TensorId) -> Option<usize> {
        self.on_chip.iter().position(|m| m.contains(t))
    }

    /// Ensure `tensor` is resident in memory `dst` by `now`, fetching
    /// from a sibling memory or DRAM as needed. Charges transfer time on
    /// every traversed port and traffic to the stats.
    pub fn ensure_resident(
        &mut self,
        now: u64,
        tensor: &TensorInfo,
        dst: usize,
    ) -> Result<FetchOutcome> {
        let t = tensor.id;
        let bytes = tensor.bytes;
        let kind = kind_label(tensor.kind);

        // Weights never occupy SRAM unless `weight_resident` (small
        // models): the weight-stationary arrays stream them DRAM -> FIFO
        // -> PE registers (charged at dispatch on the DRAM ports by the
        // engine). See DESIGN.md §5.
        if tensor.kind == TensorKind::Weight && !self.weight_resident {
            return Ok(FetchOutcome {
                ready_at: now,
                from_dram: false,
                moved_bytes: 0,
            });
        }

        if self.on_chip[dst].contains(t) {
            self.on_chip[dst].touch(t);
            return Ok(FetchOutcome {
                ready_at: now,
                from_dram: false,
                moved_bytes: 0,
            });
        }

        match self.find_resident(t) {
            // On-chip elsewhere: hop src -> (shared) -> dst.
            Some(src) => {
                let mut ready = now;
                let mut hops: Vec<usize> = Vec::new();
                if src != 0 && dst != 0 {
                    hops.push(0); // dm -> shared -> dm'
                }
                hops.push(dst);
                let mut cur = src;
                for next in hops {
                    // Read from cur, write into next.
                    let rd = self.on_chip[cur].ports.transfer(ready, bytes);
                    let word = self.on_chip[cur].cfg.bytes_per_cycle;
                    self.on_chip[cur].stats.sram_read(bytes, word, kind);
                    let wr = self.on_chip[next].ports.transfer(rd.end, bytes);
                    self.alloc_with_writeback(now, next, tensor)?;
                    let word = self.on_chip[next].cfg.bytes_per_cycle;
                    self.on_chip[next].stats.sram_write(bytes, word, kind);
                    ready = wr.end;
                    // The staging copy in shared stays resident (backup
                    // storage, Fig. 10) and retires with the tensor's
                    // global liveness (complete_op -> mark_obsolete).
                    cur = next;
                }
                Ok(FetchOutcome {
                    ready_at: ready,
                    from_dram: false,
                    moved_bytes: bytes,
                })
            }
            // Off-chip: DRAM -> shared (-> dst).
            None => {
                let dr = self.dram.transfer(now, bytes);
                self.dram_stats.dram_read(bytes);
                self.alloc_with_writeback(now, 0, tensor)?;
                let word = self.on_chip[0].cfg.bytes_per_cycle;
                self.on_chip[0].stats.sram_write(bytes, word, kind);
                let mut ready = dr.end;
                if dst != 0 {
                    let rd = self.on_chip[0].ports.transfer(ready, bytes);
                    self.on_chip[0].stats.sram_read(bytes, word, kind);
                    self.alloc_with_writeback(now, dst, tensor)?;
                    let word_d = self.on_chip[dst].cfg.bytes_per_cycle;
                    self.on_chip[dst].stats.sram_write(bytes, word_d, kind);
                    ready = rd.end;
                }
                Ok(FetchOutcome {
                    ready_at: ready,
                    from_dram: true,
                    moved_bytes: bytes,
                })
            }
        }
    }

    /// Allocate space for an op output in `dst` (no data transfer; the
    /// bytes are written by the op's drain phase, charged separately).
    pub fn allocate_output(
        &mut self,
        now: u64,
        tensor: &TensorInfo,
        dst: usize,
    ) -> Result<()> {
        if self.on_chip[dst].contains(tensor.id) {
            self.on_chip[dst].touch(tensor.id);
            // In-place updates (KV append) keep the tensor needed.
            self.on_chip[dst].mark_needed(now, tensor.id);
            return Ok(());
        }
        self.alloc_with_writeback(now, dst, tensor)?;
        Ok(())
    }

    fn alloc_with_writeback(
        &mut self,
        now: u64,
        mem: usize,
        tensor: &TensorInfo,
    ) -> Result<()> {
        let outcome = self.on_chip[mem].allocate(
            now,
            tensor.id,
            tensor.bytes,
            kind_label(tensor.kind),
        )?;
        // Write-backs stream to DRAM off the critical path: reserve DRAM
        // port time (they do consume bandwidth) but don't block the
        // caller.
        for &(_victim, bytes) in &outcome.writebacks {
            self.dram.transfer(now, bytes);
            self.dram_stats.dram_write(bytes);
        }
        Ok(())
    }

    /// Disable (or re-enable) occupancy-trace materialization in every
    /// on-chip memory (streaming-only runs, see `trace::sink`).
    pub fn set_sample_recording(&mut self, enabled: bool) {
        for m in &mut self.on_chip {
            m.set_sample_recording(enabled);
        }
    }

    /// Mark a tensor obsolete in every memory holding it.
    pub fn mark_obsolete(&mut self, now: u64, t: TensorId) {
        for m in &mut self.on_chip {
            m.mark_obsolete(now, t);
        }
    }

    /// Is the tensor resident anywhere on-chip?
    pub fn resident_anywhere(&self, t: TensorId) -> bool {
        self.find_resident(t).is_some()
    }

    pub fn finalize(&mut self, end: u64) {
        for m in &mut self.on_chip {
            m.finalize(end);
        }
    }

    /// Aggregate access stats across all on-chip memories + DRAM counts.
    pub fn total_stats(&self) -> AccessStats {
        let mut s = AccessStats::default();
        for m in &self.on_chip {
            s.merge(&m.stats);
        }
        s.merge(&self.dram_stats);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{baseline, multilevel};
    use crate::workload::{TensorInfo, TensorKind};

    fn tensor(id: u32, bytes: u64) -> TensorInfo {
        TensorInfo {
            id: TensorId(id),
            name: format!("t{id}"),
            bytes,
            kind: TensorKind::Activation,
            layer: 0,
            producer: None,
            consumers: vec![],
            affinity: None,
        }
    }

    #[test]
    fn dram_fetch_lands_in_shared() {
        let mut ms = MemorySystem::new(&baseline());
        let t = tensor(0, 1 << 20);
        let out = ms.ensure_resident(0, &t, 0).unwrap();
        assert!(out.from_dram);
        assert!(out.ready_at > 0);
        assert!(ms.shared().contains(t.id));
        assert_eq!(ms.dram_stats.dram_read_bytes, 1 << 20);
    }

    #[test]
    fn second_access_is_free() {
        let mut ms = MemorySystem::new(&baseline());
        let t = tensor(0, 4096);
        ms.ensure_resident(0, &t, 0).unwrap();
        let out = ms.ensure_resident(100, &t, 0).unwrap();
        assert_eq!(out.ready_at, 100);
        assert!(!out.from_dram);
        assert_eq!(out.moved_bytes, 0);
    }

    #[test]
    fn multilevel_fetch_stages_through_shared() {
        let mut ms = MemorySystem::new(&multilevel());
        let t = tensor(0, 4096);
        let out = ms.ensure_resident(0, &t, 1).unwrap();
        assert!(out.from_dram);
        assert!(ms.on_chip[1].contains(t.id));
        // Shared keeps a backup copy (Fig. 10); it stays needed until
        // the tensor's global liveness retires it.
        assert!(ms.on_chip[0].contains(t.id));
        assert_eq!(ms.on_chip[0].needed_bytes(), 4096);
        ms.mark_obsolete(10, t.id);
        assert_eq!(ms.on_chip[0].needed_bytes(), 0);
        assert!(ms.on_chip[0].obsolete_bytes() > 0);
        assert_eq!(ms.on_chip[1].needed_bytes(), 0);
    }

    #[test]
    fn cross_dm_hop_charges_both_paths() {
        let mut ms = MemorySystem::new(&multilevel());
        let t = tensor(0, 4096);
        ms.ensure_resident(0, &t, 1).unwrap();
        let shared_reads_before = ms.on_chip[0].stats.reads;
        let out = ms.ensure_resident(1000, &t, 2).unwrap();
        assert!(!out.from_dram, "hop must stay on-chip");
        assert!(ms.on_chip[2].contains(t.id));
        // The shared SRAM holds a backup copy after the first fetch; the
        // hop reads from it (nearest source) rather than from DM1.
        assert!(
            ms.on_chip[0].stats.reads > shared_reads_before,
            "backup copy in shared must be read"
        );
        assert!(out.ready_at > 1000, "hop takes time");
    }

    #[test]
    fn output_allocation_in_place_update() {
        let mut ms = MemorySystem::new(&baseline());
        let t = tensor(0, 4096);
        ms.allocate_output(0, &t, 0).unwrap();
        ms.shared_mut().mark_obsolete(5, t.id);
        // KV-append style re-write flips it back to needed.
        ms.allocate_output(10, &t, 0).unwrap();
        assert_eq!(ms.shared().needed_bytes(), 4096);
    }

    #[test]
    fn total_stats_aggregates() {
        let mut ms = MemorySystem::new(&multilevel());
        let t = tensor(0, 4096);
        ms.ensure_resident(0, &t, 1).unwrap();
        let total = ms.total_stats();
        assert!(total.writes > 0);
        assert_eq!(total.dram_read_bytes, 4096);
    }
}
