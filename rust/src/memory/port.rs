//! Port-level transfer timing shared by all memory models.
//!
//! Each memory has `ports` physical ports; a transfer occupies one port
//! for `latency + ceil(bytes / bytes_per_cycle)` cycles. Requests pick
//! the earliest-free port, so contention emerges naturally as queuing —
//! this is what turns high streaming demand into the memory-bound stalls
//! of the paper's Fig. 6.

use crate::config::MemConfig;

/// One timed transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfer {
    pub start: u64,
    pub end: u64,
}

impl Transfer {
    pub fn duration(&self) -> u64 {
        self.end - self.start
    }
}

#[derive(Debug, Clone)]
pub struct PortTimer {
    free_at: Vec<u64>,
    pub bytes_per_cycle: u32,
    pub latency: u64,
    /// Total port-busy cycles (bandwidth-utilization reporting).
    busy_cycles: u64,
}

impl PortTimer {
    pub fn new(cfg: &MemConfig) -> Self {
        Self {
            free_at: vec![0; cfg.ports as usize],
            bytes_per_cycle: cfg.bytes_per_cycle,
            latency: cfg.latency_cycles,
            busy_cycles: 0,
        }
    }

    /// Cycles a `bytes`-sized transfer occupies a port (latency + burst).
    pub fn service_time(&self, bytes: u64) -> u64 {
        self.latency + bytes.div_ceil(self.bytes_per_cycle as u64)
    }

    /// Reserve the earliest-available port starting no sooner than `now`.
    pub fn transfer(&mut self, now: u64, bytes: u64) -> Transfer {
        let (port, &free) = self
            .free_at
            .iter()
            .enumerate()
            .min_by_key(|(_, &f)| f)
            .expect("ports > 0");
        let start = now.max(free);
        let end = start + self.service_time(bytes);
        self.free_at[port] = end;
        self.busy_cycles += end - start;
        Transfer { start, end }
    }

    /// Earliest time a port is available.
    pub fn next_free(&self) -> u64 {
        *self.free_at.iter().min().expect("ports > 0")
    }

    pub fn busy_cycles(&self) -> u64 {
        self.busy_cycles
    }

    /// Aggregate bandwidth utilization over `elapsed` cycles.
    pub fn utilization(&self, elapsed: u64) -> f64 {
        if elapsed == 0 {
            return 0.0;
        }
        self.busy_cycles as f64 / (elapsed as f64 * self.free_at.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemConfig;

    fn cfg(ports: u32) -> MemConfig {
        MemConfig {
            name: "m".into(),
            capacity: 1 << 20,
            ports,
            bytes_per_cycle: 64,
            latency_cycles: 10,
        }
    }

    #[test]
    fn service_time_rounds_up() {
        let t = PortTimer::new(&cfg(1));
        assert_eq!(t.service_time(0), 10);
        assert_eq!(t.service_time(1), 11);
        assert_eq!(t.service_time(64), 11);
        assert_eq!(t.service_time(65), 12);
    }

    #[test]
    fn single_port_serializes() {
        let mut t = PortTimer::new(&cfg(1));
        let a = t.transfer(0, 64); // 0..11
        let b = t.transfer(0, 64); // queued: 11..22
        assert_eq!(a, Transfer { start: 0, end: 11 });
        assert_eq!(b, Transfer { start: 11, end: 22 });
    }

    #[test]
    fn two_ports_parallelize() {
        let mut t = PortTimer::new(&cfg(2));
        let a = t.transfer(0, 64);
        let b = t.transfer(0, 64);
        assert_eq!(a.start, 0);
        assert_eq!(b.start, 0);
        let c = t.transfer(0, 64); // third waits for first free port
        assert_eq!(c.start, 11);
    }

    #[test]
    fn respects_now() {
        let mut t = PortTimer::new(&cfg(2));
        let a = t.transfer(100, 64);
        assert_eq!(a.start, 100);
    }

    #[test]
    fn utilization_accounting() {
        let mut t = PortTimer::new(&cfg(2));
        t.transfer(0, 64);
        t.transfer(0, 64);
        assert_eq!(t.busy_cycles(), 22);
        assert!((t.utilization(11) - 1.0).abs() < 1e-12);
    }
}
