//! On-chip SRAM residency model: tensor tracking with needed/obsolete
//! states, LRU victim selection (obsolete preferred), capacity-induced
//! write-backs, and occupancy-trace recording.
//!
//! This implements the paper's Stage-I §A.3 semantics exactly:
//!
//! * tensors are *needed* while future ops will read them, *obsolete*
//!   afterwards;
//! * obsolete data lingers (it costs nothing) until eviction pressure;
//! * the LRU policy picks victims among obsolete tensors first — evicting
//!   them is free; when only needed data remains, the model writes it
//!   back to DRAM (counted, because the sizing loop must eliminate it).

use std::collections::BTreeSet;

use anyhow::{bail, Result};

use crate::config::MemConfig;
use crate::trace::{AccessStats, OccupancyTrace};
use crate::workload::TensorId;

use super::port::PortTimer;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Needed,
    Obsolete,
}

#[derive(Debug, Clone, Copy)]
struct Entry {
    bytes: u64,
    state: State,
    /// LRU stamp (logical use counter, not cycles: ties are impossible).
    stamp: u64,
    kind: &'static str,
}

/// Result of making room for an allocation.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AllocOutcome {
    /// Needed tensors written back to DRAM to make room (capacity
    /// violation — Stage-I sizing must drive this to zero).
    pub writebacks: Vec<(TensorId, u64)>,
    /// Obsolete tensors dropped (free).
    pub dropped: Vec<TensorId>,
}

#[derive(Debug, Clone)]
pub struct SramModel {
    pub cfg: MemConfig,
    /// Dense residency map indexed by TensorId (ids are dense u32s from
    /// the graph builder); ~5x faster than a HashMap in the event loop
    /// (EXPERIMENTS.md §Perf L3-1).
    entries: Vec<Option<Entry>>,
    /// LRU index: (stamp, id) per state. BTreeSet gives O(log n) oldest.
    lru_needed: BTreeSet<(u64, TensorId)>,
    lru_obsolete: BTreeSet<(u64, TensorId)>,
    needed_bytes: u64,
    obsolete_bytes: u64,
    stamp: u64,
    pub trace: OccupancyTrace,
    pub stats: AccessStats,
    pub ports: PortTimer,
    /// Needed-bytes-by-kind snapshot at the moment of peak needed bytes
    /// (diagnostics for calibration and the Fig. 5 decomposition).
    pub peak_composition: Vec<(&'static str, u64)>,
    peak_needed_seen: u64,
    /// When false, occupancy changes are not materialized into `trace`
    /// (streaming-only runs — consumers observe them via `TraceSink`).
    record_samples: bool,
}

impl SramModel {
    pub fn new(cfg: &MemConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            entries: Vec::new(),
            lru_needed: BTreeSet::new(),
            lru_obsolete: BTreeSet::new(),
            needed_bytes: 0,
            obsolete_bytes: 0,
            stamp: 0,
            trace: OccupancyTrace::new(&cfg.name, cfg.capacity),
            stats: AccessStats::default(),
            ports: PortTimer::new(cfg),
            peak_composition: Vec::new(),
            peak_needed_seen: 0,
            record_samples: true,
        }
    }

    /// Disable (or re-enable) trace materialization. Meant to be set
    /// before simulation starts; peak diagnostics stay live either way.
    pub fn set_sample_recording(&mut self, enabled: bool) {
        self.record_samples = enabled;
    }

    pub fn contains(&self, t: TensorId) -> bool {
        self.entries
            .get(t.0 as usize)
            .is_some_and(Option::is_some)
    }

    #[inline]
    fn slot(&mut self, t: TensorId) -> &mut Option<Entry> {
        let idx = t.0 as usize;
        if idx >= self.entries.len() {
            self.entries.resize(idx + 1, None);
        }
        &mut self.entries[idx]
    }

    pub fn needed_bytes(&self) -> u64 {
        self.needed_bytes
    }

    pub fn obsolete_bytes(&self) -> u64 {
        self.obsolete_bytes
    }

    pub fn occupied(&self) -> u64 {
        self.needed_bytes + self.obsolete_bytes
    }

    fn record(&mut self, now: u64) {
        if self.record_samples {
            self.trace.record(now, self.needed_bytes, self.obsolete_bytes);
        }
        if self.needed_bytes > self.peak_needed_seen {
            self.peak_needed_seen = self.needed_bytes;
            let mut by_kind: std::collections::BTreeMap<&'static str, u64> =
                Default::default();
            for e in self.entries.iter().flatten() {
                if e.state == State::Needed {
                    *by_kind.entry(e.kind).or_default() += e.bytes;
                }
            }
            self.peak_composition = by_kind.into_iter().collect();
        }
    }

    fn bump(&mut self) -> u64 {
        self.stamp += 1;
        self.stamp
    }

    /// Allocate `bytes` for tensor `t` (not currently resident), evicting
    /// as required. Returns what had to be evicted; the caller charges
    /// write-back traffic/time to DRAM.
    pub fn allocate(
        &mut self,
        now: u64,
        t: TensorId,
        bytes: u64,
        kind: &'static str,
    ) -> Result<AllocOutcome> {
        if self.contains(t) {
            bail!("tensor {t} already resident in {}", self.cfg.name);
        }
        if bytes > self.cfg.capacity {
            bail!(
                "tensor {t} ({bytes} B) exceeds {} capacity {}",
                self.cfg.name,
                self.cfg.capacity
            );
        }
        let mut out = AllocOutcome::default();
        while self.occupied() + bytes > self.cfg.capacity {
            // LRU among obsolete first (free), then needed (write-back).
            if let Some(&(stamp, victim)) = self.lru_obsolete.iter().next() {
                let e = self.slot(victim).take().expect("indexed");
                self.lru_obsolete.remove(&(stamp, victim));
                self.obsolete_bytes -= e.bytes;
                self.stats.evictions_obsolete += 1;
                out.dropped.push(victim);
            } else if let Some(&(stamp, victim)) = self.lru_needed.iter().next() {
                let e = self.slot(victim).take().expect("indexed");
                self.lru_needed.remove(&(stamp, victim));
                self.needed_bytes -= e.bytes;
                self.stats.writeback(e.bytes);
                out.writebacks.push((victim, e.bytes));
            } else {
                bail!("cannot fit tensor {t}: memory empty but too small");
            }
        }
        let stamp = self.bump();
        *self.slot(t) = Some(Entry {
            bytes,
            state: State::Needed,
            stamp,
            kind,
        });
        self.lru_needed.insert((stamp, t));
        self.needed_bytes += bytes;
        self.record(now);
        Ok(out)
    }

    /// Refresh LRU recency on access.
    pub fn touch(&mut self, t: TensorId) {
        let stamp = self.bump();
        if let Some(e) = self
            .entries
            .get_mut(t.0 as usize)
            .and_then(Option::as_mut)
        {
            let old = (e.stamp, t);
            e.stamp = stamp;
            match e.state {
                State::Needed => {
                    self.lru_needed.remove(&old);
                    self.lru_needed.insert((stamp, t));
                }
                State::Obsolete => {
                    self.lru_obsolete.remove(&old);
                    self.lru_obsolete.insert((stamp, t));
                }
            }
        }
    }

    /// Transition a tensor to obsolete (last consumer finished). No-op if
    /// not resident (it may have been written back).
    pub fn mark_obsolete(&mut self, now: u64, t: TensorId) {
        if let Some(e) = self
            .entries
            .get_mut(t.0 as usize)
            .and_then(Option::as_mut)
        {
            if e.state == State::Needed {
                e.state = State::Obsolete;
                self.lru_needed.remove(&(e.stamp, t));
                self.lru_obsolete.insert((e.stamp, t));
                let bytes = e.bytes;
                self.needed_bytes -= bytes;
                self.obsolete_bytes += bytes;
                self.record(now);
            }
        }
    }

    /// Transition back to needed (a written-back tensor refetched, or an
    /// obsolete one that gains a new consumer in decode loops).
    pub fn mark_needed(&mut self, now: u64, t: TensorId) {
        if let Some(e) = self
            .entries
            .get_mut(t.0 as usize)
            .and_then(Option::as_mut)
        {
            if e.state == State::Obsolete {
                e.state = State::Needed;
                self.lru_obsolete.remove(&(e.stamp, t));
                self.lru_needed.insert((e.stamp, t));
                let bytes = e.bytes;
                self.obsolete_bytes -= bytes;
                self.needed_bytes += bytes;
                self.record(now);
            }
        }
    }

    /// Kind label of a resident tensor (traffic attribution).
    pub fn kind_of(&self, t: TensorId) -> Option<&'static str> {
        self.entries
            .get(t.0 as usize)
            .and_then(Option::as_ref)
            .map(|e| e.kind)
    }

    /// Close the trace at the end of the run.
    pub fn finalize(&mut self, end: u64) {
        self.trace.finalize(end);
    }

    /// Internal-consistency check used by tests and debug assertions.
    pub fn check_invariants(&self) -> Result<()> {
        use anyhow::ensure;
        let needed: u64 = self
            .entries
            .iter()
            .flatten()
            .filter(|e| e.state == State::Needed)
            .map(|e| e.bytes)
            .sum();
        let obsolete: u64 = self
            .entries
            .iter()
            .flatten()
            .filter(|e| e.state == State::Obsolete)
            .map(|e| e.bytes)
            .sum();
        ensure!(needed == self.needed_bytes, "needed counter drift");
        ensure!(obsolete == self.obsolete_bytes, "obsolete counter drift");
        ensure!(
            self.lru_needed.len() + self.lru_obsolete.len()
                == self.entries.iter().flatten().count(),
            "LRU index size mismatch"
        );
        ensure!(
            self.occupied() <= self.cfg.capacity,
            "over capacity: {} > {}",
            self.occupied(),
            self.cfg.capacity
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn mk(capacity: u64) -> SramModel {
        SramModel::new(&MemConfig {
            name: "sram".into(),
            capacity,
            ports: 2,
            bytes_per_cycle: 64,
            latency_cycles: 4,
        })
    }

    fn tid(i: u32) -> TensorId {
        TensorId(i)
    }

    #[test]
    fn allocate_tracks_needed() {
        let mut m = mk(1000);
        m.allocate(5, tid(0), 400, "act").unwrap();
        assert_eq!(m.needed_bytes(), 400);
        assert_eq!(m.obsolete_bytes(), 0);
        assert!(m.contains(tid(0)));
        m.check_invariants().unwrap();
    }

    #[test]
    fn obsolete_preferred_over_needed() {
        let mut m = mk(1000);
        m.allocate(0, tid(0), 400, "act").unwrap(); // older
        m.allocate(1, tid(1), 400, "act").unwrap();
        m.mark_obsolete(2, tid(1)); // newer but obsolete
        let out = m.allocate(3, tid(2), 300, "act").unwrap();
        // Must drop the obsolete tid(1) even though tid(0) is older LRU.
        assert_eq!(out.dropped, vec![tid(1)]);
        assert!(out.writebacks.is_empty());
        assert!(m.contains(tid(0)));
        assert_eq!(m.stats.evictions_obsolete, 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn needed_writeback_when_no_obsolete() {
        let mut m = mk(1000);
        m.allocate(0, tid(0), 600, "kv").unwrap();
        m.allocate(1, tid(1), 300, "act").unwrap();
        let out = m.allocate(2, tid(2), 500, "act").unwrap();
        // LRU needed victim is tid(0).
        assert_eq!(out.writebacks, vec![(tid(0), 600)]);
        assert!(!m.stats.capacity_feasible());
        assert_eq!(m.stats.writeback_bytes, 600);
        m.check_invariants().unwrap();
    }

    #[test]
    fn touch_updates_lru_order() {
        let mut m = mk(1000);
        m.allocate(0, tid(0), 400, "act").unwrap();
        m.allocate(1, tid(1), 400, "act").unwrap();
        m.touch(tid(0)); // tid(1) becomes LRU victim
        let out = m.allocate(2, tid(2), 400, "act").unwrap();
        assert_eq!(out.writebacks, vec![(tid(1), 400)]);
    }

    #[test]
    fn oversized_tensor_rejected() {
        let mut m = mk(100);
        assert!(m.allocate(0, tid(0), 200, "act").is_err());
    }

    #[test]
    fn double_allocate_rejected() {
        let mut m = mk(1000);
        m.allocate(0, tid(0), 100, "act").unwrap();
        assert!(m.allocate(1, tid(0), 100, "act").is_err());
    }

    #[test]
    fn trace_records_transitions() {
        let mut m = mk(1000);
        m.allocate(5, tid(0), 300, "act").unwrap();
        m.mark_obsolete(9, tid(0));
        m.finalize(12);
        let segs: Vec<_> = m.trace.segments().collect();
        assert_eq!(segs.len(), 3);
        assert_eq!((segs[1].needed, segs[1].obsolete), (300, 0));
        assert_eq!((segs[2].needed, segs[2].obsolete), (0, 300));
        assert_eq!(m.trace.peak_needed(), 300);
    }

    #[test]
    fn mark_needed_round_trip() {
        let mut m = mk(1000);
        m.allocate(0, tid(0), 100, "kv").unwrap();
        m.mark_obsolete(1, tid(0));
        m.mark_needed(2, tid(0));
        assert_eq!(m.needed_bytes(), 100);
        assert_eq!(m.obsolete_bytes(), 0);
        m.check_invariants().unwrap();
    }

    #[test]
    fn prop_invariants_under_random_ops() {
        check("sram-invariants", 60, |rng| {
            let cap = rng.range(1_000, 100_000);
            let mut m = mk(cap);
            let mut live: Vec<TensorId> = Vec::new();
            let mut next_id = 0u32;
            let mut now = 0u64;
            for _ in 0..rng.range(10, 300) {
                now += rng.below(20);
                match rng.below(4) {
                    0 | 1 => {
                        let bytes = rng.range(1, cap / 4 + 1);
                        let t = TensorId(next_id);
                        next_id += 1;
                        let out = m.allocate(now, t, bytes, "act").unwrap();
                        for (wb, _) in &out.writebacks {
                            live.retain(|x| x != wb);
                        }
                        for d in &out.dropped {
                            live.retain(|x| x != d);
                        }
                        live.push(t);
                    }
                    2 => {
                        if let Some(&t) = live.first() {
                            m.mark_obsolete(now, t);
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let idx = rng.below(live.len() as u64) as usize;
                            m.touch(live[idx]);
                        }
                    }
                }
                m.check_invariants().unwrap();
            }
            m.finalize(now + 1);
            m.trace.validate().unwrap();
        });
    }
}
