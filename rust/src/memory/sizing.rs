//! Stage-I memory sizing loop (the blue loop in the paper's Fig. 3):
//! iteratively adjust SRAM capacity and re-simulate until execution is
//! feasible without capacity-induced write-backs, then report the peak
//! requirement rounded to the exploration step (16 MiB in §IV-B).

use anyhow::Result;

use crate::config::AccelConfig;
use crate::sim::{simulate, SimResult};

use crate::workload::WorkloadGraph;

#[derive(Debug, Clone)]
pub struct SizingResult {
    /// Peak needed bytes observed at the reference capacity.
    pub peak_needed: u64,
    /// Peak rounded up to `step` (the paper's "peak required capacity",
    /// e.g. 112 MiB for GPT-2 XL, 48 MiB for DS-R1D).
    pub required_capacity: u64,
    /// The verification run at `required_capacity`.
    pub verify: SimResult,
    /// Capacities tried (reference + verification + any bumps).
    pub iterations: Vec<u64>,
}

/// Latency model supplied by the caller (CACTI-derived in production;
/// tests pass a constant).
pub type LatencyFn<'a> = &'a dyn Fn(u64) -> u64;

/// Run the sizing loop for `graph` on `base` (whose shared-SRAM capacity
/// acts as the reference "large enough" starting point).
pub fn size_memory(
    graph: &WorkloadGraph,
    base: &AccelConfig,
    step: u64,
    latency_of: LatencyFn,
) -> Result<SizingResult> {
    let mut iterations = vec![base.shared_sram().capacity];
    let reference = simulate(graph, base)?;
    let peak = reference.peak_needed();
    let mut candidate = peak.div_ceil(step) * step;
    if candidate == 0 {
        candidate = step;
    }

    loop {
        iterations.push(candidate);
        let cfg = base.with_sram_capacity(candidate, latency_of(candidate));
        let result = simulate(graph, &cfg)?;
        if result.feasible() {
            return Ok(SizingResult {
                peak_needed: peak,
                required_capacity: candidate,
                verify: result,
                iterations,
            });
        }
        candidate += step;
        if candidate > base.dram.capacity {
            anyhow::bail!("sizing loop exceeded DRAM capacity — graph too large");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::tiny;
    use crate::workload::{build_prefill, TINY_GQA, TINY_MHA};

    #[test]
    fn sizing_converges_for_tiny_models() {
        let g = build_prefill(&TINY_GQA, 64).unwrap();
        let base = tiny();
        let r = size_memory(&g, &base, 256 * 1024, &|_| 8).unwrap();
        assert!(r.required_capacity >= r.peak_needed);
        assert!(r.required_capacity % (256 * 1024) == 0);
        assert!(r.verify.feasible());
        // The verification run at the reduced size must report the same
        // or nearly the same peak (schedule unchanged when feasible).
        assert!(r.verify.peak_needed() <= r.required_capacity);
    }

    #[test]
    fn mha_requires_more_than_gqa() {
        // The structural heart of the paper: all else equal (same FFN,
        // same head count), MHA's KV footprint demands at least as much
        // SRAM as the GQA variant of the same model.
        let seq = 64;
        let base = tiny();
        let mut gqa_variant = TINY_MHA.clone();
        gqa_variant.kv_heads = 2;
        let mha = size_memory(
            &build_prefill(&TINY_MHA, seq).unwrap(),
            &base,
            64 * 1024,
            &|_| 8,
        )
        .unwrap();
        let gqa = size_memory(
            &build_prefill(&gqa_variant, seq).unwrap(),
            &base,
            64 * 1024,
            &|_| 8,
        )
        .unwrap();
        assert!(
            mha.peak_needed >= gqa.peak_needed,
            "MHA peak {} < GQA peak {}",
            mha.peak_needed,
            gqa.peak_needed
        );
    }

    #[test]
    fn paper_step_is_16_mib() {
        use crate::util::MIB;
        // Guard the constant used by the §IV-B experiments.
        assert_eq!(16 * MIB, 16 * 1024 * 1024);
    }
}
