//! On-chip/off-chip memory models: SRAM residency with needed/obsolete
//! tracking and LRU eviction, port-level transfer timing, multi-level
//! hierarchies, and the Stage-I capacity sizing loop.

pub mod hierarchy;
pub mod port;
pub mod sizing;
pub mod sram;

pub use hierarchy::{FetchOutcome, MemorySystem};
pub use port::{PortTimer, Transfer};
pub use sizing::{size_memory, SizingResult};
pub use sram::{AllocOutcome, SramModel};
