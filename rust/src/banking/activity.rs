//! Mapping occupancy traces to bank activity (paper Eq. 1).
//!
//! `B_act(t) = clamp(ceil(o(t) / (alpha * C / B)), 0, B)` — occupied data
//! is assumed packed contiguously across banks; the headroom factor
//! `alpha` reserves per-bank slack for non-ideal placement (0.9 in the
//! paper's conservative setting, 1.0 aggressive).

use crate::trace::OccupancyTrace;
use crate::util::ceil_div;

/// Piecewise-constant bank-activity timeline segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ActivitySegment {
    pub t0: u64,
    pub t1: u64,
    /// Banks that must remain active during this segment.
    pub active: u32,
}

impl ActivitySegment {
    pub fn dt(&self) -> u64 {
        self.t1 - self.t0
    }
}

/// Eq. 1 for a single occupancy value.
pub fn banks_required(occupied: u64, capacity: u64, banks: u32, alpha: f64) -> u32 {
    assert!(alpha > 0.0 && alpha <= 1.0, "alpha={alpha}");
    assert!(banks >= 1);
    if occupied == 0 {
        return 0;
    }
    let usable_per_bank = (alpha * (capacity as f64 / banks as f64)).floor() as u64;
    if usable_per_bank == 0 {
        return banks;
    }
    ceil_div(occupied, usable_per_bank).min(banks as u64) as u32
}

/// What counts as "occupied" for Eq. 1.
///
/// The paper gates banks that hold no *needed* data; obsolete bytes are
/// evictable for free, so they do not pin banks on (dropping them is part
/// of entering the gated state). `NeededOnly` is therefore the paper's
/// semantics; `NeededPlusObsolete` is provided for the ablation bench.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OccupancyBasis {
    NeededOnly,
    NeededPlusObsolete,
}

/// Translate an occupancy trace into the bank-activity timeline for a
/// (C, B, alpha) candidate. Adjacent equal-activity segments coalesce.
pub fn bank_activity(
    trace: &OccupancyTrace,
    capacity: u64,
    banks: u32,
    alpha: f64,
    basis: OccupancyBasis,
) -> Vec<ActivitySegment> {
    let mut out: Vec<ActivitySegment> = Vec::new();
    for seg in trace.segments() {
        let occ = match basis {
            OccupancyBasis::NeededOnly => seg.needed,
            OccupancyBasis::NeededPlusObsolete => seg.occupied(),
        };
        let active = banks_required(occ, capacity, banks, alpha);
        match out.last_mut() {
            Some(last) if last.active == active && last.t1 == seg.t0 => {
                last.t1 = seg.t1;
            }
            _ => out.push(ActivitySegment {
                t0: seg.t0,
                t1: seg.t1,
                active,
            }),
        }
    }
    out
}

/// Time-weighted average active banks.
pub fn avg_active(segments: &[ActivitySegment]) -> f64 {
    let total: u64 = segments.iter().map(|s| s.dt()).sum();
    if total == 0 {
        return 0.0;
    }
    let weighted: u128 = segments
        .iter()
        .map(|s| s.active as u128 * s.dt() as u128)
        .sum();
    weighted as f64 / total as f64
}

/// Idle intervals of one bank index `b` (0-based): maximal intervals
/// where `active <= b` (banks pack low-to-high, so bank b is unused
/// whenever fewer than b+1 banks are required).
pub fn idle_intervals(segments: &[ActivitySegment], bank: u32) -> Vec<(u64, u64)> {
    let mut out: Vec<(u64, u64)> = Vec::new();
    for s in segments {
        if s.active <= bank {
            match out.last_mut() {
                Some(last) if last.1 == s.t0 => last.1 = s.t1,
                _ => out.push((s.t0, s.t1)),
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn trace(events: &[(u64, u64)], end: u64, cap: u64) -> OccupancyTrace {
        let mut tr = OccupancyTrace::new("sram", cap);
        for &(t, needed) in events {
            tr.record(t, needed, 0);
        }
        tr.finalize(end);
        tr
    }

    #[test]
    fn eq1_basic() {
        // C=100, B=4 => 25/bank; alpha=1.0.
        assert_eq!(banks_required(0, 100, 4, 1.0), 0);
        assert_eq!(banks_required(1, 100, 4, 1.0), 1);
        assert_eq!(banks_required(25, 100, 4, 1.0), 1);
        assert_eq!(banks_required(26, 100, 4, 1.0), 2);
        assert_eq!(banks_required(100, 100, 4, 1.0), 4);
        // Over-capacity clamps to B.
        assert_eq!(banks_required(1000, 100, 4, 1.0), 4);
    }

    #[test]
    fn eq1_alpha_conservative() {
        // alpha=0.9: usable 22/bank -> 23 bytes now needs 2 banks.
        assert_eq!(banks_required(22, 100, 4, 0.9), 1);
        assert_eq!(banks_required(23, 100, 4, 0.9), 2);
        // Smaller alpha never decreases the requirement (Fig. 8).
        for occ in [1u64, 10, 25, 50, 75, 100] {
            assert!(
                banks_required(occ, 100, 4, 0.9) >= banks_required(occ, 100, 4, 1.0),
                "occ={occ}"
            );
        }
    }

    #[test]
    fn activity_timeline_coalesces() {
        let tr = trace(&[(10, 30), (20, 26), (30, 80)], 40, 100);
        // B=4, alpha=1.0: 0..10 -> 0, 10..20 -> ceil(30/25)=2,
        // 20..30 -> ceil(26/25)=2 (coalesce), 30..40 -> 4.
        let act = bank_activity(&tr, 100, 4, 1.0, OccupancyBasis::NeededOnly);
        assert_eq!(
            act,
            vec![
                ActivitySegment { t0: 0, t1: 10, active: 0 },
                ActivitySegment { t0: 10, t1: 30, active: 2 },
                ActivitySegment { t0: 30, t1: 40, active: 4 },
            ]
        );
        assert!((avg_active(&act) - (20.0 * 2.0 + 10.0 * 4.0) / 40.0).abs() < 1e-12);
    }

    #[test]
    fn idle_intervals_per_bank() {
        let segs = vec![
            ActivitySegment { t0: 0, t1: 10, active: 0 },
            ActivitySegment { t0: 10, t1: 30, active: 2 },
            ActivitySegment { t0: 30, t1: 40, active: 4 },
            ActivitySegment { t0: 40, t1: 60, active: 1 },
        ];
        // Bank 0 idle only when active == 0.
        assert_eq!(idle_intervals(&segs, 0), vec![(0, 10)]);
        // Bank 2 idle when active <= 2: 0..30 (merged) and 40..60.
        assert_eq!(idle_intervals(&segs, 2), vec![(0, 30), (40, 60)]);
        // Bank 3 idle everywhere except 30..40.
        assert_eq!(idle_intervals(&segs, 3), vec![(0, 30), (40, 60)]);
    }

    #[test]
    fn obsolete_basis_needs_more_banks() {
        let mut tr = OccupancyTrace::new("sram", 100);
        tr.record(5, 20, 60);
        tr.finalize(10);
        let needed = bank_activity(&tr, 100, 4, 1.0, OccupancyBasis::NeededOnly);
        let both = bank_activity(&tr, 100, 4, 1.0, OccupancyBasis::NeededPlusObsolete);
        assert_eq!(needed.last().unwrap().active, 1);
        assert_eq!(both.last().unwrap().active, 4);
    }

    #[test]
    fn prop_activity_bounded_and_monotone_in_alpha() {
        check("eq1-bounds", 200, |rng| {
            let cap = rng.range(1, 1 << 30);
            let banks = 1u32 << rng.below(6);
            let occ = rng.below(cap * 2);
            let a_hi = 0.5 + rng.f64() * 0.5;
            let a_lo = a_hi * (0.5 + rng.f64() * 0.5);
            let hi = banks_required(occ, cap, banks, a_hi);
            let lo = banks_required(occ, cap, banks, a_lo);
            assert!(hi <= banks && lo <= banks);
            assert!(lo >= hi, "lower alpha must not reduce active banks");
            if occ == 0 {
                assert_eq!(hi, 0);
            } else {
                assert!(hi >= 1);
            }
        });
    }
}
