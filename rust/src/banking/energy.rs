//! Stage-II energy evaluation of one banking + gating candidate
//! (paper Eqs. 2-5).
//!
//! `E_tot = E_dyn + E_leak + E_sw` with
//!   * `E_dyn  = N_R * E_R + N_W * E_W`           (Stage-I access counts)
//!   * `E_leak = sum_k P_bank * B_act(k) * dt_k`  (+ ungated idle leak)
//!   * `E_sw   = N_sw * E_sw_bank`                (break-even-filtered)

use std::fmt;

use crate::cacti::{CactiModel, SramCharacterization};
use crate::trace::{AccessStats, OccupancyTrace};

use super::activity::{avg_active, bank_activity, idle_intervals, OccupancyBasis};
use super::policy::GatingPolicy;

/// Typed Stage-II evaluation error.
///
/// The evaluator used to `expect` a finalized trace and panic on library
/// misuse; it now reports the condition as data so callers (the CLI, the
/// batch runner, the optimizer) can surface it instead of aborting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EnergyError {
    /// The occupancy trace was never [`OccupancyTrace::finalize`]d, so
    /// there is no end time to integrate leakage over.
    UnfinalizedTrace { memory: String },
}

impl fmt::Display for EnergyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EnergyError::UnfinalizedTrace { memory } => write!(
                f,
                "occupancy trace `{memory}` is not finalized; call \
                 OccupancyTrace::finalize(end) before Stage-II evaluation"
            ),
        }
    }
}

impl std::error::Error for EnergyError {}

/// Full evaluation of one (C, B, alpha, policy) candidate.
#[derive(Debug, Clone)]
pub struct BankingEval {
    pub capacity: u64,
    pub banks: u32,
    pub alpha: f64,
    pub policy: GatingPolicy,
    /// Eq. 3 dynamic access energy, joules.
    pub e_dyn_j: f64,
    /// Eq. 4 leakage energy, joules (active + ungated idle).
    pub e_leak_j: f64,
    /// Eq. 5 switching overhead, joules.
    pub e_sw_j: f64,
    /// On<->off transitions actually taken.
    pub n_switch: u64,
    /// Time-weighted average active banks.
    pub avg_active_banks: f64,
    /// Fraction of total bank-time gated off.
    pub gated_fraction: f64,
    pub area_mm2: f64,
    pub latency_cycles: u64,
    pub characterization: SramCharacterization,
}

impl BankingEval {
    /// Eq. 2.
    pub fn e_total_j(&self) -> f64 {
        self.e_dyn_j + self.e_leak_j + self.e_sw_j
    }

    /// Paper's ΔE% relative to a baseline evaluation. A zero-energy
    /// baseline (zero-length trace with zero access counts) reports 0%
    /// ("no change") instead of NaN/inf — same guard as
    /// [`super::sweep::SweepPoint::delta_e_pct`].
    pub fn delta_pct(&self, base: &BankingEval) -> f64 {
        let b = base.e_total_j();
        if b == 0.0 {
            0.0
        } else {
            (self.e_total_j() - b) / b * 100.0
        }
    }
}

/// Evaluate one candidate against a Stage-I trace + access statistics.
///
/// `freq_ghz` converts trace cycles to seconds for leakage integration.
///
/// This is the single-candidate oracle: it materializes the activity
/// timeline and per-bank idle intervals. Grid sweeps go through the
/// fused single-pass engine instead ([`crate::banking::sweep`](fn@crate::banking::sweep) /
/// [`crate::banking::fused`]), whose accumulators replicate these exact
/// expressions — keep the two in sync.
///
/// Errors with [`EnergyError::UnfinalizedTrace`] when the trace has no
/// end time. Zero-length (`finalize(0)`) traces evaluate cleanly to
/// all-zero energies.
pub fn evaluate(
    cacti: &CactiModel,
    trace: &OccupancyTrace,
    stats: &AccessStats,
    capacity: u64,
    banks: u32,
    alpha: f64,
    policy: GatingPolicy,
    freq_ghz: f64,
) -> Result<BankingEval, EnergyError> {
    let ch = cacti.characterize(capacity, banks);
    let cyc_to_s = 1.0 / (freq_ghz * 1e9);
    let Some(end) = trace.end_time() else {
        return Err(EnergyError::UnfinalizedTrace {
            memory: trace.memory.clone(),
        });
    };
    let end = end as f64;

    // Eq. 3 — dynamic energy from Stage-I access counts.
    let e_dyn = stats.reads as f64 * ch.e_read_j + stats.writes as f64 * ch.e_write_j;

    // Bank-activity timeline (Eq. 1).
    let activity = bank_activity(trace, capacity, banks, alpha, OccupancyBasis::NeededOnly);
    let avg = avg_active(&activity);

    // Eq. 4 + Eq. 5 — walk each bank's idle intervals; leak while active
    // or while idle-but-not-gated; pay 2 transitions per gated interval.
    let mut gated_cycles: u128 = 0;
    let mut n_switch = 0u64;
    for bank in 0..banks {
        for (t0, t1) in idle_intervals(&activity, bank) {
            let dt = t1 - t0;
            if policy.should_gate(dt, &ch, freq_ghz) {
                gated_cycles += dt as u128;
                n_switch += 2;
            }
        }
    }
    let total_bank_cycles = end * banks as f64;
    // Acted-on idle time retains `idle_leak_factor` of nominal leakage
    // (0 for true power gating, retention_factor for drowsy mode).
    let retained = policy.idle_leak_factor();
    let leak_cycles =
        total_bank_cycles - gated_cycles as f64 * (1.0 - retained);
    let e_leak = ch.p_leak_bank_w * leak_cycles * cyc_to_s;
    // Drowsy transitions cost ~1% of a full sleep transition (no
    // power-rail collapse, just a voltage step).
    let per_switch = match policy {
        GatingPolicy::Drowsy { .. } => ch.e_switch_j * 0.01,
        _ => ch.e_switch_j,
    };
    let e_sw = n_switch as f64 * per_switch;

    Ok(BankingEval {
        capacity,
        banks,
        alpha,
        policy,
        e_dyn_j: e_dyn,
        e_leak_j: e_leak,
        e_sw_j: e_sw,
        n_switch,
        avg_active_banks: avg,
        // Guard the utilization division: a zero-length trace (end == 0)
        // has zero total bank-cycles and would otherwise yield NaN.
        gated_fraction: if total_bank_cycles > 0.0 {
            gated_cycles as f64 / total_bank_cycles
        } else {
            0.0
        },
        area_mm2: ch.area_mm2,
        latency_cycles: ch.latency_cycles,
        characterization: ch,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    /// A DS-like synthetic trace: low occupancy with periodic release.
    fn synth_trace(cap: u64, occ: u64, period: u64, cycles: u64) -> OccupancyTrace {
        let mut tr = OccupancyTrace::new("sram", cap);
        let mut t = 0;
        while t < cycles {
            tr.record(t, occ, 0);
            tr.record(t + period / 2, occ / 4, 0);
            t += period;
        }
        tr.finalize(cycles);
        tr
    }

    fn stats(reads: u64, writes: u64) -> AccessStats {
        AccessStats {
            reads,
            writes,
            ..Default::default()
        }
    }

    #[test]
    fn unbanked_ungated_is_pure_leak_plus_dyn() {
        let cacti = CactiModel::default();
        let tr = synth_trace(128 * MIB, 30 * MIB, 1_000_000, 100_000_000);
        let st = stats(1_000_000, 500_000);
        let ev = evaluate(&cacti, &tr, &st, 128 * MIB, 1, 0.9, GatingPolicy::None, 1.0).unwrap();
        let ch = cacti.characterize(128 * MIB, 1);
        let want_leak = ch.p_leak_bank_w * 0.1; // 100M cycles = 0.1 s
        assert!((ev.e_leak_j - want_leak).abs() / want_leak < 1e-9);
        assert_eq!(ev.n_switch, 0);
        assert_eq!(ev.e_sw_j, 0.0);
        assert!(ev.e_dyn_j > 0.0);
    }

    #[test]
    fn banking_plus_gating_reduces_energy() {
        // The paper's core Table II claim.
        let cacti = CactiModel::default();
        let tr = synth_trace(128 * MIB, 30 * MIB, 1_000_000, 100_000_000);
        let st = stats(10_000_000, 5_000_000);
        let base = evaluate(&cacti, &tr, &st, 128 * MIB, 1, 0.9, GatingPolicy::None, 1.0).unwrap();
        let b8 = evaluate(
            &cacti, &tr, &st, 128 * MIB, 8, 0.9,
            GatingPolicy::Aggressive, 1.0,
        ).unwrap();
        assert!(
            b8.e_total_j() < base.e_total_j() * 0.7,
            "B=8 gated {} vs B=1 {}",
            b8.e_total_j(),
            base.e_total_j()
        );
        assert!(b8.gated_fraction > 0.3);
        assert!(b8.n_switch > 0);
    }

    #[test]
    fn gating_never_worse_than_none_at_same_banking() {
        // Break-even filtering guarantees gating only helps.
        let cacti = CactiModel::default();
        let tr = synth_trace(64 * MIB, 20 * MIB, 500_000, 50_000_000);
        let st = stats(1_000_000, 1_000_000);
        for &b in &[2u32, 4, 8, 16] {
            let none = evaluate(&cacti, &tr, &st, 64 * MIB, b, 0.9, GatingPolicy::None, 1.0).unwrap();
            let agg = evaluate(
                &cacti, &tr, &st, 64 * MIB, b, 0.9,
                GatingPolicy::Aggressive, 1.0,
            ).unwrap();
            assert!(
                agg.e_total_j() <= none.e_total_j() + 1e-12,
                "B={b}: gating made it worse"
            );
        }
    }

    #[test]
    fn conservative_gates_less_than_aggressive() {
        let cacti = CactiModel::default();
        let tr = synth_trace(64 * MIB, 20 * MIB, 200_000, 50_000_000);
        let st = stats(1_000_000, 1_000_000);
        let agg = evaluate(
            &cacti, &tr, &st, 64 * MIB, 8, 1.0,
            GatingPolicy::Aggressive, 1.0,
        ).unwrap();
        let cons = evaluate(
            &cacti, &tr, &st, 64 * MIB, 8, 0.9,
            GatingPolicy::conservative(), 1.0,
        ).unwrap();
        assert!(cons.gated_fraction <= agg.gated_fraction);
        assert!(cons.n_switch <= agg.n_switch);
    }

    #[test]
    fn lower_alpha_more_active_banks() {
        // Fig. 8's message.
        let cacti = CactiModel::default();
        let tr = synth_trace(64 * MIB, 30 * MIB, 500_000, 50_000_000);
        let st = stats(1, 1);
        let a10 = evaluate(&cacti, &tr, &st, 64 * MIB, 4, 1.0, GatingPolicy::Aggressive, 1.0).unwrap();
        let a05 = evaluate(&cacti, &tr, &st, 64 * MIB, 4, 0.5, GatingPolicy::Aggressive, 1.0).unwrap();
        assert!(a05.avg_active_banks >= a10.avg_active_banks);
        assert!(a05.e_leak_j >= a10.e_leak_j);
    }

    #[test]
    fn drowsy_sits_between_none_and_full_gating() {
        let cacti = CactiModel::default();
        let tr = synth_trace(64 * MIB, 20 * MIB, 200_000, 50_000_000);
        let st = stats(1_000_000, 1_000_000);
        let none = evaluate(&cacti, &tr, &st, 64 * MIB, 8, 0.9, GatingPolicy::None, 1.0).unwrap();
        let drowsy = evaluate(
            &cacti, &tr, &st, 64 * MIB, 8, 0.9,
            GatingPolicy::drowsy(), 1.0,
        ).unwrap();
        let full = evaluate(
            &cacti, &tr, &st, 64 * MIB, 8, 0.9,
            GatingPolicy::Aggressive, 1.0,
        ).unwrap();
        assert!(drowsy.e_leak_j < none.e_leak_j);
        assert!(drowsy.e_leak_j > full.e_leak_j);
        // Drowsy acts on more intervals (no break-even filter).
        assert!(drowsy.n_switch >= full.n_switch);
    }

    #[test]
    fn delta_pct_matches_definition() {
        let cacti = CactiModel::default();
        let tr = synth_trace(64 * MIB, 10 * MIB, 500_000, 50_000_000);
        let st = stats(100, 100);
        let a = evaluate(&cacti, &tr, &st, 64 * MIB, 1, 0.9, GatingPolicy::None, 1.0).unwrap();
        let b = evaluate(&cacti, &tr, &st, 64 * MIB, 8, 0.9, GatingPolicy::Aggressive, 1.0).unwrap();
        let d = b.delta_pct(&a);
        assert!((d - (b.e_total_j() - a.e_total_j()) / a.e_total_j() * 100.0).abs() < 1e-12);
        assert!(d < 0.0, "banking+gating should be negative ΔE");
    }

    #[test]
    fn unfinalized_trace_is_a_typed_error_not_a_panic() {
        // Regression: evaluate used to `expect("trace must be finalized")`.
        let cacti = CactiModel::default();
        let tr = OccupancyTrace::new("dm1", 64 * MIB); // never finalized
        let err = evaluate(
            &cacti,
            &tr,
            &stats(1, 1),
            64 * MIB,
            4,
            0.9,
            GatingPolicy::Aggressive,
            1.0,
        )
        .unwrap_err();
        assert_eq!(
            err,
            EnergyError::UnfinalizedTrace {
                memory: "dm1".to_string()
            }
        );
        assert!(err.to_string().contains("dm1"), "{err}");
        assert!(err.to_string().contains("finalize"), "{err}");
    }

    #[test]
    fn zero_length_trace_evaluates_to_finite_zeroes() {
        // Regression: end == 0 means total_bank_cycles == 0; the
        // gated-fraction division must be guarded, not NaN.
        let cacti = CactiModel::default();
        let mut tr = OccupancyTrace::new("sram", 64 * MIB);
        tr.finalize(0);
        for policy in [
            GatingPolicy::None,
            GatingPolicy::Aggressive,
            GatingPolicy::conservative(),
            GatingPolicy::drowsy(),
        ] {
            let ev = evaluate(
                &cacti,
                &tr,
                &AccessStats::default(),
                64 * MIB,
                8,
                0.9,
                policy,
                1.0,
            )
            .unwrap();
            assert_eq!(ev.e_total_j(), 0.0, "{policy:?}");
            assert_eq!(ev.gated_fraction, 0.0, "{policy:?}");
            assert!(ev.gated_fraction.is_finite());
            assert!(ev.avg_active_banks == 0.0);
            assert_eq!(ev.n_switch, 0);
        }
    }

    #[test]
    fn zero_energy_baseline_delta_pct_is_zero_not_nan() {
        let cacti = CactiModel::default();
        let mut tr = OccupancyTrace::new("sram", 64 * MIB);
        tr.finalize(0);
        let st = AccessStats::default();
        let base =
            evaluate(&cacti, &tr, &st, 64 * MIB, 1, 0.9, GatingPolicy::None, 1.0).unwrap();
        let banked = evaluate(
            &cacti,
            &tr,
            &st,
            64 * MIB,
            8,
            0.9,
            GatingPolicy::Aggressive,
            1.0,
        )
        .unwrap();
        assert_eq!(base.e_total_j(), 0.0);
        let d = banked.delta_pct(&base);
        assert!(d.is_finite(), "delta_pct must not be NaN/inf: {d}");
        assert_eq!(d, 0.0);
    }
}
