//! Fused single-pass Stage-II sweep engine.
//!
//! The naive sweep ([`super::sweep::sweep_naive`]) re-walks the full
//! occupancy trace once per grid point (`bank_activity` is O(segments)
//! and allocates a `Vec<ActivitySegment>`), then walks the timeline once
//! per bank inside `evaluate` — O(grid × B × segments) total. With long
//! serving traces and the paper's 36-point Table II grid, that made
//! Stage II dominate wall-clock, defeating the premise that Stage II is
//! a cheap offline pass.
//!
//! This engine makes **one traversal** of the occupancy segments and
//! updates *every* (C, B, α, policy) candidate incrementally. Each
//! candidate holds O(B) state:
//!
//! * the current `banks_required` level, maintained through its
//!   **threshold ladder** (occupancy bands `(k·usable, (k+1)·usable]`):
//!   successive segments usually stay in or near the current band, so
//!   the level update is a couple of comparisons, not a division;
//! * one open-idle-run start time per bank (banks pack low-to-high, so
//!   bank `b` idles exactly while `level <= b`; a level rise closes runs,
//!   a level fall opens them);
//! * accumulators for the time-weighted active-bank integral, gated
//!   cycles, and switch counts.
//!
//! No per-candidate timeline is ever materialized, and the traversal is
//! allocation-free. Gate decisions go through the *same*
//! [`GatingPolicy::decider`] path as `evaluate`, and the floating-point
//! reductions replicate `evaluate`'s expressions exactly, so the fused
//! results are bit-identical to the naive oracle (asserted by
//! `tests/sweep_fused.rs` and the `stage2_sweep` bench).
//!
//! Two front ends:
//!
//! * [`sweep_fused`] — drop-in behind [`super::sweep::sweep`] for
//!   materialized traces; shards candidates across threads on large
//!   grid × trace products (same spawn pattern as `api::BatchRunner`).
//! * [`SweepSink`] — a [`TraceSink`] that consumes the Stage-I stream
//!   directly, so Stage I + Stage II run fused during simulation with
//!   **no materialized trace at all** (`ExperimentSpec::stream_stage2`,
//!   `ExperimentSpec::serve_fused`, `repro serve --fused`).

use crate::cacti::{CactiModel, SramCharacterization};
use crate::trace::sink::{MemoryDesc, TraceSink};
use crate::trace::{AccessStats, OccupancyTrace};
use crate::util::ceil_div;

use super::energy::{BankingEval, EnergyError};
use super::policy::{GateDecider, GatingPolicy};
use super::sweep::{SweepPoint, SweepSpec};

/// Incremental Stage-II state of one (C, B, α, policy) candidate.
#[derive(Debug, Clone)]
struct Candidate {
    capacity: u64,
    banks: u32,
    alpha: f64,
    policy: GatingPolicy,
    ch: SramCharacterization,
    decider: GateDecider,
    /// Eq. 1 denominator `floor(alpha * C / B)`; 0 means "any occupancy
    /// pins every bank" (degenerate tiny-capacity case).
    usable_per_bank: u64,
    /// Current `banks_required` level. Starts at `banks` ("everything
    /// busy, nothing open") so the first segment opens the right runs.
    level: u32,
    /// Start time of the current constant-level run (for the activity
    /// integral).
    run_start: u64,
    /// Per-bank open idle-run start; entry `b` is meaningful iff
    /// `b >= level`.
    open_since: Vec<u64>,
    /// Σ level · dt over the traversal (integer, order-independent).
    active_weighted: u128,
    gated_cycles: u128,
    n_switch: u64,
    started: bool,
}

impl Candidate {
    fn new(
        cacti: &CactiModel,
        capacity: u64,
        banks: u32,
        alpha: f64,
        policy: GatingPolicy,
        freq_ghz: f64,
    ) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha={alpha}");
        assert!(banks >= 1);
        let ch = cacti.characterize(capacity, banks);
        let decider = policy.decider(&ch, freq_ghz);
        // Exactly `banks_required`'s denominator (same float expression).
        let usable_per_bank = (alpha * (capacity as f64 / banks as f64)).floor() as u64;
        Self {
            capacity,
            banks,
            alpha,
            policy,
            ch,
            decider,
            usable_per_bank,
            level: banks,
            run_start: 0,
            open_since: vec![0; banks as usize],
            active_weighted: 0,
            gated_cycles: 0,
            n_switch: 0,
            started: false,
        }
    }

    /// Eq. 1 via the threshold ladder: walk the current level down/up
    /// until `needed` falls inside its band. Amortized O(level delta);
    /// equal to `ceil(needed / usable).min(banks)` exactly.
    #[inline]
    fn level_for(&self, needed: u64) -> u32 {
        if needed == 0 {
            return 0;
        }
        let usable = self.usable_per_bank;
        if usable == 0 {
            return self.banks;
        }
        let mut l = self.level.max(1);
        while l > 1 && needed <= usable.saturating_mul((l - 1) as u64) {
            l -= 1;
        }
        while l < self.banks && needed > usable.saturating_mul(l as u64) {
            l += 1;
        }
        debug_assert_eq!(
            l as u64,
            ceil_div(needed, usable).min(self.banks as u64),
            "ladder diverged from Eq. 1 at needed={needed}"
        );
        l
    }

    /// Close the idle run of bank `b` at time `t`, paying a transition
    /// pair iff the policy gates it.
    #[inline]
    fn close_run(&mut self, b: u32, t: u64) {
        let dt = t - self.open_since[b as usize];
        if dt > 0 && self.decider.gate(dt) {
            self.gated_cycles += dt as u128;
            self.n_switch += 2;
        }
    }

    /// Consume the occupancy change at segment boundary `t0`: from here
    /// until the next boundary (or the run's end) `needed` bytes are
    /// resident. Segments are contiguous, so only the left edge matters —
    /// the open run closes at the next call's `t0` or at [`Candidate::seal`].
    #[inline]
    fn advance(&mut self, t0: u64, needed: u64) {
        if !self.started {
            self.started = true;
            debug_assert_eq!(t0, 0, "occupancy streams start at t=0");
        }
        let new = self.level_for(needed);
        let old = self.level;
        if new != old {
            if new > old {
                for b in old..new {
                    self.close_run(b, t0);
                }
            } else {
                for b in new..old {
                    self.open_since[b as usize] = t0;
                }
            }
            self.active_weighted += old as u128 * (t0 - self.run_start) as u128;
            self.run_start = t0;
            self.level = new;
        }
    }

    /// Close every open run and the activity integral at the run's end.
    fn seal(&mut self, end: u64) {
        if !self.started {
            // Zero-segment trace (end == 0): nothing was ever active or
            // idle, matching the empty activity timeline of the oracle.
            self.level = 0;
            return;
        }
        for b in self.level..self.banks {
            self.close_run(b, end);
        }
        self.active_weighted += self.level as u128 * (end - self.run_start) as u128;
        self.run_start = end;
    }

    /// Assemble the final evaluation. Float expressions replicate
    /// [`super::energy::evaluate`] term for term so the result is
    /// bit-identical to the naive path.
    fn into_eval(self, stats: &AccessStats, end: u64, freq_ghz: f64) -> BankingEval {
        let ch = self.ch;
        let cyc_to_s = 1.0 / (freq_ghz * 1e9);
        let end_f = end as f64;

        let e_dyn = stats.reads as f64 * ch.e_read_j + stats.writes as f64 * ch.e_write_j;

        let avg = if end == 0 {
            0.0
        } else {
            self.active_weighted as f64 / end_f
        };

        let total_bank_cycles = end_f * self.banks as f64;
        let retained = self.policy.idle_leak_factor();
        let leak_cycles = total_bank_cycles - self.gated_cycles as f64 * (1.0 - retained);
        let e_leak = ch.p_leak_bank_w * leak_cycles * cyc_to_s;
        let per_switch = match self.policy {
            GatingPolicy::Drowsy { .. } => ch.e_switch_j * 0.01,
            _ => ch.e_switch_j,
        };
        let e_sw = self.n_switch as f64 * per_switch;

        BankingEval {
            capacity: self.capacity,
            banks: self.banks,
            alpha: self.alpha,
            policy: self.policy,
            e_dyn_j: e_dyn,
            e_leak_j: e_leak,
            e_sw_j: e_sw,
            n_switch: self.n_switch,
            avg_active_banks: avg,
            gated_fraction: if total_bank_cycles > 0.0 {
                self.gated_cycles as f64 / total_bank_cycles
            } else {
                0.0
            },
            area_mm2: ch.area_mm2,
            latency_cycles: ch.latency_cycles,
            characterization: ch,
        }
    }
}

/// One (capacity, alpha) group of the grid: the shared B=1 ungated
/// reference plus one candidate per (policy, banks) cell, in the naive
/// sweep's output order.
struct Group {
    capacity: u64,
    base: Candidate,
    /// `policies.len() * banks.len()` candidates, policy-major.
    cells: Vec<Candidate>,
}

/// Single-pass evaluator of a whole [`SweepSpec`] grid over a stream of
/// occupancy segments. Feed segments with [`FusedSweep::push_segment`]
/// (non-overlapping, time-ordered, starting at 0), then
/// [`FusedSweep::finish`] once with the run's end time.
pub struct FusedSweep {
    freq_ghz: f64,
    groups: Vec<Group>,
    end: Option<u64>,
}

impl FusedSweep {
    /// Build the engine for every candidate of `spec`. Capacities known
    /// to be infeasible may be pre-filtered by the caller; otherwise
    /// [`FusedSweep::finish`] filters by the observed peak.
    pub fn new(cacti: &CactiModel, spec: &SweepSpec, freq_ghz: f64) -> Self {
        let mut groups = Vec::with_capacity(spec.capacities.len() * spec.alphas.len());
        for &cap in &spec.capacities {
            for &alpha in &spec.alphas {
                let base =
                    Candidate::new(cacti, cap, 1, alpha, GatingPolicy::None, freq_ghz);
                let mut cells =
                    Vec::with_capacity(spec.policies.len() * spec.banks.len());
                for &policy in &spec.policies {
                    for &banks in &spec.banks {
                        cells.push(Candidate::new(
                            cacti, cap, banks, alpha, policy, freq_ghz,
                        ));
                    }
                }
                groups.push(Group {
                    capacity: cap,
                    base,
                    cells,
                });
            }
        }
        Self {
            freq_ghz,
            groups,
            end: None,
        }
    }

    /// Total candidates held (cells + references).
    pub fn candidates(&self) -> usize {
        self.groups.iter().map(|g| g.cells.len() + 1).sum()
    }

    /// Consume one piecewise-constant occupancy segment `[t0, t1)`
    /// holding `needed` bytes (the paper's `NeededOnly` basis). Segments
    /// must be contiguous, time-ordered, and start at 0.
    #[inline]
    pub fn push_segment(&mut self, t0: u64, t1: u64, needed: u64) {
        debug_assert!(t1 > t0, "empty segment [{t0}, {t1})");
        debug_assert!(self.end.is_none(), "push after finish");
        for g in &mut self.groups {
            g.base.advance(t0, needed);
            for c in &mut g.cells {
                c.advance(t0, needed);
            }
        }
    }

    /// Seal every candidate at the run's end time.
    pub fn finish(&mut self, end: u64) {
        assert!(self.end.is_none(), "finish called twice");
        self.end = Some(end);
        for g in &mut self.groups {
            g.base.seal(end);
            for c in &mut g.cells {
                c.seal(end);
            }
        }
    }

    /// Assemble the grid points in the naive sweep's output order
    /// (capacity → alpha → policy → banks), dropping capacities below
    /// `peak_needed` (infeasible: the schedule would change). `stats`
    /// supplies the Eq. 3 dynamic-energy counts.
    pub fn into_points(self, stats: &AccessStats, peak_needed: u64) -> Vec<SweepPoint> {
        let end = self.end.expect("finish() before into_points()");
        let freq = self.freq_ghz;
        let mut out = Vec::new();
        for g in self.groups {
            if g.capacity < peak_needed {
                continue;
            }
            let base = g.base.into_eval(stats, end, freq);
            let base_e = base.e_total_j();
            let base_a = base.area_mm2;
            for cell in g.cells {
                // The exact (B=1, no-gating) cell IS the reference; reuse
                // it like the oracle does (identical by construction).
                let eval = if cell.banks == 1 && cell.policy == GatingPolicy::None {
                    base.clone()
                } else {
                    cell.into_eval(stats, end, freq)
                };
                out.push(SweepPoint {
                    eval,
                    base_e_j: base_e,
                    base_area_mm2: base_a,
                });
            }
        }
        out
    }

    /// Split the engine's candidate groups into up to `n` shards for
    /// thread-parallel traversal; reassemble with [`FusedSweep::reunite`].
    fn split(&mut self, n: usize) -> Vec<Vec<Group>> {
        let groups = std::mem::take(&mut self.groups);
        let per = groups.len().div_ceil(n.max(1));
        let mut shards: Vec<Vec<Group>> = Vec::new();
        let mut it = groups.into_iter().peekable();
        while it.peek().is_some() {
            shards.push(it.by_ref().take(per).collect());
        }
        shards
    }

    fn reunite(&mut self, shards: Vec<Vec<Group>>) {
        self.groups = shards.into_iter().flatten().collect();
    }
}

/// Work threshold (segments × candidates) above which the materialized
/// sweep shards candidates across threads. Below it, spawn overhead
/// outweighs the win (~a quarter-million O(1) updates run in well under
/// a millisecond).
const PARALLEL_WORK_THRESHOLD: u128 = 1 << 18;

/// Fused implementation behind [`super::sweep::sweep`]: one traversal of
/// the (finalized) trace evaluates the whole grid, sharding candidate
/// groups across OS threads when the grid × trace product is large.
/// Per-candidate results are independent, so the output is byte-identical
/// at any thread count.
///
/// Errors with [`EnergyError::UnfinalizedTrace`] instead of panicking
/// when the trace has no end time.
pub fn sweep_fused(
    cacti: &CactiModel,
    trace: &OccupancyTrace,
    stats: &AccessStats,
    spec: &SweepSpec,
    freq_ghz: f64,
) -> Result<Vec<SweepPoint>, EnergyError> {
    let Some(end) = trace.end_time() else {
        return Err(EnergyError::UnfinalizedTrace {
            memory: trace.memory.clone(),
        });
    };
    let peak = trace.peak_needed();
    // Pre-filter infeasible capacities: same outcome as the post-filter,
    // without paying traversal work for points that get dropped.
    let feasible = SweepSpec {
        capacities: spec
            .capacities
            .iter()
            .copied()
            .filter(|&c| c >= peak)
            .collect(),
        banks: spec.banks.clone(),
        alphas: spec.alphas.clone(),
        policies: spec.policies.clone(),
    };
    let mut engine = FusedSweep::new(cacti, &feasible, freq_ghz);

    let work = trace.samples().len() as u128 * engine.candidates() as u128;
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if work >= PARALLEL_WORK_THRESHOLD && threads > 1 && engine.groups.len() > 1 {
        // Shard groups across threads; each walks the trace once over its
        // shard (same scoped-spawn pattern as api::BatchRunner). Scope
        // joins every worker before returning.
        let mut shards = engine.split(threads.min(engine.groups.len()));
        std::thread::scope(|scope| {
            for shard in &mut shards {
                scope.spawn(move || {
                    for seg in trace.segments() {
                        for g in shard.iter_mut() {
                            g.base.advance(seg.t0, seg.needed);
                            for c in &mut g.cells {
                                c.advance(seg.t0, seg.needed);
                            }
                        }
                    }
                });
            }
        });
        engine.reunite(shards);
    } else {
        for seg in trace.segments() {
            engine.push_segment(seg.t0, seg.t1, seg.needed);
        }
    }
    engine.finish(end);
    Ok(engine.into_points(stats, peak))
}

/// Streaming Stage-II consumer: a [`TraceSink`] that runs the fused sweep
/// engine directly on the Stage-I occupancy stream of one memory, so
/// `Stage2Run`-equivalent results come out of a simulation that never
/// materialized a trace.
///
/// Sample semantics mirror [`OccupancyTrace::record`]: same-instant
/// updates overwrite (only the final state at an instant is observable),
/// and the state at `t` holds until the next sample. The sink also tracks
/// the peak needed bytes at *sample* granularity (zero-duration final
/// states included), so its feasibility filtering matches
/// `OccupancyTrace::peak_needed` exactly.
///
/// When to stream vs. materialize: stream when the trace exists only to
/// feed Stage II on a *known* grid (O(1) trace memory, one pass);
/// materialize when the grid derives from the observed peak, when the
/// trace itself is an artifact (CSV/JSON export, figures), or when
/// several differently-parameterized sweeps will reuse it.
pub struct SweepSink {
    engine: FusedSweep,
    /// Which announced memory to consume (0 = shared SRAM / KV arena).
    mem: usize,
    /// Pending state `(t, needed)` — committed when time advances.
    pending: (u64, u64),
    peak_needed: u64,
    finished: Option<u64>,
}

impl SweepSink {
    /// Sweep `spec` over the occupancy stream of memory index 0.
    pub fn new(cacti: &CactiModel, spec: &SweepSpec, freq_ghz: f64) -> Self {
        Self::for_memory(cacti, spec, freq_ghz, 0)
    }

    /// Sweep the stream of the `mem`-th announced memory.
    pub fn for_memory(
        cacti: &CactiModel,
        spec: &SweepSpec,
        freq_ghz: f64,
        mem: usize,
    ) -> Self {
        Self {
            engine: FusedSweep::new(cacti, spec, freq_ghz),
            mem,
            pending: (0, 0),
            peak_needed: 0,
            finished: None,
        }
    }

    /// Commit the pending state over `[pending.t, until)`.
    fn commit(&mut self, until: u64) {
        let (t, needed) = self.pending;
        self.peak_needed = self.peak_needed.max(needed);
        if until > t {
            self.engine.push_segment(t, until, needed);
        }
    }

    /// Peak needed bytes observed so far (sample granularity).
    pub fn peak_needed(&self) -> u64 {
        self.peak_needed
    }

    /// Finalize into sweep points (requires the stream to have finished).
    /// Grid capacities below the observed peak are dropped, exactly like
    /// [`super::sweep::sweep`] on the materialized trace.
    pub fn into_points(self, stats: &AccessStats) -> Vec<SweepPoint> {
        assert!(
            self.finished.is_some(),
            "SweepSink::into_points before the stream finished"
        );
        self.engine.into_points(stats, self.peak_needed)
    }
}

impl TraceSink for SweepSink {
    fn begin(&mut self, memories: &[MemoryDesc]) {
        assert!(
            self.mem < memories.len(),
            "SweepSink targets memory {} but the run announced {}",
            self.mem,
            memories.len()
        );
    }

    fn on_sample(&mut self, mem: usize, t: u64, needed: u64, _obsolete: u64) {
        if mem != self.mem {
            return;
        }
        debug_assert!(t >= self.pending.0, "stream time went backwards");
        if t > self.pending.0 {
            self.commit(t);
        }
        self.pending = (t, needed);
    }

    fn finish(&mut self, end: u64) {
        self.commit(end);
        self.engine.finish(end);
        self.finished = Some(end);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::banking::sweep::sweep_naive;
    use crate::util::rng::Rng;
    use crate::util::MIB;

    fn grid() -> SweepSpec {
        SweepSpec {
            capacities: vec![16 * MIB, 48 * MIB, 64 * MIB],
            banks: vec![1, 2, 4, 8, 16, 32],
            alphas: vec![0.9, 1.0],
            policies: vec![
                GatingPolicy::None,
                GatingPolicy::Aggressive,
                GatingPolicy::conservative(),
                GatingPolicy::drowsy(),
            ],
        }
    }

    fn stats() -> AccessStats {
        AccessStats {
            reads: 12_345_678,
            writes: 987_654,
            ..Default::default()
        }
    }

    fn random_trace(rng: &mut Rng, cap: u64) -> OccupancyTrace {
        let mut tr = OccupancyTrace::new("m", cap);
        let mut t = 0u64;
        for _ in 0..rng.range(1, 120) {
            t += rng.range(1, 50_000);
            // Mix zero-occupancy gaps in so gating triggers at every B.
            let needed = if rng.below(4) == 0 { 0 } else { rng.below(cap + 1) };
            tr.record(t, needed, 0);
        }
        tr.finalize(t + rng.range(1, 10_000));
        tr
    }

    fn assert_points_identical(fused: &[SweepPoint], naive: &[SweepPoint]) {
        assert_eq!(fused.len(), naive.len());
        for (f, n) in fused.iter().zip(naive) {
            assert_eq!(f.eval.capacity, n.eval.capacity);
            assert_eq!(f.eval.banks, n.eval.banks);
            assert_eq!(f.eval.alpha.to_bits(), n.eval.alpha.to_bits());
            assert_eq!(f.eval.policy, n.eval.policy);
            assert_eq!(f.eval.n_switch, n.eval.n_switch);
            assert_eq!(
                f.eval.gated_fraction.to_bits(),
                n.eval.gated_fraction.to_bits(),
                "gated_fraction at C={} B={} {:?}",
                n.eval.capacity,
                n.eval.banks,
                n.eval.policy
            );
            assert_eq!(
                f.eval.avg_active_banks.to_bits(),
                n.eval.avg_active_banks.to_bits()
            );
            assert_eq!(f.eval.e_dyn_j.to_bits(), n.eval.e_dyn_j.to_bits());
            assert_eq!(f.eval.e_leak_j.to_bits(), n.eval.e_leak_j.to_bits());
            assert_eq!(f.eval.e_sw_j.to_bits(), n.eval.e_sw_j.to_bits());
            assert_eq!(f.base_e_j.to_bits(), n.base_e_j.to_bits());
            assert_eq!(f.base_area_mm2.to_bits(), n.base_area_mm2.to_bits());
        }
    }

    #[test]
    fn fused_matches_naive_on_random_traces() {
        let cacti = CactiModel::default();
        crate::util::proptest::check("fused-vs-naive", 40, |rng| {
            let tr = random_trace(rng, 64 * MIB);
            let st = stats();
            let fused = sweep_fused(&cacti, &tr, &st, &grid(), 1.0).unwrap();
            let naive = sweep_naive(&cacti, &tr, &st, &grid(), 1.0).unwrap();
            assert_points_identical(&fused, &naive);
        });
    }

    #[test]
    fn fused_matches_naive_on_degenerate_traces() {
        let cacti = CactiModel::default();
        let st = AccessStats::default();
        // Zero-length trace.
        let mut empty = OccupancyTrace::new("m", 64 * MIB);
        empty.finalize(0);
        assert_points_identical(
            &sweep_fused(&cacti, &empty, &st, &grid(), 1.0).unwrap(),
            &sweep_naive(&cacti, &empty, &st, &grid(), 1.0).unwrap(),
        );
        // Constant occupancy with a zero-duration final sample that sets
        // the peak (feasibility filter must see it).
        let mut spike = OccupancyTrace::new("m", 64 * MIB);
        spike.record(5, 10 * MIB, 0);
        spike.record(100, 60 * MIB, 0);
        spike.finalize(100);
        assert_eq!(spike.peak_needed(), 60 * MIB);
        assert_points_identical(
            &sweep_fused(&cacti, &spike, &st, &grid(), 1.0).unwrap(),
            &sweep_naive(&cacti, &spike, &st, &grid(), 1.0).unwrap(),
        );
    }

    #[test]
    fn sink_matches_materialized_sweep() {
        let cacti = CactiModel::default();
        let mut rng = Rng::new(99);
        let tr = random_trace(&mut rng, 48 * MIB);
        let st = stats();
        let spec = grid();

        let mut sink = SweepSink::new(&cacti, &spec, 1.0);
        sink.begin(&[MemoryDesc {
            name: "m".to_string(),
            capacity: 48 * MIB,
        }]);
        for s in tr.samples() {
            sink.on_sample(0, s.t, s.needed, s.obsolete);
        }
        sink.finish(tr.end_time().unwrap());
        assert_eq!(sink.peak_needed(), tr.peak_needed());
        let streamed = sink.into_points(&st);
        let materialized = sweep_fused(&cacti, &tr, &st, &spec, 1.0).unwrap();
        assert_points_identical(&streamed, &materialized);
    }

    #[test]
    fn sink_overwrites_same_instant_and_ignores_other_memories() {
        let cacti = CactiModel::default();
        let spec = SweepSpec {
            capacities: vec![MIB],
            banks: vec![1, 2],
            alphas: vec![1.0],
            policies: vec![GatingPolicy::Aggressive],
        };
        let mems = [
            MemoryDesc { name: "a".into(), capacity: MIB },
            MemoryDesc { name: "b".into(), capacity: MIB },
        ];

        let mut sink = SweepSink::new(&cacti, &spec, 1.0);
        sink.begin(&mems);
        sink.on_sample(0, 10, MIB, 0); // transient, overwritten below
        sink.on_sample(0, 10, 1024, 0);
        sink.on_sample(1, 20, MIB, 0); // other memory: ignored
        sink.on_sample(0, 50_000, 0, 0);
        sink.finish(1_000_000);
        let streamed = sink.into_points(&AccessStats::default());

        let mut tr = OccupancyTrace::new("a", MIB);
        tr.record(10, MIB, 0);
        tr.record(10, 1024, 0);
        tr.record(50_000, 0, 0);
        tr.finalize(1_000_000);
        let reference = sweep_fused(&cacti, &tr, &AccessStats::default(), &spec, 1.0).unwrap();
        assert_points_identical(&streamed, &reference);
        // The transient MIB at t=10 never pinned the peak.
        assert_eq!(streamed[0].eval.capacity, MIB);
    }

    #[test]
    fn parallel_sharding_is_byte_identical() {
        // Force the threaded path: every capacity feasible (occupancy
        // stays below the smallest) and segments x candidates above the
        // work threshold.
        let cacti = CactiModel::default();
        let mut rng = Rng::new(7);
        let mut tr = OccupancyTrace::new("m", 64 * MIB);
        let mut t = 0u64;
        for _ in 0..20_000 {
            t += rng.range(1, 100);
            tr.record(t, rng.below(60 * MIB), 0);
        }
        tr.finalize(t + 1);
        let spec = SweepSpec {
            capacities: vec![64 * MIB, 80 * MIB, 96 * MIB, 112 * MIB],
            banks: vec![1, 2, 4, 8, 16, 32],
            alphas: vec![0.9, 1.0],
            policies: vec![
                GatingPolicy::Aggressive,
                GatingPolicy::conservative(),
                GatingPolicy::drowsy(),
            ],
        };
        let candidates = spec.points() + spec.capacities.len() * spec.alphas.len();
        let work = tr.samples().len() as u128 * candidates as u128;
        assert!(work >= PARALLEL_WORK_THRESHOLD, "work={work}");
        let st = stats();
        let fused = sweep_fused(&cacti, &tr, &st, &spec, 1.0).unwrap();
        let naive = sweep_naive(&cacti, &tr, &st, &spec, 1.0).unwrap();
        assert_points_identical(&fused, &naive);
    }
}
