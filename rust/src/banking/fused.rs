//! Fused single-pass Stage-II sweep engine (structure-of-arrays).
//!
//! The naive sweep ([`super::sweep::sweep_naive`]) re-walks the full
//! occupancy trace once per grid point (`bank_activity` is O(segments)
//! and allocates a `Vec<ActivitySegment>`), then walks the timeline once
//! per bank inside `evaluate` — O(grid × B × segments) total. With long
//! serving traces and the paper's 36-point Table II grid, that made
//! Stage II dominate wall-clock, defeating the premise that Stage II is
//! a cheap offline pass.
//!
//! This engine makes **one traversal** of the occupancy segments and
//! updates *every* (C, B, α, policy) candidate incrementally, organized
//! by what candidates actually share rather than one struct per grid
//! point:
//!
//! * [`OrgShared`] — one entry per (C, B) SRAM organization: the CACTI
//!   characterization (α/policy-independent, so `characterize` runs once
//!   per organization, not once per grid point) and one resolved
//!   [`GateDecider`] per policy lane
//!   ([`GateDecider::for_policies`] — the same decision path as
//!   `evaluate`, hoisted out of the traversal).
//! * [`LadderGroup`] — one entry per (C, B, α): candidates that agree on
//!   (C, B, α) have an identical `usable_per_bank`, hence an identical
//!   Eq. 1 `banks_required` ladder, identical level timeline, and
//!   identical per-bank idle runs. The group holds that state **once**
//!   (current level, per-bank open-run starts, the shared activity
//!   integral) plus structure-of-arrays accumulator lanes — contiguous
//!   `gated_cycles[lane]` / `n_switch[lane]` slices, one lane per
//!   policy — so closing an idle run fans the one shared `dt` out across
//!   policies in a tight, autovectorizable lane loop.
//!
//! The ladder itself is precomputed as **band boundaries**
//! (`bounds[k] = (k+1)·usable`): a segment whose occupancy stays in the
//! current band costs two comparisons, and a band change is one
//! O(log B) `partition_point` over the boundary array — never a walk,
//! never a division.
//!
//! No per-candidate timeline is ever materialized, and the traversal is
//! allocation-free. The floating-point reductions replicate
//! [`super::energy::evaluate`]'s expressions exactly, so the fused
//! results are bit-identical to the naive oracle (asserted by
//! `tests/sweep_fused.rs`, `tests/sweep_soa_props.rs`, and the
//! `stage2_sweep` bench).
//!
//! Two front ends share the engine bit-identically:
//!
//! * [`sweep_fused`] — drop-in behind [`super::sweep::sweep`] for
//!   materialized traces; shards **whole ladder groups** across threads
//!   on large grid × trace products (no group's state is ever duplicated
//!   or split across workers; chunk-order reassembly keeps the output
//!   byte-identical at any thread count).
//! * [`SweepSink`] — a [`TraceSink`] that consumes the Stage-I stream
//!   directly, so Stage I + Stage II run fused during simulation with
//!   **no materialized trace at all** (`ExperimentSpec::stream_stage2`,
//!   `ExperimentSpec::serve_fused`, `repro serve --fused`).

use crate::cacti::{CactiModel, SramCharacterization};
use crate::trace::sink::{MemoryDesc, TraceSink};
use crate::trace::{AccessStats, OccupancyTrace};
use crate::util::ceil_div;

use super::energy::{BankingEval, EnergyError};
use super::policy::{GateDecider, GatingPolicy};
use super::sweep::{SweepPoint, SweepSpec};

/// Read-only per-(C, B) organization state shared by every α group and
/// policy lane of that organization: one CACTI characterization and one
/// resolved gate decider per policy lane. Built once at engine
/// construction, then only borrowed — including across shard threads.
#[derive(Debug)]
struct OrgShared {
    capacity: u64,
    banks: u32,
    ch: SramCharacterization,
    /// Lane axis: the spec's policies in order, plus (on the B=1
    /// reference organization, when the spec lacks `None`) one trailing
    /// ungated reference lane.
    policies: Vec<GatingPolicy>,
    /// Parallel to `policies`.
    deciders: Vec<GateDecider>,
}

/// Mutable traversal state of one (C, B, α) group: the shared threshold
/// ladder plus structure-of-arrays accumulator lanes (one per policy of
/// the group's organization).
#[derive(Debug, Clone)]
struct LadderGroup {
    /// Index of the group's [`OrgShared`] in the engine's org table.
    org: usize,
    alpha: f64,
    banks: u32,
    /// Eq. 1 denominator `floor(alpha * C / B)`; 0 means "any occupancy
    /// pins every bank" (degenerate tiny-capacity case).
    usable_per_bank: u64,
    /// Precomputed ladder band boundaries: `bounds[k] = (k+1) · usable`
    /// (saturating), so `banks_required(needed)` is the band index that
    /// brackets `needed` — two comparisons on the fast path, one
    /// `partition_point` on a band change.
    bounds: Vec<u64>,
    /// Current `banks_required` level. Starts at `banks` ("everything
    /// busy, nothing open") so the first segment opens the right runs.
    level: u32,
    /// Start time of the current constant-level run (for the activity
    /// integral).
    run_start: u64,
    /// Per-bank open idle-run start; entry `b` is meaningful iff
    /// `b >= level`. Shared by every policy lane (the ladder does not
    /// depend on the policy).
    open_since: Vec<u64>,
    /// Σ level · dt over the traversal (integer, order-independent);
    /// shared by every lane.
    active_weighted: u128,
    started: bool,
    /// SoA lane accumulators, parallel to the organization's deciders.
    gated_cycles: Vec<u128>,
    n_switch: Vec<u64>,
}

impl LadderGroup {
    fn new(org_idx: usize, org: &OrgShared, alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha={alpha}");
        assert!(org.banks >= 1);
        // Exactly `banks_required`'s denominator (same float expression).
        let usable_per_bank =
            (alpha * (org.capacity as f64 / org.banks as f64)).floor() as u64;
        let bounds = (0..org.banks)
            .map(|k| usable_per_bank.saturating_mul((k + 1) as u64))
            .collect();
        let lanes = org.deciders.len();
        Self {
            org: org_idx,
            alpha,
            banks: org.banks,
            usable_per_bank,
            bounds,
            level: org.banks,
            run_start: 0,
            open_since: vec![0; org.banks as usize],
            active_weighted: 0,
            started: false,
            gated_cycles: vec![0; lanes],
            n_switch: vec![0; lanes],
        }
    }

    /// Eq. 1 via the precomputed band boundaries: if `needed` still falls
    /// in the current level's band, two comparisons; otherwise one
    /// O(log B) `partition_point`. Equal to
    /// `ceil(needed / usable).min(banks)` exactly.
    #[inline]
    fn level_for(&self, needed: u64) -> u32 {
        if needed == 0 {
            return 0;
        }
        if self.usable_per_bank == 0 {
            return self.banks;
        }
        let bounds = &self.bounds;
        let l = self.level;
        // Band-delta fast path: level l (l >= 1) covers
        // (bounds[l-2], bounds[l-1]], with the top band unbounded above
        // (the ladder clamps at `banks`).
        let new = if l >= 1
            && (l == 1 || needed > bounds[(l - 2) as usize])
            && (l == self.banks || needed <= bounds[(l - 1) as usize])
        {
            l
        } else {
            (bounds.partition_point(|&b| b < needed) as u32 + 1).min(self.banks)
        };
        debug_assert_eq!(
            new as u64,
            ceil_div(needed, self.usable_per_bank).min(self.banks as u64),
            "ladder bounds diverged from Eq. 1 at needed={needed}"
        );
        new
    }

    /// Close the idle run of bank `b` at time `t`: the one shared `dt`
    /// fans out across the policy lanes (contiguous accumulators, so the
    /// lane loop vectorizes).
    #[inline]
    fn close_run(&mut self, b: u32, t: u64, deciders: &[GateDecider]) {
        let dt = t - self.open_since[b as usize];
        if dt == 0 {
            return;
        }
        for (lane, d) in deciders.iter().enumerate() {
            if d.gate(dt) {
                self.gated_cycles[lane] += dt as u128;
                self.n_switch[lane] += 2;
            }
        }
    }

    /// Consume the occupancy change at segment boundary `t0`: from here
    /// until the next boundary (or the run's end) `needed` bytes are
    /// resident. Segments are contiguous, so only the left edge matters —
    /// the open run closes at the next call's `t0` or at
    /// [`LadderGroup::seal`].
    #[inline]
    fn advance(&mut self, t0: u64, needed: u64, deciders: &[GateDecider]) {
        if !self.started {
            self.started = true;
            debug_assert_eq!(t0, 0, "occupancy streams start at t=0");
        }
        let new = self.level_for(needed);
        let old = self.level;
        if new != old {
            if new > old {
                for b in old..new {
                    self.close_run(b, t0, deciders);
                }
            } else {
                for b in new..old {
                    self.open_since[b as usize] = t0;
                }
            }
            self.active_weighted += old as u128 * (t0 - self.run_start) as u128;
            self.run_start = t0;
            self.level = new;
        }
    }

    /// Close every open run and the activity integral at the run's end.
    fn seal(&mut self, end: u64, deciders: &[GateDecider]) {
        if !self.started {
            // Zero-segment trace (end == 0): nothing was ever active or
            // idle, matching the empty activity timeline of the oracle.
            self.level = 0;
            return;
        }
        for b in self.level..self.banks {
            self.close_run(b, end, deciders);
        }
        self.active_weighted += self.level as u128 * (end - self.run_start) as u128;
        self.run_start = end;
    }

    /// Assemble one lane's final evaluation. Float expressions replicate
    /// [`super::energy::evaluate`] term for term so the result is
    /// bit-identical to the naive path.
    fn eval_lane(
        &self,
        lane: usize,
        org: &OrgShared,
        stats: &AccessStats,
        end: u64,
        freq_ghz: f64,
    ) -> BankingEval {
        let ch = org.ch;
        let policy = org.policies[lane];
        let cyc_to_s = 1.0 / (freq_ghz * 1e9);
        let end_f = end as f64;

        let e_dyn = stats.reads as f64 * ch.e_read_j + stats.writes as f64 * ch.e_write_j;

        let avg = if end == 0 {
            0.0
        } else {
            self.active_weighted as f64 / end_f
        };

        let total_bank_cycles = end_f * self.banks as f64;
        let retained = policy.idle_leak_factor();
        let gated = self.gated_cycles[lane];
        let leak_cycles = total_bank_cycles - gated as f64 * (1.0 - retained);
        let e_leak = ch.p_leak_bank_w * leak_cycles * cyc_to_s;
        let per_switch = match policy {
            GatingPolicy::Drowsy { .. } => ch.e_switch_j * 0.01,
            _ => ch.e_switch_j,
        };
        let n_switch = self.n_switch[lane];
        let e_sw = n_switch as f64 * per_switch;

        BankingEval {
            capacity: org.capacity,
            banks: self.banks,
            alpha: self.alpha,
            policy,
            e_dyn_j: e_dyn,
            e_leak_j: e_leak,
            e_sw_j: e_sw,
            n_switch,
            avg_active_banks: avg,
            gated_fraction: if total_bank_cycles > 0.0 {
                gated as f64 / total_bank_cycles
            } else {
                0.0
            },
            area_mm2: ch.area_mm2,
            latency_cycles: ch.latency_cycles,
            characterization: ch,
        }
    }
}

/// Single-pass evaluator of a whole [`SweepSpec`] grid over a stream of
/// occupancy segments. Feed segments with [`FusedSweep::push_segment`]
/// (non-overlapping, time-ordered, starting at 0), then
/// [`FusedSweep::finish`] once with the run's end time.
pub struct FusedSweep {
    freq_ghz: f64,
    capacities: Vec<u64>,
    alphas: Vec<f64>,
    /// The emitted bank axis (the spec's, verbatim).
    cell_banks: Vec<u32>,
    /// The emitted policy axis (the spec's, verbatim).
    policies: Vec<GatingPolicy>,
    /// Layout bank axis: the spec's banks, with B=1 prepended when the
    /// spec lacks it (the ΔE/ΔA reference needs a B=1 ladder group at
    /// every (C, α) regardless of the grid).
    bank_axis: Vec<u32>,
    /// `cell_banks[j]` lives at `bank_axis[bank_cell_offset + j]`.
    bank_cell_offset: usize,
    /// Index of the B=1 reference organization within `bank_axis`.
    base_bank_idx: usize,
    /// Lane of the ungated reference within the B=1 organization.
    base_lane: usize,
    /// `capacities.len() × bank_axis.len()` organizations, capacity-major.
    orgs: Vec<OrgShared>,
    /// `capacities.len() × alphas.len() × bank_axis.len()` groups, in
    /// (capacity, alpha, bank) order — the unit of thread sharding.
    groups: Vec<LadderGroup>,
    end: Option<u64>,
}

impl FusedSweep {
    /// Build the engine for every candidate of `spec`. Capacities known
    /// to be infeasible may be pre-filtered by the caller; otherwise
    /// [`FusedSweep::into_points`] filters by the observed peak.
    pub fn new(cacti: &CactiModel, spec: &SweepSpec, freq_ghz: f64) -> Self {
        let capacities = spec.capacities.clone();
        let alphas = spec.alphas.clone();
        let cell_banks = spec.banks.clone();
        let policies = spec.policies.clone();

        let one_pos = cell_banks.iter().position(|&b| b == 1);
        let (bank_axis, bank_cell_offset, base_bank_idx) = match one_pos {
            Some(i) => (cell_banks.clone(), 0, i),
            None => {
                let mut axis = Vec::with_capacity(cell_banks.len() + 1);
                axis.push(1);
                axis.extend_from_slice(&cell_banks);
                (axis, 1, 0)
            }
        };
        // The ungated reference lane: the spec's own `None` lane when it
        // has one, a trailing extra lane on the B=1 organization when it
        // does not, and the only lane of a synthetic B=1 organization
        // when the grid itself lacks B=1.
        let base_lane = match one_pos {
            Some(_) => policies
                .iter()
                .position(|&p| p == GatingPolicy::None)
                .unwrap_or(policies.len()),
            None => 0,
        };

        let mut orgs = Vec::with_capacity(capacities.len() * bank_axis.len());
        for &cap in &capacities {
            for (bi, &banks) in bank_axis.iter().enumerate() {
                assert!(banks >= 1);
                // Once per (C, B): α and policy do not affect the
                // characterization, so no per-grid-point re-derivation.
                let ch = cacti.characterize(cap, banks);
                let lane_policies: Vec<GatingPolicy> = if bi == base_bank_idx {
                    if one_pos.is_some() {
                        let mut ps = policies.clone();
                        if !ps.contains(&GatingPolicy::None) {
                            ps.push(GatingPolicy::None);
                        }
                        ps
                    } else {
                        vec![GatingPolicy::None]
                    }
                } else {
                    policies.clone()
                };
                let deciders = GateDecider::for_policies(&lane_policies, &ch, freq_ghz);
                orgs.push(OrgShared {
                    capacity: cap,
                    banks,
                    ch,
                    policies: lane_policies,
                    deciders,
                });
            }
        }

        let mut groups =
            Vec::with_capacity(capacities.len() * alphas.len() * bank_axis.len());
        for ci in 0..capacities.len() {
            for &alpha in &alphas {
                for bi in 0..bank_axis.len() {
                    let org_idx = ci * bank_axis.len() + bi;
                    groups.push(LadderGroup::new(org_idx, &orgs[org_idx], alpha));
                }
            }
        }

        Self {
            freq_ghz,
            capacities,
            alphas,
            cell_banks,
            policies,
            bank_axis,
            bank_cell_offset,
            base_bank_idx,
            base_lane,
            orgs,
            groups,
            end: None,
        }
    }

    /// Total candidate lanes held across all groups (grid cells plus the
    /// ungated references).
    pub fn candidates(&self) -> usize {
        self.groups
            .iter()
            .map(|g| self.orgs[g.org].deciders.len())
            .sum()
    }

    /// Consume one piecewise-constant occupancy segment `[t0, t1)`
    /// holding `needed` bytes (the paper's `NeededOnly` basis). Segments
    /// must be contiguous, time-ordered, and start at 0.
    #[inline]
    pub fn push_segment(&mut self, t0: u64, t1: u64, needed: u64) {
        debug_assert!(t1 > t0, "empty segment [{t0}, {t1})");
        debug_assert!(self.end.is_none(), "push after finish");
        let orgs = &self.orgs;
        for g in &mut self.groups {
            g.advance(t0, needed, &orgs[g.org].deciders);
        }
    }

    /// Seal every group at the run's end time.
    pub fn finish(&mut self, end: u64) {
        assert!(self.end.is_none(), "finish called twice");
        self.end = Some(end);
        let orgs = &self.orgs;
        for g in &mut self.groups {
            g.seal(end, &orgs[g.org].deciders);
        }
    }

    /// Assemble the grid points in the naive sweep's output order
    /// (capacity → alpha → policy → banks), dropping capacities below
    /// `peak_needed` (infeasible: the schedule would change). `stats`
    /// supplies the Eq. 3 dynamic-energy counts.
    pub fn into_points(self, stats: &AccessStats, peak_needed: u64) -> Vec<SweepPoint> {
        let end = self.end.expect("finish() before into_points()");
        let freq = self.freq_ghz;
        let nb = self.bank_axis.len();
        let na = self.alphas.len();
        let mut out = Vec::new();
        for (ci, &cap) in self.capacities.iter().enumerate() {
            if cap < peak_needed {
                continue;
            }
            for ai in 0..na {
                let row = (ci * na + ai) * nb;
                let base = self.groups[row + self.base_bank_idx].eval_lane(
                    self.base_lane,
                    &self.orgs[ci * nb + self.base_bank_idx],
                    stats,
                    end,
                    freq,
                );
                let base_e = base.e_total_j();
                let base_a = base.area_mm2;
                for (pi, &policy) in self.policies.iter().enumerate() {
                    for (bj, &banks) in self.cell_banks.iter().enumerate() {
                        let bi = self.bank_cell_offset + bj;
                        // The exact (B=1, no-gating) cell IS the
                        // reference; reuse it like the oracle does
                        // (identical by construction).
                        let eval = if banks == 1 && policy == GatingPolicy::None {
                            base.clone()
                        } else {
                            self.groups[row + bi].eval_lane(
                                pi,
                                &self.orgs[ci * nb + bi],
                                stats,
                                end,
                                freq,
                            )
                        };
                        out.push(SweepPoint {
                            eval,
                            base_e_j: base_e,
                            base_area_mm2: base_a,
                        });
                    }
                }
            }
        }
        out
    }

    /// Split the engine's ladder groups into up to `n` shards for
    /// thread-parallel traversal; reassemble with [`FusedSweep::reunite`].
    /// A group is never split — all of a (C, B, α) candidate family's
    /// state lives on exactly one shard.
    fn split(&mut self, n: usize) -> Vec<Vec<LadderGroup>> {
        let groups = std::mem::take(&mut self.groups);
        let per = groups.len().div_ceil(n.max(1));
        let mut shards: Vec<Vec<LadderGroup>> = Vec::new();
        let mut it = groups.into_iter().peekable();
        while it.peek().is_some() {
            shards.push(it.by_ref().take(per).collect());
        }
        shards
    }

    fn reunite(&mut self, shards: Vec<Vec<LadderGroup>>) {
        self.groups = shards.into_iter().flatten().collect();
    }
}

/// Work threshold (segments × candidates) above which the materialized
/// sweep shards groups across threads. Below it, spawn overhead
/// outweighs the win (~a quarter-million O(1) updates run in well under
/// a millisecond).
const PARALLEL_WORK_THRESHOLD: u128 = 1 << 18;

/// Fused implementation behind [`super::sweep::sweep`]: one traversal of
/// the (finalized) trace evaluates the whole grid, sharding ladder
/// groups across OS threads when the grid × trace product is large. The
/// shared org table is read-only during traversal, per-group results are
/// independent, and shards reassemble in chunk order, so the output is
/// byte-identical at any thread count.
///
/// Errors with [`EnergyError::UnfinalizedTrace`] instead of panicking
/// when the trace has no end time.
pub fn sweep_fused(
    cacti: &CactiModel,
    trace: &OccupancyTrace,
    stats: &AccessStats,
    spec: &SweepSpec,
    freq_ghz: f64,
) -> Result<Vec<SweepPoint>, EnergyError> {
    let Some(end) = trace.end_time() else {
        return Err(EnergyError::UnfinalizedTrace {
            memory: trace.memory.clone(),
        });
    };
    let peak = trace.peak_needed();
    // Pre-filter infeasible capacities: same outcome as the post-filter,
    // without paying traversal work for points that get dropped.
    let feasible = SweepSpec {
        capacities: spec
            .capacities
            .iter()
            .copied()
            .filter(|&c| c >= peak)
            .collect(),
        banks: spec.banks.clone(),
        alphas: spec.alphas.clone(),
        policies: spec.policies.clone(),
    };
    let mut engine = FusedSweep::new(cacti, &feasible, freq_ghz);

    let work = trace.samples().len() as u128 * engine.candidates() as u128;
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if work >= PARALLEL_WORK_THRESHOLD && threads > 1 && engine.groups.len() > 1 {
        // Shard whole groups across threads; each worker walks the trace
        // once over its shard, borrowing the shared org table read-only
        // (same scoped-spawn pattern as api::BatchRunner). Scope joins
        // every worker before returning.
        let mut shards = engine.split(threads.min(engine.groups.len()));
        let orgs = &engine.orgs;
        std::thread::scope(|scope| {
            for shard in &mut shards {
                scope.spawn(move || {
                    for seg in trace.segments() {
                        for g in shard.iter_mut() {
                            g.advance(seg.t0, seg.needed, &orgs[g.org].deciders);
                        }
                    }
                });
            }
        });
        engine.reunite(shards);
    } else {
        for seg in trace.segments() {
            engine.push_segment(seg.t0, seg.t1, seg.needed);
        }
    }
    engine.finish(end);
    Ok(engine.into_points(stats, peak))
}

/// Streaming Stage-II consumer: a [`TraceSink`] that runs the fused sweep
/// engine directly on the Stage-I occupancy stream of one memory, so
/// `Stage2Run`-equivalent results come out of a simulation that never
/// materialized a trace.
///
/// Sample semantics mirror [`OccupancyTrace::record`]: same-instant
/// updates overwrite (only the final state at an instant is observable),
/// and the state at `t` holds until the next sample. The sink also tracks
/// the peak needed bytes at *sample* granularity (zero-duration final
/// states included), so its feasibility filtering matches
/// `OccupancyTrace::peak_needed` exactly.
///
/// When to stream vs. materialize: stream when the trace exists only to
/// feed Stage II on a *known* grid (O(1) trace memory, one pass);
/// materialize when the grid derives from the observed peak, when the
/// trace itself is an artifact (CSV/JSON export, figures), or when
/// several differently-parameterized sweeps will reuse it.
pub struct SweepSink {
    engine: FusedSweep,
    /// Which announced memory to consume (0 = shared SRAM / KV arena).
    mem: usize,
    /// Pending state `(t, needed)` — committed when time advances.
    pending: (u64, u64),
    peak_needed: u64,
    finished: Option<u64>,
}

impl SweepSink {
    /// Sweep `spec` over the occupancy stream of memory index 0.
    pub fn new(cacti: &CactiModel, spec: &SweepSpec, freq_ghz: f64) -> Self {
        Self::for_memory(cacti, spec, freq_ghz, 0)
    }

    /// Sweep the stream of the `mem`-th announced memory.
    pub fn for_memory(
        cacti: &CactiModel,
        spec: &SweepSpec,
        freq_ghz: f64,
        mem: usize,
    ) -> Self {
        Self {
            engine: FusedSweep::new(cacti, spec, freq_ghz),
            mem,
            pending: (0, 0),
            peak_needed: 0,
            finished: None,
        }
    }

    /// Commit the pending state over `[pending.t, until)`.
    fn commit(&mut self, until: u64) {
        let (t, needed) = self.pending;
        self.peak_needed = self.peak_needed.max(needed);
        if until > t {
            self.engine.push_segment(t, until, needed);
        }
    }

    /// Peak needed bytes observed so far (sample granularity).
    pub fn peak_needed(&self) -> u64 {
        self.peak_needed
    }

    /// Finalize into sweep points (requires the stream to have finished).
    /// Grid capacities below the observed peak are dropped, exactly like
    /// [`super::sweep::sweep`] on the materialized trace.
    pub fn into_points(self, stats: &AccessStats) -> Vec<SweepPoint> {
        assert!(
            self.finished.is_some(),
            "SweepSink::into_points before the stream finished"
        );
        self.engine.into_points(stats, self.peak_needed)
    }
}

impl TraceSink for SweepSink {
    fn begin(&mut self, memories: &[MemoryDesc]) {
        assert!(
            self.mem < memories.len(),
            "SweepSink targets memory {} but the run announced {}",
            self.mem,
            memories.len()
        );
    }

    fn on_sample(&mut self, mem: usize, t: u64, needed: u64, _obsolete: u64) {
        if mem != self.mem {
            return;
        }
        debug_assert!(t >= self.pending.0, "stream time went backwards");
        if t > self.pending.0 {
            self.commit(t);
        }
        self.pending = (t, needed);
    }

    fn finish(&mut self, end: u64) {
        self.commit(end);
        self.engine.finish(end);
        self.finished = Some(end);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::banking::sweep::sweep_naive;
    use crate::util::rng::Rng;
    use crate::util::MIB;

    fn grid() -> SweepSpec {
        SweepSpec {
            capacities: vec![16 * MIB, 48 * MIB, 64 * MIB],
            banks: vec![1, 2, 4, 8, 16, 32],
            alphas: vec![0.9, 1.0],
            policies: vec![
                GatingPolicy::None,
                GatingPolicy::Aggressive,
                GatingPolicy::conservative(),
                GatingPolicy::drowsy(),
            ],
        }
    }

    fn stats() -> AccessStats {
        AccessStats {
            reads: 12_345_678,
            writes: 987_654,
            ..Default::default()
        }
    }

    fn random_trace(rng: &mut Rng, cap: u64) -> OccupancyTrace {
        let mut tr = OccupancyTrace::new("m", cap);
        let mut t = 0u64;
        for _ in 0..rng.range(1, 120) {
            t += rng.range(1, 50_000);
            // Mix zero-occupancy gaps in so gating triggers at every B.
            let needed = if rng.below(4) == 0 { 0 } else { rng.below(cap + 1) };
            tr.record(t, needed, 0);
        }
        tr.finalize(t + rng.range(1, 10_000));
        tr
    }

    fn assert_points_identical(fused: &[SweepPoint], naive: &[SweepPoint]) {
        assert_eq!(fused.len(), naive.len());
        for (f, n) in fused.iter().zip(naive) {
            assert_eq!(f.eval.capacity, n.eval.capacity);
            assert_eq!(f.eval.banks, n.eval.banks);
            assert_eq!(f.eval.alpha.to_bits(), n.eval.alpha.to_bits());
            assert_eq!(f.eval.policy, n.eval.policy);
            assert_eq!(f.eval.n_switch, n.eval.n_switch);
            assert_eq!(
                f.eval.gated_fraction.to_bits(),
                n.eval.gated_fraction.to_bits(),
                "gated_fraction at C={} B={} {:?}",
                n.eval.capacity,
                n.eval.banks,
                n.eval.policy
            );
            assert_eq!(
                f.eval.avg_active_banks.to_bits(),
                n.eval.avg_active_banks.to_bits()
            );
            assert_eq!(f.eval.e_dyn_j.to_bits(), n.eval.e_dyn_j.to_bits());
            assert_eq!(f.eval.e_leak_j.to_bits(), n.eval.e_leak_j.to_bits());
            assert_eq!(f.eval.e_sw_j.to_bits(), n.eval.e_sw_j.to_bits());
            assert_eq!(f.base_e_j.to_bits(), n.base_e_j.to_bits());
            assert_eq!(f.base_area_mm2.to_bits(), n.base_area_mm2.to_bits());
        }
    }

    #[test]
    fn fused_matches_naive_on_random_traces() {
        let cacti = CactiModel::default();
        crate::util::proptest::check("fused-vs-naive", 40, |rng| {
            let tr = random_trace(rng, 64 * MIB);
            let st = stats();
            let fused = sweep_fused(&cacti, &tr, &st, &grid(), 1.0).unwrap();
            let naive = sweep_naive(&cacti, &tr, &st, &grid(), 1.0).unwrap();
            assert_points_identical(&fused, &naive);
        });
    }

    #[test]
    fn fused_matches_naive_on_degenerate_traces() {
        let cacti = CactiModel::default();
        let st = AccessStats::default();
        // Zero-length trace.
        let mut empty = OccupancyTrace::new("m", 64 * MIB);
        empty.finalize(0);
        assert_points_identical(
            &sweep_fused(&cacti, &empty, &st, &grid(), 1.0).unwrap(),
            &sweep_naive(&cacti, &empty, &st, &grid(), 1.0).unwrap(),
        );
        // Constant occupancy with a zero-duration final sample that sets
        // the peak (feasibility filter must see it).
        let mut spike = OccupancyTrace::new("m", 64 * MIB);
        spike.record(5, 10 * MIB, 0);
        spike.record(100, 60 * MIB, 0);
        spike.finalize(100);
        assert_eq!(spike.peak_needed(), 60 * MIB);
        assert_points_identical(
            &sweep_fused(&cacti, &spike, &st, &grid(), 1.0).unwrap(),
            &sweep_naive(&cacti, &spike, &st, &grid(), 1.0).unwrap(),
        );
    }

    #[test]
    fn grid_without_bank_one_matches_naive() {
        // The ΔE/ΔA reference needs a B=1 ladder group even when the grid
        // omits B=1; the engine synthesizes one (single ungated lane).
        let cacti = CactiModel::default();
        let mut rng = Rng::new(11);
        let tr = random_trace(&mut rng, 64 * MIB);
        let spec = SweepSpec {
            capacities: vec![64 * MIB],
            banks: vec![2, 8, 32],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::None, GatingPolicy::Aggressive],
        };
        let st = stats();
        assert_points_identical(
            &sweep_fused(&cacti, &tr, &st, &spec, 1.0).unwrap(),
            &sweep_naive(&cacti, &tr, &st, &spec, 1.0).unwrap(),
        );
    }

    #[test]
    fn grid_without_none_policy_keeps_reference_lane() {
        // When the spec has no `None` policy, the B=1 organization grows
        // a trailing ungated lane so base_e_j/base_area_mm2 still exist.
        let cacti = CactiModel::default();
        let mut rng = Rng::new(12);
        let tr = random_trace(&mut rng, 64 * MIB);
        let spec = SweepSpec {
            capacities: vec![64 * MIB, 96 * MIB],
            banks: vec![1, 4],
            alphas: vec![0.9, 1.0],
            policies: vec![GatingPolicy::Aggressive, GatingPolicy::drowsy()],
        };
        let st = stats();
        assert_points_identical(
            &sweep_fused(&cacti, &tr, &st, &spec, 1.0).unwrap(),
            &sweep_naive(&cacti, &tr, &st, &spec, 1.0).unwrap(),
        );
    }

    #[test]
    fn ladder_bounds_match_eq1_over_random_needed() {
        // The band-boundary level lookup equals ceil(needed/usable)
        // clamped at B — including after arbitrary level history.
        let cacti = CactiModel::default();
        let org_src = FusedSweep::new(
            &cacti,
            &SweepSpec {
                capacities: vec![1000],
                banks: vec![7],
                alphas: vec![0.33],
                policies: vec![GatingPolicy::Aggressive],
            },
            1.0,
        );
        let mut g = org_src.groups[org_src.bank_cell_offset].clone();
        assert_eq!(g.banks, 7);
        let usable = g.usable_per_bank;
        assert!(usable > 0);
        let mut rng = Rng::new(5);
        for _ in 0..2000 {
            let needed = rng.below(3 * usable * 8 + 2);
            let want = if needed == 0 {
                0
            } else {
                ceil_div(needed, usable).min(g.banks as u64) as u32
            };
            let got = g.level_for(needed);
            assert_eq!(got, want, "needed={needed} from level={}", g.level);
            g.level = got; // exercise the band-delta fast path next round
        }
    }

    #[test]
    fn sink_matches_materialized_sweep() {
        let cacti = CactiModel::default();
        let mut rng = Rng::new(99);
        let tr = random_trace(&mut rng, 48 * MIB);
        let st = stats();
        let spec = grid();

        let mut sink = SweepSink::new(&cacti, &spec, 1.0);
        sink.begin(&[MemoryDesc {
            name: "m".to_string(),
            capacity: 48 * MIB,
        }]);
        for s in tr.samples() {
            sink.on_sample(0, s.t, s.needed, s.obsolete);
        }
        sink.finish(tr.end_time().unwrap());
        assert_eq!(sink.peak_needed(), tr.peak_needed());
        let streamed = sink.into_points(&st);
        let materialized = sweep_fused(&cacti, &tr, &st, &spec, 1.0).unwrap();
        assert_points_identical(&streamed, &materialized);
    }

    #[test]
    fn sink_overwrites_same_instant_and_ignores_other_memories() {
        let cacti = CactiModel::default();
        let spec = SweepSpec {
            capacities: vec![MIB],
            banks: vec![1, 2],
            alphas: vec![1.0],
            policies: vec![GatingPolicy::Aggressive],
        };
        let mems = [
            MemoryDesc { name: "a".into(), capacity: MIB },
            MemoryDesc { name: "b".into(), capacity: MIB },
        ];

        let mut sink = SweepSink::new(&cacti, &spec, 1.0);
        sink.begin(&mems);
        sink.on_sample(0, 10, MIB, 0); // transient, overwritten below
        sink.on_sample(0, 10, 1024, 0);
        sink.on_sample(1, 20, MIB, 0); // other memory: ignored
        sink.on_sample(0, 50_000, 0, 0);
        sink.finish(1_000_000);
        let streamed = sink.into_points(&AccessStats::default());

        let mut tr = OccupancyTrace::new("a", MIB);
        tr.record(10, MIB, 0);
        tr.record(10, 1024, 0);
        tr.record(50_000, 0, 0);
        tr.finalize(1_000_000);
        let reference = sweep_fused(&cacti, &tr, &AccessStats::default(), &spec, 1.0).unwrap();
        assert_points_identical(&streamed, &reference);
        // The transient MIB at t=10 never pinned the peak.
        assert_eq!(streamed[0].eval.capacity, MIB);
    }

    #[test]
    fn parallel_sharding_is_byte_identical() {
        // Force the threaded path: every capacity feasible (occupancy
        // stays below the smallest) and segments x candidates above the
        // work threshold.
        let cacti = CactiModel::default();
        let mut rng = Rng::new(7);
        let mut tr = OccupancyTrace::new("m", 64 * MIB);
        let mut t = 0u64;
        for _ in 0..20_000 {
            t += rng.range(1, 100);
            tr.record(t, rng.below(60 * MIB), 0);
        }
        tr.finalize(t + 1);
        let spec = SweepSpec {
            capacities: vec![64 * MIB, 80 * MIB, 96 * MIB, 112 * MIB],
            banks: vec![1, 2, 4, 8, 16, 32],
            alphas: vec![0.9, 1.0],
            policies: vec![
                GatingPolicy::Aggressive,
                GatingPolicy::conservative(),
                GatingPolicy::drowsy(),
            ],
        };
        let candidates = spec.points() + spec.capacities.len() * spec.alphas.len();
        let work = tr.samples().len() as u128 * candidates as u128;
        assert!(work >= PARALLEL_WORK_THRESHOLD, "work={work}");
        let st = stats();
        let fused = sweep_fused(&cacti, &tr, &st, &spec, 1.0).unwrap();
        let naive = sweep_naive(&cacti, &tr, &st, &spec, 1.0).unwrap();
        assert_points_identical(&fused, &naive);
    }

    #[test]
    fn characterization_hoisted_once_per_organization() {
        // Every α group of one (C, B) organization shares the same org
        // entry (and thus the same characterization and deciders).
        let cacti = CactiModel::default();
        let engine = FusedSweep::new(
            &cacti,
            &SweepSpec {
                capacities: vec![16 * MIB, 32 * MIB],
                banks: vec![1, 4],
                alphas: vec![0.5, 0.9, 1.0],
                policies: vec![GatingPolicy::Aggressive, GatingPolicy::drowsy()],
            },
            1.0,
        );
        assert_eq!(engine.orgs.len(), 2 * 2, "one org per (C, B)");
        assert_eq!(engine.groups.len(), 2 * 3 * 2, "one group per (C, B, α)");
        for g in &engine.groups {
            let org = &engine.orgs[g.org];
            assert_eq!(g.banks, org.banks);
            assert_eq!(org.ch, cacti.characterize(org.capacity, org.banks));
            assert_eq!(g.gated_cycles.len(), org.deciders.len());
            assert_eq!(g.n_switch.len(), org.deciders.len());
        }
    }
}
