//! Stage-II sweep harness: evaluate the full (capacity x banks x alpha x
//! policy) grid against a Stage-I trace — the generator behind Table II,
//! Table III, Fig. 8 and Fig. 9.

use crate::cacti::CactiModel;
use crate::trace::{AccessStats, OccupancyTrace};

use super::energy::{evaluate, BankingEval, EnergyError};
use super::policy::GatingPolicy;

/// Sweep grid specification. The paper's §IV-C setting is
/// `capacities = {peak..128 MiB step 16}`, `banks = {1,2,4,8,16,32}`,
/// `alpha = 0.9`, conservative-vs-aggressive policies.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    pub capacities: Vec<u64>,
    pub banks: Vec<u32>,
    pub alphas: Vec<f64>,
    pub policies: Vec<GatingPolicy>,
}

impl SweepSpec {
    /// The paper's Table II grid for a workload with the given minimum
    /// feasible capacity (16 MiB steps up to 128 MiB). Workloads whose
    /// peak already exceeds 128 MiB get a single-point grid at their
    /// rounded-up peak, so the grid is never empty.
    pub fn paper_grid(min_capacity: u64) -> Self {
        use crate::util::MIB;
        let mut capacities = Vec::new();
        let start = min_capacity.div_ceil(16 * MIB).max(1) * 16 * MIB;
        let mut c = start;
        while c <= 128 * MIB {
            capacities.push(c);
            c += 16 * MIB;
        }
        if capacities.is_empty() {
            capacities.push(start);
        }
        Self {
            capacities,
            banks: vec![1, 2, 4, 8, 16, 32],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive],
        }
    }

    pub fn points(&self) -> usize {
        self.capacities.len() * self.banks.len() * self.alphas.len() * self.policies.len()
    }
}

/// One grid point with its evaluation and the B=1 reference at the same
/// capacity/alpha/policy (for the paper's ΔE/ΔA columns).
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub eval: BankingEval,
    /// Energy of the unbanked (B=1, ungated) reference at this capacity.
    pub base_e_j: f64,
    pub base_area_mm2: f64,
}

impl SweepPoint {
    pub fn delta_e_pct(&self) -> f64 {
        pct_delta(self.eval.e_total_j(), self.base_e_j)
    }

    pub fn delta_a_pct(&self) -> f64 {
        pct_delta(self.eval.area_mm2, self.base_area_mm2)
    }
}

/// Relative delta in percent, guarded against a zero reference: a
/// zero-length trace with zero access statistics evaluates to zero base
/// energy, and an unguarded division would report NaN/inf instead of
/// "no change" (0%) downstream (`best_delta_pct` folds with `min`, so a
/// NaN would silently poison the headline metric).
fn pct_delta(value: f64, base: f64) -> f64 {
    if base == 0.0 {
        0.0
    } else {
        (value - base) / base * 100.0
    }
}

/// Run the sweep. The trace is capacity-agnostic (occupancy depends on
/// the schedule, not the candidate banking), exactly the decoupling the
/// paper's two-stage design exploits. Candidates whose capacity is below
/// the trace's peak needed bytes are skipped (infeasible).
///
/// Dispatches to the fused single-pass engine
/// ([`super::fused`]): one traversal of the occupancy trace evaluates
/// every grid point simultaneously, sharded across threads for large
/// grids. Differentially identical to [`sweep_naive`], the per-point
/// oracle it replaced.
///
/// Errors with [`EnergyError::UnfinalizedTrace`] instead of panicking
/// when the trace was never finalized.
pub fn sweep(
    cacti: &CactiModel,
    trace: &OccupancyTrace,
    stats: &AccessStats,
    spec: &SweepSpec,
    freq_ghz: f64,
) -> Result<Vec<SweepPoint>, EnergyError> {
    super::fused::sweep_fused(cacti, trace, stats, spec, freq_ghz)
}

/// The straightforward per-grid-point sweep: re-derives the bank-activity
/// timeline and per-bank idle intervals for every candidate
/// (O(grid × B × segments), one `Vec<ActivitySegment>` per point).
/// Kept as the differential oracle for the fused engine
/// (`tests/sweep_fused.rs`, the `stage2_sweep` bench) — production code
/// should call [`sweep`].
pub fn sweep_naive(
    cacti: &CactiModel,
    trace: &OccupancyTrace,
    stats: &AccessStats,
    spec: &SweepSpec,
    freq_ghz: f64,
) -> Result<Vec<SweepPoint>, EnergyError> {
    let peak = trace.peak_needed();
    let mut out = Vec::with_capacity(spec.points());
    for &cap in &spec.capacities {
        if cap < peak {
            continue; // infeasible: schedule would change (write-backs)
        }
        for &alpha in &spec.alphas {
            for &policy in &spec.policies {
                // B=1 ungated reference for ΔE/ΔA (paper Table II).
                let base = evaluate(
                    cacti,
                    trace,
                    stats,
                    cap,
                    1,
                    alpha,
                    GatingPolicy::None,
                    freq_ghz,
                )?;
                let base_e = base.e_total_j();
                let base_a = base.area_mm2;
                for &banks in &spec.banks {
                    // Every grid point — including B=1 — is evaluated
                    // under the *requested* policy: a single bank still
                    // has idle gaps a policy may act on (a lone drowsy
                    // bank is legal and saves leakage). Only the exact
                    // (B=1, no-gating) point can reuse the reference.
                    let eval = if banks == 1 && policy == GatingPolicy::None {
                        base.clone()
                    } else {
                        evaluate(cacti, trace, stats, cap, banks, alpha, policy, freq_ghz)?
                    };
                    out.push(SweepPoint {
                        eval,
                        base_e_j: base_e,
                        base_area_mm2: base_a,
                    });
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    fn synth_trace(cap: u64) -> OccupancyTrace {
        let mut tr = OccupancyTrace::new("sram", cap);
        let mut t = 0;
        while t < 100_000_000 {
            tr.record(t, 35 * MIB, 0);
            tr.record(t + 400_000, 8 * MIB, 0);
            t += 800_000;
        }
        tr.finalize(100_000_000);
        tr
    }

    fn stats() -> AccessStats {
        AccessStats {
            reads: 50_000_000,
            writes: 20_000_000,
            ..Default::default()
        }
    }

    #[test]
    fn paper_grid_shape() {
        let spec = SweepSpec::paper_grid(48 * MIB);
        assert_eq!(
            spec.capacities,
            vec![48, 64, 80, 96, 112, 128]
                .into_iter()
                .map(|c| c * MIB)
                .collect::<Vec<_>>()
        );
        assert_eq!(spec.banks, vec![1, 2, 4, 8, 16, 32]);
        assert_eq!(spec.points(), 36);
    }

    #[test]
    fn paper_grid_never_empty() {
        // Peaks beyond 128 MiB fall back to a single rounded-up point;
        // a zero peak starts at one 16 MiB step.
        let big = SweepSpec::paper_grid(300 * MIB);
        assert_eq!(big.capacities, vec![304 * MIB]);
        assert!(big.points() > 0);
        let zero = SweepSpec::paper_grid(0);
        assert_eq!(zero.capacities.first(), Some(&(16 * MIB)));
    }

    #[test]
    fn sweep_covers_grid_and_references_b1() {
        let tr = synth_trace(128 * MIB);
        let pts = sweep(
            &CactiModel::default(),
            &tr,
            &stats(),
            &SweepSpec::paper_grid(48 * MIB),
            1.0,
        ).unwrap();
        assert_eq!(pts.len(), 36);
        for p in &pts {
            if p.eval.banks == 1 {
                assert!((p.delta_e_pct()).abs() < 1e-9);
                assert!((p.delta_a_pct()).abs() < 1e-9);
            }
        }
        // The Table II qualitative claim: at every capacity the best bank
        // count gives a substantial reduction, and it is > 1 bank.
        for &cap in &[48 * MIB, 128 * MIB] {
            let best = pts
                .iter()
                .filter(|p| p.eval.capacity == cap)
                .min_by(|a, b| a.eval.e_total_j().total_cmp(&b.eval.e_total_j()))
                .unwrap();
            assert!(best.eval.banks >= 4, "best banks at {cap}: {}", best.eval.banks);
            assert!(best.delta_e_pct() < -20.0, "ΔE={}", best.delta_e_pct());
        }
    }

    #[test]
    fn zero_base_energy_yields_finite_deltas() {
        // Regression: a zero-length trace with zero access statistics
        // gives a B=1 reference energy of exactly 0 J; delta_e_pct used
        // to divide by it unguarded and return NaN.
        let mut tr = OccupancyTrace::new("sram", 64 * MIB);
        tr.finalize(0);
        let spec = SweepSpec {
            capacities: vec![16 * MIB],
            banks: vec![1, 4],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive],
        };
        let pts = sweep(
            &CactiModel::default(),
            &tr,
            &AccessStats::default(),
            &spec,
            1.0,
        ).unwrap();
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert_eq!(p.base_e_j, 0.0, "B=1 reference energy must be 0");
            assert!(p.delta_e_pct().is_finite(), "dE = {}", p.delta_e_pct());
            assert!(p.delta_a_pct().is_finite(), "dA = {}", p.delta_a_pct());
            assert_eq!(p.delta_e_pct(), 0.0);
        }
    }

    #[test]
    fn b1_point_carries_requested_policy_and_models_gating() {
        // Regression: the B=1 grid point used to reuse the ungated
        // reference wholesale, so `eval.policy` misstated the requested
        // policy and a lone gated/drowsy bank was never modeled. A trace
        // with long zero-occupancy gaps lets even a single bank gate.
        let mut tr = OccupancyTrace::new("sram", 64 * MIB);
        let mut t = 0;
        while t < 100_000_000 {
            tr.record(t, 20 * MIB, 0);
            tr.record(t + 100_000, 0, 0); // 900k-cycle idle tail
            t += 1_000_000;
        }
        tr.finalize(100_000_000);
        let spec = SweepSpec {
            capacities: vec![64 * MIB],
            banks: vec![1, 4],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive, GatingPolicy::drowsy()],
        };
        let pts = sweep(&CactiModel::default(), &tr, &stats(), &spec, 1.0).unwrap();
        assert_eq!(pts.len(), 4);
        for p in &pts {
            assert!(
                spec.policies.contains(&p.eval.policy),
                "emitted policy {:?} must be the requested one",
                p.eval.policy
            );
            if p.eval.banks == 1 {
                assert!(
                    p.eval.gated_fraction > 0.0,
                    "{:?}: a single bank must act on its idle gaps",
                    p.eval.policy
                );
                assert!(p.eval.n_switch > 0);
                assert!(p.delta_e_pct() < 0.0, "{:?}", p.eval.policy);
            }
        }
    }

    #[test]
    fn naive_oracle_matches_fused_dispatch() {
        let tr = synth_trace(128 * MIB);
        let spec = SweepSpec::paper_grid(48 * MIB);
        let fused = sweep(&CactiModel::default(), &tr, &stats(), &spec, 1.0).unwrap();
        let naive = sweep_naive(&CactiModel::default(), &tr, &stats(), &spec, 1.0).unwrap();
        assert_eq!(fused.len(), naive.len());
        for (a, b) in fused.iter().zip(&naive) {
            assert_eq!(a.eval.e_total_j().to_bits(), b.eval.e_total_j().to_bits());
            assert_eq!(a.eval.n_switch, b.eval.n_switch);
            assert_eq!(a.base_e_j.to_bits(), b.base_e_j.to_bits());
        }
    }

    #[test]
    fn infeasible_capacities_skipped() {
        let tr = synth_trace(128 * MIB); // peak 35 MiB
        let spec = SweepSpec {
            capacities: vec![16 * MIB, 64 * MIB],
            banks: vec![1, 4],
            alphas: vec![0.9],
            policies: vec![GatingPolicy::Aggressive],
        };
        let pts = sweep(&CactiModel::default(), &tr, &stats(), &spec, 1.0).unwrap();
        assert!(pts.iter().all(|p| p.eval.capacity == 64 * MIB));
    }

    #[test]
    fn area_monotone_in_banks_at_fixed_capacity() {
        let tr = synth_trace(128 * MIB);
        let pts = sweep(
            &CactiModel::default(),
            &tr,
            &stats(),
            &SweepSpec::paper_grid(64 * MIB),
            1.0,
        ).unwrap();
        for w in pts
            .iter()
            .filter(|p| p.eval.capacity == 64 * MIB)
            .collect::<Vec<_>>()
            .windows(2)
        {
            assert!(w[1].eval.area_mm2 >= w[0].eval.area_mm2);
        }
    }

    #[test]
    fn unfinalized_trace_errors_on_both_sweep_paths() {
        // Regression: both the fused dispatch and the naive oracle used
        // to panic inside evaluate / segments() on unfinalized traces.
        let tr = OccupancyTrace::new("sram", 64 * MIB); // no finalize
        let spec = SweepSpec::paper_grid(16 * MIB);
        let fused = sweep(&CactiModel::default(), &tr, &stats(), &spec, 1.0);
        let naive = sweep_naive(&CactiModel::default(), &tr, &stats(), &spec, 1.0);
        for r in [fused, naive] {
            let err = r.unwrap_err();
            assert_eq!(
                err,
                EnergyError::UnfinalizedTrace {
                    memory: "sram".to_string()
                }
            );
        }
    }
}
