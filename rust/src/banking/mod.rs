//! Stage II: offline SRAM banking and power-gating exploration driven by
//! Stage-I occupancy traces (paper §III-B, Eqs. 1-5).
//!
//! Grid sweeps run through the fused single-pass engine ([`fused`]): one
//! traversal of the trace (or of the live Stage-I stream, via
//! [`SweepSink`]) evaluates every (C, B, α, policy) candidate at once.
//! The per-point path survives as [`sweep_naive`], the differential
//! oracle.

pub mod activity;
pub mod energy;
pub mod fused;
pub mod optimize;
pub mod policy;
pub mod sweep;

pub use activity::{
    avg_active, bank_activity, banks_required, idle_intervals, ActivitySegment,
    OccupancyBasis,
};
pub use energy::{evaluate, BankingEval, EnergyError};
pub use fused::{sweep_fused, FusedSweep, SweepSink};
pub use optimize::{
    optimize, pareto_frontier, ConfigKey, Constraints, FrontierPoint,
    OptimizeError, OptimizeResult, PortfolioEntry, WorkloadFrontier,
    WorkloadSweep,
};
pub use policy::{GateDecider, GatingPolicy};
pub use sweep::{sweep, sweep_naive, SweepPoint, SweepSpec};
