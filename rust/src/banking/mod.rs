//! Stage II & III: SRAM banking and power-gating exploration driven by
//! Stage-I occupancy traces (paper §III-B, Eqs. 1-5).
//!
//! Grid sweeps run through the fused single-pass engine ([`fused`]): one
//! traversal of the trace (or of the live Stage-I stream, via
//! [`SweepSink`]) evaluates every (C, B, α, policy) candidate at once.
//! The per-point path survives as [`sweep_naive`], the differential
//! oracle. [`optimize`](mod@crate::banking::optimize) chooses among the
//! evaluated candidates (constraints → ε-Pareto frontier → cross-workload
//! regret portfolio), and [`online`] closes the loop with a Stage-III
//! execution-driven co-simulation of one chosen configuration, feeding
//! wake-latency stalls back into timing — the effect the offline model
//! can only bound.
//!
//! ```
//! use trapti::api::{ApiContext, ExperimentSpec};
//! use trapti::workload::TINY_GQA;
//!
//! // Spec-build → Stage I → Stage II on the paper grid derived from the
//! // observed peak (tiny preset, runs in milliseconds).
//! let ctx = ApiContext::new();
//! let spec = ExperimentSpec::builder()
//!     .model(TINY_GQA)
//!     .prefill(64)
//!     .accel(trapti::config::tiny())
//!     .build()
//!     .unwrap();
//! let s1 = spec.run_stage1(&ctx).unwrap();
//! let s2 = s1.stage2(&ctx).unwrap();
//! assert!(!s2.shared().is_empty());
//! assert!(s2.best_delta_pct() <= 0.0, "banking+gating never hurts");
//! ```

pub mod activity;
pub mod energy;
pub mod fused;
pub mod hierarchy;
pub mod online;
pub mod optimize;
pub mod policy;
pub mod sweep;

pub use activity::{
    avg_active, bank_activity, banks_required, idle_intervals, ActivitySegment,
    OccupancyBasis,
};
pub use energy::{evaluate, BankingEval, EnergyError};
pub use fused::{sweep_fused, FusedSweep, SweepSink};
pub use hierarchy::{
    replay_hierarchy, sweep_hierarchy, HierarchyConfig, HierarchyPoint,
    HierarchyReplay, L2Charge, DEFAULT_MIGRATE_ENERGY_PER_BYTE_J,
};
pub use online::{
    replay_trace, replay_trace_with, BankState, OnlineConfig, OnlineError,
    OnlineGateSim, OnlineReport, StateSpan,
};
pub use optimize::{
    optimize, pareto_frontier, ConfigKey, Constraints, FrontierPoint,
    OptimizeError, OptimizeResult, PortfolioEntry, WorkloadFrontier,
    WorkloadSweep,
};
pub use policy::{GateDecider, GatingPolicy};
pub use sweep::{sweep, sweep_naive, SweepPoint, SweepSpec};
