//! Stage II: offline SRAM banking and power-gating exploration driven by
//! Stage-I occupancy traces (paper §III-B, Eqs. 1-5).

pub mod activity;
pub mod energy;
pub mod policy;
pub mod sweep;

pub use activity::{
    avg_active, bank_activity, banks_required, idle_intervals, ActivitySegment,
    OccupancyBasis,
}; 
pub use energy::{evaluate, BankingEval};
pub use policy::GatingPolicy;
pub use sweep::{sweep, SweepPoint, SweepSpec};
