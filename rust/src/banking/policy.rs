//! Power-gating policies (paper Fig. 3, Stage II): decide, per idle
//! interval of a bank, whether to gate it off.
//!
//! Gating an interval of duration `dt` saves `P_leak_bank * dt` but costs
//! one off+on transition pair (`2 * E_switch`) and a wake-up latency; the
//! standard break-even criterion (paper §II-B, [14][15]) gates only when
//! the saving exceeds the cost.

use crate::cacti::SramCharacterization;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GatingPolicy {
    /// No power gating: every bank leaks for the whole run (the Table II
    /// baseline against which ΔE is reported at each B... and the only
    /// option at B=1).
    None,
    /// Gate every idle interval that passes break-even (alpha = 1.0 in
    /// the paper's aggressive setting — alpha is applied upstream in the
    /// activity mapping; the policy itself is identical).
    Aggressive,
    /// Reserve headroom *and* skip short idle intervals: gate only
    /// intervals at least `min_idle_factor` times the break-even
    /// duration, avoiding rapid on/off thrash on short dips.
    Conservative { min_idle_factor: f64 },
    /// Drowsy retention (paper §II-B, Flautner et al. [12]): idle banks
    /// drop to a reduced-leakage state that RETAINS data — leakage
    /// scales by `retention_factor` (~0.25 at 45 nm) instead of
    /// vanishing, but transitions are cheap enough to take on *every*
    /// idle interval (no break-even constraint) and wake-up is a single
    /// cycle. The paper lists richer low-power-mode models as future
    /// work; this implements that extension.
    Drowsy { retention_factor: f64 },
}

impl GatingPolicy {
    pub fn conservative() -> Self {
        GatingPolicy::Conservative {
            min_idle_factor: 4.0,
        }
    }

    pub fn drowsy() -> Self {
        GatingPolicy::Drowsy {
            retention_factor: 0.25,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            GatingPolicy::None => "no-gating",
            GatingPolicy::Aggressive => "aggressive",
            GatingPolicy::Conservative { .. } => "conservative",
            GatingPolicy::Drowsy { .. } => "drowsy",
        }
    }

    /// Fraction of nominal leakage an idle interval still pays when this
    /// policy acts on it (0.0 = fully gated, 1.0 = no action).
    pub fn idle_leak_factor(&self) -> f64 {
        match *self {
            GatingPolicy::Drowsy { retention_factor } => retention_factor,
            GatingPolicy::None => 1.0,
            _ => 0.0,
        }
    }

    /// Break-even idle duration in cycles for this SRAM organization:
    /// gate iff `P_leak * dt > 2 * E_switch`, i.e.
    /// `dt > 2 * E_switch / P_leak` (plus wake-up latency, which must be
    /// hidden inside the interval).
    pub fn break_even_cycles(ch: &SramCharacterization, freq_ghz: f64) -> u64 {
        if ch.p_leak_bank_w <= 0.0 {
            return u64::MAX;
        }
        let seconds = 2.0 * ch.e_switch_j / ch.p_leak_bank_w;
        let cycles = seconds * freq_ghz * 1e9;
        (cycles.ceil() as u64).saturating_add(ch.wake_cycles)
    }

    /// Should an idle interval of `dt` cycles be gated?
    pub fn should_gate(&self, dt: u64, ch: &SramCharacterization, freq_ghz: f64) -> bool {
        let be = Self::break_even_cycles(ch, freq_ghz);
        match *self {
            GatingPolicy::None => false,
            GatingPolicy::Aggressive => dt > be,
            GatingPolicy::Conservative { min_idle_factor } => {
                dt as f64 > be as f64 * min_idle_factor
            }
            // Drowsy entry/exit is ~free: act on any idle interval
            // longer than its one-cycle wake-up.
            GatingPolicy::Drowsy { .. } => dt > 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cacti::CactiModel;
    use crate::util::MIB;

    fn ch() -> SramCharacterization {
        CactiModel::default().characterize(64 * MIB, 8)
    }

    #[test]
    fn break_even_is_finite_and_sane() {
        let be = GatingPolicy::break_even_cycles(&ch(), 1.0);
        // ~2*1.6uJ / 2.2W = ~1.5us -> ~1500 cycles + wake.
        assert!(be > 100 && be < 100_000, "be={be}");
    }

    #[test]
    fn none_never_gates() {
        assert!(!GatingPolicy::None.should_gate(u64::MAX / 2, &ch(), 1.0));
    }

    #[test]
    fn aggressive_gates_past_break_even() {
        let be = GatingPolicy::break_even_cycles(&ch(), 1.0);
        assert!(!GatingPolicy::Aggressive.should_gate(be, &ch(), 1.0));
        assert!(GatingPolicy::Aggressive.should_gate(be + 1, &ch(), 1.0));
    }

    #[test]
    fn conservative_requires_longer_idles() {
        let be = GatingPolicy::break_even_cycles(&ch(), 1.0);
        let cons = GatingPolicy::conservative();
        assert!(!cons.should_gate(be * 2, &ch(), 1.0));
        assert!(cons.should_gate(be * 5, &ch(), 1.0));
    }

    #[test]
    fn labels() {
        assert_eq!(GatingPolicy::None.label(), "no-gating");
        assert_eq!(GatingPolicy::Aggressive.label(), "aggressive");
        assert_eq!(GatingPolicy::conservative().label(), "conservative");
        assert_eq!(GatingPolicy::drowsy().label(), "drowsy");
    }

    #[test]
    fn drowsy_acts_on_short_intervals_but_retains_leakage() {
        let d = GatingPolicy::drowsy();
        let be = GatingPolicy::break_even_cycles(&ch(), 1.0);
        assert!(d.should_gate(be / 10, &ch(), 1.0), "no break-even gate");
        assert!((d.idle_leak_factor() - 0.25).abs() < 1e-12);
        assert_eq!(GatingPolicy::Aggressive.idle_leak_factor(), 0.0);
        assert_eq!(GatingPolicy::None.idle_leak_factor(), 1.0);
    }
}
