//! Power-gating policies (paper Fig. 3, Stage II): decide, per idle
//! interval of a bank, whether to gate it off.
//!
//! Gating an interval of duration `dt` saves `P_leak_bank * dt` but costs
//! one off+on transition pair (`2 * E_switch`) and a wake-up latency; the
//! standard break-even criterion (paper §II-B, [14][15]) gates only when
//! the saving exceeds the cost.

use crate::cacti::SramCharacterization;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GatingPolicy {
    /// No power gating: every bank leaks for the whole run (the Table II
    /// baseline against which ΔE is reported at each B... and the only
    /// option at B=1).
    None,
    /// Gate every idle interval that passes break-even (alpha = 1.0 in
    /// the paper's aggressive setting — alpha is applied upstream in the
    /// activity mapping; the policy itself is identical).
    Aggressive,
    /// Reserve headroom *and* skip short idle intervals: gate only
    /// intervals at least `min_idle_factor` times the break-even
    /// duration, avoiding rapid on/off thrash on short dips.
    Conservative { min_idle_factor: f64 },
    /// Drowsy retention (paper §II-B, Flautner et al. [12]): idle banks
    /// drop to a reduced-leakage state that RETAINS data — leakage
    /// scales by `retention_factor` (~0.25 at 45 nm) instead of
    /// vanishing, but transitions are cheap enough to take on *every*
    /// idle interval (no break-even constraint) and wake-up is a single
    /// cycle. The paper lists richer low-power-mode models as future
    /// work; this implements that extension.
    Drowsy { retention_factor: f64 },
}

impl GatingPolicy {
    pub fn conservative() -> Self {
        GatingPolicy::Conservative {
            min_idle_factor: 4.0,
        }
    }

    pub fn drowsy() -> Self {
        GatingPolicy::Drowsy {
            retention_factor: 0.25,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            GatingPolicy::None => "no-gating",
            GatingPolicy::Aggressive => "aggressive",
            GatingPolicy::Conservative { .. } => "conservative",
            GatingPolicy::Drowsy { .. } => "drowsy",
        }
    }

    /// Fraction of nominal leakage an idle interval still pays when this
    /// policy acts on it (0.0 = fully gated, 1.0 = no action).
    pub fn idle_leak_factor(&self) -> f64 {
        match *self {
            GatingPolicy::Drowsy { retention_factor } => retention_factor,
            GatingPolicy::None => 1.0,
            _ => 0.0,
        }
    }

    /// Pure *energy* break-even idle duration in cycles: the point where
    /// the leakage saved equals the transition cost, `dt` such that
    /// `P_leak * dt = 2 * E_switch`. Wake-up latency is NOT folded in —
    /// policies add it on top (it must be hidden inside the interval,
    /// but it is a latency constraint, not an energy multiple).
    pub fn energy_break_even_cycles(ch: &SramCharacterization, freq_ghz: f64) -> u64 {
        if ch.p_leak_bank_w <= 0.0 {
            return u64::MAX;
        }
        let seconds = 2.0 * ch.e_switch_j / ch.p_leak_bank_w;
        let cycles = seconds * freq_ghz * 1e9;
        cycles.ceil() as u64
    }

    /// Break-even idle duration in cycles for this SRAM organization:
    /// gate iff `P_leak * dt > 2 * E_switch`, i.e.
    /// `dt > 2 * E_switch / P_leak` (plus wake-up latency, which must be
    /// hidden inside the interval).
    pub fn break_even_cycles(ch: &SramCharacterization, freq_ghz: f64) -> u64 {
        Self::energy_break_even_cycles(ch, freq_ghz).saturating_add(ch.wake_cycles)
    }

    /// Precompute the gate decision for this policy on one SRAM
    /// organization. The fused sweep engine and `should_gate` share this
    /// single code path, so their per-interval decisions can never drift.
    pub fn decider(&self, ch: &SramCharacterization, freq_ghz: f64) -> GateDecider {
        match *self {
            GatingPolicy::None => GateDecider::Never,
            GatingPolicy::Aggressive => {
                GateDecider::MinExclusive(Self::break_even_cycles(ch, freq_ghz))
            }
            // `min_idle_factor` scales the *energy* break-even only; the
            // wake-up latency is a fixed add-on, not something thrash
            // avoidance should multiply (that over-penalized wake-heavy
            // organizations at high factors).
            GatingPolicy::Conservative { min_idle_factor } => GateDecider::MinExclusiveF(
                min_idle_factor
                    * Self::energy_break_even_cycles(ch, freq_ghz) as f64
                    + ch.wake_cycles as f64,
            ),
            // Drowsy entry/exit is ~free: act on any idle interval
            // longer than its one-cycle wake-up.
            GatingPolicy::Drowsy { .. } => GateDecider::MinExclusive(1),
        }
    }

    /// Should an idle interval of `dt` cycles be gated?
    pub fn should_gate(&self, dt: u64, ch: &SramCharacterization, freq_ghz: f64) -> bool {
        self.decider(ch, freq_ghz).gate(dt)
    }

    /// Wake-up latency a bank pays when this policy re-activates it:
    /// the organization's full power-rail wake for true gating, a single
    /// cycle for drowsy retention (voltage step, no rail collapse), and
    /// zero for `None` (nothing is ever turned off). This is the latency
    /// the Stage-III online co-simulation
    /// ([`crate::banking::online::OnlineGateSim`]) replays by default.
    pub fn wake_latency_cycles(&self, ch: &SramCharacterization) -> u64 {
        match self {
            GatingPolicy::None => 0,
            GatingPolicy::Drowsy { .. } => 1,
            _ => ch.wake_cycles,
        }
    }
}

/// Resolved per-(policy, organization, frequency) gating rule: an idle
/// interval is gated iff its duration clears the threshold. Copy-sized so
/// the fused sweep engine can hold one per candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GateDecider {
    Never,
    /// Gate iff `dt > threshold` (integer cycles).
    MinExclusive(u64),
    /// Gate iff `dt as f64 > threshold` (fractional break-even multiple).
    MinExclusiveF(f64),
}

impl GateDecider {
    /// Resolve one decider per policy for a single SRAM organization.
    /// The decision thresholds depend only on (policy, organization,
    /// frequency) — not on α — so the fused sweep engine hoists this to
    /// once per (C, B) and shares the slice across every α group and the
    /// whole trace traversal.
    pub fn for_policies(
        policies: &[GatingPolicy],
        ch: &SramCharacterization,
        freq_ghz: f64,
    ) -> Vec<GateDecider> {
        policies.iter().map(|p| p.decider(ch, freq_ghz)).collect()
    }

    #[inline]
    pub fn gate(&self, dt: u64) -> bool {
        match *self {
            GateDecider::Never => false,
            GateDecider::MinExclusive(thr) => dt > thr,
            GateDecider::MinExclusiveF(thr) => dt as f64 > thr,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cacti::CactiModel;
    use crate::util::MIB;

    fn ch() -> SramCharacterization {
        CactiModel::default().characterize(64 * MIB, 8)
    }

    #[test]
    fn break_even_is_finite_and_sane() {
        let be = GatingPolicy::break_even_cycles(&ch(), 1.0);
        // ~2*1.6uJ / 2.2W = ~1.5us -> ~1500 cycles + wake.
        assert!(be > 100 && be < 100_000, "be={be}");
    }

    #[test]
    fn none_never_gates() {
        assert!(!GatingPolicy::None.should_gate(u64::MAX / 2, &ch(), 1.0));
    }

    #[test]
    fn aggressive_gates_past_break_even() {
        let be = GatingPolicy::break_even_cycles(&ch(), 1.0);
        assert!(!GatingPolicy::Aggressive.should_gate(be, &ch(), 1.0));
        assert!(GatingPolicy::Aggressive.should_gate(be + 1, &ch(), 1.0));
    }

    #[test]
    fn conservative_requires_longer_idles() {
        let be = GatingPolicy::break_even_cycles(&ch(), 1.0);
        let cons = GatingPolicy::conservative();
        assert!(!cons.should_gate(be * 2, &ch(), 1.0));
        assert!(cons.should_gate(be * 5, &ch(), 1.0));
    }

    #[test]
    fn conservative_scales_energy_break_even_not_wake() {
        // Regression: the factor used to multiply the *whole* break-even
        // (which already folds in wake_cycles), over-penalizing wake
        // latency at high factors. The threshold is
        // `factor * energy_break_even + wake`.
        let ch = ch();
        let energy_be = GatingPolicy::energy_break_even_cycles(&ch, 1.0);
        assert!(ch.wake_cycles > 0, "organization must have wake latency");
        let factor = 4.0;
        let cons = GatingPolicy::Conservative {
            min_idle_factor: factor,
        };
        let threshold = (factor * energy_be as f64) as u64 + ch.wake_cycles;
        assert!(!cons.should_gate(threshold, &ch, 1.0));
        assert!(cons.should_gate(threshold + 1, &ch, 1.0));
        // The old (buggy) threshold was strictly larger; a dt between the
        // two must now gate.
        let old_threshold = ((energy_be + ch.wake_cycles) as f64 * factor) as u64;
        assert!(old_threshold > threshold);
        assert!(cons.should_gate(old_threshold, &ch, 1.0));
    }

    #[test]
    fn break_even_splits_into_energy_plus_wake() {
        let ch = ch();
        assert_eq!(
            GatingPolicy::break_even_cycles(&ch, 1.0),
            GatingPolicy::energy_break_even_cycles(&ch, 1.0) + ch.wake_cycles
        );
    }

    #[test]
    fn decider_matches_should_gate_for_every_policy() {
        let ch = ch();
        let policies = [
            GatingPolicy::None,
            GatingPolicy::Aggressive,
            GatingPolicy::conservative(),
            GatingPolicy::drowsy(),
        ];
        let be = GatingPolicy::break_even_cycles(&ch, 1.0);
        for p in policies {
            let d = p.decider(&ch, 1.0);
            for dt in [0, 1, 2, be / 2, be, be + 1, be * 4, be * 4 + 101, be * 10] {
                assert_eq!(d.gate(dt), p.should_gate(dt, &ch, 1.0), "{p:?} dt={dt}");
            }
        }
    }

    #[test]
    fn for_policies_matches_each_decider() {
        let ch = ch();
        let policies = [
            GatingPolicy::None,
            GatingPolicy::Aggressive,
            GatingPolicy::conservative(),
            GatingPolicy::drowsy(),
        ];
        let shared = GateDecider::for_policies(&policies, &ch, 1.0);
        assert_eq!(shared.len(), policies.len());
        for (p, d) in policies.iter().zip(&shared) {
            assert_eq!(*d, p.decider(&ch, 1.0), "{p:?}");
        }
    }

    #[test]
    fn labels() {
        assert_eq!(GatingPolicy::None.label(), "no-gating");
        assert_eq!(GatingPolicy::Aggressive.label(), "aggressive");
        assert_eq!(GatingPolicy::conservative().label(), "conservative");
        assert_eq!(GatingPolicy::drowsy().label(), "drowsy");
    }

    #[test]
    fn drowsy_acts_on_short_intervals_but_retains_leakage() {
        let d = GatingPolicy::drowsy();
        let be = GatingPolicy::break_even_cycles(&ch(), 1.0);
        assert!(d.should_gate(be / 10, &ch(), 1.0), "no break-even gate");
        assert!((d.idle_leak_factor() - 0.25).abs() < 1e-12);
        assert_eq!(GatingPolicy::Aggressive.idle_leak_factor(), 0.0);
        assert_eq!(GatingPolicy::None.idle_leak_factor(), 1.0);
    }
}
