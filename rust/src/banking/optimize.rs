//! Stage-II Pareto optimizer with cross-workload robust selection.
//!
//! The sweep ([`super::sweep`](mod@super::sweep)) *evaluates* every (C, B, α, policy)
//! candidate; this module *chooses* among them — the missing half of the
//! paper's offline optimization flow. Three passes:
//!
//! 1. **Constraint filtering** ([`Constraints`]): drop candidates that
//!    violate a maximum area overhead (ΔA% vs the unbanked reference), a
//!    maximum wake-latency exposure (gated-interval wake-ups as a share
//!    of the run), or a minimum capacity.
//! 2. **ε-dominance Pareto frontier** ([`pareto_frontier`]) over the
//!    three objectives (energy `E_tot`, activity/latency proxy
//!    `avg_active_banks`, area `area_mm2` — all minimized). ε = 0 is the
//!    exact frontier; ε > 0 thins near-duplicates (a point survives only
//!    if no other point is within a factor `1+ε` of beating it on every
//!    objective).
//! 3. **Portfolio selection** ([`optimize`]): score every configuration
//!    that is feasible on *all* supplied workloads by its per-workload
//!    energy regret vs that workload's own optimum, and rank by
//!    worst-case regret (tie-broken by weighted-mean regret, then by
//!    config identity). The top entry is the *robust-best* configuration
//!    — the concrete artifact behind the paper's observation that the
//!    MHA-vs-GQA occupancy gap (2.72x peak) yields *different optimal
//!    configurations* per workload.
//!
//! Everything here is deterministic: candidate order is canonicalized by
//! total-order float comparison before any frontier or portfolio pass,
//! so equal inputs produce byte-identical reports.

use std::collections::BTreeMap;
use std::fmt;

use crate::util::MIB;

use super::policy::GatingPolicy;
use super::sweep::SweepPoint;

/// One workload's evaluated sweep, as fed to the optimizer. `end_cycles`
/// is the Stage-I run length (for wake-exposure accounting); `points`
/// comes from [`super::sweep::sweep`] (or the streamed
/// [`super::fused::SweepSink`]) — the optimizer never re-walks a trace.
#[derive(Debug, Clone)]
pub struct WorkloadSweep {
    pub name: String,
    pub end_cycles: u64,
    pub points: Vec<SweepPoint>,
}

/// Constraint filter applied before the frontier / portfolio passes.
/// `None` fields are unconstrained.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Constraints {
    /// Maximum banking area overhead, percent vs the unbanked (B=1)
    /// reference at the same capacity (`SweepPoint::delta_a_pct`).
    pub max_area_overhead_pct: Option<f64>,
    /// Maximum wake-latency exposure, percent of the run spent waking
    /// gated banks ([`wake_exposure_pct`]).
    pub max_wake_exposure_pct: Option<f64>,
    /// Minimum SRAM capacity in bytes (e.g. a functional floor from the
    /// sizing loop).
    pub min_capacity: Option<u64>,
}

impl Constraints {
    /// Does `point` survive the filter for a run of `end_cycles`?
    pub fn admits(&self, point: &SweepPoint, end_cycles: u64) -> bool {
        if let Some(min) = self.min_capacity {
            if point.eval.capacity < min {
                return false;
            }
        }
        if let Some(max) = self.max_area_overhead_pct {
            if point.delta_a_pct() > max {
                return false;
            }
        }
        if let Some(max) = self.max_wake_exposure_pct {
            if wake_exposure_pct(point, end_cycles) > max {
                return false;
            }
        }
        true
    }
}

/// Wake-latency exposure of a candidate: every gated interval pays the
/// organization's `wake_cycles` when its bank powers back on
/// (`n_switch / 2` intervals), expressed as a percentage of the run.
/// Zero-length runs report 0 (nothing was ever gated).
pub fn wake_exposure_pct(point: &SweepPoint, end_cycles: u64) -> f64 {
    if end_cycles == 0 {
        return 0.0;
    }
    let wakeups = point.eval.n_switch / 2;
    let wake_cycles = wakeups * point.eval.characterization.wake_cycles;
    wake_cycles as f64 / end_cycles as f64 * 100.0
}

/// Typed optimizer error.
#[derive(Debug, Clone, PartialEq)]
pub enum OptimizeError {
    /// `optimize` was called with no workloads.
    NoWorkloads,
    /// ε must be finite and >= 0.
    InvalidEpsilon(f64),
    /// Weights must match the workload count and sum to a positive value.
    InvalidWeights(String),
    /// A workload's sweep has no candidate surviving the constraints
    /// (or its sweep was empty to begin with).
    NoFeasibleConfigs { workload: String },
    /// No configuration is feasible on every supplied workload, so a
    /// portfolio cannot be selected (typically a grid whose capacities
    /// don't reach the largest workload's peak).
    NoSharedConfigs,
}

impl fmt::Display for OptimizeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OptimizeError::NoWorkloads => {
                write!(f, "optimize needs at least one workload sweep")
            }
            OptimizeError::InvalidEpsilon(e) => {
                write!(f, "epsilon must be finite and >= 0 (got {e})")
            }
            OptimizeError::InvalidWeights(why) => write!(f, "invalid weights: {why}"),
            OptimizeError::NoFeasibleConfigs { workload } => write!(
                f,
                "workload `{workload}` has no candidate satisfying the \
                 constraints (check the grid covers its peak and the \
                 constraint bounds are attainable)"
            ),
            OptimizeError::NoSharedConfigs => write!(
                f,
                "no configuration is feasible on every workload; widen the \
                 grid so its capacities cover the largest workload's peak"
            ),
        }
    }
}

impl std::error::Error for OptimizeError {}

/// Canonical identity of a (C, B, α, policy) configuration across
/// workloads. Floats are keyed by their bit patterns, so the key is
/// total-ordered and hashable while staying exactly faithful to the
/// grid's values.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ConfigKey {
    pub capacity: u64,
    pub banks: u32,
    alpha_bits: u64,
    policy_kind: u8,
    policy_param_bits: u64,
}

impl ConfigKey {
    pub fn of(point: &SweepPoint) -> Self {
        let (policy_kind, policy_param_bits) = match point.eval.policy {
            GatingPolicy::None => (0, 0),
            GatingPolicy::Aggressive => (1, 0),
            GatingPolicy::Conservative { min_idle_factor } => {
                (2, min_idle_factor.to_bits())
            }
            GatingPolicy::Drowsy { retention_factor } => {
                (3, retention_factor.to_bits())
            }
        };
        Self {
            capacity: point.eval.capacity,
            banks: point.eval.banks,
            alpha_bits: point.eval.alpha.to_bits(),
            policy_kind,
            policy_param_bits,
        }
    }

    pub fn alpha(&self) -> f64 {
        f64::from_bits(self.alpha_bits)
    }

    pub fn policy(&self) -> GatingPolicy {
        match self.policy_kind {
            0 => GatingPolicy::None,
            1 => GatingPolicy::Aggressive,
            2 => GatingPolicy::Conservative {
                min_idle_factor: f64::from_bits(self.policy_param_bits),
            },
            _ => GatingPolicy::Drowsy {
                retention_factor: f64::from_bits(self.policy_param_bits),
            },
        }
    }

    /// Compact deterministic label, e.g. `64MiB/B8/a0.90/aggressive`.
    pub fn label(&self) -> String {
        config_label(self.capacity, self.banks, self.alpha(), self.policy())
    }
}

/// The one deterministic config-label format, e.g.
/// `64MiB/B8/a0.90/aggressive` — shared by [`ConfigKey::label`] and
/// `banking::online::OnlineConfig::label` so Stage-II and Stage-III
/// artifacts can never drift apart.
pub(crate) fn config_label(
    capacity: u64,
    banks: u32,
    alpha: f64,
    policy: GatingPolicy,
) -> String {
    format!(
        "{}MiB/B{}/a{:.2}/{}",
        capacity / MIB,
        banks,
        alpha,
        policy.label(),
    )
}

/// One frontier member with its derived wake exposure.
#[derive(Debug, Clone)]
pub struct FrontierPoint {
    pub point: SweepPoint,
    pub wake_exposure_pct: f64,
}

/// Per-workload optimizer output: the constraint-feasible candidate
/// count, the ε-Pareto frontier (canonical order: energy, then activity,
/// then area), and the workload's own energy optimum (the portfolio
/// regret reference).
#[derive(Debug, Clone)]
pub struct WorkloadFrontier {
    pub workload: String,
    pub end_cycles: u64,
    /// Candidates surviving the constraint filter.
    pub feasible: usize,
    pub frontier: Vec<FrontierPoint>,
    /// Lowest total energy among feasible candidates, joules.
    pub best_energy_j: f64,
    /// Identity of that energy-optimal candidate.
    pub best_key: ConfigKey,
}

/// One portfolio candidate: a configuration feasible on every workload,
/// scored by per-workload energy regret vs each workload's own optimum.
#[derive(Debug, Clone)]
pub struct PortfolioEntry {
    pub key: ConfigKey,
    /// Total energy on each workload (same order as the input slice).
    pub energy_j: Vec<f64>,
    /// Regret vs the workload's feasible optimum, percent (>= 0).
    pub regret_pct: Vec<f64>,
    pub worst_regret_pct: f64,
    /// Weighted mean (equal weights unless supplied).
    pub mean_regret_pct: f64,
}

/// Full optimizer output. `portfolio` is sorted best-first by
/// (worst-case regret, mean regret, config identity); the robust-best
/// configuration is [`OptimizeResult::robust_best`].
#[derive(Debug, Clone)]
pub struct OptimizeResult {
    pub epsilon: f64,
    pub constraints: Constraints,
    pub workload_names: Vec<String>,
    pub frontiers: Vec<WorkloadFrontier>,
    pub portfolio: Vec<PortfolioEntry>,
}

impl OptimizeResult {
    pub fn robust_best(&self) -> Option<&PortfolioEntry> {
        self.portfolio.first()
    }
}

/// The three minimized objectives of a candidate.
#[inline]
fn objectives(p: &SweepPoint) -> [f64; 3] {
    [p.eval.e_total_j(), p.eval.avg_active_banks, p.eval.area_mm2]
}

/// Plain Pareto dominance (minimization): `a` beats-or-ties `b`
/// everywhere and strictly beats it somewhere.
#[inline]
fn dominates(a: &[f64; 3], b: &[f64; 3]) -> bool {
    let mut strict = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strict = true;
        }
    }
    strict
}

/// Multiplicative ε-dominance: `a` is within a factor `1+ε` of beating
/// `b` on every objective and strictly beats it on at least one.
/// Objectives are non-negative, so the multiplicative form is safe;
/// ε = 0 reduces to [`dominates`].
#[inline]
fn eps_dominates(a: &[f64; 3], b: &[f64; 3], eps: f64) -> bool {
    let scale = 1.0 + eps;
    let mut strict = false;
    for (x, y) in a.iter().zip(b) {
        if *x > y * scale {
            return false;
        }
        if x < y {
            strict = true;
        }
    }
    strict
}

/// Canonical deterministic processing order: objectives
/// lexicographically (total order on floats), tie-broken by config
/// identity. Dominators always sort before the points they dominate.
fn canonical_order(points: &[SweepPoint]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&i, &j| {
        let a = objectives(&points[i]);
        let b = objectives(&points[j]);
        a[0].total_cmp(&b[0])
            .then(a[1].total_cmp(&b[1]))
            .then(a[2].total_cmp(&b[2]))
            .then(ConfigKey::of(&points[i]).cmp(&ConfigKey::of(&points[j])))
    });
    order
}

/// Indices of the ε-Pareto frontier of `points` (minimizing energy,
/// activity, and area), in canonical order. With ε = 0 this is the exact
/// non-dominated set; larger ε thins near-duplicates. Regardless of ε,
/// no returned point is strictly dominated by *any* input point (a final
/// guard pass enforces this even when ε-thinning removed a point's
/// dominator chain).
pub fn pareto_frontier(points: &[SweepPoint], epsilon: f64) -> Vec<usize> {
    let obj: Vec<[f64; 3]> = points.iter().map(objectives).collect();
    let order = canonical_order(points);
    let mut archive: Vec<usize> = Vec::new();
    'candidates: for &i in &order {
        for &j in &archive {
            if eps_dominates(&obj[j], &obj[i], epsilon) {
                continue 'candidates;
            }
        }
        archive.retain(|&j| !eps_dominates(&obj[i], &obj[j], epsilon));
        archive.push(i);
    }
    // Final dominated-free guarantee across the *whole* input set.
    archive.retain(|&i| !(0..points.len()).any(|j| j != i && dominates(&obj[j], &obj[i])));
    // Restore canonical order (retain/push may have permuted it).
    let rank: BTreeMap<usize, usize> =
        order.iter().enumerate().map(|(r, &i)| (i, r)).collect();
    archive.sort_by_key(|i| rank[i]);
    archive
}

/// Run the optimizer over one or more workload sweeps: constraint
/// filtering, per-workload ε-Pareto frontiers, and — when every workload
/// shares at least one feasible configuration — the cross-workload
/// regret portfolio. `weights`, when given, must match `workloads` in
/// length and weighs the mean-regret tie-breaker (worst-case regret
/// always ranks first).
pub fn optimize(
    workloads: &[WorkloadSweep],
    constraints: &Constraints,
    epsilon: f64,
    weights: Option<&[f64]>,
) -> Result<OptimizeResult, OptimizeError> {
    if workloads.is_empty() {
        return Err(OptimizeError::NoWorkloads);
    }
    if !epsilon.is_finite() || epsilon < 0.0 {
        return Err(OptimizeError::InvalidEpsilon(epsilon));
    }
    let weights = match weights {
        None => vec![1.0; workloads.len()],
        Some(w) => {
            if w.len() != workloads.len() {
                return Err(OptimizeError::InvalidWeights(format!(
                    "{} weights for {} workloads",
                    w.len(),
                    workloads.len()
                )));
            }
            if w.iter().any(|x| !x.is_finite() || *x < 0.0) {
                return Err(OptimizeError::InvalidWeights(
                    "weights must be finite and >= 0".to_string(),
                ));
            }
            if w.iter().sum::<f64>() <= 0.0 {
                return Err(OptimizeError::InvalidWeights(
                    "weights must sum to > 0".to_string(),
                ));
            }
            w.to_vec()
        }
    };
    let weight_sum: f64 = weights.iter().sum();

    // Pass 1+2: per-workload constraint filter + frontier.
    let mut frontiers = Vec::with_capacity(workloads.len());
    // Per-workload feasible energy by config (for the portfolio pass).
    let mut energy_maps: Vec<BTreeMap<ConfigKey, f64>> = Vec::new();
    for w in workloads {
        let feasible: Vec<SweepPoint> = w
            .points
            .iter()
            .filter(|p| constraints.admits(p, w.end_cycles))
            .cloned()
            .collect();
        if feasible.is_empty() {
            return Err(OptimizeError::NoFeasibleConfigs {
                workload: w.name.clone(),
            });
        }
        // The canonical order sorts by energy first, so the workload's
        // energy optimum is the first canonical candidate.
        let order = canonical_order(&feasible);
        let best = &feasible[order[0]];
        let best_energy = best.eval.e_total_j();
        let best_key = ConfigKey::of(best);

        let frontier = pareto_frontier(&feasible, epsilon)
            .into_iter()
            .map(|i| FrontierPoint {
                wake_exposure_pct: wake_exposure_pct(&feasible[i], w.end_cycles),
                point: feasible[i].clone(),
            })
            .collect();

        let mut energies = BTreeMap::new();
        for p in &feasible {
            // Duplicate configs cannot arise from one grid sweep; keep
            // the first deterministically if a caller passes merged sets.
            energies
                .entry(ConfigKey::of(p))
                .or_insert_with(|| p.eval.e_total_j());
        }
        energy_maps.push(energies);

        frontiers.push(WorkloadFrontier {
            workload: w.name.clone(),
            end_cycles: w.end_cycles,
            feasible: feasible.len(),
            frontier,
            best_energy_j: best_energy,
            best_key,
        });
    }

    // Pass 3: portfolio over configurations feasible everywhere.
    let mut portfolio: Vec<PortfolioEntry> = Vec::new();
    for (key, &e0) in &energy_maps[0] {
        let mut energy_j = Vec::with_capacity(workloads.len());
        energy_j.push(e0);
        let mut shared = true;
        for m in &energy_maps[1..] {
            match m.get(key) {
                Some(&e) => energy_j.push(e),
                None => {
                    shared = false;
                    break;
                }
            }
        }
        if !shared {
            continue;
        }
        let regret_pct: Vec<f64> = energy_j
            .iter()
            .zip(&frontiers)
            .map(|(&e, f)| {
                if f.best_energy_j == 0.0 {
                    0.0
                } else {
                    (e - f.best_energy_j) / f.best_energy_j * 100.0
                }
            })
            .collect();
        let worst = regret_pct.iter().copied().fold(0.0f64, f64::max);
        let mean = regret_pct
            .iter()
            .zip(&weights)
            .map(|(r, w)| r * w)
            .sum::<f64>()
            / weight_sum;
        portfolio.push(PortfolioEntry {
            key: *key,
            energy_j,
            regret_pct,
            worst_regret_pct: worst,
            mean_regret_pct: mean,
        });
    }
    if portfolio.is_empty() {
        return Err(OptimizeError::NoSharedConfigs);
    }
    portfolio.sort_by(|a, b| {
        a.worst_regret_pct
            .total_cmp(&b.worst_regret_pct)
            .then(a.mean_regret_pct.total_cmp(&b.mean_regret_pct))
            .then(a.key.cmp(&b.key))
    });

    Ok(OptimizeResult {
        epsilon,
        constraints: constraints.clone(),
        workload_names: workloads.iter().map(|w| w.name.clone()).collect(),
        frontiers,
        portfolio,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::banking::sweep::{sweep_naive, SweepSpec};
    use crate::cacti::CactiModel;
    use crate::trace::{AccessStats, OccupancyTrace};

    fn synth_trace(cap: u64, occ: u64) -> OccupancyTrace {
        let mut tr = OccupancyTrace::new("sram", cap);
        let mut t = 0;
        while t < 50_000_000 {
            tr.record(t, occ, 0);
            tr.record(t + 200_000, occ / 8, 0);
            t += 1_000_000;
        }
        tr.finalize(50_000_000);
        tr
    }

    fn stats() -> AccessStats {
        AccessStats {
            reads: 5_000_000,
            writes: 2_000_000,
            ..Default::default()
        }
    }

    fn grid(capacities: Vec<u64>) -> SweepSpec {
        SweepSpec {
            capacities,
            banks: vec![1, 2, 4, 8, 16, 32],
            alphas: vec![0.9],
            policies: vec![
                GatingPolicy::None,
                GatingPolicy::Aggressive,
                GatingPolicy::conservative(),
                GatingPolicy::drowsy(),
            ],
        }
    }

    fn workload(name: &str, occ_mib: u64) -> WorkloadSweep {
        let tr = synth_trace(128 * MIB, occ_mib * MIB);
        let points = sweep_naive(
            &CactiModel::default(),
            &tr,
            &stats(),
            &grid(vec![64 * MIB, 96 * MIB, 128 * MIB]),
            1.0,
        )
        .unwrap();
        WorkloadSweep {
            name: name.to_string(),
            end_cycles: tr.end_time().unwrap(),
            points,
        }
    }

    #[test]
    fn frontier_is_dominated_free_and_covers_input() {
        let w = workload("mha-like", 60);
        let idx = pareto_frontier(&w.points, 0.0);
        assert!(!idx.is_empty());
        let obj: Vec<[f64; 3]> = w.points.iter().map(objectives).collect();
        // Dominated-free vs the whole sweep.
        for &i in &idx {
            for (j, o) in obj.iter().enumerate() {
                assert!(
                    j == i || !dominates(o, &obj[i]),
                    "frontier point {i} dominated by {j}"
                );
            }
        }
        // Every non-frontier point is weakly dominated by some member.
        for (j, o) in obj.iter().enumerate() {
            if idx.contains(&j) {
                continue;
            }
            assert!(
                idx.iter().any(|&i| (0..3).all(|k| obj[i][k] <= o[k])),
                "point {j} neither on frontier nor covered"
            );
        }
    }

    #[test]
    fn epsilon_thins_but_never_admits_dominated_points() {
        let w = workload("gqa-like", 20);
        let exact = pareto_frontier(&w.points, 0.0);
        let thinned = pareto_frontier(&w.points, 0.25);
        assert!(!thinned.is_empty());
        let obj: Vec<[f64; 3]> = w.points.iter().map(objectives).collect();
        for &i in &thinned {
            for (j, o) in obj.iter().enumerate() {
                assert!(j == i || !dominates(o, &obj[i]));
            }
        }
        assert!(
            thinned.len() <= exact.len(),
            "thinning must not grow the frontier: {} vs {}",
            thinned.len(),
            exact.len()
        );
    }

    #[test]
    fn frontier_is_deterministic() {
        let w = workload("det", 40);
        let a = pareto_frontier(&w.points, 0.1);
        let b = pareto_frontier(&w.points, 0.1);
        assert_eq!(a, b);
    }

    #[test]
    fn constraints_filter_area_capacity_and_wake() {
        let w = workload("constrained", 40);
        let unconstrained = optimize(
            std::slice::from_ref(&w),
            &Constraints::default(),
            0.0,
            None,
        )
        .unwrap();
        assert_eq!(unconstrained.frontiers[0].feasible, w.points.len());

        let min_cap = optimize(
            std::slice::from_ref(&w),
            &Constraints {
                min_capacity: Some(96 * MIB),
                ..Default::default()
            },
            0.0,
            None,
        )
        .unwrap();
        assert!(min_cap.frontiers[0].feasible < w.points.len());
        for f in &min_cap.frontiers[0].frontier {
            assert!(f.point.eval.capacity >= 96 * MIB);
        }

        let tight_area = optimize(
            std::slice::from_ref(&w),
            &Constraints {
                max_area_overhead_pct: Some(5.0),
                ..Default::default()
            },
            0.0,
            None,
        )
        .unwrap();
        for f in &tight_area.frontiers[0].frontier {
            assert!(f.point.delta_a_pct() <= 5.0);
        }

        // An unattainable bound is a typed error, not a panic.
        let err = optimize(
            std::slice::from_ref(&w),
            &Constraints {
                min_capacity: Some(1 << 60),
                ..Default::default()
            },
            0.0,
            None,
        )
        .unwrap_err();
        assert!(matches!(err, OptimizeError::NoFeasibleConfigs { .. }));
    }

    #[test]
    fn portfolio_minimizes_worst_case_regret() {
        // Two workloads with different occupancy shapes: their own
        // optima differ, and the robust pick must brute-force-minimize
        // the worst-case regret over shared configs.
        let a = workload("heavy", 100);
        let b = workload("light", 10);
        let r = optimize(&[a, b], &Constraints::default(), 0.0, None).unwrap();
        assert_eq!(r.workload_names, vec!["heavy", "light"]);
        let best = r.robust_best().unwrap();
        for e in &r.portfolio {
            assert!(
                best.worst_regret_pct <= e.worst_regret_pct + 1e-12,
                "{:?} beats robust-best",
                e.key
            );
            assert_eq!(e.regret_pct.len(), 2);
            for &reg in &e.regret_pct {
                assert!(reg >= -1e-12 && reg.is_finite());
            }
        }
        // Per-workload optima carry zero regret on their own workload.
        for (wi, f) in r.frontiers.iter().enumerate() {
            let own = r
                .portfolio
                .iter()
                .find(|e| e.key == f.best_key);
            if let Some(own) = own {
                assert!(own.regret_pct[wi].abs() < 1e-9);
            }
        }
    }

    #[test]
    fn divergent_optima_produce_nonzero_robust_regret() {
        // A config optimal for one workload is generally not optimal for
        // the other; the robust pick's worst-case regret is then the
        // headline number. At minimum the result must be internally
        // consistent: worst >= each per-workload regret >= 0.
        let a = workload("mha", 90);
        let b = workload("gqa", 12);
        let r = optimize(&[a, b], &Constraints::default(), 0.0, None).unwrap();
        let best = r.robust_best().unwrap();
        for &reg in &best.regret_pct {
            assert!(best.worst_regret_pct >= reg - 1e-12);
        }
        // Both frontiers must be non-trivial and name their own best.
        for f in &r.frontiers {
            assert!(!f.frontier.is_empty());
            assert!(f.best_energy_j > 0.0);
        }
    }

    #[test]
    fn weights_shift_mean_but_not_worst_ranking_key() {
        let a = workload("wa", 80);
        let b = workload("wb", 16);
        let even = optimize(&[a.clone(), b.clone()], &Constraints::default(), 0.0, None)
            .unwrap();
        let skewed = optimize(
            &[a, b],
            &Constraints::default(),
            0.0,
            Some(&[10.0, 0.1]),
        )
        .unwrap();
        // Same shared-config set either way.
        assert_eq!(even.portfolio.len(), skewed.portfolio.len());
        for (e, s) in even.portfolio.iter().zip(&skewed.portfolio) {
            // Worst-case regret is weight-independent (it ranks first,
            // so entries stay keyed by it)...
            assert!(e.worst_regret_pct >= 0.0 && s.worst_regret_pct >= 0.0);
        }
    }

    #[test]
    fn typed_errors_for_bad_inputs() {
        assert_eq!(
            optimize(&[], &Constraints::default(), 0.0, None).unwrap_err(),
            OptimizeError::NoWorkloads
        );
        let w = workload("w", 30);
        assert!(matches!(
            optimize(std::slice::from_ref(&w), &Constraints::default(), -0.5, None)
                .unwrap_err(),
            OptimizeError::InvalidEpsilon(_)
        ));
        assert!(matches!(
            optimize(
                std::slice::from_ref(&w),
                &Constraints::default(),
                0.0,
                Some(&[1.0, 2.0])
            )
            .unwrap_err(),
            OptimizeError::InvalidWeights(_)
        ));
        assert!(matches!(
            optimize(
                std::slice::from_ref(&w),
                &Constraints::default(),
                0.0,
                Some(&[0.0])
            )
            .unwrap_err(),
            OptimizeError::InvalidWeights(_)
        ));
    }

    #[test]
    fn config_key_roundtrips_policy_and_orders_deterministically() {
        let w = workload("keys", 24);
        for p in &w.points {
            let k = ConfigKey::of(p);
            assert_eq!(k.policy(), p.eval.policy);
            assert_eq!(k.alpha().to_bits(), p.eval.alpha.to_bits());
            assert!(k.label().contains(&format!("B{}", p.eval.banks)));
        }
        let mut keys: Vec<ConfigKey> = w.points.iter().map(ConfigKey::of).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), w.points.len(), "grid configs must be unique");
    }

    #[test]
    fn wake_exposure_accounting() {
        let w = workload("wake", 40);
        for p in &w.points {
            let e = wake_exposure_pct(p, w.end_cycles);
            assert!(e.is_finite() && e >= 0.0);
            if p.eval.n_switch == 0 {
                assert_eq!(e, 0.0);
            }
        }
        // Zero-length run: exposure is defined as 0.
        assert_eq!(wake_exposure_pct(&w.points[0], 0), 0.0);
    }
}
